"""Per-user equalizer serving demo: a fleet of QRD-RLS filters under load.

The serving story end to end, small enough to read:

1. resolve a named deployment preset (`repro.serve.presets`) to a
   `QRDConfig` + fleet shape, and bring up the `FleetServer`;
2. admit two cohorts of users (each user = one adaptive equalizer slot);
3. stream synthetic per-user traffic (`SyntheticTraffic`: every user has
   a fixed hidden channel, snapshots are noisy observations of it)
   through the async snapshot queue;
4. checkpoint mid-stream, keep serving, evict a cohort, restore — and
   verify the restored weights are bit-identical to the served ones;
5. report convergence: the fleet's weights vs the ground-truth channels.

    PYTHONPATH=src python examples/serve_fleet.py \
        [--preset equalizer-float64] [--slots 4096] [--steps 300]

The CI serve-smoke lane runs exactly this at 2^17 slots and 1000 pump
batches (`python -m repro.launch.serve`); this example is the annotated
small-scale version.
"""
import argparse
import tempfile
import time

import numpy as np

from repro.data.pipeline import SyntheticTraffic
from repro.qrd import QRDEngine
from repro.serve import FleetServer, fleet_preset, list_fleet_presets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="equalizer-float64",
                    choices=sorted(list_fleet_presets()))
    ap.add_argument("--slots", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    # --- 1. declarative bring-up --------------------------------------------
    spec = fleet_preset(args.preset, slots=args.slots)
    print(f"preset {args.preset!r}: {spec['description']}")
    print(f"config JSON: {spec['config'].to_json()}")
    fleet = QRDEngine(spec["config"]).fleet(**spec["fleet"])
    ckpt_dir = tempfile.mkdtemp(prefix="fleet_ckpt_")
    server = FleetServer(fleet, ckpt_dir=ckpt_dir, **spec["server"])

    # --- 2. two cohorts of users --------------------------------------------
    n_users = min(256, args.slots // 2)
    server.admit_cohort("cell-north", n_users)
    server.admit_cohort("cell-south", n_users)
    print(f"fleet: {fleet!r}")

    # --- 3. serve synthetic traffic -----------------------------------------
    traffic = SyntheticTraffic(users=n_users, n=fleet.n,
                               per_step=server.batch,
                               complex_dtype=fleet.is_complex, seed=7)
    applied, t0 = 0, time.perf_counter()
    for step in range(args.steps):
        tick = traffic.batch(step)
        cell = "cell-north" if step % 2 == 0 else "cell-south"
        server.submit_batch(cell, np.asarray(tick["user"]),
                            np.asarray(tick["x"]), np.asarray(tick["d"]))
        applied += server.pump()
        if step == args.steps // 2:
            server.checkpoint()          # async: serving continues
    rate = applied / (time.perf_counter() - t0)
    health = server.health()
    print(f"\nserved {applied} updates in {server.step} batches "
          f"({rate:,.0f} updates/s)")
    print(f"backlogs: " + ", ".join(
        f"{name}={c['backlog']}" for name, c in health["cohorts"].items()))

    # --- 4. checkpoint -> evict -> restore, bit-exactly ---------------------
    server.checkpoint(wait=True)
    members = np.arange(8)
    w_served = server.query("cell-north", members)
    server.evict_cohort("cell-north")            # slots recycled...
    server.restore_latest()                      # ...and rolled back
    w_restored = server.query("cell-north", members)
    assert np.array_equal(w_served, w_restored), "restore lost bits!"
    print("evict -> restore: weights bit-identical")

    # --- 5. convergence vs the hidden channels ------------------------------
    w = server.query("cell-north")
    truth = np.stack([np.asarray(traffic.channel(u)) for u in range(n_users)])
    touched = np.asarray(
        fleet.state.updates)[server.cohorts()[0].start:][:n_users] > 0
    err = np.linalg.norm(w[touched] - truth[touched], axis=1)
    err /= np.linalg.norm(truth[touched], axis=1)
    print(f"converged users: {int(touched.sum())}/{n_users}, median "
          f"relative channel error {np.median(err):.2e}")


if __name__ == "__main__":
    main()
