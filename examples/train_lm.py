"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the full production stack — config registry, synthetic data pipeline,
QMuon (Givens-QR orthogonalized) or AdamW, async checkpointing, preemption
handling — on a single host.  The model is a width/depth-reduced qwen3-style
decoder sized to ~100M params.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--optimizer qmuon]
"""
import argparse
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import init_params, train_loss
from repro.optim import (adamw_init, adamw_update, qmuon_init, qmuon_update,
                         warmup_cosine)
from repro.runtime import PreemptionHandler


def model_100m():
    base = get_config("qwen3-8b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=12, d_model=640, n_heads=10,
        n_kv_heads=5, head_dim=64, d_ff=2560, vocab=32768,
        dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", choices=("adamw", "qmuon"), default="qmuon")
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = model_100m()
    lr = args.lr or (0.02 if args.optimizer == "qmuon" else 3e-4)
    ds = SyntheticLM(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch,
                     seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    preempt = PreemptionHandler()

    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params, "
          f"optimizer={args.optimizer}, lr={lr}")

    opt_init, opt_update = ((qmuon_init, qmuon_update)
                            if args.optimizer == "qmuon"
                            else (adamw_init, adamw_update))
    opt = opt_init(params)

    @jax.jit
    def step_fn(params, opt, batch, step):
        (loss, metrics), g = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch), has_aux=True)(params)
        lr_t = warmup_cosine(step, peak_lr=lr, warmup_steps=50,
                             total_steps=args.steps)
        params, opt = opt_update(g, opt, params, lr=lr_t)
        return params, opt, loss

    # resume if a checkpoint exists
    start = 0
    got = mgr.restore_latest({"params": params, "opt": opt})
    if got[0] is not None:
        start, state, extra = got
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    t0 = time.time()
    tokens_seen = 0
    for s in range(start, args.steps):
        params, opt, loss = step_fn(params, opt, ds.batch(s),
                                    jnp.asarray(s, jnp.int32))
        tokens_seen += args.batch * args.seq
        if (s + 1) % 20 == 0:
            tps = tokens_seen / (time.time() - t0)
            print(f"step {s+1:4d}  loss {float(loss):.4f}  "
                  f"{tps/1e3:.1f}k tok/s")
        if (s + 1) % args.ckpt_every == 0 or preempt.should_stop:
            mgr.save_async(s + 1, {"params": params, "opt": opt},
                           extra={"data_step": s + 1})
        if preempt.should_stop:
            print("preempted: checkpointed and exiting cleanly")
            break
    mgr.wait()
    print(f"done: final loss {float(loss):.4f} "
          f"({time.time()-t0:.0f}s, {tokens_seen/1e6:.1f}M tokens)")
    return float(loss)


if __name__ == "__main__":
    main()
