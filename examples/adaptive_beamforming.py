"""QRD-RLS adaptive beamforming — the paper's own application domain.

A narrowband uniform linear array receives a desired signal plus two
interferers; the beamformer weights solve the recursive least-squares
problem.  Instead of forming the (ill-conditioned) covariance matrix, the
numerically-robust QRD-RLS update triangularizes the forgetting-factor-
weighted data matrix with Givens rotations — each new snapshot is annihilated
into R by exactly the rotations the paper's unit computes (vectoring on the
leading pair, sigma-replay across the row).

    PYTHONPATH=src python examples/adaptive_beamforming.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import GivensConfig, GivensUnit, qr_givens_float

N_ANT = 8          # array elements
SNAPSHOTS = 200
LAMBDA = 0.99      # forgetting factor


def steering(theta_deg, n=N_ANT):
    d = 0.5  # half-wavelength spacing
    k = 2 * np.pi * d * np.sin(np.deg2rad(theta_deg))
    return np.exp(1j * k * np.arange(n))


def qrd_rls_update(R, z, x, d, lam, unit=None, rot_fn=None):
    """One QRD-RLS step: rotate snapshot x (and target d) into (R | z).

    Complex arithmetic is carried as interleaved real rotations; with
    `unit` given, the rotations run on the paper's bit-accurate CORDIC
    engine (rot_fn = jitted unit.rotate_rows), else in f64 Givens.
    """
    R = np.sqrt(lam) * R
    z = np.sqrt(lam) * z
    work = np.concatenate([R, z[:, None]], axis=1)         # (n, n+1)
    row = np.concatenate([x, [d]])                         # (n+1,)
    for k in range(R.shape[0]):
        a, b = work[k, k], row[k]
        if unit is None:
            r = np.hypot(a, b)
            if r == 0:
                continue
            c, s = a / r, b / r
            wk = c * work[k] + s * row
            row = -s * work[k] + c * row
            work[k] = wk
        else:
            # roll so the pivot column leads: one fixed shape -> one compile
            xr, yr = rot_fn(
                unit.encode(jnp.asarray(np.roll(work[k], -k))),
                unit.encode(jnp.asarray(np.roll(row, -k))))
            work[k] = np.roll(np.asarray(unit.decode(xr)), k)
            rolled = np.array(unit.decode(yr))  # writable copy
            rolled[0] = 0.0
            row = np.roll(rolled, k)
    return work[:, :-1], work[:, -1]


def main(use_cordic=True):
    rng = np.random.default_rng(0)
    a_sig = steering(10.0)
    a_i1 = steering(-40.0)
    a_i2 = steering(55.0)

    # real-valued formulation: stack real/imag parts
    def snap():
        s = rng.normal() * 1.0
        i1 = rng.normal() * 3.0
        i2 = rng.normal() * 3.0
        noise = (rng.normal(size=N_ANT) + 1j * rng.normal(size=N_ANT)) * 0.1
        x = s * a_sig + i1 * a_i1 + i2 * a_i2 + noise
        return np.concatenate([x.real, x.imag]), s

    n = 2 * N_ANT
    R = np.eye(n) * 1e-3
    z = np.zeros(n)
    unit = GivensUnit(GivensConfig(hub=True, n=26)) if use_cordic else None
    import jax
    rot_fn = jax.jit(unit.rotate_rows) if unit else None

    errs = []
    for t in range(SNAPSHOTS):
        x, d = snap()
        R, z = qrd_rls_update(R, z, x, d, LAMBDA, unit=unit, rot_fn=rot_fn)
        # back-substitute for the weights and measure output error
        w = np.linalg.solve(R + 1e-12 * np.eye(n), z)
        errs.append((x @ w - d) ** 2)
        if (t + 1) % 100 == 0:
            print(f"step {t+1:4d}: MSE(last 50) = "
                  f"{np.mean(errs[-50:]):.4f}")

    mse_end = np.mean(errs[-50:])
    sig_power = 1.0          # var(s); interferers are 9x stronger each
    rejection_db = 10 * np.log10(sig_power / mse_end)
    print(f"\nQRD-RLS beamformer ({'CORDIC-HUB unit' if use_cordic else 'f64'}):"
          f" residual MSE {mse_end:.5f} vs signal power {sig_power:.1f} "
          f"-> {rejection_db:.1f} dB interference rejection")
    assert mse_end < 0.05 * sig_power
    return mse_end


def main_blocked(block=4):
    """Block QRD-RLS on the kernel-resident blocked Givens engine.

    The per-snapshot loop above launches n rotations from Python for every
    snapshot.  Here a whole block of snapshots is stacked under [R | z] and
    annihilated by ONE kernel-resident schedule
    (`repro.kernels.ops.givens_block_apply`) — the paper's pipeline replay
    at block granularity: the working tile stays resident across all
    block · n rotations, with a single fixed-point encode/decode.

    Exponential forgetting is preserved exactly: the carried state is
    weighted by lambda^(block/2) and row i of the block by
    lambda^((block-1-i)/2), which telescopes to the per-snapshot recursion.
    """
    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    a_sig = steering(10.0)
    a_i1 = steering(-40.0)
    a_i2 = steering(55.0)

    def snap():
        s = rng.normal() * 1.0
        i1 = rng.normal() * 3.0
        i2 = rng.normal() * 3.0
        noise = (rng.normal(size=N_ANT) + 1j * rng.normal(size=N_ANT)) * 0.1
        x = s * a_sig + i1 * a_i1 + i2 * a_i2 + noise
        return np.concatenate([x.real, x.imag]), s

    n = 2 * N_ANT
    R = np.eye(n) * 1e-3
    z = np.zeros(n)
    # annihilate column k of every stacked snapshot row against pivot row k
    steps = tuple((k, n + j, k) for k in range(n) for j in range(block))
    lam_half = np.sqrt(LAMBDA)

    errs = []
    pending = []
    for t in range(SNAPSHOTS):
        x, d = snap()
        pending.append(np.concatenate([x, [d]]))
        if len(pending) == block:
            top = np.concatenate([R, z[:, None]], axis=1) * lam_half ** block
            rows = np.stack([row * lam_half ** (block - 1 - i)
                             for i, row in enumerate(pending)])
            W = np.concatenate([top, rows], axis=0)[None]    # (1, n+B, n+1)
            Wp = np.asarray(kops.givens_block_apply(W, steps, hub=True))[0]
            R, z = Wp[:n, :n], Wp[:n, n]
            pending = []
        w = np.linalg.solve(R + 1e-12 * np.eye(n), z)
        errs.append((x @ w - d) ** 2)
        if (t + 1) % 100 == 0:
            print(f"step {t+1:4d}: MSE(last 50) = {np.mean(errs[-50:]):.4f}")

    mse_end = np.mean(errs[-50:])
    rejection_db = 10 * np.log10(1.0 / mse_end)
    print(f"\nBlock QRD-RLS beamformer (kernel-resident, block={block}):"
          f" residual MSE {mse_end:.5f} -> {rejection_db:.1f} dB "
          f"interference rejection")
    assert mse_end < 0.05
    return mse_end


if __name__ == "__main__":
    main()
    main_blocked()
