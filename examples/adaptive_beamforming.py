"""QRD-RLS adaptive beamforming — the paper's own application domain.

A narrowband uniform linear array receives a desired signal plus two
interferers; the beamformer weights solve the recursive least-squares
problem.  Instead of forming the (ill-conditioned) covariance matrix, the
numerically-robust QRD-RLS update triangularizes the forgetting-factor-
weighted data matrix with Givens rotations — each new snapshot is annihilated
into R by exactly the rotations the paper's unit computes (vectoring on the
leading pair, sigma-replay across the row).

The whole loop now runs on the library's streaming RLS state
(`repro.qrd.QRDEngine.rls` / `repro.qrd.RLSState`): ``state.update(x, d)``
absorbs a snapshot on the backend-appropriate path — per-snapshot on the
bit-accurate CORDIC-HUB unit, or ``block`` snapshots per kernel-resident
blocked annihilation — and ``state.weights()`` back-substitutes the
carried triangular factor for the beamformer weights.

    PYTHONPATH=src python examples/adaptive_beamforming.py
"""
import numpy as np

from repro.core import GivensConfig
from repro.qrd import QRDEngine

N_ANT = 8          # array elements
SNAPSHOTS = 200
LAMBDA = 0.99      # forgetting factor


def steering(theta_deg, n=N_ANT):
    d = 0.5  # half-wavelength spacing
    k = 2 * np.pi * d * np.sin(np.deg2rad(theta_deg))
    return np.exp(1j * k * np.arange(n))


def make_snapshots(rng, complex_baseband=False):
    """One (x, s) draw: desired signal + two 9x-stronger interferers.

    ``complex_baseband=False`` carries the complex arithmetic as
    interleaved real rotations (stacked real/imag parts — the real-valued
    QRD-RLS formulation a real-only unit operates on).  With
    ``complex_baseband=True`` the snapshot is the physical complex
    baseband vector itself, for the complex datapath (DESIGN.md §10).
    """
    a_sig = steering(10.0)
    a_i1 = steering(-40.0)
    a_i2 = steering(55.0)

    def snap():
        s = rng.normal() * 1.0
        i1 = rng.normal() * 3.0
        i2 = rng.normal() * 3.0
        noise = (rng.normal(size=N_ANT) + 1j * rng.normal(size=N_ANT)) * 0.1
        x = s * a_sig + i1 * a_i1 + i2 * a_i2 + noise
        if complex_baseband:
            return x, s
        return np.concatenate([x.real, x.imag]), s

    return snap


def run_beamformer(state, label, snapshots=SNAPSHOTS, mse_bound=0.05,
                   complex_baseband=False):
    """Drive a library RLS state through the snapshot stream."""
    rng = np.random.default_rng(0)
    snap = make_snapshots(rng, complex_baseband=complex_baseband)
    errs = []
    for t in range(snapshots):
        x, d = snap()
        state.update(x, d)
        w = state.weights()          # back-substituted beamformer weights
        errs.append(np.abs(x @ w - d) ** 2)
        if (t + 1) % 100 == 0:
            print(f"step {t+1:4d}: MSE(last 50) = {np.mean(errs[-50:]):.4f}")

    mse_end = np.mean(errs[-50:])
    sig_power = 1.0          # var(s); interferers are 9x stronger each
    rejection_db = 10 * np.log10(sig_power / mse_end)
    print(f"\nQRD-RLS beamformer ({label}): residual MSE {mse_end:.5f} "
          f"vs signal power {sig_power:.1f} "
          f"-> {rejection_db:.1f} dB interference rejection")
    assert mse_end < mse_bound * sig_power
    return mse_end


def main(use_cordic=True, snapshots=SNAPSHOTS):
    """Per-snapshot QRD-RLS on the unit (or the f64 float baseline)."""
    n = 2 * N_ANT
    backend = "cordic" if use_cordic else "givens_float"
    eng = QRDEngine(backend=backend,
                    givens=GivensConfig(hub=True, n=26))
    state = eng.rls(n, lam=LAMBDA, delta=1e-3)
    label = "CORDIC-HUB unit" if use_cordic else "f64"
    return run_beamformer(state, label, snapshots=snapshots)


def main_blocked(block=4, snapshots=SNAPSHOTS):
    """Block QRD-RLS on the kernel-resident blocked Givens engine.

    The per-snapshot path launches n rotations for every snapshot.  Here
    the state batches ``block`` snapshots and annihilates them under
    ``[R | z]`` with ONE kernel-resident schedule
    (`repro.kernels.ops.rls_block_steps` on `ops.givens_block_apply`) —
    the paper's pipeline replay at block granularity, with exponential
    forgetting telescoped exactly (`repro.qrd.RLSState.flush`).
    """
    n = 2 * N_ANT
    eng = QRDEngine(backend="blockfp_pallas",
                    givens=GivensConfig(hub=True, n=26))
    state = eng.rls(n, lam=LAMBDA, delta=1e-3, block=block)
    return run_beamformer(state, f"kernel-resident, block={block}",
                          snapshots=snapshots)


def main_complex(use_cordic=True, snapshots=SNAPSHOTS):
    """Complex QRD-RLS on the physical baseband snapshots (DESIGN.md §10).

    The interleaved-real formulation above doubles the filter length to
    carry re/im parts through a real-only rotator.  With the complex
    datapath the state carries ``N_ANT`` genuinely complex weights and
    every snapshot is annihilated by the three-rotation decomposition —
    two phase rotations realizing the leading entries plus the real
    Givens of the paper's unit — so the beamformer runs on the
    physically-meaningful complex baseband model directly.
    """
    backend = "cordic" if use_cordic else "givens_float"
    eng = QRDEngine(backend=backend, dtype="complex128",
                    givens=GivensConfig(hub=True, n=26))
    state = eng.rls(N_ANT, lam=LAMBDA, delta=1e-3)
    label = ("complex baseband, CORDIC-HUB unit" if use_cordic
             else "complex baseband, f64")
    return run_beamformer(state, label, snapshots=snapshots,
                          complex_baseband=True)


if __name__ == "__main__":
    main()
    main_blocked()
    main_complex()
