"""Quickstart: the FP Givens rotation unit and the solver-grade QRD API.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import GivensConfig, GivensUnit, QRDEngine, snr_db, hub_quantize
from repro import qrd


def main():
    # --- 1. a single Givens rotation, bit-accurate --------------------------
    unit = GivensUnit(GivensConfig(hub=True, n=26))   # paper's best config
    x, y = np.float64(3.0), np.float64(4.0)
    r, y0, angle_state = unit.vector(unit.encode(x), unit.encode(y))
    print(f"vectoring (3,4): r = {float(unit.decode(r)):.7f}  "
          f"(exact 5), residual y = {float(unit.decode(y0)):.2e}")

    # the sigma bits ARE the angle: replay them on another pair (paper Sec 3.2)
    x2, y2 = unit.rotate(unit.encode(np.float64(10.0)),
                         unit.encode(np.float64(0.0)), angle_state)
    print(f"rotate (10,0) by the same angle -> "
          f"({float(unit.decode(x2)):.5f}, {float(unit.decode(y2)):.5f})  "
          f"(exact (6, -8))")

    # --- 2. batched QR decomposition on the registry-dispatched engine ------
    print("\nregistered backends:",
          ", ".join(qrd.available_backends()))
    rng = np.random.default_rng(0)
    A = rng.normal(size=(1000, 4, 4))
    results = {}
    for backend in ("cordic", "cordic_pallas", "givens_float", "jnp"):
        eng = qrd.QRDEngine(backend=backend,
                            givens=GivensConfig(hub=True, n=26))
        Q, R = eng(A)
        results[backend] = (np.asarray(Q), np.asarray(R))
        print(f"QRD[{backend:13s}] mean SNR = "
              f"{float(jnp.mean(snr_db(A, Q, R))):7.2f} dB")
    # the kernel-resident blocked engine is bit-identical to the loop
    exact = all((results["cordic"][i] == results["cordic_pallas"][i]).all()
                for i in range(2))
    print(f"cordic_pallas bit-identical to cordic: {exact}")
    assert exact
    # the legacy dataclass still works, as a shim over the same registry
    lQ, lR = QRDEngine(backend="cordic",
                       givens_config=GivensConfig(hub=True, n=26))(A)
    assert (np.asarray(lQ) == results["cordic"][0]).all()

    # --- 3. problem level: least squares without forming Q ------------------
    Am = rng.normal(size=(8, 6, 3))
    b = rng.normal(size=(8, 6))
    eng = qrd.QRDEngine(backend="cordic", givens=GivensConfig(hub=True, n=26))
    xs, resid = eng.solve(Am, b, return_residuals=True)
    ref = np.stack([np.linalg.lstsq(Am[i], b[i], rcond=None)[0]
                    for i in range(8)])
    err = float(np.max(np.abs(np.asarray(xs) - ref)))
    print(f"\nsolve() vs np.linalg.lstsq: max |dx| = {err:.2e} "
          f"(tolerances: repro.qrd.SOLVE_TOLERANCES)")
    assert err < 1e-4

    # --- 4. streaming QRD-RLS (adaptive filtering) --------------------------
    n = 4
    w_true = rng.normal(size=n)
    state = eng.rls(n, lam=0.995)
    for _ in range(200):
        xv = rng.normal(size=n)
        state.update(xv, w_true @ xv + 0.01 * rng.normal())
    werr = float(np.linalg.norm(state.weights() - w_true))
    print(f"QRD-RLS on the unit: ||w - w_true|| = {werr:.4f} "
          f"after {state.updates} snapshots")
    assert werr < 0.05

    # --- 5. HUB numerics as a primitive -------------------------------------
    v = np.float64(1.2345678)
    print(f"\nhub_quantize(1.2345678, m=10) = "
          f"{float(hub_quantize(v, 10)):.7f} (round-to-nearest by truncation)")


if __name__ == "__main__":
    main()
