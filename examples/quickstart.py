"""Quickstart: the FP Givens rotation unit and the QRD engine in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (GivensConfig, GivensUnit, QRDEngine, snr_db,
                        hub_quantize)


def main():
    # --- 1. a single Givens rotation, bit-accurate --------------------------
    unit = GivensUnit(GivensConfig(hub=True, n=26))   # paper's best config
    x, y = np.float64(3.0), np.float64(4.0)
    r, y0, angle_state = unit.vector(unit.encode(x), unit.encode(y))
    print(f"vectoring (3,4): r = {float(unit.decode(r)):.7f}  "
          f"(exact 5), residual y = {float(unit.decode(y0)):.2e}")

    # the sigma bits ARE the angle: replay them on another pair (paper Sec 3.2)
    x2, y2 = unit.rotate(unit.encode(np.float64(10.0)),
                         unit.encode(np.float64(0.0)), angle_state)
    print(f"rotate (10,0) by the same angle -> "
          f"({float(unit.decode(x2)):.5f}, {float(unit.decode(y2)):.5f})  "
          f"(exact (6, -8))")

    # --- 2. batched QR decomposition on the engine ---------------------------
    rng = np.random.default_rng(0)
    A = rng.normal(size=(1000, 4, 4))
    results = {}
    for backend in ("cordic", "cordic_pallas", "givens_float", "jnp"):
        eng = QRDEngine(backend=backend,
                        givens_config=GivensConfig(hub=True, n=26))
        Q, R = eng(A)
        results[backend] = (np.asarray(Q), np.asarray(R))
        print(f"QRD[{backend:13s}] mean SNR = "
              f"{float(jnp.mean(snr_db(A, Q, R))):7.2f} dB")
    # the kernel-resident blocked engine is bit-identical to the loop
    exact = all((results["cordic"][i] == results["cordic_pallas"][i]).all()
                for i in range(2))
    print(f"cordic_pallas bit-identical to cordic: {exact}")
    assert exact

    # --- 3. HUB numerics as a primitive --------------------------------------
    v = np.float64(1.2345678)
    print(f"hub_quantize(1.2345678, m=10) = {float(hub_quantize(v, 10)):.7f} "
          f"(round-to-nearest by truncation)")


if __name__ == "__main__":
    main()
