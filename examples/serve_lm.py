"""Batched serving demo: prefill a batch of prompts, then decode greedily.

Exercises the same prefill/decode_step code paths the production serve cells
lower (KV caches, ring-buffer windows, SSM states), on a small model.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-780m]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                                    jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros(
            (args.batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)

    prefill_jit = jax.jit(lambda p, b: prefill(cfg, p, b, max_len))
    step_jit = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))

    t0 = time.time()
    logits, cache = prefill_jit(params, batch)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step_jit(params, tok, cache, args.prompt_len + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} (reduced) batch={args.batch}")
    print(f"prefill {args.prompt_len} tokens: {t_prefill*1e3:.0f} ms "
          f"(includes compile)")
    print(f"decode {args.gen-1} steps: "
          f"{(args.gen-1)*args.batch/t_decode:.1f} tok/s")
    print(f"first sampled ids: {gen[0, :10].tolist()}")
    assert np.all(gen >= 0) and np.all(gen < cfg.vocab)


if __name__ == "__main__":
    main()
