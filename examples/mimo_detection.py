"""Batched 4x4 MIMO detection on the complex QRD engine (DESIGN.md §10).

The paper motivates its rotation unit with "advanced signal processing
and communication applications"; MIMO detection is the flagship one: a
receiver with ``Nr`` antennas observes ``y = H s + n`` where ``H`` is the
complex channel matrix and ``s`` a vector of QPSK symbols, and every
channel use needs a fresh complex least-squares solve — exactly the
workload a hardware array of complex Givens rotators (three-rotation
decomposition, §10) is built for.

Two classic detectors, both on `repro.qrd.QRDEngine`:

* **ZF** (zero forcing): ``ŝ = slice(argmin_s ||H s - y||)`` — one
  batched ``engine.solve(H, y)`` over all channel uses, then a symbol
  slicer.
* **SQRD** (sorted-QRD successive interference cancellation): columns of
  H are sorted by norm (weakest first, so the most reliable stream is
  detected first from the bottom row of R), ``Q, R = engine(H_sorted)``,
  and symbols are detected successively from the last row of
  ``R ŝ = Q^H y`` with decisions fed back — the standard V-BLAST-style
  QRD detector.

Run:  PYTHONPATH=src python examples/mimo_detection.py

Prints a BER-vs-SNR table for both detectors and sanity-checks the
expected behavior (BER decreases with SNR; SQRD does not lose to ZF at
high SNR beyond Monte-Carlo noise).
"""
import numpy as np

from repro.core import GivensConfig
from repro.qrd import QRDEngine

NT = NR = 4            # 4x4 MIMO
SNRS_DB = (0.0, 5.0, 10.0, 15.0, 20.0)
CHANNEL_USES = 400     # batch of independent channel realizations


def qpsk_symbols(rng, shape):
    """Unit-energy Gray-mapped QPSK: (±1 ± 1j)/√2."""
    bits = rng.integers(0, 2, size=shape + (2,))
    return ((1 - 2 * bits[..., 0]) + 1j * (1 - 2 * bits[..., 1])) / np.sqrt(2)


def qpsk_slice(x):
    """Hard decision back onto the QPSK grid."""
    return (np.sign(x.real) + 1j * np.sign(x.imag)) / np.sqrt(2)


def qpsk_bit_errors(s_hat, s):
    """Bit errors between sliced symbols and the transmitted grid points."""
    return (np.sum(np.sign(s_hat.real) != np.sign(s.real))
            + np.sum(np.sign(s_hat.imag) != np.sign(s.imag)))


def detect_zf(engine, H, y):
    """Zero forcing: one batched complex least-squares solve."""
    return qpsk_slice(np.asarray(engine.solve(H, y)))


def detect_sqrd(engine, H, y):
    """Sorted-QRD successive interference cancellation.

    Per channel use: permute columns by ascending norm, decompose the
    permuted channel on the engine, rotate the observation by ``Q^H``,
    then detect from the bottom row of R upward, subtracting decided
    symbols (decision feedback).  Returns symbols in the original
    antenna order.
    """
    B = H.shape[0]
    norms = np.linalg.norm(H, axis=1)                  # (B, NT) column norms
    perm = np.argsort(norms, axis=1)                   # weakest first
    Hp = np.take_along_axis(H, perm[:, None, :], axis=2)
    Q, R = engine(Hp)
    Q, R = np.asarray(Q), np.asarray(R)
    z = np.einsum("bij,bi->bj", Q[:, :, :NT].conj(), y)  # (Q^H y)[:NT]
    s_hat = np.zeros((B, NT), dtype=complex)
    for k in range(NT - 1, -1, -1):
        resid = z[:, k] - np.einsum("bj,bj->b", R[:, k, k + 1:],
                                    s_hat[:, k + 1:])
        s_hat[:, k] = qpsk_slice(resid / R[:, k, k])
    out = np.zeros_like(s_hat)
    np.put_along_axis(out, perm, s_hat, axis=1)
    return out


def run(engine=None, snrs_db=SNRS_DB, uses=CHANNEL_USES, seed=0,
        verbose=True):
    """BER-vs-SNR sweep for both detectors.  Returns {detector: [BER]}."""
    if engine is None:
        engine = QRDEngine(backend="cordic", dtype="complex64",
                           givens=GivensConfig(hub=True, n=26))
    rng = np.random.default_rng(seed)
    bers = {"zf": [], "sqrd": []}
    if verbose:
        print(f"{NT}x{NR} MIMO, QPSK, {uses} channel uses per point, "
              f"backend={engine.config.backend!r} "
              f"dtype={engine.config.dtype!r}")
        print(f"{'SNR[dB]':>8} {'BER(ZF)':>10} {'BER(SQRD)':>10}")
    for snr_db in snrs_db:
        # SNR per receive antenna: E|h s|^2 = NT * Es = NT, noise var sigma^2.
        sigma = np.sqrt(NT / 10.0 ** (snr_db / 10.0))
        H = (rng.standard_normal((uses, NR, NT))
             + 1j * rng.standard_normal((uses, NR, NT))) / np.sqrt(2)
        s = qpsk_symbols(rng, (uses, NT))
        n = sigma * (rng.standard_normal((uses, NR))
                     + 1j * rng.standard_normal((uses, NR))) / np.sqrt(2)
        y = np.einsum("bij,bj->bi", H, s) + n
        nbits = 2 * uses * NT
        for name, det in (("zf", detect_zf), ("sqrd", detect_sqrd)):
            bers[name].append(qpsk_bit_errors(det(engine, H, y), s) / nbits)
        if verbose:
            print(f"{snr_db:8.1f} {bers['zf'][-1]:10.4f} "
                  f"{bers['sqrd'][-1]:10.4f}")
    return bers


def main():
    bers = run()
    # Sanity: detection actually works — BER falls with SNR and is small
    # at 20 dB (ZF 4x4 QPSK at 20 dB is well under a few percent; SQRD's
    # ordered decision feedback does at least as well up to MC noise).
    assert bers["zf"][-1] < bers["zf"][0]
    assert bers["sqrd"][-1] < bers["sqrd"][0]
    assert bers["zf"][-1] < 0.02, bers["zf"]
    assert bers["sqrd"][-1] <= bers["zf"][-1] + 0.01, (
        bers["sqrd"][-1], bers["zf"][-1])
    print("\nOK: BER decreases with SNR; SQRD >= ZF reliability at 20 dB")
    return bers


if __name__ == "__main__":
    main()
