"""Tiled-route smoke: the ISSUE-10 acceptance shapes end-to-end.

CI entry point for the tiled lane (DESIGN.md §14).  Exercises the two
production shapes the tiled datapath exists for, through the public
engine (so routing, tuned-knob resolution, and the jit cache are all on
the hot path, not a kernel-level shortcut):

* **64x64 panel** — ``tiling='auto'`` must resolve to the panel route;
  full factors, reconstruction and orthogonality checked.
* **4096x32 TSQR** — ``'auto'`` must resolve to the tree route; the
  economy R is checked upper-triangular and against ``np.linalg.qr``
  up to row signs.
* **bit-identity probe** — a small packed TSQR against
  ``tiled.tsqr_host_reference``: R must match *bitwise* (the full-size
  parity matrix lives in the tier-1 suite; this keeps the contract
  armed in the lane that owns the shapes).
* **bench row sanity** — the committed BENCH_qrd.json must carry the
  ``tiled:{m}x{n}`` rows `check_bench_regression.REQUIRED_ROWS` pins.

    PYTHONPATH=src python -m benchmarks.tiled_smoke
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

_BENCH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_qrd.json")


def main():
    import jax
    jax.config.update("jax_enable_x64", True)
    import repro.qrd as api
    from repro.qrd import tiled

    failures = []
    rng = np.random.default_rng(7)

    # 64x64 through the panel route: full Q, float-grade checks (the
    # block-FP datapath at frac=24 is ~1e-5-grade on 64-row columns).
    eng = api.QRDEngine(api.QRDConfig(backend="blockfp_pallas",
                                      dtype="float64"))
    caps = eng.capabilities
    route = tiled.resolve_route(eng.config, 64, 64, caps)
    A = rng.standard_normal((64, 64))
    Q, R = eng(A)
    recon = float(np.max(np.abs(np.asarray(Q) @ np.asarray(R) - A)))
    orth = float(np.max(np.abs(np.asarray(Q).T @ np.asarray(Q)
                               - np.eye(Q.shape[-1]))))
    ok = route == "panel" and recon < 2e-3 and orth < 1e-3
    print(f"{'ok ' if ok else 'FAIL'} 64x64 route={route} "
          f"recon={recon:.2e} orth={orth:.2e}")
    if not ok:
        failures.append("64x64 panel")

    # 4096x32 through the TSQR tree: economy R, sign-normalized vs LAPACK.
    route = tiled.resolve_route(eng.config, 4096, 32, caps)
    A = rng.standard_normal((4096, 32))
    _, R = eng(A, compute_q=False)
    R = np.asarray(R)
    Rref = np.linalg.qr(A, mode="r")
    tri = float(np.max(np.abs(np.tril(R, -1))))
    rerr = float(np.max(np.abs(np.abs(R) - np.abs(Rref))))
    tol = 1e-3 * float(np.max(np.abs(Rref)))
    ok = (route == "tsqr" and R.shape == (32, 32) and tri == 0.0
          and rerr < tol)
    print(f"{'ok ' if ok else 'FAIL'} 4096x32 route={route} "
          f"R{R.shape} |R|err={rerr:.2e} (tol {tol:.1e})")
    if not ok:
        failures.append("4096x32 tsqr")

    # Packed bit-identity probe: engine TSQR vs the host tree replay.
    import jax.numpy as jnp
    from repro.core import qrd as core_qrd
    from repro.core.givens import GivensConfig, GivensUnit
    peng = api.QRDEngine(api.QRDConfig(backend="cordic_pallas",
                                       tiling="tsqr", tile_m=12))
    Ap = rng.standard_normal((40, 4))
    _, Rt = peng(Ap, compute_q=False)
    unit = GivensUnit(GivensConfig())
    _, Rh = tiled.tsqr_host_reference(
        Ap, lambda X: core_qrd.qr_cordic(jnp.asarray(X), unit), tile_m=12)
    bit = bool(np.all(np.asarray(Rt) == Rh))
    print(f"{'ok ' if bit else 'FAIL'} packed tsqr 40x4 R bit-identical "
          f"to host tree: {bit}")
    if not bit:
        failures.append("packed tsqr bit-identity")

    # Bench row sanity: the committed baseline must measure the shapes.
    from benchmarks.check_bench_regression import REQUIRED_ROWS
    with open(_BENCH) as fh:
        rows = json.load(fh).get("results", {})
    for key in REQUIRED_ROWS:
        row = rows.get(key)
        ok = (row is not None and row.get("qrd_per_s")
              and row.get("roofline_fraction") is not None)
        print(f"{'ok ' if ok else 'FAIL'} BENCH_qrd.json[{key!r}]: "
              f"{'present with rate + roofline' if ok else 'missing/incomplete'}")
        if not ok:
            failures.append(f"bench row {key}")

    if failures:
        print(f"tiled_smoke: {len(failures)} failure(s): "
              f"{', '.join(failures)}", file=sys.stderr)
        return 1
    print("tiled_smoke: production shapes OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
