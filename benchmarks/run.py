"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints per-benchmark CSV blocks and a final ``name,us_per_call,derived``
summary line per benchmark (emitted by each module via csv_row).
--full restores the paper's 10,000-sample / full-r-sweep protocol.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    from . import (fig8_snr_vs_range, fig9_snr_vs_iters, fig10_variants,
                   fig11_fixed_vs_fp, table1_4_cost_model, table5_fixp_vs_fp,
                   table6_7_throughput)
    mods = [fig8_snr_vs_range, fig9_snr_vs_iters, fig10_variants,
            fig11_fixed_vs_fp, table1_4_cost_model, table5_fixp_vs_fp,
            table6_7_throughput]
    t0 = time.time()
    for mod in mods:
        name = mod.__name__.split(".")[-1]
        print(f"\n===== {name} =====", flush=True)
        t = time.time()
        mod.main(full=full)
        print(f"# {name}: {time.time()-t:.1f}s", flush=True)
    print(f"\n# total: {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
