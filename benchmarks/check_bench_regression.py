"""Diff a fresh BENCH_qrd.json against the committed baseline — CI gate.

Fails (exit 1) when any backend×schedule row present in both files has a
cold end-to-end time (``end_to_end_s``: trace + compile + first run) more
than ``factor`` times the baseline's, or when a baseline row disappeared
from the fresh run (coverage regression).  New rows in the fresh run are
reported but never fail — adding benchmarks is progress.

Cold time is the gated metric because it is the one the wavefront/trace
work optimizes and the least noisy across CI machines at interpret-mode
magnitudes (tens of seconds); steady-state rates are printed for
eyeballing but not gated.

    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_qrd.json BENCH_qrd.fresh.json [--factor 2.0]

``REPRO_BENCH_REGRESSION_FACTOR`` overrides the factor (CI escape hatch
for known-slow runners without editing the workflow).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FACTOR = 2.0


def compare(baseline: dict, fresh: dict, factor: float):
    """Return (failures, report_lines) for two BENCH_qrd.json documents."""
    base_rows = baseline.get("results", {})
    fresh_rows = fresh.get("results", {})
    failures, lines = [], []
    for key in sorted(base_rows):
        if key not in fresh_rows:
            failures.append(f"{key}: row missing from fresh run")
            continue
        b = base_rows[key].get("end_to_end_s")
        f = fresh_rows[key].get("end_to_end_s")
        if b is None or f is None:
            continue
        ratio = f / b if b > 0 else float("inf")
        status = "FAIL" if ratio > factor else "ok"
        lines.append(f"{status:4s} {key}: cold {f:8.3f}s vs baseline "
                     f"{b:8.3f}s ({ratio:.2f}x)")
        if ratio > factor:
            failures.append(f"{key}: cold end-to-end {f:.3f}s is "
                            f"{ratio:.2f}x the baseline {b:.3f}s "
                            f"(> {factor:.1f}x)")
    for key in sorted(set(fresh_rows) - set(base_rows)):
        lines.append(f"new  {key}: cold "
                     f"{fresh_rows[key].get('end_to_end_s', float('nan')):.3f}s"
                     " (no baseline)")
    return failures, lines


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_qrd.json")
    ap.add_argument("fresh", help="freshly measured BENCH_qrd.json")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get(
                        "REPRO_BENCH_REGRESSION_FACTOR", DEFAULT_FACTOR)),
                    help="max allowed cold-time ratio fresh/baseline")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures, lines = compare(baseline, fresh, args.factor)
    print(f"# bench regression check (factor {args.factor:.1f}x): "
          f"{args.fresh} vs {args.baseline}")
    for line in lines:
        print(line)
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("# no cold-time regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
