"""Diff a fresh BENCH_qrd.json against the committed baseline — CI gate.

Schema-version aware (``schema_version`` 2 is current):

* **Warm gate** — fails (exit 1) when any row present in both files has
  a warm time (``warm_s``: median of steady-state ``block_until_ready``
  reps) more than ``factor`` times the baseline's.  v1 documents (no
  ``warm_s``) fall back to the old cold ``end_to_end_s`` gate with a
  warning — cold times conflate trace/compile with execution and are
  reported but never gated on v2 documents.
* **Coverage gate** — a baseline row missing from the fresh run fails.
* **Roofline gate** — a fresh row measured **compiled**
  (``interpret_mode`` explicitly false) must achieve at least
  ``--min-roofline`` of its analytic bound (``roofline_fraction``);
  interpret-mode rows are exempt (they measure the emulator, not the
  device), so the gate is inert on CPU-only CI and arms itself the
  moment a compiled lane produces numbers.

New rows in the fresh run are reported but never fail — adding
benchmarks is progress.

    PYTHONPATH=src python -m benchmarks.check_bench_regression \
        BENCH_qrd.json BENCH_qrd.fresh.json [--factor 2.0] \
        [--min-roofline 0.02]

``REPRO_BENCH_REGRESSION_FACTOR`` / ``REPRO_BENCH_MIN_ROOFLINE``
override the thresholds (CI escape hatches for known-slow runners
without editing the workflow).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_FACTOR = 2.0
DEFAULT_MIN_ROOFLINE = 0.02

#: Rows every fresh run must contain (enforced by `main`, i.e. CI).
#: "New rows never fail" means the coverage gate alone cannot notice a
#: *lane* silently dropping out of the bench before its rows ever land
#: in a committed baseline — the tiled-datapath acceptance rows
#: (DESIGN.md §14: panel 64x64, TSQR 4096x32) are pinned here so a
#: refactor that stops measuring them fails loudly.
REQUIRED_ROWS = ("tiled:64x64", "tiled:4096x32")


def _gate_metric(doc: dict):
    """('warm_s', None) for v2 docs, ('end_to_end_s', warning) for v1."""
    if doc.get("schema_version", 1) >= 2:
        return "warm_s", None
    return "end_to_end_s", ("baseline is schema v1 (no warm_s): gating on "
                            "cold end_to_end_s — regenerate the baseline")


def compare(baseline: dict, fresh: dict, factor: float,
            min_roofline: float = DEFAULT_MIN_ROOFLINE,
            required: tuple = ()):
    """Return (failures, report_lines) for two BENCH_qrd.json documents.

    ``required`` lists row keys the *fresh* document must contain
    independent of the baseline (`REQUIRED_ROWS` when invoked as the CI
    gate via `main`; empty for library callers comparing arbitrary
    documents).
    """
    base_rows = baseline.get("results", {})
    fresh_rows = fresh.get("results", {})
    failures, lines = [], []
    for key in required:
        if key not in fresh_rows:
            failures.append(f"{key}: required row missing from fresh run")
            lines.append(f"FAIL {key}: required row missing")
    metric, warning = _gate_metric(baseline)
    f_metric, f_warning = _gate_metric(fresh)
    gate = metric if metric == f_metric else "end_to_end_s"
    for w in {warning, f_warning} - {None}:
        lines.append(f"warn {w}")
    if gate != metric or gate != f_metric:
        lines.append("warn mixed schema versions: gating on cold "
                     "end_to_end_s for comparability")

    for key in sorted(base_rows):
        if key not in fresh_rows:
            failures.append(f"{key}: row missing from fresh run")
            continue
        b = base_rows[key].get(gate)
        f = fresh_rows[key].get(gate)
        if b is None or f is None:
            continue
        ratio = f / b if b > 0 else float("inf")
        status = "FAIL" if ratio > factor else "ok"
        label = "warm" if gate == "warm_s" else "cold"
        cold_note = ""
        if gate == "warm_s":
            bc = base_rows[key].get("cold_s")
            fc = fresh_rows[key].get("cold_s")
            if bc and fc:
                cold_note = f"  [cold {fc:.3f}s vs {bc:.3f}s]"
        lines.append(f"{status:4s} {key}: {label} {f:8.4f}s vs baseline "
                     f"{b:8.4f}s ({ratio:.2f}x){cold_note}")
        if ratio > factor:
            failures.append(f"{key}: {label} time {f:.4f}s is "
                            f"{ratio:.2f}x the baseline {b:.4f}s "
                            f"(> {factor:.1f}x)")

    # Roofline gate: compiled rows only (interpret_mode explicitly False).
    for key in sorted(fresh_rows):
        row = fresh_rows[key]
        if row.get("interpret_mode") is not False:
            continue
        frac = row.get("roofline_fraction")
        if frac is None:
            continue
        status = "FAIL" if frac < min_roofline else "ok"
        lines.append(f"{status:4s} {key}: compiled roofline fraction "
                     f"{frac:.3f} (floor {min_roofline:.3f})")
        if frac < min_roofline:
            failures.append(f"{key}: compiled row achieves only "
                            f"{frac:.3f} of the analytic roofline "
                            f"(< {min_roofline:.3f})")

    for key in sorted(set(fresh_rows) - set(base_rows)):
        v = fresh_rows[key].get(gate)
        lines.append(f"new  {key}: {v if v is None else format(v, '.4f')}s"
                     " (no baseline)")
    return failures, lines


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_qrd.json")
    ap.add_argument("fresh", help="freshly measured BENCH_qrd.json")
    ap.add_argument("--factor", type=float,
                    default=float(os.environ.get(
                        "REPRO_BENCH_REGRESSION_FACTOR", DEFAULT_FACTOR)),
                    help="max allowed warm-time ratio fresh/baseline")
    ap.add_argument("--min-roofline", type=float,
                    default=float(os.environ.get(
                        "REPRO_BENCH_MIN_ROOFLINE", DEFAULT_MIN_ROOFLINE)),
                    help="min roofline fraction for compiled rows")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    failures, lines = compare(baseline, fresh, args.factor,
                              args.min_roofline, required=REQUIRED_ROWS)
    print(f"# bench regression check (factor {args.factor:.1f}x, "
          f"roofline floor {args.min_roofline:.3f}): "
          f"{args.fresh} vs {args.baseline}")
    for line in lines:
        print(line)
    if failures:
        print(f"\n{len(failures)} regression(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("# no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
