"""Table 5 — fixed-point (32b) vs FP-HUB (single, N=26) implementation compare.

FPGA delay/LUT/power columns are replaced by the structural cost model of
table1_4 plus measured emulation throughput; the SNR columns (the
architectural argument for FP: dynamic range) are fully reproduced.
"""
from __future__ import annotations

from repro.core import GivensConfig, SINGLE

from .common import csv_row, gen_matrices, snr_cordic, snr_fixed
from .table1_4_cost_model import cost_model


def main(full=False):
    # cost model: FixP rotator = CORDIC core only (no converters)
    fx = cost_model(SINGLE, 32, 27, hub=False)
    fx_core_only = fx["core_bits"]
    hub = cost_model(SINGLE, 26, 24, hub=True)
    print("# table5: design,model_adder_bits,paper_luts")
    print(f"fixp32,{fx_core_only},1947")
    print(f"fp_hub_32_26,{hub['adder_bits']},2182")
    ratio = hub["adder_bits"] / fx_core_only
    print(f"# model FP/FixP area ratio {ratio:.2f} (paper: 1.12)")

    # dynamic-range sweep (the reason FP exists)
    print("# table5_snr: r,fixp32,hub_n26")
    wins = 0
    for r in (2, 6, 10, 14, 20, 30):
        A = gen_matrices(5000 + r, r)
        s_fx = snr_fixed(A, 32, 27, scale_exp=r)
        s_hub = snr_cordic(GivensConfig(hub=True), A, N=26, iters=24)
        print(f"{r},{s_fx:.2f},{s_hub:.2f}")
        wins += s_hub > s_fx
    csv_row("table5_fixp_vs_fp", 0.0,
            f"model_area_ratio={ratio:.2f};hub_wins_{wins}_of_6_r_points")


if __name__ == "__main__":
    main()
