"""Fig. 9 — SNR vs number of CORDIC micro-rotations for N = 25..30.

Paper's observations to reproduce:
  - conventional (IEEE) peaks at N-3 micro-rotations, then *degrades*;
  - HUB needs N-2 and does not degrade with more iterations;
  - HUB(N) tracks IEEE(N+1); N=29 and N=30 saturate at single-precision.
"""
from __future__ import annotations

import numpy as np

from repro.core import GivensConfig

from .common import R_SET, csv_row, gen_matrices, snr_cordic


def main(full=False):
    ns = range(25, 31)
    print("# fig9: variant,N,iters,mean_snr_db")
    As = {r: gen_matrices(2000 + r, r) for r in (R_SET if not full
                                                 else range(1, 21))}
    out = {}
    for hub in (False, True):
        cfg = GivensConfig(hub=hub)
        for n in ns:
            for it in range(n - 6, min(n + 2, 31)):
                snr = float(np.mean([snr_cordic(cfg, A, N=n, iters=it)
                                     for A in As.values()]))
                out[(hub, n, it)] = snr
                print(f"{'hub' if hub else 'ieee'},{n},{it},{snr:.2f}")
    # derived: argmax iteration count per (variant, N)
    peaks = {}
    for hub in (False, True):
        for n in ns:
            best = max((it for (h, nn, it) in out if h == hub and nn == n),
                       key=lambda it: out[(hub, n, it)])
            peaks[("hub" if hub else "ieee", n)] = best - n
    csv_row("fig9_snr_vs_iters", 0.0,
            ";".join(f"{k[0]}N{k[1]}peak=N{v:+d}" for k, v in peaks.items()))
    return out, peaks


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
