"""Shared Monte-Carlo machinery for the paper's error analysis (Sec. 5.1).

Protocol (faithful to the paper):
  - N_SAMPLES random 4x4 matrices per dynamic-range point r; entries have
    magnitude in [2^-r, 2^r] (log-uniform), random sign;
  - QRD with Q computed by augmenting rows with I (e = 8 elements/row);
  - SNR_dB = 10 log10(sum A^2 / sum (A - QR)^2), reconstruction in float64;
  - reference: jnp.linalg.qr in single precision ("Matlab qr").

The paper uses 10,000 samples; default here is 2,000 for CPU-CI speed
(REPRO_BENCH_SAMPLES=10000 or --full restores the paper's count).  (N, iters)
are traced scalars, so an entire Fig. 9-style sweep reuses ONE compilation
per architecture variant.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (GivensConfig, GivensUnit, qr_cordic, qr_fixed,
                        qr_jnp, snr_db)

N_SAMPLES = int(os.environ.get("REPRO_BENCH_SAMPLES", "2000"))
R_SET = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_RSET", "1,5,10,15,20").split(","))


def gen_matrices(seed: int, r: float, n: int = None, m: int = 4):
    """(n, m, m) float64 with |a_ij| log-uniform in [2^-r, 2^r]."""
    n = N_SAMPLES if n is None else n
    rng = np.random.default_rng(seed)
    mag = np.exp2(rng.uniform(-r, r, size=(n, m, m)))
    sign = rng.choice([-1.0, 1.0], size=(n, m, m))
    return sign * mag


@functools.lru_cache(maxsize=32)
def _sweep_fn(cfg: GivensConfig):
    """One jitted (A, N, iters) -> mean SNR function per unit variant."""
    unit = GivensUnit(cfg)

    @jax.jit
    def run(A, N, iters):
        Q, R = qr_cordic(A, unit, N=N, iters=iters)
        return jnp.mean(snr_db(A, Q, R))

    return run


def snr_cordic(cfg: GivensConfig, A, N=None, iters=None) -> float:
    N = cfg.n if N is None else N
    iters = (GivensConfig(**{**cfg.__dict__, "n": int(N)}).default_iters()
             if iters is None else iters)
    return float(_sweep_fn(cfg)(A, jnp.asarray(N), jnp.asarray(iters)))


@jax.jit
def _snr_jnp(A):
    Q, R = qr_jnp(A, jnp.float32)
    return jnp.mean(snr_db(A, Q, R))


def snr_reference(A) -> float:
    return float(_snr_jnp(A))


@functools.partial(jax.jit, static_argnames=("width", "iters"))
def _snr_fixed(A, width, iters, scale_exp):
    Q, R = qr_fixed(A, width, iters, scale_exp)
    return jnp.mean(snr_db(A, Q, R))


def snr_fixed(A, width=32, iters=27, scale_exp=0) -> float:
    return float(_snr_fixed(A, width, iters, jnp.asarray(scale_exp)))


def mean_snr_over_r(fn, seed0=0, r_set=None) -> float:
    """Paper-style summary: mean SNR across the dynamic-range sweep."""
    r_set = R_SET if r_set is None else r_set
    vals = [fn(gen_matrices(seed0 + i, r)) for i, r in enumerate(r_set)]
    return float(np.mean(vals))


def timed(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def csv_row(name, us_per_call, derived):
    print(f"{name},{us_per_call:.3f},{derived}")
