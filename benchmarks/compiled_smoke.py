"""Compiled-Pallas smoke: blockfp interpret=False parity where possible.

CI entry point for the compiled lane (DESIGN.md §11).  On a host with a
compiled Pallas backend (TPU/GPU), runs the int32 block-FP blocked QRD
with ``interpret=False`` and asserts bit-identity against the interpret
path — the "compiled-mode performance truth" guarantee that the numbers
BENCH_qrd.json reports for compiled rows come from the same arithmetic
CI validates in interpret mode.  On CPU-only hosts it exits 0 with a
notice (there is nothing to compile against; the interpret path is
already covered by the tier-1 suite).

    PYTHONPATH=src python -m benchmarks.compiled_smoke
"""
from __future__ import annotations

import sys

import numpy as np


def main():
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.kernels.ops import compiled_backend_available

    if not compiled_backend_available():
        print(f"compiled_smoke: no compiled Pallas backend on "
              f"'{jax.default_backend()}' — skipping (exit 0). "
              "Run on TPU/GPU to exercise interpret=False.")
        return 0

    import jax.numpy as jnp
    from repro.core.qrd import (givens_schedule, qr_blockfp_pallas,
                                qr_blockfp_wavefront, sameh_kuck_schedule)

    rng = np.random.default_rng(0)
    failures = 0
    for m, batch in ((4, 64), (8, 32)):
        A = jnp.asarray(rng.standard_normal((batch, m, m)))
        for name, fn in (
                ("col", lambda X, i: qr_blockfp_pallas(
                    X, steps=givens_schedule(m, m), interpret=i)),
                ("sameh_kuck", lambda X, i: qr_blockfp_wavefront(
                    X, stages=sameh_kuck_schedule(m, m), interpret=i))):
            Qc, Rc = fn(A, False)   # compiled
            Qi, Ri = fn(A, True)    # interpret reference
            q_ok = bool(jnp.all(Qc == Qi))
            r_ok = bool(jnp.all(Rc == Ri))
            status = "ok " if (q_ok and r_ok) else "FAIL"
            print(f"{status} blockfp/{name} {m}x{m} batch={batch}: "
                  f"compiled == interpret (Q: {q_ok}, R: {r_ok})")
            if not (q_ok and r_ok):
                failures += 1
    if failures:
        print(f"{failures} compiled-vs-interpret mismatch(es)",
              file=sys.stderr)
        return 1
    print("compiled_smoke: all compiled outputs bit-identical to interpret")
    return 0


if __name__ == "__main__":
    sys.exit(main())
