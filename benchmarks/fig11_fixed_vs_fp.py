"""Fig. 11 — fixed-point (32b) vs floating-point (single, N=26) over r=1..40.

Paper's observations to reproduce:
  - FixP beats FP for small r (more effective fraction bits);
  - FP-HUB overtakes FixP around r ~ 8;
  - FixP SNR decays with r and collapses past r ~ 14; FP stays flat.
"""
from __future__ import annotations

from repro.core import GivensConfig

from .common import csv_row, gen_matrices, snr_cordic, snr_fixed, snr_reference


def main(full=False):
    rs = range(1, 41) if full else range(2, 41, 4)
    print("# fig11: r,variant,snr_db")
    crossover = None
    collapse = None
    for r in rs:
        A = gen_matrices(4000 + r, r)
        fx = snr_fixed(A, width=32, iters=27, scale_exp=r)
        ieee = snr_cordic(GivensConfig(hub=False), A, N=26, iters=23)
        hub = snr_cordic(GivensConfig(hub=True), A, N=26, iters=24)
        ref = snr_reference(A)
        for name, v in [("fixp32", fx), ("ieee_n26", ieee),
                        ("hub_n26", hub), ("matlab_qr_f32", ref)]:
            print(f"{r},{name},{v:.2f}")
        if crossover is None and hub > fx:
            crossover = r
        if collapse is None and fx < 40.0:
            collapse = r
    csv_row("fig11_fixed_vs_fp", 0.0,
            f"hub_overtakes_fixp_at_r={crossover};fixp_collapse_r={collapse}")
    return crossover, collapse


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
