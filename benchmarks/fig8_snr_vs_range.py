"""Fig. 8 — QRD SNR vs input dynamic range r, IEEE vs HUB, N = 25/27/29.

Paper's observations to reproduce:
  - SNR changes only slightly with r;
  - HUB(N) beats IEEE(N) at equal N (HUB needs one bit less for parity).
"""
from __future__ import annotations

from repro.core import GivensConfig

from .common import N_SAMPLES, csv_row, gen_matrices, snr_cordic, snr_reference


def main(full=False):
    rs = range(1, 21) if full else (1, 5, 10, 15, 20)
    ns = (25, 27, 29)
    print("# fig8: r,variant,N,iters,snr_db")
    rows = []
    for r in rs:
        A = gen_matrices(1000 + r, r)
        ref = snr_reference(A)
        rows.append(("fig8", r, "matlab_qr_f32", "-", "-", ref))
        for n in ns:
            for hub in (False, True):
                cfg = GivensConfig(hub=hub)
                it = n - 2 if hub else n - 3
                snr = snr_cordic(cfg, A, N=n, iters=it)
                rows.append(("fig8", r, "hub" if hub else "ieee", n, it, snr))
    for row in rows:
        print(",".join(str(x) for x in row))
    # summary assertions mirrored in tests: HUB >= IEEE at same N (mean)
    import numpy as np
    hub = np.mean([x[-1] for x in rows if x[2] == "hub"])
    ieee = np.mean([x[-1] for x in rows if x[2] == "ieee"])
    csv_row("fig8_snr_vs_range", 0.0,
            f"mean_hub={hub:.2f}dB;mean_ieee={ieee:.2f}dB;samples={N_SAMPLES}")
    return hub, ieee


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
