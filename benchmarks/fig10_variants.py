"""Fig. 10 — design-variant study vs N.

IEEE: input-converter rounding (IEEERound) vs truncation (IEEETrunc);
HUB:  full (unbiased + identity detection) / unbiased-only / detectI-only /
      basic (biased, no detection).

Paper's observations to reproduce:
  - IEEERound does NOT beat IEEETrunc (rounding the input alignment shift
    is wasted hardware);
  - identity detection is worth up to ~4 dB (the Q-accumulation rows carry
    exact 1.0s); unbiased extension only matters without detection.
"""
from __future__ import annotations

import numpy as np

from repro.core import GivensConfig

from .common import csv_row, gen_matrices, snr_cordic, R_SET

VARIANTS = {
    "IEEETrunc": GivensConfig(hub=False, input_rounding="trunc"),
    "IEEERound": GivensConfig(hub=False, input_rounding="rne"),
    "HUBFull": GivensConfig(hub=True, unbiased=True, detect_identity=True),
    "HUBunbias": GivensConfig(hub=True, unbiased=True, detect_identity=False),
    "HUBDetectI": GivensConfig(hub=True, unbiased=False, detect_identity=True),
    "HUBBasic": GivensConfig(hub=True, unbiased=False, detect_identity=False),
}


def main(full=False):
    ns = range(25, 31)
    rset = range(1, 21) if full else R_SET
    As = {r: gen_matrices(3000 + r, r) for r in rset}
    print("# fig10: variant,N,mean_snr_db")
    res = {}
    for name, cfg in VARIANTS.items():
        for n in ns:
            it = n - 2 if cfg.hub else n - 3
            snr = float(np.mean([snr_cordic(cfg, A, N=n, iters=it)
                                 for A in As.values()]))
            res[(name, n)] = snr
            print(f"{name},{n},{snr:.2f}")
    gain = np.mean([res[("HUBFull", n)] - res[("HUBBasic", n)] for n in ns])
    round_gain = np.mean([res[("IEEERound", n)] - res[("IEEETrunc", n)]
                          for n in ns])
    csv_row("fig10_variants", 0.0,
            f"detectI+unbias_gain={gain:.2f}dB;ieee_round_gain={round_gain:.2f}dB")
    return res


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
