"""Tables 6-7 — throughput/latency/area vs prior FP CORDIC designs.

The initiation-interval model is exact (it is architectural, not
technological):
    ours          II = e                     (vectoring/rotation overlapped)
    FP CORDIC[32] II = 69 + e                (angle before rotations)
    FP CORDIC[21] II = 212 + 224 e           (word-serial)
    7x7 QRD [30]  II = 364
Throughput at each design's reported fmax reproduces the paper's MOp/s
column; we also measure our emulation's actual throughput on this CPU
(vectorized over a batch of rotations — the "spatial" analogue of the
pipeline) and the Pallas-kernel (interpret mode) rotations/s.
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, timed

E = 8  # elements per row (4x4 QRD with Q, as in the paper)

DESIGNS = {
    # name: (fmax MHz, latency cycles, II(e) lambda)
    "fp_cordic_[21]": (67.1, 224, lambda e: 212 + 224 * e),
    "fp_cordic_[32]": (173.3, 138, lambda e: 69 + e),
    "hub_fp_rotator (ours)": (255.8, 60, lambda e: e),
}
PAPER_MOPS = {"fp_cordic_[21]": 0.033, "fp_cordic_[32]": 2.25,
              "hub_fp_rotator (ours)": 31.97}


def measured_kernel_rate(batch=512, L=128, iters=24):
    import jax.numpy as jnp
    from repro.kernels import ops
    x = (np.random.default_rng(0).uniform(-1.5, 1.5, (2, batch, L))
         * 2 ** 24).astype(np.int32)
    xj, yj = jnp.asarray(x[0]), jnp.asarray(x[1])

    def run():
        return ops.givens_rotate_rows_fixed(xj, yj, iters=iters, hub=True)

    sec = timed(run)
    return batch / sec


def measured_qrd_rates(batch=64, m=4):
    """Full 4x4 QRD throughput: per-step reference loop vs the
    kernel-resident blocked engines (DESIGN.md §5).

    The architectural delta: the 'cordic' loop makes 2·steps HBM passes
    over the working set (one read + one write per rotation launch); the
    blocked kernels make exactly 2 (stage in, write back).
    """
    import jax.numpy as jnp
    from repro.core import GivensConfig, QRDEngine

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.choice([-1.0, 1.0], (batch, m, m))
                    * np.exp2(rng.uniform(-4, 4, (batch, m, m))))
    steps = m * (m - 1) // 2
    cfg = GivensConfig(hub=True, n=26)
    out = {}
    for backend in ("cordic", "cordic_pallas", "blockfp_pallas"):
        eng = QRDEngine(backend=backend, givens_config=cfg)
        sec = timed(lambda: eng(A))
        passes = 2 * steps if backend == "cordic" else 2
        out[backend] = (batch / sec, passes)
    return out


def main(full=False):
    print("# table6: design,fmax_mhz,latency_cyc,II_e8,mops_model,mops_paper")
    rows = []
    for name, (fmax, lat, ii) in DESIGNS.items():
        mops = fmax / ii(E)
        rows.append((name, mops))
        print(f"{name},{fmax},{lat},{ii(E)},{mops:.3f},{PAPER_MOPS[name]}")
    ours = dict(rows)["hub_fp_rotator (ours)"]
    gen = dict(rows)["fp_cordic_[32]"]
    print(f"# speedup vs [32]: {ours/gen:.1f}x (paper: ~15x)")
    print("# table7: design,precision,luts_paper")
    for n, l in [("fp_cordic_[21]", 11718), ("fp_cordic_[32]", 22189),
                 ("hub_fp_rotator", 8463)]:
        print(f"{n},double,{l}")

    print("# blocked QRD engines: backend,qrd_per_s,hbm_passes_per_qrd")
    qrd = measured_qrd_rates()
    for backend, (qps, passes) in qrd.items():
        print(f"{backend},{qps:.1f},{passes}")

    rate = measured_kernel_rate()
    csv_row("table6_7_throughput", 1e6 / rate,
            f"model_speedup_vs_[32]={ours/gen:.1f}x;"
            f"pallas_interp_rot_per_s={rate:.0f};"
            f"qrd_loop_per_s={qrd['cordic'][0]:.1f};"
            f"qrd_blocked_per_s={qrd['cordic_pallas'][0]:.1f};"
            f"qrd_blockfp_per_s={qrd['blockfp_pallas'][0]:.1f}")


if __name__ == "__main__":
    main()
