"""Tables 6-7 — throughput/latency/area vs prior FP CORDIC designs.

The initiation-interval model is exact (it is architectural, not
technological):
    ours          II = e                     (vectoring/rotation overlapped)
    FP CORDIC[32] II = 69 + e                (angle before rotations)
    FP CORDIC[21] II = 212 + 224 e           (word-serial)
    7x7 QRD [30]  II = 364
Throughput at each design's reported fmax reproduces the paper's MOp/s
column; we also measure our emulation's actual throughput on this CPU
(vectorized over a batch of rotations — the "spatial" analogue of the
pipeline) and the Pallas-kernel rotations/s.

Timing hygiene (schema_version 2): every engine row records **cold**
(first call: trace + compile + run) and **warm** (median of
``REPRO_BENCH_WARM_REPS`` ``block_until_ready`` reps) separately —
the old ``end_to_end_s`` conflated them and is kept as an alias of cold
for v1 consumers.  Rates (``qrd_per_s``/``solve_per_s``) are computed
from warm.  Each row also carries its resolved ``interpret_mode`` and
``tile_b`` plus the measured-vs-analytic ``roofline_fraction``
(`repro.launch.roofline.roofline_for_row`), and the run exercises the
`repro.kernels.autotune` tuner on two shapes before measuring.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import csv_row, timed

E = 8  # elements per row (4x4 QRD with Q, as in the paper)
BENCH_JSON = os.environ.get("REPRO_BENCH_QRD_JSON", "BENCH_qrd.json")
WARM_REPS = int(os.environ.get("REPRO_BENCH_WARM_REPS", "5"))
SCHEMA_VERSION = 2


def _cold_warm(run, warm_reps=None):
    """(cold first-call seconds, median warm seconds) for a thunk."""
    import jax
    warm_reps = WARM_REPS if warm_reps is None else warm_reps
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    cold = time.perf_counter() - t0
    times = []
    for _ in range(warm_reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        times.append(time.perf_counter() - t0)
    return cold, float(np.median(times))


def _engine_tile_b(eng):
    """The tile_b the engine actually dispatched with (autotuned or
    default) — read off the jitted-callable LRU key's resolved config."""
    from repro.kernels.qrd_blocked import TILE_B
    cache = getattr(eng, "_fn_cache", None) or {}
    for key in cache:
        cfg = key[3][0]
        return cfg.tile_b if cfg.tile_b is not None else TILE_B
    return TILE_B

DESIGNS = {
    # name: (fmax MHz, latency cycles, II(e) lambda)
    "fp_cordic_[21]": (67.1, 224, lambda e: 212 + 224 * e),
    "fp_cordic_[32]": (173.3, 138, lambda e: 69 + e),
    "hub_fp_rotator (ours)": (255.8, 60, lambda e: e),
}
PAPER_MOPS = {"fp_cordic_[21]": 0.033, "fp_cordic_[32]": 2.25,
              "hub_fp_rotator (ours)": 31.97}


def measured_kernel_rate(batch=512, L=128, iters=24):
    import jax.numpy as jnp
    from repro.kernels import ops
    x = (np.random.default_rng(0).uniform(-1.5, 1.5, (2, batch, L))
         * 2 ** 24).astype(np.int32)
    xj, yj = jnp.asarray(x[0]), jnp.asarray(x[1])

    def run():
        return ops.givens_rotate_rows_fixed(xj, yj, iters=iters, hub=True)

    sec = timed(run)
    return batch / sec


def measured_qrd_rates(batch=64, m=4,
                       combos=(("cordic", "col"),
                               ("cordic_pallas", "col"),
                               ("cordic_pallas", "sameh_kuck"),
                               ("blockfp_pallas", "col"),
                               ("blockfp_pallas", "sameh_kuck"))):
    """Full m x m QRD throughput across backends *and* schedules.

    Two architectural axes (DESIGN.md §5, §8):

    - HBM passes: the 'cordic' loop makes 2·steps passes over the working
      set (one read + one write per rotation launch); every blocked kernel
      makes exactly 2 (stage in, write back).
    - Sequential depth: the step-serial blocked kernels run ``steps``
      dependent rotations; with ``schedule='sameh_kuck'`` the Pallas
      backends route onto the wavefront datapath and run ``stages``
      dependent scan iterations — min(m + n − 2, 2m − 3) instead of
      m·n/2-ish.

    Returns ``{f"{backend}/{schedule}": record}`` where each record holds
    the warm steady-state rate (``qrd_per_s``), cold vs warm wall times
    (``cold_s`` / ``warm_s``; cold includes trace + compile — the
    wavefront's biggest win: its trace is one stage body, not the
    unrolled schedule), the resolved ``interpret_mode`` / ``tile_b``,
    the measured-vs-analytic ``roofline_fraction``, and the depth/pass
    accounting.
    """
    import jax.numpy as jnp
    from repro.core import (GivensConfig, QRDEngine, givens_schedule,
                            sameh_kuck_schedule)
    from repro.kernels.ops import auto_interpret
    from repro.launch.roofline import roofline_for_row

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.choice([-1.0, 1.0], (batch, m, m))
                    * np.exp2(rng.uniform(-4, 4, (batch, m, m))))
    steps = len(givens_schedule(m, m))
    stages = len(sameh_kuck_schedule(m, m))
    cfg = GivensConfig(hub=True, n=26)
    interp = auto_interpret(None)
    out = {}
    for backend, sched in combos:
        eng = QRDEngine(backend=backend, givens_config=cfg, schedule=sched)
        cold, warm = _cold_warm(lambda: eng(A))
        wavefront = sched == "sameh_kuck" and backend != "cordic"
        pallas = backend.endswith("_pallas")
        row = {
            "backend": backend, "schedule": sched,
            "batch": batch, "m": m,
            "qrd_per_s": batch / warm,
            "cold_s": cold, "warm_s": warm,
            "end_to_end_s": cold,        # v1 alias (cold time)
            "interpret_mode": interp if pallas else None,
            "tile_b": _engine_tile_b(eng) if pallas else None,
            "iters": cfg.resolved_iters(),
            "steps": steps, "stages": stages,
            "seq_depth": stages if wavefront else steps,
            "hbm_passes_per_qrd": 2 * steps if backend == "cordic" else 2,
        }
        terms = roofline_for_row(row)
        if terms is not None:
            row["roofline_fraction"] = terms["roofline_fraction"]
            row["roofline_bound_qrd_per_s"] = terms["bound_qrd_per_s"]
            row["roofline_dominant"] = terms["dominant"]
        out[f"{backend}/{sched}"] = row
    return out


def measured_tiled_qrd_rates():
    """Production-shape QRD throughput on the tiled routes (DESIGN.md §14).

    Two acceptance shapes, keyed ``tiled:{m}x{n}`` (the
    `check_bench_regression.REQUIRED_ROWS` set — CI fails if either
    stops being measured):

    * ``tiled:64x64`` — the panel route on ``blockfp_pallas``: the
      whole 64-row tile stays kernel-resident, columns sweep in panels,
      full Q computed.  Four matrices per batch keep the warm time
      measurable without bloating the interpret-mode compile.
    * ``tiled:4096x32`` — the TSQR tree on ``blockfp_pallas``:
      32 leaf tiles reduced over 5 tree levels, Q-free (the
      least-squares workload; the Q composition is benchmarked by its
      own cost term in `repro.launch.perfmodel.tsqr_qrd_cost`).

    Rows carry ``tiling``/``tile_m``/``panel_n``/``compute_q`` so
    `repro.launch.roofline.roofline_for_row` scores them against the
    *tiled* cost models (trailing-panel re-reads and tree work included
    in the bound).
    """
    from repro import qrd as api
    from repro.kernels.ops import auto_interpret
    from repro.launch.roofline import roofline_for_row

    rng = np.random.default_rng(0)
    interp = auto_interpret(None)
    shapes = (
        # key suffix,  m,    n,  batch, tiling,  compute_q
        ("64x64",      64,   64, 4,     "panel", True),
        ("4096x32",    4096, 32, 1,     "tsqr",  False),
    )
    out = {}
    for label, m, n, batch, tiling, compute_q in shapes:
        A = rng.standard_normal((batch, m, n))
        eng = api.QRDEngine(api.QRDConfig(backend="blockfp_pallas",
                                          dtype="float64", tiling=tiling))
        cold, warm = _cold_warm(lambda: eng(A, compute_q=compute_q))
        resolved = eng._resolve_tuned(eng.config, m, n)
        from repro.qrd import tiled as _tiled
        tile_m, panel_n = _tiled.resolve_tiles(resolved, eng.capabilities)
        row = {
            "backend": "blockfp_pallas", "schedule": "col",
            "tiling": tiling, "tile_m": tile_m, "panel_n": panel_n,
            "batch": batch, "m": m, "n": n, "compute_q": compute_q,
            "qrd_per_s": batch / warm,
            "cold_s": cold, "warm_s": warm,
            "end_to_end_s": cold,        # v1 alias (cold time)
            "interpret_mode": interp,
            "iters": 24,
        }
        terms = roofline_for_row(row)
        if terms is not None:
            row["roofline_fraction"] = terms["roofline_fraction"]
            row["roofline_bound_qrd_per_s"] = terms["bound_qrd_per_s"]
            row["roofline_dominant"] = terms["dominant"]
        out[f"tiled:{label}"] = row
    return out


def run_tiled_autotune_demo(m=64, n=64, batch=4):
    """Tune the panel width for the 64x64 panel route; record the sweep.

    Narrow two-candidate search (each candidate pays a full
    interpret-mode trace+compile, ~20 s on CI) — enough to demonstrate
    the tiled tuner end-to-end and to persist a winner the
    ``tiled:64x64`` row's engine picks up on the next run (the row's
    config leaves ``panel_n=None``).
    """
    from repro.kernels import autotune

    entry = autotune.tune_tiled("blockfp_pallas", m, n, batch,
                                tiling="panel", dtype="float64",
                                warm_reps=2, panel_ns=(4, 8))
    return {"backend": "blockfp_pallas", "tiling": "panel",
            "m": m, "n": n, "batch": batch,
            "panel_n": entry.panel_n, "tile_m": entry.tile_m,
            "warm_s": entry.warm_s,
            "cache_key": autotune.cache_key("blockfp_pallas", "col", m, n,
                                            "float64", "panel"),
            "candidates": list(entry.candidates)}


def measured_solve_rates(batch=64, m=6, n=3,
                         combos=(("jnp", "col"),
                                 ("givens_float", "col"),
                                 ("blockfp_pallas", "sameh_kuck"))):
    """Problem-level ``engine.solve(A, b)`` throughput (DESIGN.md §9).

    Times the full least-squares path — triangularize the augmented
    ``[A | b]`` with ``compute_q=False`` on the registry-dispatched
    engine, then back-substitute — the workload the paper's rotator
    exists for (QRD-based least squares in communication systems).
    Returns ``{f"solve:{backend}/{schedule}": record}`` with the warm
    steady-state ``solve_per_s`` plus cold/warm wall times.
    """
    from repro import qrd as api
    from repro.core import GivensConfig
    from repro.kernels.ops import auto_interpret

    rng = np.random.default_rng(0)
    A = (rng.choice([-1.0, 1.0], (batch, m, n))
         * np.exp2(rng.uniform(-2, 2, (batch, m, n))))
    b = rng.normal(size=(batch, m)) * 2.0
    cfg = GivensConfig(hub=True, n=26)
    interp = auto_interpret(None)
    out = {}
    for backend, sched in combos:
        eng = api.QRDEngine(backend=backend, schedule=sched, givens=cfg)
        cold, warm = _cold_warm(lambda: eng.solve(A, b))
        out[f"solve:{backend}/{sched}"] = {
            "backend": backend, "schedule": sched, "batch": batch,
            "m": m, "n": n,
            "solve_per_s": batch / warm,
            "cold_s": cold, "warm_s": warm, "end_to_end_s": cold,
            "interpret_mode": (interp if backend.endswith("_pallas")
                               else None),
        }
    return out


def measured_complex_qrd_rates(batch=64, m=4,
                               combos=(("cordic", "col"),
                                       ("cordic_pallas", "sameh_kuck"))):
    """Complex QRD throughput on the three-rotation datapath (§10).

    Every annihilation spends three unit rotations (two phase + one real
    Givens) across twice the lanes (re/im), so the architectural cost is
    ~6x the real path per step — these rows track that the measured ratio
    stays in that ballpark and that the complex wavefront's cold
    end-to-end time keeps its one-stage-body trace advantage.
    Returns ``{f"complex:{backend}/{schedule}": record}``.
    """
    from repro import qrd as api
    from repro.core import GivensConfig, givens_schedule, sameh_kuck_schedule
    from repro.kernels.ops import auto_interpret

    rng = np.random.default_rng(0)
    A = (rng.choice([-1.0, 1.0], (batch, m, m))
         * np.exp2(rng.uniform(-4, 4, (batch, m, m)))
         + 1j * (rng.choice([-1.0, 1.0], (batch, m, m))
                 * np.exp2(rng.uniform(-4, 4, (batch, m, m)))))
    steps = len(givens_schedule(m, m))
    stages = len(sameh_kuck_schedule(m, m))
    cfg = GivensConfig(hub=True, n=26)
    interp = auto_interpret(None)
    out = {}
    for backend, sched in combos:
        eng = api.QRDEngine(backend=backend, schedule=sched, givens=cfg,
                            dtype="complex128")
        cold, warm = _cold_warm(lambda: eng(A))
        wavefront = sched == "sameh_kuck" and backend != "cordic"
        out[f"complex:{backend}/{sched}"] = {
            "backend": backend, "schedule": sched, "dtype": "complex128",
            "batch": batch, "m": m,
            "qrd_per_s": batch / warm,
            "cold_s": cold, "warm_s": warm, "end_to_end_s": cold,
            "interpret_mode": (interp if backend.endswith("_pallas")
                               else None),
            "steps": steps, "stages": stages,
            "seq_depth": stages if wavefront else steps,
        }
    return out


def measured_complex_solve_rates(batch=64, m=6, n=3,
                                 combos=(("cordic", "col"),
                                         ("givens_float", "col"))):
    """Complex ``engine.solve`` throughput (MIMO-detection workload, §10).

    The batched complex least-squares path — triangularize ``[A | b]``
    with the three-rotation decomposition, conjugate-aware
    back-substitution — i.e. the per-channel-use work of the MIMO
    zero-forcing detector (`examples/mimo_detection.py`).
    Returns ``{f"complex-solve:{backend}/{schedule}": record}``.
    """
    from repro import qrd as api
    from repro.core import GivensConfig
    from repro.kernels.ops import auto_interpret

    rng = np.random.default_rng(0)
    A = (rng.normal(size=(batch, m, n))
         + 1j * rng.normal(size=(batch, m, n)))
    b = rng.normal(size=(batch, m)) + 1j * rng.normal(size=(batch, m))
    cfg = GivensConfig(hub=True, n=26)
    interp = auto_interpret(None)
    out = {}
    for backend, sched in combos:
        eng = api.QRDEngine(backend=backend, schedule=sched, givens=cfg,
                            dtype="complex128")
        cold, warm = _cold_warm(lambda: eng.solve(A, b))
        out[f"complex-solve:{backend}/{sched}"] = {
            "backend": backend, "schedule": sched, "dtype": "complex128",
            "batch": batch, "m": m, "n": n,
            "solve_per_s": batch / warm,
            "cold_s": cold, "warm_s": warm, "end_to_end_s": cold,
            "interpret_mode": (interp if backend.endswith("_pallas")
                               else None),
        }
    return out


def measured_rls_fleet_rates(sizes=(4096, 131072), n=4, batch=256):
    """Fleet serving throughput: updates/s vs fleet size (DESIGN.md §12).

    Times the donated single-step `RLSFleet.update` in float mode (the
    serving fleet's CPU-fast lane) at each fleet size with a fixed
    snapshot batch.  The donated step consumes its input state, so the
    usual ``_cold_warm(thunk)`` re-run pattern would touch deleted
    buffers — instead the fleet's own state is threaded forward through
    every timed call (which is also the honest serving workload: each
    step really does start from the previous step's output).  The slot
    count should be a *capacity* axis, not a cost axis: the gather/
    scatter step is O(batch), so ``updates_per_s`` staying flat across
    ``sizes`` is the claim these rows track.
    Returns ``{f"fleet:{slots}x{n} (b{batch})": record}``.
    """
    import jax
    from repro.serve import RLSFleet

    rng = np.random.default_rng(0)
    out = {}
    for slots in sizes:
        fleet = RLSFleet(slots, n, mode="float", lam=0.995)
        ids = fleet.admit(batch)
        X = rng.normal(size=(batch, n))
        d = rng.normal(size=batch)
        t0 = time.perf_counter()
        fleet.update(ids, X, d)
        jax.block_until_ready(fleet.state.work)
        cold = time.perf_counter() - t0
        times = []
        for _ in range(WARM_REPS):
            t0 = time.perf_counter()
            fleet.update(ids, X, d)
            jax.block_until_ready(fleet.state.work)
            times.append(time.perf_counter() - t0)
        warm = float(np.median(times))
        out[f"fleet:{slots}x{n} (b{batch})"] = {
            "mode": "float", "slots": slots, "n": n, "batch": batch,
            "updates_per_s": batch / warm,
            "warm_s": warm, "cold_s": cold, "end_to_end_s": cold,
            "interpret_mode": None,
        }
    return out


#: (m, batch) shapes the autotune demonstration covers: a tall batch of
#: tiny matrices (tile candidates run up to the batch) vs a small batch
#: of big matrices (the batch itself caps the tile) — the shapes whose
#: winning tiles should differ.
AUTOTUNE_SHAPES = ((4, 64), (32, 8))


def run_autotune_demo(backend="blockfp_pallas", schedule="sameh_kuck",
                      shapes=AUTOTUNE_SHAPES):
    """Tune (tile_b, table_layout) on two shapes; compare vs fixed TILE_B.

    Populates the persisted autotune cache (so the engine rows above it
    in future runs dispatch on tuned tiles) and returns the comparison
    record for BENCH_qrd.json: per shape, the winner, its warm time, and
    the fixed-``TILE_B`` candidate's warm time from the same sweep.
    """
    from repro.core import GivensConfig
    from repro.kernels import autotune
    from repro.kernels.qrd_blocked import TILE_B

    cfg = GivensConfig(hub=True, n=26)
    out = {"backend": backend, "schedule": schedule, "fixed_tile_b": TILE_B,
           "cache_path": autotune.cache_path(), "shapes": {}}
    for m, batch in shapes:
        # dtype must match the engine rows' dispatch key (the legacy
        # shim's default problem dtype) or the lookup misses.
        entry = autotune.tune(backend, schedule, m, m, batch, givens=cfg,
                              dtype="float32", warm_reps=3)
        fixed = next((c for c in entry.candidates
                      if c["tile_b"] == TILE_B
                      and c["table_layout"] in ("split", None)), None)
        rec = {"batch": batch,
               "tile_b": entry.tile_b, "table_layout": entry.table_layout,
               "warm_s": entry.warm_s,
               "fixed_tile_warm_s": fixed["warm_s"] if fixed else None,
               "speedup_vs_fixed": (fixed["warm_s"] / entry.warm_s
                                    if fixed else None),
               "candidates": list(entry.candidates)}
        out["shapes"][f"m{m}_b{batch}"] = rec
    return out


def main(full=False):
    print("# table6: design,fmax_mhz,latency_cyc,II_e8,mops_model,mops_paper")
    rows = []
    for name, (fmax, lat, ii) in DESIGNS.items():
        mops = fmax / ii(E)
        rows.append((name, mops))
        print(f"{name},{fmax},{lat},{ii(E)},{mops:.3f},{PAPER_MOPS[name]}")
    ours = dict(rows)["hub_fp_rotator (ours)"]
    gen = dict(rows)["fp_cordic_[32]"]
    print(f"# speedup vs [32]: {ours/gen:.1f}x (paper: ~15x)")
    print("# table7: design,precision,luts_paper")
    for n, l in [("fp_cordic_[21]", 11718), ("fp_cordic_[32]", 22189),
                 ("hub_fp_rotator", 8463)]:
        print(f"{n},double,{l}")

    # Tune first: the 4x4 engine rows below then dispatch on the tuned
    # tile (the tuner writes the persisted cache the engine consults).
    tuned = run_autotune_demo()
    print("# autotune: shape,tile_b,table_layout,warm_s,speedup_vs_fixed")
    for shape, r in tuned["shapes"].items():
        sp = r["speedup_vs_fixed"]
        print(f"{shape},{r['tile_b']},{r['table_layout']},"
              f"{r['warm_s']:.4f},{sp:.2f}x" if sp else
              f"{shape},{r['tile_b']},{r['table_layout']},"
              f"{r['warm_s']:.4f},n/a")

    hdr = ("backend/schedule,qrd_per_s,warm_s,cold_s,seq_depth,steps,"
           "stages,hbm_passes_per_qrd,tile_b,roofline_fraction")
    print(f"# blocked QRD engines (4x4): {hdr}")
    qrd = measured_qrd_rates(m=4)
    for key, r in qrd.items():
        print(f"{key},{r['qrd_per_s']:.1f},{r['warm_s']:.4f},"
              f"{r['cold_s']:.3f},{r['seq_depth']},{r['steps']},"
              f"{r['stages']},{r['hbm_passes_per_qrd']},{r['tile_b']},"
              f"{r.get('roofline_fraction', float('nan')):.2e}")

    # The wavefront acceptance point (ISSUE 2): batched 8x8 QRD with Q —
    # the sequential blocked path's trace unrolls all 28 steps, the
    # wavefront scans 13 stages.
    print(f"# blocked QRD engines (8x8): {hdr}")
    qrd8 = measured_qrd_rates(m=8, combos=(("blockfp_pallas", "col"),
                                           ("blockfp_pallas", "sameh_kuck")))
    for key, r in qrd8.items():
        print(f"{key},{r['qrd_per_s']:.1f},{r['warm_s']:.4f},"
              f"{r['cold_s']:.3f},{r['seq_depth']},{r['steps']},"
              f"{r['stages']},{r['hbm_passes_per_qrd']},{r['tile_b']},"
              f"{r.get('roofline_fraction', float('nan')):.2e}")
    speedup_8x8 = (qrd8["blockfp_pallas/col"]["end_to_end_s"]
                   / qrd8["blockfp_pallas/sameh_kuck"]["end_to_end_s"])
    print(f"# wavefront 8x8 end-to-end speedup vs sequential blocked: "
          f"{speedup_8x8:.1f}x")

    # Tiled routes (DESIGN.md §14): tune the panel width first so the
    # tiled:64x64 row below dispatches on the persisted winner, then
    # measure the two required production shapes.
    tuned_tiled = run_tiled_autotune_demo()
    print("# tiled autotune (64x64 panel): panel_n,warm_s")
    print(f"panel_n={tuned_tiled['panel_n']},{tuned_tiled['warm_s']:.4f}")
    print("# tiled QRD routes: key,qrd_per_s,warm_s,cold_s,tiling,tile_m,"
          "panel_n,roofline_fraction")
    tiled_rows = measured_tiled_qrd_rates()
    for key, r in tiled_rows.items():
        print(f"{key},{r['qrd_per_s']:.2f},{r['warm_s']:.4f},"
              f"{r['cold_s']:.2f},{r['tiling']},{r['tile_m']},"
              f"{r['panel_n']},"
              f"{r.get('roofline_fraction', float('nan')):.2e}")

    # Solve-path rows (DESIGN.md §9): the least-squares workload on the
    # registry-dispatched engine — triangularize [A | b], back-substitute.
    print("# solve paths (6x3 + rhs): backend/schedule,solve_per_s,"
          "end_to_end_s")
    solve = measured_solve_rates()
    for key, r in solve.items():
        print(f"{key},{r['solve_per_s']:.1f},{r['end_to_end_s']:.3f}")

    # Complex datapath rows (DESIGN.md §10): three-rotation QRD and the
    # MIMO-detection solve workload on the complex-capable backends.
    print("# complex QRD (4x4): backend/schedule,qrd_per_s,end_to_end_s,"
          "seq_depth")
    cqrd = measured_complex_qrd_rates(m=4)
    for key, r in cqrd.items():
        print(f"{key},{r['qrd_per_s']:.1f},{r['end_to_end_s']:.3f},"
              f"{r['seq_depth']}")
    print("# complex solve (6x3 + rhs): backend/schedule,solve_per_s,"
          "end_to_end_s")
    csolve = measured_complex_solve_rates()
    for key, r in csolve.items():
        print(f"{key},{r['solve_per_s']:.1f},{r['end_to_end_s']:.3f}")

    # Serving-fleet rows (DESIGN.md §12): donated-step updates/s at two
    # fleet sizes — flat across sizes means slots are capacity, not cost.
    print("# RLS fleet serving (float mode): slots,batch,updates_per_s,"
          "warm_s,cold_s")
    fleet_rows = measured_rls_fleet_rates()
    for key, r in fleet_rows.items():
        print(f"{key},{r['slots']},{r['batch']},{r['updates_per_s']:.1f},"
              f"{r['warm_s']:.4f},{r['cold_s']:.3f}")

    rate = measured_kernel_rate()
    tuned["tiled"] = tuned_tiled
    write_bench_json(qrd, qrd8, solve, speedup_8x8, rate,
                     complex_rows={**cqrd, **csolve}, autotune=tuned,
                     fleet_rows=fleet_rows, tiled_rows=tiled_rows)
    csv_row("table6_7_throughput", 1e6 / rate,
            f"model_speedup_vs_[32]={ours/gen:.1f}x;"
            f"pallas_interp_rot_per_s={rate:.0f};"
            f"qrd_loop_per_s={qrd['cordic/col']['qrd_per_s']:.1f};"
            f"qrd_blocked_per_s={qrd['cordic_pallas/col']['qrd_per_s']:.1f};"
            f"qrd_blockfp_per_s="
            f"{qrd['blockfp_pallas/col']['qrd_per_s']:.1f};"
            f"solve_jnp_per_s={solve['solve:jnp/col']['solve_per_s']:.1f};"
            f"complex_qrd_per_s={cqrd['complex:cordic/col']['qrd_per_s']:.1f};"
            f"wavefront_8x8_speedup={speedup_8x8:.1f}x;"
            f"tiled_64x64_per_s={tiled_rows['tiled:64x64']['qrd_per_s']:.1f};"
            f"tiled_4096x32_per_s="
            f"{tiled_rows['tiled:4096x32']['qrd_per_s']:.2f};"
            f"fleet_updates_per_s="
            f"{fleet_rows['fleet:131072x4 (b256)']['updates_per_s']:.0f}")


def write_bench_json(qrd4, qrd8, solve, speedup_8x8, rot_per_s,
                     complex_rows=None, autotune=None, fleet_rows=None,
                     tiled_rows=None, path=BENCH_JSON):
    """Emit the machine-readable perf trajectory (BENCH_qrd.json).

    Schema version 2: one record per (backend, schedule, m) row with
    warm/cold times split (``warm_s`` drives the rates and the CI gate;
    ``cold_s`` = trace + compile + first run, aliased as the v1
    ``end_to_end_s``), per-row ``interpret_mode`` / ``tile_b`` (the old
    top-level interpret flag is gone — rows can differ once a compiled
    backend exists), ``roofline_fraction`` for modeled rows, the
    ``autotune`` comparison section, and the ``tiled:{m}x{n}``
    production-shape rows (required by the regression gate).  These are the numbers future PRs
    diff against: `benchmarks.check_bench_regression` fails CI when any
    row's warm time regresses more than 2x vs the committed baseline,
    or a compiled row falls below the roofline floor.
    """
    doc = {
        "bench": "table6_7_throughput",
        "schema_version": SCHEMA_VERSION,
        "rotations_per_s": rot_per_s,
        "results": {**{f"{k} (4x4)": v for k, v in qrd4.items()},
                    **{f"{k} (8x8)": v for k, v in qrd8.items()},
                    **{f"{k} (6x3)": v for k, v in solve.items()},
                    **{f"{k} ({v['m']}x{v.get('n', v['m'])})": v
                       for k, v in (complex_rows or {}).items()},
                    **(fleet_rows or {}),
                    **(tiled_rows or {})},
        "wavefront_8x8_end_to_end_speedup": speedup_8x8,
    }
    if autotune is not None:
        doc["autotune"] = autotune
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
