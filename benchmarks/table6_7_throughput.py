"""Tables 6-7 — throughput/latency/area vs prior FP CORDIC designs.

The initiation-interval model is exact (it is architectural, not
technological):
    ours          II = e                     (vectoring/rotation overlapped)
    FP CORDIC[32] II = 69 + e                (angle before rotations)
    FP CORDIC[21] II = 212 + 224 e           (word-serial)
    7x7 QRD [30]  II = 364
Throughput at each design's reported fmax reproduces the paper's MOp/s
column; we also measure our emulation's actual throughput on this CPU
(vectorized over a batch of rotations — the "spatial" analogue of the
pipeline) and the Pallas-kernel (interpret mode) rotations/s.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import csv_row, timed

E = 8  # elements per row (4x4 QRD with Q, as in the paper)
BENCH_JSON = os.environ.get("REPRO_BENCH_QRD_JSON", "BENCH_qrd.json")

DESIGNS = {
    # name: (fmax MHz, latency cycles, II(e) lambda)
    "fp_cordic_[21]": (67.1, 224, lambda e: 212 + 224 * e),
    "fp_cordic_[32]": (173.3, 138, lambda e: 69 + e),
    "hub_fp_rotator (ours)": (255.8, 60, lambda e: e),
}
PAPER_MOPS = {"fp_cordic_[21]": 0.033, "fp_cordic_[32]": 2.25,
              "hub_fp_rotator (ours)": 31.97}


def measured_kernel_rate(batch=512, L=128, iters=24):
    import jax.numpy as jnp
    from repro.kernels import ops
    x = (np.random.default_rng(0).uniform(-1.5, 1.5, (2, batch, L))
         * 2 ** 24).astype(np.int32)
    xj, yj = jnp.asarray(x[0]), jnp.asarray(x[1])

    def run():
        return ops.givens_rotate_rows_fixed(xj, yj, iters=iters, hub=True)

    sec = timed(run)
    return batch / sec


def measured_qrd_rates(batch=64, m=4,
                       combos=(("cordic", "col"),
                               ("cordic_pallas", "col"),
                               ("cordic_pallas", "sameh_kuck"),
                               ("blockfp_pallas", "col"),
                               ("blockfp_pallas", "sameh_kuck"))):
    """Full m x m QRD throughput across backends *and* schedules.

    Two architectural axes (DESIGN.md §5, §8):

    - HBM passes: the 'cordic' loop makes 2·steps passes over the working
      set (one read + one write per rotation launch); every blocked kernel
      makes exactly 2 (stage in, write back).
    - Sequential depth: the step-serial blocked kernels run ``steps``
      dependent rotations; with ``schedule='sameh_kuck'`` the Pallas
      backends route onto the wavefront datapath and run ``stages``
      dependent scan iterations — min(m + n − 2, 2m − 3) instead of
      m·n/2-ish.

    Returns ``{f"{backend}/{schedule}": record}`` where each record holds
    the steady-state rate (``qrd_per_s``), the cold first-call wall time
    including trace + compile (``end_to_end_s`` — the wavefront's biggest
    win: its trace is one stage body, not the unrolled schedule), and the
    depth/pass accounting.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import (GivensConfig, QRDEngine, givens_schedule,
                            sameh_kuck_schedule)

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.choice([-1.0, 1.0], (batch, m, m))
                    * np.exp2(rng.uniform(-4, 4, (batch, m, m))))
    steps = len(givens_schedule(m, m))
    stages = len(sameh_kuck_schedule(m, m))
    cfg = GivensConfig(hub=True, n=26)
    out = {}
    for backend, sched in combos:
        eng = QRDEngine(backend=backend, givens_config=cfg, schedule=sched)
        t0 = time.perf_counter()
        jax.block_until_ready(eng(A))
        cold = time.perf_counter() - t0
        sec = timed(lambda: eng(A))
        wavefront = sched == "sameh_kuck" and backend != "cordic"
        out[f"{backend}/{sched}"] = {
            "backend": backend, "schedule": sched,
            "batch": batch, "m": m,
            "qrd_per_s": batch / sec,
            "end_to_end_s": cold,
            "steps": steps, "stages": stages,
            "seq_depth": stages if wavefront else steps,
            "hbm_passes_per_qrd": 2 * steps if backend == "cordic" else 2,
        }
    return out


def measured_solve_rates(batch=64, m=6, n=3,
                         combos=(("jnp", "col"),
                                 ("givens_float", "col"),
                                 ("blockfp_pallas", "sameh_kuck"))):
    """Problem-level ``engine.solve(A, b)`` throughput (DESIGN.md §9).

    Times the full least-squares path — triangularize the augmented
    ``[A | b]`` with ``compute_q=False`` on the registry-dispatched
    engine, then back-substitute — the workload the paper's rotator
    exists for (QRD-based least squares in communication systems).
    Returns ``{f"solve:{backend}/{schedule}": record}`` with steady-state
    ``solve_per_s`` and the cold first-call wall time (``end_to_end_s``).
    """
    import jax
    from repro import qrd as api
    from repro.core import GivensConfig

    rng = np.random.default_rng(0)
    A = (rng.choice([-1.0, 1.0], (batch, m, n))
         * np.exp2(rng.uniform(-2, 2, (batch, m, n))))
    b = rng.normal(size=(batch, m)) * 2.0
    cfg = GivensConfig(hub=True, n=26)
    out = {}
    for backend, sched in combos:
        eng = api.QRDEngine(backend=backend, schedule=sched, givens=cfg)
        t0 = time.perf_counter()
        jax.block_until_ready(eng.solve(A, b))
        cold = time.perf_counter() - t0
        sec = timed(lambda: eng.solve(A, b))
        out[f"solve:{backend}/{sched}"] = {
            "backend": backend, "schedule": sched, "batch": batch,
            "m": m, "n": n,
            "solve_per_s": batch / sec, "end_to_end_s": cold,
        }
    return out


def measured_complex_qrd_rates(batch=64, m=4,
                               combos=(("cordic", "col"),
                                       ("cordic_pallas", "sameh_kuck"))):
    """Complex QRD throughput on the three-rotation datapath (§10).

    Every annihilation spends three unit rotations (two phase + one real
    Givens) across twice the lanes (re/im), so the architectural cost is
    ~6x the real path per step — these rows track that the measured ratio
    stays in that ballpark and that the complex wavefront's cold
    end-to-end time keeps its one-stage-body trace advantage.
    Returns ``{f"complex:{backend}/{schedule}": record}``.
    """
    import jax
    from repro import qrd as api
    from repro.core import GivensConfig, givens_schedule, sameh_kuck_schedule

    rng = np.random.default_rng(0)
    A = (rng.choice([-1.0, 1.0], (batch, m, m))
         * np.exp2(rng.uniform(-4, 4, (batch, m, m)))
         + 1j * (rng.choice([-1.0, 1.0], (batch, m, m))
                 * np.exp2(rng.uniform(-4, 4, (batch, m, m)))))
    steps = len(givens_schedule(m, m))
    stages = len(sameh_kuck_schedule(m, m))
    cfg = GivensConfig(hub=True, n=26)
    out = {}
    for backend, sched in combos:
        eng = api.QRDEngine(backend=backend, schedule=sched, givens=cfg,
                            dtype="complex128")
        t0 = time.perf_counter()
        jax.block_until_ready(eng(A))
        cold = time.perf_counter() - t0
        sec = timed(lambda: eng(A))
        wavefront = sched == "sameh_kuck" and backend != "cordic"
        out[f"complex:{backend}/{sched}"] = {
            "backend": backend, "schedule": sched, "dtype": "complex128",
            "batch": batch, "m": m,
            "qrd_per_s": batch / sec,
            "end_to_end_s": cold,
            "steps": steps, "stages": stages,
            "seq_depth": stages if wavefront else steps,
        }
    return out


def measured_complex_solve_rates(batch=64, m=6, n=3,
                                 combos=(("cordic", "col"),
                                         ("givens_float", "col"))):
    """Complex ``engine.solve`` throughput (MIMO-detection workload, §10).

    The batched complex least-squares path — triangularize ``[A | b]``
    with the three-rotation decomposition, conjugate-aware
    back-substitution — i.e. the per-channel-use work of the MIMO
    zero-forcing detector (`examples/mimo_detection.py`).
    Returns ``{f"complex-solve:{backend}/{schedule}": record}``.
    """
    import jax
    from repro import qrd as api
    from repro.core import GivensConfig

    rng = np.random.default_rng(0)
    A = (rng.normal(size=(batch, m, n))
         + 1j * rng.normal(size=(batch, m, n)))
    b = rng.normal(size=(batch, m)) + 1j * rng.normal(size=(batch, m))
    cfg = GivensConfig(hub=True, n=26)
    out = {}
    for backend, sched in combos:
        eng = api.QRDEngine(backend=backend, schedule=sched, givens=cfg,
                            dtype="complex128")
        t0 = time.perf_counter()
        jax.block_until_ready(eng.solve(A, b))
        cold = time.perf_counter() - t0
        sec = timed(lambda: eng.solve(A, b))
        out[f"complex-solve:{backend}/{sched}"] = {
            "backend": backend, "schedule": sched, "dtype": "complex128",
            "batch": batch, "m": m, "n": n,
            "solve_per_s": batch / sec, "end_to_end_s": cold,
        }
    return out


def main(full=False):
    print("# table6: design,fmax_mhz,latency_cyc,II_e8,mops_model,mops_paper")
    rows = []
    for name, (fmax, lat, ii) in DESIGNS.items():
        mops = fmax / ii(E)
        rows.append((name, mops))
        print(f"{name},{fmax},{lat},{ii(E)},{mops:.3f},{PAPER_MOPS[name]}")
    ours = dict(rows)["hub_fp_rotator (ours)"]
    gen = dict(rows)["fp_cordic_[32]"]
    print(f"# speedup vs [32]: {ours/gen:.1f}x (paper: ~15x)")
    print("# table7: design,precision,luts_paper")
    for n, l in [("fp_cordic_[21]", 11718), ("fp_cordic_[32]", 22189),
                 ("hub_fp_rotator", 8463)]:
        print(f"{n},double,{l}")

    hdr = ("backend/schedule,qrd_per_s,end_to_end_s,seq_depth,steps,"
           "stages,hbm_passes_per_qrd")
    print(f"# blocked QRD engines (4x4): {hdr}")
    qrd = measured_qrd_rates(m=4)
    for key, r in qrd.items():
        print(f"{key},{r['qrd_per_s']:.1f},{r['end_to_end_s']:.3f},"
              f"{r['seq_depth']},{r['steps']},{r['stages']},"
              f"{r['hbm_passes_per_qrd']}")

    # The wavefront acceptance point (ISSUE 2): batched 8x8 QRD with Q —
    # the sequential blocked path's trace unrolls all 28 steps, the
    # wavefront scans 13 stages.
    print(f"# blocked QRD engines (8x8): {hdr}")
    qrd8 = measured_qrd_rates(m=8, combos=(("blockfp_pallas", "col"),
                                           ("blockfp_pallas", "sameh_kuck")))
    for key, r in qrd8.items():
        print(f"{key},{r['qrd_per_s']:.1f},{r['end_to_end_s']:.3f},"
              f"{r['seq_depth']},{r['steps']},{r['stages']},"
              f"{r['hbm_passes_per_qrd']}")
    speedup_8x8 = (qrd8["blockfp_pallas/col"]["end_to_end_s"]
                   / qrd8["blockfp_pallas/sameh_kuck"]["end_to_end_s"])
    print(f"# wavefront 8x8 end-to-end speedup vs sequential blocked: "
          f"{speedup_8x8:.1f}x")

    # Solve-path rows (DESIGN.md §9): the least-squares workload on the
    # registry-dispatched engine — triangularize [A | b], back-substitute.
    print("# solve paths (6x3 + rhs): backend/schedule,solve_per_s,"
          "end_to_end_s")
    solve = measured_solve_rates()
    for key, r in solve.items():
        print(f"{key},{r['solve_per_s']:.1f},{r['end_to_end_s']:.3f}")

    # Complex datapath rows (DESIGN.md §10): three-rotation QRD and the
    # MIMO-detection solve workload on the complex-capable backends.
    print("# complex QRD (4x4): backend/schedule,qrd_per_s,end_to_end_s,"
          "seq_depth")
    cqrd = measured_complex_qrd_rates(m=4)
    for key, r in cqrd.items():
        print(f"{key},{r['qrd_per_s']:.1f},{r['end_to_end_s']:.3f},"
              f"{r['seq_depth']}")
    print("# complex solve (6x3 + rhs): backend/schedule,solve_per_s,"
          "end_to_end_s")
    csolve = measured_complex_solve_rates()
    for key, r in csolve.items():
        print(f"{key},{r['solve_per_s']:.1f},{r['end_to_end_s']:.3f}")

    rate = measured_kernel_rate()
    write_bench_json(qrd, qrd8, solve, speedup_8x8, rate,
                     complex_rows={**cqrd, **csolve})
    csv_row("table6_7_throughput", 1e6 / rate,
            f"model_speedup_vs_[32]={ours/gen:.1f}x;"
            f"pallas_interp_rot_per_s={rate:.0f};"
            f"qrd_loop_per_s={qrd['cordic/col']['qrd_per_s']:.1f};"
            f"qrd_blocked_per_s={qrd['cordic_pallas/col']['qrd_per_s']:.1f};"
            f"qrd_blockfp_per_s="
            f"{qrd['blockfp_pallas/col']['qrd_per_s']:.1f};"
            f"solve_jnp_per_s={solve['solve:jnp/col']['solve_per_s']:.1f};"
            f"complex_qrd_per_s={cqrd['complex:cordic/col']['qrd_per_s']:.1f};"
            f"wavefront_8x8_speedup={speedup_8x8:.1f}x")


def write_bench_json(qrd4, qrd8, solve, speedup_8x8, rot_per_s,
                     complex_rows=None, path=BENCH_JSON):
    """Emit the machine-readable perf trajectory (BENCH_qrd.json).

    One record per (backend, schedule, m) decomposition row — steady-state
    qrd/s, cold end-to-end seconds (trace + compile + run), sequential
    depth (steps vs stages) and HBM passes — plus one per solve-path row.
    These are the numbers future PRs diff against:
    `benchmarks.check_bench_regression` fails CI when any row's cold
    end-to-end time regresses more than 2x vs the committed baseline.
    """
    doc = {
        "bench": "table6_7_throughput",
        "interpret_mode": True,
        "rotations_per_s": rot_per_s,
        "results": {**{f"{k} (4x4)": v for k, v in qrd4.items()},
                    **{f"{k} (8x8)": v for k, v in qrd8.items()},
                    **{f"{k} (6x3)": v for k, v in solve.items()},
                    **{f"{k} ({v['m']}x{v.get('n', v['m'])})": v
                       for k, v in (complex_rows or {}).items()}},
        "wavefront_8x8_end_to_end_speedup": speedup_8x8,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
