"""Tables 1-4 — hardware cost model + measured emulation throughput.

No FPGA is in the loop (DESIGN.md §2), so the paper's LUT/delay/energy
numbers are reproduced through a *structural cost model* that counts the
adder bits and carry-chain depth of each architecture — the quantities that
drive LUTs and critical path on the Virtex-6:

  input conv (IEEE) : 2 exponent subs, 2 negate adders, [RNE adder + sticky]
  input conv (HUB)  : 2 exponent subs (negation is bit inversion)
  CORDIC core       : 2 adders x (N+2) bits x iters      (both variants)
  output conv (IEEE): 2 negate adders, 2 round incrementers, sticky trees,
                      exponent adjust (+ overflow increment)
  output conv (HUB) : exponent adjust only

Reported per format: model adder-bits + path-depth ratios (HUB/IEEE)
side-by-side with the paper's measured LUT and delay ratios, plus the
*measured throughput* of the bit-accurate JAX emulation and of the Pallas
kernel (interpret mode) for the N<=28 single-precision configs.
"""
from __future__ import annotations

import numpy as np

from repro.core import GivensConfig, GivensUnit, HALF, SINGLE, DOUBLE

from .common import csv_row, gen_matrices, timed

# paper Tables 1-2: (fmt, N_ieee, N_hub) -> (delay ratio, LUT ratio)
PAPER = {
    ("half", 14, 13): (0.76, 0.82),
    ("half", 16, 15): (0.74, 0.80),
    ("single", 26, 25): (0.71, 0.87),
    ("single", 28, 27): (0.73, 0.87),
    ("single", 30, 29): (0.77, 0.86),
    ("double", 55, 54): (0.67, 0.92),
    ("double", 57, 56): (0.62, 0.91),
    ("double", 59, 58): (0.67, 0.91),
}
FMTS = {"half": HALF, "single": SINGLE, "double": DOUBLE}


def cost_model(fmt, N, iters, hub, input_rne=False):
    e, m = fmt.exp_bits, fmt.man_bits
    w = N + 2
    lg = int(np.ceil(np.log2(w)))
    core = 2 * w * iters
    # both FP variants carry an input align shifter and two output
    # normalize shifters + leading-one detectors (mux bits)
    shifters = N * lg + 2 * (w * lg + w)
    if hub:
        in_conv = 2 * e + shifters            # negation is bit inversion
        out_conv = 2 * e
        path = w + e                          # one adder deep per stage
    else:
        in_conv = 2 * e + 2 * (m + 1) + (2 * N if input_rne else 0) + shifters
        sticky = 2 * (w - m)
        out_conv = 2 * w + 2 * m + sticky + 2 * e + 2
        path = w + m + e                      # negate->add->round chain
    return {"adder_bits": core + in_conv + out_conv,
            "core_bits": core, "conv_bits": in_conv + out_conv,
            "path_bits": path}


def measured_throughput(cfg: GivensConfig, batch=2048, e=8):
    unit = GivensUnit(cfg)
    A = gen_matrices(7, 4.0, n=batch)
    import jax, jax.numpy as jnp
    P = unit.encode(jnp.asarray(A))
    rows = P.reshape(batch * 2, -1)  # fake (x,y) rows of length e/2... use 4x4

    @jax.jit
    def rot(P):
        x = P[..., 0, :]
        y = P[..., 1, :]
        return unit.rotate_rows(x, y)

    sec = timed(rot, P)
    n_rot = batch  # one Givens rotation per matrix pair-slice
    return n_rot / sec


def main(full=False):
    print("# table1_3: fmt,N_ieee,N_hub,model_area_ratio,paper_lut_ratio,"
          "model_path_ratio,paper_delay_ratio")
    area_errs, delay_errs = [], []
    for (fname, n_ieee, n_hub), (d_ratio, l_ratio) in PAPER.items():
        fmt = FMTS[fname]
        it = n_ieee - 3  # same stage count for both (paper Sec. 5.2)
        ieee = cost_model(fmt, n_ieee, it, hub=False)
        hub = cost_model(fmt, n_hub, it, hub=True)
        mar = hub["adder_bits"] / ieee["adder_bits"]
        mpr = hub["path_bits"] / ieee["path_bits"]
        print(f"{fname},{n_ieee},{n_hub},{mar:.2f},{l_ratio},{mpr:.2f},{d_ratio}")
        area_errs.append(abs(mar - l_ratio))
        delay_errs.append(abs(mpr - d_ratio))

    # Table 4 analogue: relative model-area deltas
    base = cost_model(SINGLE, 26, 23, hub=False)
    plus_it = cost_model(SINGLE, 26, 24, hub=False)
    plus_n = cost_model(SINGLE, 27, 24, hub=False)
    print("# table4: change,model_area_increase_pct,paper_pct")
    print(f"+1_microrotation,{100*(plus_it['adder_bits']/base['adder_bits']-1):.1f},3.1")
    print(f"+1_N,{100*(plus_n['adder_bits']/base['adder_bits']-1):.1f},5.3")

    # measured emulation throughput (rotations/s), IEEE vs HUB
    t_ieee = measured_throughput(GivensConfig(hub=False, n=26))
    t_hub = measured_throughput(GivensConfig(hub=True, n=25))
    print(f"# measured emulation: ieee={t_ieee:.0f} rot/s, hub={t_hub:.0f} rot/s")
    csv_row("table1_4_cost_model", 1e6 / max(t_hub, 1),
            f"mean_area_model_err={np.mean(area_errs):.3f};"
            f"mean_delay_model_err={np.mean(delay_errs):.3f}")
    return area_errs, delay_errs


if __name__ == "__main__":
    main()
