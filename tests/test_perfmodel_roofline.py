"""Analytic QRD roofline model + benchmark regression gate (DESIGN.md §11).

Pins the properties downstream tooling depends on:

* `qrd_cost` — monotone in shape and iteration depth, converter dataflow
  charged only on the packed path, HBM-pass contracts per backend;
* `roofline` / `roofline_fraction` — the bound is the slower of the two
  terms and fractions scale linearly with the measured rate;
* `roofline_for_row` — models exactly the real-datapath QRD rows of
  BENCH_qrd.json, picks the word representation from ``interpret_mode``,
  and declines solve/complex rows;
* `check_bench_regression.compare` — warm gate on schema-v2 documents,
  v1 cold fallback with a warning, missing-row failures, and the
  compiled-only roofline floor.
"""
import pytest

from benchmarks.check_bench_regression import compare
from repro.launch import perfmodel as pm
from repro.launch.roofline import analyze, roofline_for_row

SPEC = pm.DeviceSpec("test", peak_ops=1e11, hbm_bw=1e11)


# --------------------------------------------------------------------------
# qrd_cost
# --------------------------------------------------------------------------
def test_cost_monotone_in_shape_and_iters():
    c4 = pm.qrd_cost(4, 4)
    c8 = pm.qrd_cost(8, 8)
    assert c8.ops > c4.ops and c8.hbm_bytes > c4.hbm_bytes
    assert pm.qrd_cost(4, 4, iters=32).ops > pm.qrd_cost(4, 4, iters=16).ops
    assert pm.qrd_cost(4, 4, compute_q=True).ops > \
        pm.qrd_cost(4, 4, compute_q=False).ops


def test_packed_path_charges_converters_and_word_factor():
    blockfp = pm.qrd_cost(4, 4, backend="blockfp_pallas")
    packed = pm.qrd_cost(4, 4, backend="cordic_pallas")          # int64
    lanes = pm.qrd_cost(4, 4, backend="cordic_pallas", word="lanes")
    # Converter dataflow + 64-bit emulation make packed strictly costlier,
    # and the dual-int32 lane split costlier still (3.5x vs 2x factor).
    assert packed.ops > blockfp.ops
    assert lanes.ops == pytest.approx(packed.ops * 3.5 / 2.0)
    # int64 words move twice the bytes of int32 significands.
    assert packed.hbm_bytes > blockfp.hbm_bytes


def test_hbm_pass_contracts():
    # Kernel-resident: HBM_PASSES_PER_QRD passes; host loop: 2 per step.
    from repro.kernels.qrd_blocked import HBM_PASSES_PER_QRD
    m = n = 4
    e = n + m
    resident = pm.qrd_cost(m, n, backend="cordic_pallas")
    host = pm.qrd_cost(m, n, backend="cordic")
    encode = 2.0 * m * e * 8
    assert resident.hbm_bytes == HBM_PASSES_PER_QRD * m * e * 8 + encode
    rotations = sum(m - 1 - c for c in range(m - 1))
    assert host.hbm_bytes == 2.0 * rotations * m * e * 8 + encode
    assert host.hbm_bytes > resident.hbm_bytes


def test_active_elements_matches_bruteforce():
    from repro.core.qrd import givens_schedule
    m, n = 6, 4
    e = n + m
    want = sum(2 * (e - col) for _, _, col in givens_schedule(m, n))
    assert pm._active_elements(m, n, e) == want


# --------------------------------------------------------------------------
# roofline / fractions / device specs
# --------------------------------------------------------------------------
def test_roofline_bound_is_slower_term():
    pt = pm.roofline(pm.QRDCost(ops=1e6, hbm_bytes=1e3), SPEC)
    assert pt.dominant == "compute"
    assert pt.bound_s == pt.t_compute
    assert pt.bound_qrd_per_s == pytest.approx(1e11 / 1e6)
    pt = pm.roofline(pm.QRDCost(ops=1e3, hbm_bytes=1e6), SPEC)
    assert pt.dominant == "memory"
    assert pt.bound_s == pt.t_memory


def test_fraction_linear_in_rate():
    cost = pm.qrd_cost(4, 4)
    bound = pm.roofline(cost, SPEC).bound_qrd_per_s
    assert pm.roofline_fraction(bound, cost, SPEC) == pytest.approx(1.0)
    assert pm.roofline_fraction(bound / 10, cost, SPEC) == \
        pytest.approx(0.1)


def test_device_spec_prefix_match_and_fallback():
    assert pm.device_spec("TPU v5 lite").name == "tpu v5 lite"
    assert pm.device_spec("cpu").name == "cpu"
    assert pm.device_spec("warp drive").name == "generic"


# --------------------------------------------------------------------------
# roofline_for_row
# --------------------------------------------------------------------------
def _row(**kw):
    base = {"backend": "blockfp_pallas", "schedule": "sameh_kuck",
            "m": 4, "n": 4, "qrd_per_s": 1e5, "iters": 24,
            "hbm_passes_per_qrd": 2, "interpret_mode": True}
    base.update(kw)
    return base


def test_row_modeled():
    terms = roofline_for_row(_row(), SPEC)
    assert terms is not None
    assert 0 < terms["roofline_fraction"] < 1
    assert terms["device"] == "test"
    assert terms["dominant"] in ("compute", "memory")


def test_row_word_follows_interpret_mode():
    # Packed rows: interpret (or host loop, interpret_mode None) costs
    # int64 emulation; only an explicitly compiled row costs the lane
    # split — a *higher* bound denominator means a lower fraction.
    fi = roofline_for_row(_row(backend="cordic_pallas"),
                          SPEC)["roofline_fraction"]
    fn = roofline_for_row(_row(backend="cordic", interpret_mode=None,
                               hbm_passes_per_qrd=None),
                          SPEC)["roofline_fraction"]
    fc = roofline_for_row(_row(backend="cordic_pallas",
                               interpret_mode=False),
                          SPEC)["roofline_fraction"]
    assert fi != fc and fn > 0
    lanes_cost = pm.qrd_cost(4, 4, backend="cordic_pallas", word="lanes",
                             hbm_passes=2)
    assert fc == pytest.approx(pm.roofline_fraction(1e5, lanes_cost, SPEC))


def test_row_declines_unmodeled():
    assert roofline_for_row(_row(backend="jnp"), SPEC) is None
    assert roofline_for_row(_row(backend="solve:jnp"), SPEC) is None
    assert roofline_for_row(_row(dtype="complex128"), SPEC) is None
    assert roofline_for_row(_row(qrd_per_s=None), SPEC) is None


def test_analyze_covers_modeled_rows_only():
    doc = {"results": {"a": _row(), "b": _row(backend="solve:jnp"),
                       "c": _row(backend="cordic", interpret_mode=None,
                                 hbm_passes_per_qrd=None)}}
    rows = analyze(doc, SPEC)
    assert [r["key"] for r in rows] == ["a", "c"]


# --------------------------------------------------------------------------
# check_bench_regression.compare
# --------------------------------------------------------------------------
def _doc(rows, version=2):
    return {"schema_version": version, "results": rows}


def test_checker_warm_gate():
    base = _doc({"x": {"warm_s": 0.01, "cold_s": 1.0}})
    ok = _doc({"x": {"warm_s": 0.015, "cold_s": 5.0}})   # cold ignored
    bad = _doc({"x": {"warm_s": 0.03, "cold_s": 1.0}})
    fails, _ = compare(base, ok, factor=2.0)
    assert not fails
    fails, _ = compare(base, bad, factor=2.0)
    assert len(fails) == 1 and "warm" in fails[0]


def test_checker_missing_row_fails_new_row_passes():
    base = _doc({"x": {"warm_s": 0.01}})
    fresh = _doc({"y": {"warm_s": 0.01}})
    fails, lines = compare(base, fresh, factor=2.0)
    assert any("missing" in f for f in fails)
    assert any(line.startswith("new  y") for line in lines)


def test_checker_v1_fallback_warns_and_gates_cold():
    base = _doc({"x": {"end_to_end_s": 1.0}}, version=1)
    fresh = _doc({"x": {"end_to_end_s": 3.0}}, version=1)
    fails, lines = compare(base, fresh, factor=2.0)
    assert any("schema v1" in line for line in lines)
    assert len(fails) == 1 and "cold" in fails[0]


def test_checker_roofline_gate_compiled_rows_only():
    base = _doc({"x": {"warm_s": 0.01}})
    interp = _doc({"x": {"warm_s": 0.01, "interpret_mode": True,
                         "roofline_fraction": 1e-6}})
    fails, _ = compare(base, interp, factor=2.0, min_roofline=0.02)
    assert not fails                       # interpret rows exempt
    compiled = _doc({"x": {"warm_s": 0.01, "interpret_mode": False,
                           "roofline_fraction": 1e-6}})
    fails, _ = compare(base, compiled, factor=2.0, min_roofline=0.02)
    assert len(fails) == 1 and "roofline" in fails[0]
    fast = _doc({"x": {"warm_s": 0.01, "interpret_mode": False,
                       "roofline_fraction": 0.5}})
    fails, _ = compare(base, fast, factor=2.0, min_roofline=0.02)
    assert not fails
