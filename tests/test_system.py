"""End-to-end system behaviour: train a tiny model, checkpoint, preempt,
resume, verify bit-identical continuation and loss improvement; multi-device
paths (compressed cross-pod psum, sharded train step) run in a subprocess
with fake devices so this process keeps its single real CPU device."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduce_config
from repro.data import SyntheticLM
from repro.models import init_params, train_loss
from repro.optim import adamw_init, adamw_update
from repro.runtime import PreemptionHandler

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _train_setup():
    cfg = reduce_config(get_config("qwen3-8b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLM(vocab=cfg.vocab, seq=32, global_batch=8, seed=1)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch), has_aux=True)(params)
        params, opt = adamw_update(g, opt, params, lr=3e-3)
        return params, opt, loss

    return cfg, params, ds, step


def test_train_loss_decreases():
    cfg, params, ds, step = _train_setup()
    opt = adamw_init(params)
    losses = []
    for s in range(30):
        params, opt, loss = step(params, opt, ds.batch(s))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
    assert np.isfinite(losses).all()


def test_preempt_checkpoint_resume_is_bit_identical(tmp_path):
    """Kill at step 7, resume from the checkpoint, reach step 12; the
    resumed trajectory must equal the uninterrupted one exactly (stateless
    data addressing + full optimizer state in the checkpoint)."""
    cfg, params0, ds, step = _train_setup()

    # uninterrupted run to step 12
    p, o = params0, adamw_init(params0)
    for s in range(12):
        p, o, _ = step(p, o, ds.batch(s))
    ref = jax.tree.leaves(p)[0]

    # interrupted run: checkpoint every 5 steps, preempt after 7
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    handler = PreemptionHandler(install=False)
    state = {"params": params0, "opt": adamw_init(params0)}
    for s in range(12):
        state["params"], state["opt"], _ = step(state["params"], state["opt"],
                                                ds.batch(s))
        if (s + 1) % 5 == 0:
            mgr.save_async(s + 1, state, extra={"data_step": s + 1})
        if s == 6:
            handler.trigger()
        if handler.should_stop:
            break
    mgr.wait()

    # "new process": restore latest (step 5) and continue
    template = {"params": params0, "opt": adamw_init(params0)}
    step_at, state2, extra = mgr.restore_latest(template)
    assert step_at == 5 and extra["data_step"] == 5
    p2, o2 = state2["params"], state2["opt"]
    for s in range(extra["data_step"], 12):
        p2, o2, _ = step(p2, o2, ds.batch(s))
    got = jax.tree.leaves(p2)[0]
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_multidevice_subprocess_paths():
    """Sharded train step + int8 compressed cross-pod psum on 8 fake devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro  # enables x64
from repro.optim.compress import compressed_psum, shard_map_compat

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

# --- compressed psum over the pod axis equals the exact mean (within int8 tol)
x = jnp.arange(16, dtype=jnp.float32).reshape(2, 8) / 7.0

def f(x):
    return compressed_psum({"g": x}, "pod")["g"]

out = jax.jit(shard_map_compat(f, mesh, P("pod", None), P("pod", None)))(x)
expect = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)
err = float(jnp.max(jnp.abs(out - expect)))
amax = float(jnp.max(jnp.abs(x)))
assert err <= amax / 127.0 + 1e-6, err

# --- sharded tiny train step compiles and runs on the 3-axis mesh
from repro.configs import get_config, reduce_config
from repro.launch.steps import build_train
from repro.configs.registry import ShapeCell
from repro.models import init_params
from repro.optim import adamw_init

cfg = reduce_config(get_config("stablelm-1.6b"))
cell = ShapeCell("tiny", "train", 32, 4)
with mesh:
    fn, args = build_train(cfg, cell, mesh)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
    p2, o2, m = fn(params, opt, batch, jnp.asarray(0, jnp.int32))
    assert np.isfinite(float(m["loss"]))
print("SUBPROCESS_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr
