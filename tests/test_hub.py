"""HUB numerics-primitive layer properties (hypothesis)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import hub_quantize, hub_error_bound

VALS = st.floats(min_value=1e-30, max_value=1e30,
                 allow_nan=False, allow_infinity=False)


@settings(max_examples=300, deadline=None)
@given(VALS, st.sampled_from([4, 8, 10, 16, 23]))
def test_hub_quantize_error_bound(v, m):
    q = float(hub_quantize(np.float64(v), m))
    assert abs(q - v) / v <= hub_error_bound(m) * (1 + 1e-12)


@settings(max_examples=200, deadline=None)
@given(VALS)
def test_hub_quantize_idempotent(v):
    q1 = float(hub_quantize(np.float64(v), 10))
    q2 = float(hub_quantize(np.float64(q1), 10))
    assert q1 == q2


@settings(max_examples=200, deadline=None)
@given(VALS, st.sampled_from([8, 16]))
def test_hub_quantize_sign_symmetry(v, m):
    assert float(hub_quantize(np.float64(-v), m)) == \
        -float(hub_quantize(np.float64(v), m))


def test_hub_values_are_odd_grid_points():
    """HUB values have ILSB 1: mantissa is an odd multiple of 2^-(m+1)."""
    rng = np.random.default_rng(0)
    v = rng.uniform(1.0, 2.0, 100)
    q = np.asarray(hub_quantize(v, 8))
    k = np.rint((q - 1.0) * 2.0 ** 9)
    assert np.all(k % 2 == 1)


def test_zero_passthrough():
    assert float(hub_quantize(np.float64(0.0), 8)) == 0.0
