"""Dual-int32 lane arithmetic vs the int64 packed word — bit-exactness.

The compilable datapath (DESIGN.md §11) re-expresses every int64
operation of the packed-word converters and CORDIC core as (hi, lo)
uint32 lane pairs so Mosaic/Triton can lower it.  The contract is
bit-identity, checked deterministically here (hypothesis properties
over the full 64-bit range live in test_packed_lanes_properties.py):

* primitive ops (add/sub/mul/shifts/compares/ilog2/RNE shift) against
  their int64 counterparts on structured + random 64-bit samples;
* the `packed_to_lanes` / `lanes_to_packed` round-trip;
* `LaneUnit` vs `GivensUnit` — vector, rotate and rotate_rows agree
  word-for-word across IEEE/HUB, rounding and iteration variants;
* `ops.qr_packed(..., lanes=True)` vs ``lanes=False`` end to end
  (serial and wavefront, both table layouts).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.givens import GivensConfig, GivensUnit
from repro.kernels import packed_lanes as pl
from repro.kernels.cordic_givens import lanes_to_packed, packed_to_lanes


def _samples(count=400, seed=11):
    """Structured corners + uniform random int64 values."""
    rng = np.random.default_rng(seed)
    corners = np.array(
        [0, 1, -1, 2, -2, 2 ** 31 - 1, 2 ** 31, -(2 ** 31), 2 ** 32 - 1,
         2 ** 32, 2 ** 62, -(2 ** 62), 2 ** 63 - 1, -(2 ** 63),
         0x00000000FFFFFFFF, -0x100000000, 0x7FFFFFFF00000000],
        dtype=object)
    rand = rng.integers(-(2 ** 63), 2 ** 63, size=count - len(corners),
                        dtype=np.int64)
    return np.concatenate([corners.astype(np.int64), rand])


def _lanes(arr):
    return packed_to_lanes(jnp.asarray(np.asarray(arr, np.int64)))


def _np64(x):
    return np.asarray(lanes_to_packed(x))


def test_round_trip():
    v = _samples()
    assert np.array_equal(_np64(_lanes(v)), v)


def test_add_sub_mul():
    a, b = _samples(seed=1), _samples(seed=2)
    la, lb = pl.lanes_unstack(_lanes(a)), pl.lanes_unstack(_lanes(b))
    with np.errstate(over="ignore"):
        assert np.array_equal(_np64(pl.lanes_stack(pl.add64(la, lb))), a + b)
        assert np.array_equal(_np64(pl.lanes_stack(pl.sub64(la, lb))), a - b)
        assert np.array_equal(_np64(pl.lanes_stack(pl.mul64(la, lb))), a * b)


@pytest.mark.parametrize("s", [0, 1, 7, 23, 31, 32, 33, 47, 62, 63])
def test_shifts(s):
    v = _samples(seed=3)
    lv = pl.lanes_unstack(_lanes(v))
    sj = jnp.int32(s)
    u = v.view(np.uint64)
    assert np.array_equal(_np64(pl.lanes_stack(pl.shl64(lv, sj))),
                          (u << np.uint64(s)).view(np.int64))
    assert np.array_equal(_np64(pl.lanes_stack(pl.shr64(lv, sj))),
                          (u >> np.uint64(s)).view(np.int64))
    # numpy's int64 >> is arithmetic but shift-by-63 is defined; use
    # python ints as the arithmetic-shift reference
    want = np.array([int(x) >> s for x in v], dtype=np.int64)
    assert np.array_equal(_np64(pl.lanes_stack(pl.sar64(lv, sj))), want)


def test_compares():
    a, b = _samples(seed=4), _samples(seed=5)
    b[:50] = a[:50]                      # force equal pairs
    la, lb = pl.lanes_unstack(_lanes(a)), pl.lanes_unstack(_lanes(b))
    assert np.array_equal(np.asarray(pl.eq64(la, lb)), a == b)
    assert np.array_equal(np.asarray(pl.is_neg64(la)), a < 0)
    assert np.array_equal(np.asarray(pl.ltu64(la, lb)),
                          a.view(np.uint64) < b.view(np.uint64))


def test_ilog2():
    v = (_samples(seed=6) & 0x3FFFFFFFFFFFFFFF) | 1   # positive, nonzero
    lv = pl.lanes_unstack(_lanes(v))
    want = np.array([int(x).bit_length() - 1 for x in v], dtype=np.int32)
    assert np.array_equal(np.asarray(pl.ilog2_64(lv)), want)


@pytest.mark.parametrize("s", [0, 1, 5, 24, 31, 32, 40, 62])
def test_rshift_rne(s):
    v = _samples(seed=7)
    lv = pl.lanes_unstack(_lanes(v))
    got = _np64(pl.lanes_stack(pl.rshift_rne64(lv, jnp.int32(s))))

    def ref(x):
        x = int(x)
        if s == 0:
            return x
        q, rem = x >> s, x & ((1 << s) - 1)
        half = 1 << (s - 1)
        if rem > half or (rem == half and (q & 1)):
            q += 1
        return np.int64(np.uint64(q & 0xFFFFFFFFFFFFFFFF))

    want = np.array([ref(x) for x in v], dtype=np.int64)
    assert np.array_equal(got, want)


# --------------------------------------------------------------------------
# LaneUnit vs GivensUnit: the datapath-level bit-identity contract.
# --------------------------------------------------------------------------
CONFIGS = [
    GivensConfig(hub=False, input_rounding="trunc"),
    GivensConfig(hub=False, input_rounding="rne"),
    GivensConfig(hub=True, unbiased=True, detect_identity=True),
    GivensConfig(hub=True, unbiased=False, detect_identity=False),
    GivensConfig(hub=True, n=30, iters=20),
]


def _sample_words(cfg, count=256, seed=7):
    rng = np.random.default_rng(seed)
    vals = np.concatenate([
        rng.standard_normal(count - 8),
        np.array([0.0, 1.0, -1.0, 2.0, 0.5, 1e-30, -1e30, np.pi])])
    unit = GivensUnit(cfg)
    return unit.encode(jnp.asarray(vals, jnp.float64))


@pytest.mark.parametrize("cfg", CONFIGS,
                         ids=lambda c: f"hub{int(c.hub)}_n{c.n}")
def test_lane_unit_matches_givens_unit(cfg):
    P = _sample_words(cfg)
    x, y = P[: P.shape[0] // 2], P[P.shape[0] // 2:]
    unit, lane = GivensUnit(cfg), pl.LaneUnit(cfg)
    xl, yl = packed_to_lanes(x), packed_to_lanes(y)

    rx, ry, (flip, sig) = unit.vector(x, y)
    lrx, lry, (lflip, lsig) = lane.vector(xl, yl)
    assert bool(jnp.all(lanes_to_packed(lrx) == rx))
    assert bool(jnp.all(lanes_to_packed(lry) == ry))
    assert bool(jnp.all(lflip.astype(jnp.int64) == flip))
    assert bool(jnp.all(lanes_to_packed(lsig) == sig))

    gx, gy = unit.rotate(x, y, (flip, sig))
    lgx, lgy = lane.rotate(xl, yl, (lflip, lsig))
    assert bool(jnp.all(lanes_to_packed(lgx) == gx))
    assert bool(jnp.all(lanes_to_packed(lgy) == gy))


@pytest.mark.parametrize("cfg", CONFIGS[:3],
                         ids=lambda c: f"hub{int(c.hub)}_n{c.n}")
def test_lane_unit_rotate_rows(cfg):
    rng = np.random.default_rng(3)
    unit, lane = GivensUnit(cfg), pl.LaneUnit(cfg)
    W = unit.encode(jnp.asarray(rng.standard_normal((5, 2, 6))))
    rx, ry = unit.rotate_rows(W[:, 0], W[:, 1])
    L = packed_to_lanes(W)
    lrx, lry = lane.rotate_rows(L[:, 0], L[:, 1])
    assert bool(jnp.all(lanes_to_packed(lrx) == rx))
    assert bool(jnp.all(lanes_to_packed(lry) == ry))


@pytest.mark.slow
@pytest.mark.parametrize("hub", [False, True])
def test_qr_packed_lanes_end_to_end(hub):
    from repro.core.qrd import givens_schedule, sameh_kuck_schedule
    from repro.kernels import ops

    cfg = GivensConfig(n=25, hub=hub)
    unit = GivensUnit(cfg)
    rng = np.random.default_rng(0)
    P = unit.encode(jnp.asarray(rng.standard_normal((6, 4, 4))))
    steps = givens_schedule(4, 4)
    ref = ops.qr_packed(P, cfg=cfg, steps=steps, lanes=False,
                        interpret=True, tile_b=4)
    lan = ops.qr_packed(P, cfg=cfg, steps=steps, lanes=True,
                        interpret=True, tile_b=4)
    assert bool(jnp.all(ref == lan))

    stages = sameh_kuck_schedule(4, 4)
    refw = ops.qr_packed_wavefront(P, cfg=cfg, stages=stages, lanes=False,
                                   interpret=True, tile_b=4)
    for layout in ("split", "stacked"):
        lanw = ops.qr_packed_wavefront(P, cfg=cfg, stages=stages,
                                       lanes=True, interpret=True,
                                       tile_b=4, table_layout=layout)
        assert bool(jnp.all(refw == lanw))
