"""Hypothesis differential tests: abstract transfer fns vs the int64 spec.

The soundness contract of `repro.analysis.domain`: for every concrete
input in an abstract input, the concrete result of the mirrored
primitive lies inside the abstract result.  Deterministic edge-case and
real-lane differentials live in test_analysis_bitflow.py (hypothesis is
a dev extra).
"""
import pytest

from repro.analysis import domain as D
from repro.analysis.bitflow import Alu
from repro.analysis.domain import (INT64_MAX, INT64_MIN, M64, ProofLog,
                                   const, interval)

pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

i64 = st.integers(min_value=INT64_MIN, max_value=INT64_MAX)
small_shift = st.integers(min_value=0, max_value=70)


def _signed(u):
    u &= M64
    return u - (1 << 64) if u >> 63 else u


@given(a=i64, b=i64)
@settings(max_examples=300, deadline=None)
def test_add_sub_mul_containment(a, b):
    log = ProofLog()
    alu = Alu(log)
    wa, wb = const(a), const(b)
    assert alu.add64(wa, wb).contains(_signed(a + b))
    assert alu.sub64(wa, wb).contains(_signed(a - b))
    assert alu.mul64(wa, wb).contains(_signed(a * b))


@given(a=i64, b=i64)
@settings(max_examples=300, deadline=None)
def test_bitwise_containment(a, b):
    alu = Alu(ProofLog())
    wa, wb = const(a), const(b)
    assert alu.and64(wa, wb).contains(_signed(a & b))
    assert alu.or64(wa, wb).contains(_signed(a | b))
    assert alu.xor64(wa, wb).contains(_signed(a ^ b))
    assert alu.not64(wa).contains(~a)


@given(v=i64, s=small_shift)
@settings(max_examples=300, deadline=None)
def test_shift_containment(v, s):
    """Concrete lanes clamp shifts to [0, 63]; mirror that here."""
    alu = Alu(ProofLog())
    wv, ws = const(v), const(s)
    sc = min(s, 63)
    assert alu.shl64(wv, ws).contains(_signed((v & M64) << sc))
    assert alu.shr64(wv, ws).contains(_signed((v & M64) >> sc))
    assert alu.sar64(wv, ws).contains(v >> sc)


@given(v=i64, s=st.integers(min_value=0, max_value=63))
@settings(max_examples=300, deadline=None)
def test_rshift_rne_containment(v, s):
    alu = Alu(ProofLog())
    res = alu.rshift_rne64(const(v), const(s), masked_above=63)
    # spec: arithmetic shift + round-to-nearest-even on dropped bits
    if s == 0:
        expect = v
    else:
        q, half = v >> s, 1 << (s - 1)
        rem = v & ((1 << s) - 1)
        if rem > half or (rem == half and q & 1):
            q += 1
        expect = q
    assert res.contains(_signed(expect))


@given(v=st.integers(min_value=1, max_value=INT64_MAX))
@settings(max_examples=300, deadline=None)
def test_ilog2_containment(v):
    alu = Alu(ProofLog())
    assert alu.ilog2_64(const(v)).contains(v.bit_length() - 1)


@given(lo=i64, hi=i64, v=i64)
@settings(max_examples=300, deadline=None)
def test_interval_transfer_monotone(lo, hi, v):
    """Interval (not just singleton) inputs must contain any member's
    image — the actual soundness property the proofs rely on."""
    lo, hi = min(lo, hi), max(lo, hi)
    if not (lo <= v <= hi):
        v = lo
    w = interval(lo, hi)
    alu = Alu(ProofLog())
    assert alu.neg64(w).contains(_signed(-v))
    assert alu.abs64(w).contains(_signed(abs(v)))
    assert D.join(w, const(0)).contains(v)


@given(lo=i64, hi=i64, v=i64, s=st.integers(min_value=0, max_value=63))
@settings(max_examples=200, deadline=None)
def test_interval_shift_containment(lo, hi, v, s):
    lo, hi = min(lo, hi), max(lo, hi)
    if not (lo <= v <= hi):
        v = hi
    w = interval(lo, hi)
    alu = Alu(ProofLog())
    assert alu.sar64(w, const(s)).contains(v >> s)
    assert alu.shr64(w, const(s)).contains(_signed((v & M64) >> s))
