"""Codec properties: packed IEEE-like and HUB formats."""
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import (HALF, SINGLE, DOUBLE, decode_hub, decode_ieee,
                        encode_hub, encode_ieee)

FINITE = st.floats(min_value=2.0 ** -60, max_value=2.0 ** 60,
                   allow_nan=False, allow_infinity=False)
SIGNED = st.tuples(st.sampled_from([-1.0, 1.0]), FINITE).map(
    lambda t: t[0] * t[1])


@settings(max_examples=200, deadline=None)
@given(st.lists(SIGNED, min_size=1, max_size=32))
def test_ieee_roundtrip_error_bound(vals):
    x = np.asarray(vals)
    y = np.asarray(decode_ieee(encode_ieee(x, SINGLE), SINGLE))
    rel = np.abs(y - x) / np.abs(x)
    assert np.all(rel <= 2.0 ** -24)  # RNE half-ulp bound


@settings(max_examples=200, deadline=None)
@given(st.lists(SIGNED, min_size=1, max_size=32))
def test_hub_roundtrip_error_bound(vals):
    """Paper Sec. 4: HUB and RNE share the same worst-case bound."""
    x = np.asarray(vals)
    y = np.asarray(decode_hub(encode_hub(x, SINGLE), SINGLE))
    rel = np.abs(y - x) / np.abs(x)
    assert np.all(rel <= 2.0 ** -24)


@settings(max_examples=200, deadline=None)
@given(st.lists(SIGNED, min_size=1, max_size=32))
def test_hub_vs_ieee_complementary_error(vals):
    """|e_hub| + |e_ieee| == half-ulp of the value (paper Sec. 4)."""
    x = np.asarray(vals)
    yi = np.asarray(decode_ieee(encode_ieee(x, SINGLE), SINGLE))
    yh = np.asarray(decode_hub(encode_hub(x, SINGLE), SINGLE))
    _, e = np.frexp(np.abs(x))
    ulp_half = np.ldexp(2.0 ** -24, e - 1 + 1) / 2  # 2^-25 * 2^exp(1.x)
    tol = np.ldexp(1.0, e - 1 - 24)  # half-ulp in absolute terms
    s = np.abs(yi - x) + np.abs(yh - x)
    # ties can make both errors land on the same side; allow <=
    assert np.all(s <= tol * (1 + 1e-12))


def test_zero_and_sign():
    for enc, dec in ((encode_ieee, decode_ieee), (encode_hub, decode_hub)):
        p = enc(np.array([0.0, -0.0, 1.0, -1.0]), SINGLE)
        v = np.asarray(dec(p, SINGLE))
        assert v[0] == 0.0 and v[1] == 0.0
        assert v[2] > 0 and v[3] < 0


def test_hub_one_is_not_exact():
    """HUB cannot represent exact 1.0 (ILSB) — motivates identity detection."""
    v = float(decode_hub(encode_hub(np.array(1.0), SINGLE), SINGLE))
    assert v != 1.0
    assert abs(v - 1.0) <= 2.0 ** -24


@pytest.mark.parametrize("fmt", [HALF, SINGLE, DOUBLE])
def test_formats_pack_unpack(fmt):
    x = np.array([1.5, -2.25, 3.0e2, -1.0e-3])
    y = np.asarray(decode_ieee(encode_ieee(x, fmt), fmt))
    assert np.allclose(y, x, rtol=2.0 ** -fmt.man_bits)


def test_saturation_and_flush():
    # beyond range: saturate (no inf), tiny: flush to zero
    big = np.array([1e300])
    tiny = np.array([1e-300])
    for enc, dec in ((encode_ieee, decode_ieee), (encode_hub, decode_hub)):
        vb = np.asarray(dec(enc(big, SINGLE), SINGLE))
        vt = np.asarray(dec(enc(tiny, SINGLE), SINGLE))
        assert np.isfinite(vb).all()
        assert vt[0] == 0.0
