"""Data pipeline, checkpointing, cluster runtime (fault tolerance)."""
import os

import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_step, save_pytree, restore_pytree
from repro.data import SyntheticLM
from repro.runtime import (ClusterMonitor, PreemptionHandler,
                           plan_elastic_mesh)


# ---------------------------------------------------------------- data
def test_data_deterministic_and_stateless():
    ds = SyntheticLM(vocab=1000, seq=16, global_batch=8, seed=3)
    a = np.asarray(ds.batch(5)["tokens"])
    b = np.asarray(ds.batch(5)["tokens"])
    c = np.asarray(ds.batch(6)["tokens"])
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_data_host_sharding_shapes():
    ds = SyntheticLM(vocab=100, seq=8, global_batch=32, seed=0)
    shards = [ds.host_batch(2, h, 4)["tokens"] for h in range(4)]
    assert all(s.shape == (8, 8) for s in shards)
    # different hosts see different data
    assert not np.array_equal(np.asarray(shards[0]), np.asarray(shards[1]))


def test_data_zipf_skew():
    ds = SyntheticLM(vocab=1000, seq=64, global_batch=64, seed=1)
    t = np.asarray(ds.batch(0)["tokens"])
    # low ids should be much more frequent than high ids
    assert (t < 100).mean() > 2.5 * (t >= 900).mean()


# ---------------------------------------------------------------- checkpoint
def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "m": [jnp.ones((2,)), jnp.zeros((0,), jnp.float32)],
            "step": jnp.asarray(7)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(d, 10, _tree(), extra={"data_step": 123})
    tree, extra = restore_pytree(d, 10, _tree())
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.arange(12).reshape(3, 4))
    assert extra["data_step"] == 123


def test_checkpoint_ignores_torn_writes(tmp_path):
    d = str(tmp_path / "ckpt")
    save_pytree(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_00000002.tmp"))  # simulated torn write
    os.makedirs(os.path.join(d, "step_00000003"))      # no manifest
    assert latest_step(d) == 1


def test_checkpoint_manager_async_keep_k(tmp_path):
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(), extra={"s": s})
    mgr.wait()
    names = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert names == ["step_00000003", "step_00000004"]
    step, tree, extra = mgr.restore_latest(_tree())
    assert step == 4 and extra["s"] == 4


def test_checkpoint_preemption_mid_save_is_safe(tmp_path):
    """A checkpoint dir with a newer torn write still restores the old one."""
    d = str(tmp_path / "ckpt")
    save_pytree(d, 5, _tree())
    tmp = os.path.join(d, "step_00000006.tmp")
    os.makedirs(tmp)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(b"partial garbage")
    assert latest_step(d) == 5
    tree, _ = restore_pytree(d, 5, _tree())
    assert float(tree["w"][0, 0]) == 0.0


# ---------------------------------------------------------------- runtime
def test_monitor_detects_dead_and_stragglers():
    mon = ClusterMonitor(n_hosts=4, beat_timeout=10.0, lag_steps=5)
    now = 100.0
    for h in range(4):
        mon.record_heartbeat(h, step=100, now=now)
    mon.record_heartbeat(2, step=80, now=now)       # straggler
    assert mon.dead_hosts(now=now) == []
    assert mon.stragglers() == []                   # first flag only
    assert mon.stragglers() == [2]                  # second consecutive flag
    assert mon.dead_hosts(now=now + 60.0) == [0, 1, 2, 3]
    mon.record_heartbeat(0, step=101, now=now + 60)
    assert 0 not in mon.dead_hosts(now=now + 61)


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=1, max_value=8))
def test_elastic_plan_properties(n_alive, chips_pow):
    chips_per_host = 2 ** (chips_pow - 1)
    tp = 16
    alive = list(range(n_alive))
    total = n_alive * chips_per_host
    if total < tp:
        with pytest.raises(RuntimeError):
            plan_elastic_mesh(alive, chips_per_host=chips_per_host,
                              model_parallel=tp)
        return
    plan = plan_elastic_mesh(alive, chips_per_host=chips_per_host,
                             model_parallel=tp)
    # the model axis is never shrunk, mesh fits in surviving chips
    assert plan.mesh_shape[-1] == tp
    assert np.prod(plan.mesh_shape) <= total
    assert set(plan.dropped_hosts).isdisjoint(plan.active_hosts)


def test_elastic_plan_multi_pod():
    plan = plan_elastic_mesh(list(range(128)), chips_per_host=4,
                             model_parallel=16, pod_size=16)
    assert plan.axis_names == ("pod", "data", "model")
    assert plan.mesh_shape[0] >= 2


def test_preemption_handler():
    h = PreemptionHandler(install=False)
    assert not h.should_stop
    h.trigger()
    assert h.should_stop
