import os
import sys

# Make src/ importable regardless of how pytest is invoked.  NOTE: no
# XLA_FLAGS here — tests must see the real single CPU device (the 512-device
# override belongs exclusively to launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
