"""Wavefront datapath (DESIGN.md §8): stage-parallel blocked QR parity.

The contract: rotating every Sameh–Kuck stage in one shot along a pair
axis — full-width rows, per-pair column masks, gather/scatter by stage
index tables — changes the *order of evaluation*, never the arithmetic.
Within-stage rotations touch disjoint row pairs, so the packed wavefront
path must match `qr_cordic` on the flattened stage schedule bit for bit
(IEEE and HUB), and the int32 block-FP wavefront path must match the
step-serial blocked kernel on the same schedule.  The schedule itself is
checked as a property: every subdiagonal entry annihilated exactly once,
all within-stage pairs disjoint, depth = min(m + n − 2, 2m − 3).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (GivensConfig, GivensUnit, QRDEngine, givens_schedule,
                        qr_blockfp_pallas, qr_blockfp_wavefront, qr_cordic,
                        qr_cordic_wavefront, sameh_kuck_schedule, snr_db)

# Interpret-mode kernel compiles dominate this module's runtime
# (tens of seconds per pallas_call trace): full lane only.
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(11)


def matrices(shape, r=4.0):
    mag = np.exp2(RNG.uniform(-r, r, size=shape))
    return RNG.choice([-1.0, 1.0], size=shape) * mag


def _flat(m, n):
    return tuple(s for stage in sameh_kuck_schedule(m, n) for s in stage)


def _assert_bit_exact(a, b):
    for u, v in zip(a, b):
        if u is None:
            assert v is None
            continue
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# odd batches stress TILE_B padding; non-square shapes stress the stage
# tables' Pmax padding (stages with fewer pairs than the widest stage)
@pytest.mark.parametrize("shape", [(5, 4, 4), (3, 6, 3), (2, 3, 5)])
@pytest.mark.parametrize("hub", [False, True])
def test_packed_wavefront_bit_exact(shape, hub):
    A = matrices(shape)
    m, n = shape[1:]
    unit = GivensUnit(GivensConfig(hub=hub, n=26))
    ref = qr_cordic(A, unit, steps=_flat(m, n))
    _assert_bit_exact(ref, qr_cordic_wavefront(A, unit))


def test_packed_wavefront_bit_exact_no_q():
    A = matrices((5, 4, 4))
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    ref = qr_cordic(A, unit, compute_q=False, steps=_flat(4, 4))
    _assert_bit_exact(ref, qr_cordic_wavefront(A, unit, compute_q=False))


@pytest.mark.parametrize("shape", [(5, 4, 4), (3, 6, 3), (2, 3, 5)])
@pytest.mark.parametrize("hub", [False, True])
def test_blockfp_wavefront_matches_sequential(shape, hub):
    """Same quantize-once datapath, stage-parallel order: the wavefront
    block-FP path reproduces the step-serial blocked kernel on the
    flattened stage schedule (within-stage pairs are disjoint, and the
    pair-axis kernel replays the identical int32 recurrence), and stays a
    faithful QRD of the input."""
    A = matrices(shape)
    m, n = shape[1:]
    ref = qr_blockfp_pallas(A, steps=_flat(m, n), hub=hub)
    got = qr_blockfp_wavefront(A, hub=hub)
    for u, v in zip(ref, got):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=0.0, atol=0.0)
    assert float(jnp.mean(snr_db(A, *got))) > 90.0


def test_engine_sameh_kuck_routes_to_wavefront():
    """schedule='sameh_kuck' on the Pallas backends = the wavefront path,
    bit-identical to the reference loop on the flattened stage order."""
    A = matrices((4, 6, 4))
    cfg = GivensConfig(hub=True, n=26)
    ref = QRDEngine(backend="cordic", givens_config=cfg,
                    schedule="sameh_kuck")(A)
    got = QRDEngine(backend="cordic_pallas", givens_config=cfg,
                    schedule="sameh_kuck")(A)
    _assert_bit_exact(ref, got)
    Q, R = QRDEngine(backend="blockfp_pallas", givens_config=cfg,
                     schedule="sameh_kuck")(A)
    assert float(jnp.mean(snr_db(A, Q, R))) > 90.0
    assert np.all(np.tril(np.asarray(R), -1) == 0.0)


def test_engine_memoizes_schedules_and_jitted_callables():
    # schedule constructors are lru_cached: one tuple object per (m, n)
    assert sameh_kuck_schedule(6, 4) is sameh_kuck_schedule(6, 4)
    assert givens_schedule(6, 4) is givens_schedule(6, 4)
    eng = QRDEngine(backend="cordic_pallas",
                    givens_config=GivensConfig(hub=True, n=26),
                    schedule="sameh_kuck")
    A = matrices((2, 4, 4))
    eng(A)
    assert len(eng._fn_cache) == 1
    fn = next(iter(eng._fn_cache.values()))
    eng(matrices((2, 4, 4)))
    assert next(iter(eng._fn_cache.values())) is fn  # no rebuild, same shape
    eng(matrices((2, 3, 3)))
    assert len(eng._fn_cache) == 2               # one callable per (m, n)


def test_sharded_wavefront_tall_skinny_batch():
    from repro.core import qr_blocked_sharded
    from repro.launch.sharding import qrd_stage_table_spec

    assert qrd_stage_table_spec() == jax.sharding.PartitionSpec()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    A = matrices((6, 8, 3), r=2.0)               # tall-skinny batch
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    ref = qr_cordic(A, unit, steps=_flat(8, 3))
    _assert_bit_exact(ref, qr_blocked_sharded(A, unit, mesh,
                                              schedule="sameh_kuck"))
