"""RLSFleet semantics: slot lifecycle, donation, bit-parity with RLSState.

The fleet's contract (DESIGN.md §12) is that it is *nothing but* N
`RLSState` objects in one pytree: an occupied slot driven through
`fleet.update` must be **bit-identical** to an independently driven
single state on the bit-accurate paths (IEEE + HUB + complex — the
acceptance criterion of ISSUE 8), slots not addressed by a batch must
not change by a single bit, and the donated step must actually donate
(input buffers deleted — zero per-step reallocation).
"""
import numpy as np
import pytest

import repro  # noqa: F401  (x64 guard)
from repro.core import GivensConfig, GivensUnit
from repro.qrd.rls import RLSState
from repro.serve import RLSFleet

RNG = np.random.default_rng(77)


def _traffic(B, n, steps, complex_dtype=False, seed=5):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        X = rng.normal(size=(B, n))
        d = rng.normal(size=B)
        if complex_dtype:
            X = X + 1j * rng.normal(size=(B, n))
            d = d + 1j * rng.normal(size=B)
        yield X, d


def _parity_case(mode, *, hub=False, complex_dtype=False, steps=4):
    """Half-occupied 12-slot fleet vs independent per-slot RLSState refs."""
    n, B = 4, 6
    dtype = "complex128" if complex_dtype else "float64"
    kw = {}
    if mode == "unit":
        kw["unit"] = GivensUnit(GivensConfig(hub=hub))
    fleet = RLSFleet(12, n, mode=mode, lam=0.97, dtype=dtype, **kw)
    ids = fleet.admit(B)                       # half-occupied: 6 of 12
    refs = [RLSState(n, lam=0.97, mode=mode, dtype=dtype, **kw)
            for _ in range(B)]
    before = np.asarray(fleet.state.work).copy()
    for X, d in _traffic(B, n, steps, complex_dtype):
        fleet.update(ids, X, d)
        for i, ref in enumerate(refs):
            ref.update(X[i], d[i])
    # untouched (unadmitted) slots: not one bit moved
    after = np.asarray(fleet.state.work)
    untouched = np.setdiff1d(np.arange(12), ids)
    np.testing.assert_array_equal(after[untouched], before[untouched])
    return fleet, ids, refs


@pytest.mark.parametrize("hub", [False, True], ids=["ieee", "hub"])
def test_fleet_unit_mode_bit_identical_to_states(hub):
    fleet, ids, refs = _parity_case("unit", hub=hub)
    for i, ref in enumerate(refs):
        exported = fleet.export_state(int(ids[i]))
        np.testing.assert_array_equal(exported["R"], ref.R)
        np.testing.assert_array_equal(exported["z"], ref.z)
        np.testing.assert_array_equal(fleet.weights([ids[i]])[0],
                                      ref.weights())


@pytest.mark.slow   # three-rotation complex annihilation compile (~1 min)
def test_fleet_complex_unit_mode_bit_identical_to_states():
    fleet, ids, refs = _parity_case("unit", complex_dtype=True)
    for i, ref in enumerate(refs):
        exported = fleet.export_state(int(ids[i]))
        assert exported["R"].dtype == np.complex128
        np.testing.assert_array_equal(exported["R"], ref.R)
        np.testing.assert_array_equal(exported["z"], ref.z)


@pytest.mark.parametrize("complex_dtype", [False, True],
                         ids=["real", "complex"])
def test_fleet_float_mode_matches_states(complex_dtype):
    # float mode: jnp vs numpy elementary ops — allclose, not bit-equal
    fleet, ids, refs = _parity_case("float", complex_dtype=complex_dtype)
    for i, ref in enumerate(refs):
        exported = fleet.export_state(int(ids[i]))
        np.testing.assert_allclose(exported["R"], ref.R,
                                   rtol=1e-12, atol=1e-13)
        np.testing.assert_allclose(exported["z"], ref.z,
                                   rtol=1e-12, atol=1e-13)


@pytest.mark.slow   # kernel-resident block annihilation compile
def test_fleet_block_mode_matches_states():
    n, B, blk = 4, 3, 4
    fleet = RLSFleet(8, n, mode="block", block=blk, lam=0.97)
    ids = fleet.admit(B)
    refs = [RLSState(n, lam=0.97, mode="block", block=blk) for _ in range(B)]
    rng = np.random.default_rng(3)
    for _ in range(3):
        X = rng.normal(size=(B, blk, n))
        d = rng.normal(size=(B, blk))
        fleet.update(ids, X, d)
        for i, ref in enumerate(refs):
            for j in range(blk):
                ref.update(X[i, j], d[i, j])
    for i, ref in enumerate(refs):
        exported = fleet.export_state(int(ids[i]))
        assert int(exported["updates"]) == ref.updates == 3 * blk
        np.testing.assert_allclose(exported["R"], ref.R,
                                   rtol=1e-10, atol=1e-12)


def test_fleet_update_donates_previous_state():
    """The jitted step must reuse the input buffers — zero reallocation."""
    fleet = RLSFleet(32, 4, mode="float")
    ids = fleet.admit(4)
    for _ in range(3):
        prev = fleet.state
        fleet.update(ids, RNG.normal(size=(4, 4)), RNG.normal(size=4))
        assert all(leaf.is_deleted() for leaf in prev), \
            "donated input buffers still alive — the step reallocated"


def test_fleet_admit_evict_reuse_and_generations():
    fleet = RLSFleet(6, 3, mode="float", lam=0.9, delta=0.5)
    ids = fleet.admit(4)
    np.testing.assert_array_equal(ids, [0, 1, 2, 3])
    gen0 = fleet.generation_of(ids)
    fleet.update(ids, RNG.normal(size=(4, 3)), RNG.normal(size=4))
    fleet.evict([1, 2])
    assert fleet.occupancy == 2
    # freed slots are reused lowest-first and come back *reset*
    ids2 = fleet.admit(2, lam=0.8)
    np.testing.assert_array_equal(ids2, [1, 2])
    exported = fleet.export_state(1)
    np.testing.assert_array_equal(exported["R"], 0.5 * np.eye(3))
    assert float(exported["lam"]) == 0.8 and int(exported["updates"]) == 0
    # evict+admit bumped the generation twice
    np.testing.assert_array_equal(fleet.generation_of([1, 2]),
                                  gen0[1:3] + 2)
    # full-fleet admit overflow and double-admit both refuse
    with pytest.raises(RuntimeError, match="fleet full"):
        fleet.admit(3)
    with pytest.raises(ValueError, match="occupied"):
        fleet.admit(slot_ids=[0])
    with pytest.raises(ValueError, match="unoccupied"):
        fleet.evict([5])


def test_fleet_masks_unoccupied_and_invalid_entries():
    fleet = RLSFleet(8, 3, mode="float")
    ids = fleet.admit(2)
    before = np.asarray(fleet.state.work).copy()
    # slot 5 unoccupied, sentinel 8 out of range, entry 1 invalid
    slot_ids = np.array([ids[0], ids[1], 5, fleet.slots])
    valid = np.array([True, False, True, False])
    fleet.update(slot_ids, RNG.normal(size=(4, 3)), RNG.normal(size=4),
                 valid=valid)
    after = np.asarray(fleet.state.work)
    assert not np.array_equal(after[ids[0]], before[ids[0]])  # applied
    np.testing.assert_array_equal(after[1:], before[1:])      # all others
    np.testing.assert_array_equal(np.asarray(fleet.state.updates),
                                  [1, 0, 0, 0, 0, 0, 0, 0])


def test_fleet_state_interop_with_rls_state():
    """export_state/import_state speak RLSState.to_arrays' schema."""
    state = RLSState(4, lam=0.93, mode="float")
    for X, d in _traffic(1, 4, 5):
        state.update(X[0], d[0])
    fleet = RLSFleet(4, 4, mode="float")
    slot = fleet.import_state(2, state.to_arrays())
    np.testing.assert_array_equal(fleet.weights([slot])[0], state.weights())
    roundtrip = RLSState(4, mode="float").from_arrays(fleet.export_state(slot))
    np.testing.assert_array_equal(roundtrip.R, state.R)
    assert roundtrip.lam == 0.93 and roundtrip.updates == 5
    # pending snapshots must be flushed before entering the fleet
    blocked = RLSState(3, mode="block", block=4)
    blocked.update(np.ones(3), 1.0)
    small = RLSFleet(2, 3, mode="float")
    with pytest.raises(ValueError, match="pending"):
        small.import_state(0, blocked.to_arrays())


def test_fleet_validation_errors():
    unit = GivensUnit(GivensConfig())
    with pytest.raises(ValueError, match="forgetting"):
        RLSFleet(4, 3, mode="float", lam=0.0)
    with pytest.raises(ValueError, match="GivensUnit"):
        RLSFleet(4, 3, mode="unit")
    with pytest.raises(TypeError, match="complex"):
        RLSFleet(4, 3, mode="block", dtype="complex128")
    fleet = RLSFleet(4, 3, mode="unit", unit=unit)
    ids = fleet.admit(2)
    with pytest.raises(TypeError, match="complex"):
        fleet.update(ids, np.ones((2, 3)) + 1j, np.ones(2))
    with pytest.raises(ValueError, match="duplicate"):
        fleet.evict([0, 0])
    with pytest.raises(ValueError, match=r"shape"):
        fleet.update(ids, np.ones((2, 5)), np.ones(2))
    with pytest.raises(ValueError, match="forgetting"):
        fleet.admit(slot_ids=[3], lam=-1.0)


def test_fleet_checkpoint_template_roundtrip(tmp_path):
    """Fleet state -> CheckpointManager -> load_state, bit-exact (incl.
    the strict dtype tags of checkpoint/ckpt.py)."""
    from repro.checkpoint import CheckpointManager

    fleet = RLSFleet(16, 4, mode="float", dtype="complex128")
    ids = fleet.admit(5)
    for X, d in _traffic(5, 4, 3, complex_dtype=True):
        fleet.update(ids, X, d)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save_async(1, fleet.state, extra={"note": "mid-stream"})
    mgr.wait()
    saved = np.asarray(fleet.state.work).copy()
    for X, d in _traffic(5, 4, 2, complex_dtype=True):   # keep serving
        fleet.update(ids, X, d)
    step, tree, extra = mgr.restore_latest(fleet.template())
    fleet.load_state(tree)
    assert step == 1 and extra["note"] == "mid-stream"
    np.testing.assert_array_equal(np.asarray(fleet.state.work), saved)
    assert np.asarray(fleet.state.work).dtype == np.complex128


def test_fleet_slot_spec_shards_slot_axis():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import fleet_slot_spec

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    mesh = FakeMesh()
    assert fleet_slot_spec(3, 128, mesh) == P(("data",), None, None)
    assert fleet_slot_spec(1, 128, mesh) == P(("data",))
    # indivisible slot counts replicate instead of failing jit divisibility
    assert fleet_slot_spec(3, 127, mesh) == P(None, None, None)
