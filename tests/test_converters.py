"""Input/output converter properties (block-FP <-> packed FP)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import SINGLE, encode_hub, encode_ieee
from repro.core.converters import (input_convert_hub, input_convert_ieee,
                                   output_convert_hub, output_convert_ieee)

N = jnp.asarray(26, jnp.int64)
F = 24  # N - 2

VAL = st.floats(min_value=2.0 ** -20, max_value=2.0 ** 20,
                allow_nan=False, allow_infinity=False)
SVAL = st.tuples(st.sampled_from([-1.0, 1.0]), VAL).map(lambda t: t[0] * t[1])


def _blockfp_value(sig, m_exp, hub):
    """Decode an aligned significand + shared exponent back to float."""
    sig = np.asarray(sig, np.float64)
    if hub:
        sig = sig + 0.5
    return sig / 2.0 ** F * 2.0 ** (np.asarray(m_exp) - SINGLE.bias)


@settings(max_examples=200, deadline=None)
@given(SVAL, SVAL)
def test_input_converter_ieee_accuracy(x, y):
    xp = encode_ieee(np.float64(x), SINGLE)
    yp = encode_ieee(np.float64(y), SINGLE)
    xf, yf, me = input_convert_ieee(xp, yp, SINGLE, N, rounding="rne")
    scale = 2.0 ** (float(me) - SINGLE.bias)
    # block-FP alignment error <= 1 internal LSB + input rounding
    tol = scale * 2.0 ** -(F - 1) + abs(x) * 2.0 ** -23
    assert abs(_blockfp_value(xf, me, False) - x) <= tol
    assert abs(_blockfp_value(yf, me, False) - y) <= tol


@settings(max_examples=200, deadline=None)
@given(SVAL, SVAL)
def test_input_converter_hub_accuracy(x, y):
    xp = encode_hub(np.float64(x), SINGLE)
    yp = encode_hub(np.float64(y), SINGLE)
    xf, yf, me = input_convert_hub(xp, yp, SINGLE, N)
    scale = 2.0 ** (float(me) - SINGLE.bias)
    tol = scale * 2.0 ** -(F - 1) + abs(x) * 2.0 ** -23
    assert abs(_blockfp_value(xf, me, True) - x) <= tol
    assert abs(_blockfp_value(yf, me, True) - y) <= tol


def test_input_converter_shared_exponent_is_max():
    xp = encode_ieee(np.float64(8.0), SINGLE)
    yp = encode_ieee(np.float64(0.25), SINGLE)
    _, _, me = input_convert_ieee(xp, yp, SINGLE, N)
    assert int(me) - SINGLE.bias == 3


def test_input_converter_far_exponents_flush_small():
    xp = encode_ieee(np.float64(2.0 ** 30), SINGLE)
    yp = encode_ieee(np.float64(2.0 ** -10), SINGLE)
    xf, yf, me = input_convert_ieee(xp, yp, SINGLE, N)
    assert int(yf) == 0  # shifted past the word width


def test_identity_detection_makes_one_nearly_exact():
    one = encode_hub(np.float64(1.0), SINGLE)
    xf_det, _, me = input_convert_hub(one, one, SINGLE, N,
                                      detect_identity=True)
    # compare against HUBBasic (biased extension, no detection — Fig. 10)
    xf_no, _, _ = input_convert_hub(one, one, SINGLE, N,
                                    unbiased=False, detect_identity=False)
    err_det = abs(_blockfp_value(xf_det, me, True) - 1.0)
    err_no = abs(_blockfp_value(xf_no, me, True) - 1.0)
    assert err_det < err_no
    assert err_det <= 2.0 ** -(F + 1)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=-2 ** 27 + 1, max_value=2 ** 27 - 1),
       st.integers(min_value=100, max_value=150))
def test_output_converter_ieee_rne(sig, m_exp):
    v = _blockfp_value(sig, m_exp, False)
    packed = output_convert_ieee(jnp.asarray(sig, jnp.int64),
                                 jnp.asarray(m_exp, jnp.int64), SINGLE, N)
    from repro.core import decode_ieee
    got = float(decode_ieee(packed, SINGLE))
    if v == 0.0:
        assert got == 0.0
    else:
        assert abs(got - v) <= abs(v) * 2.0 ** -24 * (1 + 1e-9)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=-2 ** 27 + 1, max_value=2 ** 27 - 1),
       st.integers(min_value=100, max_value=150))
def test_output_converter_hub_truncation_is_rn(sig, m_exp):
    v = _blockfp_value(sig, m_exp, True)  # true value incl. internal ILSB
    packed = output_convert_hub(jnp.asarray(sig, jnp.int64),
                                jnp.asarray(m_exp, jnp.int64), SINGLE, N,
                                unbiased=False)
    from repro.core import decode_hub
    got = float(decode_hub(packed, SINGLE))
    assert abs(got - v) <= abs(v) * 2.0 ** -24 * (1 + 1e-9)


def test_output_converter_underflow_flush():
    packed = output_convert_ieee(jnp.asarray(3, jnp.int64),
                                 jnp.asarray(2, jnp.int64), SINGLE, N)
    from repro.core import decode_ieee
    assert float(decode_ieee(packed, SINGLE)) == 0.0
