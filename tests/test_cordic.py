"""Fixed-point CORDIC core: vectoring, rotation, sigma reuse, gain."""
import numpy as np
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import cordic

F = 24  # fraction bits (N=26 -> F=N-2)
IT = 24
W = jnp.asarray(28, jnp.int64)


def fix(v):
    return jnp.asarray(np.rint(np.asarray(v) * 2.0 ** F), jnp.int64)


def unfix(v):
    return np.asarray(v, np.float64) / 2.0 ** F


COORD = st.floats(min_value=-1.9, max_value=1.9).filter(
    lambda v: abs(v) > 1e-4)


@settings(max_examples=100, deadline=None)
@given(COORD, COORD, st.booleans())
def test_vectoring_computes_hypot(x, y, hub):
    it = jnp.asarray(IT, jnp.int64)
    xr, yr, flip, sig = cordic.vectoring(fix(x), fix(y), it, hub)
    xr, yr = cordic.apply_gain(xr, yr, it, W, hub)
    r = unfix(xr)
    assert abs(r - np.hypot(x, y)) < 2e-6
    assert abs(unfix(yr)) < 4e-6


@settings(max_examples=100, deadline=None)
@given(COORD, COORD, COORD, COORD, st.booleans())
def test_sigma_reuse_is_exact_same_rotation(x1, y1, x2, y2, hub):
    """Z-datapath elimination: the replayed rotation equals the float
    rotation by angle atan2 computed in vectoring (paper Sec. 3.2)."""
    it = jnp.asarray(IT, jnp.int64)
    _, _, flip, sig = cordic.vectoring(fix(x1), fix(y1), it, hub)
    xr, yr = cordic.rotation(fix(x2), fix(y2), flip, sig, it, hub)
    xr, yr = cordic.apply_gain(xr, yr, it, W, hub)
    r = np.hypot(x1, y1)
    c, s = x1 / r, y1 / r
    # the angle quantization of vectoring scales with 1/|r1| (the leading
    # pair's fixed-point LSB is a larger *relative* perturbation when the
    # pair is small), and its effect scales with |v2|
    tol = 4e-6 * (1.0 + 0.05 / r) * max(1.0, np.hypot(x2, y2))
    assert abs(unfix(xr) - (c * x2 + s * y2)) < tol
    assert abs(unfix(yr) - (-s * x2 + c * y2)) < tol


@settings(max_examples=60, deadline=None)
@given(COORD, COORD, st.booleans())
def test_rotation_preserves_norm(x, y, hub):
    it = jnp.asarray(IT, jnp.int64)
    xr, yr, flip, sig = cordic.vectoring(fix(x), fix(y), it, hub)
    xr, yr = cordic.apply_gain(xr, yr, it, W, hub)
    n0 = np.hypot(x, y)
    n1 = np.hypot(unfix(xr), unfix(yr))
    assert abs(n1 - n0) / n0 < 1e-5


def test_gain_table():
    assert cordic.cordic_gain(0) == 1.0
    assert abs(cordic.cordic_gain(24) - 1.6467602581210656) < 1e-12
    # K(n) increases and converges
    assert cordic.cordic_gain(40) > cordic.cordic_gain(10)
    assert abs(cordic.cordic_gain(40) - cordic.cordic_gain(30)) < 1e-15


def test_fixmul_matches_exact():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.integers(-2 ** 27, 2 ** 27, 100), jnp.int64)
    p = jnp.asarray(40, jnp.int64)
    comp = jnp.asarray(int(0.607252935 * 2 ** 40), jnp.int64)
    got = np.asarray(cordic.fixmul(v, comp, p, round_nearest=False))
    exact = (np.asarray(v, object) * int(comp)) >> 40
    assert np.max(np.abs(got - np.asarray(exact, np.int64))) <= 1


def test_hub_negate_by_inversion():
    """~x as a HUB value is exactly -x (the ILSB absorbs the +1)."""
    x = np.array([5, -7, 123456, 0], np.int64)
    real = x + 0.5
    neg_stored = ~x
    assert np.all((neg_stored + 0.5) == -real)
