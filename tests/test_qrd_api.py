"""Solver-grade QRD API (DESIGN.md §9): registry, config, solve, shims.

The contract under test: the registry-dispatched `repro.qrd.QRDEngine`
reproduces the pre-refactor free functions exactly (bit-identical for the
cordic family), `solve()` matches `np.linalg.lstsq` within the documented
per-backend tolerances (`SOLVE_TOLERANCES`), the jitted-callable cache is
*bounded* (churning 50 shapes must not grow without bound), and the
legacy `repro.core.QRDEngine` dataclass plus `qr_*` free functions keep
working as thin shims over the new surface.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import qrd as api
from repro.core import (GivensConfig, GivensUnit, QRDEngine as LegacyEngine,
                        qr_cordic, qr_cordic_pallas, qr_jnp, snr_db)

RNG = np.random.default_rng(21)


def matrices(shape, r=2.0):
    mag = np.exp2(RNG.uniform(-r, r, size=shape))
    return RNG.choice([-1.0, 1.0], size=shape) * mag


def _assert_bit_exact(a, b):
    for u, v in zip(a, b):
        if u is None:
            assert v is None
            continue
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_builtin_backends_registered_with_capabilities():
    names = api.available_backends()
    assert set(names) >= {"jnp", "givens_float", "cordic", "cordic_pallas",
                          "blockfp_pallas", "fixed"}
    caps = api.list_backends()
    assert caps["cordic"].bit_exact and caps["cordic_pallas"].bit_exact
    assert caps["cordic_pallas"].wavefront and caps["blockfp_pallas"].wavefront
    assert not caps["jnp"].bit_exact and not caps["jnp"].sharding
    assert caps["cordic_pallas"].sharding


def test_register_third_party_backend_dispatches():
    def builder(config, m, n, compute_q):
        # a "new" backend: float64 Householder (not a built-in combination)
        return lambda A: qr_jnp(A, jnp.float64, compute_q=compute_q)

    api.register_backend("qr64_test", builder,
                         api.BackendCapabilities(description="test entry"))
    try:
        A = matrices((3, 4, 4))
        eng = api.QRDEngine(backend="qr64_test")
        Q, R = eng(A)
        np.testing.assert_allclose(np.asarray(Q) @ np.asarray(R), A,
                                   atol=1e-10)
        # duplicate registration is rejected unless overwrite=True
        with pytest.raises(ValueError, match="already registered"):
            api.register_backend("qr64_test", builder)
        api.register_backend("qr64_test", builder, overwrite=True)
    finally:
        api.unregister_backend("qr64_test")
    assert "qr64_test" not in api.available_backends()


def test_registry_powered_error_messages():
    with pytest.raises(ValueError, match="registered backends"):
        api.QRDEngine(backend="nope")
    with pytest.raises(ValueError, match="unknown schedule"):
        api.QRDEngine(backend="jnp", schedule="diagonal")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="sharding capability"):
        api.QRDEngine(backend="jnp", mesh=mesh)


# ---------------------------------------------------------------------------
# registry-dispatched engine == pre-refactor functions (acceptance)
# ---------------------------------------------------------------------------
@pytest.mark.slow   # pallas_call interpret-mode compile
def test_registry_cordic_paths_bit_identical_to_free_functions():
    A = matrices((3, 4, 4), r=4.0)
    cfg = GivensConfig(hub=True, n=26)
    unit = GivensUnit(cfg)
    ref = qr_cordic(A, unit)
    got_engine = api.QRDEngine(backend="cordic", givens=cfg)(A)
    _assert_bit_exact(ref, got_engine)
    got_pallas = api.QRDEngine(backend="cordic_pallas", givens=cfg)(A)
    _assert_bit_exact(ref, got_pallas)
    _assert_bit_exact(qr_cordic_pallas(A, unit), got_pallas)


# ---------------------------------------------------------------------------
# solve(): golden tolerances vs np.linalg.lstsq (IEEE + HUB)
# ---------------------------------------------------------------------------
def _lstsq_ref(A, b):
    return np.stack([np.linalg.lstsq(A[i], b[i], rcond=None)[0]
                     for i in range(A.shape[0])])


@pytest.mark.parametrize("backend,kwargs", [
    ("jnp", {}),
    ("givens_float", {}),
    ("cordic", {"givens": GivensConfig(hub=False, n=26)}),       # IEEE
    ("cordic", {"givens": GivensConfig(hub=True, n=26)}),        # HUB
    ("blockfp_pallas", {"schedule": "sameh_kuck",
                        "givens": GivensConfig(hub=True, n=26)}),
    ("fixed", {"fixed_scale_exp": 5}),
])
def test_solve_matches_lstsq_within_documented_tolerance(backend, kwargs):
    A = matrices((3, 6, 3))
    b = RNG.normal(size=(3, 6)) * 2.0
    eng = api.QRDEngine(backend=backend, **kwargs)
    x = np.asarray(eng.solve(A, b))
    ref = _lstsq_ref(A, b)
    tol = api.SOLVE_TOLERANCES[backend]
    err = np.max(np.abs(x - ref) / np.maximum(np.abs(ref), 1e-6))
    assert err < tol, (backend, err, tol)


@pytest.mark.slow   # pallas_call interpret-mode compile
def test_solve_cordic_pallas_wavefront_and_multi_rhs_residuals():
    A = matrices((2, 5, 3))
    B = RNG.normal(size=(2, 5, 2)) * 2.0
    eng = api.QRDEngine(backend="cordic_pallas", schedule="sameh_kuck",
                        givens=GivensConfig(hub=True, n=26))
    x, resid = eng.solve(A, B, return_residuals=True)
    assert np.asarray(x).shape == (2, 3, 2)
    for i in range(2):
        for k in range(2):
            xr = np.linalg.lstsq(A[i], B[i, :, k], rcond=None)[0]
            np.testing.assert_allclose(np.asarray(x)[i, :, k], xr, atol=1e-5,
                                       rtol=1e-4)
            # the annihilated tail of the b column carries ||Ax - b||
            want = np.linalg.norm(A[i] @ xr - B[i, :, k])
            np.testing.assert_allclose(np.asarray(resid)[i, k], want,
                                       rtol=1e-4, atol=1e-6)


def test_solve_shape_validation():
    eng = api.QRDEngine(backend="jnp")
    with pytest.raises(ValueError, match="m >= n"):
        eng.solve(np.ones((2, 3, 4)), np.ones((2, 3)))
    with pytest.raises(ValueError, match="rows must match"):
        eng.solve(np.ones((2, 4, 3)), np.ones((2, 5)))


def test_back_substitute_batched_matches_dense_solve():
    R = np.triu(RNG.normal(size=(4, 5, 5))) + 3 * np.eye(5)
    y = RNG.normal(size=(4, 5))
    x = np.asarray(api.back_substitute(R, y))
    for i in range(4):
        np.testing.assert_allclose(x[i], np.linalg.solve(R[i], y[i]),
                                   atol=1e-10)
    # trailing RHS axis broadcasts through
    Y = RNG.normal(size=(4, 5, 3))
    X = np.asarray(api.back_substitute(R, Y))
    for i in range(4):
        np.testing.assert_allclose(X[i], np.linalg.solve(R[i], Y[i]),
                                   atol=1e-10)


# ---------------------------------------------------------------------------
# bounded jitted-callable cache (satellite: 50-shape churn)
# ---------------------------------------------------------------------------
def test_fn_cache_is_bounded_lru_under_shape_churn():
    eng = api.QRDEngine(backend="jnp", max_cache=16)
    shapes = [(2 + i % 10, 2 + i % 3) for i in range(25)]
    for i, (m, n) in enumerate(shapes):          # 50 keys: x2 for compute_q
        for compute_q in (True, False):
            Q, R = eng(RNG.normal(size=(2, m, max(2, min(m, n)))),
                       compute_q=compute_q)
            assert (Q is None) == (not compute_q)
        assert len(eng._fn_cache) <= 16, (i, len(eng._fn_cache))
    assert len(eng._fn_cache) == 16              # full, not overfull
    # hot key survives churn: same shape returns the identical callable
    key_before = next(reversed(eng._fn_cache))
    fn_before = eng._fn_cache[key_before]
    eng(RNG.normal(size=(2, key_before[0], key_before[1])),
        compute_q=key_before[2])
    assert eng._fn_cache[key_before] is fn_before


def test_fn_cache_eviction_keeps_results_correct():
    eng = api.QRDEngine(backend="givens_float", max_cache=1)
    A1, A2 = matrices((2, 3, 3)), matrices((2, 4, 2))
    Q1, R1 = eng(A1)
    eng(A2)                                      # evicts the (3, 3) callable
    assert len(eng._fn_cache) == 1
    Q1b, R1b = eng(A1)                           # rebuilt, same results
    np.testing.assert_array_equal(np.asarray(R1), np.asarray(R1b))


# ---------------------------------------------------------------------------
# legacy surface stays working (acceptance)
# ---------------------------------------------------------------------------
def test_legacy_engine_is_a_shim_over_the_registry():
    A = matrices((3, 4, 4), r=4.0)
    cfg = GivensConfig(hub=True, n=26)
    legacy = LegacyEngine(backend="cordic", givens_config=cfg)
    new = api.QRDEngine(backend="cordic", givens=cfg)
    _assert_bit_exact(legacy(A), new(A))
    assert len(legacy._fn_cache) >= 1            # the bounded LRU, exposed
    # construction still fails fast on bad names
    with pytest.raises(ValueError):
        LegacyEngine(backend="nope")
    with pytest.raises(ValueError):
        LegacyEngine(schedule="nope")
    # field mutation misses the cache instead of returning stale results
    legacy.backend = "givens_float"
    Q, R = legacy(A)
    B = np.asarray(Q) @ np.asarray(R)
    assert np.allclose(B, A, rtol=1e-3, atol=1e-3)
    # problem-level methods ride along on the shim
    x = legacy.solve(A[..., :2], A[..., 2])
    assert np.asarray(x).shape == (3, 2)


def test_qr_jnp_compute_q_uniform_signature():
    A = matrices((4, 5, 3))
    Q, R = qr_jnp(A, jnp.float64)
    Qn, Rn = qr_jnp(A, jnp.float64, compute_q=False)
    assert Qn is None
    np.testing.assert_array_equal(np.asarray(R), np.asarray(Rn))
    assert float(jnp.mean(snr_db(A, Q, R))) > 200.0


def test_mesh_config_folds_sharded_dispatch_into_call():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    A = matrices((4, 4, 4), r=2.0)
    cfg = GivensConfig(hub=True, n=26)
    plain = api.QRDEngine(backend="cordic", givens=cfg)
    sharded = api.QRDEngine(backend="cordic", givens=cfg, mesh=mesh)
    _assert_bit_exact(plain(A), sharded(A))
    # solve() rides the same mesh dispatch (augmented operand is sharded)
    b = RNG.normal(size=(4, 4))
    np.testing.assert_array_equal(np.asarray(plain.solve(A, b)),
                                  np.asarray(sharded.solve(A, b)))
