"""Streaming QRD-RLS state: convergence, block/unit parity, beamforming.

`RLSState` replaces the beamforming example's hand-rolled update loop;
the contract is the QRD-RLS recursion itself — snapshots annihilated
into the carried ``[R | z]`` with forgetting — on all three update paths
(f64 float loop, bit-accurate unit under one jitted scan, kernel-resident
block annihilation), plus the example-level acceptance: the rewritten
`examples/adaptive_beamforming.py` must reach the same interference
rejection running entirely on the library state.
"""
import importlib.util
import os

import numpy as np
import pytest

from repro import qrd as api
from repro.core import GivensConfig, GivensUnit

RNG = np.random.default_rng(33)


def _drive(state, w_true, T, noise=0.01, seed=5):
    rng = np.random.default_rng(seed)
    n = w_true.shape[0]
    for _ in range(T):
        x = rng.normal(size=n)
        state.update(x, w_true @ x + noise * rng.normal())
    return state


def test_rls_float_and_unit_modes_converge_identically():
    n, T = 4, 150
    w_true = RNG.normal(size=n)
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    sf = _drive(api.RLSState(n, lam=0.995, mode="float"), w_true, T)
    su = _drive(api.RLSState(n, lam=0.995, mode="unit", unit=unit), w_true, T)
    ef = np.linalg.norm(sf.weights() - w_true)
    eu = np.linalg.norm(su.weights() - w_true)
    assert ef < 0.02 and eu < 0.02, (ef, eu)
    # the unit path is the same recursion in the paper's arithmetic: the
    # carried factors agree to the unit's working precision
    np.testing.assert_allclose(su.R, sf.R, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(su.z, sf.z, rtol=1e-4, atol=1e-5)
    assert sf.updates == su.updates == T


@pytest.mark.slow   # kernel-resident block annihilation compile
def test_rls_block_mode_matches_float_weights():
    n, T, block = 5, 60, 3
    w_true = RNG.normal(size=n)
    sb = _drive(api.RLSState(n, lam=0.99, mode="block", block=block),
                w_true, T)
    assert len(sb._pending) == 0                 # T divisible by block
    sf = _drive(api.RLSState(n, lam=0.99, mode="float"), w_true, T)
    # blocked kernel telescopes the forgetting exactly; block-FP datapath
    # noise only (F=24 fraction bits)
    np.testing.assert_allclose(sb.weights(), sf.weights(), atol=5e-3)
    assert np.linalg.norm(sb.weights() - w_true) < 0.05


@pytest.mark.slow   # kernel-resident block annihilation compile
def test_rls_block_partial_flush():
    n = 3
    w_true = RNG.normal(size=n)
    st = _drive(api.RLSState(n, lam=1.0, mode="block", block=4), w_true, 6)
    assert len(st._pending) == 2                 # partial block pending
    st.flush()
    assert len(st._pending) == 0
    assert np.linalg.norm(st.weights() - w_true) < 0.05


def test_rls_validation():
    with pytest.raises(ValueError, match="mode"):
        api.RLSState(4, mode="quantum")
    with pytest.raises(ValueError, match="forgetting"):
        api.RLSState(4, lam=0.0)
    with pytest.raises(ValueError, match="GivensUnit"):
        api.RLSState(4, mode="unit")
    st = api.RLSState(4)
    with pytest.raises(ValueError, match="snapshot length"):
        st.update(np.ones(3), 1.0)


@pytest.mark.parametrize("lam", [0.0, -0.5, 1.0001, float("nan")])
def test_rls_lam_validated_at_every_entry_point(lam):
    """No entry point may accept a non-positive (or >1, or NaN) λ: the √λ
    weighting would silently destroy the carried factor."""
    with pytest.raises(ValueError, match="forgetting"):
        api.RLSState(4, lam=lam)
    with pytest.raises(ValueError, match="forgetting"):
        api.QRDEngine(backend="jnp").rls(4, lam=lam)
    with pytest.raises(ValueError, match="forgetting"):
        api.QRDEngine(backend="jnp").fleet(8, 4, lam=lam)
    # ... and lam=1.0 (no forgetting) remains legal
    assert api.RLSState(4, lam=1.0).lam == 1.0


def test_rls_to_from_arrays_roundtrip_including_pending():
    """to_arrays/from_arrays: the pure-pytree export carries the block
    mode's partial-flush buffer, so a mid-block state survives the trip."""
    n = 3
    w_true = RNG.normal(size=n)
    st = _drive(api.RLSState(n, lam=0.9, mode="block", block=4), w_true, 6)
    assert len(st._pending) == 2
    arrays = st.to_arrays()
    assert arrays["pending"].shape == (4, n + 1)       # fixed-shape pytree
    assert int(arrays["pending_count"]) == 2
    clone = api.RLSState(n, lam=0.5, mode="block", block=4)
    clone.from_arrays(arrays)
    assert clone.lam == 0.9 and clone.updates == st.updates
    # identical futures: one more snapshot then a flush, bit for bit
    x, d = RNG.normal(size=n), RNG.normal()
    st.update(x, d).flush()
    clone.update(x, d).flush()
    np.testing.assert_array_equal(st.R, clone.R)
    np.testing.assert_array_equal(st.z, clone.z)
    # unblocked modes export an empty (0, n+1) buffer
    flat = api.RLSState(n, mode="float")
    flat.update(np.ones(n), 1.0)
    again = api.RLSState(n, mode="float").from_arrays(flat.to_arrays())
    np.testing.assert_array_equal(again.R, flat.R)
    # a pending-carrying export cannot enter a mode with no buffer
    with pytest.raises(ValueError, match="pending"):
        api.RLSState(n, mode="float").from_arrays(arrays)
    bad = dict(arrays)
    bad["lam"] = np.float64(-1.0)
    with pytest.raises(ValueError, match="forgetting"):
        api.RLSState(n, mode="block", block=4).from_arrays(bad)


def _load_beamforming():
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "adaptive_beamforming.py")
    spec = importlib.util.spec_from_file_location("adaptive_beamforming",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_beamforming_example_runs_on_library_state():
    """The example reaches its historical SINR with zero hand-rolled loop."""
    bf = _load_beamforming()
    import inspect
    src = inspect.getsource(bf)
    assert "qrd_rls_update" not in src           # the hand-rolled loop is gone
    assert "RLSState" in src or "eng.rls" in src or ".rls(" in src
    # float path: full 200 snapshots, same > 13 dB rejection bound the
    # example asserts internally (mse < 0.05 * signal power)
    mse = bf.main(use_cordic=False)
    assert mse < 0.05


def test_beamforming_cordic_unit_path_matches_sinr():
    """Per-rotation path on the bit-accurate CORDIC-HUB unit (the paper's
    configuration) reaches the same interference-rejection bound."""
    bf = _load_beamforming()
    mse = bf.main(use_cordic=True)
    assert mse < 0.05
