"""Hypothesis properties: dual-int32 lane primitives vs int64.

Every lane-pair primitive of `repro.kernels.packed_lanes`
(add/sub/mul/shifts/compares/ilog2/RNE shift) checked bit-for-bit
against its int64 counterpart over the full 64-bit range and every
shift amount 0..63.  Deterministic coverage of the same contract (plus
the LaneUnit datapath) lives in test_packed_lanes.py — this module
adds the adversarial search and is skipped without the dev extra.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import packed_lanes as pl
from repro.kernels.cordic_givens import lanes_to_packed, packed_to_lanes

pytest.importorskip("hypothesis",
                    reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

i64 = st.integers(min_value=-(2 ** 63), max_value=2 ** 63 - 1)
shifts = st.integers(min_value=0, max_value=63)


def _lanes(v: int):
    """python int -> stacked (2,) int32 lane array."""
    return packed_to_lanes(jnp.asarray(np.int64(v)))


def _back(L) -> int:
    return int(lanes_to_packed(L))


def _wrap(v: int) -> int:
    """Wrap a python int to signed 64-bit (numpy overflow semantics)."""
    return int(np.int64(np.uint64(v & 0xFFFFFFFFFFFFFFFF)))


@settings(max_examples=200, deadline=None)
@given(i64)
def test_round_trip(v):
    assert _back(_lanes(v)) == v


@settings(max_examples=200, deadline=None)
@given(i64, i64)
def test_add_sub_mul(a, b):
    la, lb = pl.lanes_unstack(_lanes(a)), pl.lanes_unstack(_lanes(b))
    assert _back(pl.lanes_stack(pl.add64(la, lb))) == _wrap(a + b)
    assert _back(pl.lanes_stack(pl.sub64(la, lb))) == _wrap(a - b)
    assert _back(pl.lanes_stack(pl.mul64(la, lb))) == _wrap(a * b)


@settings(max_examples=200, deadline=None)
@given(i64, shifts)
def test_shifts(v, s):
    lv = pl.lanes_unstack(_lanes(v))
    sj = jnp.int32(s)
    u = v & 0xFFFFFFFFFFFFFFFF
    assert _back(pl.lanes_stack(pl.shl64(lv, sj))) == _wrap(u << s)
    assert _back(pl.lanes_stack(pl.shr64(lv, sj))) == _wrap(u >> s)
    assert _back(pl.lanes_stack(pl.sar64(lv, sj))) == v >> s


@settings(max_examples=200, deadline=None)
@given(i64, i64)
def test_compares(a, b):
    la, lb = pl.lanes_unstack(_lanes(a)), pl.lanes_unstack(_lanes(b))
    assert bool(pl.eq64(la, lb)) == (a == b)
    assert bool(pl.is_neg64(la)) == (a < 0)
    ua, ub = a & 0xFFFFFFFFFFFFFFFF, b & 0xFFFFFFFFFFFFFFFF
    assert bool(pl.ltu64(la, lb)) == (ua < ub)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=1, max_value=2 ** 63 - 1))
def test_ilog2(v):
    lv = pl.lanes_unstack(_lanes(v))
    assert int(pl.ilog2_64(lv)) == v.bit_length() - 1


@settings(max_examples=200, deadline=None)
@given(i64, st.integers(min_value=0, max_value=62))
def test_rshift_rne(v, s):
    # reference: round-to-nearest-even on the 2^s grid
    lv = pl.lanes_unstack(_lanes(v))
    got = _back(pl.lanes_stack(pl.rshift_rne64(lv, jnp.int32(s))))
    if s == 0:
        assert got == v
        return
    q, rem = v >> s, v & ((1 << s) - 1)
    half = 1 << (s - 1)
    if rem > half or (rem == half and (q & 1)):
        q += 1
    assert got == _wrap(q)


