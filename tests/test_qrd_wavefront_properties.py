"""Sameh–Kuck schedule properties (hypothesis) — the wavefront invariants.

The wavefront kernels (DESIGN.md §8) gather, rotate and scatter a whole
stage at once; that is only sound if every stage's row pairs are disjoint
and the flattened stage order annihilates each subdiagonal entry exactly
once.  Checked here as properties over random (m, n).
"""
import pytest

pytest.importorskip("hypothesis", reason="dev extra: see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.core import givens_schedule, sameh_kuck_schedule


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=2, max_value=16),
       st.integers(min_value=1, max_value=16))
def test_sameh_kuck_properties(m, n):
    stages = sameh_kuck_schedule(m, n)
    flat = [s for stage in stages for s in stage]
    # every subdiagonal entry annihilated exactly once, none invented
    targets = [(j, c) for (_, j, c) in flat]
    assert len(targets) == len(set(targets))
    assert set(targets) == {(j, c) for (_, j, c) in givens_schedule(m, n)}
    # within a stage all row pairs are disjoint (the wavefront invariant:
    # gather/rotate/scatter of a whole stage cannot race)
    for stage in stages:
        rows = [r for (k, j, _) in stage for r in (k, j)]
        assert len(rows) == len(set(rows))
    # adjacent-row pairing, annihilation strictly below the diagonal
    assert all(k == j - 1 and c < j for (k, j, c) in flat)
    # the collapsed sequential depth of the wavefront datapath
    assert len(stages) == min(m + n - 2, 2 * m - 3)
