"""Autotuner contracts: candidate model, persistence, engine linkage.

`repro.kernels.autotune` (DESIGN.md §11) searches (tile_b,
table_layout) per problem shape and persists winners in a JSON cache
keyed by device kind; `QRDEngine` consults it at dispatch time when the
config leaves ``tile_b=None``.  These tests pin:

* the VMEM-budget candidate model (power-of-two tiles, batch cap,
  never-empty);
* `tune` with an injected deterministic timer — writes the winner,
  `lookup` round-trips it, candidates recorded;
* the engine picks the tuned tile up transparently (inspected through
  its dispatch cache) and numerics are unchanged;
* an explicit ``tile_b`` in the config always beats the cache.

All timing is faked, so the suite is fast and deterministic.
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.qrd_blocked import TILE_B
from repro.qrd import QRDConfig, QRDEngine


@pytest.fixture
def cache(tmp_path, monkeypatch):
    """Point the autotune cache at a fresh per-test file."""
    path = str(tmp_path / "qrd_autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.clear_memo()
    yield path
    autotune.clear_memo()


# --------------------------------------------------------------------------
# Candidate model
# --------------------------------------------------------------------------
def test_candidates_are_powers_of_two_capped_by_batch():
    cands = autotune.candidate_tile_bs(24, 4, 8, 8,
                                       vmem_budget=1 << 30)
    assert cands == (1, 2, 4, 8, 16)          # <= batch, powers of two
    assert autotune.candidate_tile_bs(256, 4, 8, 8,
                                      vmem_budget=1 << 30)[-1] == 64


def test_candidates_respect_vmem_budget():
    # 6 buffers * tile_b * 4*8 elements * 8 B = 1536 B per tile unit:
    # a 8 KiB budget admits tile_b in {1, 2, 4} but not 8.
    cands = autotune.candidate_tile_bs(64, 4, 8, 8, vmem_budget=8192)
    assert cands == (1, 2, 4)


def test_candidates_never_empty():
    # Budget too small even for tile_b=1: the smallest tile survives.
    assert autotune.candidate_tile_bs(64, 32, 64, 8,
                                      vmem_budget=16) == (1,)


def test_candidate_layouts():
    assert autotune.candidate_layouts("sameh_kuck") == ("split", "stacked")
    assert autotune.candidate_layouts("col") == (None,)


# --------------------------------------------------------------------------
# tune() + lookup() with an injected timer
# --------------------------------------------------------------------------
def test_tune_persists_winner_and_lookup_roundtrips(cache):
    calls = []

    def timer(fn, A, warm_reps):
        out = fn(A)                     # real dispatch, fake clock
        assert out[-1].shape == (6, 4, 4)
        calls.append(warm_reps)
        # Favor tile_b=2 with the stacked layout deterministically.
        return len(calls) * 1e-3 if calls else 1e-3

    # Monotone clock makes the *first* candidate the winner: tile 1/split.
    entry = autotune.tune("blockfp_pallas", "sameh_kuck", 4, 4, 6,
                          dtype="float64", warm_reps=2, timer=timer,
                          vmem_budget=1 << 30)
    assert entry.tile_b == 1 and entry.table_layout == "split"
    # 3 tiles (1, 2, 4) x 2 layouts timed.
    assert len(calls) == 6 and set(calls) == {2}
    assert len(entry.candidates) == 6

    hit = autotune.lookup("blockfp_pallas", "sameh_kuck", 4, 4, "float64")
    assert hit is not None
    assert (hit.tile_b, hit.table_layout) == (1, "split")

    # The file is keyed by device kind and carries the schema version.
    doc = json.load(open(cache))
    assert doc["schema_version"] == 1
    key = autotune.cache_key("blockfp_pallas", "sameh_kuck", 4, 4,
                             "float64")
    assert key in doc[autotune.device_kind()]


def test_lookup_misses_cleanly(cache):
    assert autotune.lookup("blockfp_pallas", "col", 9, 9, "float64") is None


def test_tune_rejects_untunable_backend(cache):
    with pytest.raises(ValueError, match="not tunable"):
        autotune.tune("jnp", "col", 4, 4, 6)


# --------------------------------------------------------------------------
# Engine linkage
# --------------------------------------------------------------------------
def _dispatch_config(eng):
    """The resolved QRDConfig of the engine's sole cached dispatch."""
    (key,) = eng._fn_cache.keys()
    return key[3][0]


def _tuned_entry(cache, tile_b, layout):
    """Fake-time a tune() so (tile_b, layout) wins and lands on disk."""
    def timer(fn, A, warm_reps):
        fn(A)
        cfg = timer.configs.pop(0)
        return 1e-3 if cfg == (tile_b, layout) else 2e-3

    tiles = autotune.candidate_tile_bs(6, 4, 8, 4, vmem_budget=1 << 30)
    timer.configs = [(tb, lay) for tb in tiles
                     for lay in ("split", "stacked")]
    return autotune.tune("blockfp_pallas", "sameh_kuck", 4, 4, 6,
                         dtype="float64", warm_reps=1, timer=timer,
                         vmem_budget=1 << 30)


def test_engine_consults_cache(cache):
    entry = _tuned_entry(cache, 2, "stacked")
    assert (entry.tile_b, entry.table_layout) == (2, "stacked")

    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((6, 4, 4)))

    tuned = QRDEngine(QRDConfig(backend="blockfp_pallas",
                                schedule="sameh_kuck", dtype="float64"))
    Qt, Rt = tuned(A)
    cfg = _dispatch_config(tuned)
    assert cfg.tile_b == 2 and cfg.table_layout == "stacked"

    # Numerics are invariant under the tuned tile.
    fixed = QRDEngine(QRDConfig(backend="blockfp_pallas",
                                schedule="sameh_kuck", dtype="float64",
                                tile_b=TILE_B))
    Qf, Rf = fixed(A)
    assert bool(jnp.all(Qt == Qf)) and bool(jnp.all(Rt == Rf))


def test_explicit_tile_b_beats_cache(cache):
    _tuned_entry(cache, 4, "split")
    rng = np.random.default_rng(1)
    A = jnp.asarray(rng.standard_normal((6, 4, 4)))
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas",
                              schedule="sameh_kuck", dtype="float64",
                              tile_b=2, table_layout="stacked"))
    eng(A)
    cfg = _dispatch_config(eng)
    assert cfg.tile_b == 2 and cfg.table_layout == "stacked"


def test_untuned_backend_ignores_cache(cache):
    _tuned_entry(cache, 2, "stacked")
    rng = np.random.default_rng(2)
    A = jnp.asarray(rng.standard_normal((6, 4, 4)))
    eng = QRDEngine(QRDConfig(backend="jnp", dtype="float64"))
    eng(A)
    cfg = _dispatch_config(eng)
    assert cfg.tile_b is None


def test_config_validation():
    # validate() runs at engine construction, not dataclass __init__.
    with pytest.raises(ValueError, match="table_layout"):
        QRDEngine(QRDConfig(backend="blockfp_pallas",
                            table_layout="diagonal"))
    with pytest.raises(ValueError, match="tile_b"):
        QRDEngine(QRDConfig(backend="blockfp_pallas", tile_b=0))
