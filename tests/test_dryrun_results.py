"""Validate the committed dry-run artifact (deliverable e evidence)."""
import json
import os

import pytest

PATH = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.json")


@pytest.fixture(scope="module")
def results():
    if not os.path.exists(PATH):
        pytest.skip("dryrun_results.json not present (run launch.dryrun --all)")
    with open(PATH) as f:
        return json.load(f)


def test_all_base_cells_compiled(results):
    from repro.configs import applicable_cells
    for arch, shape in applicable_cells():
        for mesh in ("16x16", "2x16x16"):
            key = f"{arch}|{shape}|{mesh}|base"
            assert key in results, f"missing {key}"
            assert results[key].get("ok"), f"{key}: {results[key].get('error')}"


def test_collectives_present_on_all_train_cells(results):
    for key, rec in results.items():
        if not rec.get("ok") or rec["tag"] != "base":
            continue
        if rec["shape"].startswith("train"):
            assert rec.get("collectives", {}).get("total", 0) > 0, key


def test_perf_iterations_improved_memory(results):
    """The §Perf tags must show the recorded improvements."""
    base = results["deepseek-v2-236b|train_4k|16x16|base"]
    opt = results.get("deepseek-v2-236b|train_4k|16x16|sp_mb8")
    if opt and opt.get("ok"):
        assert opt["bytes_per_device"] < 0.35 * base["bytes_per_device"]
    b2 = results["mamba2-780m|train_4k|16x16|base"]
    z1 = results.get("mamba2-780m|train_4k|16x16|dp_z1")
    if z1 and z1.get("ok"):
        assert z1["bytes_per_device"] < 16 * 2 ** 30      # fits HBM
        assert z1["collectives"]["total"] < 0.25 * b2["collectives"]["total"]
