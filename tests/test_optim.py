"""Optimizers: AdamW, QMuon (Givens-QR orthogonalized), compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (adamw_init, adamw_update, ef_compress,
                         dequantize_int8, qmuon_init, qmuon_update,
                         quantize_int8, warmup_cosine)
from repro.optim.qmuon import _orth_qr


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = adamw_init(params)
    for _ in range(200):
        g = {"w": 2.0 * params["w"]}
        params, state = adamw_update(g, state, params, lr=0.05,
                                     weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


@pytest.mark.parametrize("backend", ["jnp", "givens_float"])
@pytest.mark.parametrize("shape", [(16, 8), (8, 16), (12, 12)])
def test_orth_qr_produces_orthonormal(backend, shape):
    m = jax.random.normal(jax.random.PRNGKey(0), shape, jnp.float32)
    u = _orth_qr(m, backend=backend)
    p, q = shape
    scale = np.sqrt(max(p, q) / min(p, q))
    if p >= q:
        gram = np.asarray(u.T @ u) / scale ** 2
    else:
        gram = np.asarray(u @ u.T) / scale ** 2
    np.testing.assert_allclose(gram, np.eye(min(p, q)), atol=2e-3)


def test_orth_backends_agree():
    """The paper's Givens schedule and LAPACK QR give the same Q (sign-fixed)."""
    m = jax.random.normal(jax.random.PRNGKey(1), (12, 6), jnp.float32)
    u1 = _orth_qr(m, backend="jnp")
    u2 = _orth_qr(m, backend="givens_float")
    np.testing.assert_allclose(np.asarray(u1), np.asarray(u2),
                               atol=5e-3, rtol=5e-3)


def test_qmuon_trains_linear_regression():
    rng = np.random.default_rng(0)
    Wtrue = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    X = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
    Y = X @ Wtrue
    params = {"w": jnp.zeros((8, 4), jnp.float32),
              "b": jnp.zeros((4,), jnp.float32)}
    state = qmuon_init(params)

    def loss_fn(p):
        return jnp.mean((X @ p["w"] + p["b"] - Y) ** 2)

    l0 = float(loss_fn(params))
    for i in range(200):
        g = jax.grad(loss_fn)(params)
        # constant-norm orthogonal steps need a decaying LR to settle
        params, state = qmuon_update(g, state, params,
                                     lr=0.15 * 0.97 ** i)
    assert float(loss_fn(params)) < 0.05 * l0


def test_qmuon_handles_stacked_layers():
    params = {"layers": {"w": jnp.ones((3, 8, 4))},   # (L, p, q) stacked
              "norm": jnp.ones((4,))}
    state = qmuon_init(params)
    g = jax.tree.map(jnp.ones_like, params)
    new_p, state = qmuon_update(g, state, params, lr=0.1)
    assert new_p["layers"]["w"].shape == (3, 8, 4)
    assert not np.allclose(np.asarray(new_p["layers"]["w"]), 1.0)


def test_int8_quant_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 7.3
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-9


def test_error_feedback_preserves_signal():
    """Sum of compressed grads + final residual == sum of true grads."""
    rng = np.random.default_rng(3)
    res = jnp.zeros((64,))
    total_true = np.zeros((64,))
    total_sent = np.zeros((64,))
    for i in range(50):
        g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        q, s, res = ef_compress(g, res)
        total_true += np.asarray(g)
        total_sent += np.asarray(dequantize_int8(q, s))
    drift = np.abs(total_sent + np.asarray(res) - total_true)
    assert drift.max() < 1e-3


def test_schedule_shapes():
    lr0 = float(warmup_cosine(0, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lr10 = float(warmup_cosine(10, peak_lr=1.0, warmup_steps=10, total_steps=100))
    lr100 = float(warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    assert lr0 == 0.0 and abs(lr10 - 1.0) < 1e-6 and lr100 < 0.2
