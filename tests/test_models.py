"""Per-architecture smoke tests (reduced configs) + layer unit tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_config
from repro.models import (decode_step, init_decode_state, init_params,
                          prefill, train_loss)

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch):
    """One train step + prefill + decode on the reduced config (assignment)."""
    cfg = reduce_config(get_config(arch))
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)

    loss, metrics = jax.jit(lambda p, b: train_loss(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    logits, cache = jax.jit(lambda p, b: prefill(cfg, p, b, S + 8))(params, batch)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, t, c: decode_step(cfg, p, t, c, S))(params, tok, cache)
    assert logits2.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))

    # decode from a fresh zero state (the dry-run serve path)
    st = init_decode_state(cfg, B, S + 8)
    logits3, _ = jax.jit(
        lambda p, t, c: decode_step(cfg, p, t, c, 0))(params, tok, st)
    assert np.all(np.isfinite(np.asarray(logits3, np.float32)))


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyper-parameters."""
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (60, 5120, 128, 102400)
    assert c.moe.n_experts == 160 and c.moe.top_k == 6 and c.moe.n_shared == 2
    assert c.mla.kv_lora == 512
    c = get_config("command-r-35b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (40, 8192, 64, 8, 22528, 256000)
    c = get_config("mamba2-780m")
    assert (c.n_layers, c.d_model, c.vocab) == (48, 1536, 50280)
    assert c.ssm.d_state == 128 and c.subquadratic
    c = get_config("recurrentgemma-2b")
    assert c.pattern == ("rec", "rec", "attn") and c.window == 2048
    c = get_config("llama-3.2-vision-90b")
    assert c.n_layers == 100 and c.cross_every == 5
    c = get_config("whisper-medium")
    assert c.enc_layers == 24 and c.n_layers == 24 and c.vocab == 51865


def test_param_counts_sane():
    approx = {
        "deepseek-v2-236b": 236e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "command-r-35b": 35e9, "starcoder2-7b": 7e9, "qwen3-8b": 8e9,
        "stablelm-1.6b": 1.6e9, "mamba2-780m": 0.78e9,
        "recurrentgemma-2b": 2.7e9, "llama-3.2-vision-90b": 90e9,
        "whisper-medium": 0.76e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.55 * target < n < 1.6 * target, (arch, n, target)


def test_chunked_attention_matches_dense():
    from repro.models.layers import attention, chunked_attention
    k_ = jax.random.PRNGKey(1)
    B_, S_, H, Hk, D = 2, 256, 4, 2, 16
    q = jax.random.normal(k_, (B_, S_, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(k_, 1), (B_, S_, Hk, D))
    v = jax.random.normal(jax.random.fold_in(k_, 2), (B_, S_, Hk, D))
    dense = attention(q, k, v, causal=True)
    chunked = chunked_attention(q, k, v, causal=True, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)
    # sliding window variant
    dw = attention(q, k, v, causal=True, window=32)
    cw = chunked_attention(q, k, v, causal=True, window=32, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(cw), atol=2e-5)


def test_ssd_chunked_matches_naive_recurrence():
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    B_, S_, H, hd, G, N = 2, 64, 4, 8, 1, 16
    xdt = jnp.asarray(rng.normal(size=(B_, S_, H, hd)), jnp.float32)
    a = jnp.asarray(-np.abs(rng.normal(size=(B_, S_, H))) * 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B_, S_, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B_, S_, G, N)), jnp.float32)
    y, state = ssd_chunked(xdt, a, Bm, Cm, chunk=16)
    # naive recurrence
    h = np.zeros((B_, H, N, hd))
    ys = np.zeros((B_, S_, H, hd))
    for t in range(S_):
        decay = np.exp(np.asarray(a[:, t]))[:, :, None, None]
        inp = np.einsum("bn,bhd->bhnd", np.asarray(Bm[:, t, 0]),
                        np.asarray(xdt[:, t]))
        h = h * decay + inp
        ys[:, t] = np.einsum("bn,bhnd->bhd", np.asarray(Cm[:, t, 0]), h)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), h, atol=1e-3, rtol=1e-3)


def test_rglru_scan_matches_step():
    from repro.models.rglru import (RGLRUConfig, rglru_apply, rglru_init,
                                    rglru_init_cache, rglru_step)
    cfg = RGLRUConfig(lru_width=16)
    p = rglru_init(jax.random.PRNGKey(3), 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 12, 8), jnp.float32)
    y_all, _ = rglru_apply(x, p, cfg, 8)
    cache = rglru_init_cache(2, 8, cfg, jnp.float32)
    ys = []
    for t in range(12):
        y1, cache = rglru_step(x[:, t:t + 1], cache, p, cfg, 8)
        ys.append(y1)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_all), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-4)


def test_gqa_prefill_then_decode_consistent():
    """Next-token logits from (prefill S) == (prefill S via step-by-step)."""
    from repro.configs import get_config, reduce_config
    cfg = reduce_config(get_config("qwen3-8b"))
    params = init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (1, 8), 0, cfg.vocab)}
    logits_p, cache = prefill(cfg, params, batch, 16)
    # step-by-step: feed tokens one at a time from a zero cache
    st = init_decode_state(cfg, 1, 16)
    for t in range(8):
        logits_s, st = decode_step(cfg, params,
                                   batch["tokens"][:, t:t + 1], st, t)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_s),
                               atol=3e-2, rtol=3e-2)
