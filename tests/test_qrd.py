"""QRD engines: reconstruction, orthogonality, paper's error-analysis claims."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (GivensConfig, GivensUnit, QRDEngine, qr_cordic,
                        qr_fixed, qr_givens_float, snr_db,
                        givens_schedule)


def matrices(seed, n, r=4.0, m=4):
    rng = np.random.default_rng(seed)
    mag = np.exp2(rng.uniform(-r, r, size=(n, m, m)))
    return rng.choice([-1.0, 1.0], size=(n, m, m)) * mag


A64 = matrices(0, 64)


def test_schedule_covers_subdiagonal():
    steps = givens_schedule(4, 4)
    assert len(steps) == 6
    zeroed = {(j, c) for (_, j, c) in steps}
    assert zeroed == {(1, 0), (2, 0), (3, 0), (2, 1), (3, 1), (3, 2)}


@pytest.mark.parametrize("hub,n,it", [(False, 26, 23), (True, 25, 23),
                                      (True, 29, 27)])
def test_cordic_qr_reconstruction_and_orthogonality(hub, n, it):
    unit = GivensUnit(GivensConfig(hub=hub, n=n, iters=it))
    Q, R = qr_cordic(A64, unit)
    B = np.asarray(Q) @ np.asarray(R)
    snr = float(jnp.mean(snr_db(A64, Q, R)))
    assert snr > 115.0, snr
    I = np.eye(4)
    ortho = np.max(np.abs(np.swapaxes(np.asarray(Q), -1, -2) @ np.asarray(Q) - I))
    assert ortho < 1e-5
    # R strictly upper triangular below diagonal
    assert np.all(np.tril(np.asarray(R), -1) == 0.0)


def test_fig9_claims():
    """IEEE peaks at N-3 and degrades beyond; HUB(N) ~ IEEE(N+1)."""
    A = matrices(1, 256)
    def snr(hub, n, it):
        u = GivensUnit(GivensConfig(hub=hub))
        Q, R = qr_cordic(A, u, N=jnp.asarray(n), iters=jnp.asarray(it))
        return float(jnp.mean(snr_db(A, Q, R)))

    ieee_peak = snr(False, 26, 23)
    ieee_more = snr(False, 26, 26)     # extra iterations hurt (conventional)
    assert ieee_peak > ieee_more
    hub25 = snr(True, 25, 23)
    ieee26 = snr(False, 26, 23)
    # HUB needs one bit less for the same precision (paper Fig. 9)
    assert hub25 > ieee26 - 1.5


def test_hub_beats_ieee_at_equal_n():
    A = matrices(2, 256)
    ui = GivensUnit(GivensConfig(hub=False, n=26))
    uh = GivensUnit(GivensConfig(hub=True, n=26))
    si = float(jnp.mean(snr_db(A, *qr_cordic(A, ui))))
    sh = float(jnp.mean(snr_db(A, *qr_cordic(A, uh))))
    assert sh > si


def test_identity_detection_improves_q():
    """Fig. 10: detecting the exact 1.0s of the augmented identity helps."""
    A = matrices(3, 256)
    on = GivensUnit(GivensConfig(hub=True, n=26, detect_identity=True))
    off = GivensUnit(GivensConfig(hub=True, n=26, detect_identity=False,
                                  unbiased=False))
    s_on = float(jnp.mean(snr_db(A, *qr_cordic(A, on))))
    s_off = float(jnp.mean(snr_db(A, *qr_cordic(A, off))))
    assert s_on > s_off


def test_fixed_point_dynamic_range_collapse():
    """Fig. 11: FixP wins at small r, collapses at large r; FP stays flat."""
    uh = GivensUnit(GivensConfig(hub=True, n=26))
    A_small = matrices(4, 128, r=2.0)
    A_big = matrices(5, 128, r=25.0)
    fx_small = float(jnp.mean(snr_db(A_small, *qr_fixed(A_small, 32, 27, 2))))
    fp_small = float(jnp.mean(snr_db(A_small, *qr_cordic(A_small, uh))))
    fx_big = float(jnp.mean(snr_db(A_big, *qr_fixed(A_big, 32, 27, 25))))
    fp_big = float(jnp.mean(snr_db(A_big, *qr_cordic(A_big, uh))))
    assert fx_small > fp_small          # more effective bits at low range
    assert fp_big > fx_big + 30         # FP holds, FixP collapses
    assert abs(fp_big - fp_small) < 10  # FP roughly flat in r


def test_engine_backends_consistent():
    A = matrices(6, 16)
    for backend in ("jnp", "givens_float", "cordic", "fixed"):
        eng = QRDEngine(backend=backend, fixed_scale_exp=5)
        Q, R = eng(A)
        B = np.asarray(Q) @ np.asarray(R)
        assert np.allclose(B, A, rtol=1e-3, atol=1e-3), backend


def test_rectangular_qr_float():
    A = matrices(7, 8, m=6)[:, :, :3]  # (8, 6, 3) tall
    Q, R = qr_givens_float(A, dtype=jnp.float64)
    assert np.allclose(np.asarray(Q) @ np.asarray(R), A, atol=1e-8)
    QtQ = np.swapaxes(np.asarray(Q), -1, -2) @ np.asarray(Q)
    assert np.allclose(QtQ, np.eye(6), atol=1e-8)


def test_half_precision_unit():
    """The unit is format-parametric: half precision (N=14, paper Table 1)."""
    from repro.core import HALF
    unit = GivensUnit(GivensConfig(fmt=HALF, hub=True, n=13, iters=11))
    A = matrices(8, 64, r=2.0)
    Q, R = qr_cordic(A, unit)
    snr = float(jnp.mean(snr_db(A, Q, R)))
    # half precision: ~10-bit mantissa => SNR in the 50-70 dB band
    assert 45.0 < snr < 80.0, snr
    ortho = np.max(np.abs(np.swapaxes(np.asarray(Q), -1, -2) @ np.asarray(Q)
                          - np.eye(4)))
    assert ortho < 2e-2
