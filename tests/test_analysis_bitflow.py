"""Bit-width dataflow verifier: proof, negative-control, and lane tests.

Three layers:

1. the full-datapath proof discharges every obligation for every paper
   configuration (the CI contract behind ``python -m repro.analysis``);
2. negative controls: deliberately over-wide inputs must FAIL checks —
   a verifier that cannot fail proves nothing;
3. differential containment: for concrete int64 edge values, the
   abstract transfer functions must contain the value computed by the
   real dual-int32 lane primitives in `kernels/packed_lanes.py`.
   (Randomized spec-level differentials live in
   test_analysis_bitflow_properties.py under the hypothesis dev extra.)
"""
import numpy as np
import pytest

from repro.analysis.bitflow import (Alu, paper_configs, verify_all,
                                    verify_config)
from repro.analysis.domain import (INT64_MAX, INT64_MIN, M64, ProofLog,
                                   const, interval)


def _signed(u):
    u &= M64
    return u - (1 << 64) if u >> 63 else u


# -- 1. the proof itself ------------------------------------------------------

def test_all_paper_configs_prove():
    rep = verify_all()
    assert rep.ok, rep.failed[:5]
    # 18 configs x full datapath + the lane lemmas: a meaningful corpus
    assert len(rep.configs) >= 15
    assert sum(len(c["checks"]) for c in rep.configs) > 4000


def test_proven_widths_match_format_constants():
    """The report is the software analogue of the paper's tables: the
    proven occupancies must land on (and inside) the architectural
    widths N, w = N+2, and the IEEE field sizes."""
    rep = verify_all()
    for c in rep.configs:
        n = int(c["name"].split("-n")[1].split("-")[0])
        s = c["stages"]
        assert s["expand-occupancy"]["bits"] <= n
        assert s["expand-occupancy"]["capacity"] == n
        assert s["cordic-w-occupancy"]["capacity"] == n + 2   # w = N+2
        assert s["cordic-w-occupancy"]["bits"] <= n + 2
        man_cap = s["man-occupancy"]["capacity"]
        assert man_cap in (10, 23)                            # half/single
        assert s["man-occupancy"]["bits"] <= man_cap
        assert s["exp-occupancy"]["capacity"] in (5, 8)


def test_lane_lemmas_prove():
    rep = verify_all(configs=paper_configs()[:1])
    ops = {c.op for c in rep.lane_checks}
    assert "mul32-mid-no-wrap" in ops
    assert "funnel-shift-defined" in ops
    assert all(c.ok for c in rep.lane_checks)


def test_report_round_trips_to_json():
    import json
    rep = verify_all(configs=paper_configs()[:1])
    back = json.loads(json.dumps(rep.as_dict()))
    assert back["ok"] is True
    assert back["failed"] == 0


# -- 2. negative controls -----------------------------------------------------

def test_admit64_flags_overflow():
    log = ProofLog()
    alu = Alu(log)
    big = const(INT64_MAX)
    alu.add64(big, big)                 # 2^64-2: cannot fit
    assert not log.ok
    assert any(c.op == "add64" and not c.ok for c in log.checks)


def test_admit64_wraps_like_hardware():
    """On failure the result must mirror concrete modular semantics."""
    log = ProofLog()
    alu = Alu(log)
    w = alu.add64(const(INT64_MAX), const(1))
    assert not log.ok
    assert w.contains(_signed(INT64_MAX + 1))   # == INT64_MIN


def test_mul_overflow_detected():
    log = ProofLog()
    alu = Alu(log)
    alu.mul64(const(1 << 40), const(1 << 40))
    assert not log.ok


def test_unmasked_wide_shift_fails_rne_confinement():
    """rshift_rne64 with an unclamped shift range and no masking bound
    must fail the half-bit confinement obligation."""
    log = ProofLog()
    alu = Alu(log)
    alu.rshift_rne64(interval(0, 1 << 40), interval(0, 100),
                     masked_above=None)
    assert any(c.op == "rne-half-confined" and not c.ok
               for c in log.checks)


def test_oversized_config_rejected():
    """N > 50 breaks the float64-frexp ilog2 exactness domain; the
    verifier must refuse rather than silently prove nonsense."""
    from repro.core.givens import GivensConfig
    with pytest.raises(ValueError):
        verify_config(GivensConfig(n=55, hub=False))


# -- 3. differential vs the real int32 lanes (vectorized jax calls) -----------

def _edge_values():
    rng = np.random.default_rng(20260808)
    vals = [0, 1, -1, 2, -2, INT64_MAX, INT64_MIN, INT64_MAX - 1,
            INT64_MIN + 1, (1 << 32) - 1, 1 << 32, -(1 << 32),
            (1 << 31) - 1, 1 << 31, 0x5555555555555555,
            _signed(0xAAAAAAAAAAAAAAAA)]
    vals += [int(x) for x in rng.integers(INT64_MIN, INT64_MAX, 48,
                                          dtype=np.int64)]
    return vals


def test_lane_primitives_contained_in_abstract():
    pl = pytest.importorskip("repro.kernels.packed_lanes")
    import jax.numpy as jnp

    vals = _edge_values()
    pairs = [(a, b) for a in vals[:16] for b in vals[:16]]
    pairs += list(zip(vals, reversed(vals)))
    A = np.array([p[0] for p in pairs], dtype=np.int64)
    B = np.array([p[1] for p in pairs], dtype=np.int64)

    def to_lanes(X):
        return (jnp.asarray((X >> 32) & 0xFFFFFFFF, jnp.uint32),
                jnp.asarray(X & 0xFFFFFFFF, jnp.uint32))

    def from_lanes(pair):
        h = np.asarray(pair[0], np.uint64)
        l = np.asarray(pair[1], np.uint64)
        return [(int(hh) << 32) | int(ll) for hh, ll in zip(h, l)]

    la, lb = to_lanes(A), to_lanes(B)
    sh = np.abs(A) % 64
    lsh = jnp.asarray(sh, jnp.int32)   # shifts are plain int32, not lanes

    concrete = {
        "add64": from_lanes(pl.add64(la, lb)),
        "sub64": from_lanes(pl.sub64(la, lb)),
        "mul64": from_lanes(pl.mul64(la, lb)),
        "and64": from_lanes(pl.and64(la, lb)),
        "or64": from_lanes(pl.or64(la, lb)),
        "xor64": from_lanes(pl.xor64(la, lb)),
        "shl64": from_lanes(pl.shl64(la, lsh)),
        "shr64": from_lanes(pl.shr64(la, lsh)),
        "sar64": from_lanes(pl.sar64(la, lsh)),
        "rshift_rne64": from_lanes(pl.rshift_rne64(la, lsh)),
    }

    for i, (a, b) in enumerate(pairs):
        s = int(sh[i])
        alu = Alu(ProofLog())
        wa, wb, ws = const(a), const(b), const(s)
        abstract = {
            "add64": alu.add64(wa, wb),
            "sub64": alu.sub64(wa, wb),
            "mul64": alu.mul64(wa, wb),
            "and64": alu.and64(wa, wb),
            "or64": alu.or64(wa, wb),
            "xor64": alu.xor64(wa, wb),
            "shl64": alu.shl64(wa, ws),
            "shr64": alu.shr64(wa, ws),
            "sar64": alu.sar64(wa, ws),
            "rshift_rne64": alu.rshift_rne64(wa, ws, masked_above=63),
        }
        for op, words in abstract.items():
            got = _signed(concrete[op][i])
            assert words.contains(got), (
                f"{op}(a={a:#x}, b={b:#x}, s={s}): concrete {got:#x} "
                f"escapes abstract {words}")


def test_lane_ilog2_contained():
    pl = pytest.importorskip("repro.kernels.packed_lanes")
    import jax.numpy as jnp
    vals = [v for v in _edge_values() if v > 0]
    A = np.array(vals, dtype=np.int64)
    la = (jnp.asarray((A >> 32) & 0xFFFFFFFF, jnp.uint32),
          jnp.asarray(A & 0xFFFFFFFF, jnp.uint32))
    ks = np.asarray(pl.ilog2_64(la))
    for v, k in zip(vals, ks):
        alu = Alu(ProofLog())
        assert alu.ilog2_64(const(v)).contains(int(k))
