"""Tiled QRD routes: panel bit-identity, TSQR tree reduction, routing.

DESIGN.md §14 contracts:

* the panel route replays the *identical* rotation sequence as the flat
  column-major schedule (panel step tables concatenate to
  `repro.core.qrd.givens_schedule`), so the packed datapath is
  bit-identical to the flat kernels and to the host reference loop —
  IEEE and HUB both;
* the tsqr route's R is bit-identical to a host-composed tree reference
  running the same padded tree through `repro.core.qrd.qr_cordic` one
  node at a time; Q (float composition) matches to f64-rounding;
* the float-path factors of both routes stay within the golden
  tolerances vs ``np.linalg.qr`` on tall-skinny shapes, ragged last
  tiles included;
* `repro.qrd.tiled.resolve_route` is deterministic, keeps small shapes
  on the flat path, and raises the documented capacity ``ValueError``
  (naming ``max_shape`` and the tiled alternatives) instead of the old
  opaque Pallas failure;
* the tiled autotune entries round-trip and the engine fills
  ``panel_n``/``tile_m`` from them only when the config left them None.

The big acceptance shapes (64x64 panel, 4096x32 tsqr through
``engine()``/``engine.solve()``) are marked ``slow`` — interpret-mode
trace+compile dominates them; the fast lane still covers every contract
at small shapes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import qrd as q
from repro.core.givens import GivensConfig, GivensUnit
from repro.kernels import autotune, ops
from repro.qrd import QRDConfig, QRDEngine, get_backend, tiled


def _caps(backend="blockfp_pallas"):
    return get_backend(backend).capabilities


# --------------------------------------------------------------------------
# Route resolution (pure, no jit)
# --------------------------------------------------------------------------
def test_auto_small_shapes_stay_flat():
    caps = _caps()
    cfg = QRDConfig(backend="blockfp_pallas")
    for m, n in ((4, 4), (8, 8), (32, 32), (32, 4)):
        assert tiled.resolve_route(cfg, m, n, caps) == "flat"


def test_auto_routes_panel_and_tsqr():
    caps = _caps()
    cfg = QRDConfig(backend="blockfp_pallas")
    assert tiled.resolve_route(cfg, 64, 64, caps) == "panel"
    assert tiled.resolve_route(cfg, 4096, 32, caps) == "tsqr"
    # decisively tall-skinny routes tsqr even under the row capacity
    assert tiled.resolve_route(cfg, 40, 4, caps) == "tsqr"
    # wide-but-short exceeds FLAT_LIMIT columns: panel streams them
    assert tiled.resolve_route(cfg, 16, 200, caps) == "panel"


def test_forced_tiling_is_honored():
    caps = _caps()
    cfg = QRDConfig(backend="blockfp_pallas", tiling="panel")
    assert tiled.resolve_route(cfg, 4, 4, caps) == "panel"
    cfg = QRDConfig(backend="blockfp_pallas", tiling="tsqr")
    assert tiled.resolve_route(cfg, 40, 4, caps) == "tsqr"
    cfg = QRDConfig(backend="blockfp_pallas", tiling="flat")
    assert tiled.resolve_route(cfg, 32, 32, caps) == "flat"


def test_non_tiling_backends_always_flat():
    caps = _caps("cordic")
    cfg = QRDConfig(backend="cordic")
    assert tiled.resolve_route(cfg, 10000, 64, caps) == "flat"
    assert caps.fits_flat(10000, 64)       # max_shape=None: no cap


def test_capacity_error_names_max_shape_and_alternatives():
    caps = _caps()
    cfg = QRDConfig(backend="blockfp_pallas", tiling="flat")
    with pytest.raises(ValueError, match=r"max_shape=\(128, 128\)"):
        tiled.resolve_route(cfg, 200, 4, caps)
    with pytest.raises(ValueError, match="tiling='tsqr'"):
        tiled.resolve_route(cfg, 200, 4, caps)
    # auto dead-end: too many rows AND too wide for tsqr nodes
    cfg = QRDConfig(backend="blockfp_pallas")
    with pytest.raises(ValueError, match="max_shape"):
        tiled.resolve_route(cfg, 200, 200, caps)


def test_sameh_kuck_and_complex_reject_tiled_routes():
    caps = _caps("cordic_pallas")
    cfg = QRDConfig(backend="cordic_pallas", schedule="sameh_kuck",
                    tiling="panel")
    with pytest.raises(ValueError, match="sameh_kuck"):
        tiled.resolve_route(cfg, 64, 64, caps)
    cfg = QRDConfig(backend="cordic_pallas", dtype="complex128",
                    tiling="tsqr")
    with pytest.raises(ValueError, match="complex"):
        tiled.resolve_route(cfg, 4096, 32, caps)


def test_engine_raises_capacity_error_at_dispatch():
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas", tiling="flat"))
    with pytest.raises(ValueError, match="max_shape"):
        eng(np.zeros((200, 4)))
    with pytest.raises(ValueError, match="tiling='panel'"):
        eng.solve(np.zeros((200, 4)), np.zeros(200))


def test_config_validates_tiling_fields():
    with pytest.raises(ValueError, match="unknown tiling"):
        QRDConfig(backend="blockfp_pallas", tiling="bogus").validate()
    with pytest.raises(ValueError, match="tile_m"):
        QRDConfig(backend="blockfp_pallas", tile_m=1).validate()
    with pytest.raises(ValueError, match="no tiled datapath"):
        QRDConfig(backend="cordic", tiling="panel").validate()
    QRDConfig(backend="blockfp_pallas", tiling="tsqr",
              tile_m=64, panel_n=8).validate()


# --------------------------------------------------------------------------
# Panel route: bit-identity with the flat schedule (kernel level)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("hub", [False, True])
def test_panel_packed_bit_identical_to_flat(hub):
    rng = np.random.default_rng(0)
    m, n = 12, 6
    A = jnp.asarray(rng.standard_normal((2, m, n)))
    unit = GivensUnit(GivensConfig(hub=hub))
    P = unit.encode(q._augment(A, True))
    flat = ops.qr_packed(P, cfg=unit.cfg, steps=q.givens_schedule(m, n))
    for pw in (3, 8):      # ragged and aligned panel widths
        pan = ops.qr_packed_panel(P, cfg=unit.cfg, n_cols=n, panel_n=pw)
        assert bool(jnp.all(pan == flat)), f"hub={hub} pw={pw}"


@pytest.mark.parametrize("hub", [False, True])
def test_panel_blockfp_bit_identical_to_flat(hub):
    rng = np.random.default_rng(1)
    m, n = 12, 6
    W = q._augment(jnp.asarray(rng.standard_normal((2, m, n))), True)
    flat = ops.givens_block_apply(W, q.givens_schedule(m, n), hub=hub)
    pan = ops.givens_block_apply_panel(W, n_cols=n, hub=hub, panel_n=4)
    assert bool(jnp.all(pan == flat))


def test_panel_steps_concatenate_to_flat_schedule():
    m, n = 9, 5
    flat = q.givens_schedule(m, n)
    got = []
    for c0 in range(0, min(n, m - 1), 2):
        nc = min(2, n - c0)
        piv, tgt, col = ops.panel_steps(m - c0, nc)
        got += [(int(p) + c0, int(t) + c0, int(c) + c0)
                for p, t, c in zip(piv, tgt, col)]
    assert tuple(got) == flat


# --------------------------------------------------------------------------
# Panel route through the engine: bit-identical to the host reference
# --------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("hub", [False, True])
def test_engine_panel_matches_host_reference_bitwise(hub):
    rng = np.random.default_rng(2)
    # 24x10 at panel_n=4: three panels (ragged last) — big enough to
    # exercise trailing-panel replay, small enough that the interpret
    # -mode flat reference kernel stays in CI budget.
    m, n = 24, 10
    A = rng.standard_normal((m, n))
    eng = QRDEngine(QRDConfig(backend="cordic_pallas",
                              givens=GivensConfig(hub=hub), tiling="panel",
                              panel_n=4))
    Q, R = eng(A)
    # Reference: the flat kernel path (itself bit-identical to the
    # qr_cordic host loop — see test_qrd_blocked).  Eager qr_cordic at
    # this size dispatches thousands of tiny per-primitive XLA compiles
    # (CPU-compiler segfault territory late in a long suite).
    unit = GivensUnit(GivensConfig(hub=hub))
    Qr, Rr = q.qr_cordic_pallas(jnp.asarray(A), unit)
    assert np.array_equal(np.asarray(R), np.asarray(Rr))
    assert np.array_equal(np.asarray(Q), np.asarray(Qr))


# --------------------------------------------------------------------------
# TSQR tree: R bit-identical to the host-composed tree reference
# --------------------------------------------------------------------------
@pytest.mark.parametrize("hub", [False, True])
def test_tsqr_r_bit_identical_to_host_tree(hub):
    rng = np.random.default_rng(3)
    m, n, tm = 40, 4, 12          # ragged last leaf (40 = 3*12 + 4)
    A = rng.standard_normal((m, n))
    eng = QRDEngine(QRDConfig(backend="cordic_pallas",
                              givens=GivensConfig(hub=hub),
                              tiling="tsqr", tile_m=tm))
    Q, R = eng(A)
    unit = GivensUnit(GivensConfig(hub=hub))
    Qr, Rr = tiled.tsqr_host_reference(
        A, lambda X: q.qr_cordic(jnp.asarray(X), unit), tm)
    assert np.array_equal(np.asarray(R), Rr)
    # Q is float composition: XLA vs host BLAS sum orders differ
    np.testing.assert_allclose(np.asarray(Q), Qr, atol=1e-12)
    assert np.abs(np.asarray(Q) @ np.asarray(R) - A).max() < 1e-4


def test_tsqr_returns_economy_factors():
    rng = np.random.default_rng(4)
    m, n = 40, 4
    A = rng.standard_normal((2, m, n))
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas", tiling="tsqr",
                              tile_m=12))
    Q, R = eng(A)
    assert Q.shape == (2, m, n) and R.shape == (2, n, n)
    _, R_only = eng(A, compute_q=False)
    assert R_only.shape == (2, n, n)


# --------------------------------------------------------------------------
# Float-path golden tolerances vs np.linalg.qr (tall-skinny, ragged)
# --------------------------------------------------------------------------
def _sign_normalize(Q, R):
    """Fix the QR sign ambiguity: make every R diagonal non-negative."""
    s = np.sign(np.diagonal(R, axis1=-2, axis2=-1))
    s = np.where(s == 0, 1.0, s)
    return Q * s[..., None, :], R * s[..., None]


@pytest.mark.parametrize("tiling,m,n,tm", [("tsqr", 40, 4, 12),
                                           ("panel", 33, 5, None)])
def test_float_factors_match_numpy_golden(tiling, m, n, tm):
    rng = np.random.default_rng(5)
    A = rng.standard_normal((m, n))
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas", tiling=tiling,
                              tile_m=tm, panel_n=3))
    Q, R = eng(A)
    Qn, Rn = np.linalg.qr(A)                        # economy reference
    Qg, Rg = _sign_normalize(np.asarray(Q)[:, :n], np.asarray(R)[:n, :])
    Qn, Rn = _sign_normalize(Qn, Rn)
    np.testing.assert_allclose(Rg, Rn, atol=1e-3 * np.abs(Rn).max())
    np.testing.assert_allclose(Qg, Qn, atol=2e-3)
    orth = np.asarray(Q)[:, :n]
    assert np.abs(orth.T @ orth - np.eye(n)).max() < 1e-3


def test_solve_routes_through_tsqr():
    rng = np.random.default_rng(6)
    m, n = 40, 4
    A = rng.standard_normal((m, n))
    b = rng.standard_normal(m)
    eng = QRDEngine(QRDConfig(backend="cordic_pallas", tiling="tsqr",
                              tile_m=12))
    x, resid = eng.solve(A, b, return_residuals=True)
    xr, res, *_ = np.linalg.lstsq(A, b, rcond=None)
    np.testing.assert_allclose(np.asarray(x), xr, atol=1e-4)
    np.testing.assert_allclose(float(resid), np.sqrt(res[0]), rtol=1e-4)


# --------------------------------------------------------------------------
# Acceptance shapes (slow lane): 64x64 panel, 4096x32 tsqr end-to-end
# --------------------------------------------------------------------------
@pytest.mark.slow
def test_engine_64x64_end_to_end():
    rng = np.random.default_rng(7)
    A = rng.standard_normal((64, 64))
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas"))   # auto -> panel
    Q, R = eng(A)
    assert Q.shape == (64, 64) and R.shape == (64, 64)
    assert np.abs(np.asarray(Q) @ np.asarray(R) - A).max() < 2e-3
    assert np.abs(np.asarray(Q).T @ np.asarray(Q) - np.eye(64)).max() < 1e-3


@pytest.mark.slow
def test_engine_4096x32_tsqr_end_to_end():
    rng = np.random.default_rng(8)
    m, n = 4096, 32
    A = rng.standard_normal((m, n))
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas"))   # auto -> tsqr
    Q, R = eng(A)
    assert Q.shape == (m, n) and R.shape == (n, n)
    assert np.abs(np.asarray(Q) @ np.asarray(R) - A).max() < 2e-3
    assert np.abs(np.asarray(Q).T @ np.asarray(Q) - np.eye(n)).max() < 1e-3


# --------------------------------------------------------------------------
# Tiled autotune: persistence, engine linkage, explicit-wins
# --------------------------------------------------------------------------
@pytest.fixture
def cache(tmp_path, monkeypatch):
    path = str(tmp_path / "qrd_autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.clear_memo()
    yield path
    autotune.clear_memo()


def test_tiled_candidates_model():
    assert autotune.candidate_panel_ns(64) == (4, 8, 16)
    assert autotune.candidate_panel_ns(2) == (2,)
    assert autotune.candidate_tile_ms(4096, 32) == (64, 128)
    assert autotune.candidate_tile_ms(4096, 4, max_m=128) == (32, 64, 128)
    assert autotune.candidate_tile_ms(40, 32) != ()    # never empty


def test_tune_tiled_persists_and_lookup_roundtrips(cache):
    calls = []

    def fake_timer(fn, A, reps):
        calls.append(1)
        return float(len(calls))      # first candidate wins

    entry = autotune.tune_tiled("blockfp_pallas", 4096, 32, 1,
                                tiling="tsqr", timer=fake_timer)
    assert entry.tile_m == 64 and entry.panel_n == 4
    assert len(entry.candidates) == len(calls)
    hit = autotune.lookup("blockfp_pallas", "col", 4096, 32, "float64",
                          tiling="tsqr")
    assert hit is not None
    assert (hit.tile_m, hit.panel_n) == (64, 4)
    # the flat key at the same shape is untouched
    assert autotune.lookup("blockfp_pallas", "col", 4096, 32,
                           "float64") is None


def test_tuned_entry_json_backcompat():
    old = {"tile_b": 8, "table_layout": None, "warm_s": 0.1}
    entry = autotune.TuneEntry.from_json(old)
    assert entry.panel_n is None and entry.tile_m is None
    assert "panel_n" not in entry.to_json()


def test_engine_fills_tuned_tiled_knobs(cache):
    autotune.tune_tiled("blockfp_pallas", 40, 4, 1, tiling="tsqr",
                        timer=lambda fn, A, reps: 1.0,
                        tile_ms=(16,), panel_ns=(2,))
    # lookup keys include the dtype: pin it to the tune_tiled default
    eng = QRDEngine(QRDConfig(backend="blockfp_pallas", tiling="tsqr",
                              dtype="float64"))
    resolved = eng._resolve_tuned(eng.config, 40, 4)
    assert (resolved.tile_m, resolved.panel_n) == (16, 2)
    # explicit values always win over the cache
    explicit = QRDConfig(backend="blockfp_pallas", tiling="tsqr",
                         dtype="float64", tile_m=24, panel_n=4)
    resolved = eng._resolve_tuned(explicit, 40, 4)
    assert (resolved.tile_m, resolved.panel_n) == (24, 4)


# --------------------------------------------------------------------------
# Sharding specs for tree levels
# --------------------------------------------------------------------------
class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def test_tsqr_node_spec_shards_node_axis():
    from jax.sharding import PartitionSpec as P

    from repro.launch.sharding import tsqr_node_spec
    mesh = FakeMesh({"data": 16, "model": 16})
    assert tsqr_node_spec(3, 32, mesh) == P(("data",), None, None)
    # node counts that stop dividing replicate (upper tree levels)
    assert tsqr_node_spec(3, 3, mesh) == P(None, None, None)
