"""Complex-valued QRD datapath (DESIGN.md §10).

The contract under test, layer by layer:

* **no silent real-cast** — complex operands on a backend without a
  complex datapath raise ``TypeError`` naming the backend and the
  complex-capable set (historically they were cast to real with only a
  ``ComplexWarning`` and returned wrong answers);
* **bit-parity on purely-real inputs** — the three-rotation
  decomposition skips its phase rotations when the imaginary lanes are
  exact packed zeros, so a real matrix pushed through the complex
  datapath reproduces the real datapath bit for bit (cordic family,
  IEEE and HUB), with exactly-zero imaginary parts;
* **complex correctness** — unitary Q (``Q^H Q = I``), ``Q R = A``,
  upper-triangular R with a real non-negative diagonal;
* **solve golden** — batched complex least squares vs
  ``np.linalg.lstsq`` within `SOLVE_TOLERANCES`, multi-RHS included;
* **complex QRD-RLS** — convergence on complex snapshots (the
  adaptive-beamforming scenario) on the unit and float paths.
"""
import importlib.util
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import qrd as api
from repro.core import GivensConfig, GivensUnit
from repro.core import qrd as cq

RNG = np.random.default_rng(42)


def _complex(rng, shape, scale=1.0):
    return scale * (rng.standard_normal(shape)
                    + 1j * rng.standard_normal(shape))


# ---------------------------------------------------------------------------
# dtype validation: the silent-cast bug is dead
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["blockfp_pallas", "fixed"])
def test_complex_operand_on_noncapable_backend_raises(backend):
    C = _complex(RNG, (2, 4, 4))
    eng = api.QRDEngine(backend=backend)
    with pytest.raises(TypeError) as ei:
        eng(C)
    msg = str(ei.value)
    assert backend in msg                       # names the backend
    assert "cordic" in msg and "jnp" in msg     # names the capable set


@pytest.mark.parametrize("backend", ["blockfp_pallas", "fixed"])
def test_complex_config_on_noncapable_backend_raises_at_construction(backend):
    with pytest.raises(TypeError, match="complex"):
        api.QRDEngine(backend=backend, dtype="complex64")


def test_solve_rejects_complex_rhs_on_noncapable_backend():
    A = RNG.standard_normal((6, 3))
    b = _complex(RNG, (6,))
    with pytest.raises(TypeError, match="complex"):
        api.QRDEngine(backend="fixed").solve(A, b)


def test_non_numeric_operand_raises():
    with pytest.raises(TypeError):
        api.QRDEngine(backend="jnp")(np.array([["a", "b"], ["c", "d"]]))


def test_integer_operand_promotes_exactly():
    A = np.arange(12).reshape(4, 3)
    Q, R = api.QRDEngine(backend="jnp")(A)
    np.testing.assert_allclose(np.asarray(Q) @ np.asarray(R), A, atol=1e-4)


def test_dtype_normalization_and_capability_listing():
    import jax.numpy as jnp
    cfg = api.QRDConfig(backend="cordic", dtype=jnp.complex64)
    assert cfg.dtype == "complex64" and cfg.is_complex()
    caps = api.list_backends()
    capable = {n for n, c in caps.items() if c.supports_complex}
    assert capable == {"jnp", "givens_float", "cordic", "cordic_pallas"}


def test_complex_operand_auto_routes_on_capable_backend():
    C = _complex(RNG, (2, 4, 3))
    eng = api.QRDEngine(backend="cordic")     # real-dtype config
    Q, R = eng(C)
    assert np.asarray(Q).dtype.kind == "c"
    np.testing.assert_allclose(np.asarray(Q) @ np.asarray(R), C, atol=1e-4)


# ---------------------------------------------------------------------------
# bit-parity of the three-rotation decomposition on purely-real inputs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("hub", [False, True])
def test_purely_real_complex_bit_identical_to_real_datapath(hub):
    unit = GivensUnit(GivensConfig(hub=hub, n=26))
    A = RNG.standard_normal((4, 5, 3)) * np.exp2(
        RNG.uniform(-3, 3, (4, 5, 3)))
    Qr, Rr = cq.qr_cordic(A, unit)
    Qc, Rc = cq.qr_cordic_complex(A.astype(np.complex128), unit)
    assert np.array_equal(np.asarray(Qc.real), np.asarray(Qr))
    assert np.array_equal(np.asarray(Rc.real), np.asarray(Rr))
    assert np.all(np.asarray(Qc.imag) == 0.0)
    assert np.all(np.asarray(Rc.imag) == 0.0)


@pytest.mark.slow
@pytest.mark.parametrize("hub", [False, True])
def test_complex_pallas_bit_identical_to_host_loop(hub):
    unit = GivensUnit(GivensConfig(hub=hub, n=26))
    C = _complex(RNG, (3, 4, 4))
    Qh, Rh = cq.qr_cordic_complex(C, unit)
    Qp, Rp = cq.qr_cordic_complex_pallas(C, unit)
    assert np.array_equal(np.asarray(Qp), np.asarray(Qh))
    assert np.array_equal(np.asarray(Rp), np.asarray(Rh))


@pytest.mark.slow
def test_complex_wavefront_bit_identical_to_flattened_stage_order():
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    C = _complex(RNG, (3, 5, 4))
    flat = tuple(s for st in cq.sameh_kuck_schedule(5, 4) for s in st)
    Qf, Rf = cq.qr_cordic_complex(C, unit, steps=flat)
    Qw, Rw = cq.qr_cordic_complex_wavefront(C, unit)
    assert np.array_equal(np.asarray(Qw), np.asarray(Qf))
    assert np.array_equal(np.asarray(Rw), np.asarray(Rf))


# ---------------------------------------------------------------------------
# complex correctness
# ---------------------------------------------------------------------------
@pytest.mark.slow   # unrolled complex host-loop trace per hub mode
@pytest.mark.parametrize("hub", [False, True])
def test_complex_qrd_unitary_reconstruction_real_diagonal(hub):
    eng = api.QRDEngine(backend="cordic", dtype="complex128",
                        givens=GivensConfig(hub=hub, n=26))
    C = _complex(RNG, (3, 5, 4))
    Q, R = eng(C)
    Q, R = np.asarray(Q), np.asarray(R)
    np.testing.assert_allclose(Q @ R, C, atol=2e-5)
    eye = np.broadcast_to(np.eye(5), (3, 5, 5))
    np.testing.assert_allclose(np.swapaxes(Q.conj(), -1, -2) @ Q, eye,
                               atol=2e-5)
    diag = np.diagonal(R, axis1=-2, axis2=-1)
    assert np.all(diag.imag == 0.0)             # phases rotated into Q
    assert np.all(diag.real >= 0.0)
    assert np.all(np.tril(R[..., :4, :], -1) == 0.0)


def test_complex_givens_float_matches_real_path_on_real_input():
    A = RNG.standard_normal((2, 5, 3)).astype(np.float32)
    Qr, Rr = cq.qr_givens_float(A, dtype=np.float32)
    Qc, Rc = cq.qr_givens_float(A, dtype=np.complex64)
    np.testing.assert_allclose(np.asarray(Qc.real), np.asarray(Qr),
                               atol=1e-6)
    assert np.all(np.asarray(Qc.imag) == 0.0)
    np.testing.assert_allclose(np.asarray(Rc.real), np.asarray(Rr),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# solve golden vs np.linalg.lstsq (IEEE + HUB, multi-RHS)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend,kwargs", [
    ("jnp", {}),
    ("givens_float", {}),
    pytest.param("cordic", {"givens": GivensConfig(hub=False, n=26)},
                 marks=pytest.mark.slow),   # unrolled host-loop trace
    pytest.param("cordic", {"givens": GivensConfig(hub=True, n=26)},
                 marks=pytest.mark.slow),
])
def test_complex_solve_matches_lstsq(backend, kwargs):
    rng = np.random.default_rng(3)
    B, m, n, k = 3, 6, 3, 2
    A = _complex(rng, (B, m, n))
    b = _complex(rng, (B, m, k))
    eng = api.QRDEngine(backend=backend, dtype="complex128", **kwargs)
    x, resid = eng.solve(A, b, return_residuals=True)
    x = np.asarray(x)
    assert x.dtype.kind == "c" and x.shape == (B, n, k)
    tol = api.SOLVE_TOLERANCES[f"{backend}:complex"]
    for i in range(B):
        xr, res2, *_ = np.linalg.lstsq(A[i], b[i], rcond=None)
        rel = np.linalg.norm(x[i] - xr) / np.linalg.norm(xr)
        assert rel < tol, (backend, i, rel, tol)
        np.testing.assert_allclose(np.asarray(resid)[i] ** 2, res2,
                                   rtol=1e-3, atol=1e-6)
    # single-RHS vector shape round-trips
    xv = eng.solve(A, b[..., 0])
    assert np.asarray(xv).shape == (B, n)
    np.testing.assert_allclose(np.asarray(xv), x[..., 0], atol=1e-12)


@pytest.mark.slow
def test_complex_solve_on_cordic_pallas_matches_host():
    rng = np.random.default_rng(4)
    A = _complex(rng, (2, 5, 3))
    b = _complex(rng, (2, 5))
    xh = api.QRDEngine(backend="cordic", dtype="complex128").solve(A, b)
    xp = api.QRDEngine(backend="cordic_pallas",
                       dtype="complex128").solve(A, b)
    assert np.array_equal(np.asarray(xh), np.asarray(xp))


def test_back_substitute_complex():
    rng = np.random.default_rng(5)
    n = 5
    R = np.triu(_complex(rng, (n, n))) + 2 * np.eye(n)
    y = _complex(rng, (n,))
    x = np.asarray(api.back_substitute(R, y))
    np.testing.assert_allclose(R @ x, y, atol=1e-10)


# ---------------------------------------------------------------------------
# complex QRD-RLS (the beamforming scenario)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode_kwargs", [
    dict(mode="float"),
    dict(mode="unit", unit=GivensUnit(GivensConfig(hub=True, n=26))),
])
def test_complex_rls_converges(mode_kwargs):
    rng = np.random.default_rng(6)
    n, T = 4, 150
    w_true = _complex(rng, (n,))
    st = api.RLSState(n, lam=0.995, dtype="complex128", **mode_kwargs)
    for _ in range(T):
        x = _complex(rng, (n,))
        st.update(x, w_true @ x + 0.01 * _complex(rng, ()))
    err = np.linalg.norm(st.weights() - w_true)
    assert err < 0.05, (mode_kwargs["mode"], err)
    assert st.weights().dtype.kind == "c"


def test_complex_rls_block_mode_rejected():
    with pytest.raises(TypeError, match="complex"):
        api.RLSState(4, mode="block", dtype="complex128")
    eng = api.QRDEngine(backend="cordic", dtype="complex128")
    with pytest.raises(TypeError, match="complex"):
        eng.rls(4, block=2)


def test_complex_snapshot_on_real_rls_state_rejected():
    """The no-silent-real-cast contract holds on the RLS surface too."""
    st = api.RLSState(4)                    # real float64 state
    with pytest.raises(TypeError, match="real"):
        st.update(_complex(RNG, (4,)), 1.0)
    with pytest.raises(TypeError, match="real"):
        st.update(np.ones(4), np.complex128(1 + 2j))   # complex target too
    with pytest.raises(TypeError, match="real"):
        st.predict(_complex(RNG, (4,)))


def test_complex_beamforming_example():
    """The adaptive-beamforming example on physical complex baseband
    snapshots reaches the same interference-rejection bound as the
    interleaved-real formulation."""
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "adaptive_beamforming.py")
    spec = importlib.util.spec_from_file_location("adaptive_beamforming",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mse = mod.main_complex(use_cordic=True)
    assert mse < 0.05


# ---------------------------------------------------------------------------
# x64 import guard (satellite: no silent global-config clobber)
# ---------------------------------------------------------------------------
def test_import_repro_with_explicit_x64_off_raises():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, JAX_ENABLE_X64="0",
               PYTHONPATH=os.pathsep.join(
                   [src, os.environ.get("PYTHONPATH", "")]))
    proc = subprocess.run(
        [sys.executable, "-c", "import repro"],
        capture_output=True, text=True, env=env)
    assert proc.returncode != 0
    assert "jax_enable_x64" in proc.stderr
