"""Sharding rules + HLO collective parser (no fake devices needed: these
operate on ShapeDtypeStructs and PartitionSpecs, never on arrays)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import applicable_cells, ARCH_IDS, get_config
from repro.launch.hlo import collective_bytes, parse_shape_bytes


class FakeMesh:
    """Duck-typed stand-in: sharding rule code only reads .shape/.axis_names."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _specs(arch, mesh=MESH):
    from repro.launch import sharding as shd
    from repro.launch.steps import _params_struct
    cfg = get_config(arch)
    ps = _params_struct(cfg)
    return ps, shd.param_specs(ps, mesh), cfg


def test_param_specs_core_rules():
    ps, specs, cfg = _specs("qwen3-8b")
    assert specs["embed"] == P("model", ("data",))
    assert specs["lm_head"] == P(("data",), "model")
    assert specs["layers"]["attn"]["wq"] == P(None, ("data",), "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", ("data",))
    assert specs["layers"]["mlp"]["up"] == P(None, ("data",), "model")
    assert specs["layers"]["mlp"]["down"] == P(None, "model", ("data",))
    assert specs["final_norm"]["w"] == P()


def test_param_specs_moe_expert_parallel():
    ps, specs, cfg = _specs("deepseek-v2-236b")
    assert specs["layers"]["moe"]["w_gate"] == P(None, "model", ("data",), None)
    assert specs["layers"]["moe"]["w_down"] == P(None, "model", None, ("data",))
    assert specs["layers"]["moe"]["router"] == P(None, ("data",), None)


def test_param_specs_uneven_vocab_drops_axis():
    ps, specs, cfg = _specs("mamba2-780m")   # vocab 50280 % 16 != 0
    assert specs["embed"] == P(None, ("data",))
    assert specs["lm_head"] == P(("data",), None)


def test_param_specs_multipod_fsdp_axes():
    ps, specs, cfg = _specs("command-r-35b", MESH3)
    assert specs["layers"]["attn"]["wq"] == P(None, ("pod", "data"), "model")


def test_every_arch_has_sharded_big_params():
    """No multi-GB parameter may end up fully replicated."""
    for arch in ARCH_IDS:
        ps, specs, cfg = _specs(arch)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(ps)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]):
            size = np.prod(leaf.shape) * leaf.dtype.itemsize
            if size > 256 * 2 ** 20:  # 256 MB
                assert spec != P(), (arch, path, leaf.shape)


def test_applicable_cells_rules():
    cells = applicable_cells()
    assert ("mamba2-780m", "long_500k") in cells
    assert ("recurrentgemma-2b", "long_500k") in cells
    assert ("command-r-35b", "long_500k") not in cells      # full attention
    assert ("qwen3-8b", "long_500k") not in cells
    assert len(cells) == 32
    # every arch has the three universal cells
    for a in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert (a, s) in cells


# ------------------------------------------------------------------ hlo.py
def test_parse_shape_bytes():
    assert parse_shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert parse_shape_bytes("(f32[4,4], s8[16])") == 64 + 16
    assert parse_shape_bytes("f32[]") == 4  # scalar


def test_collective_bytes_parser():
    hlo = """
  %ar = f32[256,4096]{1,0} all-reduce(%x), replica_groups=[16,16]<=[16,16]T(1,0), to_apply=%sum
  %ag = bf16[1024]{0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %deg = f32[64]{0} all-reduce(%w), replica_groups={{0}}, to_apply=%sum
  %use = f32[8]{0} add(%all-reduce.5, %cp)
"""
    out = collective_bytes(hlo)
    ar = 256 * 4096 * 4
    assert out["all-reduce"] == pytest.approx(2 * ar * 15 / 16)
    assert out["all-gather"] == pytest.approx(1024 * 2 * 3 / 4)
    # degenerate single-member groups are dropped; permutes lack groups
    assert out["ops"]["all-reduce"] == 1
    assert out["total"] > 0
