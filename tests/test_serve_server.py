"""FleetServer semantics: cohorts, the bounded async queue, checkpoint.

The server's contracts: cohorts are contiguous and recycle their ranges;
the batcher never puts two snapshots for one slot in the same scatter
(FIFO per slot) and never applies a request to a recycled slot (stale
generation); the bounded queue enforces its overflow policy with
per-cohort accounting; checkpoint → evict → restore resumes serving with
bit-identical weights and an intact cohort table.
"""
import numpy as np
import pytest

import repro  # noqa: F401  (x64 guard)
from repro.qrd import QRDConfig, QRDEngine
from repro.qrd.rls import RLSState
from repro.serve import (FleetServer, RLSFleet, fleet_preset,
                         list_fleet_presets)

RNG = np.random.default_rng(11)


def _server(slots=32, n=3, batch=4, **kw):
    return FleetServer(RLSFleet(slots, n, mode="float"), batch=batch,
                       queue_limit=kw.pop("queue_limit", 64), **kw)


def test_cohorts_are_contiguous_and_ranges_recycle():
    srv = _server(slots=16)
    a = srv.admit_cohort("a", 6)
    b = srv.admit_cohort("b", 6)
    assert (a.start, a.stop, b.start, b.stop) == (0, 6, 6, 12)
    srv.evict_cohort("a")
    c = srv.admit_cohort("c", 4)        # first-fit into a's freed range
    assert (c.start, c.stop) == (0, 4)
    with pytest.raises(RuntimeError, match="contiguous"):
        srv.admit_cohort("huge", 9)     # 2 + 4 free, but not contiguous
    with pytest.raises(ValueError, match="already admitted"):
        srv.admit_cohort("b", 1)
    with pytest.raises(KeyError, match="unknown cohort"):
        srv.submit("ghost", 0, np.zeros(3), 0.0)


def test_queue_overflow_policies_and_accounting():
    srv = _server(batch=2, queue_limit=2, overflow="drop")
    srv.admit_cohort("c", 4)
    assert srv.submit("c", 0, np.zeros(3), 1.0)
    assert srv.submit("c", 1, np.zeros(3), 1.0)
    assert not srv.submit("c", 2, np.zeros(3), 1.0)   # full -> dropped
    stats = srv.health()["cohorts"]["c"]
    assert stats["dropped_overflow"] == 1 and stats["backlog"] == 2
    assert srv.pump() == 2
    assert srv.health()["cohorts"]["c"]["backlog"] == 0

    strict = _server(batch=2, queue_limit=2, overflow="raise")
    strict.admit_cohort("c", 4)
    strict.submit("c", 0, np.zeros(3), 1.0)
    strict.submit("c", 1, np.zeros(3), 1.0)
    with pytest.raises(RuntimeError, match="queue full"):
        strict.submit("c", 2, np.zeros(3), 1.0)
    # a refused submit is not counted as submitted traffic
    assert strict.health()["cohorts"]["c"]["submitted"] == 2


def test_duplicate_slot_snapshots_apply_in_fifo_order():
    """5 snapshots for ONE slot arrive in one pump: the batcher must
    serialize them across batches, reproducing the single-state stream."""
    srv = _server(slots=8, n=4, batch=4)
    srv.admit_cohort("c", 2)
    ref = RLSState(4, lam=0.99, mode="float")
    for _ in range(5):
        x, d = RNG.normal(size=4), RNG.normal()
        srv.submit("c", 0, x, d)
        ref.update(x, d)
    assert srv.pump() == 5
    assert srv.step == 5        # one live snapshot per batch here
    np.testing.assert_allclose(srv.query("c", [0])[0], ref.weights(),
                               rtol=1e-12, atol=1e-13)


def test_stale_generation_requests_are_dropped():
    srv = _server(slots=8)
    srv.admit_cohort("a", 4)
    srv.submit("a", 0, np.ones(3), 1.0)
    srv.evict_cohort("a")                   # queued request now stale
    b = srv.admit_cohort("b", 4)            # recycles the same slots
    assert (b.start, b.stop) == (0, 4)
    before = np.asarray(srv.fleet.state.work).copy()
    assert srv.pump() == 0                  # nothing may touch slot 0
    np.testing.assert_array_equal(np.asarray(srv.fleet.state.work), before)


def test_checkpoint_evict_restore_resumes_bit_identically(tmp_path):
    srv = _server(slots=16, n=4, batch=4, ckpt_dir=str(tmp_path))
    srv.admit_cohort("a", 8)
    srv.admit_cohort("b", 4)
    for step in range(6):
        srv.submit_batch("a", np.arange(4), RNG.normal(size=(4, 4)),
                         RNG.normal(size=4))
        srv.pump()
    srv.checkpoint(wait=True)
    w_served = srv.query("a")
    step_at = srv.step
    # keep serving past the checkpoint, then lose the cohort entirely
    srv.submit_batch("a", np.arange(4), RNG.normal(size=(4, 4)),
                     RNG.normal(size=4))
    srv.pump()
    srv.evict_cohort("a")
    assert srv.restore_latest() == step_at
    # cohort table AND weights come back exactly as checkpointed
    assert sorted(c.name for c in srv.cohorts()) == ["a", "b"]
    np.testing.assert_array_equal(srv.query("a"), w_served)
    stats = srv.health()["cohorts"]["a"]
    assert stats["backlog"] == 0 and stats["processed"] == stats["submitted"]


def test_health_reports_dead_cohorts_via_monitor():
    srv = _server(beat_timeout=10.0)
    srv.admit_cohort("live", 4)
    srv.admit_cohort("quiet", 4)
    srv.monitor.record_heartbeat(srv._cohorts["live"].cid, 0, now=100.0)
    srv.monitor.record_heartbeat(srv._cohorts["quiet"].cid, 0, now=50.0)
    health = srv.health(now=100.0)
    assert health["dead_cohorts"] == ["quiet"]
    assert health["occupancy"] == 8 and health["queue_depth"] == 0


def test_server_rejects_block_mode_fleets():
    with pytest.raises(ValueError, match="block"):
        FleetServer(RLSFleet(4, 3, mode="block"))


def test_presets_resolve_and_config_json_roundtrips():
    presets = list_fleet_presets()
    assert {"equalizer-ieee", "equalizer-hub", "beamformer-complex",
            "equalizer-float64"} <= set(presets)
    for name in presets:
        spec = fleet_preset(name, slots=8)
        cfg = spec["config"]
        assert QRDConfig.from_json(cfg.to_json()) == cfg
        assert spec["fleet"]["slots"] == 8          # override applied
        assert "batch" in spec["server"]
    with pytest.raises(KeyError, match="unknown fleet preset"):
        fleet_preset("nope")
    # from_dict is strict about unknown fields
    with pytest.raises(ValueError, match="unknown QRDConfig field"):
        QRDConfig.from_dict({"backend": "jnp", "warp_speed": 9})


def test_engine_fleet_factory_routes_like_rls():
    eng = QRDEngine(backend="cordic", dtype="complex128")
    fleet = eng.fleet(8, 3)
    assert fleet.mode == "unit" and fleet.is_complex
    assert QRDEngine(backend="jnp").fleet(8, 3).mode == "float"
    assert QRDEngine(backend="jnp").fleet(8, 3, block=2).mode == "block"
    with pytest.raises(TypeError, match="complex"):
        eng.fleet(8, 3, block=2)
    with pytest.raises(ValueError, match="forgetting"):
        QRDEngine(backend="jnp").fleet(8, 3, lam=1.5)
