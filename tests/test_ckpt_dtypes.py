"""Checkpoint dtype integrity: exact round-trips, no silent casts.

Fleet serving state mixes complex128 `[R | z]` work arrays, packed-int64
Givens words, occupancy bools and int32 counters in one pytree; the
checkpoint layer must restore every leaf with its exact dtype and bit
pattern, and refuse a template whose dtype disagrees with what was saved
(the pre-ISSUE-8 behavior was a silent ``asarray(..., dtype=template)``
cast — imaginary parts dropped, packed words destroyed).

Separate from test_substrate.py so these run without the `hypothesis`
dev extra, plus `SyntheticTraffic` determinism (same reason).
"""
import numpy as np
import jax.numpy as jnp
import pytest

import repro  # noqa: F401  (x64 guard)
from repro.checkpoint import restore_pytree, save_pytree
from repro.data.pipeline import SyntheticTraffic


def test_checkpoint_dtype_tags_roundtrip_exactly(tmp_path):
    """complex64/128 and packed-int64 leaves restore with their exact
    dtype and bit patterns (the fleet-state checkpointing contract)."""
    d = str(tmp_path / "ckpt")
    tree = {
        "work_c128": jnp.asarray(np.arange(6).reshape(2, 3)
                                 + 1j * np.arange(6).reshape(2, 3),
                                 jnp.complex128),
        "snap_c64": jnp.asarray([1 + 2j, 3 - 4j], jnp.complex64),
        # packed Givens words: sign bit set, full 64-bit patterns
        "packed": jnp.asarray(np.array([-(2 ** 62), 2 ** 62 + 1, -1]),
                              jnp.int64),
        "f32": jnp.ones((2,), jnp.float32),
    }
    save_pytree(d, 1, tree)
    out, _ = restore_pytree(d, 1, tree)
    for key, leaf in tree.items():
        assert out[key].dtype == leaf.dtype, key
        np.testing.assert_array_equal(np.asarray(out[key]), np.asarray(leaf))


def test_checkpoint_packed_words_survive_via_unit_encode(tmp_path):
    """Bit-accuracy end to end: words packed by the real GivensUnit come
    back identical, so a packed-domain checkpoint is exactly resumable."""
    from repro.core import GivensConfig, GivensUnit

    unit = GivensUnit(GivensConfig(hub=True))
    words = unit.encode(jnp.asarray(np.random.default_rng(5)
                                    .normal(size=(3, 4))))
    assert words.dtype == jnp.int64
    d = str(tmp_path / "ckpt")
    save_pytree(d, 7, {"P": words})
    out, _ = restore_pytree(d, 7, {"P": words})
    np.testing.assert_array_equal(np.asarray(out["P"]), np.asarray(words))


def test_checkpoint_refuses_silent_dtype_change(tmp_path):
    """A dtype mismatch between checkpoint and template raises instead of
    silently casting (complex -> real would drop the imaginary parts;
    packed int64 -> float would destroy the bit patterns)."""
    d = str(tmp_path / "ckpt")
    save_pytree(d, 1, {"w": jnp.asarray([1 + 1j], jnp.complex128)})
    with pytest.raises(TypeError, match="refusing to silently convert"):
        restore_pytree(d, 1, {"w": jnp.zeros(1, jnp.float64)})
    save_pytree(d, 2, {"w": jnp.asarray([7], jnp.int64)})
    with pytest.raises(TypeError, match="saved as int64"):
        restore_pytree(d, 2, {"w": jnp.zeros(1, jnp.float32)})
    # matching template still restores (exact dtype, not a cast)
    tree, _ = restore_pytree(d, 2, {"w": jnp.zeros(1, jnp.int64)})
    assert tree["w"].dtype == jnp.int64 and int(tree["w"][0]) == 7


def test_traffic_deterministic_and_observes_hidden_channels():
    tr = SyntheticTraffic(users=32, n=4, per_step=16, seed=9, snr_db=200.0)
    a, b = tr.batch(3), tr.batch(3)
    np.testing.assert_array_equal(np.asarray(a["user"]), np.asarray(b["user"]))
    np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
    # at 200 dB SNR the desired response is the clean channel output
    w = np.stack([np.asarray(tr.channel(int(u))) for u in a["user"]])
    np.testing.assert_allclose(np.asarray(a["d"]),
                               np.einsum("bn,bn->b", np.asarray(a["x"]), w),
                               rtol=1e-8)
    # complex traffic is complex end to end
    trc = SyntheticTraffic(users=8, n=3, per_step=4, complex_dtype=True)
    assert np.asarray(trc.batch(0)["d"]).dtype.kind == "c"
