# expect-finding: unguarded-scatter
# Minimized PR-6 reproduction: scatter over a caller-supplied slot-id
# array.  Padded batches share a sentinel id, so duplicates are real and
# the update order is unspecified.
import jax.numpy as jnp


def write_rows(buf, slot_ids, rows):
    return buf.at[slot_ids].set(rows, mode="drop")


def bump(counts, slot_ids):
    return counts.at[slot_ids].add(1)
