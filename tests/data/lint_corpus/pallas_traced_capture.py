# expect-finding: pallas-traced-capture
# Minimized PR-5 reproduction: the gain-compensation constant was built
# with jnp inside the kernel builder, so the pallas_call kernel closure
# captured a committed jax array.  Mosaic rejects captured array
# constants; interpret mode silently hides the bug.
import jax.numpy as jnp
from jax.experimental import pallas as pl


def build_rotation_kernel(cfg):
    # BUG: traced/committed array constant captured by the closure.
    # The fix is np.int64(...) — computed on host, embedded as a scalar.
    comp = jnp.int64(2) ** cfg.p

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * comp

    def run(x):
        return pl.pallas_call(kernel, out_shape=x)(x)

    return run
