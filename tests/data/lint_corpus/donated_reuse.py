# expect-finding: donated-reuse
# Reading a buffer after passing it at a donated position: the step's
# donate_argnums=(0,) invalidates `state` at the call.
import jax


def make_driver(step_fn):
    step = jax.jit(step_fn, donate_argnums=(0,))

    def drive(state, xs):
        new_state = step(state, xs)
        return state.sum() + new_state.sum()   # `state` is gone

    return drive
