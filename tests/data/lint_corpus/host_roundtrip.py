# expect-finding: host-roundtrip
# float()/.item()/np.* on a tracer inside a jitted body: concretization
# error at trace time at best, a silent host sync at worst.
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def step(x, w):
    scale = float(jnp.sum(x))          # concretizes the tracer
    return x * scale + w


@jax.jit
def norm(x):
    m = jnp.max(jnp.abs(x))
    return x / m.item()                # host round-trip


@jax.jit
def mix(x):
    return np.sqrt(x) + 1.0            # numpy on a tracer
