# expect-finding: narrowing-cast
# Minimized PR-4 reproduction: complex MIMO operands silently cast to a
# real dtype (ComplexWarning at best), outside the blessed encode/decode
# boundary modules.
import jax.numpy as jnp


def snapshot(X, d):
    snap = jnp.concatenate([X, d[:, None]], axis=1)
    return snap.astype(jnp.float64)    # drops Im(X) without a word


def downcast(acc):
    return jnp.asarray(acc, jnp.float32)
