# expect-finding: none
# The fixed counterparts of every seeded bug — must lint clean.
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def build_rotation_kernel(cfg):
    comp = np.int64(2) ** cfg.p        # host scalar: the PR-5 fix

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * comp

    def run(x):
        return pl.pallas_call(kernel, out_shape=x)(x)

    return run


@jax.jit
def step(x, w):
    scale = jnp.sum(x)                 # stays on device
    return x * scale + w


def write_rows(buf, slot_ids, rows):
    # uniqueness established by the caller; assert it to XLA
    return buf.at[slot_ids].set(rows, unique_indices=True)


def solve_rows(R, y):
    n = R.shape[-1]
    x = jnp.zeros_like(y)
    for row in range(n):               # python scalar index: no scatter risk
        x = x.at[row].set(y[row] / R[row, row])
    return x


def make_driver(step_fn):
    donating = jax.jit(step_fn, donate_argnums=(0,))

    def drive(state, xs):
        state = donating(state, xs)    # rebound: old buffer never reread
        return state.sum()

    return drive


@functools.partial(jax.jit, static_argnums=(1,))
def reshape(x, shape):
    return x.reshape(shape)


def call(x):
    return reshape(x, (4, 4))          # tuple: hashable static
