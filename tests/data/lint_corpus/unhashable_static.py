# expect-finding: unhashable-static
# List literal passed for a static jit parameter: static args are cache
# keys and must be hashable — this raises at call time.
import jax


def reshape(x, shape):
    return x.reshape(shape)


reshape_j = jax.jit(reshape, static_argnums=(1,))


def call(x):
    return reshape_j(x, [4, 4])
