"""Hazard linter tests: seeded-bug corpus, allowlist policy, dead-code.

The corpus under tests/data/lint_corpus/ holds one minimized fixture per
rule, each a faithful reduction of a bug this repo actually shipped
(PR 4 complex casts, PR 5 pallas closure capture, PR 6 scatter hazard).
Every fixture must be flagged by exactly its declared rule, and the
fixed counterparts in clean.py must stay silent — both directions guard
the rules against rot.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.allowlist import (AllowlistError, load_allowlist,
                                      parse_allowlist)
from repro.analysis.deadcode import find_dead_modules
from repro.analysis.lint import RULES, lint_paths, lint_source

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
CORPUS = os.path.join(REPO, "tests", "data", "lint_corpus")


def _expected_rules(path):
    with open(path, "r", encoding="utf-8") as fh:
        first = fh.readline()
    assert first.startswith("# expect-finding:"), path
    spec = first.split(":", 1)[1].strip()
    return set() if spec == "none" else {r.strip() for r in spec.split(",")}


# -- seeded-bug corpus --------------------------------------------------------

def _corpus_files():
    return sorted(f for f in os.listdir(CORPUS) if f.endswith(".py"))


def test_corpus_covers_every_lint_rule():
    covered = set()
    for name in _corpus_files():
        covered |= _expected_rules(os.path.join(CORPUS, name))
    # dead-module is exercised via a synthetic tree below, not a fixture
    assert covered == set(RULES) - {"dead-module"}


@pytest.mark.parametrize("name", _corpus_files())
def test_corpus_fixture_flagged_by_its_rule(name):
    path = os.path.join(CORPUS, name)
    expected = _expected_rules(path)
    with open(path, "r", encoding="utf-8") as fh:
        findings = lint_source(fh.read(), f"tests/data/lint_corpus/{name}")
    got = {f.rule for f in findings}
    if not expected:            # clean.py: the fixed patterns stay silent
        assert got == set(), [f.render() for f in findings]
    else:
        assert expected <= got, (
            f"{name}: expected {expected}, linter found {got or 'nothing'}")
        assert got <= expected, (
            f"{name}: unexpected extra findings "
            f"{[f.render() for f in findings if f.rule not in expected]}")


def test_pr5_traced_capture_reintroduction_fails_lint():
    """Reintroducing the PR-5 bug — computing the gain-compensation
    constant with jnp inside the kernel builder — must be caught."""
    src = textwrap.dedent("""
        import jax.numpy as jnp
        import numpy as np
        from jax.experimental import pallas as pl
        from repro.core import cordic

        def make_rotation(cfg, iters):
            p = min(78 - (cfg.n + 2), 46)
            comp = jnp.round(2.0 ** p / cordic.GAIN_TABLE[iters]
                             ).astype(jnp.int64)

            def kernel(x_ref, y_ref, o_ref):
                o_ref[...] = x_ref[...] * comp + y_ref[...]

            def apply(x, y):
                return pl.pallas_call(kernel, out_shape=x)(x, y)
            return apply
    """)
    findings = lint_source(src, "src/repro/kernels/cordic_givens.py")
    assert any(f.rule == "pallas-traced-capture"
               and "comp" in f.detail for f in findings), \
        [f.render() for f in findings]
    # and the PR-5 fix (numpy constant) passes
    fixed = src.replace("jnp.round", "np.round").replace(
        ".astype(jnp.int64)", ".astype(np.int64)")
    fixed_findings = lint_source(fixed, "src/repro/kernels/cordic_givens.py")
    assert not any(f.rule == "pallas-traced-capture"
                   for f in fixed_findings), \
        [f.render() for f in fixed_findings]


def test_pr4_complex_narrowing_reintroduction_fails_lint():
    src = textwrap.dedent("""
        import jax.numpy as jnp

        def snapshot(X, d, work_dtype):
            snap = jnp.concatenate([X, d[:, None]], axis=1)
            return snap.astype(jnp.float64)
    """)
    findings = lint_source(src, "src/repro/serve/fleet.py")
    assert any(f.rule == "narrowing-cast" for f in findings)


def test_inline_waiver_requires_justification():
    base = "import jax.numpy as jnp\n\ndef f(x):\n"
    waived = base + ("    # lint: allow[narrowing-cast] validated upstream\n"
                     "    return x.astype(jnp.float32)\n")
    bare = base + ("    # lint: allow[narrowing-cast]\n"
                   "    return x.astype(jnp.float32)\n")
    f1 = [f for f in lint_source(waived, "m.py")
          if f.rule == "narrowing-cast"]
    f2 = [f for f in lint_source(bare, "m.py")
          if f.rule == "narrowing-cast"]
    assert f1 and f1[0].waived            # justified marker waives
    assert f2 and not f2[0].waived        # bare marker does not


# -- repo sweep ---------------------------------------------------------------

def test_repo_sweep_has_no_unwaived_findings():
    """The CI contract: every finding in src/ is either fixed or in the
    checked-in allowlist with a justification."""
    findings = lint_paths(["src"], REPO)
    findings += find_dead_modules(REPO)
    allow = load_allowlist()
    active, waived, stale = allow.split(findings)
    assert active == [], [f.render() for f in active]
    assert stale == [], [e.pattern for e in stale]


def test_cli_exits_zero_on_clean_tree():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "--no-bitflow"],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_fails_on_seeded_bug():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis",
         "tests/data/lint_corpus/unguarded_scatter.py",
         "--no-bitflow", "--no-deadcode", "--allow-stale"],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "unguarded-scatter" in proc.stdout


# -- allowlist policy ---------------------------------------------------------

def test_allowlist_entry_requires_justification():
    with pytest.raises(AllowlistError):
        parse_allowlist("narrowing-cast:src/a.py:f:astype:jnp.float32\n")
    with pytest.raises(AllowlistError):
        parse_allowlist("narrowing-cast:src/a.py:f:astype:jnp.float32  #\n")


def test_allowlist_brackets_are_literal():
    al = parse_allowlist(
        "unguarded-scatter:src/m.py:f:at[slot_ids].set  # server dedup\n")
    findings = lint_source(
        "def f(buf, slot_ids, rows):\n"
        "    return buf.at[slot_ids].set(rows)\n", "src/m.py")
    active, waived, stale = al.split(findings)
    assert active == [] and len(waived) == 1 and stale == []


def test_allowlist_glob_and_stale_detection():
    al = parse_allowlist("narrowing-cast:src/m.py:*  # whole module waived\n"
                         "narrowing-cast:src/other.py:g:*  # never matches\n")
    findings = lint_source(
        "import jax.numpy as jnp\n"
        "def f(x):\n    return x.astype(jnp.float32)\n", "src/m.py")
    active, waived, stale = al.split(findings)
    assert active == []
    assert len(waived) == 1
    assert [e.lineno for e in stale] == [2]


def test_checked_in_allowlist_parses():
    al = load_allowlist()
    assert al.entries, "checked-in allowlist should not be empty"
    for e in al.entries:
        assert e.justification


# -- dead-code over a synthetic tree -----------------------------------------

def _mini_repo(tmp_path, extra=None):
    src = tmp_path / "src" / "repro"
    (src / "configs").mkdir(parents=True)
    (src / "__init__.py").write_text("from . import used\n")
    (src / "used.py").write_text("X = 1\n")
    (src / "orphan.py").write_text("Y = 2\n")
    (src / "configs" / "__init__.py").write_text(
        'import importlib\n'
        'def load(m):\n'
        '    return importlib.import_module(f"repro.configs.{m}")\n')
    (src / "configs" / "tiny.py").write_text("CFG = {}\n")
    for rel, body in (extra or {}).items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(body)
    return tmp_path


def test_dead_module_detected(tmp_path):
    root = _mini_repo(tmp_path)
    dead = {f.detail for f in find_dead_modules(str(root))}
    assert dead == {"repro.orphan"}


def test_dynamic_fstring_import_keeps_package_alive(tmp_path):
    root = _mini_repo(tmp_path)
    dead = {f.detail for f in find_dead_modules(str(root))}
    assert "repro.configs.tiny" not in dead


def test_ci_entry_point_keeps_module_alive(tmp_path):
    root = _mini_repo(tmp_path, extra={
        ".github/workflows/ci.yml":
            "run: python -m repro.orphan --check\n"})
    dead = {f.detail for f in find_dead_modules(str(root))}
    assert "repro.orphan" not in dead


def test_own_docstring_does_not_keep_module_alive(tmp_path):
    root = _mini_repo(tmp_path)
    (root / "src" / "repro" / "orphan.py").write_text(
        '"""Usage: python -m repro.orphan"""\nY = 2\n')
    dead = {f.detail for f in find_dead_modules(str(root))}
    assert "repro.orphan" in dead
