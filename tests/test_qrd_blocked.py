"""Kernel-resident blocked QR vs the reference loop: bit-exact parity.

The contract under test (DESIGN.md §5): moving the whole triangularization
inside one Pallas kernel changes *where* the arithmetic runs, never *what*
it computes — `'cordic_pallas'` must match `qr_cordic` bit for bit on IEEE
and HUB configs, for every schedule, on shapes that stress the batch-tile
padding.  The int32 block-fixed-point fast path is held to accuracy (not
bit) parity, and the fused single-pass row kernel is checked against the
separate vectoring/rotation kernels on odd shapes.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (GivensConfig, GivensUnit, QRDEngine, givens_schedule,
                        qr_blockfp_pallas, qr_cordic, qr_cordic_pallas,
                        sameh_kuck_schedule, snr_db)
from repro.kernels import ops

# Interpret-mode kernel compiles dominate this module's runtime
# (tens of seconds per pallas_call trace): full lane only.
pytestmark = pytest.mark.slow

RNG = np.random.default_rng(7)


def matrices(shape, r=4.0):
    mag = np.exp2(RNG.uniform(-r, r, size=shape))
    return RNG.choice([-1.0, 1.0], size=shape) * mag


def _assert_bit_exact(a, b):
    for u, v in zip(a, b):
        if u is None:
            assert v is None
            continue
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


# shapes stress the TILE_B padding (odd batches) and non-square matrices
@pytest.mark.parametrize("shape", [(5, 4, 4), (3, 6, 3), (2, 3, 5)])
@pytest.mark.parametrize("hub", [False, True])
def test_cordic_pallas_bit_exact(shape, hub):
    A = matrices(shape)
    unit = GivensUnit(GivensConfig(hub=hub, n=26))
    _assert_bit_exact(qr_cordic(A, unit), qr_cordic_pallas(A, unit))


def test_cordic_pallas_bit_exact_no_q():
    A = matrices((5, 4, 4))
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    _assert_bit_exact(qr_cordic(A, unit, compute_q=False),
                      qr_cordic_pallas(A, unit, compute_q=False))


def test_sameh_kuck_stages_disjoint_and_complete():
    for (m, n) in [(4, 4), (6, 3), (8, 8), (3, 5)]:
        stages = sameh_kuck_schedule(m, n)
        flat = [s for st in stages for s in st]
        # same rotation set as the column-major schedule
        assert {(j, c) for (_, j, c) in flat} == \
               {(j, c) for (_, j, c) in givens_schedule(m, n)}
        for stage in stages:  # within a stage all row pairs are disjoint
            rows = [r for (k, j, _) in stage for r in (k, j)]
            assert len(rows) == len(set(rows))
        # adjacent-row pairing: pivot is always target - 1
        assert all(k == j - 1 for (k, j, _) in flat)


def test_sameh_kuck_schedule_bit_exact_and_accurate():
    m, n = 6, 4
    A = matrices((3, m, n))
    sk = tuple(s for stage in sameh_kuck_schedule(m, n) for s in stage)
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    ref = qr_cordic(A, unit, steps=sk)
    got = qr_cordic_pallas(A, unit, steps=sk)
    _assert_bit_exact(ref, got)
    assert float(jnp.mean(snr_db(A, *got))) > 115.0


def test_engine_backend_parity_and_schedule():
    A = matrices((4, 4, 4))
    cfg = GivensConfig(hub=True, n=26)
    ref = QRDEngine(backend="cordic", givens_config=cfg)(A)
    for sched in ("col", "sameh_kuck"):
        got = QRDEngine(backend="cordic_pallas", givens_config=cfg,
                        schedule=sched)(A)
        if sched == "col":
            _assert_bit_exact(ref, got)
        B = np.asarray(got[0]) @ np.asarray(got[1])
        assert np.allclose(B, A, rtol=1e-4, atol=1e-4)


def test_blockfp_accuracy_and_orthogonality():
    A = matrices((8, 4, 4))
    Q, R = qr_blockfp_pallas(A)
    assert float(jnp.mean(snr_db(A, Q, R))) > 90.0
    QtQ = np.swapaxes(np.asarray(Q), -1, -2) @ np.asarray(Q)
    assert np.max(np.abs(QtQ - np.eye(4))) < 1e-4
    assert np.all(np.tril(np.asarray(R), -1) == 0.0)


def test_blockfp_custom_steps_rls_block_update():
    """RLS block update: annihilate B stacked snapshot rows into R."""
    n, B = 5, 3
    R0 = np.triu(RNG.normal(size=(n, n))) + np.eye(n) * 3
    X = RNG.normal(size=(B, n))
    W = np.concatenate([R0, X], axis=0)[None]          # (1, n+B, n)
    steps = tuple((k, j, k) for k in range(n) for j in range(n, n + B))
    got = np.asarray(ops.givens_block_apply(W, steps, hub=True))[0]
    # float Givens reference on the same schedule
    ref = W[0].copy()
    for (k, j, col) in steps:
        a, b = ref[k, col], ref[j, col]
        r = np.hypot(a, b)
        c, s = (a / r, b / r) if r > 0 else (1.0, 0.0)
        rk = c * ref[k] + s * ref[j]
        rj = -s * ref[k] + c * ref[j]
        ref[k], ref[j] = rk, rj
    np.testing.assert_allclose(got[:n], ref[:n], atol=2e-5)
    assert np.max(np.abs(got[n:])) < 2e-5  # snapshot rows fully annihilated


@pytest.mark.parametrize("B,L", [(1, 3), (9, 129), (17, 64)])
@pytest.mark.parametrize("hub", [False, True])
def test_fused_vs_separate_kernels_odd_shapes(B, L, hub):
    """Fused single-pass kernel == separate vectoring+rotation kernels."""
    v = RNG.uniform(-1.9, 1.9, size=(2, B, L))
    X = np.rint(v * 2.0 ** 24).astype(np.int32)
    x, y = jnp.asarray(X[0]), jnp.asarray(X[1])
    a = ops.givens_rotate_rows_fixed(x, y, iters=24, hub=hub)
    b = ops.givens_rotate_rows_fused(x, y, iters=24, hub=hub)
    _assert_bit_exact(a, b)


def test_sharded_tall_skinny_batch():
    from repro.core import qr_blocked_sharded
    from repro.launch.sharding import qrd_batch_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = qrd_batch_spec(3, 6, mesh)
    assert spec[0] == ("data",) and spec[1:] == (None, None)
    A = matrices((6, 8, 3), r=2.0)                     # tall-skinny batch
    unit = GivensUnit(GivensConfig(hub=True, n=26))
    _assert_bit_exact(qr_cordic(A, unit), qr_blocked_sharded(A, unit, mesh))
