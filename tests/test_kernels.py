"""Pallas CORDIC kernels vs the pure-jnp oracle: exact-equality sweeps."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import cordic as core_cordic
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def fix_rows(B, L, F=24):
    v = RNG.uniform(-1.9, 1.9, size=(2, B, L))
    return (np.rint(v * 2.0 ** F).astype(np.int32), v)


@pytest.mark.parametrize("B", [1, 7, 8, 33])
@pytest.mark.parametrize("L", [1, 5, 128, 300])
@pytest.mark.parametrize("hub", [False, True])
def test_rotate_rows_kernel_matches_ref(B, L, hub):
    (X, _) = fix_rows(B, L + 1)
    x, y = jnp.asarray(X[0]), jnp.asarray(X[1])
    xr, yr = ops.givens_rotate_rows_fixed(x, y, iters=24, hub=hub)
    xl, yl, fl, sg = ref.vectoring_ref(x[:, 0], y[:, 0], iters=24, hub=hub)
    xo, yo = ref.rotation_ref(x[:, 1:], y[:, 1:], fl[:, None], sg[:, None],
                              iters=24, hub=hub)
    ex = np.concatenate([np.asarray(xl)[:, None], np.asarray(xo)], axis=1)
    ey = np.concatenate([np.asarray(yl)[:, None], np.asarray(yo)], axis=1)
    np.testing.assert_array_equal(np.asarray(xr), ex)
    np.testing.assert_array_equal(np.asarray(yr), ey)


@pytest.mark.parametrize("iters", [8, 16, 24, 28])
@pytest.mark.parametrize("hub", [False, True])
def test_vectoring_kernel_matches_ref_iters_sweep(iters, hub):
    (X, _) = fix_rows(64, 1)
    x, y = jnp.asarray(X[0, :, 0]), jnp.asarray(X[1, :, 0])
    xr, yr, fl, sg = ops.vectoring_fixed(x, y, iters=iters, hub=hub)
    ex, ey, efl, esg = ref.vectoring_ref(x, y, iters=iters, hub=hub)
    for got, exp in ((xr, ex), (yr, ey), (fl, efl), (sg, esg)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("hub", [False, True])
def test_kernel_vs_int64_core_within_gain_rounding(hub):
    """int32 kernel (Q30 gain) vs int64 core (Q46 gain): <= 2 LSB apart."""
    (X, _) = fix_rows(32, 8)
    x, y = jnp.asarray(X[0]), jnp.asarray(X[1])
    it = jnp.asarray(24, jnp.int64)
    w = jnp.asarray(28, jnp.int64)
    xr32, yr32 = ops.givens_rotate_rows_fixed(x, y, iters=24, hub=hub)
    xl, yl, fl, sg = core_cordic.vectoring(
        x[:, 0].astype(jnp.int64), y[:, 0].astype(jnp.int64), it, hub)
    xo, yo = core_cordic.rotation(
        x[:, 1:].astype(jnp.int64), y[:, 1:].astype(jnp.int64),
        fl[:, None], sg[:, None], it, hub)
    xl, yl = core_cordic.apply_gain(xl, yl, it, w, hub)
    xo, yo = core_cordic.apply_gain(xo, yo, it, w, hub)
    ex = np.concatenate([np.asarray(xl)[:, None], np.asarray(xo)], 1)
    ey = np.concatenate([np.asarray(yl)[:, None], np.asarray(yo)], 1)
    assert np.max(np.abs(np.asarray(xr32, np.int64) - ex)) <= 2
    assert np.max(np.abs(np.asarray(yr32, np.int64) - ey)) <= 2


def test_kernel_numerics_float_reference():
    (X, v) = fix_rows(16, 16)
    x, y = jnp.asarray(X[0]), jnp.asarray(X[1])
    xr, yr = ops.givens_rotate_rows_fixed(x, y, iters=24, hub=True)
    r = np.hypot(v[0, :, 0], v[1, :, 0])
    c, s = v[0, :, 0] / r, v[1, :, 0] / r
    ex = c[:, None] * v[0, :, 1:] + s[:, None] * v[1, :, 1:]
    got = np.asarray(xr[:, 1:], np.float64) / 2 ** 24
    np.testing.assert_allclose(got, ex, atol=2e-6)
    np.testing.assert_allclose(np.asarray(xr[:, 0], np.float64) / 2 ** 24,
                               r, atol=2e-6)


def test_gain_constant_q30():
    from repro.kernels.cordic_givens import comp_q30
    for it in (8, 16, 24):
        exact = 2.0 ** 30 / core_cordic.cordic_gain(it)
        assert abs(comp_q30(it) - exact) <= 0.5


@pytest.mark.parametrize("hub", [False, True])
def test_fused_kernel_bit_equals_separate(hub):
    """§Perf C1: the fused single-pass kernel is bit-identical."""
    from repro.kernels.ops import givens_rotate_rows_fused
    (X, _) = fix_rows(24, 96)
    x, y = jnp.asarray(X[0]), jnp.asarray(X[1])
    a = ops.givens_rotate_rows_fixed(x, y, iters=24, hub=hub)
    b = givens_rotate_rows_fused(x, y, iters=24, hub=hub)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


@pytest.mark.parametrize("tile_l", [128, 256])
def test_rotation_tile_width_invariance(tile_l):
    """§Perf C2: tile width is a pure performance knob — results identical."""
    from repro.kernels import cordic_givens as k
    (X, _) = fix_rows(8, 256)
    x, y = jnp.asarray(X[0]), jnp.asarray(X[1])
    flip = jnp.zeros((8, 1), jnp.int32)
    sig = jnp.full((8, 1), 0x155555, jnp.int32)
    base = k.rotation_call(x, y, flip, sig, iters=22, hub=True,
                           interpret=True, tile_l=128)
    got = k.rotation_call(x, y, flip, sig, iters=22, hub=True,
                          interpret=True, tile_l=tile_l)
    for u, v in zip(base, got):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
