"""Compiled-vs-interpret parity and ragged-batch padding contracts.

Two guarantees from DESIGN.md §11:

* **Compiled parity** — every Pallas path that can lower on this host's
  backend must produce *bit-identical* output with ``interpret=False``
  and ``interpret=True``.  On CPU-only hosts (no Mosaic/Triton target)
  these tests skip with an explicit reason rather than silently passing;
  `benchmarks/compiled_smoke.py` is the CI entry point that runs them on
  real accelerators.

* **Ragged batches** — every ``*_call``-backed op pads the leading batch
  axis up to a multiple of ``tile_b`` and strips the padding afterward,
  so a batch of 5 with ``tile_b=4`` is bit-identical to the same batch
  with a tile that divides it exactly.  This runs everywhere (interpret
  mode included) and covers all six kernel entry points.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.givens import GivensConfig, GivensUnit
from repro.core.qrd import givens_schedule, sameh_kuck_schedule
from repro.kernels import ops

compiled = pytest.mark.skipif(
    not ops.compiled_backend_available(),
    reason="no compiled Pallas backend on "
           f"'{jax.default_backend()}' — interpret=False needs TPU/GPU")

CFG = GivensConfig(n=25, hub=True)
M = 4
STEPS = givens_schedule(M, M)
STAGES = sameh_kuck_schedule(M, M)


def _packed(batch, seed=0, cfg=CFG, m=M):
    rng = np.random.default_rng(seed)
    unit = GivensUnit(cfg)
    return unit.encode(jnp.asarray(rng.standard_normal((batch, m, m))))


def _cpacked(batch, seed=0, cfg=CFG, m=M):
    rng = np.random.default_rng(seed)
    unit = GivensUnit(cfg)
    z = rng.standard_normal((batch, m, m, 2))
    return unit.encode(jnp.asarray(z))


def _rows(batch, seed=0, m=M):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((batch, m, m)))


# --------------------------------------------------------------------------
# Compiled parity (skips on CPU with the reason above).
# --------------------------------------------------------------------------
@compiled
def test_blockfp_compiled_matches_interpret():
    W = _rows(8)
    ci = ops.givens_block_apply(W, STEPS, interpret=True)
    cc = ops.givens_block_apply(W, STEPS, interpret=False)
    assert bool(jnp.all(ci == cc))


@compiled
def test_blockfp_wavefront_compiled_matches_interpret():
    W = _rows(8, seed=1)
    ci = ops.givens_block_apply_wavefront(W, STAGES, interpret=True)
    cc = ops.givens_block_apply_wavefront(W, STAGES, interpret=False)
    assert bool(jnp.all(ci == cc))


@compiled
@pytest.mark.parametrize("lanes", [False, True])
def test_packed_compiled_matches_interpret(lanes):
    P = _packed(8, seed=2)
    ci = ops.qr_packed(P, cfg=CFG, steps=STEPS, lanes=lanes, interpret=True)
    cc = ops.qr_packed(P, cfg=CFG, steps=STEPS, lanes=lanes, interpret=False)
    assert bool(jnp.all(ci == cc))


@compiled
def test_packed_wavefront_compiled_matches_interpret():
    P = _packed(8, seed=3)
    ci = ops.qr_packed_wavefront(P, cfg=CFG, stages=STAGES, lanes=True,
                                 interpret=True)
    cc = ops.qr_packed_wavefront(P, cfg=CFG, stages=STAGES, lanes=True,
                                 interpret=False)
    assert bool(jnp.all(ci == cc))


# --------------------------------------------------------------------------
# Ragged batches: B=5 with tile_b=4 (pad+mask) vs tile_b=5 (exact fit).
# Runs on every host; interpret mode is resolved by the ops layer.
# --------------------------------------------------------------------------
B_RAGGED = 5


def test_ragged_qr_packed():
    P = _packed(B_RAGGED, seed=4)
    a = ops.qr_packed(P, cfg=CFG, steps=STEPS, tile_b=4)
    b = ops.qr_packed(P, cfg=CFG, steps=STEPS, tile_b=B_RAGGED)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))


def test_ragged_qr_packed_lanes():
    P = _packed(B_RAGGED, seed=5)
    a = ops.qr_packed(P, cfg=CFG, steps=STEPS, lanes=True, tile_b=4)
    b = ops.qr_packed(P, cfg=CFG, steps=STEPS, lanes=True, tile_b=B_RAGGED)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))


@pytest.mark.parametrize("layout", ["split", "stacked"])
def test_ragged_qr_packed_wavefront(layout):
    P = _packed(B_RAGGED, seed=6)
    a = ops.qr_packed_wavefront(P, cfg=CFG, stages=STAGES, tile_b=4,
                                lanes=True, table_layout=layout)
    b = ops.qr_packed_wavefront(P, cfg=CFG, stages=STAGES, tile_b=B_RAGGED,
                                lanes=True, table_layout=layout)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))


def test_ragged_qr_packed_complex():
    P = _cpacked(B_RAGGED, seed=7)
    a = ops.qr_packed_complex(P, cfg=CFG, steps=STEPS, tile_b=4)
    b = ops.qr_packed_complex(P, cfg=CFG, steps=STEPS, tile_b=B_RAGGED)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))


def test_ragged_qr_packed_complex_wavefront():
    P = _cpacked(B_RAGGED, seed=8)
    a = ops.qr_packed_complex_wavefront(P, cfg=CFG, stages=STAGES, tile_b=4)
    b = ops.qr_packed_complex_wavefront(P, cfg=CFG, stages=STAGES,
                                        tile_b=B_RAGGED)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))


def test_ragged_blockfp():
    W = _rows(B_RAGGED, seed=9)
    a = ops.givens_block_apply(W, STEPS, tile_b=4)
    b = ops.givens_block_apply(W, STEPS, tile_b=B_RAGGED)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))


def test_ragged_blockfp_wavefront():
    W = _rows(B_RAGGED, seed=10)
    a = ops.givens_block_apply_wavefront(W, STAGES, tile_b=4)
    b = ops.givens_block_apply_wavefront(W, STAGES, tile_b=B_RAGGED)
    assert a.shape[0] == B_RAGGED
    assert bool(jnp.all(a == b))
