"""Deterministic, shardable, checkpointable synthetic data pipeline.

Design goals (what a real pipeline needs at 1000-node scale, minus the I/O):
  - *stateless addressing*: batch(step) is a pure function of (seed, step),
    so restart-at-step-k reproduces the exact token stream with no replay;
  - *host sharding*: each host materializes only its slice of the global
    batch — `host_batch(step, host_id, n_hosts)`;
  - *checkpointable state*: the full iterator state is one integer.

Tokens follow a Zipf-ish marginal with a Markov-ish structure (a deterministic
mixing of per-position PRNG streams) so losses move like language, not noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class DataState:
    step: int = 0

    def as_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]))


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0

    def _tokens(self, key, shape):
        # Zipf-ish marginal: take the min of two uniform draws, square it —
        # skews mass toward low token ids like a real corpus.
        u = jax.random.uniform(key, shape + (2,))
        z = jnp.min(u, axis=-1) ** 2
        return jnp.clip((z * self.vocab).astype(jnp.int32), 0, self.vocab - 1)

    def batch(self, step: int):
        """Full global batch for a step: {'tokens': (B, S) int32}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return {"tokens": self._tokens(key, (self.global_batch, self.seq))}

    def host_batch(self, step: int, host_id: int, n_hosts: int):
        """This host's contiguous slice of the global batch."""
        assert self.global_batch % n_hosts == 0
        per = self.global_batch // n_hosts
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, host_id)
        return {"tokens": self._tokens(key, (per, self.seq))}

    def extras(self, cfg, batch_size: int):
        """Modality-stub inputs for encdec/vlm configs (zeros; shape-correct)."""
        out = {}
        if cfg.family == "encdec":
            out["frames"] = jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model),
                                      jnp.float32)
        if cfg.family == "vlm":
            out["image_embeds"] = jnp.zeros(
                (batch_size, cfg.n_image_tokens, cfg.d_model), jnp.float32)
        return out
