"""Deterministic, shardable, checkpointable synthetic data pipeline.

Design goals (what a real pipeline needs at 1000-node scale, minus the I/O):
  - *stateless addressing*: batch(step) is a pure function of (seed, step),
    so restart-at-step-k reproduces the exact token stream with no replay;
  - *host sharding*: each host materializes only its slice of the global
    batch — `host_batch(step, host_id, n_hosts)`;
  - *checkpointable state*: the full iterator state is one integer.

Tokens follow a Zipf-ish marginal with a Markov-ish structure (a deterministic
mixing of per-position PRNG streams) so losses move like language, not noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class DataState:
    step: int = 0

    def as_dict(self):
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(step=int(d["step"]))


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0

    def _tokens(self, key, shape):
        # Zipf-ish marginal: take the min of two uniform draws, square it —
        # skews mass toward low token ids like a real corpus.
        u = jax.random.uniform(key, shape + (2,))
        z = jnp.min(u, axis=-1) ** 2
        return jnp.clip((z * self.vocab).astype(jnp.int32), 0, self.vocab - 1)

    def batch(self, step: int):
        """Full global batch for a step: {'tokens': (B, S) int32}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return {"tokens": self._tokens(key, (self.global_batch, self.seq))}

    def host_batch(self, step: int, host_id: int, n_hosts: int):
        """This host's contiguous slice of the global batch."""
        assert self.global_batch % n_hosts == 0
        per = self.global_batch // n_hosts
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        key = jax.random.fold_in(key, host_id)
        return {"tokens": self._tokens(key, (per, self.seq))}

    def extras(self, cfg, batch_size: int):
        """Modality-stub inputs for encdec/vlm configs (zeros; shape-correct)."""
        out = {}
        if cfg.family == "encdec":
            out["frames"] = jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model),
                                      jnp.float32)
        if cfg.family == "vlm":
            out["image_embeds"] = jnp.zeros(
                (batch_size, cfg.n_image_tokens, cfg.d_model), jnp.float32)
        return out


@dataclasses.dataclass(frozen=True)
class SyntheticTraffic:
    """Stateless per-user equalizer traffic for the QRD-RLS serving fleet.

    Each user `u` owns a fixed hidden channel ``w_u`` (a pure function of
    ``(seed, u)``); `batch(step)` draws `per_step` users uniformly and
    emits one snapshot each: regressor ``x ~ N(0, I_n)`` and desired
    response ``d = x·w_u + noise`` (complex circularly-symmetric when
    `complex_dtype`).  Addressing is stateless exactly like `SyntheticLM`
    — ``batch(step)`` is a pure function of ``(seed, step)``, so a fleet
    restored from a checkpoint replays the identical post-restore
    traffic with no iterator state beyond the step integer.
    """

    users: int
    n: int
    per_step: int
    seed: int = 0
    snr_db: float = 30.0
    complex_dtype: bool = False

    def _split(self, key, shape):
        if not self.complex_dtype:
            return jax.random.normal(key, shape, dtype=jnp.float64)
        kre, kim = jax.random.split(key)
        scale = jnp.float64(jnp.sqrt(0.5))
        return (jax.random.normal(kre, shape, dtype=jnp.float64) * scale
                + 1j * jax.random.normal(kim, shape, dtype=jnp.float64)
                * scale)

    def channel(self, user):
        """The hidden ``w_user`` — ground truth for convergence checks."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), 1 + user)
        return self._split(key, (self.n,))

    def batch(self, step: int):
        """One traffic tick: ``{'user': (B,), 'x': (B, n), 'd': (B,)}``.

        Users within a tick are distinct only by chance — the server's
        batcher serializes duplicate slots, so collisions are legal.
        """
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        ku, kx, kn = jax.random.split(jax.random.fold_in(key, 0), 3)
        users = jax.random.randint(ku, (self.per_step,), 0, self.users)
        x = self._split(kx, (self.per_step, self.n))
        w = jax.vmap(self.channel)(users)
        noise = self._split(kn, (self.per_step,))
        sigma = 10.0 ** (-self.snr_db / 20.0)
        d = jnp.einsum("bn,bn->b", x, w) + sigma * noise
        return {"user": users, "x": x, "d": d}
