"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill uses an associative scan over the sequence; decode is a single gated
state update (O(1) per token) — with the bounded local-attention window this
is why recurrentgemma runs the `long_500k` cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .layers import causal_conv1d, causal_conv1d_step, dense_init

F32 = jnp.float32
_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0          # 0 -> d_model
    conv_width: int = 4

    def width(self, d_model):
        return self.lru_width or d_model


def rglru_init(key, d_model, cfg: RGLRUConfig, dtype):
    ks = jax.random.split(key, 6)
    w = cfg.width(d_model)
    # Lambda init so that a^c in [0.9, 0.999] roughly (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), F32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-ln u / c)
    return {
        "in_x": dense_init(ks[1], d_model, w, dtype),
        "in_y": dense_init(ks[2], d_model, w, dtype),
        "conv_w": (jax.random.normal(ks[3], (cfg.conv_width, w), F32)
                   / np.sqrt(cfg.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "W_a": dense_init(ks[4], w, w, F32),
        "b_a": jnp.zeros((w,), F32),
        "W_x": dense_init(ks[5], w, w, F32),
        "b_x": jnp.zeros((w,), F32),
        "Lambda": lam,
        "out": dense_init(jax.random.fold_in(key, 7), w, d_model, dtype),
    }


def _gates(xc, p):
    xf = xc.astype(F32)
    r = jax.nn.sigmoid(xf @ p["W_a"] + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["W_x"] + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["Lambda"]) * r        # log decay, < 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * xf


def rglru_apply(x, p, cfg: RGLRUConfig, d_model):
    """Prefill/train forward. x: (B, S, D) -> (B, S, D), decode cache."""
    S = x.shape[1]
    xb = x @ p["in_x"]
    yb = x @ p["in_y"]
    xc = causal_conv1d(xb, p["conv_w"], p["conv_b"])
    a, b = _gates(xc, p)                                   # (B,S,w) f32

    # h_t = a_t h_{t-1} + b_t  via associative scan along S
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * jax.nn.gelu(yb.astype(F32)).astype(x.dtype))
    cache = {"state": h[:, -1],
             "conv": xb[:, S - (cfg.conv_width - 1):]}
    return out @ p["out"], cache


def rglru_init_cache(batch, d_model, cfg: RGLRUConfig, dtype):
    w = cfg.width(d_model)
    return {
        "state": jnp.zeros((batch, w), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_step(x1, cache, p, cfg: RGLRUConfig, d_model):
    """Decode one token. x1: (B, 1, D). O(1) per token."""
    xb = x1 @ p["in_x"]
    yb = x1 @ p["in_y"]
    xc, conv_state = causal_conv1d_step(xb, cache["conv"],
                                        p["conv_w"], p["conv_b"])
    a, b = _gates(xc[:, 0], p)                             # (B,w)
    h = a * cache["state"] + b
    out = (h[:, None].astype(x1.dtype)
           * jax.nn.gelu(yb.astype(F32)).astype(x1.dtype))
    return out @ p["out"], {"state": h, "conv": conv_state}
