"""Attention blocks: GQA self-attention, MLA (DeepSeek-V2), cross-attention.

Each block exposes:
    *_init(key, cfg, dtype)                       -> params
    *_apply(x, p, cfg, ...)                       -> y          (train/prefill)
    *_init_cache(batch, max_len, cfg, dtype)      -> cache
    *_step(x1, cache, pos, p, cfg)                -> y, cache   (decode)

`cfg` here is the model-level ModelConfig (models.config); blocks read the
fields they need so one config object drives every family.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (apply_rope, attention, chunked_attention,
                     decode_attention, dense_init, rms_norm, rope_for_pos,
                     rope_for_seq)

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_dim: int = 128


# ---------------------------------------------------------------------------
# GQA self-attention
# ---------------------------------------------------------------------------
def gqa_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    D, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_()
    p = {"wq": dense_init(ks[0], D, H * dh, dtype),
         "wk": dense_init(ks[1], D, Hk * dh, dtype),
         "wv": dense_init(ks[2], D, Hk * dh, dtype),
         "wo": dense_init(ks[3], H * dh, D, dtype)}
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((H * dh,), dtype)
        p["bk"] = jnp.zeros((Hk * dh,), dtype)
        p["bv"] = jnp.zeros((Hk * dh,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def _qkv(x, p, cfg):
    B, S, _ = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, Hk, dh)
    v = v.reshape(B, S, Hk, dh)
    if cfg.qk_norm:
        q = rms_norm(q, {"w": p["q_norm"]})
        k = rms_norm(k, {"w": p["k_norm"]})
    return q, k, v


def _rot_dim(cfg):
    return int(cfg.head_dim_() * cfg.rotary_pct) // 2 * 2


def gqa_apply(x, p, cfg, *, causal=True, positions=None, use_rope=True):
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg)
    if use_rope:
        pos = jnp.arange(S) if positions is None else positions
        cos, sin = rope_for_seq(pos, _rot_dim(cfg), cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    o = chunked_attention(q, k, v, causal=causal, window=cfg.window,
                          kv_chunk=cfg.kv_chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def gqa_init_cache(batch, max_len, cfg, dtype):
    Hk, dh = cfg.n_kv_heads, cfg.head_dim_()
    # Sliding-window layers only ever need `window` cache slots.
    slots = max_len if cfg.window is None else min(max_len, cfg.window)
    return {"k": jnp.zeros((batch, slots, Hk, dh), dtype),
            "v": jnp.zeros((batch, slots, Hk, dh), dtype)}


def gqa_prefill_cache(x, p, cfg, max_len, dtype):
    """Build the cache from a full prefill pass; returns (y, cache)."""
    B, S, _ = x.shape
    q, k, v = _qkv(x, p, cfg)
    pos = jnp.arange(S)
    cos, sin = rope_for_seq(pos, _rot_dim(cfg), cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = chunked_attention(q, k, v, causal=True, window=cfg.window,
                          kv_chunk=cfg.kv_chunk)
    y = o.reshape(B, S, -1) @ p["wo"]
    cache = gqa_init_cache(B, max_len, cfg, k.dtype)
    slots = cache["k"].shape[1]
    take = min(S, slots)
    cache = {"k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k[:, S - take:], 0, 1),
             "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v[:, S - take:], 0, 1)}
    return y, cache


def gqa_step(x1, cache, pos, p, cfg):
    """pos: scalar — current position (number of tokens already cached)."""
    B = x1.shape[0]
    q, k, v = _qkv(x1, p, cfg)
    cos, sin = rope_for_pos(jnp.full((B,), pos), _rot_dim(cfg), cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    slots = cache["k"].shape[1]
    # ring-buffer write for windowed layers, linear write otherwise
    write_at = pos % slots if cfg.window is not None else pos
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write_at, 1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write_at, 1)
    if cfg.window is None:
        o = decode_attention(q, kc, vc, pos + 1)
    else:
        # ring buffer: every slot valid once pos >= slots; mask by age
        k_pos = jnp.arange(slots)
        age_ok = jnp.where(pos + 1 >= slots, jnp.ones((slots,), bool),
                           k_pos <= pos)
        scale = np.float32(1.0 / np.sqrt(cfg.head_dim_()))
        Hk = cfg.n_kv_heads
        G = cfg.n_heads // Hk
        qg = q.reshape(B, 1, Hk, G, -1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(F32), kc.astype(F32)) * scale
        s = jnp.where(age_ok[None, None, None, None, :], s, -jnp.inf)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, vc.astype(F32))
        o = o.reshape(B, 1, cfg.n_heads, -1).astype(x1.dtype)
    y = o.reshape(B, 1, -1) @ p["wo"]
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — latent KV compression
# ---------------------------------------------------------------------------
def mla_init(key, cfg, dtype):
    m: MLAConfig = cfg.mla
    ks = jax.random.split(key, 8)
    D, H = cfg.d_model, cfg.n_heads
    dqk = m.qk_nope + m.qk_rope
    return {
        "q_down": dense_init(ks[0], D, m.q_lora, dtype),
        "q_norm": jnp.ones((m.q_lora,), dtype),
        "q_up": dense_init(ks[1], m.q_lora, H * dqk, dtype),
        "kv_down": dense_init(ks[2], D, m.kv_lora + m.qk_rope, dtype),
        "kv_norm": jnp.ones((m.kv_lora,), dtype),
        "k_up": dense_init(ks[3], m.kv_lora, H * m.qk_nope, dtype),
        "v_up": dense_init(ks[4], m.kv_lora, H * m.v_dim, dtype),
        "wo": dense_init(ks[5], H * m.v_dim, D, dtype),
    }


def _mla_q(x, p, cfg):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    ql = rms_norm(x @ p["q_down"], {"w": p["q_norm"]})
    q = (ql @ p["q_up"]).reshape(B, S, H, m.qk_nope + m.qk_rope)
    return q[..., :m.qk_nope], q[..., m.qk_nope:]


def _mla_latent(x, p, cfg):
    m = cfg.mla
    kv = x @ p["kv_down"]
    c_kv = rms_norm(kv[..., :m.kv_lora], {"w": p["kv_norm"]})
    k_rope = kv[..., m.kv_lora:]                  # (B,S,rope) shared head
    return c_kv, k_rope


def mla_apply(x, p, cfg, *, positions=None):
    """Prefill/train: expand the latent and run standard MHA (nope+rope)."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(x, p, cfg)
    c_kv, k_rope = _mla_latent(x, p, cfg)
    k_nope = (c_kv @ p["k_up"]).reshape(B, S, H, m.qk_nope)
    v = (c_kv @ p["v_up"]).reshape(B, S, H, m.v_dim)
    pos = jnp.arange(S) if positions is None else positions
    cos, sin = rope_for_seq(pos, m.qk_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,rope)
    k_rope = jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    o = chunked_attention(q, k, v, causal=True, kv_chunk=cfg.kv_chunk)
    return o.reshape(B, S, -1) @ p["wo"]


def mla_init_cache(batch, max_len, cfg, dtype):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope), dtype)}


def mla_step(x1, cache, pos, p, cfg):
    """Absorbed decode: scores and values computed in the 512-d latent space.

    This is MLA's raison d'être — the KV cache is (kv_lora + qk_rope) wide
    per token instead of 2 * H * head_dim.
    """
    m = cfg.mla
    B = x1.shape[0]
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(x1, p, cfg)                     # (B,1,H,*)
    c_new, kr_new = _mla_latent(x1, p, cfg)
    cos, sin = rope_for_pos(jnp.full((B,), pos), m.qk_rope, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    kr_new = apply_rope(kr_new[:, :, None, :], cos, sin)[:, :, 0, :]
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, pos, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, pos, 1)

    W_uk = p["k_up"].reshape(m.kv_lora, H, m.qk_nope)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(F32),
                       W_uk.astype(F32))                     # (B,1,H,kv_lora)
    s = (jnp.einsum("bqhl,bkl->bhqk", q_abs, c_kv.astype(F32))
         + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(F32), k_rope.astype(F32)))
    s = s * np.float32(1.0 / np.sqrt(m.qk_nope + m.qk_rope))
    valid = jnp.arange(c_kv.shape[1]) <= pos
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqk,bkl->bqhl", pr, c_kv.astype(F32))  # latent context
    W_uv = p["v_up"].reshape(m.kv_lora, H, m.v_dim)
    o = jnp.einsum("bqhl,lhd->bqhd", ctx, W_uv.astype(F32))
    y = o.reshape(B, 1, -1).astype(x1.dtype) @ p["wo"]
    return y, {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder / llama-vision gated layers)
# ---------------------------------------------------------------------------
def cross_init(key, cfg, dtype, gated=False):
    ks = jax.random.split(key, 4)
    D, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_()
    p = {"wq": dense_init(ks[0], D, H * dh, dtype),
         "wk": dense_init(ks[1], D, Hk * dh, dtype),
         "wv": dense_init(ks[2], D, Hk * dh, dtype),
         "wo": dense_init(ks[3], H * dh, D, dtype)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    if gated:
        p["gate_attn"] = jnp.zeros((), F32)
        p["gate_mlp"] = jnp.zeros((), F32)
    return p


def cross_kv(mem, p, cfg):
    """Precompute K/V from the encoder/vision memory (B, Sm, D)."""
    B, Sm, _ = mem.shape
    Hk, dh = cfg.n_kv_heads, cfg.head_dim_()
    k = (mem @ p["wk"]).reshape(B, Sm, Hk, dh)
    v = (mem @ p["wv"]).reshape(B, Sm, Hk, dh)
    if cfg.qk_norm and "k_norm" in p:
        k = rms_norm(k, {"w": p["k_norm"]})
    return k, v


def cross_apply(x, kv, p, cfg):
    """x: (B,S,D) queries; kv: precomputed (k, v)."""
    B, S, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim_()
    q = (x @ p["wq"]).reshape(B, S, H, dh)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, {"w": p["q_norm"]})
    k, v = kv
    o = chunked_attention(q, k, v, causal=False, kv_chunk=cfg.kv_chunk) \
        if k.shape[1] > cfg.kv_chunk else attention(q, k, v, causal=False)
    return o.reshape(B, S, -1) @ p["wo"]
