"""Mixture-of-Experts FFN with group-local capacity dispatch (GShard-style).

Exact top-k routing with a static per-group capacity.  Tokens are processed
in G groups aligned with the data-parallel shards, so *every* data-dependent
step (sort, rank, scatter) is group-local and GSPMD keeps it on-shard:

  1. (B, S, d) -> (G, Tl, d); router + top-k per token,
  2. rank each assignment within (group, expert) via a group-local sort,
  3. scatter-ADD kept tokens into a dense (G, E, cap, d) buffer
     (dropped assignments are zero-valued writes -> collision-safe),
  4. relayout to (E, G*cap, d): with G sharded over the data axes and E over
     "model", this resharding IS the expert-parallel all-to-all,
  5. batched expert FFN, inverse relayout, gather + gate-weighted combine.

Static shapes, no global sorts, no O(T*E*C) one-hots.  Supports shared
(always-on) experts as in DeepSeek-V2.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import partition
from .layers import dense_init, mlp_init, mlp_apply

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    n_shared: int = 0           # DeepSeek shared experts
    d_shared: int = 0           # hidden size of the shared-expert MLP
    capacity_factor: float = 1.25
    router_scale: float = 1.0   # routed_scaling_factor (DeepSeek)
    normalize_gates: bool = True

    def capacity(self, tokens_per_group: int) -> int:
        cap = int(np.ceil(self.top_k * tokens_per_group / self.n_experts
                          * self.capacity_factor))
        return max(8, -(-cap // 8) * 8)


def moe_init(key, d_model, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    E, dff = cfg.n_experts, cfg.d_expert
    s_in = 1.0 / np.sqrt(d_model)
    s_ff = 1.0 / np.sqrt(dff)
    p = {
        "router": dense_init(ks[0], d_model, E, F32),  # router kept in f32
        "w_gate": (jax.random.normal(ks[1], (E, d_model, dff), F32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, dff), F32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, dff, d_model), F32) * s_ff).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, cfg.d_shared, dtype, gated=True)
    return p


def _group_ranks(flat_e, E):
    """flat_e: (G, A) expert ids -> rank of each assignment within its
    (group, expert) queue; group-local (vmappable/shardable) ops only."""
    G, A = flat_e.shape
    order = jnp.argsort(flat_e, axis=1, stable=True)            # (G, A)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    ones = jnp.ones_like(flat_e)
    counts = jax.vmap(
        lambda fe, on: jax.ops.segment_sum(on, fe, num_segments=E)
    )(flat_e, ones)                                              # (G, E)
    starts = (jnp.cumsum(counts, axis=1) - counts).astype(jnp.int32)
    ranks_sorted = (jnp.arange(A, dtype=jnp.int32)[None]
                    - jnp.take_along_axis(starts, sorted_e, axis=1))
    inv = jnp.argsort(order, axis=1)                             # inverse perm
    return jnp.take_along_axis(ranks_sorted, inv, axis=1)        # (G, A)


def moe_apply(x, p, cfg: MoEConfig):
    """x: (B, S, d) -> (B, S, d); also returns aux router stats."""
    B, S, d = x.shape
    T = B * S
    k = cfg.top_k
    E = cfg.n_experts
    G = partition.dp_groups()
    if T % G != 0:
        G = 1
    Tl = T // G
    cap = cfg.capacity(Tl)
    xg = x.reshape(G, Tl, d)

    logits = xg.astype(F32) @ p["router"]                # (G, Tl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                # (G, Tl, k)
    if cfg.normalize_gates:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    gates = gates * cfg.router_scale

    A = Tl * k
    flat_e = eidx.reshape(G, A).astype(jnp.int32)
    tok_of = jnp.repeat(jnp.arange(Tl, dtype=jnp.int32), k)  # same per group
    ranks = _group_ranks(flat_e, E)
    keep = ranks < cap
    slot_c = jnp.minimum(ranks, cap - 1)

    # --- dispatch: group-local scatter-add into (G, E, cap, d) ---
    vals = xg[:, tok_of] * keep[..., None].astype(x.dtype)   # (G, A, d)
    buf = jax.vmap(
        lambda fe, sc, v: jnp.zeros((E, cap, d), x.dtype).at[fe, sc].add(v)
    )(flat_e, slot_c, vals)
    buf = partition.constrain(buf, "__dp__", None, None, None)

    # --- all-to-all: (G:data, E, cap, d) -> (E:model, G*cap:data, d) ---
    he = jnp.moveaxis(buf, 0, 1).reshape(E, G * cap, d)
    he = partition.constrain(he, "model", "__dp__", None)

    # --- batched expert FFN (SwiGLU) ---
    g = jnp.einsum("ecd,edf->ecf", he, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", he, p["w_up"])
    a = jax.nn.silu(g.astype(F32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", a, p["w_down"])           # (E, G*cap, d)
    y = partition.constrain(y, "model", "__dp__", None)

    # --- inverse all-to-all + combine ---
    yg = jnp.moveaxis(y.reshape(E, G, cap, d), 1, 0)          # (G, E, cap, d)
    yg = partition.constrain(yg, "__dp__", None, None, None)
    per_asn = jax.vmap(lambda yy, fe, sc: yy[fe, sc])(yg, flat_e, slot_c)
    per_asn = per_asn * (gates.reshape(G, A, 1)
                         * keep[..., None]).astype(x.dtype)
    out = jax.vmap(
        lambda v: jax.ops.segment_sum(v, tok_of, num_segments=Tl)
    )(per_asn)                                                # (G, Tl, d)
    out = out.reshape(B, S, d)

    if cfg.n_shared:
        out = out + mlp_apply(x, p["shared"])

    counts_all = jax.ops.segment_sum(
        jnp.ones((G * A,), F32), flat_e.reshape(-1), num_segments=E)
    aux = {
        # load-balance stats (Switch-style aux loss ingredients)
        "router_frac": counts_all / (T * k),
        "router_prob": jnp.mean(probs, axis=(0, 1)),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(F32)),
    }
    return out, aux


def load_balance_loss(aux) -> jnp.ndarray:
    """Switch-Transformer load-balance loss: E * sum(frac_e * prob_e)."""
    E = aux["router_frac"].shape[0]
    return E * jnp.sum(aux["router_frac"] * aux["router_prob"])
