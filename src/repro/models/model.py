"""Model assembly for the five families: lm / encdec / vlm / hybrid / ssm.

Public API (all pure functions of (cfg, params, ...)):

    init_params(cfg, key)                          -> params
    train_loss(cfg, params, batch)                 -> (loss, metrics)
    prefill(cfg, params, batch, max_len)           -> (last_logits, cache)
    decode_step(cfg, params, token, cache, pos)    -> (logits, cache)
    init_decode_state(cfg, batch, max_len, extras) -> cache (zeros; dry-run)

Layers are stacked along a leading axis and driven with `lax.scan` so compile
time is O(1) in depth; heterogeneous stacks (vlm periods, hybrid patterns)
scan over the pattern period with a small Python loop inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import blocks, partition
from .config import ModelConfig
from .layers import (apply_norm, apply_rope, mlp_apply, mlp_init, norm_init,
                     rope_for_seq)
from .moe import load_balance_loss, moe_apply, moe_init
from .rglru import (rglru_apply, rglru_init, rglru_init_cache, rglru_step)
from .ssm import ssm_apply, ssm_init, ssm_init_cache, ssm_step

F32 = jnp.float32


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _stack_init(fn, key, n):
    """vmap an init fn over n layer keys -> params stacked on axis 0."""
    return jax.vmap(fn)(jax.random.split(key, n))


def _embed(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * np.float32(np.sqrt(cfg.d_model))
    return partition.constrain_batch(x.astype(cfg.dtype))


def _logits(cfg: ModelConfig, params, x):
    x = partition.constrain_batch(x)
    h = apply_norm(cfg.norm, x, params["final_norm"])
    return (h @ params["lm_head"]).astype(F32)


def _xent(logits, labels, mask=None):
    """logits (B,S,V) f32, labels (B,S) -> mean NLL."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def _shift_loss(cfg, params, x, tokens):
    # Keep the full S extent (a [:, :-1] slice would make the seq dim uneven
    # under sequence-parallel sharding); mask the final position instead.
    logits = _logits(cfg, params, x)              # (B,S,V)
    labels = jnp.roll(tokens, -1, axis=1)
    S = tokens.shape[1]
    mask = jnp.broadcast_to((jnp.arange(S) < S - 1)[None, :].astype(F32),
                            labels.shape)
    return _xent(logits, labels, mask=mask)


def _remat(fn):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


# ---------------------------------------------------------------------------
# generic transformer layer (GQA or MLA attention + MLP or MoE)
# ---------------------------------------------------------------------------
def _lm_layer_init(cfg: ModelConfig, use_moe: bool):
    def init(key):
        ka, kf, _ = jax.random.split(key, 3)
        p = {"norm1": norm_init(cfg.d_model, cfg.dtype, bias=cfg.norm == "ln"),
             "norm2": norm_init(cfg.d_model, cfg.dtype, bias=cfg.norm == "ln")}
        p["attn"] = (blocks.mla_init(ka, cfg, cfg.dtype) if cfg.mla
                     else blocks.gqa_init(ka, cfg, cfg.dtype))
        if use_moe:
            p["moe"] = moe_init(kf, cfg.d_model, cfg.moe, cfg.dtype)
        else:
            p["mlp"] = mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype,
                                gated=cfg.mlp_gated, bias=cfg.mlp_bias)
        return p
    return init


def _lm_layer_apply(cfg: ModelConfig, p, x):
    x = partition.constrain_batch(x)
    h = apply_norm(cfg.norm, x, p["norm1"])
    if cfg.mla:
        a = blocks.mla_apply(h, p["attn"], cfg)
    else:
        a = blocks.gqa_apply(h, p["attn"], cfg, causal=True)
    x = x + a
    h = apply_norm(cfg.norm, x, p["norm2"])
    if "moe" in p:
        f, aux = moe_apply(h, p["moe"], cfg.moe)
        return x + f, load_balance_loss(aux)
    return x + mlp_apply(h, p["mlp"], act=cfg.mlp_act), jnp.zeros((), F32)


def _lm_layer_prefill(cfg, p, x, max_len):
    """Like apply, but also emits the layer's decode cache."""
    x = partition.constrain_batch(x)
    h = apply_norm(cfg.norm, x, p["norm1"])
    if cfg.mla:
        B, S, _ = h.shape
        a = blocks.mla_apply(h, p["attn"], cfg)
        c_kv, k_rope = blocks._mla_latent(h, p["attn"], cfg)
        cache = blocks.mla_init_cache(B, max_len, cfg, cfg.dtype)
        # note: k_rope in the cache must be rope-rotated; redo the rotation
        cos, sin = rope_for_seq(jnp.arange(S), cfg.mla.qk_rope, cfg.rope_theta)
        kr = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
        cache = {
            "c_kv": jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cfg.dtype), 0, 1),
            "k_rope": jax.lax.dynamic_update_slice_in_dim(
                cache["k_rope"], kr.astype(cfg.dtype), 0, 1),
        }
    else:
        a, cache = blocks.gqa_prefill_cache(h, p["attn"], cfg, max_len, cfg.dtype)
    x = x + a
    h = apply_norm(cfg.norm, x, p["norm2"])
    if "moe" in p:
        f, _ = moe_apply(h, p["moe"], cfg.moe)
        x = x + f
    else:
        x = x + mlp_apply(h, p["mlp"], act=cfg.mlp_act)
    return x, cache


def _lm_layer_step(cfg, p, x1, cache, pos):
    x1 = partition.constrain_batch(x1)
    h = apply_norm(cfg.norm, x1, p["norm1"])
    if cfg.mla:
        a, cache = blocks.mla_step(h, cache, pos, p["attn"], cfg)
    else:
        a, cache = blocks.gqa_step(h, cache, pos, p["attn"], cfg)
    x1 = x1 + a
    h = apply_norm(cfg.norm, x1, p["norm2"])
    if "moe" in p:
        f, _ = moe_apply(h, p["moe"], cfg.moe)
        x1 = x1 + f
    else:
        x1 = x1 + mlp_apply(h, p["mlp"], act=cfg.mlp_act)
    return x1, cache


def _lm_cache_init(cfg, batch, max_len):
    if cfg.mla:
        return blocks.mla_init_cache(batch, max_len, cfg, cfg.dtype)
    return blocks.gqa_init_cache(batch, max_len, cfg, cfg.dtype)


# ===========================================================================
# family: lm (dense + MoE, GQA + MLA)
# ===========================================================================
def _lm_init(cfg: ModelConfig, key):
    ke, kh, k0, kl, kn = jax.random.split(key, 5)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), F32) * 0.02
                  ).astype(cfg.dtype),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab), F32)
                    / np.sqrt(cfg.d_model)).astype(cfg.dtype),
        "final_norm": norm_init(cfg.d_model, cfg.dtype, bias=cfg.norm == "ln"),
    }
    n_scan = cfg.n_layers - cfg.first_dense
    if cfg.first_dense:
        params["head_layers"] = _stack_init(
            _lm_layer_init(cfg, use_moe=False), k0, cfg.first_dense)
    params["layers"] = _stack_init(
        _lm_layer_init(cfg, use_moe=cfg.moe is not None), kl, n_scan)
    return params


def _lm_forward(cfg, params, tokens, remat=True):
    x = _embed(cfg, params, tokens)
    layer = functools.partial(_lm_layer_apply, cfg)
    if remat:
        layer = _remat(layer)

    def body(carry, lp):
        x, aux = carry
        x, a = layer(lp, x)
        return (x, aux + a), None

    aux = jnp.zeros((), F32)
    if cfg.first_dense:
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["head_layers"])
    (x, aux), _ = jax.lax.scan(body, (x, aux), params["layers"])
    return x, aux


def _lm_train_loss(cfg, params, batch):
    x, aux = _lm_forward(cfg, params, batch["tokens"])
    loss = _shift_loss(cfg, params, x, batch["tokens"])
    metrics = {"xent": loss, "moe_aux": aux}
    if cfg.moe:
        loss = loss + 0.01 * aux / cfg.n_layers
    return loss, metrics


def _lm_prefill(cfg, params, batch, max_len):
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)

    def body(x, lp):
        x, cache = _lm_layer_prefill(cfg, lp, x, max_len)
        return x, cache

    caches = {}
    if cfg.first_dense:
        x, caches["head"] = jax.lax.scan(body, x, params["head_layers"])
    x, caches["main"] = jax.lax.scan(body, x, params["layers"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def _lm_decode_step(cfg, params, token, cache, pos):
    x = _embed(cfg, params, token)

    def body(x, inp):
        lp, lc = inp
        x, nc = _lm_layer_step(cfg, lp, x, lc, pos)
        return x, nc

    new_cache = {}
    if cfg.first_dense:
        x, new_cache["head"] = jax.lax.scan(
            body, x, (params["head_layers"], cache["head"]))
    x, new_cache["main"] = jax.lax.scan(body, x, (params["layers"], cache["main"]))
    logits = _logits(cfg, params, x)
    return logits[:, 0], new_cache


def _lm_init_decode_state(cfg, batch, max_len, extras=None):
    def one(_):
        return _lm_cache_init(cfg, batch, max_len)
    cache = {"main": jax.vmap(one)(jnp.arange(cfg.n_layers - cfg.first_dense))}
    if cfg.first_dense:
        cache["head"] = jax.vmap(one)(jnp.arange(cfg.first_dense))
    return cache


# ===========================================================================
# family: ssm (Mamba-2)
# ===========================================================================
def _ssm_layer_init(cfg):
    def init(key):
        return {"norm": norm_init(cfg.d_model, cfg.dtype),
                "mixer": ssm_init(key, cfg.d_model, cfg.ssm, cfg.dtype)}
    return init


def _ssm_init(cfg, key):
    ke, kh, kl = jax.random.split(key, 3)
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), F32) * 0.02
                  ).astype(cfg.dtype),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab), F32)
                    / np.sqrt(cfg.d_model)).astype(cfg.dtype),
        "final_norm": norm_init(cfg.d_model, cfg.dtype),
        "layers": _stack_init(_ssm_layer_init(cfg), kl, cfg.n_layers),
    }


def _ssm_train_loss(cfg, params, batch):
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)

    def layer(lp, x):
        x = partition.constrain_batch(x)
        h = apply_norm(cfg.norm, x, lp["norm"])
        y, _cache = ssm_apply(h, lp["mixer"], cfg.ssm, cfg.d_model)
        return x + y

    f = _remat(layer)

    def body(x, lp):
        return f(lp, x), None

    x, _ = jax.lax.scan(body, x, params["layers"])
    loss = _shift_loss(cfg, params, x, tokens)
    return loss, {"xent": loss}


def _ssm_prefill(cfg, params, batch, max_len):
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)

    def body(x, lp):
        x = partition.constrain_batch(x)
        h = apply_norm(cfg.norm, x, lp["norm"])
        y, cache = ssm_apply(h, lp["mixer"], cfg.ssm, cfg.d_model)
        return x + y, cache

    x, caches = jax.lax.scan(body, x, params["layers"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def _ssm_decode_step(cfg, params, token, cache, pos):
    x = _embed(cfg, params, token)

    def body(x, inp):
        lp, st, cv = inp
        x = partition.constrain_batch(x)
        h = apply_norm(cfg.norm, x, lp["norm"])
        y, nc = ssm_step(h, {"state": st, "conv": cv}, lp["mixer"],
                         cfg.ssm, cfg.d_model)
        return x + y, (nc["state"], nc["conv"])

    x, (states, convs) = jax.lax.scan(
        body, x, (params["layers"], cache["state"], cache["conv"]))
    logits = _logits(cfg, params, x)
    return logits[:, 0], {"state": states, "conv": convs}


def _ssm_init_decode_state(cfg, batch, max_len, extras=None):
    def one(_):
        return ssm_init_cache(batch, cfg.d_model, cfg.ssm, cfg.dtype)
    c = jax.vmap(one)(jnp.arange(cfg.n_layers))
    return {"state": c["state"], "conv": c["conv"]}


# ===========================================================================
# family: hybrid (RecurrentGemma: pattern of rec/rec/attn blocks)
# ===========================================================================
def _hyb_block_init(cfg, kind):
    def init(key):
        kt, kf = jax.random.split(key)
        p = {"norm1": norm_init(cfg.d_model, cfg.dtype),
             "norm2": norm_init(cfg.d_model, cfg.dtype)}
        if kind == "rec":
            p["rec"] = rglru_init(kt, cfg.d_model, cfg.rglru, cfg.dtype)
        else:
            p["attn"] = blocks.gqa_init(kt, cfg, cfg.dtype)
        p["mlp"] = mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype,
                            gated=cfg.mlp_gated, bias=cfg.mlp_bias)
        return p
    return init


def _hyb_layout(cfg):
    period = cfg.pattern
    n_full = cfg.n_layers // len(period)
    tail = tuple(period[: cfg.n_layers % len(period)])
    return period, n_full, tail


def _hyb_init(cfg, key):
    period, n_full, tail = _hyb_layout(cfg)
    ke, kh, kp, kt = jax.random.split(key, 4)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), F32) * 0.02
                  ).astype(cfg.dtype),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab), F32)
                    / np.sqrt(cfg.d_model)).astype(cfg.dtype),
        "final_norm": norm_init(cfg.d_model, cfg.dtype),
    }

    def period_init(k):
        ks = jax.random.split(k, len(period))
        return {f"b{i}": _hyb_block_init(cfg, kind)(ks[i])
                for i, kind in enumerate(period)}

    params["periods"] = _stack_init(period_init, kp, n_full)
    params["tail"] = [
        _hyb_block_init(cfg, kind)(jax.random.fold_in(kt, i))
        for i, kind in enumerate(tail)]
    return params


def _hyb_block_apply(cfg, kind, p, x):
    x = partition.constrain_batch(x)
    h = apply_norm(cfg.norm, x, p["norm1"])
    if kind == "rec":
        y, _ = rglru_apply(h, p["rec"], cfg.rglru, cfg.d_model)
    else:
        y = blocks.gqa_apply(h, p["attn"], cfg, causal=True)
    x = x + y
    h = apply_norm(cfg.norm, x, p["norm2"])
    return x + mlp_apply(h, p["mlp"], act=cfg.mlp_act)


def _hyb_train_loss(cfg, params, batch):
    period, n_full, tail = _hyb_layout(cfg)
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)

    def period_apply(pp, x):
        for i, kind in enumerate(period):
            x = _hyb_block_apply(cfg, kind, pp[f"b{i}"], x)
        return x

    f = _remat(period_apply)

    def body(x, pp):
        return f(pp, x), None

    x, _ = jax.lax.scan(body, x, params["periods"])
    for p, kind in zip(params["tail"], tail):
        x = _hyb_block_apply(cfg, kind, p, x)
    loss = _shift_loss(cfg, params, x, tokens)
    return loss, {"xent": loss}


def _hyb_block_cache(cfg, kind, batch, max_len):
    if kind == "rec":
        return rglru_init_cache(batch, cfg.d_model, cfg.rglru, cfg.dtype)
    return blocks.gqa_init_cache(batch, max_len, cfg, cfg.dtype)


def _hyb_block_step(cfg, kind, p, x1, cache, pos):
    x1 = partition.constrain_batch(x1)
    h = apply_norm(cfg.norm, x1, p["norm1"])
    if kind == "rec":
        y, cache = rglru_step(h, cache, p["rec"], cfg.rglru, cfg.d_model)
    else:
        y, cache = blocks.gqa_step(h, cache, pos, p["attn"], cfg)
    x1 = x1 + y
    h = apply_norm(cfg.norm, x1, p["norm2"])
    return x1 + mlp_apply(h, p["mlp"], act=cfg.mlp_act), cache


def _hyb_decode_step(cfg, params, token, cache, pos):
    period, n_full, tail = _hyb_layout(cfg)
    x = _embed(cfg, params, token)

    def body(x, inp):
        pp, pc = inp
        ncs = {}
        for i, kind in enumerate(period):
            x, nc = _hyb_block_step(cfg, kind, pp[f"b{i}"], x, pc[f"b{i}"], pos)
            ncs[f"b{i}"] = nc
        return x, ncs

    x, new_periods = jax.lax.scan(body, x, (params["periods"], cache["periods"]))
    new_tail = []
    for p, kind, c in zip(params["tail"], tail, cache["tail"]):
        x, nc = _hyb_block_step(cfg, kind, p, x, c, pos)
        new_tail.append(nc)
    logits = _logits(cfg, params, x)
    return logits[:, 0], {"periods": new_periods, "tail": new_tail}


def _hyb_init_decode_state(cfg, batch, max_len, extras=None):
    period, n_full, tail = _hyb_layout(cfg)

    def one(_):
        return {f"b{i}": _hyb_block_cache(cfg, kind, batch, max_len)
                for i, kind in enumerate(period)}

    return {"periods": jax.vmap(one)(jnp.arange(n_full)),
            "tail": [_hyb_block_cache(cfg, kind, batch, max_len)
                     for kind in tail]}


def _hyb_prefill(cfg, params, batch, max_len):
    # Serving prefill for hybrids: run block-by-block, capturing states.
    period, n_full, tail = _hyb_layout(cfg)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)

    def period_prefill(pp, x):
        caches = {}
        for i, kind in enumerate(period):
            p = pp[f"b{i}"]
            h = apply_norm(cfg.norm, x, p["norm1"])
            if kind == "rec":
                y, cache = rglru_apply(h, p["rec"], cfg.rglru, cfg.d_model)
            else:
                y, cache = blocks.gqa_prefill_cache(h, p["attn"], cfg,
                                                    max_len, cfg.dtype)
            x = x + y
            h = apply_norm(cfg.norm, x, p["norm2"])
            x = x + mlp_apply(h, p["mlp"], act=cfg.mlp_act)
            caches[f"b{i}"] = cache
        return x, caches

    x, period_caches = jax.lax.scan(
        lambda x, pp: period_prefill(pp, x), x, params["periods"])
    tail_caches = []
    for p, kind in zip(params["tail"], tail):
        h = apply_norm(cfg.norm, x, p["norm1"])
        if kind == "rec":
            y, cache = rglru_apply(h, p["rec"], cfg.rglru, cfg.d_model)
        else:
            y, cache = blocks.gqa_prefill_cache(h, p["attn"], cfg,
                                                max_len, cfg.dtype)
        x = x + y
        h = apply_norm(cfg.norm, x, p["norm2"])
        x = x + mlp_apply(h, p["mlp"], act=cfg.mlp_act)
        tail_caches.append(cache)
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], {"periods": period_caches, "tail": tail_caches}


# ===========================================================================
# family: encdec (Whisper backbone; conv frontend is a stub)
# ===========================================================================
def _enc_layer_init(cfg):
    def init(key):
        ka, kf = jax.random.split(key)
        return {"norm1": norm_init(cfg.d_model, cfg.dtype, bias=True),
                "norm2": norm_init(cfg.d_model, cfg.dtype, bias=True),
                "attn": blocks.gqa_init(ka, cfg, cfg.dtype),
                "mlp": mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype,
                                gated=False, bias=True)}
    return init


def _dec_layer_init(cfg):
    def init(key):
        ka, kc, kf = jax.random.split(key, 3)
        return {"norm1": norm_init(cfg.d_model, cfg.dtype, bias=True),
                "norm_x": norm_init(cfg.d_model, cfg.dtype, bias=True),
                "norm2": norm_init(cfg.d_model, cfg.dtype, bias=True),
                "attn": blocks.gqa_init(ka, cfg, cfg.dtype),
                "cross": blocks.cross_init(kc, cfg, cfg.dtype),
                "mlp": mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype,
                                gated=False, bias=True)}
    return init


def _sinusoid(S, D):
    pos = np.arange(S)[:, None]
    dim = np.arange(D // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / D)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1), F32)


def _encdec_init(cfg, key):
    ke, kh, k1, k2 = jax.random.split(key, 4)
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), F32) * 0.02
                  ).astype(cfg.dtype),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab), F32)
                    / np.sqrt(cfg.d_model)).astype(cfg.dtype),
        "final_norm": norm_init(cfg.d_model, cfg.dtype, bias=True),
        "enc_final_norm": norm_init(cfg.d_model, cfg.dtype, bias=True),
        "enc_layers": _stack_init(_enc_layer_init(cfg), k1, cfg.enc_layers),
        "dec_layers": _stack_init(_dec_layer_init(cfg), k2, cfg.n_layers),
    }


def _encode(cfg, params, frames):
    """frames: (B, enc_seq, D) — stub frontend output (pre-computed embeds)."""
    S = frames.shape[1]
    x = frames.astype(cfg.dtype) + _sinusoid(S, cfg.d_model).astype(cfg.dtype)

    def layer(lp, x):
        x = partition.constrain_batch(x)
        h = apply_norm("ln", x, lp["norm1"])
        a = blocks.gqa_apply(h, lp["attn"], cfg, causal=False, use_rope=False)
        x = x + a
        h = apply_norm("ln", x, lp["norm2"])
        return x + mlp_apply(h, lp["mlp"], act="gelu")

    f = _remat(layer)
    x, _ = jax.lax.scan(lambda x, lp: (f(lp, x), None), x, params["enc_layers"])
    return apply_norm("ln", x, params["enc_final_norm"])


def _encdec_train_loss(cfg, params, batch):
    mem = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    S = tokens.shape[1]
    x = _embed(cfg, params, tokens) + _sinusoid(S, cfg.d_model).astype(cfg.dtype)

    def layer(lp, x):
        x = partition.constrain_batch(x)
        h = apply_norm("ln", x, lp["norm1"])
        x = x + blocks.gqa_apply(h, lp["attn"], cfg, causal=True, use_rope=False)
        h = apply_norm("ln", x, lp["norm_x"])
        kv = blocks.cross_kv(mem, lp["cross"], cfg)
        x = x + blocks.cross_apply(h, kv, lp["cross"], cfg)
        h = apply_norm("ln", x, lp["norm2"])
        return x + mlp_apply(h, lp["mlp"], act="gelu")

    f = _remat(layer)
    x, _ = jax.lax.scan(lambda x, lp: (f(lp, x), None), x, params["dec_layers"])
    loss = _shift_loss(cfg, params, x, tokens)
    return loss, {"xent": loss}


def _encdec_prefill(cfg, params, batch, max_len):
    mem = _encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = _embed(cfg, params, tokens) + _sinusoid(S, cfg.d_model).astype(cfg.dtype)

    def layer(x, lp):
        x = partition.constrain_batch(x)
        h = apply_norm("ln", x, lp["norm1"])
        a, cache = blocks.gqa_prefill_cache(h, lp["attn"], cfg, max_len, cfg.dtype)
        x = x + a
        h = apply_norm("ln", x, lp["norm_x"])
        kv = blocks.cross_kv(mem, lp["cross"], cfg)
        x = x + blocks.cross_apply(h, kv, lp["cross"], cfg)
        h = apply_norm("ln", x, lp["norm2"])
        x = x + mlp_apply(h, lp["mlp"], act="gelu")
        return x, {"self": cache, "cross_k": kv[0], "cross_v": kv[1]}

    x, caches = jax.lax.scan(layer, x, params["dec_layers"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def _encdec_decode_step(cfg, params, token, cache, pos):
    slots = cache["self"]["k"].shape[2]  # (L, B, slots, Hk, dh)
    pe = _sinusoid(slots, cfg.d_model)   # static table, gathered at pos
    x = _embed(cfg, params, token) + pe[pos][None, None, :].astype(cfg.dtype)

    def layer(x, inp):
        lp, lc = inp
        x = partition.constrain_batch(x)
        h = apply_norm("ln", x, lp["norm1"])
        a, sc = blocks.gqa_step(h, lc["self"], pos, lp["attn"], cfg)
        x = x + a
        h = apply_norm("ln", x, lp["norm_x"])
        x = x + blocks.cross_apply(h, (lc["cross_k"], lc["cross_v"]),
                                   lp["cross"], cfg)
        h = apply_norm("ln", x, lp["norm2"])
        x = x + mlp_apply(h, lp["mlp"], act="gelu")
        return x, {"self": sc, "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}

    x, new_cache = jax.lax.scan(layer, x, (params["dec_layers"], cache))
    logits = _logits(cfg, params, x)
    return logits[:, 0], new_cache


def _encdec_init_decode_state(cfg, batch, max_len, extras=None):
    Hk, dh = cfg.n_kv_heads, cfg.head_dim_()

    def one(_):
        return {"self": blocks.gqa_init_cache(batch, max_len, cfg, cfg.dtype),
                "cross_k": jnp.zeros((batch, cfg.enc_seq, Hk, dh), cfg.dtype),
                "cross_v": jnp.zeros((batch, cfg.enc_seq, Hk, dh), cfg.dtype)}

    return jax.vmap(one)(jnp.arange(cfg.n_layers))


# ===========================================================================
# family: vlm (Llama-3.2-Vision backbone: gated cross-attn every N layers)
# ===========================================================================
def _vlm_period_init(cfg):
    n_self = cfg.cross_every - 1

    def init(key):
        ks, kc, kf = jax.random.split(key, 3)
        p = {"self": _stack_init(_lm_layer_init(cfg, use_moe=False), ks, n_self)}
        cross = {"norm1": norm_init(cfg.d_model, cfg.dtype),
                 "norm2": norm_init(cfg.d_model, cfg.dtype),
                 "attn": blocks.cross_init(kc, cfg, cfg.dtype, gated=True),
                 "mlp": mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype,
                                 gated=True)}
        p["cross"] = cross
        return p
    return init


def _vlm_init(cfg, key):
    ke, kh, kp = jax.random.split(key, 3)
    n_periods = cfg.n_layers // cfg.cross_every
    return {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model), F32) * 0.02
                  ).astype(cfg.dtype),
        "lm_head": (jax.random.normal(kh, (cfg.d_model, cfg.vocab), F32)
                    / np.sqrt(cfg.d_model)).astype(cfg.dtype),
        "final_norm": norm_init(cfg.d_model, cfg.dtype),
        "periods": _stack_init(_vlm_period_init(cfg), kp, n_periods),
    }


def _vlm_cross_apply(cfg, p, x, img):
    x = partition.constrain_batch(x)
    h = apply_norm(cfg.norm, x, p["norm1"])
    kv = blocks.cross_kv(img, p["attn"], cfg)
    a = blocks.cross_apply(h, kv, p["attn"], cfg)
    x = x + jnp.tanh(p["attn"]["gate_attn"]).astype(x.dtype) * a
    h = apply_norm(cfg.norm, x, p["norm2"])
    f = mlp_apply(h, p["mlp"], act=cfg.mlp_act)
    return x + jnp.tanh(p["attn"]["gate_mlp"]).astype(x.dtype) * f


def _vlm_train_loss(cfg, params, batch):
    tokens = batch["tokens"]
    img = batch["image_embeds"].astype(cfg.dtype)
    x = _embed(cfg, params, tokens)
    self_layer = _remat(functools.partial(_lm_layer_apply, cfg))

    def period(pp, x):
        def body(x, lp):
            x, _ = self_layer(lp, x)
            return x, None
        x, _ = jax.lax.scan(body, x, pp["self"])
        return _vlm_cross_apply(cfg, pp["cross"], x, img)

    f = _remat(period)
    x, _ = jax.lax.scan(lambda x, pp: (f(pp, x), None), x, params["periods"])
    loss = _shift_loss(cfg, params, x, tokens)
    return loss, {"xent": loss}


def _vlm_prefill(cfg, params, batch, max_len):
    tokens = batch["tokens"]
    img = batch["image_embeds"].astype(cfg.dtype)
    x = _embed(cfg, params, tokens)

    def period(x, pp):
        def body(x, lp):
            return _lm_layer_prefill(cfg, lp, x, max_len)
        x, self_caches = jax.lax.scan(body, x, pp["self"])
        kv = blocks.cross_kv(img, pp["cross"]["attn"], cfg)
        x = _vlm_cross_apply(cfg, pp["cross"], x, img)
        return x, {"self": self_caches, "cross_k": kv[0], "cross_v": kv[1]}

    x, caches = jax.lax.scan(period, x, params["periods"])
    logits = _logits(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def _vlm_decode_step(cfg, params, token, cache, pos):
    x = _embed(cfg, params, token)

    def period(x, inp):
        pp, pc = inp

        def body(carry, lp_lc):
            x = carry
            lp, lc = lp_lc
            x, nc = _lm_layer_step(cfg, lp, x, lc, pos)
            return x, nc

        x, self_caches = jax.lax.scan(body, x, (pp["self"], pc["self"]))
        h = apply_norm(cfg.norm, x, pp["cross"]["norm1"])
        a = blocks.cross_apply(h, (pc["cross_k"], pc["cross_v"]),
                               pp["cross"]["attn"], cfg)
        x = x + jnp.tanh(pp["cross"]["attn"]["gate_attn"]).astype(x.dtype) * a
        h = apply_norm(cfg.norm, x, pp["cross"]["norm2"])
        f = mlp_apply(h, pp["cross"]["mlp"], act=cfg.mlp_act)
        x = x + jnp.tanh(pp["cross"]["attn"]["gate_mlp"]).astype(x.dtype) * f
        return x, {"self": self_caches, "cross_k": pc["cross_k"],
                   "cross_v": pc["cross_v"]}

    x, new_cache = jax.lax.scan(period, x, (params["periods"], cache))
    logits = _logits(cfg, params, x)
    return logits[:, 0], new_cache


def _vlm_init_decode_state(cfg, batch, max_len, extras=None):
    Hk, dh = cfg.n_kv_heads, cfg.head_dim_()
    n_periods = cfg.n_layers // cfg.cross_every
    n_self = cfg.cross_every - 1

    def one(_):
        def one_self(_):
            return blocks.gqa_init_cache(batch, max_len, cfg, cfg.dtype)
        return {"self": jax.vmap(one_self)(jnp.arange(n_self)),
                "cross_k": jnp.zeros((batch, cfg.n_image_tokens, Hk, dh),
                                     cfg.dtype),
                "cross_v": jnp.zeros((batch, cfg.n_image_tokens, Hk, dh),
                                     cfg.dtype)}

    return jax.vmap(one)(jnp.arange(n_periods))


# ===========================================================================
# dispatch
# ===========================================================================
_FAMS = {
    "lm": (_lm_init, _lm_train_loss, _lm_prefill, _lm_decode_step,
           _lm_init_decode_state),
    "ssm": (_ssm_init, _ssm_train_loss, _ssm_prefill, _ssm_decode_step,
            _ssm_init_decode_state),
    "hybrid": (_hyb_init, _hyb_train_loss, _hyb_prefill, _hyb_decode_step,
               _hyb_init_decode_state),
    "encdec": (_encdec_init, _encdec_train_loss, _encdec_prefill,
               _encdec_decode_step, _encdec_init_decode_state),
    "vlm": (_vlm_init, _vlm_train_loss, _vlm_prefill, _vlm_decode_step,
            _vlm_init_decode_state),
}


def init_params(cfg: ModelConfig, key):
    return _FAMS[cfg.family][0](cfg, key)


def train_loss(cfg: ModelConfig, params, batch):
    return _FAMS[cfg.family][1](cfg, params, batch)


def prefill(cfg: ModelConfig, params, batch, max_len):
    return _FAMS[cfg.family][2](cfg, params, batch, max_len)


def decode_step(cfg: ModelConfig, params, token, cache, pos):
    return _FAMS[cfg.family][3](cfg, params, token, cache, pos)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int, extras=None):
    return _FAMS[cfg.family][4](cfg, batch, max_len, extras)
