from .config import (MLAConfig, ModelConfig, MoEConfig, RGLRUConfig, SSMConfig)
from .model import (decode_step, init_decode_state, init_params, prefill,
                    train_loss)

__all__ = ["ModelConfig", "MLAConfig", "MoEConfig", "RGLRUConfig", "SSMConfig",
           "init_params", "train_loss", "prefill", "decode_step",
           "init_decode_state"]
