"""Shared neural-net layers: norms, RoPE, MLPs, attention (incl. chunked
flash-style attention for long-context prefill), depthwise causal conv.

Conventions:
  - activations are (B, S, D); attention heads are materialized as (B, S, H, Dh)
  - params are plain nested dicts of jnp arrays (pytrees)
  - every op takes an explicit compute dtype; accumulation/softmax in f32
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "layer_norm", "apply_norm", "rope_table", "apply_rope",
           "rope_for_seq", "rope_for_pos",
           "mlp_init", "mlp_apply", "attention", "chunked_attention",
           "decode_attention", "causal_conv1d", "causal_conv1d_step",
           "dense_init", "norm_init"]

F32 = jnp.float32


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = (1.0 / np.sqrt(d_in)) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out), F32) * scale).astype(dtype)


def norm_init(d, dtype, bias=False):
    p = {"w": jnp.ones((d,), dtype)}
    if bias:
        p["b"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, p, eps=1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(F32)).astype(x.dtype)


def layer_norm(x, p, eps=1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["w"].astype(F32)
    if "b" in p:
        y = y + p["b"].astype(F32)
    return y.astype(x.dtype)


def apply_norm(kind, x, p, eps=1e-6):
    return rms_norm(x, p, eps) if kind == "rms" else layer_norm(x, p, eps)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_table(positions, rot_dim, theta=10000.0):
    """positions: (...,) int -> (cos, sin) each (..., rot_dim/2), f32."""
    half = rot_dim // 2
    inv = 1.0 / (theta ** (np.arange(half, dtype=np.float32) * 2.0 / rot_dim))
    ang = positions.astype(F32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, Dh); cos/sin broadcastable to (B, S, H, rot/2).

    Rotates the first `rot` dims (half-split layout), passes the rest through.
    Use `rope_for_seq` / `rope_for_pos` to build correctly-shaped tables.
    """
    assert cos.ndim == x.ndim, "use rope_for_seq/rope_for_pos"
    rot = cos.shape[-1] * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(xr.astype(F32), 2, axis=-1)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1.astype(x.dtype), o2.astype(x.dtype), xp], axis=-1)


def rope_for_seq(seq_positions, rot_dim, theta):
    """(S,) positions -> cos/sin shaped (1, S, 1, rot/2) for (B,S,H,D) tensors."""
    cos, sin = rope_table(seq_positions, rot_dim, theta)
    return cos[None, :, None, :], sin[None, :, None, :]


def rope_for_pos(positions, rot_dim, theta):
    """(B,) per-sample positions -> cos/sin shaped (B, 1, 1, rot/2)."""
    cos, sin = rope_table(positions, rot_dim, theta)
    return cos[:, None, None, :], sin[:, None, None, :]


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype, gated=True, bias=False):
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    if bias:
        p["up_b"] = jnp.zeros((d_ff,), dtype)
        p["down_b"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(x, p, act="silu"):
    fn = jax.nn.gelu if act == "gelu" else jax.nn.silu
    up = x @ p["up"]
    if "up_b" in p:
        up = up + p["up_b"]
    if "gate" in p:
        g = x @ p["gate"]
        h = fn(g.astype(F32)).astype(x.dtype) * up
    else:
        h = fn(up.astype(F32)).astype(x.dtype)
    out = h @ p["down"]
    if "down_b" in p:
        out = out + p["down_b"]
    return out


# ---------------------------------------------------------------------------
# attention (dense, chunked-flash, decode)
# ---------------------------------------------------------------------------
def _gqa_scores(q, k, scale):
    """q: (B,Sq,H,D), k: (B,Sk,Hk,D) -> scores (B, Hk, G, Sq, Sk), f32."""
    B, Sq, H, D = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, D)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(F32), k.astype(F32)) * scale


def _gqa_out(probs, v):
    """probs: (B,Hk,G,Sq,Sk) f32; v: (B,Sk,Hk,D) -> (B,Sq,H,D)."""
    B, Hk, G, Sq, Sk = probs.shape
    D = v.shape[-1]
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(F32))
    return o.reshape(B, Sq, Hk * G, D)


def _mask_bias(q_pos, k_pos, causal, window):
    """-> additive bias (Sq, Sk), 0 where allowed, -inf where masked."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, -jnp.inf).astype(F32)


def attention(q, k, v, *, causal=True, window=None, q_pos=None, k_pos=None):
    """Dense GQA attention. Positions default to iota (self-attention)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = np.float32(1.0 / np.sqrt(D))
    q_pos = jnp.arange(Sq) if q_pos is None else q_pos
    k_pos = jnp.arange(Sk) if k_pos is None else k_pos
    s = _gqa_scores(q, k, scale)
    s = s + _mask_bias(q_pos, k_pos, causal, window)[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=None, kv_chunk=1024):
    """Flash-style online-softmax attention: scan over KV chunks.

    Memory is O(Sq * kv_chunk) instead of O(Sq * Sk); used whenever
    Sk > kv_chunk (e.g. the 32k prefill cells).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    if Sk <= kv_chunk or Sk % kv_chunk != 0:
        # dense fallback (short KV, or KV not a chunk multiple e.g. whisper's
        # 1500-frame encoder memory)
        return attention(q, k, v, causal=causal, window=window)
    Hk = k.shape[2]
    G = H // Hk
    nkv = Sk // kv_chunk
    scale = np.float32(1.0 / np.sqrt(D))
    qg = q.reshape(B, Sq, Hk, G, D).astype(F32)
    kc = k.reshape(B, nkv, kv_chunk, Hk, k.shape[-1])
    vc = v.reshape(B, nkv, kv_chunk, Hk, v.shape[-1])
    q_pos = jnp.arange(Sq)

    def body(carry, inp):
        m, l, acc = carry
        j, kb, vb = inp
        k_pos = j * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb.astype(F32)) * scale
        bias = _mask_bias(q_pos, k_pos, causal, window)
        s = s + bias[None, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> use safe m
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isneginf(m), m_safe, m) - m_safe)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vb.astype(F32))
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    Dv = v.shape[-1]  # may differ from the q/k head dim (e.g. MLA 192 vs 128)
    m0 = jnp.full((B, Hk, G, Sq), -jnp.inf, F32)
    l0 = jnp.zeros((B, Hk, G, Sq), F32)
    a0 = jnp.zeros((B, Hk, G, Sq, Dv), F32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(nkv), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, -2, 1).reshape(B, Sq, Hk * G, Dv)
    return out.astype(q.dtype)


def decode_attention(q1, k_cache, v_cache, cache_len, *, window=None):
    """Single-position decode: q1 (B, 1, H, D) vs cache (B, Smax, Hk, D).

    `cache_len` (scalar int) is the number of valid cache positions; the new
    token's K/V must already be written at cache_len - 1.
    """
    B, _, H, D = q1.shape
    Smax = k_cache.shape[1]
    scale = np.float32(1.0 / np.sqrt(D))
    s = _gqa_scores(q1, k_cache, scale)  # (B,Hk,G,1,Smax)
    k_pos = jnp.arange(Smax)
    ok = k_pos < cache_len
    if window is not None:
        ok &= k_pos > cache_len - 1 - window
    s = jnp.where(ok[None, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v_cache).astype(q1.dtype)


# ---------------------------------------------------------------------------
# depthwise causal conv (SSM / RG-LRU front conv)
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, b=None):
    """x: (B, S, C); w: (K, C) depthwise kernel -> (B, S, C), causal."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(F32), w.astype(F32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    if b is not None:
        out = out + b.astype(F32)
    return out.astype(x.dtype)


def causal_conv1d_step(x1, conv_state, w, b=None):
    """Decode step. x1: (B, 1, C); conv_state: (B, K-1, C) past inputs.

    Returns (y1, new_conv_state).
    """
    K = w.shape[0]
    window = jnp.concatenate([conv_state, x1], axis=1)        # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(F32), w.astype(F32))
    if b is not None:
        y = y + b.astype(F32)
    new_state = window[:, 1:] if K > 1 else conv_state
    return y[:, None, :].astype(x1.dtype), new_state
