"""Activation sharding hints (GSPMD needs anchors, not just param shardings).

Without constraints, the embedding gather creates a sharding conflict
(tokens want batch-sharding, the table wants d_model-sharding) that the
partitioner can resolve by *replicating the batch* — catastrophic for
activation memory.  `constrain_batch` pins the canonical layout at block
boundaries:

  - batch over the data axes (DP/FSDP),
  - optionally the sequence dim over the model axis (Megatron-style
    sequence parallelism) — this shards the per-layer residuals that
    scan+remat must keep alive, the largest train-time activation term;
    GSPMD auto-inserts the all-gather before attention/MLP and the
    reduce-scatter after, exactly like hand-written SP.

The launch layer calls `set_activation_axes(...)` before tracing; model code
stays mesh-agnostic (the hints are no-ops when unset).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_DP_AXES: Optional[Tuple[str, ...]] = None
_TP_AXIS: Optional[str] = None
_TP_SIZE: int = 1
_DP_SIZE: int = 1
_SEQ_PARALLEL: bool = False


def set_activation_axes(dp_axes, tp_axis: Optional[str] = None,
                        tp_size: int = 1, seq_parallel: bool = False,
                        dp_size: int = 1):
    """dp_axes: data axes for the batch dim (None disables all hints)."""
    global _DP_AXES, _TP_AXIS, _TP_SIZE, _SEQ_PARALLEL, _DP_SIZE
    _DP_AXES = tuple(dp_axes) if dp_axes else None
    _TP_AXIS = tp_axis
    _TP_SIZE = tp_size
    _DP_SIZE = dp_size
    _SEQ_PARALLEL = seq_parallel and tp_axis is not None


def get_activation_axes():
    return _DP_AXES


def dp_groups() -> int:
    """Number of data-parallel shards (MoE dispatch group count)."""
    return _DP_SIZE if _DP_AXES is not None else 1


def constrain_batch(x):
    """Pin (B, S, ...) activations: batch->data [, seq->model if SP]."""
    if _DP_AXES is None:
        return x
    if (_SEQ_PARALLEL and x.ndim >= 3 and x.shape[1] > 1
            and x.shape[1] % _TP_SIZE == 0):
        spec = P(_DP_AXES, _TP_AXIS, *([None] * (x.ndim - 2)))
    else:
        spec = P(_DP_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def constrain(x, *spec_entries):
    """Explicit spec; '__dp__' resolves to the data axes."""
    if _DP_AXES is None:
        return x
    spec = P(*[(_DP_AXES if s == "__dp__" else s) for s in spec_entries])
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_moe(buf):
    """(E, cap, d) dispatch buffer: experts over model (EP), slots over data."""
    if _DP_AXES is None:
        return buf
    E, cap = buf.shape[0], buf.shape[1]
    e_ax = _TP_AXIS if (_TP_AXIS and E % _TP_SIZE == 0) else None
    c_ax = _DP_AXES if cap % max(_DP_SIZE, 1) == 0 else None
    return jax.lax.with_sharding_constraint(buf, P(e_ax, c_ax, None))
