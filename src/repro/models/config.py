"""ModelConfig — one declarative config drives all ten architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

from .blocks import MLAConfig
from .moe import MoEConfig
from .rglru import RGLRUConfig
from .ssm import SSMConfig

__all__ = ["ModelConfig", "MLAConfig", "MoEConfig", "RGLRUConfig", "SSMConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # 'lm' | 'encdec' | 'vlm' | 'hybrid' | 'ssm'
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads

    # attention options
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    window: Optional[int] = None    # sliding-window self-attention
    attn_bias: bool = False
    kv_chunk: int = 1024            # flash chunk for long prefill

    # mlp options
    mlp_gated: bool = True
    mlp_act: str = "silu"
    mlp_bias: bool = False
    norm: str = "rms"               # 'rms' | 'ln'
    embed_scale: bool = False       # gemma-style sqrt(d_model) embed scaling

    # family extensions
    moe: Optional[MoEConfig] = None
    first_dense: int = 0            # leading dense-FFN layers in an MoE model
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    pattern: Optional[Tuple[str, ...]] = None  # hybrid, e.g. ('rec','rec','attn')
    cross_every: int = 0            # vlm: one gated cross block per N self blocks
    enc_layers: int = 0             # encdec encoder depth
    enc_seq: int = 1500             # whisper frame count (stub frontend)
    n_image_tokens: int = 6144      # vlm stub patch-embedding count

    # numerics
    dtype: Any = jnp.bfloat16

    # capability flags
    subquadratic: bool = False      # may run the long_500k cell
    has_decoder: bool = True        # encoder-only archs would set False

    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        D, V, L = self.d_model, self.vocab, self.n_layers
        total = V * D  # embed
        total += V * D  # head (untied)
        dh = self.head_dim_()

        def attn_params():
            if self.mla:
                m = self.mla
                dqk = m.qk_nope + m.qk_rope
                return (D * m.q_lora + m.q_lora * self.n_heads * dqk
                        + D * (m.kv_lora + m.qk_rope)
                        + m.kv_lora * self.n_heads * (m.qk_nope + m.v_dim)
                        + self.n_heads * m.v_dim * D)
            return (D * self.n_heads * dh + 2 * D * self.n_kv_heads * dh
                    + self.n_heads * dh * D)

        def mlp_params(dff):
            mult = 3 if self.mlp_gated else 2
            return mult * D * dff

        def moe_params():
            m = self.moe
            routed = m.n_experts * 3 * D * m.d_expert + D * m.n_experts
            shared = (3 * D * m.d_shared) if m.n_shared else 0
            return routed + shared

        if self.family == "ssm":
            s = self.ssm
            d_in = s.d_inner(D)
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            per = (D * (d_in + conv_ch + s.n_heads(D))  # in_proj
                   + s.conv_width * conv_ch + d_in * D)
            return total + L * per
        if self.family == "hybrid":
            n_attn = sum(1 for i in range(L)
                         if self.pattern[i % len(self.pattern)] == "attn")
            n_rec = L - n_attn
            w = self.rglru.width(D)
            rec = 2 * D * w + self.rglru.conv_width * w + 2 * w * w + w * D
            per_mlp = mlp_params(self.d_ff)
            return total + n_attn * (attn_params() + per_mlp) + n_rec * (rec + per_mlp)
        if self.family == "encdec":
            enc = self.enc_layers * (attn_params() + mlp_params(self.d_ff))
            dec = L * (2 * attn_params() + mlp_params(self.d_ff))
            return total + enc + dec
        if self.family == "vlm":
            period = self.cross_every
            n_cross = L // period if period else 0
            n_self = L - n_cross
            per_mlp = mlp_params(self.d_ff)
            return total + (n_self + n_cross) * (attn_params() + per_mlp)
        # plain / moe lm
        per_attn = attn_params()
        if self.moe:
            dense_l = self.first_dense
            moe_l = L - dense_l
            return (total + L * per_attn + dense_l * mlp_params(self.d_ff)
                    + moe_l * moe_params())
        return total + L * (per_attn + mlp_params(self.d_ff))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        D, L = self.d_model, self.n_layers
        moe_l = L - self.first_dense
        routed_active = m.top_k * 3 * D * m.d_expert + D * m.n_experts
        shared = (3 * D * m.d_shared) if m.n_shared else 0
        full = self.param_count()
        routed_total = m.n_experts * 3 * D * m.d_expert + D * m.n_experts
        return full - moe_l * (routed_total + shared) \
            + moe_l * (routed_active + shared)
