"""Mamba-2 (SSD — state-space duality) block, arXiv:2405.21060.

Training/prefill uses the chunked block decomposition: quadratic attention-like
compute inside fixed-size chunks + a sequential inter-chunk state scan, giving
O(S * chunk) work per head with an O(1)-per-token state.  Decode is a single
state update — this is why mamba2 runs the `long_500k` cell that dense
attention archs skip.

Recurrence per head (h: (N, hd) state, per token t):
    h_t = exp(a_t) * h_{t-1} + B_t (x_t * dt_t)^T
    y_t = C_t @ h_t + D * x_t
with a_t = A * dt_t (A < 0 scalar per head), B/C shared across heads per group.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .layers import (causal_conv1d, causal_conv1d_step, dense_init, rms_norm)

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128

    def d_inner(self, d_model):
        return self.expand * d_model

    def n_heads(self, d_model):
        return self.d_inner(d_model) // self.head_dim


def ssm_init(key, d_model, cfg: SSMConfig, dtype):
    ks = jax.random.split(key, 4)
    d_in = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    conv_ch = d_in + 2 * cfg.n_groups * cfg.d_state
    # in_proj emits [z, xBC, dt]
    d_proj = d_in + conv_ch + H
    p = {
        "in_proj": dense_init(ks[0], d_model, d_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch), F32)
                   / np.sqrt(cfg.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(F32)),
        "D": jnp.ones((H,), F32),
        "dt_bias": jnp.full((H,), np.log(np.expm1(0.01)), F32),
        "norm_w": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[2], d_in, d_model, dtype),
    }
    return p


def _split_proj(proj, d_in, conv_ch):
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + conv_ch]
    dt = proj[..., d_in + conv_ch:]
    return z, xBC, dt


def _split_xbc(xBC, d_in, G, N):
    x = xBC[..., :d_in]
    Bm = xBC[..., d_in:d_in + G * N]
    Cm = xBC[..., d_in + G * N:]
    return x, Bm, Cm


def _gated_norm(y, z, w):
    return rms_norm(y * jax.nn.silu(z.astype(F32)).astype(y.dtype), {"w": w})


def ssd_chunked(xdt, a, Bm, Cm, chunk):
    """Chunked SSD scan.

    xdt: (B, S, H, hd) inputs pre-multiplied by dt
    a:   (B, S, H) per-step log decay (negative)
    Bm, Cm: (B, S, G, N); heads are grouped H = G * (H//G)
    Returns y (B, S, H, hd) and the final state (B, H, N, hd).
    """
    B_, S, H, hd = xdt.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    assert S % chunk == 0
    nc = S // chunk
    xc = xdt.reshape(B_, nc, chunk, H, hd).astype(F32)
    ac = a.reshape(B_, nc, chunk, H).astype(F32)
    Bc = Bm.reshape(B_, nc, chunk, G, N).astype(F32)
    Cc = Cm.reshape(B_, nc, chunk, G, N).astype(F32)

    cum = jnp.cumsum(ac, axis=2)                       # (B,nc,Q,H)
    total = cum[:, :, -1, :]                           # (B,nc,H)

    # ---- intra-chunk (quadratic within the chunk) ----
    # scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) for j <= i
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)      # (B,nc,G,Q,Q)
    dec = cum[..., None, :] - cum[:, :, None]          # cum_i - cum_j: (B,nc,Q[i],Q[j],H)? build explicitly
    # build (B,nc,Q,Q,H): cum_i - cum_j
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(dec), 0.0)
    # expand CB over heads-per-group and apply decay
    scores = CB[:, :, :, None, :, :]                   # (B,nc,G,1,Q,Q)
    scores = jnp.broadcast_to(scores, (B_, nc, G, hpg, chunk, chunk))
    Lh = jnp.moveaxis(L, -1, 2).reshape(B_, nc, G, hpg, chunk, chunk)
    y_intra = jnp.einsum("bcghqk,bckghd->bcqghd",
                         scores * Lh,
                         xc.reshape(B_, nc, chunk, G, hpg, hd))

    # ---- chunk-final local states ----
    # S_local = sum_j exp(total - cum_j) * B_j x_j^T   -> (B,nc,H,N,hd)
    w = jnp.exp(total[:, :, None, :] - cum)            # (B,nc,Q,H)
    xw = xc * w[..., None]
    S_local = jnp.einsum("bcqgn,bcqghd->bcghnd",
                         Bc, xw.reshape(B_, nc, chunk, G, hpg, hd))

    # ---- inter-chunk state scan ----
    def body(S_prev, inp):
        S_loc, tot = inp                                # (B,G,hpg,N,hd), (B,H)
        toth = tot.reshape(B_, G, hpg)[..., None, None]
        S_new = S_prev * jnp.exp(toth) + S_loc
        return S_new, S_prev

    S0 = jnp.zeros((B_, G, hpg, N, hd), F32)
    S_final, S_ins = jax.lax.scan(
        body, S0, (jnp.moveaxis(S_local, 1, 0), jnp.moveaxis(total, 1, 0)))
    S_ins = jnp.moveaxis(S_ins, 0, 1)                   # state entering chunk c

    # ---- inter-chunk contribution: y_i += C_i exp(cum_i) S_in ----
    ci = jnp.exp(cum)                                   # (B,nc,Q,H)
    y_inter = jnp.einsum("bcqgn,bcghnd->bcqghd", Cc, S_ins)
    y_inter = y_inter * ci.reshape(B_, nc, chunk, G, hpg)[..., None]

    y = (y_intra + y_inter).reshape(B_, S, H, hd)
    return y, S_final.reshape(B_, H, N, hd)


def ssm_apply(x, p, cfg: SSMConfig, d_model):
    """Training/prefill forward. x: (B, S, D) -> (B, S, D), final state."""
    B, S, D = x.shape
    d_in = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    G, N, hd = cfg.n_groups, cfg.d_state, cfg.head_dim
    conv_ch = d_in + 2 * G * N

    proj = x @ p["in_proj"]
    z, xBC_pre, dt = _split_proj(proj, d_in, conv_ch)
    xBC = causal_conv1d(xBC_pre, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC.astype(F32)).astype(x.dtype)
    xs, Bm, Cm = _split_xbc(xBC, d_in, G, N)

    dtv = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])      # (B,S,H)
    A = -jnp.exp(p["A_log"])                                   # (H,)
    a = A * dtv
    xh = xs.reshape(B, S, H, hd)
    xdt = xh.astype(F32) * dtv[..., None]
    y, state = ssd_chunked(xdt, a, Bm.reshape(B, S, G, N),
                           Cm.reshape(B, S, G, N), cfg.chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(F32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_w"])
    # decode handoff: the conv state is the last (K-1) *pre-conv* inputs
    cache = {"state": state, "conv": xBC_pre[:, S - (cfg.conv_width - 1):]}
    return y @ p["out_proj"], cache


def ssm_init_cache(batch, d_model, cfg: SSMConfig, dtype):
    H = cfg.n_heads(d_model)
    conv_ch = cfg.d_inner(d_model) + 2 * cfg.n_groups * cfg.d_state
    return {
        "state": jnp.zeros((batch, H, cfg.d_state, cfg.head_dim), F32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


def ssm_step(x1, cache, p, cfg: SSMConfig, d_model):
    """Decode one token. x1: (B, 1, D) -> (B, 1, D), new cache. O(1) in S."""
    B = x1.shape[0]
    d_in = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    G, N, hd = cfg.n_groups, cfg.d_state, cfg.head_dim
    conv_ch = d_in + 2 * G * N

    proj = x1 @ p["in_proj"]
    z, xBC, dt = _split_proj(proj, d_in, conv_ch)
    xBC, conv_state = causal_conv1d_step(xBC, cache["conv"],
                                         p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC.astype(F32)).astype(x1.dtype)
    xs, Bm, Cm = _split_xbc(xBC, d_in, G, N)

    dtv = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(A * dtv)                                      # (B,H)
    xh = xs.reshape(B, H, hd).astype(F32)
    Bmg = Bm.reshape(B, G, N).astype(F32)
    Cmg = Cm.reshape(B, G, N).astype(F32)
    hpg = H // G

    inp = jnp.einsum("bgn,bghd->bghnd", Bmg,
                     (xh * dtv[..., None]).reshape(B, G, hpg, hd))
    state = cache["state"].reshape(B, G, hpg, N, hd)
    state = state * decay.reshape(B, G, hpg)[..., None, None] + inp
    y = jnp.einsum("bgn,bghnd->bghd", Cmg, state).reshape(B, H, hd)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, d_in).astype(x1.dtype)
    y = _gated_norm(y, z, p["norm_w"])
    out = y @ p["out_proj"]
    return out, {"state": state.reshape(B, H, N, hd), "conv": conv_state}
