"""HUB (Half-Unit-Biased) numerics as a standalone primitive layer.

Beyond the paper's converter-internal use, HUB rounding is exposed here as a
cheap *unbiased-bound* round-to-nearest cast for float tensors: truncate the
mantissa to (m) bits — the implicit half-ULP then makes the representable
value the round-to-nearest of every real in the bin.  Worst-case error equals
RNE's; no sticky/round-up logic is needed, which is why the paper's HUB
datapath is smaller and faster.

`hub_quantize(x, man_bits)` returns the float value *represented by* the HUB
word (i.e. truncated mantissa + half ULP), so downstream float math sees
exactly what a HUB unit would compute.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["hub_quantize", "hub_error_bound"]


def hub_quantize(x, man_bits: int):
    """Round float array to an m-bit-mantissa HUB value (value-level emulation).

    Works for any float dtype; computed in float64 for exactness.
    """
    xd = jnp.asarray(x, jnp.float64)
    sign = jnp.sign(xd)
    ax = jnp.abs(xd)
    is_zero = ax == 0.0
    f, e = jnp.frexp(jnp.where(is_zero, 1.0, ax))  # f in [0.5, 1)
    scale = jnp.float64(1 << (man_bits + 1))
    # truncate to man_bits fractional bits of the [1,2) significand, + ILSB
    sig = (jnp.floor(f * scale) + 0.5) / scale     # in [0.5, 1)
    out = sign * jnp.ldexp(sig, e)
    out = jnp.where(is_zero, 0.0, out)
    return out.astype(jnp.result_type(x))


def hub_error_bound(man_bits: int) -> float:
    """Worst-case relative rounding error (same bound as RNE): 2^-(m+1)."""
    return 2.0 ** -(man_bits + 1)
