"""QR decomposition engines built on the Givens rotation unit.

The paper evaluates its rotator inside the pipelined QRD architecture of
[Muñoz & Hormigo, TCAS-II 2015]: an m x n input matrix is triangularized by
the column-major Givens schedule, and Q is obtained by augmenting the rows
with the identity — the exact setup behind the paper's "e = 8 elements per
row for 4x4 matrices" throughput accounting and the HUB identity-detection
feature (the 1.0 entries of I enter the unit as data).

Backends:
  'cordic'        the paper's unit, bit-accurate (GivensUnit; IEEE or HUB)
  'cordic_pallas' the same unit, kernel-resident: the whole triangularization
                  runs inside one Pallas kernel (DESIGN.md §5), bit-identical
                  to 'cordic'
  'blockfp_pallas' int32 block-fixed-point blocked kernel: quantize once,
                  rotate everything fixed-point in VMEM, decode once (the
                  TPU-compilable fast path; not bit-identical to 'cordic')
  'givens_float'  float Givens rotations (algorithmic baseline, any dtype)
  'jnp'           jnp.linalg.qr (LAPACK-style "Matlab qr" reference)
  'fixed'         the 32-bit fixed-point rotator of [20] (Fig. 11 baseline)

All backends are batched over a leading batch axis.  Schedules: the default
column-major order, or the Sameh–Kuck parallel pairing
(`sameh_kuck_schedule`) whose stages rotate disjoint row pairs — the order a
spatial/multi-unit implementation would use.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic
from .givens import GivensConfig, GivensUnit

__all__ = ["qr_cordic", "qr_cordic_pallas", "qr_blockfp_pallas",
           "qr_givens_float", "qr_jnp", "qr_fixed", "qr_blocked_sharded",
           "QRDEngine", "snr_db", "givens_schedule", "sameh_kuck_schedule"]


def givens_schedule(m: int, n: int):
    """Column-major zeroing order for an m x n matrix.

    Returns
    -------
    list[(int, int, int)]
        ``(pivot_row, target_row, col)`` triples: entry ``(target_row,
        col)`` is annihilated against the diagonal row ``col``, one column
        at a time.  This is the order the reference loop and the blocked
        kernels share.
    """
    steps = []
    for k in range(min(m - 1, n)):
        for j in range(k + 1, m):
            steps.append((k, j, k))
    return steps


def sameh_kuck_schedule(m: int, n: int):
    """Sameh–Kuck parallel pairing schedule [Sameh & Kuck, JACM 1978].

    Entry ``(r, c)`` is annihilated against the *adjacent* row ``r - 1`` at
    stage ``(m - 1 - r) + 2 c``; all rotations within a stage touch
    disjoint row pairs, so a spatial array of rotators (or a wide vector
    unit) executes each stage fully in parallel.

    Returns
    -------
    list[list[(int, int, int)]]
        One inner list of ``(pivot_row, target_row, col)`` triples per
        stage.  Flatten (``sum(stages, [])``) for engines that consume a
        sequential order — within-stage rotations commute, so any
        flattening of the stage order gives identical results.
    """
    stages: dict[int, list] = {}
    for c in range(min(m - 1, n)):
        for r in range(m - 1, c, -1):
            stages.setdefault((m - 1 - r) + 2 * c, []).append((r - 1, r, c))
    return [stages[t] for t in sorted(stages)]


def _split_qr(out, m, n, compute_q):
    """Split a decoded working matrix [R' | Qt] and force R's structure."""
    R = out[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, 0.0, R)
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(out[..., n:], -1, -2)
    return Q, R


# --------------------------------------------------------------------------
# Paper backend: the CORDIC unit over packed words, rows augmented with I.
# --------------------------------------------------------------------------
def _augment(A, compute_q):
    """Append the identity columns: rows of e = n + m elements (or e = n)."""
    if not compute_q:
        return A
    m = A.shape[-2]
    eye = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float64), A.shape[:-1] + (m,))
    return jnp.concatenate([A, eye], axis=-1)


def qr_cordic(A, unit: GivensUnit, N=None, iters=None, compute_q=True,
              steps=None):
    """QRD of a batch of matrices with the paper's unit (reference loop).

    One `GivensUnit.rotate_rows` launch per schedule step: every step
    round-trips the two packed rows through host-level ops — the behavior
    the kernel-resident `qr_cordic_pallas` eliminates while staying
    bit-identical.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (converted to float64).
    unit : GivensUnit
        The configured rotator (IEEE or HUB datapath).
    N, iters : optional traced scalars
        Override the config's significand width / CORDIC depth (used by the
        paper's Fig. 9 sweeps); None takes the config defaults.
    compute_q : bool
        Augment the rows with the identity to accumulate Q^T (the paper's
        setup; the 1.0 entries enter the unit as data).
    steps : sequence[(int, int, int)], optional
        Rotation schedule; defaults to the column-major `givens_schedule`.

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``), with R's
    structural zeros forced (the systolic array never stores them).
    """
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    work = _augment(A, compute_q)
    P = unit.encode(work)
    if steps is None:
        steps = givens_schedule(m, n)
    for (k, j, col) in steps:
        # Leading pair at `col`; rotate every remaining element of both rows.
        row_x = P[..., k, col:]
        row_y = P[..., j, col:]
        rx, ry = unit.rotate_rows(row_x, row_y, N=N, iters=iters)
        # The zeroed entry is structural in the systolic array.
        ry = ry.at[..., 0].set(0)
        P = P.at[..., k, col:].set(rx)
        P = P.at[..., j, col:].set(ry)
    # decode() maps packed-zero to +/-0.0; re-zero explicitly for cleanliness
    out = unit.decode(P)
    return _split_qr(out, m, n, compute_q)


def qr_cordic_pallas(A, unit: GivensUnit, compute_q=True, steps=None,
                     interpret=None):
    """Kernel-resident QRD: the whole triangularization in one Pallas call.

    Semantically `qr_cordic` with the Python loop moved *inside* the
    kernel: the working tile stays in VMEM across all schedule steps and
    the per-step converter dataflow runs in registers (DESIGN.md §5).
    (Q, R) are bit-identical to `qr_cordic` for the same `GivensConfig`
    (IEEE and HUB) — the kernel calls the same unit arithmetic.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (converted to float64).
    unit : GivensUnit
        The configured rotator; its frozen config is a static kernel
        parameter.
    steps : sequence[(int, int, int)], optional
        Schedule; defaults to column-major.  Pass a flattened
        `sameh_kuck_schedule` for the parallel-pairing order.
    interpret : bool, optional
        Forwarded to the kernel; None auto-selects (interpret on CPU).

    Returns
    -------
    (Q, R) : float64 arrays, bit-identical to `qr_cordic`.
    """
    from repro.kernels import ops as _kops  # deferred: core must not
    # depend on the kernels package at import time
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    P = unit.encode(_augment(A, compute_q))
    if steps is None:
        steps = givens_schedule(m, n)
    Pout = _kops.qr_packed(P, cfg=unit.cfg, steps=tuple(steps),
                           interpret=interpret)
    out = unit.decode(Pout)
    return _split_qr(out, m, n, compute_q)


def qr_blockfp_pallas(A, compute_q=True, iters=24, hub=True, frac=24,
                      steps=None, interpret=None):
    """Blocked QRD on the int32 block-fixed-point kernel (the fast path).

    The working matrix is quantized once to per-column block fixed point,
    every rotation step runs int32 inside one Pallas kernel, and a single
    decode at the end recovers floats — no per-step FP round-trips.  Not
    bit-identical to `qr_cordic` (Q30 gain, no per-step renormalization);
    accuracy is that of an F-fraction-bit fixed-point datapath per column,
    which for ``frac=24`` lands within a few dB of the packed path on
    well-scaled inputs.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices.  ``frac=24`` supports m up to ~64 (two
        CORDIC growth bits + √m column-norm growth inside int32).
    iters, hub, frac : int, bool, int
        CORDIC depth, HUB/conventional arithmetic, fraction bits.
    steps : sequence[(int, int, int)], optional
        Schedule; defaults to column-major.

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    work = _augment(A, compute_q)
    if steps is None:
        steps = givens_schedule(m, n)
    out = _kops.givens_block_apply(work, tuple(steps), iters=iters, hub=hub,
                                   frac=frac, interpret=interpret)
    return _split_qr(out, m, n, compute_q)


def qr_blocked_sharded(A, unit: GivensUnit, mesh, compute_q=True,
                       steps=None, interpret=None):
    """Batch-sharded kernel-resident QRD (the tall-skinny scaling path).

    Places the leading batch axis of ``A`` across the mesh's data axes
    (`repro.launch.sharding.shard_qrd_batch`) and runs `qr_cordic_pallas`;
    under jit the per-device kernels each triangularize their local batch
    shard — QRD is embarrassingly parallel over the batch, so no collective
    is needed until the caller combines results.

    Parameters
    ----------
    A : (batch, m, n) array_like
    mesh : jax.sharding.Mesh
        Mesh with a "model" axis and one or more data axes (see
        `repro.launch.mesh`).

    Returns
    -------
    (Q, R) with the same batch sharding as the input placement.
    """
    from repro.launch import sharding as _sh
    A = _sh.shard_qrd_batch(jnp.asarray(A, jnp.float64), mesh)
    return qr_cordic_pallas(A, unit, compute_q=compute_q, steps=steps,
                            interpret=interpret)


# --------------------------------------------------------------------------
# Float Givens baseline (the algorithm, without the paper's arithmetic).
# --------------------------------------------------------------------------
def qr_givens_float(A, dtype=jnp.float32, compute_q=True):
    """Batched QR via float Givens rotations (same schedule as the unit).

    The algorithmic baseline: identical column-major schedule and
    augmented-identity Q accumulation, but plain `dtype` floating point
    instead of the paper's arithmetic.  A: (..., m, n); returns (Q, R) in
    `dtype` (Q is None when ``compute_q=False``).
    """
    A = jnp.asarray(A, dtype)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=dtype), A.shape[:-1] + (m,))
        W = jnp.concatenate([A, eye], axis=-1)
    else:
        W = A
    for (k, j, col) in givens_schedule(m, n):
        a = W[..., k, col]
        b = W[..., j, col]
        r = jnp.sqrt(a * a + b * b)
        safe = r > 0
        c = jnp.where(safe, a / jnp.where(safe, r, 1), 1.0)
        s = jnp.where(safe, b / jnp.where(safe, r, 1), 0.0)
        rk = c[..., None] * W[..., k, :] + s[..., None] * W[..., j, :]
        rj = -s[..., None] * W[..., k, :] + c[..., None] * W[..., j, :]
        rj = rj.at[..., col].set(0)
        rk = rk.at[..., col].set(r)
        W = W.at[..., k, :].set(rk)
        W = W.at[..., j, :].set(rj)
    R = W[..., :n]
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(W[..., n:], -1, -2)
    return Q, R


def qr_jnp(A, dtype=jnp.float32):
    """LAPACK-style reference ("Matlab qr, single precision").

    A: (..., m, n); returns complete-mode (Q, R) from `jnp.linalg.qr` in
    `dtype` — the paper's comparison reference.
    """
    Q, R = jnp.linalg.qr(jnp.asarray(A, dtype), mode="complete")
    return Q, R


# --------------------------------------------------------------------------
# Fixed-point rotator of [20] (Fig. 11 comparison): inputs pre-scaled by
# 2^-scale_exp into (-1, 1), W-bit datapath, CORDIC + gain compensation.
# --------------------------------------------------------------------------
def qr_fixed(A, width=32, iters=27, scale_exp=0, compute_q=True):
    """Batched QRD in pure fixed point (W-bit, F = width-2 fraction bits).

    The Fig. 11 baseline [20]: inputs are pre-scaled by 2^-scale_exp into
    (-1, 1) and quantized RNE to the F-bit grid; the whole decomposition
    runs in int64-carried W-bit two's complement with CORDIC + gain
    compensation.  A: (..., m, n); returns float64 (Q, R).
    """
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float64), A.shape[:-1] + (m,))
        W = jnp.concatenate([A, eye], axis=-1)
    else:
        W = A
    F = width - 2
    scale = jnp.exp2(jnp.asarray(F - scale_exp, jnp.float64))
    X = jnp.rint(W * scale).astype(jnp.int64)  # RNE quantization to the grid
    itv = jnp.asarray(iters, jnp.int64)
    wv = jnp.asarray(width + 2, jnp.int64)
    for (k, j, col) in givens_schedule(m, n):
        xl, yl, flip, sig = cordic.vectoring(X[..., k, col], X[..., j, col],
                                             itv, hub=False)
        xr, yr = cordic.rotation(X[..., k, col + 1:], X[..., j, col + 1:],
                                 flip[..., None], sig[..., None], itv, hub=False)
        xl, yl = cordic.apply_gain(xl, yl, itv, wv, hub=False)
        xr, yr = cordic.apply_gain(xr, yr, itv, wv, hub=False)
        X = X.at[..., k, col].set(xl)
        X = X.at[..., j, col].set(0)
        X = X.at[..., k, col + 1:].set(xr)
        X = X.at[..., j, col + 1:].set(yr)
    out = X.astype(jnp.float64) / scale
    R = out[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, 0.0, R)
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(out[..., n:], -1, -2)
    return Q, R


# --------------------------------------------------------------------------
# Engine facade + error metric
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QRDEngine:
    """Backend-selectable batched QRD (the framework-facing API).

    Parameters
    ----------
    backend : str
        One of ``'jnp'`` (LAPACK reference), ``'givens_float'`` (float
        Givens baseline), ``'cordic'`` (bit-accurate unit, reference
        loop), ``'cordic_pallas'`` (same unit, kernel-resident — (Q, R)
        bit-identical to ``'cordic'``), ``'blockfp_pallas'`` (int32
        block-fixed-point blocked kernel), ``'fixed'`` (32-bit fixed-point
        rotator of [20]).
    givens_config : GivensConfig
        Unit parameters for the ``'cordic'`` / ``'cordic_pallas'``
        backends; ``'blockfp_pallas'`` uses its ``hub`` flag and resolved
        iteration count.
    schedule : str
        ``'col'`` (column-major) or ``'sameh_kuck'`` (parallel pairing,
        flattened) — applies to the cordic-family and blockfp backends.
    fixed_width, fixed_iters, fixed_scale_exp : int
        Parameters of the ``'fixed'`` baseline.

    Call with ``engine(A, compute_q=...)`` where ``A`` is ``(..., m, n)``;
    returns ``(Q, R)`` float arrays (Q is None when ``compute_q=False``).
    """

    backend: str = "jnp"
    givens_config: GivensConfig = dataclasses.field(default_factory=GivensConfig)
    schedule: str = "col"
    fixed_width: int = 32
    fixed_iters: int = 27
    fixed_scale_exp: int = 0

    def __post_init__(self):
        self._unit = (GivensUnit(self.givens_config)
                      if self.backend in ("cordic", "cordic_pallas") else None)

    def _steps(self, m, n):
        if self.schedule == "col":
            return None  # backends default to givens_schedule(m, n)
        if self.schedule == "sameh_kuck":
            return tuple(s for stage in sameh_kuck_schedule(m, n)
                         for s in stage)
        raise ValueError(f"unknown schedule {self.schedule!r}")

    def __call__(self, A, compute_q=True):
        A = jnp.asarray(A)
        m, n = A.shape[-2], A.shape[-1]
        if self.backend == "cordic":
            return qr_cordic(A, self._unit, compute_q=compute_q,
                             steps=self._steps(m, n))
        if self.backend == "cordic_pallas":
            return qr_cordic_pallas(A, self._unit, compute_q=compute_q,
                                    steps=self._steps(m, n))
        if self.backend == "blockfp_pallas":
            cfg = self.givens_config
            return qr_blockfp_pallas(A, compute_q=compute_q, hub=cfg.hub,
                                     iters=cfg.resolved_iters(),
                                     steps=self._steps(m, n))
        if self.backend == "givens_float":
            return qr_givens_float(A, compute_q=compute_q)
        if self.backend == "jnp":
            return qr_jnp(A)
        if self.backend == "fixed":
            return qr_fixed(A, self.fixed_width, self.fixed_iters,
                            self.fixed_scale_exp, compute_q=compute_q)
        raise ValueError(f"unknown backend {self.backend!r}")


def snr_db(A, Q, R):
    """Paper's error metric: SNR of the reconstruction B = Q @ R vs A, in dB.

    Computed in double precision; mean is taken over the batch by the caller
    (the paper reports the mean SNR of 10,000 matrices).
    """
    A = jnp.asarray(A, jnp.float64)
    B = jnp.matmul(jnp.asarray(Q, jnp.float64), jnp.asarray(R, jnp.float64))
    num = jnp.sum(A * A, axis=(-2, -1))
    den = jnp.sum((A - B) ** 2, axis=(-2, -1))
    return 10.0 * jnp.log10(num / jnp.maximum(den, 1e-300))
