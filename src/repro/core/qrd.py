"""QR decomposition engines built on the Givens rotation unit.

The paper evaluates its rotator inside the pipelined QRD architecture of
[Muñoz & Hormigo, TCAS-II 2015]: an m x n input matrix is triangularized by
the column-major Givens schedule, and Q is obtained by augmenting the rows
with the identity — the exact setup behind the paper's "e = 8 elements per
row for 4x4 matrices" throughput accounting and the HUB identity-detection
feature (the 1.0 entries of I enter the unit as data).

Backends:
  'cordic'       the paper's unit, bit-accurate (GivensUnit; IEEE or HUB)
  'givens_float' float Givens rotations (algorithmic baseline, any dtype)
  'jnp'          jnp.linalg.qr (LAPACK-style "Matlab qr" reference)
  'fixed'        the 32-bit fixed-point rotator of [20] (Fig. 11 baseline)

All backends are batched over a leading batch axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic
from .givens import GivensConfig, GivensUnit

__all__ = ["qr_cordic", "qr_givens_float", "qr_jnp", "qr_fixed",
           "QRDEngine", "snr_db", "givens_schedule"]


def givens_schedule(m: int, n: int):
    """Column-major zeroing order: [(pivot_row, target_row, col), ...]."""
    steps = []
    for k in range(min(m - 1, n)):
        for j in range(k + 1, m):
            steps.append((k, j, k))
    return steps


# --------------------------------------------------------------------------
# Paper backend: the CORDIC unit over packed words, rows augmented with I.
# --------------------------------------------------------------------------
def qr_cordic(A, unit: GivensUnit, N=None, iters=None, compute_q=True):
    """QRD of a batch of matrices with the paper's unit.

    A: (..., m, n) float array.  Returns (Q, R) as float64 (decoded), with
    R's structural zeros forced (the systolic array never stores them).
    """
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float64), A.shape[:-1] + (m,))
        work = jnp.concatenate([A, eye], axis=-1)  # rows of e = n + m elements
    else:
        work = A
    P = unit.encode(work)
    for (k, j, col) in givens_schedule(m, n):
        # Leading pair at `col`; rotate every remaining element of both rows.
        row_x = P[..., k, col:]
        row_y = P[..., j, col:]
        rx, ry = unit.rotate_rows(row_x, row_y, N=N, iters=iters)
        # The zeroed entry is structural in the systolic array.
        ry = ry.at[..., 0].set(0)
        P = P.at[..., k, col:].set(rx)
        P = P.at[..., j, col:].set(ry)
    out = unit.decode(P)
    # decode() maps packed-zero to +/-0.0; re-zero explicitly for cleanliness
    R = out[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, 0.0, R)
    if not compute_q:
        return None, R
    Qt = out[..., n:]
    Q = jnp.swapaxes(Qt, -1, -2)
    return Q, R


# --------------------------------------------------------------------------
# Float Givens baseline (the algorithm, without the paper's arithmetic).
# --------------------------------------------------------------------------
def qr_givens_float(A, dtype=jnp.float32, compute_q=True):
    """Batched QR via float Givens rotations (same schedule as the unit)."""
    A = jnp.asarray(A, dtype)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=dtype), A.shape[:-1] + (m,))
        W = jnp.concatenate([A, eye], axis=-1)
    else:
        W = A
    for (k, j, col) in givens_schedule(m, n):
        a = W[..., k, col]
        b = W[..., j, col]
        r = jnp.sqrt(a * a + b * b)
        safe = r > 0
        c = jnp.where(safe, a / jnp.where(safe, r, 1), 1.0)
        s = jnp.where(safe, b / jnp.where(safe, r, 1), 0.0)
        rk = c[..., None] * W[..., k, :] + s[..., None] * W[..., j, :]
        rj = -s[..., None] * W[..., k, :] + c[..., None] * W[..., j, :]
        rj = rj.at[..., col].set(0)
        rk = rk.at[..., col].set(r)
        W = W.at[..., k, :].set(rk)
        W = W.at[..., j, :].set(rj)
    R = W[..., :n]
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(W[..., n:], -1, -2)
    return Q, R


def qr_jnp(A, dtype=jnp.float32):
    """Reference ("Matlab qr, single precision"): jnp.linalg.qr."""
    Q, R = jnp.linalg.qr(jnp.asarray(A, dtype), mode="complete")
    return Q, R


# --------------------------------------------------------------------------
# Fixed-point rotator of [20] (Fig. 11 comparison): inputs pre-scaled by
# 2^-scale_exp into (-1, 1), W-bit datapath, CORDIC + gain compensation.
# --------------------------------------------------------------------------
def qr_fixed(A, width=32, iters=27, scale_exp=0, compute_q=True):
    """Batched QRD in pure fixed point (W-bit, F = width-2 fraction bits)."""
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float64), A.shape[:-1] + (m,))
        W = jnp.concatenate([A, eye], axis=-1)
    else:
        W = A
    F = width - 2
    scale = jnp.exp2(jnp.asarray(F - scale_exp, jnp.float64))
    X = jnp.rint(W * scale).astype(jnp.int64)  # RNE quantization to the grid
    itv = jnp.asarray(iters, jnp.int64)
    wv = jnp.asarray(width + 2, jnp.int64)
    for (k, j, col) in givens_schedule(m, n):
        xl, yl, flip, sig = cordic.vectoring(X[..., k, col], X[..., j, col],
                                             itv, hub=False)
        xr, yr = cordic.rotation(X[..., k, col + 1:], X[..., j, col + 1:],
                                 flip[..., None], sig[..., None], itv, hub=False)
        xl, yl = cordic.apply_gain(xl, yl, itv, wv, hub=False)
        xr, yr = cordic.apply_gain(xr, yr, itv, wv, hub=False)
        X = X.at[..., k, col].set(xl)
        X = X.at[..., j, col].set(0)
        X = X.at[..., k, col + 1:].set(xr)
        X = X.at[..., j, col + 1:].set(yr)
    out = X.astype(jnp.float64) / scale
    R = out[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, 0.0, R)
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(out[..., n:], -1, -2)
    return Q, R


# --------------------------------------------------------------------------
# Engine facade + error metric
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QRDEngine:
    """Backend-selectable batched QRD (the framework-facing API)."""

    backend: str = "jnp"
    givens_config: GivensConfig = dataclasses.field(default_factory=GivensConfig)
    fixed_width: int = 32
    fixed_iters: int = 27
    fixed_scale_exp: int = 0

    def __post_init__(self):
        self._unit = (GivensUnit(self.givens_config)
                      if self.backend == "cordic" else None)

    def __call__(self, A, compute_q=True):
        if self.backend == "cordic":
            return qr_cordic(A, self._unit, compute_q=compute_q)
        if self.backend == "givens_float":
            return qr_givens_float(A, compute_q=compute_q)
        if self.backend == "jnp":
            return qr_jnp(A)
        if self.backend == "fixed":
            return qr_fixed(A, self.fixed_width, self.fixed_iters,
                            self.fixed_scale_exp, compute_q=compute_q)
        raise ValueError(f"unknown backend {self.backend!r}")


def snr_db(A, Q, R):
    """Paper's error metric: SNR of the reconstruction B = Q @ R vs A, in dB.

    Computed in double precision; mean is taken over the batch by the caller
    (the paper reports the mean SNR of 10,000 matrices).
    """
    A = jnp.asarray(A, jnp.float64)
    B = jnp.matmul(jnp.asarray(Q, jnp.float64), jnp.asarray(R, jnp.float64))
    num = jnp.sum(A * A, axis=(-2, -1))
    den = jnp.sum((A - B) ** 2, axis=(-2, -1))
    return 10.0 * jnp.log10(num / jnp.maximum(den, 1e-300))
