"""QR decomposition engines built on the Givens rotation unit.

The paper evaluates its rotator inside the pipelined QRD architecture of
[Muñoz & Hormigo, TCAS-II 2015]: an m x n input matrix is triangularized by
the column-major Givens schedule, and Q is obtained by augmenting the rows
with the identity — the exact setup behind the paper's "e = 8 elements per
row for 4x4 matrices" throughput accounting and the HUB identity-detection
feature (the 1.0 entries of I enter the unit as data).

Backends:
  'cordic'        the paper's unit, bit-accurate (GivensUnit; IEEE or HUB)
  'cordic_pallas' the same unit, kernel-resident: the whole triangularization
                  runs inside one Pallas kernel (DESIGN.md §5), bit-identical
                  to 'cordic'
  'blockfp_pallas' int32 block-fixed-point blocked kernel: quantize once,
                  rotate everything fixed-point in VMEM, decode once (the
                  TPU-compilable fast path; not bit-identical to 'cordic')
  'givens_float'  float Givens rotations (algorithmic baseline, any dtype)
  'jnp'           jnp.linalg.qr (LAPACK-style "Matlab qr" reference)
  'fixed'         the 32-bit fixed-point rotator of [20] (Fig. 11 baseline)

All backends are batched over a leading batch axis.  Schedules: the default
column-major order, or the Sameh–Kuck parallel pairing
(`sameh_kuck_schedule`) whose stages rotate disjoint row pairs — the order a
spatial/multi-unit implementation would use.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp

from . import cordic
from .givens import GivensConfig, GivensUnit

__all__ = ["qr_cordic", "qr_cordic_pallas", "qr_blockfp_pallas",
           "qr_cordic_panel", "qr_blockfp_panel",
           "qr_cordic_wavefront", "qr_blockfp_wavefront",
           "qr_cordic_complex", "qr_cordic_complex_pallas",
           "qr_cordic_complex_wavefront",
           "qr_givens_float", "qr_jnp", "qr_fixed", "qr_blocked_sharded",
           "QRDEngine", "snr_db", "givens_schedule", "sameh_kuck_schedule"]

#: Bound on the host-side schedule memoization.  Schedules are derived
#: per *tile* (the tiled layer never asks for a full tall-skinny m ~ 10k
#: schedule — that would be a multi-MB tuple per shape), so a small LRU
#: covers every shape a process realistically touches while capping
#: worst-case host memory (DESIGN.md §14).
SCHEDULE_CACHE_SIZE = 128


@lru_cache(maxsize=SCHEDULE_CACHE_SIZE)
def givens_schedule(m: int, n: int):
    """Column-major zeroing order for an m x n matrix (memoized).

    Returns
    -------
    tuple[(int, int, int), ...]
        ``(pivot_row, target_row, col)`` triples: entry ``(target_row,
        col)`` is annihilated against the diagonal row ``col``, one column
        at a time.  This is the order the reference loop and the blocked
        kernels share.  The tuple is hashable (a jit static) and cached
        per ``(m, n)``, so repeated engine calls reuse one object.
    """
    return tuple((k, j, k)
                 for k in range(min(m - 1, n))
                 for j in range(k + 1, m))


@lru_cache(maxsize=SCHEDULE_CACHE_SIZE)
def sameh_kuck_schedule(m: int, n: int):
    """Sameh–Kuck parallel pairing schedule [Sameh & Kuck, JACM 1978].

    Entry ``(r, c)`` is annihilated against the *adjacent* row ``r - 1`` at
    stage ``(m - 1 - r) + 2 c``; all rotations within a stage touch
    disjoint row pairs, so a spatial array of rotators (or the wavefront
    kernels' pair axis, DESIGN.md §8) executes each stage fully in
    parallel.  The stage count is ``min(m + n - 2, 2 m - 3)`` — the
    sequential depth of the wavefront path, vs ``len(givens_schedule)``
    dependent rotations for the step-serial path.

    Returns
    -------
    tuple[tuple[(int, int, int), ...], ...]
        One inner tuple of ``(pivot_row, target_row, col)`` triples per
        stage (hashable — usable as a jit static; memoized per
        ``(m, n)``).  Flatten for engines that consume a sequential order
        — within-stage rotations commute, so any flattening of the stage
        order gives identical results.
    """
    stages: dict[int, list] = {}
    for c in range(min(m - 1, n)):
        for r in range(m - 1, c, -1):
            stages.setdefault((m - 1 - r) + 2 * c, []).append((r - 1, r, c))
    return tuple(tuple(stages[t]) for t in sorted(stages))


def _split_qr(out, m, n, compute_q):
    """Split a decoded working matrix [R' | Qt] and force R's structure."""
    R = out[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, 0.0, R)
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(out[..., n:], -1, -2)
    return Q, R


# --------------------------------------------------------------------------
# Paper backend: the CORDIC unit over packed words, rows augmented with I.
# --------------------------------------------------------------------------
def _augment(A, compute_q):
    """Append the identity columns: rows of e = n + m elements (or e = n)."""
    if not compute_q:
        return A
    m = A.shape[-2]
    eye = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float64), A.shape[:-1] + (m,))
    return jnp.concatenate([A, eye], axis=-1)


def qr_cordic(A, unit: GivensUnit, N=None, iters=None, compute_q=True,
              steps=None):
    """QRD of a batch of matrices with the paper's unit (reference loop).

    One `GivensUnit.rotate_rows` launch per schedule step: every step
    round-trips the two packed rows through host-level ops — the behavior
    the kernel-resident `qr_cordic_pallas` eliminates while staying
    bit-identical.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (converted to float64).
    unit : GivensUnit
        The configured rotator (IEEE or HUB datapath).
    N, iters : optional traced scalars
        Override the config's significand width / CORDIC depth (used by the
        paper's Fig. 9 sweeps); None takes the config defaults.
    compute_q : bool
        Augment the rows with the identity to accumulate Q^T (the paper's
        setup; the 1.0 entries enter the unit as data).
    steps : sequence[(int, int, int)], optional
        Rotation schedule; defaults to the column-major `givens_schedule`.

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``), with R's
    structural zeros forced (the systolic array never stores them).
    """
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    work = _augment(A, compute_q)
    P = unit.encode(work)
    if steps is None:
        steps = givens_schedule(m, n)
    for (k, j, col) in steps:
        # Leading pair at `col`; rotate every remaining element of both rows.
        row_x = P[..., k, col:]
        row_y = P[..., j, col:]
        rx, ry = unit.rotate_rows(row_x, row_y, N=N, iters=iters)
        # The zeroed entry is structural in the systolic array.
        ry = ry.at[..., 0].set(0)
        P = P.at[..., k, col:].set(rx)
        P = P.at[..., j, col:].set(ry)
    # decode() maps packed-zero to +/-0.0; re-zero explicitly for cleanliness
    out = unit.decode(P)
    return _split_qr(out, m, n, compute_q)


def qr_cordic_pallas(A, unit: GivensUnit, compute_q=True, steps=None,
                     interpret=None, tile_b=None):
    """Kernel-resident QRD: the whole triangularization in one Pallas call.

    Semantically `qr_cordic` with the Python loop moved *inside* the
    kernel: the working tile stays in VMEM across all schedule steps and
    the per-step converter dataflow runs in registers (DESIGN.md §5).
    (Q, R) are bit-identical to `qr_cordic` for the same `GivensConfig`
    (IEEE and HUB) — the kernel calls the same unit arithmetic.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (converted to float64).
    unit : GivensUnit
        The configured rotator; its frozen config is a static kernel
        parameter.
    steps : sequence[(int, int, int)], optional
        Schedule; defaults to column-major.  Pass a flattened
        `sameh_kuck_schedule` for the parallel-pairing order.
    interpret : bool, optional
        Forwarded to the kernel; None auto-selects (interpret on CPU).
    tile_b : int, optional
        Batch tile of the blocked kernel; None takes the default
        (``TILE_B``, or the engine's autotuned value when dispatched
        through `repro.qrd.QRDEngine`).

    Returns
    -------
    (Q, R) : float64 arrays, bit-identical to `qr_cordic`.
    """
    from repro.kernels import ops as _kops  # deferred: core must not
    # depend on the kernels package at import time
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    P = unit.encode(_augment(A, compute_q))
    if steps is None:
        steps = givens_schedule(m, n)
    Pout = _kops.qr_packed(P, cfg=unit.cfg, steps=tuple(steps),
                           interpret=interpret, tile_b=tile_b)
    out = unit.decode(Pout)
    return _split_qr(out, m, n, compute_q)


def qr_blockfp_pallas(A, compute_q=True, iters=24, hub=True, frac=24,
                      steps=None, interpret=None, tile_b=None):
    """Blocked QRD on the int32 block-fixed-point kernel (the fast path).

    The working matrix is quantized once to per-column block fixed point,
    every rotation step runs int32 inside one Pallas kernel, and a single
    decode at the end recovers floats — no per-step FP round-trips.  Not
    bit-identical to `qr_cordic` (Q30 gain, no per-step renormalization);
    accuracy is that of an F-fraction-bit fixed-point datapath per column,
    which for ``frac=24`` lands within a few dB of the packed path on
    well-scaled inputs.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices.  ``frac=24`` supports m up to ~64 (two
        CORDIC growth bits + √m column-norm growth inside int32).
    iters, hub, frac : int, bool, int
        CORDIC depth, HUB/conventional arithmetic, fraction bits.
    steps : sequence[(int, int, int)], optional
        Schedule; defaults to column-major.

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    work = _augment(A, compute_q)
    if steps is None:
        steps = givens_schedule(m, n)
    out = _kops.givens_block_apply(work, tuple(steps), iters=iters, hub=hub,
                                   frac=frac, interpret=interpret,
                                   tile_b=tile_b)
    return _split_qr(out, m, n, compute_q)


def qr_cordic_panel(A, unit: GivensUnit, compute_q=True, panel_n=8,
                    interpret=None, tile_b=None):
    """Tiled panel QRD over packed words: production m at kernel speed.

    The scaling form of `qr_cordic_pallas` (DESIGN.md §14): the flat
    kernel unrolls the whole schedule into one straight-line body, which
    stops tracing beyond toy m; here the triangularization proceeds
    panel by panel with the rotation control words exported from each
    panel factorization and replayed over the trailing panels
    (`ops.qr_packed_panel`).  Column-major order is preserved exactly,
    so (Q, R) are **bit-identical** to `qr_cordic` / `qr_cordic_pallas`
    with the default schedule (IEEE and HUB).

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (converted to float64).
    unit : GivensUnit
        The configured rotator.
    panel_n : int
        Panel width (autotuner dimension).

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    P = unit.encode(_augment(A, compute_q))
    Pout = _kops.qr_packed_panel(P, cfg=unit.cfg, n_cols=n, panel_n=panel_n,
                                 interpret=interpret, tile_b=tile_b)
    out = unit.decode(Pout)
    return _split_qr(out, m, n, compute_q)


def qr_blockfp_panel(A, compute_q=True, iters=24, hub=True, frac=24,
                     panel_n=8, interpret=None, tile_b=None):
    """Tiled panel QRD on the int32 block-FP datapath (the fast path).

    The scaling form of `qr_blockfp_pallas`: quantize once, sweep the
    panels with exported/replayed control words, decode once
    (`ops.givens_block_apply_panel`).  Bit-identical to
    `qr_blockfp_pallas` with the default schedule.  ``frac=24`` supports
    m ≤ 128 (2 CORDIC growth bits + √m column-norm growth inside int32).

    Parameters as `qr_blockfp_pallas` plus ``panel_n`` (panel width).

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    work = _augment(A, compute_q)
    out = _kops.givens_block_apply_panel(work, n_cols=n, iters=iters, hub=hub,
                                         frac=frac, panel_n=panel_n,
                                         interpret=interpret, tile_b=tile_b)
    return _split_qr(out, m, n, compute_q)


# --------------------------------------------------------------------------
# Complex datapath: three-rotation Givens on (re, im) lane pairs (§10).
# --------------------------------------------------------------------------
def _augment_complex(A, compute_q):
    """Append the (real) identity columns to a complex working matrix."""
    if not compute_q:
        return A
    m = A.shape[-2]
    eye = jnp.broadcast_to(jnp.eye(m, dtype=A.dtype), A.shape[:-1] + (m,))
    return jnp.concatenate([A, eye], axis=-1)


def _encode_complex(unit, C):
    """complex (..., m, e) -> packed (..., m, e, 2) re/im lane pairs."""
    return jnp.stack([unit.encode(C.real), unit.encode(C.imag)], axis=-1)


def _decode_complex(unit, P):
    """packed (..., m, e, 2) -> complex128 (..., m, e)."""
    out = unit.decode(P)
    return jax.lax.complex(out[..., 0], out[..., 1])


def _split_qr_complex(C, m, n, compute_q):
    """Split a decoded complex working matrix [R' | G] into (Q, R).

    The rotations accumulate the unitary G with ``G A = R``, so
    ``Q = G^H`` — the conjugate transpose, where the real datapath takes a
    plain transpose.
    """
    R = C[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, jnp.zeros((), R.dtype), R)
    if not compute_q:
        return None, R
    Q = jnp.conj(jnp.swapaxes(C[..., n:], -1, -2))
    return Q, R


def qr_cordic_complex(A, unit: GivensUnit, N=None, iters=None, compute_q=True,
                      steps=None):
    """Complex QRD of a batch of matrices with the paper's unit.

    The complex counterpart of `qr_cordic`: every schedule step runs the
    three-rotation decomposition (`GivensUnit.rotate_rows_complex`) — two
    vectoring phase rotations realize the leading entries, then the real
    Givens of the real datapath replays across the re and im lanes.  R
    comes out with a real non-negative diagonal (the phases are rotated
    into Q), the standard convention of complex Givens QRD hardware.
    Purely-real inputs reproduce `qr_cordic` bit for bit (the phase
    rotations skip as exact identities).

    Parameters
    ----------
    A : (..., m, n) array_like, complex
        Batch of input matrices (converted to complex128).
    unit : GivensUnit
        The configured rotator (IEEE or HUB datapath).
    N, iters : optional traced scalars
        Override the config's significand width / CORDIC depth.
    compute_q : bool
        Augment the rows with the identity to accumulate the unitary G;
        ``Q = G^H``.
    steps : sequence[(int, int, int)], optional
        Rotation schedule; defaults to the column-major `givens_schedule`.

    Returns
    -------
    (Q, R) : complex128 arrays (Q is None when ``compute_q=False``), with
    R's structural zeros forced and its diagonal exactly real.
    """
    A = jnp.asarray(A, jnp.complex128)
    m, n = A.shape[-2], A.shape[-1]
    P = _encode_complex(unit, _augment_complex(A, compute_q))
    if steps is None:
        steps = givens_schedule(m, n)
    for (k, j, col) in steps:
        rx, ry = unit.rotate_rows_complex(P[..., k, col:, :],
                                          P[..., j, col:, :], N=N, iters=iters)
        P = P.at[..., k, col:, :].set(rx)
        P = P.at[..., j, col:, :].set(ry)
    out = _decode_complex(unit, P)
    return _split_qr_complex(out, m, n, compute_q)


def qr_cordic_complex_pallas(A, unit: GivensUnit, compute_q=True, steps=None,
                             interpret=None, tile_b=None):
    """Kernel-resident complex QRD: the triangularization in one Pallas call.

    `qr_cordic_complex` with the step loop moved inside the kernel — the
    (re, im) lane pairs ride along as a trailing axis of the resident
    tile, and each step runs the same three-rotation
    `GivensUnit.rotate_rows_complex` dataflow in registers.  (Q, R) are
    bit-identical to `qr_cordic_complex` for the same `GivensConfig`.

    Parameters as `qr_cordic_complex`; ``interpret`` is forwarded to the
    kernel (None auto-selects: interpret on CPU).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.complex128)
    m, n = A.shape[-2], A.shape[-1]
    P = _encode_complex(unit, _augment_complex(A, compute_q))
    if steps is None:
        steps = givens_schedule(m, n)
    Pout = _kops.qr_packed_complex(P, cfg=unit.cfg, steps=tuple(steps),
                                   interpret=interpret, tile_b=tile_b)
    return _split_qr_complex(_decode_complex(unit, Pout), m, n, compute_q)


def qr_cordic_complex_wavefront(A, unit: GivensUnit, compute_q=True,
                                stages=None, interpret=None, tile_b=None,
                                table_layout=None):
    """Wavefront kernel-resident complex QRD (one scan step per stage).

    The stage-parallel counterpart of `qr_cordic_complex_pallas`: every
    Sameh–Kuck stage's disjoint row pairs run the three-rotation
    decomposition in one shot along the pair axis, with the (re, im)
    lanes as an extra trailing axis and the per-pair column masks of the
    real wavefront path unchanged (DESIGN.md §8, §10).  Bit-identical to
    `qr_cordic_complex` on the flattened stage schedule.

    Parameters as `qr_cordic_wavefront`.
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.complex128)
    m, n = A.shape[-2], A.shape[-1]
    P = _encode_complex(unit, _augment_complex(A, compute_q))
    Pout = _kops.qr_packed_complex_wavefront(
        P, cfg=unit.cfg, stages=_as_stages(m, n, stages), interpret=interpret,
        tile_b=tile_b, table_layout=table_layout)
    return _split_qr_complex(_decode_complex(unit, Pout), m, n, compute_q)


def _as_stages(m, n, stages):
    """Normalize a stage schedule to a hashable tuple-of-tuples static."""
    if stages is None:
        return sameh_kuck_schedule(m, n)
    return tuple(tuple(st) for st in stages)


def qr_cordic_wavefront(A, unit: GivensUnit, compute_q=True, stages=None,
                        interpret=None, tile_b=None, table_layout=None):
    """Wavefront kernel-resident QRD: one scan step per Sameh–Kuck stage.

    The stage-parallel counterpart of `qr_cordic_pallas` (DESIGN.md §8):
    all rotations of a stage — their row pairs are disjoint by construction
    — run in one shot along a (TILE_B, Pmax, e) pair axis, so the
    sequential depth collapses from ``len(steps)`` dependent rotations to
    ``len(stages)`` scan iterations, and the trace holds one stage body
    instead of the whole unrolled schedule.  (Q, R) are bit-identical to
    `qr_cordic` on the flattened stage schedule (same `GivensUnit`
    arithmetic; within-stage rotations commute).

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (converted to float64).
    unit : GivensUnit
        The configured rotator; its frozen config is a static kernel
        parameter.
    stages : sequence[sequence[(int, int, int)]], optional
        Stage schedule; defaults to ``sameh_kuck_schedule(m, n)``.  Every
        inner sequence's row pairs must be disjoint.

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    P = unit.encode(_augment(A, compute_q))
    Pout = _kops.qr_packed_wavefront(P, cfg=unit.cfg,
                                     stages=_as_stages(m, n, stages),
                                     interpret=interpret, tile_b=tile_b,
                                     table_layout=table_layout)
    out = unit.decode(Pout)
    return _split_qr(out, m, n, compute_q)


def qr_blockfp_wavefront(A, compute_q=True, iters=24, hub=True, frac=24,
                         stages=None, interpret=None, tile_b=None,
                         table_layout=None):
    """Wavefront blocked QRD on the int32 block-FP kernel (fastest path).

    `qr_blockfp_pallas` with the step-serial schedule replaced by the
    Sameh–Kuck stage tables: quantize once, rotate every stage's disjoint
    row pairs in one shot, decode once (DESIGN.md §8).  Bit-identical to
    `qr_blockfp_pallas` on the flattened stage schedule; accuracy is that
    of the F-fraction-bit block-FP datapath, as for the sequential path.

    Parameters
    ----------
    A : (..., m, n) array_like
        Batch of input matrices (``frac=24`` supports m up to ~64).
    stages : sequence[sequence[(int, int, int)]], optional
        Stage schedule; defaults to ``sameh_kuck_schedule(m, n)``.

    Returns
    -------
    (Q, R) : float64 arrays (Q is None when ``compute_q=False``).
    """
    from repro.kernels import ops as _kops
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    work = _augment(A, compute_q)
    out = _kops.givens_block_apply_wavefront(
        work, _as_stages(m, n, stages), iters=iters, hub=hub, frac=frac,
        interpret=interpret, tile_b=tile_b, table_layout=table_layout)
    return _split_qr(out, m, n, compute_q)


def qr_blocked_sharded(A, unit: GivensUnit, mesh, compute_q=True,
                       steps=None, interpret=None, schedule="col"):
    """Batch-sharded kernel-resident QRD (the tall-skinny scaling path).

    Legacy shim: since the API redesign (DESIGN.md §9) this is plain
    engine dispatch with a mesh-carrying config —
    ``repro.qrd.QRDEngine(backend='cordic_pallas', mesh=mesh)(A)`` — which
    places the leading batch axis of ``A`` across the mesh's data axes
    (`repro.launch.sharding.shard_qrd_batch`) and runs the kernel-resident
    QRD; under jit the per-device kernels each triangularize their local
    batch shard — QRD is embarrassingly parallel over the batch, so no
    collective is needed until the caller combines results.

    Parameters
    ----------
    A : (batch, m, n) array_like
    mesh : jax.sharding.Mesh
        Mesh with a "model" axis and one or more data axes (see
        `repro.launch.mesh`).
    schedule : str
        ``'col'`` runs the step-serial `qr_cordic_pallas`;
        ``'sameh_kuck'`` runs the wavefront `qr_cordic_wavefront` — each
        device's kernel rotates whole stages at once, and the stage index
        tables are replicated across the mesh
        (`repro.launch.sharding.qrd_stage_table_spec`).
    steps : tuple, optional
        Explicit step-serial schedule override (not expressible as an
        engine config; runs the direct sharded path).

    Returns
    -------
    (Q, R) with the same batch sharding as the input placement.
    """
    if schedule not in ("col", "sameh_kuck"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if steps is not None:
        if schedule == "sameh_kuck":
            raise ValueError("steps= is the step-serial schedule; the "
                             "wavefront path takes stage schedules — call "
                             "qr_cordic_wavefront(stages=...) directly")
        from repro.launch import sharding as _sh
        A = _sh.shard_qrd_batch(jnp.asarray(A, jnp.float64), mesh)
        return qr_cordic_pallas(A, unit, compute_q=compute_q, steps=steps,
                                interpret=interpret)
    from repro import qrd as _api
    cfg = _api.QRDConfig(backend="cordic_pallas", schedule=schedule,
                         givens=unit.cfg, interpret=interpret, mesh=mesh)
    return _shared_engine()._dispatch(A, compute_q, cfg)


def _shared_engine():
    """Module-level dispatch host for the legacy free-function shims.

    One bounded jitted-callable LRU shared by all legacy calls; the
    per-call config (including its mesh, keyed by identity) selects the
    actual backend.
    """
    global _SHARED_ENGINE
    if _SHARED_ENGINE is None:
        from repro import qrd as _api
        _SHARED_ENGINE = _api.QRDEngine()
    return _SHARED_ENGINE


_SHARED_ENGINE = None


# --------------------------------------------------------------------------
# Float Givens baseline (the algorithm, without the paper's arithmetic).
# --------------------------------------------------------------------------
def qr_givens_float(A, dtype=jnp.float32, compute_q=True):
    """Batched QR via float Givens rotations (same schedule as the unit).

    The algorithmic baseline: identical column-major schedule and
    augmented-identity Q accumulation, but plain `dtype` floating point
    instead of the paper's arithmetic.  Complex dtypes use the conjugate
    Givens rotation ``G = [[ā, b̄], [-b, a]] / r`` with ``r = √(|a|²+|b|²)``
    — unitary, annihilates b, and reduces exactly to the real rotation
    when the inputs are real (conjugation is the identity there, so the
    real path is unchanged bit for bit).  A: (..., m, n); returns (Q, R)
    in `dtype` (Q is None when ``compute_q=False``); for complex dtypes
    ``Q = G^H`` takes the conjugate transpose and R's diagonal is real
    non-negative.
    """
    dtype = jnp.dtype(dtype)
    A = jnp.asarray(A, dtype)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=dtype), A.shape[:-1] + (m,))
        W = jnp.concatenate([A, eye], axis=-1)
    else:
        W = A
    for (k, j, col) in givens_schedule(m, n):
        a = W[..., k, col]
        b = W[..., j, col]
        r = jnp.sqrt(jnp.abs(a) ** 2 + jnp.abs(b) ** 2)
        safe = r > 0
        rs = jnp.where(safe, r, 1).astype(dtype)
        c = jnp.where(safe, jnp.conj(a) / rs, 1.0).astype(dtype)
        s = jnp.where(safe, jnp.conj(b) / rs, 0.0).astype(dtype)
        rk = c[..., None] * W[..., k, :] + s[..., None] * W[..., j, :]
        rj = (-jnp.conj(s)[..., None] * W[..., k, :]
              + jnp.conj(c)[..., None] * W[..., j, :])
        rj = rj.at[..., col].set(0)
        rk = rk.at[..., col].set(r.astype(dtype))
        W = W.at[..., k, :].set(rk)
        W = W.at[..., j, :].set(rj)
    R = W[..., :n]
    if not compute_q:
        return None, R
    Q = jnp.conj(jnp.swapaxes(W[..., n:], -1, -2))
    return Q, R


def qr_jnp(A, dtype=jnp.float32, compute_q=True):
    """LAPACK-style reference ("Matlab qr, single precision").

    A: (..., m, n); returns complete-mode (Q, R) from `jnp.linalg.qr` in
    `dtype` — the paper's comparison reference.  ``compute_q=False``
    returns ``(None, R)`` like every other backend (the registry exposes
    one uniform backend signature); under jit XLA dead-code-eliminates
    the unused Q factor.
    """
    Q, R = jnp.linalg.qr(jnp.asarray(A, dtype), mode="complete")
    return (Q if compute_q else None), R


# --------------------------------------------------------------------------
# Fixed-point rotator of [20] (Fig. 11 comparison): inputs pre-scaled by
# 2^-scale_exp into (-1, 1), W-bit datapath, CORDIC + gain compensation.
# --------------------------------------------------------------------------
def qr_fixed(A, width=32, iters=27, scale_exp=0, compute_q=True):
    """Batched QRD in pure fixed point (W-bit, F = width-2 fraction bits).

    The Fig. 11 baseline [20]: inputs are pre-scaled by 2^-scale_exp into
    (-1, 1) and quantized RNE to the F-bit grid; the whole decomposition
    runs in int64-carried W-bit two's complement with CORDIC + gain
    compensation.  A: (..., m, n); returns float64 (Q, R).
    """
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    if compute_q:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=jnp.float64), A.shape[:-1] + (m,))
        W = jnp.concatenate([A, eye], axis=-1)
    else:
        W = A
    F = width - 2
    scale = jnp.exp2(jnp.asarray(F - scale_exp, jnp.float64))
    X = jnp.rint(W * scale).astype(jnp.int64)  # RNE quantization to the grid
    itv = jnp.asarray(iters, jnp.int64)
    wv = jnp.asarray(width + 2, jnp.int64)
    for (k, j, col) in givens_schedule(m, n):
        xl, yl, flip, sig = cordic.vectoring(X[..., k, col], X[..., j, col],
                                             itv, hub=False)
        xr, yr = cordic.rotation(X[..., k, col + 1:], X[..., j, col + 1:],
                                 flip[..., None], sig[..., None], itv, hub=False)
        xl, yl = cordic.apply_gain(xl, yl, itv, wv, hub=False)
        xr, yr = cordic.apply_gain(xr, yr, itv, wv, hub=False)
        X = X.at[..., k, col].set(xl)
        X = X.at[..., j, col].set(0)
        X = X.at[..., k, col + 1:].set(xr)
        X = X.at[..., j, col + 1:].set(yr)
    out = X.astype(jnp.float64) / scale
    R = out[..., :n]
    tri = jnp.tril(jnp.ones((m, n), bool), -1)
    R = jnp.where(tri, 0.0, R)
    if not compute_q:
        return None, R
    Q = jnp.swapaxes(out[..., n:], -1, -2)
    return Q, R


# --------------------------------------------------------------------------
# Engine facade + error metric
# --------------------------------------------------------------------------
@dataclasses.dataclass
class QRDEngine:
    """Backend-selectable batched QRD — legacy shim over `repro.qrd`.

    Since the API redesign (DESIGN.md §9) this dataclass is a thin facade
    over the registry-dispatched `repro.qrd.QRDEngine`: construction
    validates the backend/schedule against the registry, and every call
    rebuilds a `repro.qrd.QRDConfig` from the (mutable) fields, so field
    mutation between calls misses the jitted-callable cache rather than
    returning stale results.  New code should use `repro.qrd.QRDEngine`
    directly — it adds ``solve()`` (batched least squares), ``rls()``
    (streaming QRD-RLS) and mesh-sharded dispatch.

    Parameters
    ----------
    backend : str
        Any registered backend (`repro.qrd.available_backends()`); the
        built-ins are ``'jnp'`` (LAPACK reference), ``'givens_float'``
        (float Givens baseline), ``'cordic'`` (bit-accurate unit,
        reference loop), ``'cordic_pallas'`` (same unit, kernel-resident —
        (Q, R) bit-identical to ``'cordic'``), ``'blockfp_pallas'`` (int32
        block-fixed-point blocked kernel), ``'fixed'`` (32-bit fixed-point
        rotator of [20]).
    givens_config : GivensConfig
        Unit parameters for the ``'cordic'`` / ``'cordic_pallas'``
        backends; ``'blockfp_pallas'`` uses its ``hub`` flag and resolved
        iteration count.
    schedule : str
        ``'col'`` (column-major) or ``'sameh_kuck'`` (parallel pairing).
        With ``'sameh_kuck'`` the Pallas backends route onto the
        **wavefront datapath** (DESIGN.md §8); the ``'cordic'`` loop
        consumes the flattened stage order.
    fixed_width, fixed_iters, fixed_scale_exp : int
        Parameters of the ``'fixed'`` baseline.

    Call with ``engine(A, compute_q=...)`` where ``A`` is ``(..., m, n)``;
    returns ``(Q, R)`` float arrays (Q is None when ``compute_q=False``).
    The engine memoizes one jitted callable per ``(m, n, compute_q,
    config)`` in a *bounded* LRU (`repro.qrd.QRDEngine`), so churning
    many shapes evicts cold callables instead of growing without bound.
    """

    backend: str = "jnp"
    givens_config: GivensConfig = dataclasses.field(default_factory=GivensConfig)
    schedule: str = "col"
    fixed_width: int = 32
    fixed_iters: int = 27
    fixed_scale_exp: int = 0

    _BACKENDS = ("jnp", "givens_float", "cordic", "cordic_pallas",
                 "blockfp_pallas", "fixed")

    def _to_config(self):
        from repro import qrd as _api
        return _api.QRDConfig(backend=self.backend, schedule=self.schedule,
                              givens=self.givens_config,
                              fixed_width=self.fixed_width,
                              fixed_iters=self.fixed_iters,
                              fixed_scale_exp=self.fixed_scale_exp)

    def __post_init__(self):
        # fail at construction, not first call: bad backend/schedule names
        # and invalid unit configs should not surface deep inside a run
        from repro import qrd as _api
        self._engine = _api.QRDEngine(self._to_config())

    @property
    def _fn_cache(self):
        """The underlying bounded jitted-callable LRU (tests poke this)."""
        return self._engine._fn_cache

    def __call__(self, A, compute_q=True):
        return self._engine._dispatch(A, compute_q, self._to_config())

    def solve(self, A, b, return_residuals=False):
        """Batched least squares — see `repro.qrd.QRDEngine.solve`."""
        eng = self._engine
        eng.config = self._to_config()
        return eng.solve(A, b, return_residuals=return_residuals)

    def rls(self, n, lam=0.99, delta=1e-3, block=None):
        """Streaming QRD-RLS state — see `repro.qrd.QRDEngine.rls`."""
        eng = self._engine
        eng.config = self._to_config()
        return eng.rls(n, lam=lam, delta=delta, block=block)


def snr_db(A, Q, R):
    """Paper's error metric: SNR of the reconstruction B = Q @ R vs A, in dB.

    Computed in double precision; mean is taken over the batch by the caller
    (the paper reports the mean SNR of 10,000 matrices).
    """
    A = jnp.asarray(A, jnp.float64)
    B = jnp.matmul(jnp.asarray(Q, jnp.float64), jnp.asarray(R, jnp.float64))
    num = jnp.sum(A * A, axis=(-2, -1))
    den = jnp.sum((A - B) ** 2, axis=(-2, -1))
    return 10.0 * jnp.log10(num / jnp.maximum(den, 1e-300))
