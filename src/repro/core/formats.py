"""Floating-point formats and packed-integer codecs.

The paper's unit operates on IEEE754-like numbers (no NaN/Inf/subnormals) and
on HUB (Half-Unit-Biased) floating-point numbers [Hormigo & Villalba, IEEE TC
2016].  Both are carried here as *packed* int64 words with the layout

        [ sign(1) | exponent(e) | mantissa(m) ]

- Conventional decode:  (-1)^s * (1 + M/2^m)            * 2^(E - bias)
- HUB decode:           (-1)^s * (1 + M/2^m + 2^-(m+1)) * 2^(E - bias)
  (the extra 2^-(m+1) term is the Implicit LSB, always 1)
- E == 0 encodes exact zero in either format (subnormals unsupported,
  matching the paper's converters).

Encoding from binary64 uses round-to-nearest-even for the conventional format
and plain truncation for HUB (truncation *is* round-to-nearest for HUB).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = [
    "FloatFormat", "HALF", "SINGLE", "DOUBLE",
    "encode_ieee", "decode_ieee", "encode_hub", "decode_hub",
    "pack_fields", "unpack_fields", "packed_is_zero",
]


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """An IEEE754-like storage format: 1 sign, `exp_bits`, `man_bits`."""

    exp_bits: int
    man_bits: int
    name: str = ""

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def max_exp_raw(self) -> int:
        # Largest *raw* exponent we emit; the all-ones code is avoided so the
        # packed space stays NaN/Inf-free (the converters saturate instead).
        return (1 << self.exp_bits) - 2

    @property
    def total_bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    def __post_init__(self):
        # 64 fits: the sign bit may occupy bit 63 (int64 wraps are benign —
        # packed words are bit patterns, all field accesses go through masks).
        if self.total_bits > 64:
            raise ValueError("packed format must fit int64")


HALF = FloatFormat(5, 10, "half")
SINGLE = FloatFormat(8, 23, "single")
DOUBLE = FloatFormat(11, 52, "double")


def pack_fields(sign, exp_raw, man, fmt: FloatFormat):
    """Assemble packed int64 words from (sign, raw exponent, mantissa)."""
    sign = jnp.asarray(sign, jnp.int64)
    exp_raw = jnp.asarray(exp_raw, jnp.int64)
    man = jnp.asarray(man, jnp.int64)
    return (sign << (fmt.exp_bits + fmt.man_bits)) | (exp_raw << fmt.man_bits) | man


def unpack_fields(packed, fmt: FloatFormat):
    """Split packed words into (sign, raw exponent, mantissa)."""
    packed = jnp.asarray(packed, jnp.int64)
    man = packed & ((1 << fmt.man_bits) - 1)
    exp_raw = (packed >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)
    sign = (packed >> (fmt.exp_bits + fmt.man_bits)) & 1
    return sign, exp_raw, man


def packed_is_zero(packed, fmt: FloatFormat):
    """True where a packed word encodes ±0 (raw exponent field 0).

    Shared by both formats — E == 0 is the zero encoding for IEEE-like and
    HUB words alike (subnormals are unsupported).  Used by the complex
    datapath to detect exactly-real entries, for which the phase rotation
    is skipped as an exact identity (DESIGN.md §10).
    """
    packed = jnp.asarray(packed, jnp.int64)
    return ((packed >> fmt.man_bits) & ((1 << fmt.exp_bits) - 1)) == 0


def _split_finite(x):
    """x (float64) -> sign, unbiased exponent, significand in [1, 2).

    Zero maps to (sign, None-marker) via the `is_zero` mask returned.
    """
    x = jnp.asarray(x, jnp.float64)
    sign = (jnp.signbit(x)).astype(jnp.int64)
    ax = jnp.abs(x)
    is_zero = ax == 0.0
    # frexp: ax = f * 2^e with f in [0.5, 1)  ->  significand 2f in [1,2).
    f, e = jnp.frexp(jnp.where(is_zero, 1.0, ax))
    return sign, (e - 1).astype(jnp.int64), 2.0 * f, is_zero


def encode_ieee(x, fmt: FloatFormat):
    """binary64 -> packed conventional word (RNE; saturates, flushes to 0)."""
    sign, e, sig, is_zero = _split_finite(x)
    scale = np.float64(1 << fmt.man_bits)
    man = jnp.rint((sig - 1.0) * scale).astype(jnp.int64)  # RNE
    # Mantissa rounding may carry out (sig ~ 2.0).
    carry = man >> fmt.man_bits
    man = jnp.where(carry > 0, 0, man)
    e = e + carry
    exp_raw = e + fmt.bias
    underflow = exp_raw < 1
    overflow = exp_raw > fmt.max_exp_raw
    exp_raw = jnp.clip(exp_raw, 1, fmt.max_exp_raw)
    man = jnp.where(overflow, (1 << fmt.man_bits) - 1, man)
    packed = pack_fields(sign, exp_raw, man, fmt)
    return jnp.where(is_zero | underflow, sign << (fmt.exp_bits + fmt.man_bits), packed)


def decode_ieee(packed, fmt: FloatFormat):
    """packed conventional word -> binary64."""
    sign, exp_raw, man = unpack_fields(packed, fmt)
    sig = 1.0 + man.astype(jnp.float64) / np.float64(1 << fmt.man_bits)
    val = jnp.ldexp(sig, (exp_raw - fmt.bias).astype(jnp.int32))
    val = jnp.where(exp_raw == 0, 0.0, val)
    return jnp.where(sign == 1, -val, val)


def encode_hub(x, fmt: FloatFormat):
    """binary64 -> packed HUB word.

    Round-to-nearest for HUB is *truncation* of the mantissa field.
    """
    sign, e, sig, is_zero = _split_finite(x)
    scale = np.float64(1 << fmt.man_bits)
    man = jnp.floor((sig - 1.0) * scale).astype(jnp.int64)  # truncate == RN(HUB)
    man = jnp.clip(man, 0, (1 << fmt.man_bits) - 1)  # sig==2.0 cannot occur (frexp)
    exp_raw = e + fmt.bias
    underflow = exp_raw < 1
    overflow = exp_raw > fmt.max_exp_raw
    exp_raw = jnp.clip(exp_raw, 1, fmt.max_exp_raw)
    man = jnp.where(overflow, (1 << fmt.man_bits) - 1, man)
    packed = pack_fields(sign, exp_raw, man, fmt)
    return jnp.where(is_zero | underflow, sign << (fmt.exp_bits + fmt.man_bits), packed)


def decode_hub(packed, fmt: FloatFormat):
    """packed HUB word -> binary64 (includes the ILSB term 2^-(m+1))."""
    sign, exp_raw, man = unpack_fields(packed, fmt)
    scale = np.float64(1 << fmt.man_bits)
    sig = 1.0 + (man.astype(jnp.float64) + 0.5) / scale
    val = jnp.ldexp(sig, (exp_raw - fmt.bias).astype(jnp.int32))
    val = jnp.where(exp_raw == 0, 0.0, val)
    return jnp.where(sign == 1, -val, val)
