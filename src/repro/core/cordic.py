"""Fixed-point CORDIC core with sigma-bit reuse (Z-datapath elimination).

This is the heart of the paper's Givens rotator (Sec. 3.2 / Fig. 3): the
classic X-Y CORDIC datapath, *without* a Z (angle) datapath.  In vectoring
mode the per-microrotation direction bits sigma_i (plus one coarse "flip" bit
for x<0 pre-rotation) are produced; in rotation mode the stored bits replay
the exact same micro-rotation sequence on further element pairs of the rows.

Arithmetic conventions
----------------------
Values are w-bit two's-complement integers carried in int64 lanes, with
F = N - 2 fraction bits and w = N + 2 total bits (the paper appends two
integer growth bits for the CORDIC gain, Sec. 5.2).

- Conventional mode: right shifts truncate (floor), subtraction is exact
  two's complement (x + ~y + 1).
- HUB mode (Sec. 4.2 / Fig. 6): every stored value carries an implicit LSB
  (ILSB) of weight half an LSB.  The shifted operand is implicitly
  rounded-to-nearest by the truncating shift, and the adder carry-in is the
  (n+1)-th MSB of the shifted coordinate:
      add:  x + (y >> i) + c        c = 1 if i == 0 else bit_{i-1}(y)
      sub:  x + ~(y >> i) + (1 - c)
  (negation of a HUB number is pure bit inversion — the ILSB absorbs the +1).

`iters` and `N` may be traced scalars: a single jit specialization then
serves every (N, iters) sweep point of the paper's error analysis (Fig. 9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MAX_ITERS", "cordic_gain", "gain_comp_constant", "fixmul",
    "vectoring", "rotation", "vectoring_rotation",
]

MAX_ITERS = 60

# K(k) = prod_{i<k} sqrt(1 + 2^-2i); GAIN_TABLE[k] is the gain after k
# micro-rotations.  float64, exact enough for any comp constant below.
_g = np.cumprod([np.sqrt(1.0 + 2.0 ** (-2.0 * i)) for i in range(MAX_ITERS)])
GAIN_TABLE = np.concatenate([[1.0], _g])


def cordic_gain(iters: int) -> float:
    return float(GAIN_TABLE[iters])


def gain_comp_constant(iters, p):
    """Integer compensation constant: round(2^p / K(iters)).

    `iters` and `p` may be traced (int64) or static Python ints.  The
    static path computes the identical IEEE-double value in numpy and
    avoids staging the gain table as an array constant — required inside
    Pallas kernels, which reject captured array consts.
    """
    if isinstance(iters, (int, np.integer)) and isinstance(p, (int, np.integer)):
        inv_gain = np.float64(1.0) / np.float64(GAIN_TABLE[iters])
        return jnp.asarray(np.rint(inv_gain * np.exp2(np.float64(p))),
                           jnp.int64)
    inv_gain = 1.0 / jnp.asarray(GAIN_TABLE, jnp.float64)[iters]
    p = jnp.asarray(p, jnp.int64)
    return jnp.rint(inv_gain * jnp.exp2(p.astype(jnp.float64))).astype(jnp.int64)


def fixmul(v, comp, p, round_nearest):
    """(v * comp) >> p for w-bit v and ~p-bit comp without int64 overflow.

    Splits v into 16-bit low / high halves so partial products stay < 2^63.
    Requires p > 16 (always true here: p >= 24).
    `round_nearest=True` adds half an LSB before the final shift (round half
    up — the cheap multiplier rounding); HUB mode passes False (truncation is
    round-to-nearest for HUB).
    """
    v = jnp.asarray(v, jnp.int64)
    v_lo = v & 0xFFFF
    v_hi = v >> 16  # arithmetic; keeps the sign
    acc = v_hi * comp + ((v_lo * comp) >> 16)
    sh = p - 16
    if round_nearest:
        acc = acc + (jnp.asarray(1, jnp.int64) << (sh - 1))
    return acc >> sh


def _negate(v, hub: bool):
    return ~v if hub else -v


def _carry_bit(y, i):
    """HUB carry-in: ILSB (1) at i == 0, else bit (i-1) of the pre-shift y."""
    return jnp.where(i == 0, jnp.asarray(1, jnp.int64), (y >> jnp.maximum(i - 1, 0)) & 1)


def _microrotation(x, y, i, d_pos, hub: bool):
    """One micro-rotation:  x' = x - d*(y>>i),  y' = y + d*(x>>i).

    d_pos is a boolean lane: True => d = +1, False => d = -1.
    """
    ys = y >> i
    xs = x >> i
    if hub:
        cy = _carry_bit(y, i)
        cx = _carry_bit(x, i)
        x_sub = x + ~ys + (1 - cy)   # x - (y>>i)
        x_add = x + ys + cy          # x + (y>>i)
        y_add = y + xs + cx          # y + (x>>i)
        y_sub = y + ~xs + (1 - cx)   # y - (x>>i)
    else:
        x_sub = x - ys
        x_add = x + ys
        y_add = y + xs
        y_sub = y - xs
    x_new = jnp.where(d_pos, x_sub, x_add)
    y_new = jnp.where(d_pos, y_add, y_sub)
    return x_new, y_new


def vectoring(x, y, iters, hub: bool):
    """Vectoring mode: drive y -> 0, recording direction bits.

    Returns (x_rot, y_rot, flip, sigmas):
      flip   : int64 0/1 — coarse pi pre-rotation applied when x < 0
      sigmas : int64 bitmask; bit i == 1 means d_i = +1 (y was negative)
    Gain compensation is NOT applied here (see `apply_gain`).
    """
    x = jnp.asarray(x, jnp.int64)
    y = jnp.asarray(y, jnp.int64)
    flip = (x < 0).astype(jnp.int64)
    x = jnp.where(flip == 1, _negate(x, hub), x)
    y = jnp.where(flip == 1, _negate(y, hub), y)

    def body(i, carry):
        cx, cy, sig = carry
        d_pos = cy < 0
        nx, ny = _microrotation(cx, cy, i, d_pos, hub)
        sig = sig | (d_pos.astype(jnp.int64) << i)
        return nx, ny, sig

    sig0 = jnp.zeros_like(x)
    x, y, sigmas = jax.lax.fori_loop(0, iters, body, (x, y, sig0))
    return x, y, flip, sigmas


def rotation(x, y, flip, sigmas, iters, hub: bool):
    """Rotation mode: replay the stored (flip, sigma) micro-rotation sequence."""
    x = jnp.asarray(x, jnp.int64)
    y = jnp.asarray(y, jnp.int64)
    x = jnp.where(flip == 1, _negate(x, hub), x)
    y = jnp.where(flip == 1, _negate(y, hub), y)

    def body(i, carry):
        cx, cy = carry
        d_pos = ((sigmas >> i) & 1) == 1
        return _microrotation(cx, cy, i, d_pos, hub)

    x, y = jax.lax.fori_loop(0, iters, body, (x, y))
    return x, y


def apply_gain(x, y, iters, w, hub: bool):
    """Compensate the CORDIC gain: multiply by round(2^p / K(iters)) >> p.

    p is chosen so the partial products stay inside int64: p = 78 - w capped
    to 46 (comp error ~2^-p, far below the N-bit LSB for every supported N).
    `iters` and `w` may be static Python ints (kernel-resident path) or
    traced scalars (sweep path) — both produce identical constants.
    """
    if isinstance(w, (int, np.integer)) and isinstance(iters, (int, np.integer)):
        p = int(min(78 - w, 46))
    else:
        w = jnp.asarray(w, jnp.int64)
        p = jnp.minimum(jnp.asarray(78, jnp.int64) - w,
                        jnp.asarray(46, jnp.int64))
    comp = gain_comp_constant(iters, p)
    return (fixmul(x, comp, p, round_nearest=not hub),
            fixmul(y, comp, p, round_nearest=not hub))


def vectoring_rotation(x_lead, y_lead, x_rest, y_rest, iters, w, hub: bool):
    """Full Givens rotation of two rows in the fixed-point domain.

    (x_lead, y_lead): the leading element pair (batched arbitrarily).
    (x_rest, y_rest): remaining element pairs, with one extra trailing axis
                      that the sigma state broadcasts across.
    Returns rotated (r_lead, y0_lead, x_rest', y_rest') with gain compensated.
    """
    xl, yl, flip, sig = vectoring(x_lead, y_lead, iters, hub)
    xr, yr = rotation(x_rest, y_rest, flip[..., None], sig[..., None], iters, hub)
    xl, yl = apply_gain(xl, yl, iters, w, hub)
    xr, yr = apply_gain(xr, yr, iters, w, hub)
    return xl, yl, xr, yr
