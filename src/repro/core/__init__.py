# The paper's primary contribution: a bit-accurate, fully-vectorized JAX
# emulation of the floating-point Givens rotation unit (block-FP CORDIC with
# sigma-bit reuse, conventional + HUB datapaths) and the QRD engines built on
# it.  See DESIGN.md §1-§3.
from .formats import (FloatFormat, HALF, SINGLE, DOUBLE,
                      encode_ieee, decode_ieee, encode_hub, decode_hub,
                      packed_is_zero)
from .givens import GivensConfig, GivensUnit
from .qrd import (QRDEngine, qr_cordic, qr_cordic_pallas, qr_blockfp_pallas,
                  qr_cordic_wavefront, qr_blockfp_wavefront,
                  qr_cordic_complex, qr_cordic_complex_pallas,
                  qr_cordic_complex_wavefront,
                  qr_blocked_sharded, qr_givens_float, qr_jnp, qr_fixed,
                  snr_db, givens_schedule, sameh_kuck_schedule)
from .hub import hub_quantize, hub_error_bound
from . import cordic, converters

__all__ = [
    "FloatFormat", "HALF", "SINGLE", "DOUBLE",
    "encode_ieee", "decode_ieee", "encode_hub", "decode_hub",
    "packed_is_zero",
    "GivensConfig", "GivensUnit",
    "QRDEngine", "qr_cordic", "qr_cordic_pallas", "qr_blockfp_pallas",
    "qr_cordic_wavefront", "qr_blockfp_wavefront",
    "qr_cordic_complex", "qr_cordic_complex_pallas",
    "qr_cordic_complex_wavefront",
    "qr_blocked_sharded", "qr_givens_float", "qr_jnp", "qr_fixed",
    "snr_db", "givens_schedule", "sameh_kuck_schedule",
    "hub_quantize", "hub_error_bound",
    "cordic", "converters",
]
