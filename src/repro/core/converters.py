"""FP <-> block fixed-point converters (paper Secs. 3.1, 3.3, 4.1, 4.3).

Input converter (Figs. 2 / 5): two packed FP words -> two aligned N-bit
two's-complement significands sharing the larger exponent (block FP).
Output converter (Figs. 4 / 7): two rotated w-bit fixed-point values + the
common exponent -> two packed FP words (normalize, round, underflow flush).

Every paper variant is implemented:
  IEEE  : input alignment rounding 'rne' or 'trunc'  (Fig. 10: IEEERound/Trunc)
  HUB   : biased vs unbiased extension, identity ("1.0") detection
          (Fig. 10: HUBBasic / HUBunbias / HUBDetectI / HUBFull)

`N` may be a traced scalar so that bit-width sweeps share one compilation.
Internally significands use F = N-2 fraction bits; the CORDIC datapath width
is w = N+2 (two growth bits, Sec. 5.2).
"""
from __future__ import annotations

import jax.numpy as jnp

from .formats import FloatFormat, pack_fields, unpack_fields

__all__ = ["input_convert_ieee", "input_convert_hub",
           "output_convert_ieee", "output_convert_hub", "ilog2"]

_I64 = lambda v: jnp.asarray(v, jnp.int64)


def ilog2(a):
    """floor(log2(a)) for int64 a > 0 (exact for a < 2^53)."""
    _, e = jnp.frexp(a.astype(jnp.float64))
    return (e - 1).astype(jnp.int64)


def _rshift_rne(v, sh):
    """Arithmetic right shift with round-to-nearest-even on the dropped bits."""
    sh = jnp.maximum(sh, 0)
    q = v >> sh
    rem = v - (q << sh)
    half = jnp.where(sh > 0, _I64(1) << jnp.maximum(sh - 1, 0), _I64(0))
    round_up = ((rem > half) | ((rem == half) & ((q & 1) == 1))) & (sh > 0)
    return q + round_up.astype(jnp.int64)


def _align(xfix, yfix, ex, ey, N, round_mode):
    """Shift the significand with the smaller exponent right by |ex - ey|.

    round_mode: 'rne' | 'trunc' (conventional) | 'hub' (truncation *is* RN).
    The shifter forces exact zero when the distance exceeds the word width.
    """
    d_xy = ex - ey
    x_is_low = d_xy < 0
    sh = jnp.abs(d_xy)
    lo = jnp.where(x_is_low, xfix, yfix)
    if round_mode == "rne":
        lo_sh = _rshift_rne(lo, sh)
    else:  # 'trunc' and 'hub': plain arithmetic shift
        lo_sh = lo >> jnp.minimum(sh, 62)
    lo_sh = jnp.where(sh >= N + 2, _I64(0), lo_sh)
    xout = jnp.where(x_is_low, lo_sh, xfix)
    yout = jnp.where(x_is_low, yfix, lo_sh)
    m_exp = jnp.maximum(ex, ey)
    return xout, yout, m_exp


def _expand_ieee(sign, exp_raw, man, fmt: FloatFormat, N):
    """Packed fields -> N-bit two's-complement significand (no alignment yet)."""
    is_zero = exp_raw == 0
    k_ext = N - 2 - fmt.man_bits  # appended zeros; requires N >= m + 2
    mag = ((_I64(1) << fmt.man_bits) | man) << k_ext
    mag = jnp.where(is_zero, 0, mag)
    return jnp.where(sign == 1, -mag, mag)


def input_convert_ieee(x_packed, y_packed, fmt: FloatFormat, N, rounding="rne"):
    """Conventional input converter (Fig. 2). rounding: 'rne' | 'trunc'."""
    sx, ex, mx = unpack_fields(x_packed, fmt)
    sy, ey, my = unpack_fields(y_packed, fmt)
    xf = _expand_ieee(sx, ex, mx, fmt, N)
    yf = _expand_ieee(sy, ey, my, fmt, N)
    return _align(xf, yf, ex, ey, N, rounding)


def _expand_hub(sign, exp_raw, man, fmt: FloatFormat, N,
                unbiased: bool, detect_identity: bool):
    """Packed HUB fields -> N-bit HUB significand (Fig. 5).

    Extension below the m explicit fraction bits (k = N-2-m bits):
      biased   : ILSB '1' then zeros                     ('1000...')
      unbiased : explicit-LSB then its inverse repeated  ('1000..'/'0111..')
      identity : exact 1.0 detected (exp==bias, man==0) -> all-zero extension,
                 so the fixed-point HUB word is 1.0 + 2^-(N-1) instead of
                 1.0 + 2^-(m+1).
    """
    is_zero = exp_raw == 0
    k = N - 2 - fmt.man_bits
    base = ((_I64(1) << fmt.man_bits) | man) << k
    km1 = jnp.maximum(k - 1, 0)
    top = _I64(1) << km1
    if unbiased:
        lsb = man & 1
        ext = jnp.where(lsb == 1, top, top - 1)
    else:
        ext = top
    ext = jnp.where(k > 0, ext, 0)
    if detect_identity:
        is_one = (exp_raw == fmt.bias) & (man == 0)
        ext = jnp.where(is_one, 0, ext)
    mag = base | ext
    mag = jnp.where(is_zero, 0, mag)
    # HUB negation: pure bit inversion (the ILSB absorbs the +1).
    return jnp.where(sign == 1, ~mag, mag)


def input_convert_hub(x_packed, y_packed, fmt: FloatFormat, N,
                      unbiased=True, detect_identity=True):
    """HUB input converter (Fig. 5)."""
    sx, ex, mx = unpack_fields(x_packed, fmt)
    sy, ey, my = unpack_fields(y_packed, fmt)
    xf = _expand_hub(sx, ex, mx, fmt, N, unbiased, detect_identity)
    yf = _expand_hub(sy, ey, my, fmt, N, unbiased, detect_identity)
    return _align(xf, yf, ex, ey, N, "hub")


def _saturate_pack(sign, exp_new, man, fmt: FloatFormat, flush_zero):
    overflow = exp_new > fmt.max_exp_raw
    exp_out = jnp.clip(exp_new, 1, fmt.max_exp_raw)
    man = jnp.where(overflow, (1 << fmt.man_bits) - 1, man)
    packed = pack_fields(sign, exp_out, man, fmt)
    underflow = (exp_new <= 0) | flush_zero
    return jnp.where(underflow, sign << (fmt.exp_bits + fmt.man_bits), packed)


def output_convert_ieee(v, m_exp, fmt: FloatFormat, N):
    """Conventional output converter (Fig. 4): normalize + RNE + exponent."""
    v = _I64(v)
    sign = (v < 0).astype(jnp.int64)
    a = jnp.abs(v)
    is_zero = a == 0
    a_safe = jnp.where(is_zero, 1, a)
    k = ilog2(a_safe)  # leading-one position
    m = fmt.man_bits
    # Keep m+1 significant bits with RNE on the discarded ones.
    down = jnp.maximum(k - m, 0)
    up = jnp.maximum(m - k, 0)
    q = _rshift_rne(a_safe, down) << up
    # Rounding may carry out: q == 2^(m+1).
    carry = q >> (m + 1)
    q = jnp.where(carry > 0, q >> 1, q)
    k = k + carry
    man = q - (_I64(1) << m)
    exp_new = m_exp + k - (N - 2)
    return _saturate_pack(sign, exp_new, man, fmt, is_zero)


def output_convert_hub(v, m_exp, fmt: FloatFormat, N, unbiased=True):
    """HUB output converter (Fig. 7): invert-negate, append ILSB, truncate.

    No sticky bit, no round-up adder, no mantissa-overflow path — truncation
    of a HUB word is round-to-nearest.
    """
    v = _I64(v)
    sign = (v < 0).astype(jnp.int64)
    stored = jnp.where(sign == 1, ~v, v)  # |value| stored part, >= 0
    A = (stored << 1) | 1                  # append the explicit ILSB
    k2 = ilog2(A)                          # A >= 1 always
    m = fmt.man_bits
    down = jnp.maximum(k2 - m, 0)
    up = jnp.maximum(m - k2, 0)
    hi = A >> down                         # truncation == RN for HUB
    if unbiased:
        # bits shifted in during left normalization: first = stored LSB,
        # rest = its inverse ('1000...' / '0111...'), Sec. 4.3.
        lsb = stored & 1
        upm1 = jnp.maximum(up - 1, 0)
        fill = jnp.where(lsb == 1, _I64(1) << upm1, (_I64(1) << upm1) - 1)
        fill = jnp.where(up > 0, fill, 0)
    else:
        fill = _I64(0)
    q = (hi << up) | fill
    man = q - (_I64(1) << m)
    exp_new = m_exp + (k2 - 1) - (N - 2)
    # The all-inverted zero (stored == 0 from v == -1) etc. round through the
    # normal path; true zero only via exponent underflow.
    return _saturate_pack(sign, exp_new, man, fmt, jnp.zeros_like(sign, bool))
