"""The FP Givens rotation unit (paper Fig. 1): converters + fixed-point core.

`GivensUnit` wires the input converter, the sigma-reusing CORDIC rotator and
the output converter into the paper's two operations:

  vector(x, y)            -> (r, y0, state)   # vectoring: compute the angle
  rotate(x, y, state)     -> (x', y')         # rotation: replay the angle

Both operate on *packed* FP words (see repro.core.formats) and are fully
vectorized: any batch shape works, and `rotate` broadcasts one state over a
trailing axis of row elements — exactly the unit's pipeline overlap, in space
instead of time.

The unit is bit-accurate w.r.t. the architectures of Figs. 2-7; `N` and
`iters` may be traced scalars so parameter sweeps reuse one compilation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from . import converters as conv
from . import cordic
from .formats import (FloatFormat, SINGLE, encode_hub, encode_ieee,
                      decode_hub, decode_ieee, packed_is_zero)

__all__ = ["GivensConfig", "GivensUnit", "RotationState"]


@dataclasses.dataclass(frozen=True)
class GivensConfig:
    """Implementation parameters of the unit (paper Sec. 5 sweep space)."""

    fmt: FloatFormat = SINGLE
    n: int = 26                 # internal significand width N
    iters: int | None = None    # CORDIC micro-rotations; None -> paper default
    hub: bool = False           # conventional (IEEE-like) vs HUB datapath
    input_rounding: str = "trunc"   # IEEE input converter: 'rne' | 'trunc'
    unbiased: bool = True           # HUB converters: unbiased extension
    detect_identity: bool = True    # HUB input converter: detect exact 1.0

    def default_iters(self) -> int:
        # Fig. 9: conventional peaks at N-3 micro-rotations, HUB at N-2.
        return self.n - 2 if self.hub else self.n - 3

    def resolved_iters(self) -> int:
        return self.default_iters() if self.iters is None else self.iters

    def validate(self):
        if self.n < self.fmt.man_bits + 2:
            raise ValueError("need N >= man_bits + 2 for a lossless expand")
        if self.n + 3 > 53:
            raise ValueError("bit-accurate emulation supports N <= 50 "
                             "(int64 lanes + exact float64 ilog2)")
        if self.input_rounding not in ("rne", "trunc"):
            raise ValueError(self.input_rounding)


# (flip, sigmas) from vectoring — the entire "Z coordinate" of the unit.
RotationState = Any


class GivensUnit:
    """Bit-accurate facade over the converter + CORDIC pipeline (Fig. 1).

    All methods operate on *packed* FP words: int64 integers with the
    ``[sign | exponent | mantissa]`` layout of ``cfg.fmt`` (see
    `repro.core.formats`) — the HUB variant carries an implicit always-1
    LSB.  Everything is vectorized over arbitrary batch shapes, and the
    same instance serves both the host-side reference loop and the
    kernel-resident blocked QR (its methods trace inside Pallas kernels).

    Parameters
    ----------
    config : GivensConfig
        Frozen (hashable) implementation parameters; validated on
        construction.
    """

    def __init__(self, config: GivensConfig):
        config.validate()
        self.cfg = config

    # -- packed codec helpers -------------------------------------------------
    def encode(self, x):
        """float array -> int64 packed words of ``cfg.fmt`` (IEEE or HUB).

        Conventional encoding rounds to nearest-even; HUB encoding
        truncates (truncation *is* round-to-nearest for HUB).  Zeros map
        to packed words with exponent field 0.
        """
        f = encode_hub if self.cfg.hub else encode_ieee
        return f(x, self.cfg.fmt)

    def decode(self, packed):
        """int64 packed words -> float64 values (packed-zero -> ±0.0)."""
        f = decode_hub if self.cfg.hub else decode_ieee
        return f(packed, self.cfg.fmt)

    # -- converter plumbing ---------------------------------------------------
    def _to_fixed(self, xp, yp, N):
        if self.cfg.hub:
            return conv.input_convert_hub(
                xp, yp, self.cfg.fmt, N,
                unbiased=self.cfg.unbiased,
                detect_identity=self.cfg.detect_identity)
        return conv.input_convert_ieee(
            xp, yp, self.cfg.fmt, N, rounding=self.cfg.input_rounding)

    def _to_float(self, v, m_exp, N):
        if self.cfg.hub:
            return conv.output_convert_hub(
                v, m_exp, self.cfg.fmt, N, unbiased=self.cfg.unbiased)
        return conv.output_convert_ieee(v, m_exp, self.cfg.fmt, N)

    # -- the two operations of the unit --------------------------------------
    def vector(self, xp, yp, N=None, iters=None):
        """Vectoring: compute the rotation angle from the leading pair.

        Parameters
        ----------
        xp, yp : int64 packed FP words, any (broadcastable) batch shape.
        N, iters : optional
            Significand width / CORDIC depth overrides.  None resolves the
            config value as a *static* Python int (required inside Pallas
            kernels); traced scalars are accepted for sweep reuse.

        Returns
        -------
        (r_packed, y_packed, state)
            ``r_packed`` is ±hypot(x, y) packed, ``y_packed`` the ≈0
            residual, ``state`` the ``(flip, sigmas)`` control word that
            `rotate` replays — the entire "Z coordinate" of the unit.
        """
        N = self.cfg.n if N is None else N
        iters = self.cfg.resolved_iters() if iters is None else iters
        xf, yf, m_exp = self._to_fixed(xp, yp, N)
        xr, yr, flip, sig = cordic.vectoring(xf, yf, iters, self.cfg.hub)
        xr, yr = cordic.apply_gain(xr, yr, iters, N + 2, self.cfg.hub)
        return (self._to_float(xr, m_exp, N),
                self._to_float(yr, m_exp, N),
                (flip, sig))

    def rotate(self, xp, yp, state, N=None, iters=None):
        """Rotation: replay `state` on another element pair of the rows.

        Parameters
        ----------
        xp, yp : int64 packed FP words; ``state`` broadcasts across any
            trailing axes (one control word rotates a whole row).
        state : (flip, sigmas)
            Control word from `vector` — int64 0/1 coarse flip plus the
            packed per-microrotation direction bits.
        N, iters : optional overrides, as in `vector`.

        Returns
        -------
        (x_packed, y_packed) — the rotated element pair, packed.
        """
        N = self.cfg.n if N is None else N
        iters = self.cfg.resolved_iters() if iters is None else iters
        flip, sig = state
        xf, yf, m_exp = self._to_fixed(xp, yp, N)
        xr, yr = cordic.rotation(xf, yf, flip, sig, iters, self.cfg.hub)
        xr, yr = cordic.apply_gain(xr, yr, iters, N + 2, self.cfg.hub)
        return (self._to_float(xr, m_exp, N),
                self._to_float(yr, m_exp, N))

    def rotate_rows(self, row_x, row_y, N=None, iters=None):
        """Full Givens rotation of two packed rows (..., e).

        Vectoring on element 0, rotation broadcast over elements 1..e-1 —
        the paper's one-element-per-cycle pipeline, vectorized in space.
        Returns the rotated rows; row_y[..., 0] is the (near-)zeroed entry.
        """
        rx0, ry0, state = self.vector(row_x[..., 0], row_y[..., 0], N, iters)
        flip, sig = state
        rx, ry = self.rotate(row_x[..., 1:], row_y[..., 1:],
                             (flip[..., None], sig[..., None]), N, iters)
        return (jnp.concatenate([rx0[..., None], rx], axis=-1),
                jnp.concatenate([ry0[..., None], ry], axis=-1))

    # -- complex datapath: the three-rotation decomposition (DESIGN.md §10) --
    def phase_vector(self, re_p, im_p, N=None, iters=None):
        """Vectoring on the (re, im) lane pair of one complex entry.

        The first two rotations of the complex Givens decomposition are
        *phase* rotations: vectoring on the (re, im) pair of a row's
        leading entry computes e^{-i·arg z} as a CORDIC control word, and
        replaying it on every other (re, im) pair of the row multiplies
        the whole row by that unit phasor — the same packed unit as the
        real datapath, applied to the re/im lane pair instead of a row
        pair.

        Exactly-real entries (packed imaginary word ±0) are detected and
        flagged for skipping: their true phase rotation is the identity
        (or π, which the real Givens' own flip handles), so skipping keeps
        purely-real complex inputs bit-identical to the real datapath.

        Parameters
        ----------
        re_p, im_p : int64 packed FP words, any batch shape
            Real and imaginary lanes of the leading entry.

        Returns
        -------
        (mag_packed, state, skip)
            ``mag_packed`` is the realized entry (|z| packed; the raw real
            lane where ``skip``), ``state`` the replayable ``(flip,
            sigmas)`` phase control word, ``skip`` the bool lanes where
            the phase rotation must be treated as the exact identity.
        """
        mag, _, state = self.vector(re_p, im_p, N=N, iters=iters)
        skip = packed_is_zero(im_p, self.cfg.fmt)
        return jnp.where(skip, re_p, mag), state, skip

    def phase_rotate(self, re_p, im_p, state, skip, N=None, iters=None):
        """Replay a phase control word on further (re, im) lane pairs.

        ``state`` and ``skip`` come from `phase_vector` and broadcast over
        any trailing element axes; where ``skip`` the inputs pass through
        untouched (the exact identity phase).
        """
        rr, ri = self.rotate(re_p, im_p, state, N=N, iters=iters)
        return jnp.where(skip, re_p, rr), jnp.where(skip, im_p, ri)

    def rotate_rows_complex(self, row_x, row_y, N=None, iters=None):
        """Complex Givens rotation of two packed rows of (re, im) lanes.

        The three-rotation decomposition (DESIGN.md §10): two vectoring
        phase rotations realize the leading entries of the pivot and
        target rows (each is the real unit applied to the row's (re, im)
        lane pairs), then the real Givens of the real datapath rotates the
        realized leads and replays across the re and im lanes
        independently.  The composite is exactly unitary-by-construction
        in infinite precision, and every constituent rotation is the
        bit-accurate packed unit — IEEE/HUB bit-accuracy carries over
        unchanged.

        Rows whose leading entries are exactly real (packed imaginary
        lane ±0) skip their phase rotation, so purely-real inputs follow
        the real `rotate_rows` datapath bit for bit, with the imaginary
        lanes propagating exact packed zeros.

        Parameters
        ----------
        row_x, row_y : (..., e, 2) int64 packed FP words
            Pivot and target rows; the trailing axis holds the (re, im)
            lanes of each element.

        Returns
        -------
        (row_x', row_y') : (..., e, 2) packed rows with the structural
        zeros forced: ``row_y'[..., 0, :] == 0`` (the annihilated entry)
        and ``row_x'[..., 0, 1] == 0`` (the realized pivot is real).
        """
        xr, xi = row_x[..., 0], row_x[..., 1]
        yr, yi = row_y[..., 0], row_y[..., 1]
        # Phase rotations: realize the leading entry of each row.
        magx, stx, skx = self.phase_vector(xr[..., 0], xi[..., 0], N, iters)
        magy, sty, sky = self.phase_vector(yr[..., 0], yi[..., 0], N, iters)
        pxr, pxi = self.phase_rotate(
            xr[..., 1:], xi[..., 1:],
            (stx[0][..., None], stx[1][..., None]), skx[..., None], N, iters)
        pyr, pyi = self.phase_rotate(
            yr[..., 1:], yi[..., 1:],
            (sty[0][..., None], sty[1][..., None]), sky[..., None], N, iters)
        # Real Givens on the realized leads; the sigma word replays across
        # the re and im lanes independently (a real rotation acts on a
        # complex element as the same 2x2 on each lane).
        r, _, stt = self.vector(magx, magy, N=N, iters=iters)
        st_b = (stt[0][..., None], stt[1][..., None])
        oxr, oyr = self.rotate(pxr, pyr, st_b, N=N, iters=iters)
        oxi, oyi = self.rotate(pxi, pyi, st_b, N=N, iters=iters)
        zero = jnp.zeros_like(r)
        out_x = jnp.stack([jnp.concatenate([r[..., None], oxr], axis=-1),
                           jnp.concatenate([zero[..., None], oxi], axis=-1)],
                          axis=-1)
        out_y = jnp.stack([jnp.concatenate([zero[..., None], oyr], axis=-1),
                           jnp.concatenate([zero[..., None], oyi], axis=-1)],
                          axis=-1)
        return out_x, out_y

    def annihilate_complex(self, row_x, row_y, col, N=None, iters=None):
        """Complex-Givens-rotate two packed rows so ``row_y[col]`` is zeroed.

        The pivot-anywhere form of `rotate_rows_complex` — the primitive
        of complex QRD-RLS updates, mirroring `annihilate`: the rows are
        rolled along the element axis so the pivot column leads, rotated
        by the three-rotation decomposition (structural zeros included),
        and rolled back.  ``col`` may be a traced scalar.

        Parameters
        ----------
        row_x, row_y : (..., e, 2) int64 packed FP words
            Pivot row and target row of (re, im) lanes.
        col : int or traced scalar
            Pivot column; ``row_y[..., col, :]`` is annihilated.
        """
        rx = jnp.roll(row_x, -col, axis=-2)
        ry = jnp.roll(row_y, -col, axis=-2)
        ox, oy = self.rotate_rows_complex(rx, ry, N=N, iters=iters)
        return jnp.roll(ox, col, axis=-2), jnp.roll(oy, col, axis=-2)

    def annihilate(self, row_x, row_y, col, N=None, iters=None):
        """Givens-rotate two packed rows so ``row_y[col]`` is zeroed.

        The pivot-anywhere form of `rotate_rows`, the primitive of
        QRD-RLS updates (`repro.qrd.rls.RLSState`): the rows are rolled
        so the pivot column leads, rotated (vectoring on the pivot pair,
        σ-replay across the rest), the annihilated entry forced to the
        structural packed zero, and rolled back.  ``col`` may be a traced
        scalar — one fixed row shape compiles once and serves every pivot
        column, so a jitted scan over pivots traces this body a single
        time.

        Parameters
        ----------
        row_x, row_y : (..., e) int64 packed FP words
            Pivot row and target row.
        col : int or traced scalar
            Pivot column; ``row_y[..., col]`` is annihilated against
            ``row_x[..., col]``.

        Returns
        -------
        (row_x', row_y') packed rows with ``row_y'[..., col] == 0``.
        """
        rx = jnp.roll(row_x, -col, axis=-1)
        ry = jnp.roll(row_y, -col, axis=-1)
        ox, oy = self.rotate_rows(rx, ry, N=N, iters=iters)
        oy = oy.at[..., 0].set(0)   # the zeroed entry is structural
        return jnp.roll(ox, col, axis=-1), jnp.roll(oy, col, axis=-1)
