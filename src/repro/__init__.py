"""repro — a JAX training/serving framework built around the paper

    Hormigo & Muñoz, "Efficient Floating-Point Givens Rotation Unit",
    Circuits, Systems, and Signal Processing (2020).

Layout:
    repro.core      bit-accurate emulation of the FP Givens rotation unit
                    (block-FP CORDIC, sigma-bit reuse, HUB format) + QRD
                    backends
    repro.qrd       the solver-grade QRD API: backend registry, QRDConfig,
                    engine with solve() and streaming QRD-RLS (DESIGN.md §9)
    repro.kernels   Pallas TPU kernels for the CORDIC Givens rotator
    repro.models    the ten assigned LM-family architectures
    repro.optim     AdamW + QMuon (Givens-QR orthogonalized updates)
    repro.data      deterministic shardable data pipeline
    repro.checkpoint, repro.runtime   fault-tolerance substrate
    repro.configs   per-architecture configs (--arch selectable)
    repro.launch    mesh / dryrun / train / serve entry points
"""
import jax

# The bit-accurate arithmetic emulation in repro.core requires 64-bit integer
# lanes (internal significands up to ~48 bits).  All model/launch code pins
# dtypes explicitly (bf16/f32/int32), so enabling x64 globally is safe.
jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
