"""repro — a JAX training/serving framework built around the paper

    Hormigo & Muñoz, "Efficient Floating-Point Givens Rotation Unit",
    Circuits, Systems, and Signal Processing (2020).

Layout:
    repro.core      bit-accurate emulation of the FP Givens rotation unit
                    (block-FP CORDIC, sigma-bit reuse, HUB format) + QRD
                    backends
    repro.qrd       the solver-grade QRD API: backend registry, QRDConfig,
                    engine with solve() and streaming QRD-RLS (DESIGN.md §9)
    repro.kernels   Pallas TPU kernels for the CORDIC Givens rotator
    repro.models    the ten assigned LM-family architectures
    repro.optim     AdamW + QMuon (Givens-QR orthogonalized updates)
    repro.data      deterministic shardable data pipeline
    repro.checkpoint, repro.runtime   fault-tolerance substrate
    repro.configs   per-architecture configs (--arch selectable)
    repro.launch    mesh / dryrun / serve entry points

x64 requirement
---------------
The bit-accurate arithmetic emulation in ``repro.core`` requires 64-bit
integer lanes (internal significands up to ~48 bits), so importing
``repro`` enables ``jax_enable_x64`` globally when it is off.  All
model/launch code pins dtypes explicitly (bf16/f32/int32), so this is
safe for fresh sessions — but it must never *silently* override an
explicit user choice:

* an explicit disable via the ``JAX_ENABLE_X64`` environment variable or
  a thread-local override (``jax.experimental.enable_x64`` context /
  ``jax.config`` local state) is detected and raises ``ImportError``
  instead of being clobbered;
* if JAX backends are already initialized (computations have run under
  x64=False), the flip is applied but a ``UserWarning`` is emitted —
  arrays created before the import keep their 32-bit dtypes.

An explicit ``jax.config.update("jax_enable_x64", False)`` *before* any
computation is indistinguishable from the default through JAX's public
config API; if you need x64 off, set ``JAX_ENABLE_X64=0`` (detected,
loud) or simply do not import ``repro``.
"""
import os
import warnings

import jax

__version__ = "1.0.0"


def _require_x64():
    if jax.config.jax_enable_x64:
        return
    env = os.environ.get("JAX_ENABLE_X64", "").strip().lower()
    explicit = env in ("0", "false", "no", "off")
    try:  # thread-local override (enable_x64 context manager / set_local)
        from jax._src import config as _jcfg
        local = _jcfg.enable_x64.get_local()
        explicit = explicit or local is False
    except Exception:
        pass
    if explicit:
        raise ImportError(
            "repro requires jax_enable_x64 (64-bit integer lanes for the "
            "bit-accurate Givens unit), but x64 was explicitly disabled "
            "(JAX_ENABLE_X64 env var or a local jax.config override). "
            "Remove the explicit disable before importing repro.")
    already_live = False
    try:  # backends initialized => computations may have run under x64=False
        from jax._src import xla_bridge as _xb
        already_live = bool(getattr(_xb, "_backends", None))
    except Exception:
        pass
    jax.config.update("jax_enable_x64", True)
    if already_live:
        warnings.warn(
            "importing repro enabled jax_enable_x64 globally, but JAX "
            "backends were already initialized — arrays created earlier "
            "keep their 32-bit dtypes.  Import repro before running "
            "computations (or set JAX_ENABLE_X64=1) to avoid mixed-width "
            "sessions.", UserWarning, stacklevel=3)


_require_x64()
