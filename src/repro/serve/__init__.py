"""QRD-RLS serving: fleets of adaptive filters behind a batched server.

The serving subsystem turns the single-state streaming QRD-RLS of
`repro.qrd.rls` into a deployment shape: `RLSFleet` holds N independent
filter states as one sharded struct-of-arrays pytree updated by a
single donated jitted step, and `FleetServer` wraps it with cohort
lifecycle (admit/evict/query/checkpoint of contiguous slot ranges),
asynchronous snapshot batching behind a bounded queue, and
health/occupancy reporting.  `presets` names ready-made deployment
configurations.  See DESIGN.md §12.
"""
from repro.serve.fleet import FleetState, RLSFleet, validate_lam
from repro.serve.server import Cohort, FleetServer
from repro.serve.presets import fleet_preset, list_fleet_presets

__all__ = [
    "FleetState",
    "RLSFleet",
    "validate_lam",
    "Cohort",
    "FleetServer",
    "fleet_preset",
    "list_fleet_presets",
]
