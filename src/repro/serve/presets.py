"""Named deployment presets for QRD-RLS fleets.

The seed's `configs/registry.py` resolves ``--arch`` ids to model
configs through a plain module-level table; this registry does the same
for serving deployments: a preset name resolves to a `QRDConfig` (the
arithmetic — backend, format, datapath) plus fleet/server shape kwargs
(capacity, filter length, batch size, queue bound).  Presets are
declarative end to end: the embedded `QRDConfig` round-trips through
``to_json``/``from_json``, so a deployment is one name or one JSON blob.

    >>> from repro.serve import fleet_preset
    >>> from repro.qrd import QRDEngine
    >>> spec = fleet_preset("equalizer-ieee", slots=1 << 17)
    >>> fleet = QRDEngine(spec["config"]).fleet(**spec["fleet"])

``launch/serve.py`` exposes the same table on the command line
(``python -m repro.launch.serve --preset equalizer-ieee``).
"""
from __future__ import annotations

from repro.core.formats import SINGLE
from repro.core.givens import GivensConfig
from repro.qrd.config import QRDConfig

__all__ = ["fleet_preset", "list_fleet_presets", "register_fleet_preset"]

# name -> (description, QRDConfig kwargs-free instance, fleet kwargs,
#          server kwargs).  Fleet kwargs feed QRDEngine.fleet(); server
#          kwargs feed FleetServer(...).
_PRESETS = {}


def register_fleet_preset(name, *, description, config, fleet, server=None):
    """Register a deployment preset (see module docstring).

    `fleet` must carry ``slots`` and ``n``; `server` kwargs are
    forwarded to `FleetServer` (batch, queue_limit, overflow, ...).
    """
    if name in _PRESETS:
        raise ValueError(f"preset {name!r} already registered")
    if not isinstance(config, QRDConfig):
        raise TypeError(f"config must be a QRDConfig, got {type(config)}")
    for key in ("slots", "n"):
        if key not in fleet:
            raise ValueError(f"fleet kwargs must include {key!r}")
    _PRESETS[name] = {"description": description, "config": config,
                      "fleet": dict(fleet), "server": dict(server or {})}
    return _PRESETS[name]


def list_fleet_presets():
    """{name: one-line description} of every registered preset."""
    return {name: spec["description"] for name, spec in _PRESETS.items()}


def fleet_preset(name, **fleet_overrides):
    """Resolve `name` to a fresh deployment spec.

    Returns ``{"description", "config": QRDConfig, "fleet": {...},
    "server": {...}}`` — copies, safe to mutate.  `fleet_overrides`
    patch the fleet kwargs (e.g. ``slots=1 << 20`` to scale capacity).
    """
    try:
        spec = _PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown fleet preset {name!r}; available: "
                       f"{', '.join(sorted(_PRESETS))}") from None
    fleet = dict(spec["fleet"])
    fleet.update(fleet_overrides)
    return {"description": spec["description"], "config": spec["config"],
            "fleet": fleet, "server": dict(spec["server"])}


# -- the built-in deployments -------------------------------------------------
# Per-user channel equalizers: short real filters, bit-accurate single-
# precision unit (the paper's conventional IEEE-like datapath).
register_fleet_preset(
    "equalizer-ieee",
    description="per-user equalizers, bit-accurate IEEE single CORDIC unit",
    config=QRDConfig(backend="cordic", dtype="float64",
                     givens=GivensConfig(fmt=SINGLE, hub=False)),
    fleet=dict(slots=1 << 17, n=4, lam=0.995),
    server=dict(batch=256, queue_limit=1 << 14),
)

# Same deployment on the HUB datapath (paper Sec. 4: cheaper rounding,
# one extra micro-rotation of accuracy headroom).
register_fleet_preset(
    "equalizer-hub",
    description="per-user equalizers on the HUB datapath",
    config=QRDConfig(backend="cordic", dtype="float64",
                     givens=GivensConfig(fmt=SINGLE, hub=True)),
    fleet=dict(slots=1 << 17, n=4, lam=0.995),
    server=dict(batch=256, queue_limit=1 << 14),
)

# Adaptive beamformers on complex baseband snapshots: the three-rotation
# complex datapath (DESIGN.md §10) per antenna channel.
register_fleet_preset(
    "beamformer-complex",
    description="complex baseband beamformers, three-rotation unit datapath",
    config=QRDConfig(backend="cordic", dtype="complex128",
                     givens=GivensConfig(fmt=SINGLE, hub=False)),
    fleet=dict(slots=1 << 14, n=4, lam=0.99),
    server=dict(batch=128, queue_limit=1 << 13),
)

# Float64 reference fleet: no unit emulation — the fastest CPU path and
# the numerical reference the bit-accurate fleets are compared against.
register_fleet_preset(
    "equalizer-float64",
    description="float64 conjugate-Givens reference fleet (fast CPU path)",
    config=QRDConfig(backend="jnp", dtype="float64"),
    fleet=dict(slots=1 << 17, n=4, lam=0.995),
    server=dict(batch=512, queue_limit=1 << 14),
)
