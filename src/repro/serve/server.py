"""`FleetServer` — the service layer over an `RLSFleet`.

The fleet is a device-resident state machine; the server is everything a
deployment needs around it:

* **Cohorts** — filters are admitted in named cohorts occupying a
  *contiguous* slot range (a tenant, a cell, a beam group).  Contiguity
  makes a cohort a slice of every fleet buffer: checkpoints, queries and
  eviction address ``[start, stop)`` without index lists, and the
  sharded slot axis keeps a cohort on few shards.
* **Async snapshot batching** — `submit` enqueues single ``(slot, x, d)``
  snapshots into a bounded FIFO; `pump` drains it into fixed-shape
  batches for the fleet's donated step.  Two invariants are enforced at
  batch-assembly time: (a) slots are *distinct within a batch* (the
  in-place scatter is unordered for duplicate indices — the second
  snapshot for a slot waits for the next batch, preserving FIFO order
  per slot), and (b) requests carrying a stale generation (their slot
  was evicted/readmitted since submit) are dropped, never applied to the
  recycled slot.  Batches are padded to a fixed size with the fleet's
  sentinel slot id so one compilation serves the whole request stream.
* **Backlog accounting** — per-cohort submitted/processed/dropped
  counters; `health()` reports queue depth, occupancy and per-cohort
  backlog, and flags stale cohorts via `runtime.cluster.ClusterMonitor`
  (each cohort is a "host" in monitor terms: its heartbeat advances
  whenever one of its snapshots is processed, so a cohort whose traffic
  stalls or lags the fleet's step watermark shows up as dead/straggler).
* **Checkpoint / restore** — `checkpoint()` snapshots the whole fleet
  state plus the cohort table through `checkpoint.CheckpointManager`
  (async, atomic, keep-last-k); `restore_latest()` reloads state *and*
  re-populates the cohort table so serving resumes mid-stream with
  bit-identical weights.

Thread-safety: all public methods take one re-entrant lock; `submit`
from request threads while another thread calls `pump` is supported.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.runtime.cluster import ClusterMonitor
from repro.serve.fleet import RLSFleet

__all__ = ["Cohort", "FleetServer"]


@dataclasses.dataclass
class Cohort:
    """A named contiguous slot range plus its traffic accounting."""

    name: str
    cid: int          # monitor host id
    start: int        # first slot (inclusive)
    stop: int         # last slot (exclusive)
    submitted: int = 0
    processed: int = 0
    dropped_stale: int = 0
    dropped_overflow: int = 0

    @property
    def size(self) -> int:
        return self.stop - self.start

    @property
    def backlog(self) -> int:
        """Snapshots accepted but not yet applied to the fleet."""
        return (self.submitted - self.processed
                - self.dropped_stale - self.dropped_overflow)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class _Request:
    slot: int
    x: np.ndarray
    d: object
    generation: int
    cohort: str


class FleetServer:
    """Admit/evict/query/checkpoint cohorts of RLS filters over a fleet.

    Parameters
    ----------
    fleet : RLSFleet
        The state machine (``mode='block'`` fleets are not servable —
        the queue batches single snapshots; use unit or float modes).
    batch : int
        Fixed snapshot-batch size for the donated step (short batches
        are padded, never recompiled).
    queue_limit : int
        Bound on queued snapshots across all cohorts.
    overflow : str
        ``'raise'`` — `submit` raises when full; ``'drop'`` — the new
        snapshot is dropped and counted against its cohort.
    ckpt_dir : str, optional
        Enables `checkpoint` / `restore_latest` via `CheckpointManager`.
    keep : int
        Checkpoints retained (keep-last-k).
    max_cohorts : int
        Monitor capacity (cohort ids are monitor host ids).
    beat_timeout, lag_steps :
        `ClusterMonitor` thresholds — a cohort with no processed
        snapshot for `beat_timeout` seconds is "dead" (traffic stopped);
        one whose last-processed server step trails the median by more
        than `lag_steps` twice in a row is a straggler.
    """

    def __init__(self, fleet: RLSFleet, *, batch: int = 256,
                 queue_limit: int = 4096, overflow: str = "raise",
                 ckpt_dir: Optional[str] = None, keep: int = 3,
                 max_cohorts: int = 64, beat_timeout: float = 60.0,
                 lag_steps: int = 1000):
        if fleet.mode == "block":
            raise ValueError(
                "FleetServer batches single snapshots; block-mode fleets "
                "take stacked snapshot groups — drive them directly via "
                "RLSFleet.update")
        if overflow not in ("raise", "drop"):
            raise ValueError(f"overflow must be 'raise' or 'drop', "
                             f"got {overflow!r}")
        if batch < 1 or queue_limit < batch:
            raise ValueError("need batch >= 1 and queue_limit >= batch")
        self.fleet = fleet
        self.batch = int(batch)
        self.queue_limit = int(queue_limit)
        self.overflow = overflow
        self.monitor = ClusterMonitor(max_cohorts, beat_timeout=beat_timeout,
                                      lag_steps=lag_steps)
        self.step = 0          # snapshot-batches pumped
        self._queue: deque = deque()
        self._cohorts: Dict[str, Cohort] = {}
        self._lock = threading.RLock()
        self._ckpt = None
        if ckpt_dir is not None:
            from repro.checkpoint.ckpt import CheckpointManager
            self._ckpt = CheckpointManager(ckpt_dir, keep=keep)

    # -- cohort lifecycle -----------------------------------------------------
    def _free_range(self, size: int) -> int:
        """First contiguous run of `size` free slots (first-fit)."""
        occ = np.asarray(self.fleet.state.occupied)
        start = 0
        while start + size <= occ.size:
            span = occ[start:start + size]
            hits = np.flatnonzero(span)
            if hits.size == 0:
                return start
            start += int(hits[-1]) + 1  # skip past the last conflict
        raise RuntimeError(
            f"no contiguous range of {size} free slots in a "
            f"{occ.size}-slot fleet (occupancy {int(occ.sum())})")

    def admit_cohort(self, name: str, size: int, *, lam=None,
                     delta=None) -> Cohort:
        """Admit `size` fresh filters as cohort `name` (contiguous slots)."""
        with self._lock:
            if name in self._cohorts:
                raise ValueError(f"cohort {name!r} already admitted")
            used = {c.cid for c in self._cohorts.values()}
            free_cids = [i for i in range(self.monitor.n_hosts)
                         if i not in used]
            if not free_cids:
                raise RuntimeError(f"max_cohorts={self.monitor.n_hosts} "
                                   "cohorts already admitted")
            start = self._free_range(size)
            self.fleet.admit(slot_ids=np.arange(start, start + size),
                             lam=lam, delta=delta)
            cohort = Cohort(name=name, cid=free_cids[0], start=start,
                            stop=start + size)
            self._cohorts[name] = cohort
            self.monitor.record_heartbeat(cohort.cid, self.step)
            return cohort

    def evict_cohort(self, name: str) -> Cohort:
        """Evict a cohort: queued snapshots are dropped, slots freed."""
        with self._lock:
            cohort = self._cohort(name)
            kept = deque()
            for req in self._queue:
                if req.cohort == name:
                    cohort.dropped_stale += 1
                else:
                    kept.append(req)
            self._queue = kept
            self.fleet.evict(np.arange(cohort.start, cohort.stop))
            del self._cohorts[name]
            return cohort

    def _cohort(self, name: str) -> Cohort:
        try:
            return self._cohorts[name]
        except KeyError:
            raise KeyError(f"unknown cohort {name!r}; admitted: "
                           f"{sorted(self._cohorts)}") from None

    def cohorts(self) -> List[Cohort]:
        with self._lock:
            return list(self._cohorts.values())

    # -- request path ---------------------------------------------------------
    def submit(self, name: str, member: int, x, d) -> bool:
        """Enqueue one snapshot for cohort member `member` (0-based offset).

        Returns True if accepted; False if dropped by the ``'drop'``
        overflow policy.  Raises under ``'raise'`` when the queue is full.
        """
        with self._lock:
            cohort = self._cohort(name)
            if not 0 <= member < cohort.size:
                raise IndexError(f"member {member} out of range for cohort "
                                 f"{name!r} of size {cohort.size}")
            cohort.submitted += 1
            if len(self._queue) >= self.queue_limit:
                if self.overflow == "raise":
                    cohort.submitted -= 1
                    raise RuntimeError(
                        f"request queue full ({self.queue_limit}); "
                        f"pump() or use overflow='drop'")
                cohort.dropped_overflow += 1
                return False
            slot = cohort.start + member
            gen = int(np.asarray(self.fleet.state.generation)[slot])
            x = np.asarray(x)
            if x.shape != (self.fleet.n,):
                raise ValueError(f"snapshot x must have shape "
                                 f"({self.fleet.n},), got {x.shape}")
            self._queue.append(_Request(slot, x, d, gen, name))
            return True

    def submit_batch(self, name: str, members, X, d) -> int:
        """Enqueue many snapshots for one cohort; returns accepted count."""
        members = np.asarray(members).ravel()
        X = np.asarray(X)
        d = np.asarray(d).ravel()
        ok = 0
        for m, xi, di in zip(members, X, d):
            ok += bool(self.submit(name, int(m), xi, di))
        return ok

    def _next_batch(self):
        """Pop <= `batch` queued requests with distinct slots (FIFO per
        slot), dropping stale-generation requests along the way."""
        gen = np.asarray(self.fleet.state.generation)
        taken, deferred, seen = [], [], set()
        while self._queue and len(taken) < self.batch:
            req = self._queue.popleft()
            cohort = self._cohorts.get(req.cohort)
            if cohort is None or gen[req.slot] != req.generation:
                if cohort is not None:
                    cohort.dropped_stale += 1
                continue
            if req.slot in seen:
                deferred.append(req)  # second snapshot for a slot: next batch
                continue
            seen.add(req.slot)
            taken.append(req)
        self._queue.extendleft(reversed(deferred))
        return taken

    def pump(self, max_batches: Optional[int] = None) -> int:
        """Drain the queue through the fleet's donated step.

        Returns the number of snapshots applied.  Each batch advances
        `step` and heartbeats every cohort it contained.
        """
        applied = 0
        with self._lock:
            while self._queue and (max_batches is None or max_batches > 0):
                taken = self._next_batch()
                if not taken:
                    break
                n, B = self.fleet.n, self.batch
                pad = B - len(taken)
                dt = self.fleet.dtype
                slot_ids = np.fromiter(
                    (r.slot for r in taken), dtype=np.int32, count=len(taken))
                slot_ids = np.concatenate(
                    [slot_ids, np.full(pad, self.fleet.slots, np.int32)])
                X = np.zeros((B, n), dtype=dt)
                d = np.zeros((B,), dtype=dt)
                for i, r in enumerate(taken):
                    X[i] = r.x
                    d[i] = r.d
                valid = np.arange(B) < len(taken)
                self.fleet.update(slot_ids, X, d, valid=valid)
                self.step += 1
                for r in taken:
                    self._cohorts[r.cohort].processed += 1
                for cid in {self._cohorts[r.cohort].cid for r in taken}:
                    self.monitor.record_heartbeat(cid, self.step)
                applied += len(taken)
                if max_batches is not None:
                    max_batches -= 1
        return applied

    # -- query ----------------------------------------------------------------
    def query(self, name: str, members=None, ridge: float = 1e-12):
        """Weights for cohort members — ``(len(members), n)`` ndarray."""
        with self._lock:
            cohort = self._cohort(name)
            if members is None:
                members = np.arange(cohort.size)
            members = np.asarray(members).ravel()
            if members.size and (members.min() < 0
                                 or members.max() >= cohort.size):
                raise IndexError(f"members out of range for cohort "
                                 f"{name!r} of size {cohort.size}")
            return self.fleet.weights(cohort.start + members, ridge=ridge)

    # -- health ---------------------------------------------------------------
    def health(self, now: Optional[float] = None) -> dict:
        """Occupancy, queue depth, per-cohort backlog, dead/stragglers."""
        with self._lock:
            by_cid = {c.cid: c.name for c in self._cohorts.values()}
            dead = [by_cid[h] for h in self.monitor.dead_hosts(now)
                    if h in by_cid]
            lagging = [by_cid[h] for h in self.monitor.stragglers()
                       if h in by_cid]
            return {
                "step": self.step,
                "slots": self.fleet.slots,
                "occupancy": self.fleet.occupancy,
                "queue_depth": len(self._queue),
                "cohorts": {c.name: {"size": c.size, "backlog": c.backlog,
                                     "submitted": c.submitted,
                                     "processed": c.processed,
                                     "dropped_stale": c.dropped_stale,
                                     "dropped_overflow": c.dropped_overflow}
                            for c in self._cohorts.values()},
                "dead_cohorts": dead,
                "straggler_cohorts": lagging,
            }

    # -- checkpoint / restore -------------------------------------------------
    def _require_ckpt(self):
        if self._ckpt is None:
            raise RuntimeError("server was built without ckpt_dir=")
        return self._ckpt

    def _extra(self) -> dict:
        return {"server_step": self.step,
                "cohorts": [c.as_dict() for c in self._cohorts.values()]}

    def checkpoint(self, wait: bool = False):
        """Async whole-fleet checkpoint (state + cohort table)."""
        mgr = self._require_ckpt()
        with self._lock:
            mgr.save_async(self.step, self.fleet.state, extra=self._extra())
        if wait:
            mgr.wait()

    def restore_latest(self) -> Optional[int]:
        """Restore the newest checkpoint: fleet state AND cohort table.

        Returns the restored server step, or None if no checkpoint exists.
        Queued (pre-restore) requests are cleared — their generations no
        longer describe the restored fleet.
        """
        mgr = self._require_ckpt()
        with self._lock:
            step, tree, extra = mgr.restore_latest(self.fleet.template())
            if step is None:
                return None
            self.fleet.load_state(tree)
            self._queue.clear()
            self._cohorts = {}
            for c in extra.get("cohorts", []):
                cohort = Cohort(**c)
                self._cohorts[cohort.name] = cohort
                self.monitor.record_heartbeat(cohort.cid, self.step)
            self.step = int(extra.get("server_step", step))
            return self.step

    def wait(self):
        """Block until any in-flight checkpoint lands (surfaces errors)."""
        if self._ckpt is not None:
            self._ckpt.wait()
