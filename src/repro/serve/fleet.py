"""`RLSFleet` — millions of concurrent QRD-RLS filter states as ONE pytree.

PR 3's `RLSState` is a single in-process object: one ``[R | z]`` pair,
one Python attribute per field.  A serving deployment (per-user
equalizers, beamforming channels) holds *millions* of such states and
updates thousands per second; looping over Python objects cannot keep
up, and neither can a pytree-of-objects (N separate small buffers).  The
fleet therefore stores all N states **struct-of-arrays**: one slot-major
array per field, so the whole fleet is a handful of large buffers and a
snapshot batch touches them with one gather → one vectorized
annihilation → one scatter.

`FleetState` (the carried pytree) is a NamedTuple of slot-major arrays:

* ``work``       (N, n, n+1) — the per-slot carried ``[R | z]``,
  float64 for the real datapaths and complex128 for the complex one.
  The carried domain is the *decoded* float domain (exactly as
  `RLSState` keeps it): the forgetting multiply ``√λ·[R | z]`` happens
  in float64 *before* the unit's input converter rounds, so storing the
  packed words instead would double-round the cold-start state and break
  bit-parity with the single-state reference.
* ``lam``        (N,)  float64 — per-slot forgetting factor λ.
* ``occupied``   (N,)  bool — slot occupancy mask.
* ``generation`` (N,)  int32 — bumped on every admit/evict so stale
  requests addressed to a recycled slot are detectable.
* ``updates``    (N,)  int32 — snapshots absorbed per slot.

The hot path is ONE jitted, **donated** step per batch of snapshots::

    fleet.update(slot_ids, X, d)     # (B,), (B, n), (B,)

which gathers the targeted rows, runs the existing `repro.qrd` RLS
annihilation paths vectorized over the batch — the bit-accurate
`GivensUnit.annihilate` / `annihilate_complex` recursion for the cordic
family, the kernel-resident ``givens_block_apply`` block path, or the
f64 conjugate-Givens loop — and scatters the results back **in place**:
``jax.jit(..., donate_argnums=0)`` hands the previous state's buffers to
XLA, so a steady-state serving loop performs zero per-step reallocation
(verified by ``is_deleted`` assertions in tests/test_serve_fleet.py).
Padded / stale batch entries carry the out-of-range sentinel slot id N
(gathers clip, scatters drop) plus a ``valid`` mask, so every batch
shape is fixed and one compilation serves the whole stream.

Because the vectorized paths run the *same* jitted element ops as the
single-state `RLSState`, an occupied fleet slot is **bit-identical** to
an independently driven `RLSState` on the IEEE, HUB and complex unit
paths — the acceptance contract of DESIGN.md §12.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

import typing

from repro.qrd.rls import validate_lam
from repro.qrd.solve import back_substitute

__all__ = ["FleetState", "RLSFleet", "validate_lam"]

_MODES = ("float", "unit", "block")


class FleetState(typing.NamedTuple):
    """Slot-major struct-of-arrays fleet state (a jit/donation-friendly
    pytree; see the module docstring for the per-field layout)."""

    work: jax.Array        # (N, n, n+1) float64 | complex128
    lam: jax.Array         # (N,) float64
    occupied: jax.Array    # (N,) bool
    generation: jax.Array  # (N,) int32
    updates: jax.Array     # (N,) int32


class RLSFleet:
    """N independent QRD-RLS filter states, updated as one batched pytree.

    Parameters
    ----------
    slots : int
        Fleet capacity N (slots are admitted/evicted individually; the
        buffers are allocated once, up front).
    n : int
        Filter length (size of each carried triangular R).
    mode : str
        ``'unit'`` (bit-accurate `GivensUnit` recursion — IEEE/HUB/
        complex), ``'block'`` (kernel-resident ``givens_block_apply``
        of ``block`` stacked snapshots per slot per call) or ``'float'``
        (f64 conjugate-Givens loop).  Usually chosen by
        `repro.qrd.QRDEngine.fleet` from the backend.
    unit : GivensUnit, required for ``mode='unit'``.
    lam, delta : float
        Default forgetting factor / cold-start diagonal loading applied
        by `admit` (λ can be overridden per admit — it is per-slot
        state).
    dtype : str
        ``'float64'`` or ``'complex128'`` (complex only on the unit and
        float modes, exactly as `RLSState`).
    block, hub, iters, frac, interpret :
        Blocked-kernel parameters (``mode='block'``).
    mesh : jax.sharding.Mesh, optional
        When set, every state leaf is placed with its slot axis sharded
        across the mesh's data axes (`repro.launch.sharding.shard_fleet`)
        — the fleet analogue of ``QRDConfig.mesh``.

    Notes
    -----
    The carried state lives in ``self.state`` (a `FleetState`); `update`
    *replaces* it with the donated-step output, so host references to a
    previous state observe deleted buffers — snapshot with
    `export_state` / checkpointing, not by aliasing ``fleet.state``.
    """

    def __init__(self, slots, n, *, mode="unit", unit=None, lam=0.99,
                 delta=1e-3, dtype="float64", block=4, hub=True, iters=24,
                 frac=24, interpret=None, mesh=None):
        if mode not in _MODES:
            raise ValueError(f"unknown mode {mode!r}; expected one of {_MODES}")
        if mode == "unit" and unit is None:
            raise ValueError("mode='unit' needs a GivensUnit")
        if dtype not in ("float64", "complex128"):
            raise ValueError(f"dtype must be 'float64' or 'complex128', "
                             f"got {dtype!r}")
        if mode == "block" and dtype == "complex128":
            raise TypeError("the blocked-kernel RLS path has no complex "
                            "datapath; use mode='unit' or mode='float' for "
                            "complex QRD-RLS fleets")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        validate_lam(lam)
        self.slots = int(slots)
        self.n = int(n)
        self.mode = mode
        self.unit = unit
        self.lam = float(lam)
        self.delta = float(delta)
        self.dtype = np.dtype(dtype)
        self.block = int(block)
        self._blockfp = dict(hub=hub, iters=iters, frac=frac,
                             interpret=interpret)
        self.mesh = mesh
        N, width = self.slots, self.n + 1
        self.state = FleetState(
            work=jnp.zeros((N, self.n, width), dtype=self.dtype),
            lam=jnp.full((N,), self.lam, dtype=jnp.float64),
            occupied=jnp.zeros((N,), dtype=bool),
            generation=jnp.zeros((N,), dtype=jnp.int32),
            updates=jnp.zeros((N,), dtype=jnp.int32),
        )
        self._place()
        self._update_fn = jax.jit(self._make_step(), donate_argnums=(0,))
        self._weights_fn = jax.jit(self._make_weights())

    # -- introspection --------------------------------------------------------
    @property
    def is_complex(self):
        return self.dtype.kind == "c"

    @property
    def occupancy(self):
        """Occupied-slot count (host int)."""
        return int(np.asarray(self.state.occupied).sum())

    def __repr__(self):
        return (f"RLSFleet(slots={self.slots}, n={self.n}, "
                f"mode={self.mode!r}, dtype={self.dtype.name!r}, "
                f"occupied={self.occupancy})")

    def _place(self):
        if self.mesh is not None:
            from repro.launch.sharding import shard_fleet
            self.state = shard_fleet(self.state, self.mesh)

    # -- the donated batched step --------------------------------------------
    def _make_step(self):
        """Build the jitted step: gather → vectorized annihilate → scatter.

        All three paths share the wrapper: ``slot_ids`` may contain the
        sentinel N for padded entries (gather clips, scatter drops), and
        ``valid & occupied`` masks the write-back so invalid or evicted
        entries leave their slots bit-untouched.
        """
        n, mode = self.n, self.mode

        def gather(state, slot_ids):
            rows = jnp.take(state.work, slot_ids, axis=0, mode="clip")
            lam = jnp.take(state.lam, slot_ids, mode="clip")
            occ = jnp.take(state.occupied, slot_ids, mode="clip")
            return rows, lam, occ

        def scatter(state, slot_ids, rows, out, mask, count):
            new_rows = jnp.where(mask[:, None, None], out, rows)
            work = state.work.at[slot_ids].set(new_rows, mode="drop")
            inc = jnp.where(mask, jnp.int32(count), jnp.int32(0))
            updates = state.updates.at[slot_ids].add(inc, mode="drop")
            return state._replace(work=work, updates=updates)

        if mode == "unit":
            unit = self.unit
            if self.is_complex:
                from repro.core.qrd import _decode_complex, _encode_complex

                def annihilate(scaled, snap):
                    P = _encode_complex(unit, scaled)
                    prow = _encode_complex(unit, snap)

                    def body(k, carry):
                        P, prow = carry
                        xk, prow = unit.annihilate_complex(P[:, k], prow, k)
                        return P.at[:, k].set(xk), prow

                    P, _ = jax.lax.fori_loop(0, n, body, (P, prow))
                    return _decode_complex(unit, P)
            else:
                def annihilate(scaled, snap):
                    P = unit.encode(scaled)
                    prow = unit.encode(snap)

                    def body(k, carry):
                        P, prow = carry
                        xk, prow = unit.annihilate(P[:, k], prow, k)
                        return P.at[:, k].set(xk), prow

                    P, _ = jax.lax.fori_loop(0, n, body, (P, prow))
                    return unit.decode(P)
        elif mode == "float":
            def annihilate(scaled, snap):
                # Conjugate Givens, vectorized over the batch axis; the
                # conjugation is the identity for real dtypes, matching
                # RLSState's float path element for element.
                out, row = scaled, snap
                for k in range(n):
                    a, b = out[:, k, k], row[:, k]
                    r = jnp.hypot(jnp.abs(a), jnp.abs(b))
                    safe = r > 0.0
                    rs = jnp.where(safe, r, 1.0)
                    c = (jnp.conj(a) / rs)[:, None]
                    s = (jnp.conj(b) / rs)[:, None]
                    wk = c * out[:, k] + s * row
                    nrow = -jnp.conj(s) * out[:, k] + jnp.conj(c) * row
                    nrow = nrow.at[:, k].set(0.0)
                    wk = wk.at[:, k].set(r.astype(out.dtype))
                    out = out.at[:, k].set(
                        jnp.where(safe[:, None], wk, out[:, k]))
                    row = jnp.where(safe[:, None], nrow, row)
                return out

        if mode in ("unit", "float"):
            def step(state, slot_ids, X, d, valid):
                rows, lam, occ = gather(state, slot_ids)
                mask = valid & occ
                snap = jnp.concatenate(
                    [X, d[:, None]], axis=1).astype(state.work.dtype)
                scaled = rows * jnp.sqrt(lam)[:, None, None]
                out = annihilate(scaled, snap)
                return scatter(state, slot_ids, rows, out, mask, 1)

            return step

        # mode == 'block': k snapshots per slot per call, annihilated by
        # one kernel-resident blocked schedule with the forgetting
        # telescoped exactly as RLSState.flush does.
        blockfp, blk = self._blockfp, self.block

        def step(state, slot_ids, X, d, valid):
            from repro.kernels import ops as kops
            rows, lam, occ = gather(state, slot_ids)
            mask = valid & occ
            lam_half = jnp.sqrt(lam)
            top = rows * (lam_half ** blk)[:, None, None]
            exps = jnp.arange(blk - 1, -1, -1, dtype=jnp.float64)
            w_snap = lam_half[:, None] ** exps[None, :]
            snaps = jnp.concatenate(
                [X, d[..., None]], axis=-1).astype(state.work.dtype)
            snaps = snaps * w_snap[..., None]
            W = jnp.concatenate([top, snaps], axis=1)      # (B, n+blk, n+1)
            steps = kops.rls_block_steps(self.n, blk)
            Wp = kops.givens_block_apply(W, steps, **blockfp)
            return scatter(state, slot_ids, rows, Wp[:, :self.n, :],
                           mask, blk)

        return step

    def update(self, slot_ids, X, d, valid=None):
        """Absorb one snapshot batch: scatter ``(x, d)`` pairs into slots.

        Parameters
        ----------
        slot_ids : (B,) int array
            Target slot per snapshot.  Entries MUST be distinct within a
            batch (the scatter is unordered for duplicates — the server's
            batcher enforces this); padded entries use the sentinel
            ``fleet.slots`` and ``valid=False``.
        X : (B, n) array — or ``(B, block, n)`` in ``mode='block'``
            (``block`` stacked snapshots per slot per call).
        d : (B,) array — or ``(B, block)`` in ``mode='block'``.
        valid : (B,) bool, optional
            Mask of live entries (default: all valid).  Invalid entries
            and entries addressing unoccupied slots leave their slots
            bit-untouched and do not advance ``updates``.

        Returns
        -------
        self (for chaining).  The previous ``FleetState``'s buffers are
        donated to the step and must not be read afterwards.
        """
        slot_ids = jnp.asarray(slot_ids, dtype=jnp.int32)
        X = jnp.asarray(X)
        d = jnp.asarray(d)
        if ((X.dtype.kind == "c" or d.dtype.kind == "c")
                and not self.is_complex):
            raise TypeError(
                "complex snapshot batch on a real-dtype fleet (no silent "
                "real cast); create the fleet with dtype='complex128'")
        want = 3 if self.mode == "block" else 2
        if X.ndim != want or X.shape[-1] != self.n:
            raise ValueError(
                f"mode={self.mode!r} expects X of shape "
                f"{'(B, block, n)' if want == 3 else '(B, n)'} with "
                f"n={self.n}, got {X.shape}")
        if self.mode == "block" and X.shape[1] != self.block:
            raise ValueError(f"block fleet expects {self.block} snapshots "
                             f"per slot per call, got {X.shape[1]}")
        if d.shape != X.shape[:-1]:
            raise ValueError(f"d shape {d.shape} != {X.shape[:-1]}")
        if valid is None:
            valid = jnp.ones(slot_ids.shape, dtype=bool)
        else:
            valid = jnp.asarray(valid, dtype=bool)
        self.state = self._update_fn(self.state, slot_ids, X, d, valid)
        return self

    # -- slot lifecycle -------------------------------------------------------
    def _check_ids(self, slot_ids):
        ids = np.asarray(slot_ids, dtype=np.int64).ravel()
        if ids.size and (ids.min() < 0 or ids.max() >= self.slots):
            raise IndexError(f"slot ids out of range [0, {self.slots})")
        if np.unique(ids).size != ids.size:
            raise ValueError("duplicate slot ids")
        return ids

    def admit(self, count=None, slot_ids=None, *, lam=None, delta=None):
        """Admit filters into free slots: reset state, bump generation.

        Parameters
        ----------
        count : int — admit this many filters into the lowest free
            slots; or
        slot_ids : explicit free slot ids to admit into.
        lam : scalar or (B,) array, optional — per-slot forgetting
            factor (validated ``0 < lam <= 1``); defaults to the fleet's.
        delta : float, optional — cold-start diagonal loading.

        Returns
        -------
        (B,) int64 ndarray of admitted slot ids.
        """
        occ = np.asarray(self.state.occupied)
        if slot_ids is None:
            if count is None:
                raise ValueError("admit() needs count= or slot_ids=")
            free = np.flatnonzero(~occ)
            if count > free.size:
                raise RuntimeError(
                    f"fleet full: {count} slots requested, "
                    f"{free.size} free of {self.slots}")
            ids = free[:count]
        else:
            ids = self._check_ids(slot_ids)
            if occ[ids].any():
                busy = ids[occ[ids]][:8]
                raise ValueError(f"admit of occupied slot(s) {busy.tolist()}"
                                 " — evict first")
        lam_arr = validate_lam(self.lam if lam is None else lam)
        # validate_lam returns float64 (and rejects complex), so a bare
        # broadcast is dtype-safe here.
        lam_arr = np.broadcast_to(lam_arr, ids.shape)
        delta = self.delta if delta is None else float(delta)
        init = jnp.eye(self.n, self.n + 1, dtype=self.dtype) * delta
        rows = jnp.broadcast_to(init, (ids.size, self.n, self.n + 1))
        jids = jnp.asarray(ids)
        st = self.state
        # unique_indices: `free` slots are distinct by construction and
        # _check_ids raises on duplicate caller ids, so XLA may skip the
        # serialized-scatter fallback.
        self.state = FleetState(
            work=st.work.at[jids].set(rows, unique_indices=True),
            lam=st.lam.at[jids].set(jnp.asarray(lam_arr),
                                    unique_indices=True),
            occupied=st.occupied.at[jids].set(True, unique_indices=True),
            generation=st.generation.at[jids].add(1, unique_indices=True),
            updates=st.updates.at[jids].set(0, unique_indices=True),
        )
        self._place()
        return ids

    def evict(self, slot_ids):
        """Evict slots: clear occupancy, bump generation (state rows are
        left stale — admit overwrites them)."""
        ids = self._check_ids(slot_ids)
        occ = np.asarray(self.state.occupied)
        if not occ[ids].all():
            idle = ids[~occ[ids]][:8]
            raise ValueError(f"evict of unoccupied slot(s) {idle.tolist()}")
        jids = jnp.asarray(ids)
        st = self.state
        # unique_indices: _check_ids raises on duplicate ids.
        self.state = st._replace(
            occupied=st.occupied.at[jids].set(False, unique_indices=True),
            generation=st.generation.at[jids].add(1, unique_indices=True),
        )
        self._place()
        return ids

    def generation_of(self, slot_ids):
        """Host-side generation counters for `slot_ids` (stale-request
        detection)."""
        return np.asarray(self.state.generation)[
            self._check_ids(slot_ids)]

    # -- readout --------------------------------------------------------------
    def _make_weights(self):
        n = self.n

        def weights(work, slot_ids, ridge):
            rows = jnp.take(work, slot_ids, axis=0, mode="clip")
            R = rows[..., :n] + ridge * jnp.eye(n, dtype=rows.dtype)
            return back_substitute(R, rows[..., n])

        return weights

    def weights(self, slot_ids, ridge=1e-12):
        """Back-substitute ``R w = z`` for a batch of slots.

        Returns a ``(B, n)`` float64 (complex128) ndarray — bit-identical
        to `RLSState.weights` on each occupied slot.
        """
        ids = self._check_ids(slot_ids)
        return np.asarray(self._weights_fn(self.state.work, jnp.asarray(ids),
                                           ridge))

    def predict(self, slot_ids, X):
        """Filter outputs ``x_iᵀ w_i`` for one snapshot per slot."""
        X = np.asarray(X).astype(self.dtype)
        return np.einsum("bn,bn->b", X, self.weights(slot_ids))

    # -- single-state interop (RLSState.to_arrays schema) ---------------------
    def export_state(self, slot):
        """Export one slot as an `RLSState.from_arrays`-compatible pytree."""
        (slot,) = self._check_ids([slot])
        if not bool(np.asarray(self.state.occupied)[slot]):
            raise ValueError(f"slot {slot} is not occupied")
        row = np.asarray(self.state.work[slot])
        return {
            "R": row[:, :self.n].copy(),
            "z": row[:, self.n].copy(),
            "lam": np.float64(np.asarray(self.state.lam)[slot]),
            "updates": np.int64(np.asarray(self.state.updates)[slot]),
            "pending": np.zeros((0, self.n + 1), dtype=self.dtype),
            "pending_count": np.int64(0),
        }

    def import_state(self, slot, arrays):
        """Admit `arrays` (the `RLSState.to_arrays` schema) into a free slot.

        The donor state must have an empty pending buffer
        (``RLSState.flush()`` first) — the fleet has no per-slot pending;
        batching lives in the server's queue, not in device state.
        """
        if int(arrays.get("pending_count", 0)) != 0:
            raise ValueError("cannot import a state with pending snapshots; "
                             "call RLSState.flush() first")
        R = np.asarray(arrays["R"])
        z = np.asarray(arrays["z"])
        if R.shape != (self.n, self.n) or z.shape != (self.n,):
            raise ValueError(f"state shape mismatch: R {R.shape}, z {z.shape}"
                             f" vs fleet n={self.n}")
        (slot,) = self.admit(slot_ids=[slot], lam=float(arrays["lam"]))
        row = np.concatenate([R, z[:, None]], axis=1).astype(self.dtype)
        st = self.state
        # unique_indices: `slot` is a single admitted slot id.
        self.state = st._replace(
            work=st.work.at[slot].set(jnp.asarray(row),
                                      unique_indices=True),
            updates=st.updates.at[slot].set(
                jnp.int32(int(arrays["updates"])), unique_indices=True),
        )
        self._place()
        return slot

    # -- checkpoint interop ---------------------------------------------------
    def template(self):
        """A `FleetState` of the live structure/shapes/dtypes — the
        restore template for `repro.checkpoint.restore_pytree`."""
        return self.state

    def load_state(self, state: FleetState):
        """Replace the carried fleet state (checkpoint restore path)."""
        if jax.tree.structure(state) != jax.tree.structure(self.state):
            raise ValueError("restored pytree structure does not match")
        for new, cur in zip(jax.tree.leaves(state),
                            jax.tree.leaves(self.state)):
            if (tuple(new.shape) != tuple(cur.shape)
                    or np.dtype(new.dtype) != np.dtype(cur.dtype)):
                raise ValueError(
                    f"restored leaf {new.shape}/{new.dtype} does not match "
                    f"fleet {cur.shape}/{cur.dtype}")
        self.state = FleetState(*[jnp.asarray(l)
                                  for l in jax.tree.leaves(state)])
        self._place()
        return self
