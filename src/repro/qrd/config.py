"""Unified QRD problem configuration (DESIGN.md §9).

`QRDConfig` consolidates every knob that used to be scattered across the
free functions — ``steps``/``stages`` schedule selection, the blockfp
``iters``/``hub``/``frac`` trio, the ``fixed_*`` baseline parameters —
plus an optional sharding ``mesh`` so the batch-sharded path
(`qr_blocked_sharded`) folds into plain ``engine(A)`` dispatch.

The config is a frozen dataclass: hashable (it participates in the
engine's jitted-callable cache key) except for ``mesh``, which is
excluded from equality/hash and keyed by identity instead (meshes are
runtime placement, not arithmetic).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax.numpy as jnp

from repro.core.formats import FloatFormat
from repro.core.givens import GivensConfig

__all__ = ["QRDConfig"]


@dataclasses.dataclass(frozen=True)
class QRDConfig:
    """Everything a QRD problem dispatch depends on.

    Parameters
    ----------
    backend : str
        A registered backend name (`repro.qrd.registry.available_backends`).
    schedule : str
        ``'col'`` (column-major) or ``'sameh_kuck'`` (parallel pairing);
        backends with the ``wavefront`` capability route ``'sameh_kuck'``
        onto the stage-parallel datapath (DESIGN.md §8).
    givens : GivensConfig
        Unit parameters for the cordic family; ``'blockfp_pallas'``
        derives its defaults (``hub``, iteration count) from it.
    iters, hub, frac : optional overrides for the block-FP kernel
        ``None`` resolves from ``givens`` (``resolved_iters()`` /
        ``givens.hub``); ``frac`` is the fraction-bit count F of the int32
        significands (F=24 keeps m ≲ 64 inside int32).
    fixed_width, fixed_iters, fixed_scale_exp : int
        Parameters of the ``'fixed'`` baseline rotator of [20].
    dtype : str or dtype-like
        Element dtype of the problem.  Real dtypes select the real
        datapath (output dtype for the float backends ``'jnp'`` /
        ``'givens_float'``; the bit-accurate backends always return
        float64).  Complex dtypes (``'complex64'`` / ``'complex128'``)
        select the **complex datapath** (DESIGN.md §10) on
        complex-capable backends — three-rotation Givens on (re, im)
        lane pairs; the bit-accurate backends then return complex128
        (precision still comes from ``givens.fmt``).  Normalized to the
        canonical dtype name string on construction; requesting a
        complex dtype on a backend without complex capability raises
        ``TypeError`` at validation.
    interpret : bool, optional
        Forwarded to the Pallas kernels; ``None`` auto-selects
        (interpret on CPU, Mosaic on TPU).
    tile_b : int, optional
        Batch tile of the blocked Pallas kernels.  ``None`` consults the
        persisted autotune cache (`repro.kernels.autotune.lookup`) at
        dispatch time and falls back to the fixed ``TILE_B`` default on a
        cache miss; an explicit value always wins.
    table_layout : str, optional
        Stage-table memory layout of the wavefront kernels: ``'split'``
        (three separate (S, Pmax) operands) or ``'stacked'`` (one
        concatenated (3S, Pmax) operand — fewer kernel parameters, one
        contiguous DMA).  ``None`` resolves from the autotune cache like
        ``tile_b``.
    tiling : str, optional
        Route selection for the tiled QR layer (DESIGN.md §14):
        ``None``/``'auto'`` picks per-shape (flat for small single-tile
        operands, panel factorization for dense m up to the backend's
        ``max_shape``, TSQR tree reduction for tall-skinny / oversized
        m); ``'flat'`` forces the single-tile path (raises a shape error
        beyond ``max_shape`` instead of failing inside the kernel);
        ``'panel'`` / ``'tsqr'`` force the respective tiled route —
        requires the backend's ``supports_tiling`` capability.
    tile_m : int, optional
        Row-block height of the TSQR leaves (and the resident row count
        cap of the panel path).  ``None`` resolves from the autotune
        cache, falling back to the backend's ``max_shape`` rows; an
        explicit value always wins.
    panel_n : int, optional
        Column width of one panel in the panel/TSQR factorization.
        ``None`` resolves from the autotune cache, falling back to the
        built-in default (8); an explicit value always wins.
    mesh : jax.sharding.Mesh, optional
        When set, the engine places the operand's leading batch axis
        across the mesh's data axes before dispatch
        (`repro.launch.sharding.shard_qrd_batch`) — requires the
        backend's ``sharding`` capability.  Excluded from hash/equality.

    Use ``dataclasses.replace(cfg, ...)`` (or ``cfg.replace(...)``) to
    derive variants.
    """

    backend: str = "jnp"
    schedule: str = "col"
    givens: GivensConfig = dataclasses.field(default_factory=GivensConfig)
    iters: int | None = None
    hub: bool | None = None
    frac: int = 24
    fixed_width: int = 32
    fixed_iters: int = 27
    fixed_scale_exp: int = 0
    dtype: str = "float32"
    interpret: bool | None = None
    tile_b: int | None = None
    table_layout: str | None = None
    tiling: str | None = None
    tile_m: int | None = None
    panel_n: int | None = None
    mesh: Any = dataclasses.field(default=None, compare=False, repr=False)

    SCHEDULES = ("col", "sameh_kuck")
    TABLE_LAYOUTS = (None, "split", "stacked")
    TILINGS = (None, "auto", "flat", "panel", "tsqr")

    def __post_init__(self):
        # Normalize dtype-likes (jnp.complex64, np.dtype('float32'), ...) to
        # the canonical name so the frozen dataclass stays hashable and the
        # cache key is canonical.
        try:
            name = jnp.dtype(self.dtype).name
        except TypeError:
            raise TypeError(f"dtype must be a dtype or dtype name, got "
                            f"{self.dtype!r}") from None
        object.__setattr__(self, "dtype", name)

    def replace(self, **changes) -> "QRDConfig":
        return dataclasses.replace(self, **changes)

    def is_complex(self) -> bool:
        """Whether this config selects the complex datapath."""
        return jnp.dtype(self.dtype).kind == "c"

    # -- resolved block-FP parameters ----------------------------------------
    def blockfp_iters(self) -> int:
        return self.givens.resolved_iters() if self.iters is None else self.iters

    def blockfp_hub(self) -> bool:
        return self.givens.hub if self.hub is None else self.hub

    # -- declarative deployments: JSON round-trip ----------------------------
    def as_dict(self) -> dict:
        """JSON-ready dict of every *arithmetic* field.

        ``mesh`` is runtime placement, not arithmetic — it is excluded
        (exactly as it is excluded from hash/equality); reattach one on
        load with ``cfg.replace(mesh=mesh)``.  Nested `GivensConfig` /
        `FloatFormat` dataclasses recurse to plain dicts.
        """
        d = dataclasses.asdict(self)
        d.pop("mesh", None)
        return d

    def to_json(self, **json_kwargs) -> str:
        """Serialize to JSON (deterministic key order) — the declarative
        deployment format consumed by `repro.serve.presets` and
        ``launch/serve.py --config``."""
        json_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.as_dict(), **json_kwargs)

    @classmethod
    def from_dict(cls, d: dict) -> "QRDConfig":
        """Inverse of `as_dict` (strict: unknown keys raise)."""
        d = dict(d)
        g = d.get("givens")
        if isinstance(g, dict):
            g = dict(g)
            fmt = g.get("fmt")
            if isinstance(fmt, dict):
                g["fmt"] = FloatFormat(**fmt)
            d["givens"] = GivensConfig(**g)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown QRDConfig field(s) {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**d)

    @classmethod
    def from_json(cls, s: str) -> "QRDConfig":
        """Inverse of `to_json`: ``QRDConfig.from_json(cfg.to_json()) == cfg``."""
        return cls.from_dict(json.loads(s))

    def cache_key(self):
        """Hashable key covering *everything* dispatch depends on.

        The frozen dataclass hash already covers the arithmetic fields;
        ``mesh`` (compare=False) is appended by identity so that engines
        re-used across meshes miss the cache instead of returning arrays
        with stale placement.
        """
        return (self, None if self.mesh is None else id(self.mesh))

    def validate(self):
        """Early validation against the registry's capability metadata."""
        from . import registry
        spec = registry.get_backend(self.backend)  # raises w/ available set
        caps = spec.capabilities
        if self.schedule not in self.SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {self.SCHEDULES}")
        if self.table_layout not in self.TABLE_LAYOUTS:
            raise ValueError(
                f"unknown table_layout {self.table_layout!r}; "
                f"expected one of {self.TABLE_LAYOUTS}")
        if self.tile_b is not None and self.tile_b < 1:
            raise ValueError(f"tile_b must be >= 1, got {self.tile_b}")
        if self.tiling not in self.TILINGS:
            raise ValueError(f"unknown tiling {self.tiling!r}; "
                             f"expected one of {self.TILINGS}")
        if self.tile_m is not None and self.tile_m < 2:
            raise ValueError(f"tile_m must be >= 2, got {self.tile_m}")
        if self.panel_n is not None and self.panel_n < 1:
            raise ValueError(f"panel_n must be >= 1, got {self.panel_n}")
        if (self.tiling in ("panel", "tsqr")
                and not caps.supports_tiling):
            tiled = [n for n, c in registry.list_backends().items()
                     if c.supports_tiling]
            raise ValueError(
                f"backend {self.backend!r} has no tiled datapath "
                f"(tiling={self.tiling!r}); tiling-capable backends: "
                f"{', '.join(tiled)}")
        if self.schedule not in caps.schedules:
            raise ValueError(
                f"backend {self.backend!r} does not support "
                f"schedule={self.schedule!r} (supported: {caps.schedules})")
        if jnp.dtype(self.dtype).kind not in "fc":
            raise TypeError(
                f"dtype {self.dtype!r} is not a floating or complex dtype; "
                "QRD backends operate on real or complex matrices")
        if self.is_complex() and not caps.supports_complex:
            raise TypeError(
                f"backend {self.backend!r} has no complex datapath "
                f"(dtype={self.dtype!r}); complex-capable backends: "
                f"{', '.join(registry.complex_capable_backends())}")
        if self.mesh is not None and not caps.sharding:
            capable = [n for n, c in registry.list_backends().items()
                       if c.sharding]
            raise ValueError(
                f"backend {self.backend!r} has no sharding capability; "
                f"mesh dispatch is available on: {', '.join(capable)}")
        if caps.bit_exact:
            self.givens.validate()
        return spec
