"""Unified QRD problem configuration (DESIGN.md §9).

`QRDConfig` consolidates every knob that used to be scattered across the
free functions — ``steps``/``stages`` schedule selection, the blockfp
``iters``/``hub``/``frac`` trio, the ``fixed_*`` baseline parameters —
plus an optional sharding ``mesh`` so the batch-sharded path
(`qr_blocked_sharded`) folds into plain ``engine(A)`` dispatch.

The config is a frozen dataclass: hashable (it participates in the
engine's jitted-callable cache key) except for ``mesh``, which is
excluded from equality/hash and keyed by identity instead (meshes are
runtime placement, not arithmetic).
"""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.givens import GivensConfig

__all__ = ["QRDConfig"]


@dataclasses.dataclass(frozen=True)
class QRDConfig:
    """Everything a QRD problem dispatch depends on.

    Parameters
    ----------
    backend : str
        A registered backend name (`repro.qrd.registry.available_backends`).
    schedule : str
        ``'col'`` (column-major) or ``'sameh_kuck'`` (parallel pairing);
        backends with the ``wavefront`` capability route ``'sameh_kuck'``
        onto the stage-parallel datapath (DESIGN.md §8).
    givens : GivensConfig
        Unit parameters for the cordic family; ``'blockfp_pallas'``
        derives its defaults (``hub``, iteration count) from it.
    iters, hub, frac : optional overrides for the block-FP kernel
        ``None`` resolves from ``givens`` (``resolved_iters()`` /
        ``givens.hub``); ``frac`` is the fraction-bit count F of the int32
        significands (F=24 keeps m ≲ 64 inside int32).
    fixed_width, fixed_iters, fixed_scale_exp : int
        Parameters of the ``'fixed'`` baseline rotator of [20].
    dtype : str
        Output dtype for the float backends (``'jnp'``,
        ``'givens_float'``); the bit-accurate backends always return
        float64.
    interpret : bool, optional
        Forwarded to the Pallas kernels; ``None`` auto-selects
        (interpret on CPU, Mosaic on TPU).
    mesh : jax.sharding.Mesh, optional
        When set, the engine places the operand's leading batch axis
        across the mesh's data axes before dispatch
        (`repro.launch.sharding.shard_qrd_batch`) — requires the
        backend's ``sharding`` capability.  Excluded from hash/equality.

    Use ``dataclasses.replace(cfg, ...)`` (or ``cfg.replace(...)``) to
    derive variants.
    """

    backend: str = "jnp"
    schedule: str = "col"
    givens: GivensConfig = dataclasses.field(default_factory=GivensConfig)
    iters: int | None = None
    hub: bool | None = None
    frac: int = 24
    fixed_width: int = 32
    fixed_iters: int = 27
    fixed_scale_exp: int = 0
    dtype: str = "float32"
    interpret: bool | None = None
    mesh: Any = dataclasses.field(default=None, compare=False, repr=False)

    SCHEDULES = ("col", "sameh_kuck")

    def replace(self, **changes) -> "QRDConfig":
        return dataclasses.replace(self, **changes)

    # -- resolved block-FP parameters ----------------------------------------
    def blockfp_iters(self) -> int:
        return self.givens.resolved_iters() if self.iters is None else self.iters

    def blockfp_hub(self) -> bool:
        return self.givens.hub if self.hub is None else self.hub

    def cache_key(self):
        """Hashable key covering *everything* dispatch depends on.

        The frozen dataclass hash already covers the arithmetic fields;
        ``mesh`` (compare=False) is appended by identity so that engines
        re-used across meshes miss the cache instead of returning arrays
        with stale placement.
        """
        return (self, None if self.mesh is None else id(self.mesh))

    def validate(self):
        """Early validation against the registry's capability metadata."""
        from . import registry
        spec = registry.get_backend(self.backend)  # raises w/ available set
        caps = spec.capabilities
        if self.schedule not in self.SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"expected one of {self.SCHEDULES}")
        if self.schedule not in caps.schedules:
            raise ValueError(
                f"backend {self.backend!r} does not support "
                f"schedule={self.schedule!r} (supported: {caps.schedules})")
        if self.mesh is not None and not caps.sharding:
            capable = [n for n, c in registry.list_backends().items()
                       if c.sharding]
            raise ValueError(
                f"backend {self.backend!r} has no sharding capability; "
                f"mesh dispatch is available on: {', '.join(capable)}")
        if caps.bit_exact:
            self.givens.validate()
        return spec
