"""The solver-grade QRD engine: registry-dispatched, problem-level API.

`QRDEngine` here is the canonical surface (DESIGN.md §9); the legacy
``repro.core.QRDEngine`` dataclass is a thin shim over it.  Three layers:

* **decompose** — ``engine(A)`` / ``engine.decompose(A)``: batched
  ``(Q, R)`` via the registered backend, one jitted callable per
  ``(m, n, compute_q, config)`` held in a *bounded* LRU (churning many
  shapes evicts cold callables instead of growing without bound; see the
  repo's lru_cache tracer-leak pitfall — the cache stores only jitted
  callables keyed by static shape, never arrays from inside a trace).
* **solve** — ``engine.solve(A, b)``: batched least squares via the
  Q-free augmented-column trick + `repro.qrd.solve.back_substitute`.
* **rls** — ``engine.rls(n)``: a streaming QRD-RLS state
  (`repro.qrd.rls.RLSState`) on the backend-appropriate update path.
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp

from repro.kernels import autotune

from .config import QRDConfig
from .solve import lstsq_from_triangular

__all__ = ["QRDEngine"]


class QRDEngine:
    """Registry-dispatched batched QRD with problem-level methods.

    Parameters
    ----------
    config : QRDConfig, optional
        The problem configuration; defaults to ``QRDConfig()``.
    max_cache : int
        Bound on the jitted-callable LRU (distinct
        ``(m, n, compute_q, config)`` keys held at once); least-recently
        used entries are evicted beyond it.
    **overrides
        Field overrides applied on top of ``config`` — any `QRDConfig`
        field, e.g. ``backend='cordic_pallas'``, ``schedule='sameh_kuck'``,
        ``mesh=mesh``.  ``givens_config=`` is accepted as an alias for
        ``givens=`` (legacy spelling).

    Examples
    --------
    >>> eng = QRDEngine(backend='cordic_pallas',
    ...                 givens=GivensConfig(hub=True, n=26))
    >>> Q, R = eng(A)                      # decomposition
    >>> x = eng.solve(A, b)                # batched least squares
    >>> state = eng.rls(n)                 # streaming QRD-RLS
    """

    def __init__(self, config: QRDConfig | None = None, *, max_cache=32,
                 **overrides):
        if config is None:
            config = QRDConfig()
        if "givens_config" in overrides:
            overrides["givens"] = overrides.pop("givens_config")
        if overrides:
            config = config.replace(**overrides)
        self._spec = config.validate()   # raises early: bad backend/schedule
        self.config = config
        if max_cache < 1:
            raise ValueError("max_cache must be >= 1")
        self._max_cache = int(max_cache)
        self._fn_cache: OrderedDict = OrderedDict()

    # -- introspection --------------------------------------------------------
    @property
    def capabilities(self):
        """The configured backend's `BackendCapabilities`."""
        return self._spec.capabilities

    def __repr__(self):
        return (f"QRDEngine(backend={self.config.backend!r}, "
                f"schedule={self.config.schedule!r}, "
                f"cached={len(self._fn_cache)}/{self._max_cache})")

    # -- decomposition --------------------------------------------------------
    def _validate_operand(self, A, config: QRDConfig):
        """Validate the operand dtype against the backend's capabilities.

        Historically complex (and integer) operands were cast straight
        through ``jnp.asarray(..., float64)`` inside the backends — a
        complex matrix lost its imaginary part with nothing but a
        ``ComplexWarning`` from deep inside the cast.  Now:

        * bool/integer operands are promoted to float64 explicitly (an
          exact, documented promotion, as in ``np.linalg``);
        * complex operands require a complex-capable backend — otherwise
          ``TypeError`` names the backend and the complex-capable set —
          and are routed onto the complex datapath by upgrading the
          config's dtype to the matching complex dtype;
        * anything else (strings, objects) raises ``TypeError``.

        Returns the (possibly promoted) operand and the routing config.
        """
        A = jnp.asarray(A)
        kind = A.dtype.kind
        if kind in "biu":
            # lint: allow[narrowing-cast] bool/int -> float64 upcast only
            A = A.astype(jnp.float64)
        elif kind == "c":
            if not config.is_complex():
                from . import registry
                caps = registry.get_backend(config.backend).capabilities
                if not caps.supports_complex:
                    raise TypeError(
                        f"complex operand (dtype {A.dtype}) but backend "
                        f"{config.backend!r} has no complex datapath; "
                        "complex-capable backends: "
                        f"{', '.join(registry.complex_capable_backends())}."
                        "  Configure one with e.g. QRDConfig("
                        "backend='cordic', dtype='complex64'), or take "
                        "A.real explicitly if that was intended.")
                config = config.replace(dtype=A.dtype.name)
        elif kind != "f":
            raise TypeError(f"operand dtype {A.dtype} is not a real, "
                            "complex, or integer numeric dtype")
        return A, config

    @staticmethod
    def _resolve_tuned(config: QRDConfig, m: int, n: int) -> QRDConfig:
        """Fill tuned kernel parameters from the autotune cache.

        Only fires for the tunable Pallas backends, and only for fields
        the config left ``None`` (an explicit value always wins):
        ``tile_b``/``table_layout`` from the flat entry, and — when the
        shape routes onto a tiled datapath — ``panel_n``/``tile_m``
        from the ``/tiled-<route>`` entry (`autotune.tune_tiled`).
        Runs *before* jitted-callable cache-key formation so a cache
        entry appearing between calls misses the LRU instead of
        silently running the stale tile.  Cost on a tuned run is one
        ``os.stat`` (`repro.kernels.autotune.lookup` memoizes the file
        by mtime).
        """
        if config.backend not in autotune.TUNABLE_BACKENDS:
            return config
        if config.tile_b is None:
            hit = autotune.lookup(config.backend, config.schedule, m, n,
                                  config.dtype)
            if hit is not None:
                layout = (config.table_layout
                          if config.table_layout is not None
                          else hit.table_layout)
                config = config.replace(tile_b=hit.tile_b,
                                        table_layout=layout)
        if config.panel_n is None or config.tile_m is None:
            from . import registry, tiled
            caps = registry.get_backend(config.backend).capabilities
            if not caps.supports_tiling:
                return config
            try:
                route = tiled.resolve_route(config, m, n, caps)
            except ValueError:
                return config      # dispatch re-raises the clear error
            if route in ("panel", "tsqr"):
                hit = autotune.lookup(config.backend, "col", m, n,
                                      config.dtype, tiling=route)
                if hit is not None:
                    updates = {}
                    if config.panel_n is None and hit.panel_n is not None:
                        updates["panel_n"] = hit.panel_n
                    if config.tile_m is None and hit.tile_m is not None:
                        updates["tile_m"] = hit.tile_m
                    if updates:
                        config = config.replace(**updates)
        return config

    def _dispatch(self, A, compute_q, config: QRDConfig | None = None):
        """Registry dispatch with the bounded jitted-callable LRU.

        ``config`` defaults to the engine's own; the legacy shim passes a
        per-call config rebuilt from its mutable fields, so field
        mutation misses the cache instead of returning stale results.
        The operand dtype is validated against the backend capabilities
        first (`_validate_operand`) — complex operands route onto the
        complex datapath where capable and raise ``TypeError`` otherwise.
        `_resolve_tuned` then fills autotuned tile parameters before the
        cache key is formed.

        Shapes beyond the flat kernels' `BackendCapabilities.max_shape`
        route onto the tiled datapaths (`repro.qrd.tiled`): panel sweeps
        when the rows still fit one tile, TSQR tree reduction for
        tall-skinny operands.  `tiled.resolve_route` is deterministic in
        ``(m, n, config)`` — the cache key needs no route component —
        and raises a ``ValueError`` naming ``max_shape`` and the tiled
        alternatives when no route can hold the operand (instead of the
        opaque Pallas failure oversized shapes used to hit).  Note the
        TSQR route returns *economy* factors (``Q (m, n), R (n, n)``).
        """
        if config is None:
            config = self.config
        A, config = self._validate_operand(A, config)
        if A.ndim < 2:
            raise ValueError(f"expected (..., m, n) operand, got {A.shape}")
        m, n = A.shape[-2], A.shape[-1]
        config = self._resolve_tuned(config, m, n)
        key = (m, n, bool(compute_q), config.cache_key())
        fn = self._fn_cache.pop(key, None)
        if fn is None:
            from . import tiled
            spec = config.validate()
            route = tiled.resolve_route(config, m, n, spec.capabilities)
            if route == "flat":
                fn = jax.jit(spec.builder(config, m, n, bool(compute_q)))
            else:
                fn = jax.jit(tiled.build_tiled(route, config, m, n,
                                               bool(compute_q),
                                               spec.capabilities))
        self._fn_cache[key] = fn           # (re-)insert as most-recent
        while len(self._fn_cache) > self._max_cache:
            self._fn_cache.popitem(last=False)
        if config.mesh is not None:
            from repro.launch.sharding import shard_qrd_batch
            work_dtype = (jnp.complex128 if config.is_complex()
                          else jnp.float64)
            A = shard_qrd_batch(jnp.asarray(A, work_dtype), config.mesh)
        return fn(A)

    def __call__(self, A, compute_q=True):
        """Batched QRD: ``A (..., m, n) -> (Q, R)`` (Q None w/o compute_q)."""
        return self._dispatch(A, compute_q)

    decompose = __call__

    # -- least squares --------------------------------------------------------
    def solve(self, A, b, return_residuals=False):
        """Batched least squares ``min_x ||A x - b||`` without forming Q.

        The engine triangularizes the augmented matrix ``[A | b]`` with
        ``compute_q=False`` — the appended column(s) come out as ``Qᵀ b``
        under the same rotations that reduce A — then back-substitutes
        (`repro.qrd.solve`).  Runs on whatever backend/schedule/mesh this
        engine is configured with; per-backend accuracy vs
        ``np.linalg.lstsq`` is documented in
        `repro.qrd.solve.SOLVE_TOLERANCES`.

        Complex systems (complex ``A``/``b``, or a complex-dtype config)
        run on the complex datapath of a complex-capable backend: the
        rotations triangularizing ``[A | b]`` are unitary, the appended
        columns come out as ``Q^H b``, and the conjugate-aware
        back-substitution recovers x; residual norms are the usual
        ``√Σ|·|²`` over the annihilated tail.

        Parameters
        ----------
        A : (..., m, n) array_like, with ``m >= n`` (full-rank for a
            finite solution, as with any non-pivoting QR solve).
        b : (..., m) or (..., m, k) array_like
            One RHS vector per matrix, or ``k`` stacked RHS columns.
        return_residuals : bool
            Also return the ``(..., k)`` residual two-norms
            ``||A x - b||`` — free with the augmented-column trick (the
            annihilated tail of the b column carries them).

        Returns
        -------
        x : (..., n) or (..., n, k) float64 — complex128 for complex
        problems — (matching ``b``), or ``(x, residuals)`` when
        ``return_residuals`` (residuals are always real).
        """
        A = jnp.asarray(A)
        b = jnp.asarray(b)
        if (self.config.is_complex() or A.dtype.kind == "c"
                or b.dtype.kind == "c"):
            work_dtype = jnp.complex128
        else:
            work_dtype = jnp.float64
        A = A.astype(work_dtype)
        b = b.astype(work_dtype)
        m, n = A.shape[-2], A.shape[-1]
        if m < n:
            raise ValueError(f"solve() needs m >= n (got {m} x {n}); "
                             "underdetermined systems have no unique "
                             "least-squares triangular solve")
        vec = b.ndim == A.ndim - 1
        B = b[..., None] if vec else b
        if B.ndim != A.ndim or B.shape[-2] != m:
            raise ValueError(f"b rows must match A rows: A {A.shape}, "
                             f"b {b.shape}")
        aug = jnp.concatenate([A, B], axis=-1)
        _, Raug = self._dispatch(aug, False)
        x, resid = lstsq_from_triangular(Raug, n)
        if vec:
            x, resid = x[..., 0], resid[..., 0]
        return (x, resid) if return_residuals else x

    # -- streaming RLS --------------------------------------------------------
    def rls(self, n, lam=0.99, delta=1e-3, block=None):
        """Create a streaming QRD-RLS state bound to this engine's backend.

        Parameters
        ----------
        n : int
            Filter length (columns of the carried R).
        lam : float
            Forgetting factor λ.
        delta : float
            Initial diagonal loading of R (regularizes the cold start).
        block : int, optional
            Update granularity.  ``None`` selects the backend's natural
            path: the cordic family updates per snapshot on the
            bit-accurate unit (`GivensUnit.annihilate` under one jitted
            scan), ``'blockfp_pallas'`` batches ``block=4`` snapshots per
            kernel-resident block annihilation, and the float backends
            use a plain f64 rotation loop.  An explicit ``block`` forces
            the blocked-kernel path on any backend.

        A complex-dtype config creates a **complex QRD-RLS** state
        (complex128 carried ``[R | z]``, snapshots rotated by the
        three-rotation decomposition on the unit path or conjugate
        Givens on the float path) — the adaptive-beamforming scenario on
        complex baseband snapshots.  The blocked-kernel path has no
        complex datapath; requesting it raises ``TypeError``.

        Returns
        -------
        `repro.qrd.rls.RLSState` — ``state.update(x, d)`` /
        ``state.weights()``.
        """
        from repro.core.givens import GivensUnit
        from .rls import RLSState, validate_lam

        validate_lam(lam)  # eagerly — before any mode routing can raise
        cfg = self.config
        dtype = "complex128" if cfg.is_complex() else "float64"
        if block is not None or cfg.backend == "blockfp_pallas":
            if cfg.is_complex():
                raise TypeError(
                    "the blocked-kernel RLS path has no complex datapath; "
                    "use the cordic family (mode='unit') or a float "
                    "backend for complex QRD-RLS")
            return RLSState(n, lam=lam, delta=delta, mode="block",
                            block=4 if block is None else int(block),
                            hub=cfg.blockfp_hub(), iters=cfg.blockfp_iters(),
                            frac=cfg.frac, interpret=cfg.interpret)
        if cfg.backend in ("cordic", "cordic_pallas"):
            return RLSState(n, lam=lam, delta=delta, mode="unit",
                            unit=GivensUnit(cfg.givens), dtype=dtype)
        return RLSState(n, lam=lam, delta=delta, mode="float", dtype=dtype)

    def fleet(self, slots, n, lam=0.99, delta=1e-3, block=None, mesh=None):
        """Create an `repro.serve.RLSFleet` bound to this engine's backend.

        The fleet analogue of `rls`: N independent streaming QRD-RLS
        states as one struct-of-arrays pytree updated by a single
        donated jitted step (`repro.serve.fleet`, DESIGN.md §12).  Mode
        routing mirrors `rls` exactly — the cordic family vectorizes the
        bit-accurate `GivensUnit` annihilation over slots (so fleet
        slots stay bit-identical to single `RLSState` objects), explicit
        ``block`` or ``'blockfp_pallas'`` selects the kernel-resident
        blocked path (real only), anything else the f64 rotation loop.

        Parameters
        ----------
        slots : int — fleet capacity N.
        n : int — filter length.
        lam, delta : defaults for `RLSFleet.admit` (λ is per-slot state
            and may be overridden per admit).
        block : int, optional — force the blocked-kernel path with this
            many stacked snapshots per slot per update call.
        mesh : jax.sharding.Mesh, optional — shard the slot axis across
            the mesh's data axes; defaults to ``config.mesh``.

        Returns
        -------
        `repro.serve.RLSFleet` — ``fleet.admit(k)`` /
        ``fleet.update(slot_ids, X, d)`` / ``fleet.weights(slot_ids)``.
        """
        from repro.core.givens import GivensUnit
        from repro.serve.fleet import RLSFleet

        from .rls import validate_lam

        validate_lam(lam)
        cfg = self.config
        mesh = cfg.mesh if mesh is None else mesh
        dtype = "complex128" if cfg.is_complex() else "float64"
        if block is not None or cfg.backend == "blockfp_pallas":
            if cfg.is_complex():
                raise TypeError(
                    "the blocked-kernel RLS path has no complex datapath; "
                    "use the cordic family (mode='unit') or a float "
                    "backend for complex QRD-RLS fleets")
            return RLSFleet(slots, n, lam=lam, delta=delta, mode="block",
                            block=4 if block is None else int(block),
                            hub=cfg.blockfp_hub(), iters=cfg.blockfp_iters(),
                            frac=cfg.frac, interpret=cfg.interpret,
                            mesh=mesh)
        if cfg.backend in ("cordic", "cordic_pallas"):
            return RLSFleet(slots, n, lam=lam, delta=delta, mode="unit",
                            unit=GivensUnit(cfg.givens), dtype=dtype,
                            mesh=mesh)
        return RLSFleet(slots, n, lam=lam, delta=delta, mode="float",
                        dtype=dtype, mesh=mesh)
