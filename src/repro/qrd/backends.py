"""Built-in backend registrations (the entries `QRDEngine._build` used to
hard-code as an if/elif chain).

Each builder closes over a resolved `QRDConfig` + static shape and returns
a jit-compatible ``(A) -> (Q, R)`` callable on the corresponding free
function in `repro.core.qrd` — the free functions stay the single source
of arithmetic truth, the registry only owns dispatch.  Importing this
module (it is imported by ``repro.qrd``) populates the registry;
third-party backends call `repro.qrd.register_backend` the same way.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import qrd as _q
from repro.core.givens import GivensUnit

from .registry import (BackendCapabilities, available_backends,
                       register_backend)

__all__ = ["register_builtin_backends"]


def _flat_steps(config, m, n):
    """Schedule for step-serial backends: None = column-major default."""
    if config.schedule == "sameh_kuck":
        return tuple(s for st in _q.sameh_kuck_schedule(m, n) for s in st)
    return None


def _build_jnp(config, m, n, compute_q):
    dtype = jnp.dtype(config.dtype)
    return lambda A: _q.qr_jnp(A, dtype, compute_q=compute_q)


def _build_givens_float(config, m, n, compute_q):
    dtype = jnp.dtype(config.dtype)
    return lambda A: _q.qr_givens_float(A, dtype=dtype, compute_q=compute_q)


def _build_cordic(config, m, n, compute_q):
    unit = GivensUnit(config.givens)
    steps = _flat_steps(config, m, n)
    if config.is_complex():               # complex datapath (DESIGN.md §10)
        return lambda A: _q.qr_cordic_complex(A, unit, compute_q=compute_q,
                                              steps=steps)
    return lambda A: _q.qr_cordic(A, unit, compute_q=compute_q, steps=steps)


def _build_cordic_pallas(config, m, n, compute_q):
    unit = GivensUnit(config.givens)
    tile_b, layout = config.tile_b, config.table_layout
    if config.schedule == "sameh_kuck":   # wavefront datapath (DESIGN.md §8)
        stages = _q.sameh_kuck_schedule(m, n)
        if config.is_complex():
            return lambda A: _q.qr_cordic_complex_wavefront(
                A, unit, compute_q=compute_q, stages=stages,
                interpret=config.interpret, tile_b=tile_b,
                table_layout=layout)
        return lambda A: _q.qr_cordic_wavefront(
            A, unit, compute_q=compute_q, stages=stages,
            interpret=config.interpret, tile_b=tile_b, table_layout=layout)
    if config.is_complex():
        return lambda A: _q.qr_cordic_complex_pallas(
            A, unit, compute_q=compute_q, interpret=config.interpret,
            tile_b=tile_b)
    return lambda A: _q.qr_cordic_pallas(A, unit, compute_q=compute_q,
                                         interpret=config.interpret,
                                         tile_b=tile_b)


def _build_blockfp_pallas(config, m, n, compute_q):
    iters, hub, frac = (config.blockfp_iters(), config.blockfp_hub(),
                        config.frac)
    tile_b, layout = config.tile_b, config.table_layout
    if config.schedule == "sameh_kuck":
        stages = _q.sameh_kuck_schedule(m, n)
        return lambda A: _q.qr_blockfp_wavefront(
            A, compute_q=compute_q, iters=iters, hub=hub, frac=frac,
            stages=stages, interpret=config.interpret, tile_b=tile_b,
            table_layout=layout)
    return lambda A: _q.qr_blockfp_pallas(
        A, compute_q=compute_q, iters=iters, hub=hub, frac=frac,
        interpret=config.interpret, tile_b=tile_b)


def _build_fixed(config, m, n, compute_q):
    return lambda A: _q.qr_fixed(A, config.fixed_width, config.fixed_iters,
                                 config.fixed_scale_exp, compute_q=compute_q)


def register_builtin_backends(overwrite=False):
    """Populate the registry with the six built-in backends (idempotent)."""
    entries = (
        ("jnp", _build_jnp, BackendCapabilities(
            bit_exact=False, wavefront=False, sharding=False,
            dtypes=("float16", "float32", "float64",
                    "complex64", "complex128"),
            description="jnp.linalg.qr Householder reference "
                        "(schedule-agnostic; 'sameh_kuck' degrades to it)")),
        ("givens_float", _build_givens_float, BackendCapabilities(
            bit_exact=False, wavefront=False, sharding=False,
            dtypes=("float16", "float32", "float64",
                    "complex64", "complex128"),
            description="float Givens baseline, column-major schedule "
                        "(complex via conjugate rotations)")),
        ("cordic", _build_cordic, BackendCapabilities(
            bit_exact=True, wavefront=False, sharding=True,
            dtypes=("float64", "complex128"),
            description="the paper's unit, host reference loop "
                        "('sameh_kuck' consumes the flattened stage order; "
                        "complex via the three-rotation decomposition)")),
        ("cordic_pallas", _build_cordic_pallas, BackendCapabilities(
            bit_exact=True, wavefront=True, sharding=True,
            dtypes=("float64", "complex128"),
            max_shape=(128, 128), supports_tiling=True,
            description="kernel-resident unit, bit-identical to 'cordic'; "
                        "'sameh_kuck' routes onto the wavefront datapath")),
        ("blockfp_pallas", _build_blockfp_pallas, BackendCapabilities(
            bit_exact=False, wavefront=True, sharding=True,
            max_shape=(128, 128), supports_tiling=True,
            description="int32 block-FP blocked kernel (fast TPU path)")),
        ("fixed", _build_fixed, BackendCapabilities(
            bit_exact=False, wavefront=False, sharding=False,
            description="32-bit fixed-point rotator of [20] "
                        "(Fig. 11 baseline; schedule-agnostic)")),
    )
    registered = available_backends()
    for name, builder, caps in entries:
        if overwrite or name not in registered:
            register_backend(name, builder, caps, overwrite=overwrite)


register_builtin_backends()
