"""Tiled QRD routes: panel factorization + TSQR tree reduction (DESIGN.md §14).

The flat Pallas datapaths keep the whole augmented ``(m, n + m)`` tile
kernel-resident, which caps them at `BackendCapabilities.max_shape`
(VMEM).  This module supplies the two routes that lift that cap while
preserving the wavefront property — every rotation is *computed once*
(vectoring on the leading pair) and *replayed everywhere else* from its
``(flip, sigma)`` control words:

* **panel** — sweep the columns in ``panel_n``-wide panels.  Each panel
  is factorized by a kernel-resident scan
  (`repro.kernels.qrd_blocked.panel_factor_*`) that exports the control
  words of every rotation; the trailing columns are updated by a replay
  kernel batched over *both* the matrix batch and the trailing-panel
  axis of the Pallas grid (`panel_apply_*`).  The panel schedule is the
  column-major flat schedule split at panel boundaries — the
  concatenation of the per-panel step tables *is*
  `repro.core.qrd.givens_schedule`, so the route is bit-identical to
  the flat reference ordering by construction (verified by
  ``tests/test_qrd_tiled.py``).  Rows still ride in one tile: m is
  bounded by ``max_shape[0]``; n is unbounded (columns stream through
  the grid).

* **tsqr** — the communication-avoiding tall-skinny route.  Rows are
  zero-padded to ``L * tile_m`` and split into L leaf tiles; every leaf
  is factorized by the panel driver as one batched launch, then a binary
  tree of ``(2n, n)`` stacked R-pair factorizations reduces the L leaf
  R factors to one.  Each tree level is again one batched launch —
  sharded over the mesh's data axes via
  `repro.launch.sharding.tsqr_node_spec` when ``config.mesh`` is set —
  so the critical path is ``ceil(log2 L)`` launches regardless of m.
  Returns the *economy* factors ``Q (m, n), R (n, n)`` (a full m x m Q
  would defeat the point at m = 10^4).  Q is recovered without ever
  materializing tree-level Qs at full height: each leaf carries an
  ``(n, n)`` composition factor B, updated per level from the economy Q
  of the node that consumed the leaf's R (top or bottom half, selected
  by a *static* owner/side index map), and the final
  ``Q = concat_l(Q_leaf[l] @ B[l])[:m]``.

Route selection (`resolve_route`) is deterministic in
``(m, n, config)`` — the engine's jitted-callable LRU key
``(m, n, compute_q, config.cache_key())`` therefore already
distinguishes routes.  ``tiling='auto'`` (or None) keeps every shape
that previously worked on the flat datapath unchanged
(``m, n <= FLAT_LIMIT``), so existing callers see identical bits and
identical cache behavior.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FLAT_LIMIT", "DEFAULT_PANEL_N", "resolve_route", "resolve_tiles",
           "build_tiled", "tsqr_host_reference"]

# auto routes shapes at or under this bound onto the flat datapath --
# small problems fit comfortably and all pre-tiling callers stay on
# byte-identical code paths (same kernels, same jit cache entries).
FLAT_LIMIT = 32

# default panel width when config.panel_n is None and the autotuner has
# no entry: 8 columns matches TILE_B-sized VMEM tiles on both datapaths.
DEFAULT_PANEL_N = 8


def _capacity_error(config, caps, m, n, detail):
    max_m, max_n = caps.max_shape
    return ValueError(
        f"operand {m}x{n} exceeds backend {config.backend!r} kernel "
        f"capacity max_shape={caps.max_shape} ({detail}); "
        f"tiled alternatives: tiling='panel' keeps m <= {max_m} rows "
        f"kernel-resident with unbounded columns, tiling='tsqr' handles "
        f"tall-skinny m > {max_m} with n <= {max_m // 2} (tree nodes "
        f"stack R pairs to 2n x n), and tiling='auto' selects between "
        f"them.  See DESIGN.md §14.")


def resolve_route(config, m, n, caps) -> str:
    """Pick the datapath route for an (m, n) problem: flat | panel | tsqr.

    Deterministic in ``(m, n, config)``.  Raises ``ValueError`` naming
    the backend's ``max_shape`` and the tiled alternative whenever the
    requested (or only available) route cannot hold the operand —
    previously such shapes died deep inside Pallas with an opaque VMEM
    or iota-shape error.
    """
    tiling = "auto" if config.tiling is None else config.tiling

    # Backends without a tiled datapath (host references, float
    # baselines) have max_shape=None and always run flat.
    if not caps.supports_tiling:
        return "flat"
    # The complex datapath and the sameh_kuck wavefront ordering only
    # exist flat: the tiled routes replay the column-major schedule.
    forced_tiled = tiling in ("panel", "tsqr")
    if config.is_complex() or config.schedule == "sameh_kuck":
        which = ("complex datapath" if config.is_complex()
                 else "schedule='sameh_kuck'")
        if forced_tiled:
            raise ValueError(
                f"tiling={tiling!r} is only defined for the real "
                f"column-major datapath, but this config uses {which}; "
                "the tiled routes replay the flat column-major ordering "
                "(schedule='col')")
        if not caps.fits_flat(m, n):
            raise _capacity_error(
                config, caps, m, n,
                f"{which} runs flat only, and the whole augmented tile "
                "must fit VMEM")
        return "flat"

    max_m, max_n = caps.max_shape
    if tiling == "flat":
        if not caps.fits_flat(m, n):
            raise _capacity_error(
                config, caps, m, n,
                "tiling='flat' keeps the whole augmented tile "
                "kernel-resident")
        return "flat"
    if tiling == "panel":
        if m > max_m:
            raise _capacity_error(
                config, caps, m, n,
                "the panel route keeps all m rows kernel-resident")
        return "panel"
    if tiling == "tsqr":
        if 2 * n > max_m or n > max_n:
            raise _capacity_error(
                config, caps, m, n,
                "tsqr tree nodes stack R pairs to 2n x n tiles")
        return "tsqr"

    # -- auto -------------------------------------------------------------
    if m <= FLAT_LIMIT and n <= FLAT_LIMIT:
        return "flat"
    tsqr_ok = 2 * n <= max_m and n <= max_n
    if tsqr_ok and (m > max_m or m >= 4 * n):
        return "tsqr"          # over row capacity, or decisively tall-skinny
    if m <= max_m:
        return "panel"
    raise _capacity_error(
        config, caps, m, n,
        "m exceeds the row capacity and n is too wide for tsqr tree nodes")


def resolve_tiles(config, caps):
    """Resolve ``(tile_m, panel_n)``: explicit config values win, else the
    backend's row capacity and `DEFAULT_PANEL_N` (the engine fills tuned
    values into the config *before* this runs, so autotuned winners land
    here as if explicit)."""
    tile_m = config.tile_m if config.tile_m is not None else caps.max_shape[0]
    panel_n = config.panel_n if config.panel_n is not None else DEFAULT_PANEL_N
    return tile_m, panel_n


def _leaf_qr_fn(config, panel_n):
    """The batched small-QR primitive both tiled routes are built from:
    ``qr(X, compute_q) -> (Q, R)`` on the panel driver of the configured
    backend (full-shape factors, `repro.core.qrd._split_qr` contract)."""
    from repro.core import qrd as _q
    from repro.core.givens import GivensUnit

    if config.backend == "cordic_pallas":
        unit = GivensUnit(config.givens)

        def qr(X, cq):
            return _q.qr_cordic_panel(X, unit, compute_q=cq, panel_n=panel_n,
                                      interpret=config.interpret,
                                      tile_b=config.tile_b)
        return qr

    iters, hub, frac = (config.blockfp_iters(), config.blockfp_hub(),
                        config.frac)

    def qr(X, cq):
        return _q.qr_blockfp_panel(X, compute_q=cq, iters=iters, hub=hub,
                                   frac=frac, panel_n=panel_n,
                                   interpret=config.interpret,
                                   tile_b=config.tile_b)
    return qr


def build_tiled(route, config, m, n, compute_q, caps):
    """Builder for the tiled routes — same contract as a registry builder
    (``(A) -> (Q, R)``, jit-compatible), selected by `resolve_route`.

    ``route='panel'`` returns full factors like the flat datapath
    (``Q (m, m), R (m, n)``); ``route='tsqr'`` returns the economy
    factors (``Q (m, n), R (n, n)``) — at TSQR scale a full Q is the
    product the route exists to avoid.
    """
    tile_m, panel_n = resolve_tiles(config, caps)
    qr = _leaf_qr_fn(config, panel_n)
    if route == "panel":
        return lambda A: qr(A, compute_q)
    if route != "tsqr":
        raise ValueError(f"unknown tiled route {route!r}")
    mesh = config.mesh

    def fn(A):
        return _tsqr(A, leaf_qr=qr, tile_m=tile_m, compute_q=compute_q,
                     mesh=mesh)
    return fn


def _constrain_nodes(X, mesh):
    """In-jit analogue of `repro.launch.sharding.shard_tsqr_nodes`: a
    sharding *constraint* (placement hints are all a trace can express —
    ``device_put`` belongs outside jit)."""
    if mesh is None:
        return X
    from jax.sharding import NamedSharding

    from repro.launch.sharding import tsqr_node_spec
    spec = tsqr_node_spec(X.ndim, X.shape[0], mesh)
    return jax.lax.with_sharding_constraint(X, NamedSharding(mesh, spec))


def _tsqr(A, *, leaf_qr, tile_m, compute_q, mesh):
    """TSQR binary tree reduction over batched tall-skinny operands.

    Tree plan (pairings, owner/side maps) is static numpy — only the
    node factorizations and the (n, n) composition einsums trace.  Zero
    rows padding the last leaf ride through its factorization (columns
    of zeros rotate to zeros; the pad rows of Q are sliced off at the
    end) — the bit-exactness contract is against a host reference with
    the *same* padded tree (`tsqr_host_reference`): **R bit-identical**
    (it is produced entirely by the bit-exact rotation datapath), Q to
    float64-rounding tolerance (the composition is float matmul, whose
    summation order differs between XLA and host BLAS).
    """
    A = jnp.asarray(A, jnp.float64)
    m, n = A.shape[-2], A.shape[-1]
    batch = A.shape[:-2]
    Af = A.reshape((-1, m, n))
    B = Af.shape[0]
    L = -(-m // tile_m)
    pad = L * tile_m - m
    if pad:
        Af = jnp.pad(Af, ((0, 0), (0, pad), (0, 0)))

    nodes = _constrain_nodes(Af.reshape(B * L, tile_m, n), mesh)
    Qf, Rf = leaf_qr(nodes, compute_q)
    Rs = Rf[..., :n, :].reshape(B, L, n, n)
    if compute_q:
        Qleaf = Qf[..., :n].reshape(B, L, tile_m, n)   # economy leaf Q
        eye = jnp.eye(n, dtype=Qleaf.dtype)
        comp = jnp.broadcast_to(eye, (B, L, n, n))     # per-leaf B factors

    owner = np.arange(L)        # which live R-slot each leaf feeds (static)
    cur = L
    while cur > 1:
        pairs, odd = cur // 2, cur % 2
        stack = jnp.concatenate([Rs[:, 0:2 * pairs:2], Rs[:, 1:2 * pairs:2]],
                                axis=-2).reshape(B * pairs, 2 * n, n)
        Qn, Rn = leaf_qr(_constrain_nodes(stack, mesh), compute_q)
        new_Rs = Rn[..., :n, :].reshape(B, pairs, n, n)
        if odd:                 # unpaired last node carries to the next level
            new_Rs = jnp.concatenate([new_Rs, Rs[:, -1:]], axis=1)
        if compute_q:
            Qe = Qn[..., :n].reshape(B, pairs, 2 * n, n)
            # T-stack layout [top halves | bottom halves | I]; each leaf
            # selects its consumer node's half (or I when carried) by a
            # static index -- a gather, never a traced branch.
            T = jnp.concatenate(
                [Qe[:, :, :n, :], Qe[:, :, n:, :],
                 jnp.broadcast_to(eye, (B, 1, n, n))], axis=1)
            sel = np.where(owner < 2 * pairs,
                           owner // 2 + (owner % 2) * pairs, 2 * pairs)
            comp = jnp.einsum("blij,bljk->blik", comp, T[:, sel])
        owner = np.where(owner < 2 * pairs, owner // 2, pairs)
        Rs, cur = new_Rs, pairs + odd

    R = Rs[:, 0].reshape(batch + (n, n))
    if not compute_q:
        return None, R
    Q = jnp.einsum("blij,bljk->blik", Qleaf, comp)
    Q = Q.reshape(B, L * tile_m, n)[:, :m]
    return Q.reshape(batch + (m, n)), R


def tsqr_host_reference(A, node_qr, tile_m):
    """Host-loop TSQR oracle for the bit-exactness tests.

    Runs the *same* padded tree plan as `_tsqr` but factorizes every
    node one at a time through ``node_qr(X) -> (Q, R)`` (full-shape
    factors, e.g. `repro.core.qrd.qr_cordic` on the column-major
    schedule) — a completely independent execution path from the
    batched panel kernels, sharing only the rotation *ordering*.
    Returns economy ``(Q (m, n), R (n, n))``; R compares bitwise
    against the tsqr route, Q to float64-rounding tolerance (host BLAS
    and XLA matmuls sum in different orders).
    """
    A = np.asarray(A, np.float64)
    m, n = A.shape
    L = -(-m // tile_m)
    Af = np.zeros((L * tile_m, n))
    Af[:m] = A
    Qs, Rs = [], []
    for leaf in range(L):
        Q, R = node_qr(Af[leaf * tile_m:(leaf + 1) * tile_m])
        Qs.append(np.asarray(Q)[:, :n])
        Rs.append(np.asarray(R)[:n, :])
    comp = [np.eye(n) for _ in range(L)]
    owner = list(range(L))
    while len(Rs) > 1:
        pairs = len(Rs) // 2
        new_Rs, tops, bots = [], [], []
        for p in range(pairs):
            Q, R = node_qr(np.concatenate([Rs[2 * p], Rs[2 * p + 1]]))
            Qe = np.asarray(Q)[:, :n]
            new_Rs.append(np.asarray(R)[:n, :])
            tops.append(Qe[:n])
            bots.append(Qe[n:])
        if len(Rs) % 2:
            new_Rs.append(Rs[-1])
        for leaf in range(L):
            o = owner[leaf]
            if o < 2 * pairs:
                half = tops[o // 2] if o % 2 == 0 else bots[o // 2]
                comp[leaf] = comp[leaf] @ half
                owner[leaf] = o // 2
            else:
                owner[leaf] = pairs
        Rs = new_Rs
    Q = np.concatenate([Qs[leaf] @ comp[leaf] for leaf in range(L)])[:m]
    return Q, Rs[0]
