"""Problem-level least squares on top of the QRD engines (DESIGN.md §9).

The paper motivates its rotation unit with QRD-based least squares in
communication systems; this module closes that loop without ever forming
Q.  For ``min_x ||A x - b||`` the engine triangularizes the *augmented*
matrix ``[A | b]``: the same orthogonal transform that reduces A to R
lands ``Qᵀ b`` in the appended column (the classic augmented-column / "z
column" trick of QRD-RLS), so a ``compute_q=False`` decomposition plus a
triangular back-substitution yields x.  This is exactly how a hardware
array built from the paper's rotators would solve — the b column streams
through the same rotation pipeline as the data columns.

`back_substitute` is the new batched, jit-safe triangular solve; it is
shared by `Engine.solve` and the streaming `RLSState.weights`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["back_substitute", "lstsq_from_triangular", "SOLVE_TOLERANCES"]


#: Documented per-backend tolerances of ``engine.solve`` vs
#: ``np.linalg.lstsq`` (relative error on x, well-conditioned inputs of
#: moderate dynamic range; see tests/test_qrd_api.py which enforces them).
#: The float32 backends are limited by their working precision; the
#: bit-accurate cordic family by the N=26-bit internal significand; the
#: block-FP kernel by its F=24 fraction bits; the fixed-point baseline by
#: its pre-scaling (assumes a sane ``fixed_scale_exp``).
#: Complex problems are keyed ``"<backend>:complex"`` — the complex
#: datapath spends three rotations per annihilation (two phase + one
#: Givens, DESIGN.md §10), so its error is a small multiple of the real
#: path's; backends without a complex datapath have no complex entry.
SOLVE_TOLERANCES = {
    "jnp": 1e-3,
    "givens_float": 1e-3,
    "cordic": 1e-5,
    "cordic_pallas": 1e-5,
    "blockfp_pallas": 1e-3,
    "fixed": 1e-2,
    "jnp:complex": 1e-3,
    "givens_float:complex": 1e-3,
    "cordic:complex": 3e-5,
    "cordic_pallas:complex": 3e-5,
}


@jax.jit
def back_substitute(R, y):
    """Solve the upper-triangular system ``R x = y``, batched and jitted.

    Parameters
    ----------
    R : (..., n, n) array
        Upper-triangular coefficient matrices (entries below the diagonal
        are ignored — the QRD engines force them to structural zeros
        anyway).  Any leading batch shape.
    y : (..., n) or (..., n, k) array
        Right-hand sides (a trailing RHS axis ``k`` is broadcast through).

    Returns
    -------
    x with the shape of ``y`` — float64, or complex128 when either
    operand is complex.

    Notes
    -----
    Implemented as a ``lax.fori_loop`` over rows from the bottom up —
    fixed trip count, one dynamic row update per step — so it traces to a
    constant-size program regardless of batch shape; the wrapper is
    jitted here (one compile per shape, shared by `QRDEngine.solve` and
    `RLSState.weights`).  A zero diagonal (rank-deficient R)
    produces inf/nan, matching direct substitution; callers needing
    ridge behavior add it to R beforehand (see `RLSState.weights`).

    Complex systems use plain complex arithmetic — R is applied as
    stored, *not* conjugated: the engines hand this the already-rotated
    ``[R | Q^H b]``, so conjugation has been absorbed by the unitary
    reduction (the "conjugate-aware" contract of DESIGN.md §10).
    """
    R = jnp.asarray(R)
    y = jnp.asarray(y)
    work_dtype = (jnp.complex128 if R.dtype.kind == "c"
                  or y.dtype.kind == "c" else jnp.float64)
    R = R.astype(work_dtype)
    y = y.astype(work_dtype)
    vec = y.ndim == R.ndim - 1
    if vec:
        y = y[..., None]
    n = R.shape[-1]
    if y.shape[-2] != n:
        raise ValueError(f"shape mismatch: R is (..., {n}, {n}), "
                         f"y rows = {y.shape[-2]}")

    def body(i, x):
        row = n - 1 - i
        # rows below `row` are already solved; rows above still hold the
        # zero init, and R's upper-triangular structure ignores them.
        acc = jnp.einsum("...j,...jk->...k", R[..., row, :], x)
        xi = (y[..., row, :] - acc) / R[..., row, row][..., None]
        return x.at[..., row, :].set(xi)

    x = jax.lax.fori_loop(0, n, body, jnp.zeros_like(y))
    return x[..., 0] if vec else x


@functools.partial(jax.jit, static_argnums=(1,))
def lstsq_from_triangular(Raug, n):
    """Extract the least-squares solution from a triangularized ``[A | b]``.

    Parameters
    ----------
    Raug : (..., m, n + k) array
        The R factor of the augmented matrix: columns ``:n`` hold R(A),
        columns ``n:`` hold ``Qᵀ b``.
    n : int
        Column count of the original A.

    Returns
    -------
    (x, resid) where ``x`` is ``(..., n, k)`` and ``resid`` is the
    ``(..., k)`` *real* residual two-norms ``||A x - b||`` read off the
    annihilated tail of the b column(s) — free with the augmented trick
    (``√Σ|·|²`` over the tail, conjugate-aware for complex problems).
    """
    Raug = jnp.asarray(Raug)
    Raug = Raug.astype(jnp.complex128 if Raug.dtype.kind == "c"
                       else jnp.float64)
    R = Raug[..., :n, :n]
    C = Raug[..., :n, n:]
    x = back_substitute(R, C)
    tail = Raug[..., n:, n:]
    resid = jnp.sqrt(jnp.sum(jnp.real(tail * jnp.conj(tail)), axis=-2))
    return x, resid
