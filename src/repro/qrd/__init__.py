"""repro.qrd — the solver-grade QRD API (DESIGN.md §9).

The problem-level surface the paper's rotation unit exists for:

* `QRDConfig` — one config for backend, schedule, unit parameters,
  block-FP knobs, fixed-point baseline parameters and an optional
  sharding mesh;
* `register_backend` / `list_backends` — the backend registry (the
  built-ins ``'jnp'``, ``'givens_float'``, ``'cordic'``,
  ``'cordic_pallas'``, ``'blockfp_pallas'``, ``'fixed'`` are entries like
  any third-party backend);
* `QRDEngine` — registry-dispatched decomposition plus **solve()**
  (batched least squares, Q-free augmented-column trick) and **rls()**
  (streaming QRD-RLS state for adaptive filtering);
* `back_substitute` — the batched, jit-safe triangular solve both
  problem paths share.

Legacy entrypoints (``repro.core.QRDEngine``, the ``qr_*`` free
functions) keep working as thin shims over this package.
"""
from .registry import (BackendCapabilities, BackendSpec, register_backend,
                       unregister_backend, get_backend, list_backends,
                       available_backends, complex_capable_backends)
from .config import QRDConfig
from .solve import back_substitute, lstsq_from_triangular, SOLVE_TOLERANCES
from .rls import RLSState
from . import backends as _backends  # populates the registry on import
from .engine import QRDEngine

__all__ = [
    "BackendCapabilities", "BackendSpec", "register_backend",
    "unregister_backend", "get_backend", "list_backends",
    "available_backends", "complex_capable_backends",
    "QRDConfig", "QRDEngine",
    "back_substitute", "lstsq_from_triangular", "SOLVE_TOLERANCES",
    "RLSState",
]
