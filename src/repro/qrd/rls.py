"""Streaming QRD-RLS state — the paper's adaptive-filtering application.

QRD-RLS never forms the (ill-conditioned) covariance matrix: the carried
state is the Cholesky-equivalent pair ``[R | z]`` of the forgetting-
factor-weighted data matrix, and every new snapshot ``(x, d)`` is
annihilated into it by exactly the Givens rotations the paper's unit
computes (vectoring on the leading pair, σ-replay across the row).  The
beamforming example used to hand-roll this loop; `RLSState` is the
library-grade replacement, with three update paths:

* ``mode='unit'`` — per-snapshot on the bit-accurate `GivensUnit`: the n
  pivot annihilations run inside one jitted ``lax.fori_loop`` over
  `GivensUnit.annihilate` (traced pivot column via the roll trick — one
  fixed shape, one compile, no per-rotation host round-trips);
* ``mode='block'`` — the kernel-resident path: ``block`` snapshots are
  stacked under ``[R | z]`` and annihilated by ONE blocked Pallas
  schedule (`repro.kernels.ops.givens_block_apply` on
  `ops.rls_block_steps`), with exponential forgetting telescoped exactly
  (state weighted λ^{b/2}, pending row i by λ^{(b-1-i)/2});
* ``mode='float'`` — plain f64 Givens loop (algorithmic baseline).

Weights come from the shared jit-safe back-substitution
(`repro.qrd.solve.back_substitute`) — the same triangular solve the
engine's `solve()` uses.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .solve import back_substitute

__all__ = ["RLSState", "validate_lam"]

_MODES = ("float", "unit", "block")


def validate_lam(lam, what="forgetting factor"):
    """Validate ``0 < lam <= 1`` (scalar or per-slot array) loudly.

    QRD-RLS with λ <= 0 silently destroys the carried factor (the √λ
    weighting zeroes — or, for negative λ, imaginarizes — R); λ > 1
    amplifies history without bound; NaN poisons the state on the first
    update.  Every entry point (`RLSState`, `QRDEngine.rls`,
    `repro.serve.RLSFleet`) funnels through here so no path accepts a
    non-positive λ.
    """
    raw = np.asarray(lam)
    if raw.dtype.kind == "c":
        # np.asarray(complex, float64) would silently discard the
        # imaginary part, letting e.g. 0.9+0.5j pass as 0.9.
        raise TypeError(f"{what} must be real, got complex {lam!r}")
    if raw.dtype.kind not in "fiu":
        raise TypeError(f"{what} must be numeric, got {raw.dtype}")
    # lint: allow[narrowing-cast] real/int-only here, complex rejected above
    arr = raw.astype(np.float64)
    if arr.size == 0:
        raise ValueError(f"{what} must be non-empty")
    if not np.all((arr > 0.0) & (arr <= 1.0)):
        raise ValueError(f"{what} must be in (0, 1], got {lam!r}")
    return arr


class RLSState:
    """Carried QRD-RLS state ``[R | z]`` with streaming updates.

    Parameters
    ----------
    n : int
        Filter length (size of the carried upper-triangular R).
    lam : float
        Forgetting factor λ in (0, 1].
    delta : float
        Initial diagonal loading: ``R0 = delta * I`` (cold-start
        regularization, standard QRD-RLS initialization).
    mode : str
        ``'float'`` | ``'unit'`` | ``'block'`` (see module docstring).
        Usually chosen by `repro.qrd.QRDEngine.rls` from the backend.
    unit : GivensUnit, required for ``mode='unit'``
        The bit-accurate rotator the updates run on.
    block, hub, iters, frac, interpret :
        Blocked-kernel parameters (``mode='block'``): snapshots per
        kernel launch and the block-FP datapath knobs of
        `repro.kernels.ops.givens_block_apply`.
    dtype : str
        ``'float64'`` (default) or ``'complex128'``.  Complex states
        carry complex ``[R | z]`` and rotate snapshots with unitary
        complex Givens — the three-rotation decomposition on the unit
        path (`GivensUnit.annihilate_complex`, DESIGN.md §10), conjugate
        rotations on the float path.  The blocked-kernel path has no
        complex datapath (``mode='block'`` with a complex dtype raises
        ``TypeError``).

    Attributes
    ----------
    R : (n, n) ndarray — carried triangular factor (dtype as configured).
    z : (n,) ndarray — carried rotated target vector.
    updates : int — snapshots absorbed (committed + pending).

    Notes
    -----
    In ``mode='block'`` snapshots accumulate in a pending buffer and are
    committed ``block`` at a time; `weights` reads the *committed* state
    (call `flush` first to force a partial block through the kernel).
    """

    def __init__(self, n, lam=0.99, delta=1e-3, *, mode="float", unit=None,
                 block=4, hub=True, iters=24, frac=24, interpret=None,
                 dtype="float64"):
        if mode not in _MODES:
            raise ValueError(f"unknown mode {mode!r}; expected one of {_MODES}")
        validate_lam(lam)
        if mode == "unit" and unit is None:
            raise ValueError("mode='unit' needs a GivensUnit")
        if dtype not in ("float64", "complex128"):
            raise ValueError(f"dtype must be 'float64' or 'complex128', "
                             f"got {dtype!r}")
        if mode == "block" and dtype == "complex128":
            raise TypeError("the blocked-kernel RLS path has no complex "
                            "datapath; use mode='unit' or mode='float' for "
                            "complex QRD-RLS")
        self.n = int(n)
        self.lam = float(lam)
        self.mode = mode
        self.unit = unit
        self.block = int(block)
        self.dtype = np.dtype(dtype)
        self._blockfp = dict(hub=hub, iters=iters, frac=frac,
                             interpret=interpret)
        self.R = np.eye(self.n, dtype=self.dtype) * float(delta)
        self.z = np.zeros(self.n, dtype=self.dtype)
        self.updates = 0
        self._pending: list[np.ndarray] = []
        if mode == "unit":
            self._unit_update = jax.jit(self._make_unit_update())

    @property
    def is_complex(self):
        return self.dtype.kind == "c"

    # -- update paths ---------------------------------------------------------
    def _make_unit_update(self):
        unit, n = self.unit, self.n
        if self.is_complex:
            def update(P, prow):
                """Annihilate one packed complex snapshot into [R | z]."""
                def body(k, carry):
                    P, prow = carry
                    xk, prow = unit.annihilate_complex(P[k], prow, k)
                    return P.at[k].set(xk), prow
                P, _ = jax.lax.fori_loop(0, n, body, (P, prow))
                return P
            return update

        def update(P, prow):
            """Annihilate one packed snapshot row into packed [R | z]."""
            def body(k, carry):
                P, prow = carry
                xk, prow = unit.annihilate(P[k], prow, k)
                return P.at[k].set(xk), prow
            P, _ = jax.lax.fori_loop(0, n, body, (P, prow))
            return P

        return update

    def _encode(self, work):
        """float/complex ndarray -> packed words ((..., 2) lanes if complex).

        The complex lane packing is the shared `repro.core.qrd` codec —
        one source of truth for the (re, im) trailing-axis convention.
        """
        from repro.core.qrd import _encode_complex
        if self.is_complex:
            return _encode_complex(self.unit, jnp.asarray(work))
        return self.unit.encode(jnp.asarray(work))

    def _decode(self, P):
        from repro.core.qrd import _decode_complex
        if self.is_complex:
            return np.asarray(_decode_complex(self.unit, P))
        return np.asarray(self.unit.decode(P))

    def _work(self, weight):
        return np.concatenate([self.R, self.z[:, None]], axis=1) * weight

    def update(self, x, d):
        """Absorb one snapshot: rotate ``[x, d]`` into ``[√λ R | √λ z]``.

        Parameters
        ----------
        x : (n,) array_like — input/regressor snapshot.
        d : scalar — desired response.

        Returns
        -------
        self (for chaining).
        """
        x = np.asarray(x)
        if ((x.dtype.kind == "c" or np.asarray(d).dtype.kind == "c")
                and not self.is_complex):
            raise TypeError(
                "complex snapshot on a real-dtype RLS state (no silent "
                "real cast); create the state with dtype='complex128' — "
                "e.g. engine.rls() on a complex-dtype QRDConfig")
        row = np.concatenate([x.astype(self.dtype).ravel(),
                              [self.dtype.type(d)]])
        if row.shape[0] != self.n + 1:
            raise ValueError(f"snapshot length {row.shape[0] - 1} != n="
                             f"{self.n}")
        self.updates += 1
        if self.mode == "block":
            self._pending.append(row)
            if len(self._pending) >= self.block:
                self.flush()
            return self
        work = self._work(np.sqrt(self.lam))
        if self.mode == "unit":
            P = self._unit_update(self._encode(work), self._encode(row))
            out = self._decode(P)
        else:  # float: conjugate Givens (reduces to the real rotation
            #    for real dtypes — conjugation is the identity there)
            out = work
            for k in range(self.n):
                a, b = out[k, k], row[k]
                r = np.hypot(abs(a), abs(b))
                if r == 0.0:
                    continue
                c, s = np.conj(a) / r, np.conj(b) / r
                wk = c * out[k] + s * row
                row = -np.conj(s) * out[k] + np.conj(c) * row
                row[k] = 0.0
                out[k] = wk
                out[k, k] = r
        self.R, self.z = out[:, :self.n], out[:, self.n]
        return self

    def flush(self):
        """Commit pending snapshots through the blocked kernel (mode='block').

        One `givens_block_apply` launch annihilates all ``b`` stacked
        rows column-by-column against the carried state; the forgetting
        weights (state × λ^{b/2}, row i × λ^{(b-1-i)/2}) telescope to the
        per-snapshot recursion exactly.  No-op when nothing is pending.
        """
        b = len(self._pending)
        if b == 0:
            return self
        from repro.kernels import ops as kops
        lam_half = np.sqrt(self.lam)
        top = self._work(lam_half ** b)
        rows = np.stack([row * lam_half ** (b - 1 - i)
                         for i, row in enumerate(self._pending)])
        W = np.concatenate([top, rows], axis=0)[None]   # (1, n+b, n+1)
        steps = kops.rls_block_steps(self.n, b)
        Wp = np.asarray(kops.givens_block_apply(W, steps,
                                                **self._blockfp))[0]
        self.R, self.z = Wp[:self.n, :self.n], Wp[:self.n, self.n]
        self._pending = []
        return self

    # -- pure pytree export / import ------------------------------------------
    def to_arrays(self):
        """Export the full state as a pure array pytree.

        Block mode's partial-flush buffer used to live only as Python
        list state — invisible to checkpointing and to the fleet; here
        it is materialized as a fixed-shape ``(block, n+1)`` array (rows
        beyond ``pending_count`` are zero padding), so the export has a
        static structure suitable as a `repro.checkpoint` template and
        as the interop schema of `repro.serve.RLSFleet.import_state` /
        ``export_state``.

        Returns
        -------
        dict with keys ``R`` (n, n), ``z`` (n,), ``lam`` float64,
        ``updates`` int64, ``pending`` (block, n+1) — (0, n+1) for the
        unblocked modes — and ``pending_count`` int64.
        """
        cap = self.block if self.mode == "block" else 0
        pending = np.zeros((cap, self.n + 1), dtype=self.dtype)
        for i, row in enumerate(self._pending):
            pending[i] = row
        return {"R": self.R.copy(), "z": self.z.copy(),
                "lam": np.float64(self.lam),
                "updates": np.int64(self.updates),
                "pending": pending,
                "pending_count": np.int64(len(self._pending))}

    def from_arrays(self, arrays):
        """Load a `to_arrays` pytree into this (compatibly configured)
        state — the restore half of the pure export.

        The receiving state supplies the *configuration* (mode, unit,
        kernel knobs — none of which are arrays); `arrays` supplies the
        carried numbers.  Shapes, dtype kind and λ are validated;
        pending snapshots beyond the unblocked modes' empty buffer
        require ``mode='block'``.
        """
        R = np.asarray(arrays["R"])
        z = np.asarray(arrays["z"])
        if R.shape != (self.n, self.n) or z.shape != (self.n,):
            raise ValueError(f"state shape mismatch: R {R.shape}, z {z.shape}"
                             f" vs n={self.n}")
        if (R.dtype.kind == "c") != self.is_complex:
            raise TypeError(f"dtype kind mismatch: imported {R.dtype} into a "
                            f"{self.dtype} state (no silent cast)")
        count = int(arrays.get("pending_count", 0))
        pending = np.asarray(arrays.get("pending",
                                        np.zeros((0, self.n + 1),
                                                 dtype=self.dtype)))
        if count:
            if self.mode != "block":
                raise ValueError(f"{count} pending snapshot(s) in the import "
                                 f"but mode={self.mode!r} has no pending "
                                 "buffer (flush() the source first)")
            if count > pending.shape[0] or pending.shape[1:] != (self.n + 1,):
                raise ValueError(f"pending buffer {pending.shape} cannot hold "
                                 f"{count} rows of length {self.n + 1}")
        self.lam = float(validate_lam(np.asarray(arrays["lam"]).item()))
        self.R = R.astype(self.dtype).copy()
        self.z = z.astype(self.dtype).copy()
        self.updates = int(arrays["updates"])
        self._pending = [pending[i].astype(self.dtype).copy()
                         for i in range(count)]
        return self

    # -- readout --------------------------------------------------------------
    def weights(self, ridge=1e-12):
        """Back-substitute the carried ``R w = z`` for the filter weights.

        Parameters
        ----------
        ridge : float
            Diagonal loading added to R before the solve (guards the
            cold-started diagonal; matches the historical example's
            ``solve(R + 1e-12 I, z)``).

        Returns
        -------
        (n,) float64 ndarray.
        """
        R = self.R + ridge * np.eye(self.n) if ridge else self.R
        return np.asarray(back_substitute(jnp.asarray(R),
                                          jnp.asarray(self.z)))

    def predict(self, x):
        """Filter output ``xᵀ w`` for a snapshot ``x`` (complex for
        complex states)."""
        x = np.asarray(x)
        if x.dtype.kind == "c" and not self.is_complex:
            raise TypeError("complex snapshot on a real-dtype RLS state "
                            "(no silent real cast)")
        out = x.astype(self.dtype) @ self.weights()
        return complex(out) if self.is_complex else float(out)
