"""Backend registry for the solver-grade QRD API (DESIGN.md §9).

The registry replaces the if/elif dispatch that used to live inside
``QRDEngine._build``: every backend is an entry mapping a name to a
*builder* plus a :class:`BackendCapabilities` record.  The engine looks
backends up here, validates the requested configuration against the
capability metadata (schedules, sharding, wavefront routing), and builds
one jitted ``(A) -> (Q, R)`` callable per shape.  Third parties add
backends with :func:`register_backend` — no core edits required.

Builder contract
----------------
``builder(config, m, n, compute_q) -> callable``

* ``config``   : the resolved :class:`repro.qrd.config.QRDConfig`;
* ``m, n``     : static matrix shape (trailing two axes of the operand);
* ``compute_q``: whether the returned callable must produce Q.

The returned callable maps a ``(..., m, n)`` array to ``(Q, R)`` with
``Q is None`` when ``compute_q=False``.  It must be jit-compatible: the
engine wraps it in ``jax.jit`` and memoizes it per
``(m, n, compute_q, config)`` in a bounded LRU.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["BackendCapabilities", "BackendSpec", "register_backend",
           "unregister_backend", "get_backend", "list_backends",
           "available_backends", "complex_capable_backends"]


@dataclasses.dataclass(frozen=True)
class BackendCapabilities:
    """What a QRD backend can do — drives validation and error messages.

    Parameters
    ----------
    bit_exact : bool
        The backend reproduces the paper's unit bit-for-bit (the
        ``'cordic'`` family contract, DESIGN.md §5).
    schedules : tuple[str, ...]
        Rotation schedules the backend understands.  Backends that do not
        consume a Givens schedule at all (``'jnp'``) list only ``'col'``
        and are rejected early when another schedule is requested.
    wavefront : bool
        ``schedule='sameh_kuck'`` routes onto the stage-parallel wavefront
        datapath (DESIGN.md §8) instead of a flattened step order.
    sharding : bool
        The backend composes with a batch-sharding mesh
        (``QRDConfig.mesh``, `repro.launch.sharding.shard_qrd_batch`).
    dtypes : tuple[str, ...]
        Dtypes the backend can produce.  These gate the *dtype family*
        (real vs complex), not the exact precision: requesting a dtype
        of a listed family is always valid, and the backend outputs its
        natural precision for that family (the bit-accurate backends
        list only float64/complex128 and return those regardless of the
        requested precision — their accuracy comes from ``givens.fmt``,
        exactly as the real path has always worked with the default
        float32 config).  Complex entries declare the backend
        complex-capable: `QRDConfig` validation rejects complex dtypes on
        backends without one, and `QRDEngine` routes complex operands
        onto the complex datapath only where one is declared.
    max_shape : tuple[int, int] or None
        Largest ``(m, n)`` a *single flat* (one-tile) factorization may
        have on this backend, or ``None`` for "unbounded" (host loops and
        jnp reference paths).  Bounded backends are the kernel-resident
        ones: one matrix tile must fit VMEM, and the int32 block-FP
        datapath additionally caps m by fixed-point headroom (frac + 2
        CORDIC guard bits + log2(sqrt(m)) column-growth must stay inside
        a signed 32-bit word — DESIGN.md §14).  The engine consults this
        to auto-route oversized operands onto the tiled layer and to
        raise a shape error naming the capacity instead of letting the
        kernel fail deep inside Pallas.
    supports_tiling : bool
        The backend's kernels compose with the tiled panel/TSQR layer
        (`repro.qrd.tiled`): its rotation control words can be exported
        from a panel factorization and replayed across trailing panels.
    description : str
        One line for docs and error messages.
    """

    bit_exact: bool = False
    schedules: tuple[str, ...] = ("col", "sameh_kuck")
    wavefront: bool = False
    sharding: bool = False
    dtypes: tuple[str, ...] = ("float64",)
    max_shape: tuple[int, int] | None = None
    supports_tiling: bool = False
    description: str = ""

    @property
    def supports_complex(self) -> bool:
        """Whether the backend declares a complex datapath."""
        return any(d.startswith("complex") for d in self.dtypes)

    def fits_flat(self, m: int, n: int) -> bool:
        """Whether an ``(m, n)`` operand fits one flat (untiled) kernel call."""
        if self.max_shape is None:
            return True
        return m <= self.max_shape[0] and n <= self.max_shape[1]


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """A registry entry: name + builder + capabilities."""

    name: str
    builder: Callable
    capabilities: BackendCapabilities


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(name: str, builder: Callable,
                     capabilities: BackendCapabilities | None = None,
                     *, overwrite: bool = False) -> BackendSpec:
    """Register a QRD backend under ``name``.

    Parameters
    ----------
    name : str
        Registry key — becomes a valid ``QRDConfig.backend`` value.
    builder : callable
        ``builder(config, m, n, compute_q) -> (A) -> (Q, R)`` (see module
        docstring for the full contract).
    capabilities : BackendCapabilities, optional
        Capability metadata; defaults to the conservative record (not
        bit-exact, both schedules, no wavefront/sharding).
    overwrite : bool
        Allow replacing an existing entry (default: raise on collision so
        a typo cannot silently shadow a built-in).

    Returns
    -------
    BackendSpec — the stored entry.
    """
    if not name or not isinstance(name, str):
        raise ValueError(f"backend name must be a non-empty str, got {name!r}")
    if not callable(builder):
        raise TypeError(f"builder for {name!r} must be callable")
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered "
                         "(pass overwrite=True to replace)")
    spec = BackendSpec(name, builder, capabilities or BackendCapabilities())
    _REGISTRY[name] = spec
    return spec


def unregister_backend(name: str) -> None:
    """Remove a backend (mainly for tests of third-party registration)."""
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> BackendSpec:
    """Look a backend up; unknown names raise with the available set."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(_REGISTRY))}") from None


def list_backends() -> dict[str, BackendCapabilities]:
    """Name -> capabilities for every registered backend (sorted copy)."""
    return {k: _REGISTRY[k].capabilities for k in sorted(_REGISTRY)}


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def complex_capable_backends() -> tuple[str, ...]:
    """Names of registered backends with a complex datapath (sorted).

    The single source of truth for 'complex-capable backends: ...' error
    messages (`QRDConfig.validate`, `QRDEngine._validate_operand`).
    """
    return tuple(n for n in sorted(_REGISTRY)
                 if _REGISTRY[n].capabilities.supports_complex)
