"""Fault-tolerant checkpointing: atomic, async, keep-last-k, resumable.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, written to a tmp dir and
`os.replace`d into place so a preemption mid-write never corrupts the latest
checkpoint.  `CheckpointManager` runs saves on a background thread (training
never blocks on disk), prunes old steps, and finds the newest complete
checkpoint at restart — including ones written by a *different* mesh size
(elastic restart re-shards at load time since arrays are stored unsharded).

At real multi-pod scale the npz writer would be swapped for a per-host
sharded writer (same manifest protocol); the manager/resume logic is the part
that matters and is what's tested.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import ml_dtypes
import numpy as np

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"

# npz cannot serialize ml_dtypes custom dtypes; store them as raw views
_CUSTOM = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _to_savable(a: np.ndarray):
    """Array -> (npz-serializable view, dtype tag).

    The tag is the *authoritative* dtype of the leaf: custom ml_dtypes
    leaves are stored as raw integer views (npz can't hold them) and the
    tag is the only record of what they were; native leaves — including
    complex64/128 `[R | z]` state and the packed-int64 words of the
    bit-accurate unit — round-trip through npz unchanged and the tag is
    verified against the restore template (`_check_dtype`).
    """
    name = a.dtype.name
    if name in _CUSTOM:
        return a.view(_CUSTOM[name][1]), name
    return a, name


def _from_saved(a: np.ndarray, name: str):
    if name in _CUSTOM:
        return a.view(_CUSTOM[name][0])
    return a


def _check_dtype(i: int, saved: str, template_dtype):
    """Refuse a silent dtype change at restore time.

    Restoring a complex128 fleet state into a float64 template would
    previously drop the imaginary parts via ``asarray(..., dtype=...)``
    (numpy ComplexWarning at best); packed-int64 Givens words cast to a
    float template would destroy their bit patterns entirely.  A dtype
    mismatch between checkpoint and template is a config error — fail
    loudly and make the caller convert deliberately.
    """
    want = np.dtype(template_dtype).name
    if saved != want:
        raise TypeError(
            f"checkpoint leaf {i} was saved as {saved} but the restore "
            f"template expects {want}; refusing to silently convert — "
            f"cast the restored tree (or fix the template) explicitly")


def _flatten(pytree):
    leaves, treedef = jax.tree.flatten(pytree)
    return leaves, treedef


def save_pytree(directory: str, step: int, pytree, extra: Optional[dict] = None):
    """Atomic synchronous save of one step."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(pytree)
    arrays, dtypes = {}, []
    for i, l in enumerate(leaves):
        a, name = _to_savable(np.asarray(l))
        arrays[f"leaf_{i}"] = a
        dtypes.append(name)
    np.savez(os.path.join(tmp, _ARRAYS), **arrays)
    manifest = {"step": step, "n_leaves": len(leaves), "dtypes": dtypes,
                "extra": extra or {}, "complete": True}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    """Newest step with a complete manifest (ignores torn writes)."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if not name.startswith("step_") or name.endswith(".tmp"):
            continue
        mpath = os.path.join(directory, name, _MANIFEST)
        try:
            with open(mpath) as f:
                m = json.load(f)
            if m.get("complete"):
                steps.append(int(m["step"]))
        except (OSError, ValueError, KeyError):
            continue
    return max(steps) if steps else None


def restore_pytree(directory: str, step: int, template):
    """Restore into `template`'s structure/dtypes (reshard-at-load)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, _ARRAYS))
    leaves, treedef = _flatten(template)
    assert manifest["n_leaves"] == len(leaves), \
        "checkpoint/template structure mismatch"
    dtypes = manifest.get("dtypes", [None] * len(leaves))
    out = []
    for i, l in enumerate(leaves):
        a = _from_saved(data[f"leaf_{i}"], dtypes[i])
        assert a.shape == tuple(l.shape), f"leaf {i}: {a.shape} vs {l.shape}"
        # Pre-dtype-manifest checkpoints (tag None) keep the legacy
        # cast-to-template behavior; tagged ones restore their exact dtype.
        if dtypes[i] is not None:
            _check_dtype(i, dtypes[i], l.dtype)
        out.append(jax.numpy.asarray(a, dtype=l.dtype))
    return treedef.unflatten(out), manifest["extra"]


class CheckpointManager:
    """Async save + keep-last-k pruning + resume."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, pytree, extra: Optional[dict] = None):
        self.wait()  # one in-flight save at a time
        # device_get on the caller thread: snapshot before training mutates
        host_tree = jax.tree.map(np.asarray, pytree)

        def work():
            try:
                save_pytree(self.directory, step, host_tree, extra)
                self._prune()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _prune(self):
        names = sorted(n for n in os.listdir(self.directory)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        for name in names[: max(0, len(names) - self.keep)]:
            shutil.rmtree(os.path.join(self.directory, name),
                          ignore_errors=True)

    def restore_latest(self, template):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        tree, extra = restore_pytree(self.directory, step, template)
        return step, tree, extra
