"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427]
"""
from repro.models import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    vocab=256000,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    head_dim=256,
    window=2048,                 # local attention
    pattern=("rec", "rec", "attn"),
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    mlp_act="gelu",              # GeGLU
    embed_scale=True,
    subquadratic=True,           # bounded state => runs long_500k
)
