"""Architecture / shape registry: --arch <id> --shape <cell> resolution.

Shape cells (assignment):
    train_4k     seq=4096    global_batch=256   (train_step)
    prefill_32k  seq=32768   global_batch=32    (prefill)
    decode_32k   seq=32768   global_batch=128   (serve_step, 1 new token)
    long_500k    seq=524288  global_batch=1     (serve_step; sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models import ModelConfig

_MODULES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "whisper-medium": "whisper_medium",
    "stablelm-1.6b": "stablelm_1_6b",
    "command-r-35b": "command_r_35b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen3-8b": "qwen3_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "mamba2-780m": "mamba2_780m",
}
ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str        # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def shape_of(name: str) -> ShapeCell:
    return SHAPES[name]


def applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    """Assignment rules: long_500k only for sub-quadratic archs; decode only
    for archs with a decoder (all ten here have one)."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False
    if cell.kind == "decode" and not cfg.has_decoder:
        return False
    return True


def applicable_cells():
    """All runnable (arch, shape) pairs — the dry-run/roofline cell list."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if applicable(cfg, s):
                out.append((a, s.name))
    return out


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (per the assignment)."""
    r = {
        "d_model": 128,
        "vocab": 512,
        "n_heads": 4,
        "n_kv_heads": min(max(cfg.n_kv_heads, 1), 2) if cfg.n_heads else 0,
        "d_ff": 256 if cfg.d_ff else 0,
        "head_dim": 32,
        "kv_chunk": 64,
        "window": 16 if cfg.window else None,
        "n_image_tokens": 64,
        "enc_seq": 32,
    }
    if cfg.family == "vlm":
        r["n_layers"] = 4
        r["cross_every"] = 2
    elif cfg.family == "hybrid":
        r["n_layers"] = 5          # 1 full (rec,rec,attn) period + 2 tail
    elif cfg.family == "encdec":
        r["n_layers"] = 2
        r["enc_layers"] = 2
    else:
        r["n_layers"] = 2
    if cfg.moe:
        r["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, d_expert=64,
            d_shared=64 if cfg.moe.n_shared else 0)
    if cfg.mla:
        r["mla"] = dataclasses.replace(
            cfg.mla, q_lora=64, kv_lora=32, qk_nope=16, qk_rope=16, v_dim=16)
        r["head_dim"] = 32
        r["n_kv_heads"] = 4        # MLA: kv heads == heads
    if cfg.ssm:
        r["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16,
                                       chunk=8)
    if cfg.rglru:
        r["rglru"] = dataclasses.replace(cfg.rglru, lru_width=128)
    return dataclasses.replace(cfg, **r)
