"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000, GQA, no-bias. [hf:CohereForAI/c4ai-command-r-v01]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="lm",
    n_layers=40,
    d_model=8192,
    vocab=256000,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    head_dim=128,
    rope_theta=10000.0,
)
