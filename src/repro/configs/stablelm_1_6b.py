"""stablelm-1.6b [dense]: 24L d_model=2048 32H d_ff=5632 vocab=100352,
partial rotary (25%). [hf:stabilityai/stablelm-2-1_6b]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="lm",
    n_layers=24,
    d_model=2048,
    vocab=100352,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    head_dim=64,
    rotary_pct=0.25,
    norm="ln",
)
