from .registry import (ARCH_IDS, SHAPES, applicable_cells, get_config,
                       reduce_config, shape_of)

__all__ = ["ARCH_IDS", "SHAPES", "get_config", "reduce_config",
           "applicable_cells", "shape_of"]
