"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff(expert)=1536 vocab=102400,
MoE 160e top-6, MLA kv_lora=512, 2 shared + 160 routed. [arXiv:2405.04434; hf]
"""
from repro.models import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="lm",
    n_layers=60,
    d_model=5120,
    vocab=102400,
    n_heads=128,
    n_kv_heads=128,           # MLA: every head has its own (latent) KV
    d_ff=12288,               # the single leading dense layer
    head_dim=128,
    rope_theta=10000.0,
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536,
                  n_shared=2, d_shared=2 * 1536, router_scale=16.0),
    first_dense=1,
    kv_chunk=512,             # 128 heads x 32k prefill: keep score tiles small
)
