"""mamba2-780m [ssm]: 48L d_model=1536 (attn-free) vocab=50280 ssm_state=128,
SSD (state-space duality). [arXiv:2405.21060]
"""
from repro.models import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    vocab=50280,
    d_ff=0,                      # attention-free, no MLP
    ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1,
                  conv_width=4, chunk=128),
    subquadratic=True,           # O(1) decode state => runs long_500k
)
