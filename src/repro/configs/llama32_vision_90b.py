"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, gated cross-attn image layers every 5th; vision frontend is a
STUB (input_specs provides patch embeddings). [hf:meta-llama/Llama-3.2-*-Vision]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,            # 80 self + 20 gated cross layers
    d_model=8192,
    vocab=128256,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    head_dim=128,
    rope_theta=500000.0,
    cross_every=5,
    n_image_tokens=6144,     # stub: 6k precomputed patch embeddings
)
