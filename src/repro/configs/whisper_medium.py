"""whisper-medium [audio]: 24L(enc)+24L(dec) d_model=1024 16H d_ff=4096
vocab=51865, enc-dec, conv frontend is a STUB (input_specs provides frame
embeddings). [arXiv:2212.04356]
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,             # decoder depth
    enc_layers=24,
    enc_seq=1500,            # 30 s of audio at 50 Hz after the conv stub
    d_model=1024,
    vocab=51865,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    head_dim=64,
    norm="ln",
    attn_bias=True,
    mlp_gated=False,
    mlp_act="gelu",
    mlp_bias=True,
)
