"""Cluster runtime: heartbeats, straggler detection, elastic re-mesh plans,
preemption handling.

This is the control-plane logic a 1000-node job needs; it is deliberately
free of jax.distributed so it can be unit-tested in-process (the transport —
GCS bucket, etcd, or the TPU coordination service — plugs in behind
`record_heartbeat`).  The *data plane* consequences (rebuild the mesh, replay
the data stream, restore the checkpoint) are all pure functions.

Policies implemented:
  - straggler detection by step-progress watermark (a host > `lag_steps`
    behind the median is flagged; flagged twice in a row -> evict);
  - fail-stop detection by heartbeat age;
  - elastic re-mesh: keep the model axis intact (TP groups must be whole),
    shrink the data(-parallel) axis to the largest full multiple that the
    surviving hosts can populate, and re-balance data shards.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class HostState:
    last_beat: float = 0.0
    step: int = 0
    flags: int = 0


class ClusterMonitor:
    """Tracks per-host heartbeats {host_id -> (time, step)}."""

    def __init__(self, n_hosts: int, beat_timeout: float = 60.0,
                 lag_steps: int = 50):
        self.n_hosts = n_hosts
        self.beat_timeout = beat_timeout
        self.lag_steps = lag_steps
        self.hosts: Dict[int, HostState] = {
            h: HostState() for h in range(n_hosts)}

    def record_heartbeat(self, host: int, step: int, now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        st = self.hosts[host]
        st.last_beat = now
        st.step = step

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.beat_timeout]

    def stragglers(self) -> List[int]:
        steps = sorted(st.step for st in self.hosts.values())
        median = steps[len(steps) // 2]
        out = []
        for h, st in self.hosts.items():
            if median - st.step > self.lag_steps:
                st.flags += 1
                if st.flags >= 2:
                    out.append(h)
            else:
                st.flags = 0
        return out

    def healthy_hosts(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.dead_hosts(now)) | set(self.stragglers())
        return [h for h in range(self.n_hosts) if h not in bad]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    active_hosts: tuple
    dropped_hosts: tuple
    restore_required: bool


def plan_elastic_mesh(alive_hosts: List[int], *, chips_per_host: int,
                      model_parallel: int, pod_size: int = 0) -> ElasticPlan:
    """Largest (data, model) mesh the surviving hosts can populate.

    The model (TP) axis is never shrunk — a partial TP group cannot hold a
    whole parameter shard set; instead whole TP groups are dropped from the
    data axis.  If `pod_size` > 0 and more than one full pod survives, a
    (pod, data, model) mesh is produced.
    """
    alive = sorted(alive_hosts)
    total_chips = len(alive) * chips_per_host
    data = total_chips // model_parallel
    if data == 0:
        raise RuntimeError("not enough chips for one model-parallel group")
    used_chips = data * model_parallel
    used_hosts = used_chips // chips_per_host
    active = tuple(alive[:used_hosts])
    dropped = tuple(h for h in alive if h not in active)
    if pod_size and used_chips >= 2 * pod_size * model_parallel:
        pods = used_chips // (pod_size * model_parallel)
        return ElasticPlan((pods, pod_size, model_parallel),
                           ("pod", "data", "model"),
                           active, dropped, restore_required=True)
    return ElasticPlan((data, model_parallel), ("data", "model"),
                       active, dropped, restore_required=True)


class PreemptionHandler:
    """SIGTERM-aware graceful shutdown: flips a flag the train loop polls."""

    def __init__(self, install: bool = True):
        self._requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:
                pass  # not on the main thread (tests)

    def _on_signal(self, signum, frame):
        self._requested = True

    def trigger(self):  # for tests
        self._requested = True

    @property
    def should_stop(self) -> bool:
        return self._requested
