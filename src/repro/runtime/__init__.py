from .cluster import (ClusterMonitor, ElasticPlan, PreemptionHandler,
                      plan_elastic_mesh)

__all__ = ["ClusterMonitor", "PreemptionHandler", "ElasticPlan",
           "plan_elastic_mesh"]
