"""AdamW with f32 moments over (possibly bf16) params. Pure-functional."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(F32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(F32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        new_p = p.astype(F32) - lr * (u + weight_decay * p.astype(F32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
