"""QMuon — Muon-style orthogonalized momentum updates via the paper's QRD.

Muon (Jordan et al. 2024) replaces the elementwise Adam update of 2-D weight
matrices with an (approximately) orthogonalized momentum matrix.  QMuon uses
an *exact thin QR factorization* computed by the framework's Givens-rotation
QRD engine instead of Newton-Schulz iterations — this is where the paper's
unit becomes a first-class training feature:

    m     = beta * m + g                      (momentum, f32)
    Q, R  = qr(m)            for (p >= q); qr(m.T).T otherwise
    u     = Q * sign(diag(R))                 column-sign fix
    p    -= lr * scale * u,   scale = sqrt(max(p, q) / min(p, q))

Backend 'jnp' is the production path; 'givens_float' runs the paper's exact
Givens rotation schedule in f32 (the same rotation order as the hardware
unit); the bit-accurate 'cordic' backend is exercised in tests on small
matrices.  Non-matrix leaves (norm gains, biases, scalars) fall back to AdamW.

Stacked layer weights (L, p, q) are handled by vmap over the leading axis.
State is held as flat leaf lists (python lists are pytrees, so jit/checkpoint
handle them transparently).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.qrd import qr_givens_float

F32 = jnp.float32


def _is_matrix(p):
    """2-D (or layer-stacked 3-D) weight with both trailing dims > 1."""
    return (p.ndim in (2, 3) and p.shape[-1] > 1 and p.shape[-2] > 1
            and jnp.issubdtype(p.dtype, jnp.floating))


def _orth_qr(m, backend="jnp"):
    """Orthogonalize a (p, q) matrix via thin QR; sign-fixed columns."""
    p, q = m.shape[-2], m.shape[-1]
    transpose = p < q
    a = jnp.swapaxes(m, -1, -2) if transpose else m
    if backend == "givens_float":
        # the paper's Givens schedule in f32 (column-major zeroing order)
        Qc, R = qr_givens_float(a, dtype=F32, compute_q=True)
        Q = Qc[..., :, : a.shape[-1]]
        R = R[..., : a.shape[-1], :]
    else:
        Q, R = jnp.linalg.qr(a.astype(F32), mode="reduced")
    d = jnp.sign(jnp.diagonal(R, axis1=-2, axis2=-1))
    d = jnp.where(d == 0, 1.0, d)
    Q = Q * d[..., None, :]
    out = jnp.swapaxes(Q, -1, -2) if transpose else Q
    scale = jnp.sqrt(max(p, q) / min(p, q)).astype(F32)
    return out * scale


def qmuon_init(params):
    leaves = jax.tree.leaves(params)
    mat = [_is_matrix(l) for l in leaves]
    return {
        "mom": [jnp.zeros(l.shape, F32) if m else jnp.zeros((0,), F32)
                for l, m in zip(leaves, mat)],
        "m": [jnp.zeros((0,), F32) if m else jnp.zeros(l.shape, F32)
              for l, m in zip(leaves, mat)],
        "v": [jnp.zeros((0,), F32) if m else jnp.zeros(l.shape, F32)
              for l, m in zip(leaves, mat)],
        "step": jnp.zeros((), jnp.int32),
    }


def qmuon_update(grads, state, params, *, lr, beta=0.95, weight_decay=0.0,
                 backend="jnp", adam_lr=None, b1=0.9, b2=0.95, eps=1e-8):
    adam_lr = lr if adam_lr is None else adam_lr
    g_leaves, treedef = jax.tree.flatten(grads)
    p_leaves = jax.tree.leaves(params)
    step = state["step"] + 1
    t = step.astype(F32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    new_p, new_mom, new_m, new_v = [], [], [], []
    for g, p, mom, m, v in zip(g_leaves, p_leaves,
                               state["mom"], state["m"], state["v"]):
        g32 = g.astype(F32)
        if _is_matrix(p):
            mom = beta * mom + g32
            if mom.ndim == 3:
                u = jax.vmap(functools.partial(_orth_qr, backend=backend))(mom)
            else:
                u = _orth_qr(mom, backend=backend)
            pn = p.astype(F32) * (1.0 - lr * weight_decay) - lr * u
            new_p.append(pn.astype(p.dtype))
            new_mom.append(mom)
            new_m.append(m)
            new_v.append(v)
        else:
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            pn = p.astype(F32) - adam_lr * u
            new_p.append(pn.astype(p.dtype))
            new_mom.append(mom)
            new_m.append(m)
            new_v.append(v)

    return treedef.unflatten(new_p), {
        "mom": new_mom, "m": new_m, "v": new_v, "step": step}
