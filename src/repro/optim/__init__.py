from .adamw import adamw_init, adamw_update
from .qmuon import qmuon_init, qmuon_update
from .compress import (compressed_psum, cross_pod_grad_sync, dequantize_int8,
                       ef_compress, ef_init, quantize_int8)
from .schedule import constant, warmup_cosine

__all__ = ["adamw_init", "adamw_update", "qmuon_init", "qmuon_update",
           "compressed_psum", "cross_pod_grad_sync", "quantize_int8",
           "dequantize_int8", "ef_compress", "ef_init",
           "warmup_cosine", "constant"]
