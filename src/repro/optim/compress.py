"""Gradient compression for cross-pod reduction: int8 quantization with
error feedback (1-bit-Adam-style noise shaping, at 8 bits).

The cross-pod data-parallel all-reduce is the longest-haul collective in a
multi-pod job (DCN or optical links, far slower than intra-pod ICI).
`compressed_psum` runs it at int8 instead of bf16/f32 — 2-4x fewer bytes on
the slowest link — and the residual quantization error is carried into the
next step (error feedback keeps the *accumulated* update unbiased; plain
quantized SGD provably stalls without it).

Under jit on a multi-pod mesh the all-gather below lowers to an int8
collective on the 'pod' axis — visible (and counted) in the dry-run HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

F32 = jnp.float32


def shard_map_compat(f, mesh, in_specs, out_specs):
    """`jax.shard_map` across JAX versions.

    Newer JAX exposes `jax.shard_map(..., check_vma=...)`; 0.4.x has
    `jax.experimental.shard_map.shard_map(..., check_rep=...)`.  Both
    replication checks are disabled (the int8 payload intentionally
    differs per participant before the gather).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def axis_size_compat(axis_name):
    """`jax.lax.axis_size` across JAX versions (0.4.x: psum of a literal)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(F32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.rint(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(F32) * scale


def ef_compress(g, residual):
    """Error-feedback compression of one tensor: returns (q, scale, new_res)."""
    corrected = g.astype(F32) + residual
    q, scale = quantize_int8(corrected)
    new_res = corrected - dequantize_int8(q, scale)
    return q, scale, new_res


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def compressed_psum(tree, axis_name):
    """shard_map-compatible mean-all-reduce at int8 precision.

    Each participant quantizes its local contribution, the int8 payloads are
    all-gathered over `axis_name`, dequantized and averaged locally.
    """
    n = axis_size_compat(axis_name)

    def one(x):
        q, scale = quantize_int8(x)
        qs = jax.lax.all_gather(q, axis_name)              # int8 on the wire
        ss = jax.lax.all_gather(scale, axis_name)
        return jnp.sum(qs.astype(F32) * ss.reshape((n,) + (1,) * x.ndim),
                       axis=0) / n

    return jax.tree.map(one, tree)


def cross_pod_grad_sync(grads, residuals, mesh, enabled=True):
    """Error-feedback int8 mean-reduction of grads across the 'pod' axis.

    grads must be pod-local (i.e. produced under shard_map over 'pod' or with
    batch-per-pod loss).  Returns (synced_grads, new_residuals).
    """
    if not enabled or "pod" not in mesh.axis_names:
        return grads, residuals

    def inner(g_tree, r_tree):
        qs = jax.tree.map(ef_compress, g_tree, r_tree)
        q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
        s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
        new_r = jax.tree.map(lambda t: t[2], qs, is_leaf=lambda x: isinstance(x, tuple))
        n = axis_size_compat("pod")

        def reduce_one(qi, si):
            qg = jax.lax.all_gather(qi, "pod")
            sg = jax.lax.all_gather(si, "pod")
            return jnp.sum(qg.astype(F32)
                           * sg.reshape((n,) + (1,) * qi.ndim), axis=0) / n

        synced = jax.tree.map(reduce_one, q, s)
        return synced, new_r

    spec = jax.tree.map(lambda _: P(), grads)
    fn = shard_map_compat(inner, mesh, (spec, spec), (spec, spec))
    return fn(grads, residuals)
