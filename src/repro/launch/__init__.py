# Launch layer: production mesh, GSPMD sharding rules, jitted step builders,
# the multi-pod dry-run driver and the roofline analyzer.
