"""QRD roofline analysis over BENCH_qrd.json (DESIGN.md §11).

Scores every measured backend×schedule row against the analytic bound
from `repro.launch.perfmodel`: the exact rotation-schedule work (ops)
and the kernels' HBM-pass contract (bytes) divided by a `DeviceSpec`'s
peak rates.  The fraction column is the repo's "performance truth" —
interpret-mode rows land orders of magnitude below 1.0 (they measure
the Python emulator, not the device), compiled rows are expected within
an order of magnitude of the bound.

    PYTHONPATH=src python -m repro.launch.roofline [BENCH_qrd.json]
        [--device-kind cpu] [--markdown]

`roofline_for_row` is the library entry point
`benchmarks.table6_7_throughput` calls to stamp each row's
``roofline_fraction`` as it is measured.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import perfmodel

__all__ = ["roofline_for_row", "analyze", "main"]

#: Rows the analytic model covers: real-datapath decomposition rows with
#: a measured rate.  Solve and complex rows carry different work (the
#: augmented column / three-rotation factor) — modeled as not-covered
#: rather than pretending.
_MODELED_BACKENDS = ("cordic", "cordic_pallas", "blockfp_pallas")


def roofline_for_row(row: dict, spec=None) -> dict | None:
    """Roofline terms for one BENCH_qrd.json result row, or None.

    Returns ``{"roofline_fraction", "bound_qrd_per_s", "dominant",
    "intensity_ops_per_byte", "device"}`` for modeled rows (real-QRD
    decomposition rows with ``qrd_per_s``); None for rows the analytic
    model does not cover (solve paths, complex datapath).

    Tiled rows (``row["tiling"]`` of 'panel' or 'tsqr', stamped by
    ``benchmarks.table6_7_throughput.measured_tiled_qrd_rates``) are
    scored against the *tiled* cost models
    (`perfmodel.panel_qrd_cost` / `perfmodel.tsqr_qrd_cost`) — the
    trailing-panel HBM re-reads and the tree composition work are part
    of the bound, not excuses below it.
    """
    backend = row.get("backend")
    if backend not in _MODELED_BACKENDS:
        return None
    if row.get("dtype", "").startswith("complex"):
        return None
    rate = row.get("qrd_per_s")
    m = row.get("m")
    if rate is None or m is None:
        return None
    n = row.get("n", m)
    if spec is None:
        spec = perfmodel.device_spec()
    # Interpret-mode packed rows run int64 emulation; a compiled packed
    # row (interpret_mode explicitly False) runs the dual-int32 lane
    # split.  Block-FP is int32 either way; None (host loop) is int64.
    word = None
    if backend in ("cordic", "cordic_pallas"):
        word = "lanes" if row.get("interpret_mode") is False else "int64"
    tiling = row.get("tiling")
    compute_q = bool(row.get("compute_q", True))
    iters = int(row.get("iters", 24))
    if tiling == "panel":
        cost = perfmodel.panel_qrd_cost(
            m, n, compute_q=compute_q, iters=iters, backend=backend,
            panel_n=int(row.get("panel_n", 8)), word=word)
    elif tiling == "tsqr":
        cost = perfmodel.tsqr_qrd_cost(
            m, n, compute_q=compute_q, iters=iters, backend=backend,
            tile_m=int(row.get("tile_m", 128)),
            panel_n=int(row.get("panel_n", 8)), word=word)
    else:
        cost = perfmodel.qrd_cost(
            m, n, compute_q=compute_q, iters=iters,
            backend=backend, schedule=row.get("schedule", "col"),
            hbm_passes=row.get("hbm_passes_per_qrd"), word=word)
    pt = perfmodel.roofline(cost, spec)
    return {
        "roofline_fraction": perfmodel.roofline_fraction(rate, cost, spec),
        "bound_qrd_per_s": pt.bound_qrd_per_s,
        "dominant": pt.dominant,
        "intensity_ops_per_byte": cost.intensity,
        "device": spec.name,
    }


def analyze(doc: dict, spec=None) -> list[dict]:
    """Score every modeled row of a BENCH_qrd.json document."""
    if spec is None:
        spec = perfmodel.device_spec()
    out = []
    for key in sorted(doc.get("results", {})):
        row = doc["results"][key]
        terms = roofline_for_row(row, spec)
        if terms is None:
            continue
        out.append({"key": key, "qrd_per_s": row.get("qrd_per_s"),
                    "interpret_mode": row.get("interpret_mode"), **terms})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("bench", nargs="?", default="BENCH_qrd.json",
                    help="BENCH_qrd.json to score")
    ap.add_argument("--device-kind", default=None,
                    help="override the DeviceSpec (default: this host)")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    with open(args.bench) as fh:
        doc = json.load(fh)
    spec = perfmodel.device_spec(args.device_kind)
    rows = analyze(doc, spec)
    if args.markdown:
        print("| row | measured qrd/s | bound qrd/s | fraction | dominant |"
              " interpret |")
        print("|---|---:|---:|---:|---|---|")
        for r in rows:
            print(f"| {r['key']} | {r['qrd_per_s']:.1f} | "
                  f"{r['bound_qrd_per_s']:.3g} | "
                  f"{r['roofline_fraction']:.2e} | {r['dominant']} | "
                  f"{r['interpret_mode']} |")
    else:
        print(f"# roofline vs {spec.name} "
              f"(peak {spec.peak_ops:.3g} ops/s, {spec.hbm_bw:.3g} B/s)")
        for r in rows:
            print(f"{r['key']:42s} measured={r['qrd_per_s']:12.1f}/s "
                  f"bound={r['bound_qrd_per_s']:12.3g}/s "
                  f"frac={r['roofline_fraction']:.2e} "
                  f"{r['dominant']:7s} interpret={r['interpret_mode']}")
        if not rows:
            print("no modeled rows found")
    return 0


if __name__ == "__main__":
    sys.exit(main())
