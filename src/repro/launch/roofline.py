"""Roofline analysis (EXPERIMENTS.md §Roofline).

Combines the analytic per-cell performance model (launch/perfmodel.py, which
encodes the partitioning the dry-run proved coherent) with the dry-run
artifacts (per-device live bytes from memory_analysis, collective shapes from
the post-SPMD HLO as a structural cross-check).

Terms per (arch x shape), single-pod mesh:
    t_compute    = FLOPs_pd / 197 TF/s      t_memory = HBM_pd / 819 GB/s
    t_collective = wire_pd / 50 GB/s
    roofline fraction = (MODEL_FLOPS / n_dev / peak) / max(term)
    useful ratio      = MODEL_FLOPS / (HLO-equivalent FLOPs, global)

    PYTHONPATH=src python -m repro.launch.roofline [--markdown] [--tag base]
"""
from __future__ import annotations

import argparse
import json
import os

from repro.configs import applicable_cells
from . import perfmodel

RESULTS_PATH = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "dryrun_results.json")


def load_record(results, arch, shape, mesh="16x16", tag="base"):
    return results.get(f"{arch}|{shape}|{mesh}|{tag}")


def analyze_cell(arch, shape, rec=None, **model_kw):
    m = perfmodel.build(arch, shape, **model_kw)
    out = {
        "arch": arch, "shape": shape,
        "t_compute_ms": m.t_compute * 1e3,
        "t_memory_ms": m.t_memory * 1e3,
        "t_collective_ms": m.t_collective * 1e3,
        "dominant": m.dominant,
        "model_flops": m.model_flops,
        "useful_ratio": m.model_flops / m.hlo_flops_global,
        "roofline_fraction": (m.model_flops / 256 / perfmodel.PEAK_FLOPS)
        / m.bound,
    }
    if rec:
        out["bytes_per_device_gib"] = (rec.get("bytes_per_device") or 0) / 2**30
        out["fits_hbm16"] = (rec.get("bytes_per_device") or 0) < 16 * 2**30
        out["hlo_collective_ops"] = rec.get("collectives", {}).get("ops", {})
        out["compile_ok"] = rec.get("ok", False)
    return out


_HINTS = {
    "compute": "compute-bound: raise per-device tile sizes / drop remat",
    "memory": ("HBM-bound: weight reads dominate — raise arithmetic "
               "intensity (bigger batch, fewer passes) or quantize weights"),
    "collective": ("collective-bound: cut FSDP gather volume (fewer gather "
                   "passes, SP halves TP traffic, int8 grad compression)"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="base")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--sp", action="store_true",
                    help="model sequence-parallel activations")
    args = ap.parse_args()

    results = {}
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            results = json.load(f)

    rows = []
    for arch, shape in applicable_cells():
        rec = load_record(results, arch, shape, args.mesh, args.tag)
        rows.append(analyze_cell(arch, shape, rec,
                                 seq_parallel=args.sp))

    if args.markdown:
        print("| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant |"
              " useful | roofline | GiB/dev | fits 16G |")
        print("|---|---|---:|---:|---:|---|---:|---:|---:|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']:.2f} | "
                  f"{r['t_memory_ms']:.2f} | {r['t_collective_ms']:.2f} | "
                  f"{r['dominant']} | {r['useful_ratio']*100:.0f}% | "
                  f"{r['roofline_fraction']*100:.1f}% | "
                  f"{r.get('bytes_per_device_gib', 0):.2f} | "
                  f"{'y' if r.get('fits_hbm16') else 'N'} |")
    else:
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} "
                  f"comp={r['t_compute_ms']:9.2f} mem={r['t_memory_ms']:9.2f} "
                  f"coll={r['t_collective_ms']:9.2f} dom={r['dominant']:10s} "
                  f"useful={r['useful_ratio']*100:4.0f}% "
                  f"roofline={r['roofline_fraction']*100:5.1f}% "
                  f"mem/dev={r.get('bytes_per_device_gib', 0):6.2f}GiB")
        doms = {}
        for r in rows:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\ndominant-term counts: {doms}")
        for d, hint in _HINTS.items():
            if doms.get(d):
                print(f"  {d}: {hint}")


if __name__ == "__main__":
    main()
