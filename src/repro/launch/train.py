"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
        [--shape train_4k] [--steps N] [--reduced] [--devices K] \
        [--opt seq_parallel] [--ckpt-dir DIR]

On a real TPU slice this binds the production mesh; on CPU (this container)
pass `--devices K --reduced` to run the same sharded step on K fake host
devices with the reduced config (the integration path the tests exercise).
The loop wires together every substrate layer: deterministic data, the
sharded jitted step, async checkpointing with resume, preemption handling,
and heartbeat reporting.
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU-smoke reduced config")
    ap.add_argument("--devices", type=int, default=0,
                    help="fake host devices (CPU bring-up); 0 = real devices")
    ap.add_argument("--mesh", choices=("auto", "single", "multi"),
                    default="auto")
    ap.add_argument("--batch", type=int, default=0, help="override batch")
    ap.add_argument("--seq", type=int, default=0, help="override seq")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--opt", action="append", default=[],
                    help="perf knob (see steps.OPTIONS), e.g. seq_parallel")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.devices}")
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, reduce_config, shape_of
    from repro.configs.registry import ShapeCell
    from repro.data import SyntheticLM
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_production_mesh
    from repro.models import init_params
    from repro.optim import adamw_init
    from repro.runtime import ClusterMonitor, PreemptionHandler

    for k in args.opt:
        if "=" in k:
            k, v = k.split("=")
            steps_mod.OPTIONS[k] = int(v)
        else:
            steps_mod.OPTIONS[k] = True

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    cell = shape_of(args.shape)
    if args.batch or args.seq:
        cell = ShapeCell(cell.name, cell.kind,
                         args.seq or cell.seq, args.batch or cell.batch)

    n_dev = len(jax.devices())
    if args.mesh == "auto" and n_dev not in (256, 512):
        # bring-up mesh: factor the available devices into (data, model)
        model = 1
        for m in (16, 8, 4, 2, 1):
            if n_dev % m == 0:
                model = m
                break
        mesh = jax.make_mesh((n_dev // model, model), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  "
          f"cell: {cell.name} (B={cell.batch}, S={cell.seq})")

    with mesh:
        fn, _ = steps_mod.build_train(cfg, cell, mesh, lr=args.lr)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        ds = SyntheticLM(vocab=cfg.vocab, seq=cell.seq,
                         global_batch=cell.batch, seed=0)
        mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
        preempt = PreemptionHandler()
        monitor = ClusterMonitor(n_hosts=jax.process_count())

        start = 0
        if mgr:
            got = mgr.restore_latest({"params": params, "opt": opt})
            if got[0] is not None:
                start, state, _ = got
                params, opt = state["params"], state["opt"]
                print(f"resumed at step {start}")

        for s in range(start, args.steps):
            batch = ds.batch(s)
            extras = ds.extras(cfg, cell.batch)
            batch.update(extras)
            params, opt, metrics = fn(params, opt, batch,
                                      jnp.asarray(s, jnp.int32))
            monitor.record_heartbeat(jax.process_index(), s)
            if (s + 1) % 10 == 0:
                print(f"step {s+1:5d}  loss {float(metrics['loss']):.4f}")
            if mgr and ((s + 1) % args.ckpt_every == 0 or preempt.should_stop):
                mgr.save_async(s + 1, {"params": params, "opt": opt},
                               extra={"data_step": s + 1})
            if preempt.should_stop:
                print("preemption: checkpointed, exiting 0")
                break
        if mgr:
            mgr.wait()
        print(f"done at step {s+1}, loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
