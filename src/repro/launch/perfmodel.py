"""Analytic per-cell performance model (FLOPs, HBM traffic, collectives).

Why analytic: the CPU dry-run pipeline makes two compiler artifacts
unavoidable — (a) `cost_analysis()` does not count library-call dots, and
(b) ops inside `while` (scan) bodies are counted once instead of
trip-count times.  The sharding *structure* (what is gathered/reduced, by
whom, how often) is fully determined by the dry-run's partitioning, so the
three roofline terms are derived here from first principles and
cross-checked against the post-SPMD HLO (per-body collective shapes match;
see EXPERIMENTS.md §Roofline notes).

All quantities are per device per step, on a mesh with `dp` data shards and
`tp` model shards (n_dev = dp * tp).

FLOPs (forward):
    matmul     2 * N_active * tokens / n_dev
    attention  4 * B*S^2/2 * H*dh / n_dev  (causal)        [train/prefill]
               4 * B*S_cache * H*dh / n_dev                [decode]
    ssd        4 * B*S*H*hd*(chunk/2 + d_state) / n_dev
train = fwd * (1 fwd + 2 bwd + 1 remat-replay) = 4x fwd.

HBM traffic:
    weights    2*N_total/tp read per pass (TP-resident after FSDP gather;
               MoE reads ALL experts — capacity slots are dense)
    optimizer  20 * N_total / n_dev (m,v f32 r+w, p r+w, grads)
    residuals  layer-stack saved by scan+remat: L*B/dp*S*D*2 (w+r)
               (/tp when sequence-parallel)
    logits     3 passes * B/dp * S * V/tp * 4
    kv/state   cache bytes read once per decode step

Collectives (wire bytes, ring-model):
    FSDP AG    passes * 2*N_total/tp * (dp-1)/dp
    grad RS+AG 2 * 2*N_total/tp  (reduce-scatter + opt all-gather)
    TP AR      2 * n_ar_per_layer * L * (B/dp * S * D * 2) * (tp-1)/tp
               (n_ar = 2 fwd + 2 bwd, halved to RS+AG pairs under SP)
    MoE A2A    2 passes * top_k * B/dp * S * D * 2  (dispatch + combine)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import get_config, shape_of

PEAK_FLOPS = 197e12     # bf16/chip, v5e-class target
HBM_BW = 819e9          # bytes/s/chip
ICI_BW = 50e9           # bytes/s/link


@dataclasses.dataclass
class CellModel:
    flops_pd: float
    hbm_pd: float
    coll_pd: float
    model_flops: float          # global useful FLOPs (6/2 * N_active * D)
    hlo_flops_global: float

    @property
    def t_compute(self):
        return self.flops_pd / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_pd / HBM_BW

    @property
    def t_collective(self):
        return self.coll_pd / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound(self):
        return max(self.t_compute, self.t_memory, self.t_collective)


def _attn_layers(cfg):
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        per = cfg.pattern
        return sum(1 for i in range(cfg.n_layers)
                   if per[i % len(per)] == "attn")
    if cfg.family == "encdec":
        return cfg.enc_layers + 2 * cfg.n_layers  # self + cross
    return cfg.n_layers


def build(arch: str, shape: str, *, dp=16, tp=16, pods=1,
          seq_parallel=False, remat_passes=1.0, fsdp_passes=3.0,
          grad_bytes=2.0, moe_capacity_factor=None) -> CellModel:
    cfg = get_config(arch)
    cell = shape_of(shape)
    n_dev = dp * tp * pods
    dp_t = dp * pods                      # total data shards (pod x data)
    B, S = cell.batch, cell.seq
    D = cfg.d_model
    L = cfg.n_layers + (cfg.enc_layers or 0)
    N_act = cfg.active_param_count()
    N_tot = cfg.param_count()
    H = max(cfg.n_heads, 1)
    dh = cfg.head_dim_()
    is_train = cell.kind == "train"
    is_decode = cell.kind == "decode"
    tokens = B * (1 if is_decode else S)
    B_loc = B / min(dp_t, B)

    # ---- FLOPs ----
    fwd = 2.0 * N_act * tokens
    n_attn = _attn_layers(cfg)
    if is_decode:
        kv_span = min(S, cfg.window) if cfg.window else S
        fwd += 4.0 * B * kv_span * H * dh * n_attn
    elif n_attn:
        span = min(S, cfg.window) if cfg.window else S
        fwd += 4.0 * B * S * span / 2 * H * dh * n_attn / max(
            1, (1 if cfg.family != "encdec" else 2))
    if cfg.ssm:
        hd = cfg.ssm.head_dim
        Hs = cfg.ssm.n_heads(D)
        fwd += 4.0 * tokens * Hs * hd * (cfg.ssm.chunk / 2 + cfg.ssm.d_state)
    if cfg.moe and moe_capacity_factor is None:
        moe_capacity_factor = cfg.moe.capacity_factor
    if cfg.moe:
        # capacity padding: expert slots are computed dense
        moe_l = cfg.n_layers - cfg.first_dense
        expert_fwd = 2.0 * (cfg.moe.top_k * 3 * D * cfg.moe.d_expert) \
            * tokens * moe_l / cfg.n_layers
        fwd += expert_fwd * (moe_capacity_factor - 1.0)

    passes = (3.0 + remat_passes) if is_train else 1.0
    flops_global = fwd * passes
    flops_pd = flops_global / n_dev

    # ---- HBM traffic ----
    w_read = 2.0 * N_tot / tp                      # per pass, per device
    hbm = passes * w_read
    if is_train:
        hbm += 20.0 * N_tot / n_dev                # optimizer + grads f32
        sp = tp if seq_parallel else 1
        hbm += 2.0 * L * B_loc * S * D * 2.0 / sp  # saved residual stack w+r
        hbm += 3.0 * B_loc * S * (cfg.vocab / tp) * 4.0   # logits fwd+bwd
    else:
        hbm += tokens / max(B, 1) * B_loc * S * D * 2.0 / max(n_dev // tp, 1)
    if is_decode:
        # read the whole KV/state cache once per token
        if cfg.family == "ssm":
            Hs = cfg.ssm.n_heads(D)
            cache = B * cfg.n_layers * Hs * cfg.ssm.d_state \
                * cfg.ssm.head_dim * 4.0
        elif cfg.mla:
            cache = B * S * cfg.n_layers * (cfg.mla.kv_lora
                                            + cfg.mla.qk_rope) * 2.0
        else:
            kv_span = min(S, cfg.window) if cfg.window else S
            cache = B * kv_span * 2 * cfg.n_kv_heads * dh * 2.0 * n_attn
        hbm += cache / n_dev * tp                  # batch-sharded only

    # ---- Collectives ----
    coll = 0.0
    frac_dp = (dp_t - 1) / dp_t if dp_t > 1 else 0.0
    frac_tp = (tp - 1) / tp if tp > 1 else 0.0
    if is_train:
        coll += fsdp_passes * (2.0 * N_tot / tp) * frac_dp      # FSDP AG
        coll += 2.0 * grad_bytes * N_tot / tp * frac_dp         # grad RS+AG
        n_ar = 2.0 if seq_parallel else 4.0   # SP: AR -> RS+AG pairs (half)
        coll += 2.0 * n_ar * L * (B_loc * S * D * 2.0) * frac_tp * 1.5
        if cfg.moe:
            coll += 2.0 * passes * cfg.moe.top_k * B_loc * S * D * 2.0 \
                * frac_tp
    else:
        # weights are TP-resident (no FSDP gather at serve time if cached),
        # but TP all-reduces remain
        n_ar = 2.0
        coll += n_ar * L * (B_loc * (1 if is_decode else S) * D * 2.0) \
            * frac_tp * 2.0
        if cfg.moe:
            coll += 2.0 * cfg.moe.top_k * B_loc * (1 if is_decode else S) \
                * D * 2.0 * frac_tp

    model_flops = (6.0 if is_train else 2.0) * N_act * tokens
    return CellModel(flops_pd=flops_pd, hbm_pd=hbm, coll_pd=coll,
                     model_flops=model_flops, hlo_flops_global=flops_global)
