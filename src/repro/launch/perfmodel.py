"""Analytic QRD performance model: ops + HBM bytes per decomposition.

Why analytic: the interpret-mode kernels measure Python dispatch, not
hardware, and compiled-mode wall clocks mix achievable throughput with
achieved.  The *work* of a blocked Givens QRD, by contrast, is exact —
the rotation schedule, the per-rotation element counts, the CORDIC
iteration depth and the kernels' HBM-pass contract are all architectural
— so the two roofline terms are derived here from first principles and
measured rates are reported as a fraction of the resulting bound
(DESIGN.md §11).

Work accounting for one m x n QRD (e = n + m row elements with Q):

    rotations        len(givens_schedule(m, n))  — the Sameh–Kuck stages
                     reorder but never change this set
    elements/rot     2 * (e - col)               — both rows from `col`
    ops/element      iters * OPS_PER_MICROROTATION + OPS_GAIN
                     (+ OPS_CONVERT on the packed path: the converter
                     dataflow runs per element per rotation)
    word factor      1.0 for the int32 block-FP datapath, ~2x for the
                     int64 packed word (64-bit ALU emulation), ~3.5x for
                     the dual-int32 lane split (carry/shift cross terms)

HBM bytes: the kernel-resident paths stage the working tile into VMEM
once and write it back once (``qrd_blocked.HBM_PASSES_PER_QRD`` = 2
passes over ``m * e * itemsize``); the step-serial host loop
('cordic' backend) round-trips every rotation — ``2 * len(steps)``
passes.  Encode/decode round-trips of the float64 operand add two more
8-byte passes on every path.

`DeviceSpec` carries the peak elementwise-op rate and HBM bandwidth per
device kind; `roofline` turns (cost, spec) into the achievable QRD/s
bound and `roofline_fraction` scores a measured rate against it.
"""
from __future__ import annotations

import dataclasses

__all__ = ["DeviceSpec", "QRDCost", "DEVICE_SPECS", "device_spec",
           "qrd_cost", "panel_qrd_cost", "tsqr_qrd_cost",
           "roofline", "roofline_fraction",
           "OPS_PER_MICROROTATION", "OPS_GAIN", "OPS_CONVERT",
           "WORD_FACTOR"]

#: Integer ops per element per CORDIC micro-rotation: two shifted
#: adds/subtracts (x', y'), the direction select, and the sigma/flip
#: bookkeeping amortized across the row.
OPS_PER_MICROROTATION = 8.0

#: Gain compensation per element: the fixed-point multiply by 1/K
#: (two 16-bit partial products, shift, optional RNE round).
OPS_GAIN = 12.0

#: Packed-path converter dataflow per element per rotation: unpack,
#: exponent align, expand (hidden bit / HUB extension), renormalize,
#: saturate/pack — roughly 40 elementwise ops each way.
OPS_CONVERT = 80.0

#: Relative ALU cost of one "op" in each datapath's word representation.
WORD_FACTOR = {
    "int32": 1.0,      # blockfp: native 32-bit lanes
    "int64": 2.0,      # packed word on a 64-bit ALU (interpret / CPU)
    "lanes": 3.5,      # dual-int32 split: carries, two-case shifts, muls
}


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Peak rates the roofline divides by.

    ``peak_ops`` is the sustained *elementwise integer/vector* op rate
    (ops/s) — these kernels run shifts/adds/selects, not MXU matmuls, so
    the VPU-class number is the honest ceiling, not the headline FLOPs.
    ``hbm_bw`` is bytes/s of main-memory bandwidth.
    """

    name: str
    peak_ops: float
    hbm_bw: float


#: Keyed by `jax.devices()[0].device_kind` (lowercased prefix match).
DEVICE_SPECS = {
    # Generic host CPU: ~12 int32 lanes x ~4 GHz sustained vector ALU,
    # dual-channel DDR-class bandwidth.  Deliberately round numbers —
    # the CPU lane is interpret-mode anyway; fractions are directional.
    "cpu": DeviceSpec("cpu", peak_ops=4.8e10, hbm_bw=2.0e10),
    # TPU v5e: 8 VPU lanes x 8x128 x 940 MHz ~ 1e12 int32 ops/s/core,
    # 819 GB/s HBM.
    "tpu v5 lite": DeviceSpec("tpu v5 lite", peak_ops=9.6e11, hbm_bw=8.19e11),
    "tpu v4": DeviceSpec("tpu v4", peak_ops=1.1e12, hbm_bw=1.2e12),
}

_GENERIC = DeviceSpec("generic", peak_ops=1.0e11, hbm_bw=1.0e11)


def device_spec(kind: str | None = None) -> DeviceSpec:
    """Resolve a `DeviceSpec` for a device kind (default: this process's
    first device).  Unknown kinds get a generic mid-range spec — the
    fraction column stays defined, clearly labeled by spec name."""
    if kind is None:
        import jax
        kind = jax.devices()[0].device_kind
    k = kind.lower()
    for prefix, spec in DEVICE_SPECS.items():
        if k.startswith(prefix):
            return spec
    return _GENERIC


@dataclasses.dataclass(frozen=True)
class QRDCost:
    """Work of one QRD: elementwise ops and HBM bytes (per matrix)."""

    ops: float
    hbm_bytes: float

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, ops per HBM byte."""
        return self.ops / self.hbm_bytes if self.hbm_bytes else float("inf")


def _datapath_terms(backend: str, iters: int, word: str | None):
    """Shared datapath constants: (weighted ops per rotated element,
    working-word itemsize in bytes) for the named backend."""
    packed = backend in ("cordic", "cordic_pallas")
    per_elem = iters * OPS_PER_MICROROTATION + OPS_GAIN
    if packed:
        per_elem += OPS_CONVERT
    if word is None:
        word = "int64" if (packed or backend == "fixed") else "int32"
    itemsize = 8 if (packed or backend == "fixed") else 4
    return per_elem * WORD_FACTOR[word], itemsize


def _active_elements(m: int, n: int, e: int) -> float:
    """Sum over the schedule of the elements both rows rotate.

    The column-major and Sameh–Kuck schedules perform the identical
    rotation set — (pivot, target, col) with 2·(e − col) live elements —
    so this is schedule-independent.
    """
    total = 0
    for col in range(min(m - 1, n)):
        total += (m - 1 - col) * 2 * (e - col)
    return float(total)


def qrd_cost(m: int, n: int, *, compute_q: bool = True, iters: int = 24,
             backend: str = "blockfp_pallas", schedule: str = "col",
             hbm_passes: float | None = None,
             word: str | None = None) -> QRDCost:
    """Analytic cost of one m x n QRD on the named datapath.

    Parameters
    ----------
    iters : int
        CORDIC micro-rotation depth (``GivensConfig.resolved_iters()``
        for the packed path, the ``iters`` knob for block-FP).
    backend : str
        ``'blockfp_pallas'`` (int32, no converter dataflow),
        ``'cordic_pallas'`` / ``'cordic'`` (packed word + converters),
        ``'fixed'`` (int64 word, no converters).
    hbm_passes : float, optional
        Override the kernel's HBM-pass contract; defaults from the
        backend (`repro.kernels.qrd_blocked.HBM_PASSES_PER_QRD` for the
        kernel-resident paths, ``2 * len(steps)`` for the host loop).
    word : str, optional
        Word representation override (`WORD_FACTOR` key); defaults from
        the backend (+ device: the packed path costs int64 emulation on
        CPU hosts and the lane split on 32-bit accelerators — callers
        who know pass it explicitly, the default stays 'int64').
    """
    e = n + (m if compute_q else 0)
    elems = _active_elements(m, n, e)
    rotations = sum(m - 1 - c for c in range(min(m - 1, n)))

    ops_per_elem, itemsize = _datapath_terms(backend, iters, word)
    ops = elems * ops_per_elem

    if hbm_passes is None:
        if backend == "cordic":          # host loop: round-trip per step
            hbm_passes = 2.0 * rotations
        else:                            # kernel-resident: in + out
            from repro.kernels.qrd_blocked import HBM_PASSES_PER_QRD
            hbm_passes = float(HBM_PASSES_PER_QRD)
    bytes_ = hbm_passes * m * e * itemsize
    bytes_ += 2.0 * m * e * 8            # float64 encode read + decode write
    return QRDCost(ops=ops, hbm_bytes=bytes_)


def panel_qrd_cost(m: int, n: int, *, compute_q: bool = True, iters: int = 24,
                   backend: str = "blockfp_pallas", panel_n: int = 8,
                   word: str | None = None) -> QRDCost:
    """Analytic cost of the tiled *panel* route (`repro.qrd.tiled`).

    The rotation set is identical to the flat schedule, but the
    dataflow differs on both roofline axes and the model must say so:

    * **ops** — every rotation spans the full ``panel_n``-wide factor
      tile (masked lanes still burn ALU slots) and the trailing region
      padded up to whole panel tiles, instead of exactly the live
      ``e − col`` suffix.
    * **bytes** — the factor tile and the trailing panels round-trip
      HBM *once per panel sweep*, so the matrix sees ≈ ``n / panel_n``
      passes where the flat kernel's contract is
      `repro.kernels.qrd_blocked.HBM_PASSES_PER_QRD` total.  This is
      the price of unbounded columns; the roofline fraction of a
      ``tiled:`` row is judged against this heavier bound, not the
      flat one.
    """
    e = n + (m if compute_q else 0)
    ops_per_elem, itemsize = _datapath_terms(backend, iters, word)
    elems = 0.0
    bytes_ = 0.0
    for c0 in range(0, min(n, m - 1), panel_n):
        nc = min(panel_n, n - c0)
        mr = m - c0
        tw = e - c0 - nc
        twp = -(-tw // panel_n) * panel_n if tw > 0 else 0
        rot = sum(mr - 1 - c for c in range(min(mr - 1, nc)))
        elems += rot * 2.0 * (nc + twp)
        bytes_ += 2.0 * mr * (nc + twp) * itemsize   # sweep in + out
    bytes_ += 2.0 * m * e * 8            # float64 encode read + decode write
    return QRDCost(ops=elems * ops_per_elem, hbm_bytes=bytes_)


def tsqr_qrd_cost(m: int, n: int, *, compute_q: bool = True, iters: int = 24,
                  backend: str = "blockfp_pallas", tile_m: int = 128,
                  panel_n: int = 8, word: str | None = None) -> QRDCost:
    """Analytic cost of the tiled *tsqr* route (`repro.qrd.tiled`).

    ``L = ceil(m / tile_m)`` leaf factorizations of ``(tile_m, n)`` plus
    ``L − 1`` tree-node factorizations of stacked ``(2n, n)`` R pairs,
    each costed on the panel model (the tiled driver runs every node
    through the panel kernels).  With Q the composition adds the float64
    einsum work — ``ceil(log2 L)`` levels of per-leaf ``(n, n)`` factor
    updates and the final ``(tile_m, n) @ (n, n)`` per leaf — plus one
    HBM round-trip of the leaf-Q stack (``L · tile_m · n`` float64
    elements held between the leaf launch and the composition).
    """
    L = -(-m // tile_m)
    leaf = panel_qrd_cost(tile_m, n, compute_q=compute_q, iters=iters,
                          backend=backend, panel_n=panel_n, word=word)
    node = panel_qrd_cost(2 * n, n, compute_q=compute_q, iters=iters,
                          backend=backend, panel_n=panel_n, word=word)
    ops = L * leaf.ops + (L - 1) * node.ops
    bytes_ = L * leaf.hbm_bytes + (L - 1) * node.hbm_bytes
    if compute_q:
        levels = max(1, (L - 1).bit_length())
        ops += levels * L * 2.0 * n ** 3         # per-level B updates
        ops += L * 2.0 * tile_m * n ** 2         # final Q_leaf @ B
        bytes_ += 2.0 * L * tile_m * n * 8       # leaf-Q stack round-trip
    return QRDCost(ops=ops, hbm_bytes=bytes_)


@dataclasses.dataclass(frozen=True)
class RooflinePoint:
    """The bound for one (cost, device) pair."""

    t_compute: float     # s per QRD at peak_ops
    t_memory: float      # s per QRD at hbm_bw

    @property
    def bound_s(self) -> float:
        return max(self.t_compute, self.t_memory)

    @property
    def bound_qrd_per_s(self) -> float:
        return 1.0 / self.bound_s

    @property
    def dominant(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"


def roofline(cost: QRDCost, spec: DeviceSpec) -> RooflinePoint:
    """The achievable-rate bound: whichever of compute and memory is
    slower caps throughput (batched QRDs pipeline, so no latency term)."""
    return RooflinePoint(t_compute=cost.ops / spec.peak_ops,
                         t_memory=cost.hbm_bytes / spec.hbm_bw)


def roofline_fraction(measured_qrd_per_s: float, cost: QRDCost,
                      spec: DeviceSpec) -> float:
    """Measured rate as a fraction of the analytic bound.

    ~1.0 means the kernel saturates the modeled resource; interpret-mode
    rates land orders of magnitude below 1 (they measure the emulator,
    not the device) — which is exactly the honesty the column exists
    to enforce.
    """
    return measured_qrd_per_s / roofline(cost, spec).bound_qrd_per_s
