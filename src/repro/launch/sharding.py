"""GSPMD sharding rules: params, optimizer state, batches, decode caches.

Scheme (DESIGN.md §6): FSDP x TP.
  - column-parallel projections (wq/wk/wv, mlp up/gate, ssm in_proj, ...):
        (d_in, d_out) -> P(fsdp, "model")
  - row-parallel projections (wo, mlp down, out_proj, ...):
        (d_in, d_out) -> P("model", fsdp)
  - MoE experts shard the expert axis over "model" (expert parallelism)
    and an inner dim over fsdp.
  - embeddings/lm_head shard the vocab over "model" and d_model over fsdp.
  - norms, biases, gates, small per-head vectors: replicated.
Rules are right-aligned to the leaf rank, so layer-stacked (L, ...) and
period-stacked (P, k, ...) parameters inherit the same rule with leading
None axes.

fsdp = ("data",) on the single-pod mesh, ("pod", "data") on the multi-pod
mesh — ZeRO-3-style sharding extends across pods; batch shards the same axes.
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import data_axes, dp_size

__all__ = ["param_specs", "opt_specs", "batch_specs", "cache_specs",
           "to_shardings", "qrd_batch_spec", "qrd_stage_table_spec",
           "shard_qrd_batch", "tsqr_node_spec", "shard_tsqr_nodes",
           "fleet_slot_spec", "shard_fleet"]

_FSDP = "__fsdp__"  # placeholder resolved to the mesh's data axes

# last-path-component -> right-aligned partition rule
_PARAM_RULES = {
    # embeddings / head
    "embed": ("model", _FSDP),
    "lm_head": (_FSDP, "model"),
    # attention
    "wq": (_FSDP, "model"), "wk": (_FSDP, "model"), "wv": (_FSDP, "model"),
    "wo": ("model", _FSDP),
    "bq": ("model",), "bk": ("model",), "bv": ("model",),
    # MLA
    "q_down": (_FSDP, None), "q_up": (None, "model"),
    "kv_down": (_FSDP, None), "k_up": (None, "model"),
    "v_up": (None, "model"),
    # MLP
    "up": (_FSDP, "model"), "gate": (_FSDP, "model"),
    "down": ("model", _FSDP),
    "up_b": ("model",),
    # MoE
    "router": (_FSDP, None),
    "w_gate": ("model", _FSDP, None), "w_up": ("model", _FSDP, None),
    "w_down": ("model", None, _FSDP),
    # SSM / RG-LRU
    "in_proj": (_FSDP, "model"), "out_proj": ("model", _FSDP),
    "in_x": (_FSDP, "model"), "in_y": (_FSDP, "model"),
    "W_a": (None, "model"), "W_x": (None, "model"),
    "Lambda": ("model",), "b_a": ("model",), "b_x": ("model",),
    "conv_w": (None, "model"), "conv_b": ("model",),
    "norm_w": ("model",),
    "out": ("model", _FSDP),
}


def _key_name(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return entry.name
    return ""


def _walk(tree, path):
    """Follow a key path (Dict/Sequence entries) through a pytree."""
    sub = tree
    for entry in path:
        if isinstance(entry, jax.tree_util.DictKey):
            if not (isinstance(sub, dict) and entry.key in sub):
                return None
            sub = sub[entry.key]
        elif isinstance(entry, jax.tree_util.SequenceKey):
            if not isinstance(sub, (list, tuple)) or entry.idx >= len(sub):
                return None
            sub = sub[entry.idx]
        else:
            return None
    return sub


def _right_align(rule, ndim):
    rule = tuple(rule)
    if len(rule) > ndim:     # e.g. a scalar matched by name: replicate
        return P()
    return P(*((None,) * (ndim - len(rule)) + rule))


def _resolve(spec: P, fsdp):
    return P(*(fsdp if s == _FSDP else s for s in spec))


def _mask_uneven(shape, spec: P, mesh) -> P:
    """Drop sharding on dims the axis product doesn't divide evenly —
    jit arguments require exact divisibility (unlike GSPMD intermediates)."""
    out = []
    for dim, s in zip(shape, spec):
        if s is not None:
            axes = s if isinstance(s, tuple) else (s,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % n != 0:
                s = None
        out.append(s)
    return P(*out)


def param_specs(params_struct, mesh):
    """PartitionSpec tree for a params (or ShapeDtypeStruct) tree."""
    fsdp = data_axes(mesh)

    def one(path, leaf):
        name = ""
        for entry in reversed(path):
            name = _key_name(entry)
            if name:
                break
        rule = _PARAM_RULES.get(name)
        if rule is None or leaf.ndim == 0:
            return P()
        spec = _resolve(_right_align(rule, leaf.ndim), fsdp)
        return _mask_uneven(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, params_struct)


def opt_specs(opt_struct, pspecs, mesh):
    """Optimizer state follows its parameter's sharding (m/v mirror params)."""

    def one(path, leaf):
        names = [_key_name(e) for e in path]
        if "step" in names or leaf.ndim == 0 or leaf.size == 0:
            return P()
        # adamw state: {'m': tree, 'v': tree, 'step'} — strip the head key
        # and look the parameter up in pspecs by the remaining path.
        sub = _walk(pspecs, path[1:])
        return sub if isinstance(sub, P) else P()

    return jax.tree_util.tree_map_with_path(one, opt_struct)


def batch_specs(batch_struct, mesh):
    """Shard the batch dim over the data axes (replicate if not divisible)."""
    fsdp = data_axes(mesh)
    n = dp_size(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        b = leaf.shape[0]
        lead = fsdp if b % n == 0 else None
        return P(lead, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_struct)


# cache leaf-name -> (batch_axis_offset_from_right, rule right of batch)
_CACHE_RULES = {
    # (..., B, S, Hk, dh): shard batch + head_dim (kv heads are often < 16)
    "k": (_FSDP, None, None, "model"),
    "v": (_FSDP, None, None, "model"),
    "cross_k": (_FSDP, None, None, "model"),
    "cross_v": (_FSDP, None, None, "model"),
    # MLA latent cache (..., B, S, lat)
    "c_kv": (_FSDP, None, "model"),
    "k_rope": (_FSDP, None, None),
}


def cache_specs(cache_struct, mesh):
    fsdp = data_axes(mesh)
    n = dp_size(mesh)

    def one(path, leaf):
        name = ""
        for entry in reversed(path):
            name = _key_name(entry)
            if name and not name.isdigit():
                break
        if name in _CACHE_RULES:
            rule = _CACHE_RULES[name]
        elif name == "state" and leaf.ndim >= 4:   # ssm (..., B, H, N, hd)
            rule = (_FSDP, "model", None, None)
        elif name == "state":                       # rg-lru (..., B, w)
            rule = (_FSDP, "model")
        elif name == "conv":                        # (..., B, K-1, C)
            rule = (_FSDP, None, "model")
        else:
            return P()
        spec = _resolve(_right_align(rule, leaf.ndim), fsdp)
        # batch divisibility: find the batch dim (first non-None entry)
        resolved = []
        for dim, s in zip(leaf.shape, spec):
            if s is not None:
                axes = s if isinstance(s, tuple) else (s,)
                sz = int(np.prod([mesh.shape[a] for a in axes]))
                if dim % sz != 0:
                    s = None
            resolved.append(s)
        return P(*resolved)

    return jax.tree_util.tree_map_with_path(one, cache_struct)


def qrd_batch_spec(ndim, batch, mesh) -> P:
    """PartitionSpec for a batched QRD operand: batch axis over data axes.

    A batch of (tall-skinny) matrices ``(batch, m, n)`` is embarrassingly
    parallel over the leading axis — each device triangularizes its local
    shard with the kernel-resident blocked QR and no collectives are
    needed.  The matrix axes stay replicated (a single m x n tile lives in
    one core's VMEM); falls back to full replication when the data-axis
    product doesn't divide the batch (jit arguments need exact
    divisibility).
    """
    fsdp = data_axes(mesh)
    lead = fsdp if batch % dp_size(mesh) == 0 else None
    return P(lead, *([None] * (ndim - 1)))


def qrd_stage_table_spec() -> P:
    """PartitionSpec for the wavefront stage index tables: replicated.

    The (S, Pmax) pivot/target/column tables of the wavefront kernels
    (`repro.kernels.ops.qr_packed_wavefront`) are control metadata, a few
    hundred bytes per schedule — every device consumes the *whole* table to
    drive its local stage scan, so they are replicated across the mesh.
    GSPMD infers this for the table constants baked into the jitted
    wavefront callables; the spec is exposed for callers that stream
    schedules in as explicit arguments (e.g. schedule sweeps).
    """
    return P()


def shard_qrd_batch(A, mesh):
    """Place a batched QRD operand with its leading axis sharded on `mesh`.

    Accepts any ``(batch..., m, n)`` shape — the engine's mesh dispatch
    (`repro.qrd.QRDEngine` with ``QRDConfig.mesh``) routes augmented
    solve operands and multi-axis batches through here too.  Only the
    first axis is sharded (over the data axes, when divisible); a single
    unbatched ``(m, n)`` matrix is replicated — there is nothing to
    scale over.
    """
    if A.ndim < 3:
        return jax.device_put(A, NamedSharding(mesh, P()))
    spec = qrd_batch_spec(A.ndim, A.shape[0], mesh)
    return jax.device_put(A, NamedSharding(mesh, spec))


def tsqr_node_spec(ndim, nodes, mesh) -> P:
    """PartitionSpec for a flattened TSQR node batch: node axis over data axes.

    A TSQR tree level is a stack of independent small QRDs — leaf tiles
    ``(batch*leaves, tile_m, n)`` at level 0, stacked R-pairs
    ``(batch*pairs, 2n, n)`` above — so each level shards exactly like a
    batched QRD operand over its flattened node axis.  This *is*
    `qrd_batch_spec` applied per tree level (one rule: a tree level is a
    batched annihilation); the alias exists so the tiled driver reads as
    tree code and documents that the node count halves per level, which
    means upper levels may fall back to replication when the shrunken
    node count stops dividing the data-axis product.
    """
    return qrd_batch_spec(ndim, nodes, mesh)


def shard_tsqr_nodes(X, mesh):
    """Place a flattened TSQR node stack with its node axis sharded on `mesh`.

    Applied by the tiled QRD driver (`repro.qrd.tiled`) before each tree
    level's batched factorization so leaf QRs and R-pair reductions run
    data-parallel; the surviving R factors are tiny (n x n) and gather
    implicitly through GSPMD when pairs recombine at the next level.
    """
    if X.ndim < 3:
        return jax.device_put(X, NamedSharding(mesh, P()))
    spec = tsqr_node_spec(X.ndim, X.shape[0], mesh)
    return jax.device_put(X, NamedSharding(mesh, spec))


def fleet_slot_spec(ndim, slots, mesh) -> P:
    """PartitionSpec for one `repro.serve.FleetState` leaf: slot axis over
    the data axes.

    Every fleet buffer is slot-major — ``(N, ...)`` with one row per
    filter — so the fleet shards exactly like a batched QRD operand:
    embarrassingly parallel over the leading axis, per-slot trailing
    axes replicated within their shard.  This *is* `qrd_batch_spec`
    applied to the slot axis (one rule for both: a fleet update is a
    batched annihilation); the alias exists so serving code reads as
    serving code and so 1-D leaves (λ, occupancy, generations) get the
    same leading-axis placement the 3-D work array does.
    """
    return qrd_batch_spec(max(ndim, 1), slots, mesh)


def shard_fleet(state, mesh):
    """Place every `FleetState` leaf with its slot axis sharded on `mesh`.

    Applied at fleet construction and re-applied after host-side slot
    mutations (admit/evict/restore) so the donated update step always
    sees consistently placed inputs — donation reuses the input buffers,
    hence placement must be decided before the first step, not by GSPMD
    inference mid-stream.
    """
    return jax.tree.map(
        lambda l: jax.device_put(
            l, NamedSharding(mesh, fleet_slot_spec(l.ndim, l.shape[0], mesh))),
        state)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
