"""Jitted step builders: train_step / prefill_step / serve_step per cell.

`build_cell(cfg, cell, mesh)` returns (jitted_fn, arg_structs) where
arg_structs are ShapeDtypeStructs — .lower(*arg_structs) never allocates, so
a 236B-parameter train step lowers on a laptop (this is the dry-run path).
The same builders drive real training/serving when given real arrays.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (decode_step, init_decode_state, init_params,
                          prefill, train_loss)
from repro.models.partition import set_activation_axes
from repro.optim import adamw_init, adamw_update, warmup_cosine
from . import sharding as shd
from .mesh import data_axes, dp_size


# Perf knobs togglable per dry-run tag (see EXPERIMENTS.md §Perf).
OPTIONS = {
    "seq_parallel": False,   # Megatron-style SP: shard seq dim of residuals
    "microbatch": 0,         # grad accumulation over k microbatches (0 = off)
    "pure_dp": False,        # small models: replicate params, DP over all axes
    "zero1": False,          # with pure_dp: shard optimizer state (ZeRO-1)
}


def _set_act_axes(mesh, batch: int):
    """Enable batch-activation constraints when the batch is shardable."""
    if OPTIONS["pure_dp"]:
        axes = tuple(mesh.axis_names)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        if batch % n == 0:
            set_activation_axes(axes, tp_axis=None, tp_size=1,
                                seq_parallel=False, dp_size=n)
        else:
            set_activation_axes(None)
        return
    if batch % dp_size(mesh) == 0:
        set_activation_axes(data_axes(mesh), tp_axis="model",
                            tp_size=mesh.shape["model"],
                            seq_parallel=OPTIONS["seq_parallel"],
                            dp_size=dp_size(mesh))
    else:
        set_activation_axes(None)

__all__ = ["batch_struct", "build_train", "build_prefill", "build_decode",
           "build_cell"]


def batch_struct(cfg, batch: int, seq: int):
    s = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        s["frames"] = jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model),
                                           jnp.float32)
    if cfg.family == "vlm":
        s["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
    return s


def _params_struct(cfg):
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


def build_train(cfg, cell, mesh, *, lr=3e-4, donate=True):
    _set_act_axes(mesh, cell.batch)
    p_struct = _params_struct(cfg)
    o_struct = jax.eval_shape(adamw_init, p_struct)
    b_struct = batch_struct(cfg, cell.batch, cell.seq)
    if OPTIONS["pure_dp"]:
        all_axes = tuple(mesh.axis_names)
        n_all = int(np.prod([mesh.shape[a] for a in all_axes]))
        p_spec = jax.tree.map(lambda _: P(), p_struct)
        if OPTIONS["zero1"]:
            # ZeRO-1: shard f32 moments over all chips, on the first dim
            # the axis product divides (layer-stacked dim 0 rarely does)
            def z1(l):
                for i, d in enumerate(l.shape):
                    if d % n_all == 0:
                        spec = [None] * l.ndim
                        spec[i] = all_axes
                        return P(*spec)
                return P()
            o_spec = jax.tree.map(z1, o_struct)
        else:
            o_spec = jax.tree.map(lambda _: P(), o_struct)
        b_spec = jax.tree.map(
            lambda l: P(all_axes, *([None] * (l.ndim - 1))), b_struct)
    else:
        p_spec = shd.param_specs(p_struct, mesh)
        o_spec = shd.opt_specs(o_struct, p_spec, mesh)
        b_spec = shd.batch_specs(b_struct, mesh)
    scalar = P()
    k_micro = OPTIONS["microbatch"]

    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = train_loss(cfg, p, batch)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step_fn(params, opt_state, batch, step):
        if k_micro and cell.batch % k_micro == 0:
            # gradient accumulation: scan over k microbatches; peak
            # activation memory drops ~k-fold, FSDP gathers are hoisted
            # out of the loop by XLA (loop-invariant params)
            micro = jax.tree.map(
                lambda x: x.reshape((k_micro, x.shape[0] // k_micro)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (loss, metrics), g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(lambda x: x / k_micro, g))
                return acc, loss

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(body, zeros, micro)
            loss = jnp.mean(losses)
            metrics = {}
        else:
            (loss, metrics), grads = grads_of(params, batch)
        lr_t = warmup_cosine(step, peak_lr=lr, warmup_steps=100,
                             total_steps=10000)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr=lr_t)
        return new_params, new_opt, {"loss": loss, **metrics}

    ns = lambda t: shd.to_shardings(t, mesh)
    jitted = jax.jit(
        step_fn,
        in_shardings=(ns(p_spec), ns(o_spec), ns(b_spec), NamedSharding(mesh, scalar)),
        out_shardings=(ns(p_spec), ns(o_spec), None),
        donate_argnums=(0, 1) if donate else (),
    )
    args = (p_struct, o_struct, b_struct,
            jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args


def build_prefill(cfg, cell, mesh):
    _set_act_axes(mesh, cell.batch)
    p_struct = _params_struct(cfg)
    b_struct = batch_struct(cfg, cell.batch, cell.seq)
    p_spec = shd.param_specs(p_struct, mesh)
    b_spec = shd.batch_specs(b_struct, mesh)

    def prefill_fn(params, batch):
        logits, cache = prefill(cfg, params, batch, cell.seq)
        return jnp.argmax(logits, -1).astype(jnp.int32), cache

    ns = lambda t: shd.to_shardings(t, mesh)
    jitted = jax.jit(prefill_fn,
                     in_shardings=(ns(p_spec), ns(b_spec)),
                     out_shardings=None)
    return jitted, (p_struct, b_struct)


def build_decode(cfg, cell, mesh):
    """One serve_step: new token against a seq_len-deep cache."""
    _set_act_axes(mesh, cell.batch)
    p_struct = _params_struct(cfg)
    c_struct = jax.eval_shape(
        functools.partial(init_decode_state, cfg, cell.batch, cell.seq))
    t_struct = jax.ShapeDtypeStruct((cell.batch, 1), jnp.int32)
    p_spec = shd.param_specs(p_struct, mesh)
    c_spec = shd.cache_specs(c_struct, mesh)
    t_spec = shd.batch_specs({"t": t_struct}, mesh)["t"]

    def serve_fn(params, token, cache, pos):
        logits, new_cache = decode_step(cfg, params, token, cache, pos)
        return jnp.argmax(logits, -1)[:, None].astype(jnp.int32), new_cache

    ns = lambda t: shd.to_shardings(t, mesh)
    jitted = jax.jit(
        serve_fn,
        in_shardings=(ns(p_spec), NamedSharding(mesh, t_spec), ns(c_spec),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, t_spec), ns(c_spec)),
        donate_argnums=(2,),
    )
    args = (p_struct, t_struct, c_struct, jax.ShapeDtypeStruct((), jnp.int32))
    return jitted, args


def build_cell(cfg, cell, mesh, **kw):
    if cell.kind == "train":
        return build_train(cfg, cell, mesh, **kw)
    if cell.kind == "prefill":
        return build_prefill(cfg, cell, mesh)
    if cell.kind == "decode":
        return build_decode(cfg, cell, mesh)
    raise ValueError(cell.kind)
