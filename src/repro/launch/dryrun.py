import os
# 512 fake host devices for the production meshes (must precede ANY jax
# import).  The disabled passes are CPU-pipeline loop-hoists that widen the
# bf16 remat stack to f32 — an artifact a TPU compile does not have; with
# them off, memory_analysis tracks the TPU-relevant footprint more closely.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion,convert-mover")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces:
  - proof the sharding config is coherent (compile succeeds),
  - memory_analysis (bytes/device — proves it fits),
  - cost_analysis (FLOPs / bytes accessed — feeds the roofline),
  - per-device collective wire bytes parsed from the post-SPMD HLO.

Results are cached in dryrun_results.json keyed by (arch, shape, mesh, tag)
so re-runs only compile what changed.  The 512 fake host devices exist ONLY
here (the env var above precedes every jax import, pinning the device count
before backend init).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import applicable_cells, get_config, shape_of
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

RESULTS_PATH = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "dryrun_results.json")


def _mesh_name(multi_pod):
    return "2x16x16" if multi_pod else "16x16"


def _sharded_bytes(struct_tree, spec_tree, mesh):
    """Analytic per-device bytes of a struct tree under its partition specs."""
    import numpy as np
    from jax.sharding import PartitionSpec

    total = 0
    for leaf, spec in zip(jax.tree.leaves(struct_tree),
                          jax.tree.leaves(spec_tree,
                                          is_leaf=lambda x: isinstance(
                                              x, PartitionSpec))):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for s in spec:
            if s is None:
                continue
            for ax in (s if isinstance(s, tuple) else (s,)):
                shards *= mesh.shape[ax]
        total += n * leaf.dtype.itemsize / shards
    return int(total)


def run_cell(arch: str, shape: str, multi_pod: bool, tag: str = "base"):
    cfg = get_config(arch)
    cell = shape_of(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        fn, args = build_cell(cfg, cell, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    rec = {"arch": arch, "shape": shape, "mesh": _mesh_name(multi_pod),
           "tag": tag, "ok": True,
           "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
           "n_devices": mesh.devices.size}

    # analytic per-device parameter bytes (independent of compiler artifacts)
    try:
        from repro.launch import sharding as shd_mod
        from repro.launch.steps import _params_struct
        ps = _params_struct(cfg)
        rec["param_bytes_per_device"] = _sharded_bytes(
            ps, shd_mod.param_specs(ps, mesh), mesh)
        rec["n_params"] = cfg.param_count()
        rec["n_params_active"] = cfg.active_param_count()
    except Exception as e:
        rec["param_bytes_error"] = f"{type(e).__name__}: {e}"

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)}
        arg = rec["memory"].get("argument_size_in_bytes", 0)
        alias = rec["memory"].get("alias_size_in_bytes", 0)
        tmp = rec["memory"].get("temp_size_in_bytes", 0)
        out = rec["memory"].get("output_size_in_bytes", 0)
        # live bytes/device: args + temps + (outputs not aliased to args)
        rec["bytes_per_device"] = int(arg + tmp + max(out - alias, 0))
    except Exception as e:  # CPU backend may not implement everything
        rec["memory_error"] = f"{type(e).__name__}: {e}"

    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["flops_per_device"] = float(cost.get("flops", 0.0))
        rec["hbm_bytes_per_device"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        rec["cost_error"] = f"{type(e).__name__}: {e}"

    try:
        txt = compiled.as_text()
        rec["collectives"] = collective_bytes(txt)
        rec["hlo_chars"] = len(txt)
    except Exception as e:
        rec["collective_error"] = f"{type(e).__name__}: {e}"
    rec["t_total_s"] = round(time.time() - t0, 1)
    return rec


def load_results():
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def save_results(res):
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1, sort_keys=True)


def key_of(arch, shape, multi_pod, tag):
    return f"{arch}|{shape}|{_mesh_name(multi_pod)}|{tag}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--tag", default="base")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", action="append", default=[],
                    help="perf knob, e.g. --opt seq_parallel (see steps.OPTIONS)")
    args = ap.parse_args()

    from repro.launch import steps as steps_mod
    for k in args.opt:
        if "=" in k:
            k, v = k.split("=")
            assert k in steps_mod.OPTIONS, k
            steps_mod.OPTIONS[k] = int(v)
        else:
            assert k in steps_mod.OPTIONS, k
            steps_mod.OPTIONS[k] = True

    if args.all:
        cells = applicable_cells()
    else:
        cells = [(args.arch, args.shape)]
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]
    if args.multi_pod and not args.all:
        meshes = [True]

    results = load_results()
    for (arch, shape) in cells:
        for mp in meshes:
            k = key_of(arch, shape, mp, args.tag)
            if not args.force and k in results and results[k].get("ok"):
                print(f"SKIP {k} (cached)")
                continue
            print(f"RUN  {k} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp, args.tag)
                cb = rec.get("collectives", {}).get("total", 0)
                print(f"  ok: {rec['t_total_s']}s, "
                      f"{rec.get('flops_per_device', 0):.3e} flops/dev, "
                      f"{rec.get('bytes_per_device', 0)/2**30:.2f} GiB/dev, "
                      f"{cb/2**20:.1f} MiB collective", flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": _mesh_name(mp),
                       "tag": args.tag, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {type(e).__name__}: {str(e)[:200]}", flush=True)
            results[k] = rec
            save_results(results)
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {os.path.abspath(RESULTS_PATH)}")


if __name__ == "__main__":
    main()
