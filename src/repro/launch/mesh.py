"""Production meshes.

Single pod:  (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Defined as a *function* so importing this module never touches jax device
state (the dry-run pins XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "data_axes", "dp_size"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch/FSDP axes: everything except 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n
