"""QRD-RLS fleet serving launcher — the deployment entrypoint.

    PYTHONPATH=src python -m repro.launch.serve --preset equalizer-ieee \
        [--slots 131072] [--cohorts 4] [--steps 1000] [--devices K] \
        [--ckpt-dir DIR] [--config cfg.json] [--seed 0]

Brings up an `repro.serve.RLSFleet` + `FleetServer` from a named preset
(`repro.serve.presets`) or a ``QRDConfig.to_json`` file, admits
`--cohorts` equal cohorts filling the fleet, then drives `--steps`
synthetic-traffic ticks (`repro.data.pipeline.SyntheticTraffic`) through
the async snapshot queue — submit, pump, heartbeat — with a checkpoint
every `--ckpt-every` steps when `--ckpt-dir` is set, and prints the
health report and sustained update throughput at the end.

``--devices K`` fakes a K-device host (the launch.train convention:
``--xla_force_host_platform_device_count``) and shards the slot axis
across a (K, 1) data mesh via `launch.sharding.shard_fleet`.

Exit code 0 requires every submitted snapshot to be applied (no backlog,
nothing dropped) and, when checkpointing, a final evict → restore that
reproduces the served weights bit-exactly — this is what CI's
serve-smoke lane asserts at the 2^17-slot scale.
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="equalizer-float64",
                    help="named deployment (repro.serve.list_fleet_presets)")
    ap.add_argument("--config", default=None,
                    help="QRDConfig JSON file (overrides the preset's config)")
    ap.add_argument("--slots", type=int, default=0,
                    help="fleet capacity (0 = preset default)")
    ap.add_argument("--n", type=int, default=0,
                    help="filter length (0 = preset default)")
    ap.add_argument("--cohorts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=200,
                    help="traffic ticks to serve")
    ap.add_argument("--per-step", type=int, default=0,
                    help="snapshots per tick (0 = server batch size)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.devices}")
    import numpy as np

    from repro.data.pipeline import SyntheticTraffic
    from repro.qrd import QRDConfig, QRDEngine
    from repro.serve import FleetServer, fleet_preset

    spec = fleet_preset(args.preset)
    cfg = spec["config"]
    if args.config:
        with open(args.config) as f:
            cfg = QRDConfig.from_json(f.read())
    fleet_kw = spec["fleet"]
    if args.slots:
        fleet_kw["slots"] = args.slots
    if args.n:
        fleet_kw["n"] = args.n

    mesh = None
    if args.devices:
        import jax
        mesh = jax.make_mesh((args.devices, 1), ("data", "model"))

    print(f"preset {args.preset}: {spec['description']}")
    print(f"config: {cfg.to_json()}")
    t0 = time.perf_counter()
    fleet = QRDEngine(cfg).fleet(mesh=mesh, **fleet_kw)
    server = FleetServer(fleet, ckpt_dir=args.ckpt_dir, **spec["server"])
    size = fleet.slots // args.cohorts
    for c in range(args.cohorts):
        server.admit_cohort(
            f"cohort-{c}",
            size if c else fleet.slots - size * (args.cohorts - 1))
    print(f"bring-up: {fleet!r} in {time.perf_counter() - t0:.2f}s, "
          f"{args.cohorts} cohorts of ~{size}")

    per_step = args.per_step or server.batch
    names = [c.name for c in server.cohorts()]
    traffic = SyntheticTraffic(users=min(c.size for c in server.cohorts()),
                               n=fleet.n, per_step=per_step, seed=args.seed,
                               complex_dtype=fleet.is_complex)
    applied = 0
    t0 = time.perf_counter()
    for step in range(args.steps):
        tick = traffic.batch(step)
        name = names[step % len(names)]
        server.submit_batch(name, np.asarray(tick["user"]),
                            np.asarray(tick["x"]), np.asarray(tick["d"]))
        applied += server.pump()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            server.checkpoint()
    elapsed = time.perf_counter() - t0
    health = server.health()
    print(f"served {applied} snapshot updates over {server.step} batches "
          f"in {elapsed:.2f}s ({applied / elapsed:,.0f} updates/s)")
    for name, stats in health["cohorts"].items():
        print(f"  {name}: {stats}")

    failures = []
    if health["queue_depth"] or any(
            s["backlog"] or s["dropped_stale"] or s["dropped_overflow"]
            for s in health["cohorts"].values()):
        failures.append(f"unserved traffic: {health}")

    if args.ckpt_dir:
        server.checkpoint(wait=True)
        probe = names[0]
        members = np.arange(min(8, size))
        w_before = server.query(probe, members)
        server.evict_cohort(probe)           # exercise slot recycling ...
        restored = server.restore_latest()   # ... then roll everything back
        w_after = server.query(probe, members)
        if restored is None or not np.array_equal(w_before, w_after):
            failures.append("restore did not reproduce served weights")
        else:
            print(f"checkpoint/restore at step {restored}: weights "
                  "bit-identical")

    if failures:
        raise SystemExit("; ".join(failures))
    print("serve smoke OK")


if __name__ == "__main__":
    main()
