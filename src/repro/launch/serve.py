"""Production serving launcher: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        [--reduced] [--devices K] [--batch 4] [--prompt-len 32] [--gen 16]

Same mesh/bring-up conventions as launch.train; uses the sharded
prefill/serve_step builders (KV caches, ring windows, SSM states included).
"""
import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   f" --xla_force_host_platform_device_count="
                                   f"{args.devices}")
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.configs.registry import ShapeCell
    from repro.data import SyntheticLM
    from repro.launch import steps as steps_mod
    from repro.models import init_params

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    max_len = args.prompt_len + args.gen
    n_dev = len(jax.devices())
    model = 1
    for m in (16, 8, 4, 2, 1):
        if n_dev % m == 0:
            model = m
            break
    mesh = jax.make_mesh((n_dev // model, model), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}")

    with mesh:
        pre_cell = ShapeCell("serve_prefill", "prefill", args.prompt_len,
                             args.batch)
        dec_cell = ShapeCell("serve_decode", "decode", max_len, args.batch)
        prefill_fn, _ = steps_mod.build_prefill(cfg, pre_cell, mesh)
        # decode builder creates its own zero cache struct; we reuse the
        # prefill cache, so rebuild the jit without donation mismatch
        serve_fn, _ = steps_mod.build_decode(cfg, dec_cell, mesh)

        params = init_params(cfg, jax.random.PRNGKey(0))
        ds = SyntheticLM(vocab=cfg.vocab, seq=args.prompt_len,
                         global_batch=args.batch, seed=7)
        batch = ds.batch(0)
        batch.update(ds.extras(cfg, args.batch))

        # prefill builds a max_len cache? prefill() uses cell.seq as max_len,
        # so decode continues in a fresh zero cache fed by replay for demo
        t0 = time.time()
        from repro.models import decode_step, init_decode_state, prefill
        logits, _short_cache = jax.jit(
            lambda p, b: prefill(cfg, p, b, max_len))(params, batch)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        print(f"prefill: {time.time()-t0:.1f}s (incl. compile)")

        cache = init_decode_state(cfg, args.batch, max_len)
        # re-ingest the prompt token-by-token (keeps the demo cache simple)
        step = jax.jit(lambda p, t, c, pos: decode_step(cfg, p, t, c, pos))
        for t in range(args.prompt_len):
            _, cache = step(params, batch["tokens"][:, t:t + 1], cache, t)
        out = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            logits, cache = step(params, tok, cache, args.prompt_len + i)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        rate = (args.gen - 1) * args.batch / (time.time() - t0)
        gen = np.asarray(jnp.concatenate(out, axis=1))
        print(f"decode: {rate:.1f} tok/s; sample: {gen[0, :8].tolist()}")


if __name__ == "__main__":
    main()
