"""Post-SPMD HLO analysis: collective byte counting + roofline terms.

`collective_bytes(hlo_text)` parses the partitioned module and sums, per
collective opcode, the bytes each device moves on the wire:

    all-gather          out_bytes * (n-1)/n
    all-reduce          2 * bytes * (n-1)/n        (ring: RS + AG phases)
    reduce-scatter      in_bytes * (n-1)/n  ==  out_bytes * (n-1)
    all-to-all          bytes * (n-1)/n
    collective-permute  bytes

where n is the replica-group size parsed from the op (n = 1 groups are
dropped — XLA sometimes emits degenerate collectives).  These are the
standard ring/bidirectional cost models; they are what feeds the
"collective term" of the roofline (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from collections import defaultdict


__all__ = ["collective_bytes", "DTYPE_BYTES", "parse_shape_bytes"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")


def parse_shape_bytes(shape_str: str) -> int:
    """Total bytes of all arrays in a result type like
    'bf16[8,128]' or '(bf16[8,128], f32[4])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("},")[0].strip("{}")
        return len([x for x in first.split(",") if x.strip() != ""])
    return 0


def collective_bytes(hlo_text: str):
    """-> dict: opcode -> per-device wire bytes (summed over ops), plus
    'total' and 'ops' (op count by opcode)."""
    per = defaultdict(float)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("//") or " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        # opcode sits between the result type (which may carry a layout
        # annotation, e.g. `f32[8,16]{1,0}`) and the operand list:
        #   %x = f32[8,16]{1,0} all-reduce(%y), replica_groups=...
        opcode = None
        for op in _OPS:
            if re.search(rf"(?:^|[)}}\]]\s*){op}(?:-start)?\(", rhs):
                opcode = op
                break
        if opcode is None:
            continue
        shape_part = rhs.split(opcode)[0]
        out_bytes = parse_shape_bytes(shape_part)
        n = _group_size(rhs)
        if n <= 1:
            continue
        frac = (n - 1) / n
        if opcode == "all-gather":
            b = out_bytes * frac
        elif opcode == "all-reduce":
            b = 2.0 * out_bytes * frac
        elif opcode == "reduce-scatter":
            b = out_bytes * (n - 1)
        elif opcode == "all-to-all":
            b = out_bytes * frac
        else:  # collective-permute
            b = out_bytes
        per[opcode] += b
        counts[opcode] += 1
    out = dict(per)
    out["total"] = float(sum(per.values()))
    out["ops"] = dict(counts)
    return out
