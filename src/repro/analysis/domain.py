"""Abstract domains for the bit-width dataflow verifier.

`Word` abstracts a 64-bit two's-complement machine word by the *reduced
product* of two classic domains:

- a signed value interval ``[lo, hi]`` (Python ints, so intermediate
  results are exact and overflow is *detected*, never silently wrapped),
- known-bits masks ``ones`` / ``zeros`` over the 64-bit pattern (a bit in
  ``ones`` is certainly 1 in every concretization, a bit in ``zeros``
  certainly 0).

The two views cross-tighten on construction (`make`): a non-negative
interval pins the high bits to zero, known masks bound the interval.

Soundness contract (exercised by tests/test_analysis_bitflow.py): for
every transfer function, each concretely reachable bit pattern of the
mirrored int64 / dual-int32-lane primitive lies inside the abstract
result.  Transfer functions compute the *exact* unbounded result and
route it through `ProofLog.admit64`, which records a proof obligation
("this operation never leaves the 64-bit word") and only wraps — exactly
as the hardware would — when the obligation fails, so a width bug shows
up as a failed check, not a silent widening.

`Bools` is the flat boolean domain {∅ is unused, {F}, {T}, {F,T}} used
for abstract comparisons and `where`-style selection.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

__all__ = ["M64", "INT64_MIN", "INT64_MAX", "Word", "Bools", "Check",
           "ProofLog", "make", "const", "interval", "top", "join"]

M64 = (1 << 64) - 1
INT64_MIN = -(1 << 63)
INT64_MAX = (1 << 63) - 1


def _signed(u: int) -> int:
    """uint64 bit pattern -> signed value."""
    u &= M64
    return u - (1 << 64) if u >> 63 else u


@dataclasses.dataclass(frozen=True)
class Word:
    """Abstract 64-bit word: signed interval + known-bits masks."""

    lo: int
    hi: int
    zeros: int  # mask of bits known to be 0
    ones: int   # mask of bits known to be 1

    @property
    def exact(self) -> Optional[int]:
        return self.lo if self.lo == self.hi else None

    def signed_bits(self) -> int:
        """Two's-complement width needed for every concrete value."""
        return max(_sbits(self.lo), _sbits(self.hi))

    def contains(self, v: int) -> bool:
        p = v & M64
        return (self.lo <= v <= self.hi
                and (p & self.zeros) == 0
                and (p & self.ones) == self.ones)

    def __repr__(self):
        if self.exact is not None:
            return f"Word({self.lo:#x})"
        return f"Word([{self.lo}, {self.hi}])"


def _sbits(v: int) -> int:
    """Bits needed to store v in two's complement (incl. sign bit)."""
    return v.bit_length() + 1 if v >= 0 else (-v - 1).bit_length() + 1


def make(lo: int, hi: int, zeros: int = 0, ones: int = 0) -> Word:
    """Build a Word, cross-tightening interval and known bits once."""
    assert INT64_MIN <= lo <= hi <= INT64_MAX, (lo, hi)
    # interval -> masks: the shared two's-complement prefix of lo and hi
    # is known (for a contiguous signed range, high bits agree above the
    # first differing position).
    plo, phi = lo & M64, hi & M64
    diff = plo ^ phi
    if lo < 0 <= hi:
        common = 0  # range crosses the pattern wrap at -1 -> 0
    else:
        common = M64 ^ ((1 << diff.bit_length()) - 1)
    ones |= plo & common
    zeros |= ~plo & common & M64
    # masks -> interval: unsigned extremes under the masks, mapped back
    # to signed if the sign bit is known.
    umin, umax = ones, ~zeros & M64
    if zeros >> 63:
        lo, hi = max(lo, umin), min(hi, _signed(umax))
    elif ones >> 63:
        lo, hi = max(lo, _signed(umin)), min(hi, _signed(umax))
    assert lo <= hi, "contradictory word abstraction"
    assert not (zeros & ones), "contradictory known bits"
    return Word(lo, hi, zeros, ones)


def const(v: int) -> Word:
    assert INT64_MIN <= v <= INT64_MAX
    p = v & M64
    return Word(v, v, ~p & M64, p)


def interval(lo: int, hi: int) -> Word:
    return make(lo, hi)


def top() -> Word:
    return Word(INT64_MIN, INT64_MAX, 0, 0)


def join(*ws: Word) -> Word:
    ws = [w for w in ws if w is not None]
    assert ws
    return make(min(w.lo for w in ws), max(w.hi for w in ws),
                zeros=_mask_and(w.zeros for w in ws),
                ones=_mask_and(w.ones for w in ws))


def _mask_and(ms: Iterable[int]) -> int:
    out = M64
    for m in ms:
        out &= m
    return out


@dataclasses.dataclass(frozen=True)
class Bools:
    """Abstract boolean: which of {False, True} are reachable."""

    can_false: bool
    can_true: bool

    @staticmethod
    def of(*vals: bool) -> "Bools":
        return Bools(False in vals, True in vals)

    @property
    def exact(self) -> Optional[bool]:
        if self.can_true != self.can_false:
            return self.can_true
        return None


BOTH = Bools(True, True)
TRUE = Bools(False, True)
FALSE = Bools(True, False)


# -- proof log ----------------------------------------------------------------

@dataclasses.dataclass
class Check:
    """One discharged (or failed) proof obligation."""

    site: str       # dotted driver location, e.g. "single-n26-hub/align"
    op: str         # obligation name, e.g. "fits-int64", "man-occupancy"
    ok: bool
    bits: int       # proven occupancy (two's-complement bits)
    capacity: int   # available width at this point of the datapath
    detail: str = ""

    def as_dict(self):
        return dataclasses.asdict(self)


class ProofLog:
    """Collects proof obligations emitted by the abstract interpreter."""

    def __init__(self):
        self.checks: list[Check] = []
        self._site: list[str] = []

    # -- site scoping ---------------------------------------------------------
    def enter(self, name: str):
        self._site.append(name)
        return self

    def exit(self):
        self._site.pop()

    @property
    def site(self) -> str:
        return "/".join(self._site) or "<toplevel>"

    # -- obligations ----------------------------------------------------------
    def require(self, op: str, ok: bool, *, bits: int, capacity: int,
                detail: str = "") -> bool:
        self.checks.append(Check(self.site, op, bool(ok), int(bits),
                                 int(capacity), detail))
        return bool(ok)

    def admit64(self, op: str, lo: int, hi: int,
                zeros: int = 0, ones: int = 0) -> Word:
        """Record a fits-in-int64 obligation; wrap modularly on failure.

        Wrapping on failure mirrors what the concrete int64 lanes would
        do, so a width bug is reported *and* downstream analysis stays
        sound with respect to the buggy concrete behaviour.
        """
        bits = max(_sbits(lo), _sbits(hi))
        ok = INT64_MIN <= lo and hi <= INT64_MAX
        self.require(op, ok, bits=bits, capacity=64,
                     detail="" if ok else f"range [{lo}, {hi}] wraps int64")
        if ok:
            return make(lo, hi, zeros, ones)
        return _wrap64(lo, hi)

    @property
    def failed(self) -> list[Check]:
        return [c for c in self.checks if not c.ok]

    @property
    def ok(self) -> bool:
        return not self.failed


def _wrap64(lo: int, hi: int) -> Word:
    if hi - lo >= (1 << 64):
        return top()
    a, b = _signed(lo), _signed(hi)
    if a <= b and (b - a) == (hi - lo):
        return make(a, b)
    return top()


# -- transfer functions -------------------------------------------------------
# Pure interval/bit algebra; overflow-checked entry points live on
# `Alu` in bitflow.py, which threads the ProofLog through these.

def add_exact(a: Word, b: Word) -> tuple[int, int]:
    return a.lo + b.lo, a.hi + b.hi


def sub_exact(a: Word, b: Word) -> tuple[int, int]:
    return a.lo - b.hi, a.hi - b.lo


def mul_exact(a: Word, b: Word) -> tuple[int, int]:
    cands = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
    return min(cands), max(cands)


def not_(a: Word) -> Word:
    return make(-1 - a.hi, -1 - a.lo, zeros=a.ones, ones=a.zeros)


def _unsigned_ranges(a: Word) -> list[tuple[int, int]]:
    """Concretize to unsigned uint64 interval(s); two when sign-mixed."""
    if a.lo >= 0:
        return [(a.lo, a.hi)]
    if a.hi < 0:
        return [(a.lo & M64, a.hi & M64)]
    return [(0, a.hi), (a.lo & M64, M64)]


def _from_masks(zeros: int, ones: int) -> Word:
    """Tightest signed interval containing every pattern allowed by masks.

    A pattern p is possible iff ``ones <= p <= ~zeros`` bit-wise.  The
    signed minimum sets the sign bit if it may be 1 and clears every
    optional bit; the maximum clears the sign bit if it may be 0 and
    sets every optional bit.
    """
    pmin = ones | ((1 << 63) if not (zeros >> 63) else 0)
    pmax = (~zeros & M64) & (~(1 << 63) if not (ones >> 63) else M64)
    return make(_signed(pmin), _signed(pmax), zeros=zeros, ones=ones)


def and_(a: Word, b: Word) -> Word:
    return _from_masks(a.zeros | b.zeros, a.ones & b.ones)


def or_(a: Word, b: Word) -> Word:
    return _from_masks(a.zeros & b.zeros, a.ones | b.ones)


def xor_(a: Word, b: Word) -> Word:
    return _from_masks((a.zeros & b.zeros) | (a.ones & b.ones),
                       (a.ones & b.zeros) | (a.zeros & b.ones))


def disjoint(a: Word, b: Word) -> bool:
    """True when no bit can be 1 in both words (safe to OR as a pack)."""
    return ((~a.zeros) & (~b.zeros) & M64) == 0


def shift_cases(s: Word, clamp_lo: int = 0, clamp_hi: int = 63):
    """Enumerate the concrete shift amounts of a (clamped) abstract shift."""
    lo = max(s.lo, clamp_lo)
    hi = min(s.hi, clamp_hi)
    if lo > hi:  # fully clamped from one side
        lo = hi = clamp_lo if s.hi < clamp_lo else clamp_hi
    return range(lo, hi + 1)


def eq(a: Word, b: Word) -> Bools:
    if a.hi < b.lo or b.hi < a.lo:
        return FALSE
    if (a.ones & b.zeros) or (b.ones & a.zeros):
        return FALSE
    if a.exact is not None and a.exact == b.exact:
        return TRUE
    return BOTH


def lt_s(a: Word, b: Word) -> Bools:
    if a.hi < b.lo:
        return TRUE
    if a.lo >= b.hi:
        return FALSE
    return BOTH


def lt_u(a: Word, b: Word) -> Bools:
    au, bu = _unsigned_ranges(a), _unsigned_ranges(b)
    can_t = any(alo < bhi for alo, _ in au for _, bhi in bu)
    can_f = any(ahi >= blo for _, ahi in au for blo, _ in bu)
    return Bools(can_f, can_t)


def is_neg(a: Word) -> Bools:
    if a.hi < 0:
        return TRUE
    if a.lo >= 0:
        return FALSE
    return BOTH


def select(c: Bools, t: Word, f: Word) -> Word:
    if c.exact is True:
        return t
    if c.exact is False:
        return f
    return join(t, f)
