"""Dead-module detection over the `repro` package.

A module is *referenced* when any of the following names it:

- a static import (``import repro.x`` / ``from repro.x import y`` /
  relative imports, resolved against the importing module's package —
  imports in an ``__init__.py`` belong to the *package*, not its
  parent);
- a string literal containing its dotted name, or an f-string whose
  constant prefix names its parent package with a trailing dot (the
  ``configs/registry.py`` pattern:
  ``importlib.import_module(f"repro.configs.{mod}")`` keeps every
  module of ``repro.configs`` alive);
- a ``python -m repro.x`` entry point in a CI workflow or pyproject
  script table.

Reference *sources* are every ``.py`` file under src/tests/examples/
benchmarks plus ``.github/workflows/*.yml`` and ``pyproject.toml``.
Documentation does not keep code alive.  ``__init__.py`` files and
``__main__.py`` files are structural and never reported dead
(``__main__`` is an entry point by construction).

Each unreferenced module becomes a ``dead-module`` lint `Finding`, so
deletions go through the same allowlist/justification policy as every
other rule.
"""
from __future__ import annotations

import ast
import os
import re
from typing import Iterable

from .lint import Finding

__all__ = ["find_dead_modules", "module_graph"]

_REF_DIRS = ("src", "tests", "examples", "benchmarks")
_TEXT_REFS = (".github/workflows", "pyproject.toml")
_DOTTED = re.compile(r"\brepro(?:\.[A-Za-z_][A-Za-z0-9_]*)+\.?")


def _discover(repo_root: str) -> dict[str, str]:
    """Map dotted module name -> file path for everything under src/repro."""
    base = os.path.join(repo_root, "src", "repro")
    out: dict[str, str] = {}
    for dirpath, dirnames, filenames in os.walk(base):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for f in sorted(filenames):
            if not f.endswith(".py"):
                continue
            full = os.path.join(dirpath, f)
            rel = os.path.relpath(full, os.path.join(repo_root, "src"))
            parts = rel[:-3].split(os.sep)
            if parts[-1] == "__init__":
                parts = parts[:-1]
            out[".".join(parts)] = full
    return out


def _module_of(py_path: str, repo_root: str) -> str | None:
    rel = os.path.relpath(py_path, os.path.join(repo_root, "src"))
    if rel.startswith(".."):
        return None
    parts = rel[:-3].split(os.sep)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _iter_ref_files(repo_root: str) -> Iterable[str]:
    for d in _REF_DIRS:
        top = os.path.join(repo_root, d)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [x for x in dirnames
                           if x not in ("__pycache__", ".git")]
            for f in sorted(filenames):
                if f.endswith(".py"):
                    yield os.path.join(dirpath, f)


def _resolve_relative(importer: str, level: int, name: str | None,
                      is_pkg_init: bool) -> str | None:
    # For `from ..a import b` inside module p.q.r: level 1 -> p.q,
    # level 2 -> p.  An __init__.py's own package counts as one level.
    parts = importer.split(".")
    if not is_pkg_init:
        parts = parts[:-1]
    drop = level - 1
    if drop > len(parts):
        return None
    base = parts[:len(parts) - drop]
    if name:
        base = base + name.split(".")
    return ".".join(base) if base else None


def module_graph(repo_root: str):
    """Return (modules, referenced, dynamic_pkgs).

    *modules* maps dotted name -> path; *referenced* is the set of
    dotted names something imports or names; *dynamic_pkgs* are packages
    referenced through string-building imports (all their members count
    as referenced).
    """
    modules = _discover(repo_root)
    packages = {m for m, p in modules.items()
                if os.path.basename(p) == "__init__.py"}
    referenced: set[str] = set()
    dynamic_pkgs: set[str] = set()

    def note(name: str | None, self_mod: str | None):
        # a module naming itself (its own usage docstring) is not a
        # reference that keeps it alive
        if name and name != self_mod:
            referenced.add(name)

    def note_string(s: str, self_mod: str | None, fstring: bool = False):
        for m in _DOTTED.finditer(s):
            token = m.group(0)
            if token.endswith("."):
                # a dotted prefix with a trailing dot only signals a
                # dynamic import when it is the constant part of an
                # f-string (importlib.import_module(f"repro.configs.{m}"));
                # in plain prose it is just documentation
                pkg = token[:-1]
                if fstring and pkg in packages:
                    dynamic_pkgs.add(pkg)
                continue
            note(token, self_mod)

    for path in _iter_ref_files(repo_root):
        importer = _module_of(path, repo_root)
        is_pkg_init = path.endswith("__init__.py")
        try:
            with open(path, "r", encoding="utf-8") as fh:
                tree = ast.parse(fh.read())
        except (OSError, SyntaxError):
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    note(alias.name, importer)
            elif isinstance(node, ast.ImportFrom):
                if node.level and importer:
                    base = _resolve_relative(importer, node.level,
                                             node.module, is_pkg_init)
                else:
                    base = node.module
                if base:
                    note(base, importer)
                    for alias in node.names:
                        note(f"{base}.{alias.name}", importer)
            elif isinstance(node, ast.Constant) and isinstance(node.value,
                                                               str):
                note_string(node.value, importer)
            elif isinstance(node, ast.JoinedStr):
                for v in node.values:
                    if isinstance(v, ast.Constant) and isinstance(v.value,
                                                                  str):
                        note_string(v.value, importer, fstring=True)

    for entry in _TEXT_REFS:
        full = os.path.join(repo_root, entry)
        files = []
        if os.path.isdir(full):
            files = [os.path.join(full, f) for f in sorted(os.listdir(full))]
        elif os.path.isfile(full):
            files = [full]
        for f in files:
            try:
                with open(f, "r", encoding="utf-8") as fh:
                    note_string(fh.read(), None)
            except OSError:
                continue

    return modules, referenced, dynamic_pkgs


def find_dead_modules(repo_root: str) -> list[Finding]:
    modules, referenced, dynamic_pkgs = module_graph(repo_root)
    findings: list[Finding] = []
    for name in sorted(modules):
        path = modules[name]
        base = os.path.basename(path)
        if base in ("__init__.py", "__main__.py"):
            continue
        if name in referenced:
            continue
        if any(name == p or name.startswith(p + ".") for p in dynamic_pkgs):
            continue
        rel = os.path.relpath(path, repo_root).replace(os.sep, "/")
        findings.append(Finding(
            rule="dead-module", path=rel, line=1, col=0, scope="<module>",
            detail=name,
            message=f"module '{name}' has no static import, dynamic-import "
                    "string, or CI entry-point reference — delete it or "
                    "allowlist with the reason it must stay"))
    return findings
