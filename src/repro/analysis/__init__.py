"""Static-analysis layer: bit-width dataflow verifier + JAX/Pallas linter.

The paper's core contribution is a *static* argument — an error/bit-width
analysis proving that the Givens datapath widths (mantissa + guard bits,
HUB rounding, the w = N+2 CORDIC growth margin) are sufficient.  This
package is that argument's software analogue, plus a linter for the
JAX/Pallas hazard classes this repo has actually been burned by:

``repro.analysis.bitflow``
    Abstract interpreter (value-range + known-bits domains, `domain.py`)
    that symbolically executes the packed-word dataflow of
    `core/formats.py`, `core/converters.py`, `core/cordic.py` and the
    dual-int32 lane primitives of `kernels/packed_lanes.py`, proving per
    operation that field occupancy stays inside the word — no mantissa or
    guard-bit overflow, no carry bleed across the (hi, lo) lane boundary,
    RNE sticky bits confined to their field.  Emits a machine-readable
    report of proven widths vs the format constants (the software version
    of the paper's Tables 1-4).

``repro.analysis.lint``
    AST rules grounded in this repo's bug history (DESIGN.md §13):
    traced-array capture by `pallas_call` kernel closures (PR 5), host
    round-trips on tracers inside jit/scan bodies, implicit narrowing
    casts outside the blessed encode/decode boundaries (PR 4), unguarded
    potentially-duplicate scatters (PR 6), donated-buffer reuse, and
    unhashable jit statics.

``repro.analysis.deadcode``
    Import-graph reachability over src/tests/examples/benchmarks (plus
    CI workflows for `-m` entry points and string-literal dynamic
    imports): modules nobody references.

``python -m repro.analysis src/`` runs everything; findings not in the
checked-in allowlist (`allowlist.txt`, one justified line per waiver)
fail the run — the CI `lint` lane enforces exit 0.
"""
from __future__ import annotations

from .bitflow import BitflowReport, verify_all, verify_config
from .lint import Finding, lint_paths
from .allowlist import Allowlist, load_allowlist

__all__ = [
    "BitflowReport", "verify_all", "verify_config",
    "Finding", "lint_paths",
    "Allowlist", "load_allowlist",
]
