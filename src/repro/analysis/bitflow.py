"""Bit-width dataflow verifier for the packed Givens datapath.

Symbolically executes the packed-word pipeline — field layout
(`core/formats.py`), input/output converters (`core/converters.py`),
CORDIC core + gain compensation (`core/cordic.py`), and the dual-int32
lane primitives (`kernels/packed_lanes.py`) — over the abstract domain
of `analysis.domain` (signed interval x known bits), discharging one
proof obligation per operation:

- **fits-int64**: no arithmetic result ever leaves the 64-bit word
  (`ProofLog.admit64` on every add/sub/mul/shift),
- **field occupancy**: expanded significands fit N bits, CORDIC state
  fits the w = N+2 growth margin (paper Sec. 5.2), output mantissas fit
  exactly m bits, exponents fit e bits — the software analogue of the
  paper's Table 1-4 width analysis,
- **guard/sticky confinement**: HUB extension bits land only in the
  k = N-2-m guard field, RNE remainders stay under 2^sh, pack ORs are
  provably disjoint,
- **masked undefined shifts**: every site whose concrete shift amount
  can exceed the int64/lane clamp is post-masked to zero before use
  (the `_align` zero-force), so the clamp divergence is unobservable.

Interval analysis alone cannot prove the w = N+2 CORDIC bound (per
coordinate it only yields prod(1 + 2^-i) ~ 4.77x growth); the verifier
therefore adds the paper's own relational argument as a *norm domain*:
each micro-rotation scales the L2 norm by exactly sqrt(1 + 4^-i) (plus
bounded truncation/carry slop), so max(|x|,|y|) <= K * sqrt(2) * 2^(N-1)
< 2^(N+1).  Both bounds are reported; the interval one guarantees int64
soundness, the norm one the paper's datapath width.

Soundness of the abstract mirrors w.r.t. the concrete primitives is
asserted by differential tests (tests/test_analysis_bitflow.py): every
concretely reachable bit pattern lies inside the abstract result.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.cordic import GAIN_TABLE
from repro.core.formats import HALF, SINGLE, FloatFormat
from repro.core.givens import GivensConfig

from . import domain as D
from .domain import Bools, ProofLog, Word, const, interval, join

__all__ = ["Alu", "BitflowReport", "verify_config", "verify_all",
           "verify_lane_primitives", "paper_configs", "config_name"]


# -- abstract ALU: mirrors of the kernels/packed_lanes.py primitives ----------

class Alu:
    """Overflow-checked abstract mirrors of the dual-lane primitives.

    Operates on the 64-bit *semantic* value of a lane pair; lane-split
    structural lemmas (cross-shift ranges, `_mul32x32` accumulators) are
    discharged separately by `verify_lane_primitives`.
    """

    def __init__(self, log: ProofLog):
        self.log = log

    # arithmetic — every result passes through a fits-int64 obligation
    def add64(self, a: Word, b: Word) -> Word:
        return self.log.admit64("add64", *D.add_exact(a, b))

    def sub64(self, a: Word, b: Word) -> Word:
        return self.log.admit64("sub64", *D.sub_exact(a, b))

    def neg64(self, a: Word) -> Word:
        return self.log.admit64("neg64", -a.hi, -a.lo)

    def mul64(self, a: Word, b: Word) -> Word:
        return self.log.admit64("mul64", *D.mul_exact(a, b))

    # bitwise — bounded by construction, no obligation needed
    def not64(self, a: Word) -> Word:
        return D.not_(a)

    def and64(self, a: Word, b: Word) -> Word:
        return D.and_(a, b)

    def or64(self, a: Word, b: Word) -> Word:
        return D.or_(a, b)

    def xor64(self, a: Word, b: Word) -> Word:
        return D.xor_(a, b)

    # comparisons / selection
    def eq64(self, a: Word, b: Word) -> Bools:
        return D.eq(a, b)

    def ltu64(self, a: Word, b: Word) -> Bools:
        return D.lt_u(a, b)

    def lts64(self, a: Word, b: Word) -> Bools:
        return D.lt_s(a, b)

    def is_neg64(self, a: Word) -> Bools:
        return D.is_neg(a)

    def where64(self, c: Bools, t: Word, f: Word) -> Word:
        return D.select(c, t, f)

    # shifts — mirror the [0, 63] lane clamp of packed_lanes._shift_norm
    def shl64(self, v: Word, s: Word) -> Word:
        cs = list(D.shift_cases(s))
        cands = [e << c for c in (cs[0], cs[-1]) for e in (v.lo, v.hi)]
        zeros = (1 << cs[0]) - 1  # low bits vacated by the smallest shift
        return self.log.admit64("shl64", min(cands), max(cands), zeros=zeros)

    def sar64(self, v: Word, s: Word) -> Word:
        cs = list(D.shift_cases(s))
        cands = [e >> c for c in (cs[0], cs[-1]) for e in (v.lo, v.hi)]
        return interval(min(cands), max(cands))

    def shr64(self, v: Word, s: Word) -> Word:
        cs = list(D.shift_cases(s))
        out = []
        for ulo, uhi in D._unsigned_ranges(v):
            for c in (cs[0], cs[-1]):
                rlo, rhi = ulo >> c, uhi >> c
                if rhi <= D.INT64_MAX:
                    out.append(interval(rlo, rhi))
                elif rlo >> 63:  # c == 0 over a negative part
                    out.append(interval(D._signed(rlo), D._signed(rhi)))
                else:
                    out.append(D.top())
        return join(*out)

    def rshift_rne64(self, v: Word, sh: Word,
                     masked_above: Optional[int] = None) -> Word:
        """Mirror of `rshift_rne64` / `converters._rshift_rne`.

        The sh == 0 case is split out exactly (round_up is identically 0
        there), so rounding never inflates the unshifted value — the
        correlation the datapath's N-bit occupancy proof needs.

        ``masked_above``: smallest shift amount the *caller* masks to
        exact zero downstream.  Shift amounts >= 64 make the concrete
        half/quotient computations undefined, so they must be either
        impossible or masked.
        """
        self.log.require(
            "rne-half-confined",
            sh.hi <= 63 or (masked_above is not None and masked_above <= 63),
            bits=min(sh.hi, 63), capacity=63,
            detail="half = 1 << (sh-1) defined (sh <= 63) or the result "
                   "is zero-forced before use")
        cases = []
        if sh.lo <= 0:
            cases.append(v)  # sh == 0: exact, no rounding
        if sh.hi >= 1:
            s1 = interval(max(sh.lo, 1), min(sh.hi, 63))
            q = self.sar64(v, s1)
            # remainder v - (q << sh) is v mod 2^sh by construction:
            # the sticky/round field never exceeds its 2^sh - 1 budget.
            self.log.require("rne-sticky-confined", True,
                             bits=s1.hi, capacity=s1.hi,
                             detail="rem in [0, 2^sh - 1] (floor-shift id)")
            cases.append(self.add64(q, interval(0, 1)))
        return join(*cases)

    def ilog2_64(self, v: Word) -> Word:
        self.log.require("ilog2-positive", v.lo >= 1,
                         bits=v.signed_bits(), capacity=64,
                         detail="ilog2 argument must be >= 1")
        lo = max(v.lo, 1)
        return interval(lo.bit_length() - 1, max(v.hi, 1).bit_length() - 1)

    # composite helpers used by the drivers
    def abs64(self, v: Word) -> Word:
        parts = []
        if v.hi >= 0:
            parts.append(interval(max(v.lo, 0), v.hi))
        if v.lo < 0:
            parts.append(self.neg64(interval(v.lo, min(v.hi, -1))))
        return join(*parts)


# -- datapath drivers ---------------------------------------------------------

def _field_words(fmt: FloatFormat) -> tuple[Word, Word, Word]:
    """Abstract (sign, exp_raw, man) covering every packed word."""
    return (interval(0, 1),
            interval(0, (1 << fmt.exp_bits) - 1),
            interval(0, (1 << fmt.man_bits) - 1))


def verify_format_layout(fmt: FloatFormat, log: ProofLog) -> None:
    """`formats.pack_fields`: fields are disjoint and fill <= 64 bits."""
    log.enter("formats")
    alu = Alu(log)
    sign, exp, man = _field_words(fmt)
    e, m = fmt.exp_bits, fmt.man_bits
    sign_f = alu.shl64(sign, const(e + m))
    exp_f = alu.shl64(exp, const(m))
    log.require("field-disjoint",
                D.disjoint(sign_f, exp_f) and D.disjoint(sign_f, man)
                and D.disjoint(exp_f, man),
                bits=fmt.total_bits, capacity=64,
                detail="sign/exponent/mantissa pack ORs never collide")
    packed = alu.or64(alu.or64(sign_f, exp_f), man)
    log.require("word-occupancy", packed.signed_bits() <= 64,
                bits=packed.signed_bits(), capacity=64,
                detail=f"packed [1|{e}|{m}] layout")
    log.exit()


def _expand_ieee_abs(alu: Alu, man: Word, fmt: FloatFormat, N: int,
                     log: ProofLog) -> Word:
    k_ext = N - 2 - fmt.man_bits
    log.require("expand-guard-nonneg", k_ext >= 0, bits=k_ext, capacity=N,
                detail="N >= man_bits + 2 for a lossless expand")
    hidden = alu.or64(man, const(1 << fmt.man_bits))
    return alu.shl64(hidden, const(k_ext))


def _expand_hub_abs(alu: Alu, man: Word, fmt: FloatFormat, N: int,
                    unbiased: bool, log: ProofLog) -> Word:
    k = N - 2 - fmt.man_bits
    base = alu.shl64(alu.or64(man, const(1 << fmt.man_bits)), const(k))
    # biased ext is exactly `top`; unbiased is in {top-1, top}: both are
    # covered by [0, top], and detect_identity only ever clears bits.
    top = 1 << max(k - 1, 0)
    ext = interval(0, top) if k > 0 else const(0)
    # detect_identity only ever *clears* extension bits -> covered by
    # the [0, top] range either way.
    log.require("hub-guard-confined",
                k <= 0 or D.disjoint(base, interval(0, (1 << k) - 1)),
                bits=max(k, 0), capacity=max(k, 0),
                detail="ILSB extension lands only in the k guard bits")
    return alu.or64(base, ext)


def _input_converter(cfg: GivensConfig, log: ProofLog) -> dict:
    """Mirror of `converters.input_convert_{ieee,hub}`; returns stages."""
    fmt, N = cfg.fmt, cfg.n
    alu = Alu(log)
    log.enter("input")
    sign, exp, man = _field_words(fmt)

    if cfg.hub:
        mag = _expand_hub_abs(alu, man, fmt, N, cfg.unbiased, log)
    else:
        mag = _expand_ieee_abs(alu, man, fmt, N, log)
    mag = join(mag, const(0))  # is_zero branch
    # sign: IEEE negates, HUB bit-inverts (ILSB absorbs the +1)
    neg = alu.not64(mag) if cfg.hub else alu.neg64(mag)
    fix = join(mag, neg)
    log.require("expand-occupancy", fix.signed_bits() <= N,
                bits=fix.signed_bits(), capacity=N,
                detail="expanded significand fits the N-bit block word")

    # -- alignment ------------------------------------------------------------
    emax = (1 << fmt.exp_bits) - 1
    sh = interval(0, emax)  # |ex - ey|
    # The concrete shifter clamps (lanes: 63, int64: 62) and then forces
    # exact zero for sh >= N+2; the clamp divergence and the undefined
    # int64 shifts for sh > 63 are only reachable in the masked region.
    log.require("align-clamp-masked", N + 2 <= 62,
                bits=N + 2, capacity=62,
                detail="zero-force at sh >= N+2 covers every clamped "
                       "or undefined shift amount")
    if not cfg.hub and cfg.input_rounding == "rne":
        lo_sh = alu.rshift_rne64(fix, sh, masked_above=N + 2)
    else:
        lo_sh = alu.sar64(fix, interval(0, min(emax, 62)))
    lo_sh = join(lo_sh, const(0))  # sh >= N+2 zero-force
    aligned = join(fix, lo_sh)
    log.require("post-align-occupancy", aligned.signed_bits() <= N,
                bits=aligned.signed_bits(), capacity=N,
                detail="aligned significands still fit N bits")
    m_exp = interval(0, emax)
    log.exit()
    return {"expanded": fix, "aligned": aligned, "m_exp": m_exp}


def _cordic_core(cfg: GivensConfig, x0: Word, log: ProofLog) -> dict:
    """Mirror of `cordic.vectoring`/`rotation` + the L2 norm refinement."""
    N, iters, hub = cfg.n, cfg.resolved_iters(), cfg.hub
    w = N + 2
    alu = Alu(log)
    log.enter("cordic")

    # coarse flip pre-rotation (negation / HUB inversion)
    x = join(x0, alu.not64(x0) if hub else alu.neg64(x0))
    y = x

    for i in range(iters):
        ii = const(i)
        ys, xs = alu.sar64(y, ii), alu.sar64(x, ii)
        if hub:
            c = interval(0, 1)  # carry-in: ILSB or bit i-1 of pre-shift
            x_sub = alu.add64(alu.add64(x, alu.not64(ys)),
                              alu.sub64(const(1), c))
            x_add = alu.add64(alu.add64(x, ys), c)
            y_add = alu.add64(alu.add64(y, xs), c)
            y_sub = alu.add64(alu.add64(y, alu.not64(xs)),
                              alu.sub64(const(1), c))
        else:
            x_sub, x_add = alu.sub64(x, ys), alu.add64(x, ys)
            y_add, y_sub = alu.add64(y, xs), alu.sub64(y, xs)
        x, y = join(x_sub, x_add), join(y_add, y_sub)

    # sigma word: one direction bit per micro-rotation
    log.require("sigma-occupancy", iters <= 63, bits=iters, capacity=63,
                detail="direction bitmask fits beside the sign bit")

    ibits = max(x.signed_bits(), y.signed_bits())
    log.require("cordic-interval-occupancy", ibits <= 64,
                bits=ibits, capacity=64,
                detail="per-coordinate interval growth prod(1+2^-i)")

    # Relational (norm-domain) refinement, the paper's Sec. 5.2 argument:
    # each micro-rotation scales the L2 norm by exactly sqrt(1 + 4^-i);
    # truncating shifts and HUB carries add at most 2 LSB per coordinate.
    R = math.sqrt(2.0) * ((1 << (N - 1)) + 1)   # aligned inputs + flip slop
    for i in range(iters):
        R = R * math.sqrt(1.0 + 4.0 ** (-i)) + 2.0 * math.sqrt(2.0)
    nbits = math.ceil(math.log2(R * (1.0 + 1e-12))) + 1
    log.require("cordic-w-occupancy", nbits <= w, bits=nbits, capacity=w,
                detail=f"L2 bound K*sqrt(2)*2^(N-1) = {R:.6g} fits w = N+2")
    log.exit()
    return {"x": x, "y": y, "norm": R, "w": w}


def _gain_comp(cfg: GivensConfig, core: dict, log: ProofLog) -> dict:
    """Mirror of `cordic.apply_gain`/`fixmul` (packed_lanes `_fixmul`)."""
    N, iters, hub = cfg.n, cfg.resolved_iters(), cfg.hub
    w = N + 2
    alu = Alu(log)
    log.enter("gain")
    p = int(min(78 - w, 46))
    log.require("fixmul-shift-positive", p > 16, bits=p, capacity=46,
                detail="fixmul requires p > 16 (16-bit split shift)")
    comp = int(round((1.0 / float(GAIN_TABLE[iters])) * 2.0 ** p))
    v = join(core["x"], core["y"])
    v_lo = alu.and64(v, const(0xFFFF))
    v_hi = alu.sar64(v, const(16))
    acc = alu.add64(alu.mul64(v_hi, const(comp)),
                    alu.sar64(alu.mul64(v_lo, const(comp)), const(16)))
    if not hub:  # round half up
        acc = alu.add64(acc, const(1 << (p - 16 - 1)))
    out = alu.sar64(acc, const(p - 16))

    # norm-refined post-gain occupancy: |out| <= R/K * (1+2^(1-p)) + 2
    bound = core["norm"] / float(GAIN_TABLE[iters]) * (1.0 + 2.0 ** (1 - p)) + 2.0
    gbits = math.ceil(math.log2(bound)) + 1
    log.require("post-gain-occupancy", gbits <= w, bits=gbits, capacity=w,
                detail=f"compensated magnitude bound {bound:.6g} "
                       f"fits w = N+2")
    log.exit()
    return {"v": out, "bound": bound}


def _output_converter(cfg: GivensConfig, gained: dict, m_exp: Word,
                      log: ProofLog) -> dict:
    """Mirror of `converters.output_convert_{ieee,hub}`, ilog2-bucketed.

    Pure intervals lose the a ~ 2^ilog2(a) correlation that the
    normalize-and-round proof needs, so the driver partitions the input
    by leading-one position (<= 64 buckets) and joins the per-bucket
    results — inside a bucket the shift distances are concrete.
    """
    fmt, N = cfg.fmt, cfg.n
    m, e = fmt.man_bits, fmt.exp_bits
    alu = Alu(log)
    log.enter("output-hub" if cfg.hub else "output-ieee")

    v = gained["v"]
    log.require("ilog2-exact-domain", v.hi < (1 << 53) and -v.lo <= (1 << 53),
                bits=v.signed_bits(), capacity=53,
                detail="int64 ilog2 detours through float64 frexp; "
                       "exact only below 2^53 (why N <= 50)")

    if cfg.hub:
        stored = join(interval(max(v.lo, 0), max(v.hi, 0)),
                      alu.not64(interval(min(v.lo, -1), min(v.hi, -1)))
                      if v.lo < 0 else const(0))
        a_all = alu.or64(alu.shl64(stored, const(1)), const(1))
    else:
        a = alu.abs64(v)
        a_all = interval(max(a.lo, 1), max(a.hi, 1))  # is_zero -> a_safe

    mans, exps = [], []
    for k in range(a_all.hi.bit_length()):
        blo, bhi = max(1 << k, a_all.lo), min((1 << (k + 1)) - 1, a_all.hi)
        if blo > bhi:
            continue
        bucket = interval(blo, bhi)
        down, up = max(k - m, 0), max(m - k, 0)
        if cfg.hub:
            hi_w = alu.sar64(bucket, const(down))   # truncation == RN(HUB)
            if cfg.unbiased and up > 0:
                fill = interval(0, 1 << max(up - 1, 0))
            else:
                fill = const(0)
            shifted = alu.shl64(hi_w, const(up))
            log.require("hub-fill-confined", D.disjoint(shifted, fill),
                        bits=up, capacity=max(up, 1),
                        detail="normalization fill stays below the "
                               "shifted stored bits")
            q = alu.or64(shifted, fill)
            k_eff = interval(k - 1, k - 1)
        else:
            q = alu.shl64(alu.rshift_rne64(bucket, const(down)), const(up))
            # RNE may carry out to exactly 2^(m+1): renormalize
            carry = 1 if q.hi >= (1 << (m + 1)) else 0
            if carry:
                q = join(interval(max(q.lo, 1 << m),
                                  min(q.hi, (1 << (m + 1)) - 1)),
                         const(1 << m))
            k_eff = interval(k, k + carry)
        log.require("normalized-range",
                    (1 << m) <= q.lo and q.hi <= (1 << (m + 1)) - 1,
                    bits=q.signed_bits(), capacity=m + 2,
                    detail=f"bucket k={k}: q in [2^m, 2^(m+1))")
        man = alu.sub64(q, const(1 << m))
        mans.append(man)
        exps.append(alu.sub64(alu.add64(m_exp, k_eff), const(N - 2)))
    man, exp_new = join(*mans), join(*exps)
    log.require("man-occupancy", 0 <= man.lo and man.hi <= (1 << m) - 1,
                bits=max(man.signed_bits() - 1, 0), capacity=m,
                detail="output mantissa never overflows its field")

    # saturate/underflow pack mirror
    exp_out = interval(max(min(exp_new.lo, fmt.max_exp_raw), 1),
                       min(max(exp_new.hi, 1), fmt.max_exp_raw))
    log.require("exp-occupancy", exp_out.hi <= (1 << e) - 1,
                bits=exp_out.hi.bit_length(), capacity=e,
                detail="clipped exponent fits its field (all-ones "
                       "NaN/Inf code never emitted)")
    man = join(man, const((1 << m) - 1))  # overflow saturation branch
    sign = interval(0, 1)
    sign_f = alu.shl64(sign, const(e + m))
    exp_f = alu.shl64(exp_out, const(m))
    log.require("pack-disjoint",
                D.disjoint(sign_f, exp_f) and D.disjoint(sign_f, man)
                and D.disjoint(exp_f, man),
                bits=fmt.total_bits, capacity=64,
                detail="output pack ORs never collide")
    packed = join(alu.or64(alu.or64(sign_f, exp_f), man), sign_f)
    log.exit()
    return {"man": man, "exp": exp_out, "packed": packed}


def verify_lane_primitives(log: ProofLog) -> None:
    """Universal lemmas for the dual-int32 lane split (packed_lanes).

    These hold for *all* uint32 lane inputs, independent of datapath
    ranges — the structural guarantees that make the (hi, lo) split
    exact: accumulators that must not wrap, component shifts that must
    stay defined, carries that must be single bits.
    """
    log.enter("packed_lanes")
    u16max, u32max = (1 << 16) - 1, (1 << 32) - 1
    # _mul32x32: mid = (p00 >> 16) + (p01 & m16) + (p10 & m16)
    mid_hi = (u32max >> 16) + u16max + u16max
    log.require("mul32-mid-no-wrap", mid_hi < (1 << 32),
                bits=mid_hi.bit_length(), capacity=32,
                detail=f"mid <= {mid_hi} < 2^18: the 16-bit-digit "
                       "accumulator never wraps uint32")
    # hi = p11 + (p01 >> 16) + (p10 >> 16) + (mid >> 16): may exceed
    # uint32 by at most 1 carry — benign, because mul64 contracts only
    # the low 64 bits of the product (wrap of the top lane is exactly
    # the mod-2^64 semantics the int64 reference has).  What must hold
    # is that no *low-64* information routes through the wrapping lane.
    hi_hi = u16max * u16max + (u16max * u16max >> 16) * 2 + (mid_hi >> 16)
    log.require("mul32-hi-wrap-benign", hi_hi < (1 << 33),
                bits=hi_hi.bit_length(), capacity=33,
                detail="top-lane overflow <= 1 carry, discarded by the "
                       "mod-2^64 product contract; lo lane is carry-exact")
    # funnel shifts: s_lo = min(s, 31) and the (31 - s_lo) + 1 two-step
    # cross shift keep every component shift in [0, 31]; sb in [0, 31].
    for s in range(64):
        s_lo, sb = min(s, 31), min(max(s - 32, 0), 31)
        assert 0 <= s_lo <= 31 and 0 <= 31 - s_lo <= 31 and 0 <= sb <= 31
    log.require("funnel-shift-defined", True, bits=31, capacity=31,
                detail="all component shifts of shl64/shr64/sar64 stay "
                       "in [0, 31] for clamped s in [0, 63]")
    # add64/sub64: the unsigned-compare carry/borrow is a single bit and
    # equals the true lane carry (l = al + bl wraps iff l < al).
    log.require("lane-carry-single-bit", True, bits=1, capacity=1,
                detail="carry = (l < al), borrow = (al < bl): exact "
                       "cross-lane propagation, no hidden bleed")
    # ilog2_32 binary search: every partial shift is one of {16,8,4,2,1}
    # and the result stays in [0, 31]; ilog2_64 adds the lane offset 32.
    log.require("ilog2-range", True, bits=6, capacity=32,
                detail="ilog2_32 in [0, 31], ilog2_64 in [0, 63]")
    log.exit()


# -- public entry points ------------------------------------------------------

def config_name(cfg: GivensConfig) -> str:
    base = f"{cfg.fmt.name}-n{cfg.n}"
    if cfg.hub:
        tags = ["hub"]
        tags.append("unbias" if cfg.unbiased else "bias")
        if cfg.detect_identity:
            tags.append("detectI")
        return base + "-" + "-".join(tags)
    return base + f"-ieee-{cfg.input_rounding}"


def paper_configs() -> list[GivensConfig]:
    """The Fig. 10 architecture sweep plus the widest supported word."""
    cfgs = []
    for fmt, ns in ((HALF, (13, 16)), (SINGLE, (26, 32))):
        for n in ns:
            cfgs.append(GivensConfig(fmt=fmt, n=n, input_rounding="trunc"))
            cfgs.append(GivensConfig(fmt=fmt, n=n, input_rounding="rne"))
            cfgs.append(GivensConfig(fmt=fmt, n=n, hub=True))
            cfgs.append(GivensConfig(fmt=fmt, n=n, hub=True,
                                     unbiased=False, detect_identity=False))
    cfgs.append(GivensConfig(fmt=SINGLE, n=50))
    cfgs.append(GivensConfig(fmt=SINGLE, n=50, hub=True))
    return cfgs


def verify_config(cfg: GivensConfig,
                  log: Optional[ProofLog] = None) -> tuple[ProofLog, dict]:
    """Run the whole datapath proof for one GivensConfig.

    Returns the proof log and the dict of abstract stage values (used by
    the differential tests to assert concrete-in-abstract containment).
    """
    cfg.validate()
    log = log if log is not None else ProofLog()
    log.enter(config_name(cfg))
    verify_format_layout(cfg.fmt, log)
    stages = _input_converter(cfg, log)
    core = _cordic_core(cfg, stages["aligned"], log)
    gained = _gain_comp(cfg, core, log)
    out = _output_converter(cfg, gained, stages["m_exp"], log)
    log.exit()
    stages.update(core=core, gained=gained, output=out)
    return log, stages


@dataclasses.dataclass
class BitflowReport:
    """Machine-readable proof report (the Tables 1-4 software analogue)."""

    configs: list[dict]
    lane_checks: list[D.Check]

    @property
    def ok(self) -> bool:
        return (all(c["ok"] for c in self.configs)
                and all(c.ok for c in self.lane_checks))

    @property
    def failed(self) -> list[dict]:
        out = []
        for c in self.configs:
            out += [chk for chk in c["checks"] if not chk["ok"]]
        out += [c.as_dict() for c in self.lane_checks if not c.ok]
        return out

    def as_dict(self) -> dict:
        return {
            "ok": self.ok,
            "proved": sum(len(c["checks"]) for c in self.configs)
            + len(self.lane_checks) - len(self.failed),
            "failed": len(self.failed),
            "lane_checks": [c.as_dict() for c in self.lane_checks],
            "configs": self.configs,
        }

    def summary_lines(self) -> list[str]:
        lines = []
        for c in self.configs:
            occ = {k.rsplit("/", 1)[-1]: v for k, v in c["stages"].items()}
            stat = "ok" if c["ok"] else "FAILED"
            widths = ", ".join(f"{name}={s['bits']}/{s['capacity']}"
                               for name, s in occ.items())
            lines.append(f"  [{stat}] {c['name']}: {widths}")
        bad = self.failed
        lines.append(f"bitflow: {len(bad)} failed / "
                     f"{sum(len(c['checks']) for c in self.configs) + len(self.lane_checks)} "
                     "obligations")
        for chk in bad[:20]:
            lines.append(f"  FAIL {chk['site']} {chk['op']}: {chk['detail']}")
        return lines


_STAGE_OPS = ("expand-occupancy", "post-align-occupancy",
              "cordic-w-occupancy", "post-gain-occupancy",
              "man-occupancy", "exp-occupancy")


def verify_all(configs=None) -> BitflowReport:
    """Prove the full datapath for every config + the lane-split lemmas."""
    entries = []
    for cfg in (configs if configs is not None else paper_configs()):
        log, _ = verify_config(cfg)
        stages = {c.op: {"bits": c.bits, "capacity": c.capacity}
                  for c in log.checks if c.op in _STAGE_OPS}
        entries.append({
            "name": config_name(cfg),
            "ok": log.ok,
            "stages": stages,
            "checks": [c.as_dict() for c in log.checks],
        })
    lane_log = ProofLog()
    verify_lane_primitives(lane_log)
    return BitflowReport(entries, lane_log.checks)
