"""Checked-in allowlist for accepted linter findings.

Format (``allowlist.txt``, one entry per line)::

    <fingerprint-pattern>  # <mandatory justification>

A fingerprint is ``rule:path:scope:detail`` (see `lint.Finding`); the
pattern side supports ``fnmatch``-style ``*`` wildcards so a whole scope
or file can be waived with one justified line.  Lines starting with
``#`` and blank lines are comments.

Policy (DESIGN.md §13):

- every entry MUST carry a justification after ``#`` — the loader
  rejects entries without one, so "allowlist it and move on" leaves a
  written trace of *why* the hazard is acceptable;
- stale entries (matching zero current findings) fail the run by
  default, keeping the file honest as code moves.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Iterable, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from .lint import Finding

__all__ = ["Allowlist", "AllowlistError", "load_allowlist", "DEFAULT_PATH"]

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "allowlist.txt")


class AllowlistError(ValueError):
    """Malformed allowlist entry (missing justification, bad shape)."""


def _glob_match(pattern: str, text: str) -> bool:
    """Glob with only ``*`` (any run) and ``?`` (any char) special.

    Unlike fnmatch, ``[`` / ``]`` are literal — fingerprints contain
    ``at[idx]`` details that must not become character classes.
    """
    rx = "".join(".*" if c == "*" else "." if c == "?" else re.escape(c)
                 for c in pattern)
    return re.fullmatch(rx, text) is not None


@dataclasses.dataclass(frozen=True)
class Entry:
    pattern: str
    justification: str
    lineno: int


@dataclasses.dataclass
class Allowlist:
    entries: list[Entry]
    path: str = "<memory>"

    def match(self, finding: "Finding") -> Entry | None:
        fp = finding.fingerprint
        for e in self.entries:
            if _glob_match(e.pattern, fp):
                return e
        return None

    def split(self, findings: Iterable["Finding"]):
        """Partition findings and report stale entries.

        Returns ``(active, waived, stale_entries)`` where *active* are
        unwaived findings (inline ``# lint: allow`` markers also waive),
        and *stale_entries* matched nothing this run.
        """
        active, waived = [], []
        used: set[int] = set()
        for f in findings:
            if f.waived:
                waived.append(f)
                continue
            e = self.match(f)
            if e is not None:
                used.add(e.lineno)
                waived.append(f)
            else:
                active.append(f)
        stale = [e for e in self.entries if e.lineno not in used]
        return active, waived, stale


def parse_allowlist(text: str, path: str = "<memory>") -> Allowlist:
    entries: list[Entry] = []
    for lineno, raw in enumerate(text.splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "#" not in line:
            raise AllowlistError(
                f"{path}:{lineno}: allowlist entry has no justification "
                f"('{line}') — append '# why this is acceptable'")
        pattern, _, justification = line.partition("#")
        pattern = pattern.strip()
        justification = justification.strip()
        if not justification:
            raise AllowlistError(
                f"{path}:{lineno}: empty justification for '{pattern}'")
        if pattern.count(":") < 3 and "*" not in pattern:
            raise AllowlistError(
                f"{path}:{lineno}: '{pattern}' is not a "
                "rule:path:scope:detail fingerprint (or glob)")
        entries.append(Entry(pattern, justification, lineno))
    return Allowlist(entries, path)


def load_allowlist(path: str | None = None) -> Allowlist:
    path = path or DEFAULT_PATH
    if not os.path.exists(path):
        return Allowlist([], path)
    with open(path, "r", encoding="utf-8") as fh:
        return parse_allowlist(fh.read(), path)
