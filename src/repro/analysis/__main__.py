"""CLI: ``python -m repro.analysis [paths...]``.

Runs all three engines and exits non-zero on any unwaived finding or
failed bitflow obligation (the CI `lint` lane's contract):

- bitflow: proves the packed Givens datapath widths for every paper
  configuration (skip with ``--no-bitflow``);
- lint: the JAX/Pallas hazard rules over the given paths;
- deadcode: unreferenced-module scan (runs when a scanned path contains
  the `repro` package root, i.e. the default ``src`` sweep).

``--report FILE`` writes the machine-readable JSON report (proven
widths vs format capacities + findings).  ``--emit-allowlist`` prints
ready-to-paste allowlist lines for the current active findings.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .allowlist import AllowlistError, load_allowlist
from .bitflow import verify_all
from .deadcode import find_dead_modules
from .lint import lint_paths


def _find_repo_root(start: str) -> str:
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, "src", "repro")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="bit-width dataflow verifier + JAX/Pallas hazard linter")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist file (default: the checked-in one)")
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="write JSON report here")
    ap.add_argument("--emit-allowlist", action="store_true",
                    help="print allowlist lines for active findings")
    ap.add_argument("--no-bitflow", action="store_true")
    ap.add_argument("--no-lint", action="store_true")
    ap.add_argument("--no-deadcode", action="store_true")
    ap.add_argument("--allow-stale", action="store_true",
                    help="do not fail on allowlist entries matching nothing")
    args = ap.parse_args(argv)

    root = os.path.abspath(args.root) if args.root else _find_repo_root(".")
    paths = args.paths or ["src"]
    rc = 0

    # -- bitflow --------------------------------------------------------------
    report_json: dict = {}
    if not args.no_bitflow:
        rep = verify_all()
        report_json["bitflow"] = rep.as_dict()
        for line in rep.summary_lines():
            print(line)
        if not rep.ok:
            rc = 1  # summary_lines already printed each failed obligation
        print()

    # -- lint + deadcode ------------------------------------------------------
    findings = []
    if not args.no_lint:
        findings.extend(lint_paths(paths, root))
    if not args.no_deadcode:
        scans_repro_root = any(
            os.path.isdir(os.path.join(root, p, "repro"))
            or os.path.basename(os.path.normpath(p)) == "src"
            for p in paths)
        if scans_repro_root:
            findings.extend(find_dead_modules(root))

    try:
        allow = load_allowlist(args.allowlist)
    except AllowlistError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    active, waived, stale = allow.split(findings)

    for f in active:
        print(f.render())
    if active:
        rc = 1
        print(f"\n{len(active)} finding(s) not in the allowlist "
              f"({allow.path}).")
        if args.emit_allowlist:
            print("\n# candidate allowlist lines (justify each!):")
            for f in active:
                print(f"{f.fingerprint}  # TODO: why is this acceptable?")
    if waived:
        print(f"{len(waived)} finding(s) waived "
              "(allowlist or inline marker).")
    if stale:
        msg = (f"{len(stale)} stale allowlist entr"
               f"{'y' if len(stale) == 1 else 'ies'} "
               "(matched no finding):")
        print(msg)
        for e in stale:
            print(f"  {allow.path}:{e.lineno}: {e.pattern}")
        if not args.allow_stale:
            rc = 1

    report_json["findings"] = [
        {"fingerprint": f.fingerprint, "line": f.line,
         "message": f.message, "waived": False} for f in active
    ] + [
        {"fingerprint": f.fingerprint, "line": f.line,
         "message": f.message, "waived": True} for f in waived
    ]
    report_json["stale_allowlist"] = [e.pattern for e in stale]
    if args.report:
        with open(args.report, "w", encoding="utf-8") as fh:
            json.dump(report_json, fh, indent=2, sort_keys=True)
        print(f"report written to {args.report}")

    if rc == 0:
        print("analysis: OK (no unwaived findings, all widths proven)"
              if not args.no_bitflow else
              "analysis: OK (no unwaived findings)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
