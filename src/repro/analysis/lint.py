"""JAX/Pallas hazard linter: AST rules from this repo's own bug history.

Every rule encodes an incident we actually debugged (DESIGN.md §13):

``pallas-traced-capture``  (PR 5)
    A `pallas_call` kernel closure captures a value produced by a call
    that may return a traced/committed jax array (the 1/K gain constant
    bug: Mosaic rejects captured array constants, interpret mode hides
    it).  Captures must be visibly static: literals, numpy/math results,
    config objects, enclosing parameters.

``host-roundtrip``  (PR 5-adjacent)
    `float()/int()/bool()`, `.item()`, `.tolist()` or `np.*` applied to
    values derived from the parameters of a jit/scan/fori_loop-traced
    function — a concretization error at best, a silent host sync that
    destroys the trace at worst.

``narrowing-cast``  (PR 4)
    `.astype`/`asarray` onto a real float dtype (complex would be
    silently truncated, f64 would be silently narrowed for sub-f64
    targets) outside the blessed encode/decode boundary modules where
    packed <-> float codecs legitimately live.

``unguarded-scatter``  (PR 6)
    `.at[idx].set/add/...` with a dynamic (array-valued) index and no
    `unique_indices=True` guarantee: duplicate indices make the scatter
    order unspecified (the fleet's duplicate-slot hazard, serialized
    server-side by the FIFO dedup).

``donated-reuse``  (PR 6)
    A buffer passed at a donated position of a `jax.jit(...,
    donate_argnums=...)` callable is read again after the donating call
    — the buffer is deleted, the read raises (or worse, reads garbage
    under some backends).

``unhashable-static``
    A list/dict/set literal (or a jnp array expression) passed for a
    parameter declared static (`static_argnums`/`static_argnames`) or
    into an `lru_cache` function: unhashable jit keys fail at runtime,
    and array-valued cache keys silently retain tracers.

Suppression: a finding is waived either by the central allowlist
(`allowlist.txt`, see `analysis.allowlist`) or by an inline
``# lint: allow[rule-id] <why>`` marker on the finding's line or the
line above it.  Both require a justification.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Optional

__all__ = ["Finding", "lint_source", "lint_paths", "iter_py_files", "RULES"]

RULES = ("pallas-traced-capture", "host-roundtrip", "narrowing-cast",
         "unguarded-scatter", "donated-reuse", "unhashable-static",
         "dead-module")

# Modules whose whole *purpose* is crossing the packed/float boundary —
# real-float casts inside them are the codec itself, not a hazard.
# Everything else needs an allowlist entry with a justification.
BLESSED_CAST_BOUNDARIES = (
    "repro/core/formats.py",      # packed word <-> binary64 codecs
    "repro/core/converters.py",   # FP <-> block fixed-point converters
    "repro/core/cordic.py",       # gain-constant construction (float64 math)
    "repro/core/hub.py",          # value-level HUB quantization codec
)

# Callable roots whose results are static at trace time (safe to close
# over in a Pallas kernel).  numpy is the canonical PR-5 fix: compute
# kernel constants in numpy, not jnp.
_STATIC_CALL_ROOTS = {"np", "numpy", "math", "int", "float", "bool", "str",
                      "tuple", "list", "dict", "set", "frozenset", "len",
                      "range", "min", "max", "abs", "sum", "sorted",
                      "functools", "partial", "isinstance", "getattr"}

_TRACING_COMBINATORS = {
    "jax.jit", "jit", "jax.vmap", "vmap", "jax.pmap",
    "jax.lax.scan", "lax.scan", "jax.lax.fori_loop", "lax.fori_loop",
    "jax.lax.while_loop", "lax.while_loop", "jax.lax.cond", "lax.cond",
    "jax.lax.map", "lax.map", "jax.checkpoint", "jax.remat",
    "shard_map", "jax.experimental.shard_map.shard_map",
}

_NARROW_REAL_TARGETS = {
    "jnp.float64", "np.float64", "jnp.float32", "np.float32",
    "jnp.float16", "np.float16", "jnp.bfloat16", "float",
    "'float64'", '"float64"', "'float32'", '"float32"',
    "'float16'", '"float16"', "'bfloat16'", '"bfloat16"',
}

_SCATTER_METHODS = {"set", "add", "mul", "min", "max", "multiply", "divide"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str       # repo-relative, forward slashes
    line: int
    col: int
    scope: str      # dotted enclosing-def chain ("<module>" at top level)
    detail: str     # stable, line-number-free discriminator
    message: str
    waived: bool = False   # inline `# lint: allow[...]` marker present

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}:{self.path}:{self.scope}:{self.detail}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: [{self.rule}] "
                f"{self.message}")


def _dotted(node: ast.AST) -> Optional[str]:
    """Name/Attribute chain -> 'a.b.c' (None for anything else)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _root(dotted: Optional[str]) -> Optional[str]:
    return dotted.split(".", 1)[0] if dotted else None


def _clean(s: str, limit: int = 60) -> str:
    """Detail strings must stay fingerprint-safe: one line, no '#'."""
    s = " ".join(s.split()).replace("#", "")
    return s[:limit]


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - malformed synthetic nodes
        return "<expr>"


class _FnInfo:
    """Per-function metadata collected in the structure pass."""

    def __init__(self, node, name: str, parent: Optional["_FnInfo"]):
        self.node = node
        self.name = name
        self.parent = parent
        self.params: set[str] = set()
        self.locals: set[str] = set()
        self.assigns: dict[str, list[ast.AST]] = {}
        self.scalar_names: set[str] = set()
        self.traced = False
        self.kernel = False

    @property
    def scope_name(self) -> str:
        parts = []
        f: Optional[_FnInfo] = self
        while f is not None and f.name != "<module>":
            parts.append(f.name)
            f = f.parent
        return ".".join(reversed(parts)) or "<module>"


class _Analyzer:
    """One pass over a module: structure, then the per-rule checks."""

    def __init__(self, tree: ast.Module, path: str, source: str):
        self.tree = tree
        self.path = path
        self.lines = source.splitlines()
        self.findings: list[Finding] = []
        self.fn_of_node: dict[ast.AST, _FnInfo] = {}
        self.module_fn = _FnInfo(tree, "<module>", None)
        self.module_names: set[str] = set()     # imports + module assigns
        self.np_like_globals: set[str] = set()  # module consts from np/math
        self.defs_by_name: dict[str, list[ast.AST]] = {}
        self.donating: dict[str, tuple[int, ...]] = {}  # callable -> positions
        self.static_jits: dict[str, dict] = {}  # fn name -> static arg spec
        self.lru_cached: set[str] = set()
        self._collect_structure()

    # -- structure pass -------------------------------------------------------
    def _collect_structure(self):
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._parent = node  # type: ignore[attr-defined]

        def visit(node, fn: _FnInfo):
            self.fn_of_node[node] = fn
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                sub = _FnInfo(node, node.name, fn)
                a = node.args
                for arg in (a.posonlyargs + a.args + a.kwonlyargs
                            + ([a.vararg] if a.vararg else [])
                            + ([a.kwarg] if a.kwarg else [])):
                    sub.params.add(arg.arg)
                fn.locals.add(node.name)
                self.defs_by_name.setdefault(node.name, []).append(node)
                self.fn_of_node[node] = fn  # the def itself lives in fn
                for st in node.body:
                    visit(st, sub)
                for dec in node.decorator_list:
                    visit(dec, fn)
                return
            if isinstance(node, ast.Lambda):
                sub = _FnInfo(node, "<lambda>", fn)
                for arg in node.args.args:
                    sub.params.add(arg.arg)
                visit(node.body, sub)
                return
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    self._record_binding(fn, tgt, node.value)
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                if node.value is not None:
                    self._record_binding(fn, node.target, node.value)
            elif isinstance(node, ast.For):
                self._record_binding(fn, node.target, None)
                # Python-level loop targets are trace-time statics: the
                # loop unrolls, so using them as indices cannot produce
                # array-valued (duplicable) scatter indices.  The PR-6
                # hazard class is array indices flowing in as arguments.
                for n in ast.walk(node.target):
                    if isinstance(n, ast.Name):
                        fn.scalar_names.add(n.id)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for alias in node.names:
                    nm = (alias.asname or alias.name).split(".")[0]
                    (fn.locals if fn.parent else self.module_names).add(nm)
            for child in ast.iter_child_nodes(node):
                visit(child, fn)

        for st in self.tree.body:
            visit(st, self.module_fn)
        self.module_names |= self.module_fn.locals
        self.module_names |= set(self.module_fn.assigns)
        for name, exprs in self.module_fn.assigns.items():
            if all(e is not None and self._is_static_expr(e, self.module_fn)
                   for e in exprs):
                self.np_like_globals.add(name)
        self._mark_traced_and_kernels()
        self._collect_donating_and_static()

    def _record_binding(self, fn: _FnInfo, tgt, value):
        for n in ast.walk(tgt) if not isinstance(tgt, ast.Name) else [tgt]:
            if isinstance(n, ast.Name):
                fn.locals.add(n.id)
                fn.assigns.setdefault(n.id, []).append(value)

    def _fn_info(self, node: ast.AST) -> Optional[_FnInfo]:
        for sub in self.iter_fn_infos():
            if sub.node is node:
                return sub
        return None

    def iter_fn_infos(self) -> Iterable[_FnInfo]:
        seen = set()
        for fn in self.fn_of_node.values():
            if id(fn) not in seen:
                seen.add(id(fn))
                yield fn

    def _mark_traced_and_kernels(self):
        infos = {f.node: f for f in self.iter_fn_infos()}
        # decorators
        for node, fn in list(infos.items()):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                d = _dotted(dec) or _dotted(getattr(dec, "func", dec))
                if d in _TRACING_COMBINATORS:
                    fn.traced = True
                if isinstance(dec, ast.Call):
                    for a in list(dec.args) + [kw.value for kw in dec.keywords]:
                        if _dotted(a) in ("jax.jit", "jit"):
                            fn.traced = True
        # combinator / pallas_call arguments
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            is_pallas = bool(d) and d.split(".")[-1] == "pallas_call"
            if d not in _TRACING_COMBINATORS and not is_pallas:
                continue
            for a in list(node.args) + [kw.value for kw in node.keywords]:
                target = None
                if isinstance(a, ast.Name):
                    target = self._resolve_local_def(a, node)
                elif isinstance(a, (ast.Lambda,)):
                    target = a
                if target is not None and target in infos:
                    infos[target].traced = True
                    if is_pallas:
                        infos[target].kernel = True
        # bodies nested inside traced functions trace too
        changed = True
        while changed:
            changed = False
            for fn in infos.values():
                if not fn.traced and fn.parent is not None and fn.parent.traced:
                    fn.traced = True
                    changed = True

    def _resolve_local_def(self, name_node: ast.Name,
                           at: ast.AST) -> Optional[ast.AST]:
        fn = self.fn_of_node.get(at) or self.module_fn
        while fn is not None:
            for cand in self.defs_by_name.get(name_node.id, []):
                if self.fn_of_node.get(cand) is fn:
                    return cand
            fn = fn.parent
        cands = self.defs_by_name.get(name_node.id, [])
        return cands[-1] if cands else None

    def _collect_donating_and_static(self):
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            if d not in ("jax.jit", "jit", "functools.partial", "partial"):
                continue
            kwargs = {kw.arg: kw.value for kw in node.keywords if kw.arg}
            is_jit = d in ("jax.jit", "jit") or any(
                _dotted(a) in ("jax.jit", "jit") for a in node.args)
            if not is_jit:
                continue
            donate = kwargs.get("donate_argnums")
            statics = {k: kwargs[k] for k in
                       ("static_argnums", "static_argnames") if k in kwargs}
            parent = getattr(node, "_parent", None)
            # name the resulting callable: `X = jax.jit(...)` or
            # `self._f = jax.jit(...)`; decorator form names the def.
            bound = None
            if isinstance(parent, ast.Assign) and len(parent.targets) == 1:
                bound = _dotted(parent.targets[0])
            elif isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
                bound = parent.name
            if donate is not None and bound:
                positions = _int_tuple(donate)
                if positions:
                    self.donating[bound.split(".")[-1]] = positions
            if statics and bound:
                self.static_jits[bound.split(".")[-1]] = {
                    k: _static_spec(v) for k, v in statics.items()}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    d = _dotted(dec) or _dotted(getattr(dec, "func", dec))
                    if d and d.split(".")[-1] in ("lru_cache", "cache"):
                        self.lru_cached.add(node.name)

    # -- static-expression classifier (pallas capture rule) -------------------
    def _is_static_expr(self, expr: ast.AST, fn: _FnInfo,
                        depth: int = 0) -> bool:
        if depth > 16 or expr is None:
            return False
        if isinstance(expr, ast.Constant):
            return True
        if isinstance(expr, (ast.Tuple, ast.List, ast.Set)):
            return all(self._is_static_expr(e, fn, depth + 1)
                       for e in expr.elts)
        if isinstance(expr, ast.Name):
            if expr.id in fn.params:
                return True  # enclosing builder params are static config
            if expr.id in self.np_like_globals or expr.id in self.module_names:
                return True
            binds = _lookup_assigns(fn, expr.id)
            return bool(binds) and all(
                b is not None and self._is_static_expr(b, fn, depth + 1)
                for b in binds)
        if isinstance(expr, ast.Attribute):
            return True  # cfg.hub / self.cfg / np.float64 style access
        if isinstance(expr, (ast.BinOp, ast.BoolOp, ast.Compare)):
            return all(self._is_static_expr(e, fn, depth + 1)
                       for e in ast.iter_child_nodes(expr)
                       if not isinstance(e, (ast.operator, ast.cmpop,
                                             ast.boolop)))
        if isinstance(expr, ast.UnaryOp):
            return self._is_static_expr(expr.operand, fn, depth + 1)
        if isinstance(expr, ast.Subscript):
            return self._is_static_expr(expr.value, fn, depth + 1)
        if isinstance(expr, ast.Call):
            d = _dotted(expr.func)
            root = _root(d)
            if root in _STATIC_CALL_ROOTS or root in self.np_like_globals:
                return all(self._is_static_expr(a, fn, depth + 1)
                           for a in expr.args)
            # Uppercase initial: config-object constructor (GivensConfig)
            if d and d.split(".")[-1][:1].isupper():
                return True
            # Method call on a static *computed* receiver, e.g.
            # np.round(...).astype(...).  Only when the callee is not a
            # plain dotted chain (those were already judged above —
            # jnp.int64(...) must stay non-static).
            if (d is None and isinstance(expr.func, ast.Attribute)
                    and self._is_static_expr(expr.func.value, fn,
                                             depth + 1)):
                return all(self._is_static_expr(a, fn, depth + 1)
                           for a in expr.args)
            return False
        if isinstance(expr, ast.IfExp):
            return all(self._is_static_expr(e, fn, depth + 1)
                       for e in (expr.test, expr.body, expr.orelse))
        return False

    # -- emission -------------------------------------------------------------
    def emit(self, rule: str, node: ast.AST, scope: str, detail: str,
             message: str):
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        waived = self._inline_waiver(rule, line)
        self.findings.append(Finding(rule, self.path, line, col, scope,
                                     _clean(detail), message, waived))

    def _inline_waiver(self, rule: str, line: int) -> bool:
        for ln in (line, line - 1):
            if 1 <= ln <= len(self.lines):
                text = self.lines[ln - 1]
                marker = f"lint: allow[{rule}]"
                if marker in text:
                    after = text.split(marker, 1)[1].strip()
                    if after:  # justification required
                        return True
        return False

    # -- rules ----------------------------------------------------------------
    def run(self) -> list[Finding]:
        self.rule_pallas_traced_capture()
        self.rule_host_roundtrip()
        self.rule_narrowing_cast()
        self.rule_unguarded_scatter()
        self.rule_donated_reuse()
        self.rule_unhashable_static()
        return self.findings

    def rule_pallas_traced_capture(self):
        for fn in list(self.iter_fn_infos()):
            if not fn.kernel:
                continue
            free = self._free_names(fn)
            for name, load in sorted(free.items()):
                enclosing, binds = self._find_enclosing_binding(fn, name)
                if enclosing is None:
                    continue  # module global / builtin: static
                bad = [b for b in binds
                       if b is None or not self._is_static_expr(b, enclosing)]
                if bad:
                    rhs = _unparse(bad[0]) if bad[0] is not None else "<loop>"
                    self.emit(
                        "pallas-traced-capture", load, fn.scope_name,
                        detail=f"capture:{name}",
                        message=f"pallas kernel '{fn.name}' closes over "
                                f"'{name}' bound from non-static "
                                f"'{_clean(rhs)}' — compute kernel "
                                "constants in numpy (PR-5 bug class)")

    def _free_names(self, fn: _FnInfo) -> dict[str, ast.AST]:
        bound = fn.params | fn.locals
        free: dict[str, ast.AST] = {}
        for node in ast.walk(fn.node):
            if (isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load)
                    and node.id not in bound
                    and node.id not in _BUILTIN_NAMES
                    and self.fn_of_node.get(node, fn).scope_in(fn)):
                free.setdefault(node.id, node)
        return free

    def _find_enclosing_binding(self, fn: _FnInfo, name: str):
        enc = fn.parent
        while enc is not None and enc.parent is not None:  # stop at module
            if name in enc.params:
                return None, []  # params treated as static config
            if name in enc.assigns:
                return enc, enc.assigns[name]
            if name in enc.locals:
                return enc, [None]
            enc = enc.parent
        return None, []

    def rule_host_roundtrip(self):
        for fn in self.iter_fn_infos():
            if not fn.traced:
                continue
            tracer_names = self._tracerish_names(fn)
            for node in ast.walk(fn.node):
                if self.fn_of_node.get(node) is not None and \
                        not self.fn_of_node[node].scope_in(fn):
                    continue
                if not isinstance(node, ast.Call):
                    continue
                d = _dotted(node.func)
                if isinstance(node.func, ast.Attribute) and \
                        node.func.attr in ("item", "tolist"):
                    self.emit("host-roundtrip", node, fn.scope_name,
                              detail=f".{node.func.attr}()",
                              message=f"'.{node.func.attr}()' inside traced "
                                      f"'{fn.name}' forces a host round-trip")
                    continue
                root = _root(d)
                mentions = {n.id for a in node.args
                            for n in ast.walk(a) if isinstance(n, ast.Name)}
                if not (mentions & tracer_names):
                    continue
                if d in ("float", "int", "bool"):
                    self.emit("host-roundtrip", node, fn.scope_name,
                              detail=f"{d}({_clean(_unparse(node.args[0]) if node.args else '')})",
                              message=f"'{d}()' on a traced value inside "
                                      f"'{fn.name}' concretizes the tracer")
                elif root in ("np", "numpy"):
                    self.emit("host-roundtrip", node, fn.scope_name,
                              detail=_clean(f"{d}(...)"),
                              message=f"numpy call '{d}' receives traced "
                                      f"values inside '{fn.name}'")

    def _tracerish_names(self, fn: _FnInfo) -> set[str]:
        names = set(fn.params)
        for _ in range(2):  # tiny fixpoint: assignments from tracer exprs
            for name, exprs in fn.assigns.items():
                for e in exprs:
                    if e is None:
                        continue
                    for n in ast.walk(e):
                        if isinstance(n, ast.Name) and n.id in names:
                            names.add(name)
                        d = _dotted(n) if isinstance(n, ast.Attribute) else None
                        if d and _root(d) in ("jnp", "lax"):
                            names.add(name)
        return names

    def rule_narrowing_cast(self):
        blessed = any(self.path.endswith(b) for b in BLESSED_CAST_BOUNDARIES)
        if blessed:
            return
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            scope = (self.fn_of_node.get(node) or self.module_fn).scope_name
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "astype" and node.args:
                tgt = _unparse(node.args[0])
                if tgt in _NARROW_REAL_TARGETS:
                    self.emit("narrowing-cast", node, scope,
                              detail=f"astype:{tgt}",
                              message=f"'.astype({tgt})' silently drops "
                                      "imaginary parts / narrows precision "
                                      "outside a blessed codec boundary "
                                      "(PR-4 bug class)")
                continue
            d = _dotted(node.func)
            if d in ("jnp.asarray", "np.asarray", "jnp.array", "np.array") \
                    and len(node.args) >= 2:
                tgt = _unparse(node.args[1])
                if tgt in _NARROW_REAL_TARGETS:
                    self.emit("narrowing-cast", node, scope,
                              detail=f"{d}:{tgt}",
                              message=f"'{d}(..., {tgt})' is an implicit "
                                      "real/narrowing cast outside a blessed "
                                      "codec boundary (PR-4 bug class)")

    def rule_unguarded_scatter(self):
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SCATTER_METHODS
                    and isinstance(node.func.value, ast.Subscript)
                    and isinstance(node.func.value.value, ast.Attribute)
                    and node.func.value.value.attr == "at"):
                continue
            idx = node.func.value.slice
            fn = self.fn_of_node.get(node) or self.module_fn
            if not self._dynamic_index(idx, fn):
                continue
            kwargs = {kw.arg: kw.value for kw in node.keywords}
            uniq = kwargs.get("unique_indices")
            if isinstance(uniq, ast.Constant) and uniq.value is True:
                continue
            self.emit("unguarded-scatter", node, fn.scope_name,
                      detail=f"at[{_clean(_unparse(idx), 40)}].{node.func.attr}",
                      message="scatter with a dynamic index and no "
                              "unique_indices guarantee: duplicate indices "
                              "make the update order unspecified (PR-6 "
                              "fleet hazard) — guard, serialize, or "
                              "allowlist with the dedup argument")

    def _dynamic_index(self, idx: ast.AST, fn: _FnInfo) -> bool:
        elts = idx.elts if isinstance(idx, ast.Tuple) else [idx]
        scalars = set(fn.scalar_names)
        f = fn.parent
        while f is not None:
            scalars |= f.scalar_names
            f = f.parent
        # first parameter of a loop-body function is the induction scalar
        if fn.params and fn.traced:
            first = (fn.node.args.args[0].arg
                     if getattr(fn.node, "args", None) and fn.node.args.args
                     else None)
            if first:
                scalars.add(first)
        for e in elts:
            if not self._is_scalar_index(e, fn, scalars):
                return True
        return False

    def _is_scalar_index(self, e: ast.AST, fn: _FnInfo, scalars: set[str],
                         depth: int = 0) -> bool:
        """Can `e` only ever be a python/trace-time scalar index?

        Scalar indices cannot carry duplicate entries, so scatters over
        them are unique by construction.
        """
        if depth > 5:
            return False
        if isinstance(e, (ast.Constant, ast.Slice)):
            return True
        if isinstance(e, ast.UnaryOp):
            return self._is_scalar_index(e.operand, fn, scalars, depth + 1)
        if isinstance(e, ast.BinOp):
            return (self._is_scalar_index(e.left, fn, scalars, depth + 1)
                    and self._is_scalar_index(e.right, fn, scalars,
                                              depth + 1))
        if isinstance(e, ast.Call) and _dotted(e.func) in ("len", "int",
                                                           "min", "max"):
            return True
        if isinstance(e, ast.Subscript):
            # `X.shape[k]` is a python int
            v = e.value
            return isinstance(v, ast.Attribute) and v.attr == "shape"
        if isinstance(e, ast.Name):
            if e.id in scalars:
                return True
            binds = _lookup_assigns(fn, e.id)
            return bool(binds) and all(
                b is not None
                and self._is_scalar_index(b, fn, scalars, depth + 1)
                for b in binds)
        return False

    def rule_donated_reuse(self):
        if not self.donating:
            return
        for fn in self.iter_fn_infos():
            if not isinstance(fn.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            self._check_donated_in_body(fn, fn.node.body)

    def _check_donated_in_body(self, fn: _FnInfo, body: list[ast.stmt]):
        donated: dict[str, int] = {}  # name -> line of the donating call
        for stmt in body:
            rebound = set()
            if isinstance(stmt, ast.Assign):
                for tgt in stmt.targets:
                    for n in ast.walk(tgt):
                        if isinstance(n, ast.Name):
                            rebound.add(n.id)
            for node in ast.walk(stmt):
                if isinstance(node, ast.Name) and \
                        isinstance(node.ctx, ast.Load) and node.id in donated:
                    self.emit("donated-reuse", node, fn.scope_name,
                              detail=f"use-after-donate:{node.id}",
                              message=f"'{node.id}' was donated at line "
                                      f"{donated[node.id]} and is read "
                                      "again — the buffer is deleted by "
                                      "donate_argnums")
            for node in ast.walk(stmt):
                if not isinstance(node, ast.Call):
                    continue
                callee = _dotted(node.func)
                short = callee.split(".")[-1] if callee else None
                if short in self.donating:
                    for pos in self.donating[short]:
                        if pos < len(node.args) and \
                                isinstance(node.args[pos], ast.Name):
                            nm = node.args[pos].id
                            if nm not in rebound:
                                donated[nm] = node.lineno
            donated = {k: v for k, v in donated.items() if k not in rebound}

    def rule_unhashable_static(self):
        targets = dict(self.static_jits)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            d = _dotted(node.func)
            short = d.split(".")[-1] if d else None
            if short in self.lru_cached:
                for a in list(node.args) + [kw.value for kw in node.keywords]:
                    if self._unhashable_expr(a):
                        fn = self.fn_of_node.get(node) or self.module_fn
                        self.emit("unhashable-static", node, fn.scope_name,
                                  detail=f"lru:{short}:{_clean(_unparse(a), 30)}",
                                  message=f"unhashable/array argument "
                                          f"'{_clean(_unparse(a), 40)}' to "
                                          f"lru_cached '{short}'")
                continue
            if short not in targets:
                continue
            spec = targets[short]
            argnums = spec.get("static_argnums") or ()
            argnames = spec.get("static_argnames") or ()
            fn = self.fn_of_node.get(node) or self.module_fn
            for i, a in enumerate(node.args):
                if i in argnums and self._unhashable_expr(a):
                    self.emit("unhashable-static", node, fn.scope_name,
                              detail=f"jit:{short}:pos{i}",
                              message=f"unhashable value at static position "
                                      f"{i} of jitted '{short}'")
            for kw in node.keywords:
                if kw.arg in argnames and self._unhashable_expr(kw.value):
                    self.emit("unhashable-static", node, fn.scope_name,
                              detail=f"jit:{short}:{kw.arg}",
                              message=f"unhashable value for static arg "
                                      f"'{kw.arg}' of jitted '{short}'")

    def _unhashable_expr(self, a: ast.AST) -> bool:
        if isinstance(a, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                          ast.DictComp, ast.SetComp)):
            return True
        if isinstance(a, ast.Call):
            d = _dotted(a.func)
            return _root(d) in ("jnp",) or d in ("list", "dict", "set")
        return False


def _int_tuple(node: ast.AST) -> tuple[int, ...]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
        return tuple(out)
    return ()


def _static_spec(node: ast.AST):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if isinstance(e, ast.Constant):
                vals.append(e.value)
        return tuple(vals)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    return ()


def _lookup_assigns(fn: _FnInfo, name: str):
    f: Optional[_FnInfo] = fn
    while f is not None:
        if name in f.assigns:
            return f.assigns[name]
        if name in f.params:
            return []
        f = f.parent
    return []


# names always available without a binding
_BUILTIN_NAMES = set(dir(__builtins__)) | {
    "True", "False", "None", "self", "cls", "__name__", "__file__",
}


def _scope_in(self: _FnInfo, other: _FnInfo) -> bool:
    f: Optional[_FnInfo] = self
    while f is not None:
        if f is other:
            return True
        f = f.parent
    return False


_FnInfo.scope_in = _scope_in  # type: ignore[attr-defined]


def lint_source(source: str, path: str) -> list[Finding]:
    """Lint one module's source text; `path` is the repo-relative name."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Finding("syntax-error", path, exc.lineno or 1, 0, "<module>",
                        "syntax", f"cannot parse: {exc.msg}")]
    analyzer = _Analyzer(tree, path.replace(os.sep, "/"), source)
    return analyzer.run()


def iter_py_files(paths: Iterable[str], root: str) -> Iterable[str]:
    for p in paths:
        full = os.path.join(root, p) if not os.path.isabs(p) else p
        if os.path.isfile(full) and full.endswith(".py"):
            yield full
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                for f in sorted(filenames):
                    if f.endswith(".py"):
                        yield os.path.join(dirpath, f)


def lint_paths(paths: Iterable[str], root: str = ".") -> list[Finding]:
    """Lint every .py file under `paths` (files or directories)."""
    findings: list[Finding] = []
    for full in iter_py_files(paths, root):
        rel = os.path.relpath(full, root).replace(os.sep, "/")
        with open(full, "r", encoding="utf-8") as fh:
            findings.extend(lint_source(fh.read(), rel))
    return findings
