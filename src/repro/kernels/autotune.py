"""Per-shape autotuning for the blocked QRD Pallas kernels (DESIGN.md §11).

The blocked kernels used to run with one hardcoded batch tile
(``qrd_blocked.TILE_B = 8``) and one stage-table layout, whatever the
problem shape or device.  This module searches the small discrete space
that actually matters for these kernels —

* ``tile_b``  — how many matrices ride in one kernel instance's VMEM
  block (powers of two up to the batch, capped by a VMEM budget model);
* ``table_layout`` — ``'split'`` (three (S, Pmax) stage-table operands)
  vs ``'stacked'`` (one concatenated (3S, Pmax) operand) for the
  wavefront kernels

— by timing real engine dispatches, and persists the winners in a JSON
cache keyed by **device kind** so results survive processes but never
leak across hardware.  `repro.qrd.QRDEngine` consults `lookup` at
dispatch time whenever the config leaves ``tile_b=None``; `tune` is the
explicit (and benchmark-suite) entry point that fills the cache.

Cache file: ``$REPRO_AUTOTUNE_CACHE`` or
``~/.cache/repro/qrd_autotune.json``.  Schema::

    {"schema_version": 1,
     "<device kind>": {
        "<backend>/<schedule>/m4/n4/float64": {
            "tile_b": 16, "table_layout": "split",
            "warm_s": 1.2e-3,
            "candidates": [{"tile_b": 8, ...,  "warm_s": ...}, ...]}}}

Lookups are mtime-memoized: the file is re-read only when it changed on
disk, so the per-dispatch cost is one ``os.stat``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

__all__ = ["TuneEntry", "TUNABLE_BACKENDS", "cache_path", "device_kind",
           "cache_key", "lookup", "tune", "tune_tiled",
           "candidate_tile_bs", "candidate_layouts", "candidate_panel_ns",
           "candidate_tile_ms", "clear_memo"]

TUNABLE_BACKENDS = ("cordic_pallas", "blockfp_pallas")

#: Default VMEM budget (bytes) for the tile model — deliberately modest
#: (a TPU core has ~16 MiB but the working tile shares it with stage
#: tables, semaphores, and double-buffering headroom).
DEFAULT_VMEM_BUDGET = 2 * 1024 * 1024

#: Buffers the VMEM model charges per resident element: input block +
#: output block + roughly four working copies live across a rotation
#: step (x/y gathers, rotated halves, scatter temporaries).
_VMEM_BUFFERS = 6

_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class TuneEntry:
    """One persisted winner: the parameters `lookup` hands the engine.

    ``panel_n`` / ``tile_m`` are the tiled-route knobs (`tune_tiled`);
    flat entries leave them None and serialize without them, so cache
    files written before the tiled routes existed still load.
    """

    tile_b: int
    table_layout: str | None
    warm_s: float
    candidates: tuple = ()
    panel_n: int | None = None
    tile_m: int | None = None

    def to_json(self):
        d = {"tile_b": self.tile_b, "table_layout": self.table_layout,
             "warm_s": self.warm_s, "candidates": list(self.candidates)}
        if self.panel_n is not None:
            d["panel_n"] = self.panel_n
        if self.tile_m is not None:
            d["tile_m"] = self.tile_m
        return d

    @classmethod
    def from_json(cls, d):
        pn, tm = d.get("panel_n"), d.get("tile_m")
        return cls(tile_b=int(d["tile_b"]),
                   table_layout=d.get("table_layout"),
                   warm_s=float(d.get("warm_s", 0.0)),
                   candidates=tuple(d.get("candidates", ())),
                   panel_n=None if pn is None else int(pn),
                   tile_m=None if tm is None else int(tm))


# --------------------------------------------------------------------------
# Cache file plumbing
# --------------------------------------------------------------------------
def cache_path() -> str:
    """Resolve the cache file path (env override, else ~/.cache)."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "qrd_autotune.json")


def device_kind() -> str:
    """The accelerator identity the cache is keyed by (e.g. 'cpu',
    'TPU v5 lite') — tuned tiles must never leak across hardware."""
    import jax
    return jax.devices()[0].device_kind


def cache_key(backend: str, schedule: str, m: int, n: int,
              dtype: str, tiling: str | None = None) -> str:
    """Cache key; tiled-route entries get a ``/tiled-<route>`` suffix so
    they never collide with (or shadow) a flat entry at the same shape."""
    key = f"{backend}/{schedule}/m{m}/n{n}/{dtype}"
    return key if tiling is None else f"{key}/tiled-{tiling}"


# path -> (mtime_ns, parsed doc); lookup() re-reads only on mtime change
_MEMO: dict = {}


def clear_memo():
    """Drop the mtime memo (tests that swap cache files under one path)."""
    _MEMO.clear()


def _load(path: str):
    try:
        stat = os.stat(path)
    except OSError:
        _MEMO.pop(path, None)
        return None
    hit = _MEMO.get(path)
    if hit is not None and hit[0] == stat.st_mtime_ns:
        return hit[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return None
    _MEMO[path] = (stat.st_mtime_ns, doc)
    return doc


def _store(path: str, device: str, key: str, entry: TuneEntry):
    doc = _load(path) or {"schema_version": _SCHEMA_VERSION}
    doc.setdefault(device, {})[key] = entry.to_json()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    _MEMO.pop(path, None)   # force a re-read (mtime granularity)


def lookup(backend: str, schedule: str, m: int, n: int, dtype: str,
           path: str | None = None,
           tiling: str | None = None) -> TuneEntry | None:
    """Cache-only lookup (never tunes): the engine's dispatch-time hook.

    Returns the persisted `TuneEntry` for this (device kind, backend,
    schedule, m, n, dtype[, tiled route]) or None on a miss.  Cost on
    the hot path is one ``os.stat`` (the parsed file is memoized by
    mtime).
    """
    doc = _load(path or cache_path())
    if not doc:
        return None
    per_dev = doc.get(device_kind())
    if not per_dev:
        return None
    raw = per_dev.get(cache_key(backend, schedule, m, n, dtype, tiling))
    if raw is None:
        return None
    try:
        return TuneEntry.from_json(raw)
    except (KeyError, TypeError, ValueError):
        return None


# --------------------------------------------------------------------------
# Candidate generation
# --------------------------------------------------------------------------
def candidate_tile_bs(batch: int, m: int, e: int, itemsize: int,
                      vmem_budget: int | None = None) -> tuple:
    """Power-of-two batch tiles that fit the VMEM budget model.

    The model charges ``_VMEM_BUFFERS`` resident copies of the
    (tile_b, m, e) working block at ``itemsize`` bytes per element
    against the budget (``$REPRO_TILE_VMEM_BUDGET`` or
    `DEFAULT_VMEM_BUDGET`).  Candidates are capped at
    ``min(batch, 64)``; the smallest power of two always survives so the
    search space is never empty.
    """
    if vmem_budget is None:
        vmem_budget = int(os.environ.get("REPRO_TILE_VMEM_BUDGET",
                                         DEFAULT_VMEM_BUDGET))
    cap = max(1, min(int(batch), 64))
    cands = []
    tb = 1
    while tb <= cap:
        cands.append(tb)
        tb *= 2
    bytes_per = _VMEM_BUFFERS * m * e * itemsize
    fit = [tb for tb in cands if tb * bytes_per <= vmem_budget]
    return tuple(fit) if fit else (cands[0],)


def candidate_layouts(schedule: str) -> tuple:
    """Stage-table layouts worth timing: only the wavefront path has
    stage tables at all."""
    return ("split", "stacked") if schedule == "sameh_kuck" else (None,)


def candidate_panel_ns(n: int) -> tuple:
    """Panel widths worth timing for the tiled routes.

    Powers of two in the lane-friendly range, capped at the column
    count — a panel wider than n degenerates to the flat schedule with
    padding.  Never empty: a narrow problem tunes at its own width.
    """
    cands = tuple(w for w in (4, 8, 16) if w <= n)
    return cands if cands else (max(1, n),)


def candidate_tile_ms(m: int, n: int, max_m: int = 128) -> tuple:
    """Leaf heights worth timing for the tsqr route.

    Powers of two up to the backend's row capacity ``max_m``, strictly
    below m (a single leaf is just the panel route) and at least ``2n``
    (shorter leaves do less annihilation per launch than the tree nodes
    they feed).  Never empty: the row capacity itself always survives.
    """
    cands = tuple(t for t in (32, 64, 128)
                  if t <= max_m and t < m and t >= 2 * n)
    return cands if cands else (min(max_m, max(2, m - 1)),)


# --------------------------------------------------------------------------
# The tuner
# --------------------------------------------------------------------------
def _default_timer(fn, A, warm_reps: int):
    """Cold call (trace+compile, discarded), then median of warm reps."""
    import jax
    jax.block_until_ready(fn(A))
    times = []
    for _ in range(warm_reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(A))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def tune(backend: str, schedule: str, m: int, n: int, batch: int, *,
         dtype: str = "float64", givens=None, compute_q: bool = True,
         path: str | None = None, warm_reps: int = 3, timer=None,
         vmem_budget: int | None = None, seed: int = 0) -> TuneEntry:
    """Search (tile_b, table_layout) for one problem shape and persist.

    Builds one `repro.qrd.QRDEngine` per candidate (explicit ``tile_b``
    / ``table_layout`` in its config, so nothing consults the cache
    being filled), times each with a cold call discarded and the median
    of ``warm_reps`` warm ``block_until_ready`` reps, writes the winner
    into the cache file, and returns its `TuneEntry` (with the full
    candidate table attached for the benchmark report).

    Parameters
    ----------
    backend, schedule, m, n, dtype : the cache key coordinates.
    batch : int
        Batch size to tune at — tile candidates never exceed it.
    givens : GivensConfig, optional
        Unit parameters for the engine configs.
    timer : callable, optional
        ``timer(fn, A, warm_reps) -> seconds`` override (tests inject a
        deterministic fake; the default runs real wall-clock timing).
    """
    from repro.qrd import QRDConfig, QRDEngine

    if backend not in TUNABLE_BACKENDS:
        raise ValueError(f"backend {backend!r} is not tunable; "
                         f"expected one of {TUNABLE_BACKENDS}")
    if timer is None:
        timer = _default_timer

    # Working-element size of the kernel-resident block: the packed
    # cordic word is 8 bytes (int64, or the dual-int32 lane pair); the
    # block-FP path holds int32 significands.
    itemsize = 8 if backend == "cordic_pallas" else 4
    e = n + (m if compute_q else 0)
    tiles = candidate_tile_bs(batch, m, e, itemsize, vmem_budget)
    layouts = candidate_layouts(schedule)

    kwargs = {} if givens is None else {"givens": givens}
    rng = np.random.default_rng(seed)
    A = np.asarray(rng.standard_normal((batch, m, n)), dtype=np.float64)

    rows = []
    for tb in tiles:
        for layout in layouts:
            cfg = QRDConfig(backend=backend, schedule=schedule, dtype=dtype,
                            tile_b=tb, table_layout=layout, **kwargs)
            eng = QRDEngine(cfg)
            warm = float(timer(lambda X: eng(X, compute_q=compute_q), A,
                               warm_reps))
            rows.append({"tile_b": tb, "table_layout": layout,
                         "warm_s": warm})

    best = min(rows, key=lambda r: r["warm_s"])
    entry = TuneEntry(tile_b=best["tile_b"],
                      table_layout=best["table_layout"],
                      warm_s=best["warm_s"], candidates=tuple(rows))
    _store(path or cache_path(), device_kind(),
           cache_key(backend, schedule, m, n, dtype), entry)
    return entry


def tune_tiled(backend: str, m: int, n: int, batch: int, *, tiling: str,
               dtype: str = "float64", givens=None, compute_q: bool = True,
               path: str | None = None, warm_reps: int = 3, timer=None,
               max_tile_m: int = 128, seed: int = 0,
               panel_ns: tuple | None = None,
               tile_ms: tuple | None = None) -> TuneEntry:
    """Search the tiled-route knobs for one problem shape and persist.

    ``tiling='panel'`` searches ``panel_n`` (`candidate_panel_ns`);
    ``tiling='tsqr'`` searches ``tile_m x panel_n``
    (`candidate_tile_ms`).  Each candidate is timed through a real
    `repro.qrd.QRDEngine` with the route and knobs pinned explicitly —
    nothing consults the cache being filled, and an explicit
    ``panel_n`` / ``tile_m`` in a user's `QRDConfig` always wins over
    the stored entry at dispatch (the engine only fills fields left
    None).  The winner is stored under the ``/tiled-<route>`` cache key
    and returned with the full candidate table for the benchmark
    report's autotune section.  ``panel_ns`` / ``tile_ms`` override the
    candidate generators — large shapes pay a full trace+compile per
    candidate, so cost-sensitive callers (the CI bench) narrow the
    sweep explicitly.
    """
    from repro.qrd import QRDConfig, QRDEngine

    if backend not in TUNABLE_BACKENDS:
        raise ValueError(f"backend {backend!r} is not tunable; "
                         f"expected one of {TUNABLE_BACKENDS}")
    if tiling not in ("panel", "tsqr"):
        raise ValueError(f"tiling {tiling!r} is not tunable; "
                         "expected 'panel' or 'tsqr'")
    if timer is None:
        timer = _default_timer

    if panel_ns is None:
        panel_ns = candidate_panel_ns(n)
    if tile_ms is None:
        tile_ms = (candidate_tile_ms(m, n, max_tile_m) if tiling == "tsqr"
                   else (None,))

    kwargs = {} if givens is None else {"givens": givens}
    rng = np.random.default_rng(seed)
    A = np.asarray(rng.standard_normal((batch, m, n)), dtype=np.float64)

    rows = []
    for tm in tile_ms:
        for pw in panel_ns:
            cfg = QRDConfig(backend=backend, dtype=dtype, tiling=tiling,
                            panel_n=pw, tile_m=tm, **kwargs)
            eng = QRDEngine(cfg)
            warm = float(timer(lambda X: eng(X, compute_q=compute_q), A,
                               warm_reps))
            rows.append({"tile_m": tm, "panel_n": pw, "warm_s": warm})

    best = min(rows, key=lambda r: r["warm_s"])
    entry = TuneEntry(tile_b=0, table_layout=None, warm_s=best["warm_s"],
                      candidates=tuple(rows), panel_n=best["panel_n"],
                      tile_m=best["tile_m"])
    _store(path or cache_path(), device_kind(),
           cache_key(backend, "col", m, n, dtype, tiling), entry)
    return entry
