"""Dual-int32 lane emulation of the packed int64 Givens datapath.

Why this exists: the packed-word QRD kernels (`kernels/qrd_blocked.py`)
carry IEEE/HUB words as int64 lanes, and both Mosaic (TPU) and Triton
(GPU) reject 64-bit integer vector lanes — so the `cordic_pallas`
backend has been pinned to interpret mode since PR 1.  This module
re-expresses the entire unit (input converter -> CORDIC -> gain
compensation -> output converter, `repro.core.{converters,cordic,
givens}`) over *pairs of 32-bit lanes*, so the same kernels lower
through the hardware compilers.

Representation
--------------
A packed int64 word ``p`` is carried as two int32 lanes stacked on a
trailing axis of size 2::

    L[..., 0] = hi = int32(p >> 32)          # sign-carrying half
    L[..., 1] = lo = int32(p & 0xFFFFFFFF)   # bit pattern of the low half

(`kernels.cordic_givens.packed_to_lanes` / `lanes_to_packed` are the
host-side converters.)  Internally every primitive operates on a
``(hi, lo)`` tuple of **uint32** arrays — unsigned lanes make the
carry/borrow compares and the logical cross-lane shifts natural; the
sign only matters for arithmetic shifts and comparisons, which view the
high lane as int32.

Bit-exactness contract
----------------------
Every emulated primitive computes the exact low 64 bits of its int64
counterpart (two's complement is modular, so add/sub/mul agree between
signed and unsigned interpretations).  Shift amounts are clamped to
[0, 63]; the datapath masks any shift >= N + 2 to exact zero downstream
(`_align`), so the clamp can never be observed for supported N <= 50.
`ilog2` is an exact integer binary search (the int64 path detours
through float64 `frexp`, which Mosaic also rejects).  `LaneUnit` is
asserted bit-identical to `GivensUnit` by tests/test_packed_lanes.py.

Only static ``N`` / ``iters`` are supported (the kernel-resident case);
the traced-parameter sweep path stays on the int64 reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cordic
from repro.core.formats import FloatFormat

__all__ = ["LaneUnit", "lanes_stack", "lanes_unstack"]

_U32 = jnp.uint32
_I32 = jnp.int32
_M32 = 0xFFFFFFFF


# -- lane word construction ---------------------------------------------------

def u64(v: int):
    """Python int -> (hi, lo) uint32 scalar pair (two's complement)."""
    return (jnp.asarray((v >> 32) & _M32, _U32),
            jnp.asarray(v & _M32, _U32))


_ZERO = 0          # built lazily: u64 at trace time keeps constants local
_ONE = 1


def lanes_unstack(L):
    """Stacked int32 (..., 2) lane word -> (hi, lo) uint32 tuple."""
    return L[..., 0].astype(_U32), L[..., 1].astype(_U32)


def lanes_stack(pair):
    """(hi, lo) uint32 tuple -> stacked int32 (..., 2) lane word."""
    h, l = pair
    return jnp.stack([h.astype(_I32), l.astype(_I32)], axis=-1)


def from_i32(x):
    """Sign-extend an int32 array (small field values) to a lane pair."""
    x = jnp.asarray(x, _I32)
    return ((x >> 31).astype(_U32), x.astype(_U32))


def _low(x):
    """Nonnegative int32 array -> lane pair with zero high half."""
    x = jnp.asarray(x, _I32)
    return (jnp.zeros_like(x, _U32), x.astype(_U32))


# -- 64-bit integer primitives over (hi, lo) uint32 pairs ---------------------

def add64(a, b):
    ah, al = a
    bh, bl = b
    l = al + bl
    carry = (l < al).astype(_U32)
    return ah + bh + carry, l


def sub64(a, b):
    ah, al = a
    bh, bl = b
    borrow = (al < bl).astype(_U32)
    return ah - bh - borrow, al - bl


def not64(a):
    return ~a[0], ~a[1]


def neg64(a):
    return add64(not64(a), u64(1))


def and64(a, b):
    return a[0] & b[0], a[1] & b[1]


def or64(a, b):
    return a[0] | b[0], a[1] | b[1]


def xor64(a, b):
    return a[0] ^ b[0], a[1] ^ b[1]


def eq64(a, b):
    return (a[0] == b[0]) & (a[1] == b[1])


def ltu64(a, b):
    """Unsigned a < b."""
    return (a[0] < b[0]) | ((a[0] == b[0]) & (a[1] < b[1]))


def is_neg64(a):
    return a[0].astype(_I32) < 0


def where64(cond, a, b):
    return jnp.where(cond, a[0], b[0]), jnp.where(cond, a[1], b[1])


def _shift_norm(s):
    """Normalize a shift amount (python int or traced) to int32 in [0, 63]."""
    return jnp.clip(jnp.asarray(s, _I32), 0, 63)


def shl64(v, s):
    h, l = v
    s = _shift_norm(s)
    s_lo = jnp.minimum(s, 31)
    su = s_lo.astype(_U32)
    # cross = l >> (32 - s) for s in [1, 31], 0 for s == 0 — the two-step
    # shift avoids the undefined shift-by-32 at s == 0.
    cross = (l >> (31 - s_lo).astype(_U32)) >> _U32(1)
    h_small = (h << su) | cross
    l_small = l << su
    sb = jnp.clip(s - 32, 0, 31).astype(_U32)
    big = s >= 32
    return (jnp.where(big, l << sb, h_small),
            jnp.where(big, _U32(0), l_small))


def shr64(v, s):
    """Logical (zero-fill) right shift."""
    h, l = v
    s = _shift_norm(s)
    s_lo = jnp.minimum(s, 31)
    su = s_lo.astype(_U32)
    cross = (h << (31 - s_lo).astype(_U32)) << _U32(1)   # h << (32 - s)
    l_small = (l >> su) | cross
    h_small = h >> su
    sb = jnp.clip(s - 32, 0, 31).astype(_U32)
    big = s >= 32
    return (jnp.where(big, _U32(0), h_small),
            jnp.where(big, h >> sb, l_small))


def sar64(v, s):
    """Arithmetic (sign-fill) right shift."""
    h, l = v
    hs = h.astype(_I32)
    s = _shift_norm(s)
    s_lo = jnp.minimum(s, 31)
    su = s_lo.astype(_U32)
    cross = (h << (31 - s_lo).astype(_U32)) << _U32(1)
    l_small = (l >> su) | cross
    h_small = (hs >> s_lo).astype(_U32)
    sb = jnp.clip(s - 32, 0, 31)
    sign_fill = (hs >> 31).astype(_U32)
    big = s >= 32
    return (jnp.where(big, sign_fill, h_small),
            jnp.where(big, (hs >> sb).astype(_U32), l_small))


def _mul32x32(x, y):
    """Exact uint32 x uint32 -> (hi, lo) uint32 pair via 16-bit digits."""
    m16 = _U32(0xFFFF)
    x0, x1 = x & m16, x >> _U32(16)
    y0, y1 = y & m16, y >> _U32(16)
    p00 = x0 * y0
    p01 = x0 * y1
    p10 = x1 * y0
    p11 = x1 * y1
    mid = (p00 >> _U32(16)) + (p01 & m16) + (p10 & m16)   # < 2^18, no wrap
    lo = (p00 & m16) | ((mid & m16) << _U32(16))
    hi = p11 + (p01 >> _U32(16)) + (p10 >> _U32(16)) + (mid >> _U32(16))
    return hi, lo


def mul64(a, b):
    """Low 64 bits of the product (signed == unsigned mod 2^64)."""
    ah, al = a
    bh, bl = b
    hi, lo = _mul32x32(al, bl)
    cross = al * bh + ah * bl          # uint32 wrap keeps exactly the low 32
    return hi + cross, lo


def ilog2_32(u):
    """floor(log2(u)) for uint32 u >= 1 (0 for u == 0), pure integer."""
    r = jnp.where(u > _U32(0xFFFF), _I32(16), _I32(0))
    u = u >> r.astype(_U32)
    s = jnp.where(u > _U32(0xFF), _I32(8), _I32(0))
    u = u >> s.astype(_U32)
    r = r + s
    s = jnp.where(u > _U32(0xF), _I32(4), _I32(0))
    u = u >> s.astype(_U32)
    r = r + s
    s = jnp.where(u > _U32(0x3), _I32(2), _I32(0))
    u = u >> s.astype(_U32)
    r = r + s
    return r + jnp.where(u > _U32(0x1), _I32(1), _I32(0))


def ilog2_64(v):
    """floor(log2(v)) for a positive lane pair, int32 result."""
    h, l = v
    use_hi = h != 0
    k = ilog2_32(jnp.where(use_hi, h, l))
    return jnp.where(use_hi, k + 32, k)


def rshift_rne64(v, sh):
    """Arithmetic right shift with round-to-nearest-even on dropped bits.

    Lane mirror of `repro.core.converters._rshift_rne`; sh is clamped to
    [0, 63] (divergence beyond that is masked by the `_align` zero-force,
    identically to the int64 path's own undefined-shift masking).
    """
    sh = jnp.maximum(jnp.asarray(sh, _I32), 0)
    q = sar64(v, sh)
    rem = sub64(v, shl64(q, sh))
    half = shl64(u64(1), jnp.maximum(sh - 1, 0))
    half = where64(sh > 0, half, u64(0))
    round_up = ((ltu64(half, rem)
                 | (eq64(rem, half) & ((q[1] & _U32(1)) == 1)))
                & (sh > 0))
    return add64(q, (jnp.zeros_like(q[0]), round_up.astype(_U32)))


# -- converter datapath (lane mirror of repro.core.converters) ----------------

def _unpack(p, fmt: FloatFormat):
    man = and64(p, u64((1 << fmt.man_bits) - 1))
    exp_raw = (shr64(p, fmt.man_bits)[1]
               & _U32((1 << fmt.exp_bits) - 1)).astype(_I32)
    sign = (shr64(p, fmt.exp_bits + fmt.man_bits)[1] & _U32(1)).astype(_I32)
    return sign, exp_raw, man


def _align(xfix, yfix, ex, ey, N, round_mode):
    d_xy = ex - ey
    x_is_low = d_xy < 0
    sh = jnp.abs(d_xy)
    lo = where64(x_is_low, xfix, yfix)
    if round_mode == "rne":
        lo_sh = rshift_rne64(lo, sh)
    else:  # 'trunc' and 'hub': plain arithmetic shift
        lo_sh = sar64(lo, jnp.minimum(sh, 62))
    lo_sh = where64(sh >= N + 2, u64(0), lo_sh)
    xout = where64(x_is_low, lo_sh, xfix)
    yout = where64(x_is_low, yfix, lo_sh)
    return xout, yout, jnp.maximum(ex, ey)


def _expand_ieee(sign, exp_raw, man, fmt: FloatFormat, N):
    is_zero = exp_raw == 0
    k_ext = N - 2 - fmt.man_bits
    mag = shl64(or64(man, u64(1 << fmt.man_bits)), k_ext)
    mag = where64(is_zero, u64(0), mag)
    return where64(sign == 1, neg64(mag), mag)


def _expand_hub(sign, exp_raw, man, fmt: FloatFormat, N,
                unbiased: bool, detect_identity: bool):
    is_zero = exp_raw == 0
    k = N - 2 - fmt.man_bits          # static here (LaneUnit: static N only)
    base = shl64(or64(man, u64(1 << fmt.man_bits)), k)
    top = 1 << max(k - 1, 0)
    if unbiased:
        lsb = (man[1] & _U32(1)).astype(_I32)
        ext = where64(lsb == 1, u64(top), u64(top - 1))
    else:
        ext = u64(top)
    if k <= 0:
        ext = u64(0)
    if detect_identity:
        is_one = (exp_raw == fmt.bias) & eq64(man, u64(0))
        ext = where64(is_one, u64(0), ext)
    mag = or64(base, ext)
    mag = where64(is_zero, u64(0), mag)
    # HUB negation: pure bit inversion (the ILSB absorbs the +1).
    return where64(sign == 1, not64(mag), mag)


def _input_convert(xp, yp, cfg, N):
    fmt = cfg.fmt
    sx, ex, mx = _unpack(xp, fmt)
    sy, ey, my = _unpack(yp, fmt)
    if cfg.hub:
        xf = _expand_hub(sx, ex, mx, fmt, N, cfg.unbiased, cfg.detect_identity)
        yf = _expand_hub(sy, ey, my, fmt, N, cfg.unbiased, cfg.detect_identity)
        return _align(xf, yf, ex, ey, N, "hub")
    xf = _expand_ieee(sx, ex, mx, fmt, N)
    yf = _expand_ieee(sy, ey, my, fmt, N)
    return _align(xf, yf, ex, ey, N, cfg.input_rounding)


def _saturate_pack(sign, exp_new, man, fmt: FloatFormat, flush_zero):
    overflow = exp_new > fmt.max_exp_raw
    exp_out = jnp.clip(exp_new, 1, fmt.max_exp_raw)
    man = where64(overflow, u64((1 << fmt.man_bits) - 1), man)
    packed = or64(shl64(_low(sign), fmt.exp_bits + fmt.man_bits),
                  or64(shl64(_low(exp_out), fmt.man_bits), man))
    underflow = (exp_new <= 0) | flush_zero
    szero = shl64(_low(sign), fmt.exp_bits + fmt.man_bits)
    return where64(underflow, szero, packed)


def _output_ieee(v, m_exp, fmt: FloatFormat, N):
    neg = is_neg64(v)
    sign = neg.astype(_I32)
    a = where64(neg, neg64(v), v)
    is_zero = eq64(a, u64(0))
    a_safe = where64(is_zero, u64(1), a)
    k = ilog2_64(a_safe)
    m = fmt.man_bits
    down = jnp.maximum(k - m, 0)
    up = jnp.maximum(m - k, 0)
    q = shl64(rshift_rne64(a_safe, down), up)
    carry = (shr64(q, m + 1)[1]).astype(_I32)      # 0 or 1
    q = where64(carry > 0, sar64(q, 1), q)
    k = k + carry
    man = sub64(q, u64(1 << m))
    exp_new = m_exp + k - (N - 2)
    return _saturate_pack(sign, exp_new, man, fmt, is_zero)


def _output_hub(v, m_exp, fmt: FloatFormat, N, unbiased: bool):
    neg = is_neg64(v)
    sign = neg.astype(_I32)
    stored = where64(neg, not64(v), v)
    A = or64(shl64(stored, 1), u64(1))             # append the explicit ILSB
    k2 = ilog2_64(A)
    m = fmt.man_bits
    down = jnp.maximum(k2 - m, 0)
    up = jnp.maximum(m - k2, 0)
    hi = sar64(A, down)                            # truncation == RN for HUB
    if unbiased:
        lsb = (stored[1] & _U32(1)).astype(_I32)
        upm1 = jnp.maximum(up - 1, 0)
        fill = where64(lsb == 1, shl64(u64(1), upm1),
                       sub64(shl64(u64(1), upm1), u64(1)))
        fill = where64(up > 0, fill, u64(0))
    else:
        fill = u64(0)
    q = or64(shl64(hi, up), fill)
    man = sub64(q, u64(1 << m))
    exp_new = m_exp + (k2 - 1) - (N - 2)
    return _saturate_pack(sign, exp_new, man, fmt,
                          jnp.zeros_like(sign, bool))


def _output_convert(v, m_exp, cfg, N):
    if cfg.hub:
        return _output_hub(v, m_exp, cfg.fmt, N, cfg.unbiased)
    return _output_ieee(v, m_exp, cfg.fmt, N)


# -- CORDIC core (lane mirror of repro.core.cordic) ---------------------------

def _negate_fx(v, hub: bool):
    return not64(v) if hub else neg64(v)


def _carry_bit(y, i):
    """HUB carry-in: ILSB (1) at i == 0, else bit (i-1) of the pre-shift y."""
    bit = (sar64(y, jnp.maximum(i - 1, 0))[1] & _U32(1)).astype(_I32)
    return jnp.where(i == 0, _I32(1), bit)


def _microrotation(x, y, i, d_pos, hub: bool):
    ys = sar64(y, i)
    xs = sar64(x, i)
    if hub:
        cy = _carry_bit(y, i)
        cx = _carry_bit(x, i)
        x_sub = add64(add64(x, not64(ys)), _low(1 - cy))   # x - (y>>i)
        x_add = add64(add64(x, ys), _low(cy))              # x + (y>>i)
        y_add = add64(add64(y, xs), _low(cx))              # y + (x>>i)
        y_sub = add64(add64(y, not64(xs)), _low(1 - cx))   # y - (x>>i)
    else:
        x_sub = sub64(x, ys)
        x_add = add64(x, ys)
        y_add = add64(y, xs)
        y_sub = sub64(y, xs)
    return (where64(d_pos, x_sub, x_add), where64(d_pos, y_add, y_sub))


def _vectoring(x, y, iters, hub: bool):
    flip = is_neg64(x).astype(_I32)
    x = where64(flip == 1, _negate_fx(x, hub), x)
    y = where64(flip == 1, _negate_fx(y, hub), y)

    def body(i, carry):
        xh, xl, yh, yl, sh, sl = carry
        cx, cy, sig = (xh, xl), (yh, yl), (sh, sl)
        d_pos = is_neg64(cy)
        nx, ny = _microrotation(cx, cy, i, d_pos, hub)
        bit = (jnp.zeros_like(sh), d_pos.astype(_U32))
        sig = or64(sig, shl64(bit, i))
        return (*nx, *ny, *sig)

    z = jnp.zeros_like(x[0])
    out = jax.lax.fori_loop(0, iters, body, (*x, *y, z, z))
    return ((out[0], out[1]), (out[2], out[3]), flip, (out[4], out[5]))


def _rotation(x, y, flip, sig, iters, hub: bool):
    x = where64(flip == 1, _negate_fx(x, hub), x)
    y = where64(flip == 1, _negate_fx(y, hub), y)

    def body(i, carry):
        xh, xl, yh, yl = carry
        d_pos = (shr64(sig, i)[1] & _U32(1)) == 1
        nx, ny = _microrotation((xh, xl), (yh, yl), i, d_pos, hub)
        return (*nx, *ny)

    out = jax.lax.fori_loop(0, iters, body, (*x, *y))
    return (out[0], out[1]), (out[2], out[3])


def _fixmul(v, comp: int, p: int, round_nearest: bool):
    """Lane mirror of `cordic.fixmul` with a static comp constant."""
    v_lo = and64(v, u64(0xFFFF))
    v_hi = sar64(v, 16)
    comp_p = u64(comp)
    acc = add64(mul64(v_hi, comp_p), sar64(mul64(v_lo, comp_p), 16))
    sh = p - 16
    if round_nearest:
        acc = add64(acc, u64(1 << (sh - 1)))
    return sar64(acc, sh)


def _apply_gain(x, y, iters: int, w: int, hub: bool):
    p = int(min(78 - w, 46))
    # The identical IEEE-double rounding as `cordic.gain_comp_constant`,
    # kept in numpy: the constant must be a Python int inside the kernel.
    inv_gain = np.float64(1.0) / np.float64(cordic.GAIN_TABLE[iters])
    comp = int(np.rint(inv_gain * np.exp2(np.float64(p))))
    rn = not hub
    return _fixmul(x, comp, p, rn), _fixmul(y, comp, p, rn)


# -- the unit -----------------------------------------------------------------

class LaneUnit:
    """Lane-pair mirror of `repro.core.givens.GivensUnit`.

    All methods operate on *stacked* lane words: int32 arrays with a
    trailing axis of size 2 holding the (hi, lo) halves of each packed
    int64 word.  The rotation state is ``(flip, sig)`` with ``flip`` an
    int32 0/1 array and ``sig`` a stacked lane word (the sigma bitmask
    may need up to iters <= 48 bits).  Bit-identical to `GivensUnit` on
    the int64 packing of the same words; static ``N`` / ``iters`` only.
    """

    def __init__(self, config):
        config.validate()
        self.cfg = config

    def vector(self, xp, yp):
        cfg = self.cfg
        N = cfg.n
        iters = cfg.resolved_iters()
        x, y = lanes_unstack(xp), lanes_unstack(yp)
        xf, yf, m_exp = _input_convert(x, y, cfg, N)
        xr, yr, flip, sig = _vectoring(xf, yf, iters, cfg.hub)
        xr, yr = _apply_gain(xr, yr, iters, N + 2, cfg.hub)
        return (lanes_stack(_output_convert(xr, m_exp, cfg, N)),
                lanes_stack(_output_convert(yr, m_exp, cfg, N)),
                (flip, lanes_stack(sig)))

    def rotate(self, xp, yp, state):
        cfg = self.cfg
        N = cfg.n
        iters = cfg.resolved_iters()
        flip, sig = state
        x, y = lanes_unstack(xp), lanes_unstack(yp)
        xf, yf, m_exp = _input_convert(x, y, cfg, N)
        xr, yr = _rotation(xf, yf, flip, lanes_unstack(sig), iters, cfg.hub)
        xr, yr = _apply_gain(xr, yr, iters, N + 2, cfg.hub)
        return (lanes_stack(_output_convert(xr, m_exp, cfg, N)),
                lanes_stack(_output_convert(yr, m_exp, cfg, N)))

    def rotate_rows(self, row_x, row_y):
        """Rotate two stacked-lane rows (..., e, 2); vectoring on element 0."""
        rx0, ry0, (flip, sig) = self.vector(row_x[..., 0, :],
                                            row_y[..., 0, :])
        rx, ry = self.rotate(row_x[..., 1:, :], row_y[..., 1:, :],
                             (flip[..., None], sig[..., None, :]))
        return (jnp.concatenate([rx0[..., None, :], rx], axis=-2),
                jnp.concatenate([ry0[..., None, :], ry], axis=-2))
