"""Jitted public wrappers over the Pallas CORDIC Givens kernels.

`givens_rotate_rows_fixed` is the kernel-level analogue of
`GivensUnit.rotate_rows`: vectoring on the leading element pair of every
row-pair, rotation of all remaining elements with the broadcast sigma words.
Padding to the (8, 128) int32 tile is handled here; callers pass any (B, L).

On CPU (this container) the kernels run in interpret mode; on TPU they
compile to Mosaic.  `interpret=None` auto-selects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import cordic_givens as k

__all__ = ["vectoring_fixed", "rotation_fixed", "givens_rotate_rows_fixed"]


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def vectoring_fixed(x, y, *, iters=24, hub=False, interpret=None):
    """(B,) int32 leading pairs -> (xr, yr, flip, sigma), each (B,)."""
    interpret = _auto_interpret(interpret)
    B = x.shape[0]
    xp = _pad_to(x.astype(jnp.int32)[:, None], k.TILE_B, 0)
    yp = _pad_to(y.astype(jnp.int32)[:, None], k.TILE_B, 0)
    xr, yr, flip, sig = k.vectoring_call(xp, yp, iters=iters, hub=hub,
                                         interpret=interpret)
    return xr[:B, 0], yr[:B, 0], flip[:B, 0], sig[:B, 0]


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def rotation_fixed(x, y, flip, sigma, *, iters=24, hub=False, interpret=None):
    """(B, L) int32 rows + (B,) control words -> rotated (B, L) pair."""
    interpret = _auto_interpret(interpret)
    B, L = x.shape
    xp = _pad_to(_pad_to(x.astype(jnp.int32), k.TILE_B, 0), k.TILE_L, 1)
    yp = _pad_to(_pad_to(y.astype(jnp.int32), k.TILE_B, 0), k.TILE_L, 1)
    fp = _pad_to(flip.astype(jnp.int32)[:, None], k.TILE_B, 0)
    sp = _pad_to(sigma.astype(jnp.int32)[:, None], k.TILE_B, 0)
    xr, yr = k.rotation_call(xp, yp, fp, sp, iters=iters, hub=hub,
                             interpret=interpret)
    return xr[:B, :L], yr[:B, :L]


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def givens_rotate_rows_fixed(x_rows, y_rows, *, iters=24, hub=False,
                             interpret=None):
    """Full fixed-point Givens rotation of B row pairs of length L.

    x_rows, y_rows: (B, L) int32 block-FP significands (element 0 is the
    leading pair).  Returns rotated rows; y[:, 0] is the zeroed entry's
    residual (callers typically force it to 0 structurally).
    """
    interpret = _auto_interpret(interpret)
    xl, yl, flip, sig = vectoring_fixed(x_rows[:, 0], y_rows[:, 0],
                                        iters=iters, hub=hub,
                                        interpret=interpret)
    xr, yr = rotation_fixed(x_rows[:, 1:], y_rows[:, 1:], flip, sig,
                            iters=iters, hub=hub, interpret=interpret)
    return (jnp.concatenate([xl[:, None], xr], axis=1),
            jnp.concatenate([yl[:, None], yr], axis=1))


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def givens_rotate_rows_fused(x_rows, y_rows, *, iters=24, hub=False,
                             interpret=None):
    """Fused single-pass variant (§Perf): rows stay in VMEM across the
    vectoring and rotation phases — one HBM read + one write per element.
    Bit-identical to `givens_rotate_rows_fixed` (the rotation of the leading
    pair by its own sigma IS the vectoring result)."""
    interpret = _auto_interpret(interpret)
    B, L = x_rows.shape
    xp = _pad_to(x_rows.astype(jnp.int32), k.TILE_B, 0)
    yp = _pad_to(y_rows.astype(jnp.int32), k.TILE_B, 0)
    xr, yr = k.fused_call(xp, yp, iters=iters, hub=hub, interpret=interpret)
    return xr[:B], yr[:B]
