"""Jitted public wrappers over the Pallas CORDIC Givens kernels.

`givens_rotate_rows_fixed` is the kernel-level analogue of
`GivensUnit.rotate_rows`: vectoring on the leading element pair of every
row-pair, rotation of all remaining elements with the broadcast sigma words.
Padding to the (8, 128) int32 tile is handled here; callers pass any (B, L).

On CPU (this container) the kernels run in interpret mode; on TPU they
compile to Mosaic.  `interpret=None` auto-selects (`auto_interpret`).
When the packed-word QR wrappers target a compiled backend they
automatically reroute onto the dual-int32 lane kernels
(`qrd_blocked.qr_packed_lanes_call`) — Mosaic/Triton reject int64 lanes;
the split is bit-exact (`lanes=None`/`True`/`False` overrides).

``tile_b=None`` resolves to the fixed `TILE_B` here; shape-tuned values
come from `repro.kernels.autotune` via `repro.qrd.engine` (DESIGN.md §11).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import cordic_givens as k
from . import qrd_blocked as qb

__all__ = ["vectoring_fixed", "rotation_fixed", "givens_rotate_rows_fixed",
           "givens_rotate_rows_fused", "qr_packed", "qr_packed_wavefront",
           "qr_packed_complex", "qr_packed_complex_wavefront",
           "givens_block_apply", "givens_block_apply_wavefront",
           "qr_packed_panel", "givens_block_apply_panel", "panel_steps",
           "rls_block_steps", "auto_interpret", "compiled_backend_available"]

#: Memoization bound for host-side schedule/table caches.  The tiled layer
#: (DESIGN.md §14) derives schedules *per tile* (tile_m ≤ 128 rows), never
#: per full matrix — a tall-skinny m ~ 10k schedule would be a multi-MB
#: host table — so a small bounded LRU holds every shape a process
#: realistically touches while capping worst-case host memory.
SCHEDULE_CACHE_SIZE = 128


@functools.lru_cache(maxsize=SCHEDULE_CACHE_SIZE)
def rls_block_steps(n: int, block: int):
    """Annihilation schedule for a QRD-RLS block update (memoized).

    For a working tile ``[√λ-weighted R | z]`` of ``n`` state rows with
    ``block`` snapshot rows stacked underneath (rows ``n .. n+block-1``),
    column ``k`` of every snapshot row is annihilated against the
    diagonal pivot row ``k`` — the blocked-kernel replay of the
    per-snapshot QRD-RLS recursion (`repro.qrd.rls.RLSState.flush` feeds
    this straight into `givens_block_apply`).

    Returns a hashable tuple of ``(pivot_row, target_row, col)`` triples
    (a jit static), cached per ``(n, block)`` like the QRD schedules.
    """
    return tuple((k, n + j, k) for k in range(n) for j in range(block))


def compiled_backend_available() -> bool:
    """True when a Pallas compiler (Mosaic/Triton) backs the default device.

    The device-detection guard of DESIGN.md §11: CPU has no Pallas
    compiler, so CI on this container stays on the interpret path while
    TPU/GPU hosts run the same code with ``interpret=False``.
    """
    return jax.default_backend() in ("tpu", "gpu")


def auto_interpret(interpret=None) -> bool:
    """Resolve ``interpret=None`` to the device default (interpret on CPU)."""
    if interpret is None:
        return not compiled_backend_available()
    return interpret


_auto_interpret = auto_interpret


def _resolve_tile_b(tile_b):
    """``tile_b=None`` -> the fixed default; tuned values come from callers."""
    return qb.TILE_B if tile_b is None else tile_b


def _resolve_layout(table_layout):
    return "split" if table_layout is None else table_layout


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def vectoring_fixed(x, y, *, iters=24, hub=False, interpret=None):
    """Vectoring kernel: compute per-row CORDIC control words.

    Parameters
    ----------
    x, y : (B,) int32
        Leading-element pairs as block-FP significands (w = iters+2 ≤ 30
        bits; callers align exponents beforehand).
    iters, hub : static CORDIC depth / HUB arithmetic flag.

    Returns
    -------
    (xr, yr, flip, sigma) : four (B,) int32 arrays
        Gain-compensated rotated pair (``yr`` ≈ 0), the coarse π-flip bit,
        and the packed σ direction bits (bit i == 1 ⇔ d_i = +1).
    """
    interpret = _auto_interpret(interpret)
    B = x.shape[0]
    xp = _pad_to(x.astype(jnp.int32)[:, None], k.TILE_B, 0)
    yp = _pad_to(y.astype(jnp.int32)[:, None], k.TILE_B, 0)
    xr, yr, flip, sig = k.vectoring_call(xp, yp, iters=iters, hub=hub,
                                         interpret=interpret)
    return xr[:B, 0], yr[:B, 0], flip[:B, 0], sig[:B, 0]


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def rotation_fixed(x, y, flip, sigma, *, iters=24, hub=False, interpret=None):
    """Rotation kernel: replay stored control words across full rows.

    Parameters
    ----------
    x, y : (B, L) int32
        Row elements as block-FP significands.
    flip, sigma : (B,) int32
        Per-row control words from `vectoring_fixed`; broadcast across the
        lane axis inside the kernel.

    Returns
    -------
    (xr, yr) : (B, L) int32 gain-compensated rotated rows.
    """
    interpret = _auto_interpret(interpret)
    B, L = x.shape
    xp = _pad_to(_pad_to(x.astype(jnp.int32), k.TILE_B, 0), k.TILE_L, 1)
    yp = _pad_to(_pad_to(y.astype(jnp.int32), k.TILE_B, 0), k.TILE_L, 1)
    fp = _pad_to(flip.astype(jnp.int32)[:, None], k.TILE_B, 0)
    sp = _pad_to(sigma.astype(jnp.int32)[:, None], k.TILE_B, 0)
    xr, yr = k.rotation_call(xp, yp, fp, sp, iters=iters, hub=hub,
                             interpret=interpret)
    return xr[:B, :L], yr[:B, :L]


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def givens_rotate_rows_fixed(x_rows, y_rows, *, iters=24, hub=False,
                             interpret=None):
    """Full fixed-point Givens rotation of B row pairs of length L.

    x_rows, y_rows: (B, L) int32 block-FP significands (element 0 is the
    leading pair).  Returns rotated rows; y[:, 0] is the zeroed entry's
    residual (callers typically force it to 0 structurally).
    """
    interpret = _auto_interpret(interpret)
    xl, yl, flip, sig = vectoring_fixed(x_rows[:, 0], y_rows[:, 0],
                                        iters=iters, hub=hub,
                                        interpret=interpret)
    xr, yr = rotation_fixed(x_rows[:, 1:], y_rows[:, 1:], flip, sig,
                            iters=iters, hub=hub, interpret=interpret)
    return (jnp.concatenate([xl[:, None], xr], axis=1),
            jnp.concatenate([yl[:, None], yr], axis=1))


@functools.partial(jax.jit, static_argnames=("iters", "hub", "interpret"))
def givens_rotate_rows_fused(x_rows, y_rows, *, iters=24, hub=False,
                             interpret=None):
    """Fused single-pass variant (§Perf): rows stay in VMEM across the
    vectoring and rotation phases — one HBM read + one write per element.
    Bit-identical to `givens_rotate_rows_fixed` (the rotation of the leading
    pair by its own sigma IS the vectoring result)."""
    interpret = _auto_interpret(interpret)
    B, L = x_rows.shape
    xp = _pad_to(x_rows.astype(jnp.int32), k.TILE_B, 0)
    yp = _pad_to(y_rows.astype(jnp.int32), k.TILE_B, 0)
    xr, yr = k.fused_call(xp, yp, iters=iters, hub=hub, interpret=interpret)
    return xr[:B], yr[:B]


# ---------------------------------------------------------------------------
# Blocked QR wrappers (kernel-resident triangularization, DESIGN.md §5)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("cfg", "steps", "interpret", "tile_b",
                                    "lanes"))
def qr_packed(P, *, cfg, steps, interpret=None, tile_b=None, lanes=None):
    """Kernel-resident blocked QR over packed FP words (bit-exact path).

    Parameters
    ----------
    P : (..., m, e) int64
        Packed FP words (see `repro.core.formats`) of the augmented working
        matrices; any leading batch shape.
    cfg : GivensConfig
        Static unit configuration — hashable, used as a jit static.
    steps : tuple[(int, int, int), ...]
        Static `(pivot_row, target_row, col)` rotation schedule.
    lanes : bool, optional
        Carry the words as dual int32 lanes (`qr_packed_lanes_call`)
        instead of int64 — required for compiled execution, bit-identical
        by construction.  ``None`` auto-selects: lanes whenever the kernel
        compiles (``interpret=False``).

    Returns
    -------
    (..., m, e) int64 — triangularized packed words, bit-identical to
    running `GivensUnit.rotate_rows` step by step (`qr_cordic`).
    """
    interpret = _auto_interpret(interpret)
    lanes = (not interpret) if lanes is None else lanes
    tile_b = _resolve_tile_b(tile_b)
    batch = P.shape[:-2]
    m, e = P.shape[-2:]
    Pf = P.astype(jnp.int64).reshape((-1,) + (m, e))
    if lanes:
        out = k.lanes_to_packed(qb.qr_packed_lanes_call(
            k.packed_to_lanes(Pf), cfg=cfg, steps=steps,
            interpret=interpret, tile_b=tile_b))
    else:
        out = qb.qr_packed_call(Pf, cfg=cfg, steps=steps,
                                interpret=interpret, tile_b=tile_b)
    return out.reshape(batch + (m, e))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "steps", "interpret", "tile_b"))
def qr_packed_complex(P, *, cfg, steps, interpret=None, tile_b=None):
    """Kernel-resident blocked complex QR over packed (re, im) lane pairs.

    The complex counterpart of `qr_packed` (DESIGN.md §10): the operand
    carries a trailing axis of size 2 holding the packed real and
    imaginary lanes of each element, and every schedule step runs the
    three-rotation decomposition in-kernel.

    Parameters
    ----------
    P : (..., m, e, 2) int64
        Packed FP words of the augmented complex working matrices; any
        leading batch shape.
    cfg : GivensConfig
        Static unit configuration.
    steps : tuple[(int, int, int), ...]
        Static `(pivot_row, target_row, col)` rotation schedule.

    Returns
    -------
    (..., m, e, 2) int64 — triangularized packed words, bit-identical to
    running `GivensUnit.rotate_rows_complex` step by step
    (`qr_cordic_complex`).
    """
    interpret = _auto_interpret(interpret)
    tile_b = _resolve_tile_b(tile_b)
    batch = P.shape[:-3]
    m, e, _ = P.shape[-3:]
    Pf = P.astype(jnp.int64).reshape((-1,) + (m, e, 2))
    out = qb.qr_packed_complex_call(Pf, cfg=cfg, steps=steps,
                                    interpret=interpret, tile_b=tile_b)
    return out.reshape(batch + (m, e, 2))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "stages", "interpret", "tile_b",
                                    "table_layout"))
def qr_packed_complex_wavefront(P, *, cfg, stages, interpret=None,
                                tile_b=None, table_layout=None):
    """Wavefront blocked complex QR over packed (re, im) lane pairs.

    The stage-parallel counterpart of `qr_packed_complex`: the Sameh–Kuck
    stage index tables of `qr_packed_wavefront` drive the scan, with the
    re/im lanes as an extra trailing axis and the per-pair column masks
    unchanged (DESIGN.md §8, §10).  Bit-identical to `qr_packed_complex`
    on the flattened stage schedule.

    Parameters
    ----------
    P : (..., m, e, 2) int64
        Packed FP words of the augmented complex working matrices.
    cfg : GivensConfig
        Static unit configuration.
    stages : tuple[tuple[(pivot, target, col), ...], ...]
        Static stage schedule (`sameh_kuck_schedule(m, n)`).

    Returns
    -------
    (..., m, e, 2) int64 — triangularized packed words.
    """
    interpret = _auto_interpret(interpret)
    tile_b = _resolve_tile_b(tile_b)
    table_layout = _resolve_layout(table_layout)
    batch = P.shape[:-3]
    m, e, _ = P.shape[-3:]
    piv, tgt, col = _stage_tables(stages, m)
    Pf = P.astype(jnp.int64).reshape((-1,) + (m, e, 2))
    out = qb.qr_packed_complex_wavefront_call(Pf, piv, tgt, col, cfg=cfg,
                                              interpret=interpret,
                                              tile_b=tile_b,
                                              table_layout=table_layout)
    return out.reshape(batch + (m, e, 2))


@functools.lru_cache(maxsize=SCHEDULE_CACHE_SIZE)
def _stage_tables(stages, m):
    """Stage index tables for the wavefront kernels (memoized).

    stages : tuple[tuple[(pivot, target, col), ...], ...]
        One inner tuple per Sameh–Kuck stage (`sameh_kuck_schedule`).
    m : int
        Row count of the working tile; padded pairs carry the out-of-range
        row index ``m`` so their one-hot row selectors are all-zero — they
        gather zero rows and scatter nothing (`qrd_blocked._stage_masks`).

    Returns three (S, Pmax) int32 numpy arrays: pivot rows, target rows,
    leading columns, one row per stage.  (numpy, not jnp: the memoized
    tables are staged as fresh constants by each trace — caching device
    arrays here would leak tracers across jit calls.)
    """
    S = len(stages)
    Pmax = max(len(st) for st in stages)
    piv = np.full((S, Pmax), m, np.int32)
    tgt = np.full((S, Pmax), m, np.int32)
    col = np.zeros((S, Pmax), np.int32)
    for s, st in enumerate(stages):
        rows = [r for (kk, jj, _) in st for r in (kk, jj)]
        if len(rows) != len(set(rows)):  # racy scatter otherwise
            raise ValueError(f"stage {s} rotations touch overlapping rows")
        if not all(0 <= r < m for r in rows):  # would alias the padding
            raise ValueError(f"stage {s} row index out of range for m={m}")
        for p, (kk, jj, cc) in enumerate(st):
            piv[s, p], tgt[s, p], col[s, p] = kk, jj, cc
    piv.setflags(write=False)
    tgt.setflags(write=False)
    col.setflags(write=False)
    return piv, tgt, col


@functools.partial(jax.jit,
                   static_argnames=("cfg", "stages", "interpret", "tile_b",
                                    "lanes", "table_layout"))
def qr_packed_wavefront(P, *, cfg, stages, interpret=None, tile_b=None,
                        lanes=None, table_layout=None):
    """Wavefront blocked QR over packed FP words (bit-exact path).

    The stage-parallel counterpart of `qr_packed`: all rotations of each
    Sameh–Kuck stage run in one shot along a pair axis, collapsing the
    sequential depth from ``steps`` dependent rotations to ``len(stages)``
    scan iterations (DESIGN.md §8).  Bit-identical to `qr_packed` on the
    flattened stage schedule.

    Parameters
    ----------
    P : (..., m, e) int64
        Packed FP words of the augmented working matrices.
    cfg : GivensConfig
        Static unit configuration.
    stages : tuple[tuple[(pivot, target, col), ...], ...]
        Static stage schedule (`sameh_kuck_schedule(m, n)`); every inner
        tuple's row pairs must be disjoint.
    lanes : bool, optional
        Dual-int32 lane datapath, as in `qr_packed` (None auto-selects).
    table_layout : 'split' | 'stacked', optional
        Stage-table transfer layout (autotuner dimension; None = 'split').

    Returns
    -------
    (..., m, e) int64 — triangularized packed words.
    """
    interpret = _auto_interpret(interpret)
    lanes = (not interpret) if lanes is None else lanes
    tile_b = _resolve_tile_b(tile_b)
    table_layout = _resolve_layout(table_layout)
    batch = P.shape[:-2]
    m, e = P.shape[-2:]
    piv, tgt, col = _stage_tables(stages, m)
    Pf = P.astype(jnp.int64).reshape((-1,) + (m, e))
    if lanes:
        out = k.lanes_to_packed(qb.qr_packed_lanes_wavefront_call(
            k.packed_to_lanes(Pf), piv, tgt, col, cfg=cfg,
            interpret=interpret, tile_b=tile_b, table_layout=table_layout))
    else:
        out = qb.qr_packed_wavefront_call(Pf, piv, tgt, col, cfg=cfg,
                                          interpret=interpret, tile_b=tile_b,
                                          table_layout=table_layout)
    return out.reshape(batch + (m, e))


def _blockfp_encode(Wf, frac):
    """float (B, m, e) -> int32 significands + per-(matrix, column) exponent.

    One shared exponent per (matrix, column): amax in [2^(ex-1), 2^ex).
    Valid under any Givens schedule — rotations only combine same-column
    elements of two rows, so per-column scales are invariant.
    """
    amax = jnp.max(jnp.abs(Wf), axis=-2, keepdims=True)
    _, ex = jnp.frexp(jnp.where(amax > 0, amax, 1.0))
    ex = jnp.where(amax > 0, ex, 0)
    # float64 exponent arithmetic: int32 `frac - ex` would promote exp2 to
    # float32, which overflows/underflows for |amax| beyond ~2^±103
    X = jnp.rint(Wf * jnp.exp2(jnp.asarray(frac - ex, jnp.float64))
                 ).astype(jnp.int32)
    return X, ex


def _blockfp_decode(X, ex, frac):
    return X.astype(jnp.float64) * jnp.exp2(ex.astype(jnp.float64) - frac)


@functools.partial(jax.jit, static_argnames=("steps", "iters", "hub", "frac",
                                             "interpret", "tile_b"))
def givens_block_apply(W, steps, *, iters=24, hub=True, frac=24,
                       interpret=None, tile_b=None):
    """Apply a Givens schedule to float matrices on the int32 blocked kernel.

    The fast (TPU-shaped) path: ``W`` is quantized **once** to int32
    block-fixed-point significands with one shared exponent per
    (matrix, column) — valid because Givens rotations only combine
    same-column elements of two rows, so per-column scales are invariant
    under the whole schedule.  All rotation steps then run fixed-point
    inside one `pallas_call`, and a single FP decode recovers floats.

    Parameters
    ----------
    W : (..., m, e) float
        Working matrices (e.g. ``[A | I]`` for a full QRD, or ``[R | z]``
        stacked over new rows for an RLS block update).
    steps : tuple[(int, int, int), ...]
        Static `(pivot_row, target_row, col)` schedule.
    iters, hub : static CORDIC depth / HUB arithmetic flag.
    frac : int
        Fraction bits F of the significands.  F = 24 keeps every
        intermediate (2 CORDIC growth bits + √m column-norm growth)
        inside int32 for m up to ~64.

    Returns
    -------
    (..., m, e) float64 — the rotated working matrices.
    """
    interpret = _auto_interpret(interpret)
    tile_b = _resolve_tile_b(tile_b)
    W = jnp.asarray(W, jnp.float64)
    batch = W.shape[:-2]
    m, e = W.shape[-2:]
    X, ex = _blockfp_encode(W.reshape((-1, m, e)), frac)
    out = qb.qr_blockfp_call(X, iters=iters, hub=hub, steps=steps,
                             interpret=interpret, tile_b=tile_b)
    return _blockfp_decode(out, ex, frac).reshape(batch + (m, e))


@functools.partial(jax.jit, static_argnames=("stages", "iters", "hub", "frac",
                                             "interpret", "tile_b",
                                             "table_layout"))
def givens_block_apply_wavefront(W, stages, *, iters=24, hub=True, frac=24,
                                 interpret=None, tile_b=None,
                                 table_layout=None):
    """Wavefront variant of `givens_block_apply` (the stage-parallel path).

    Identical quantize-once / decode-once block-FP dataflow, but the step
    schedule is replaced by Sameh–Kuck stage index tables: one scan
    iteration rotates every disjoint row pair of a stage along a
    (TILE_B, Pmax, e) pair axis (DESIGN.md §8).  Bit-identical to
    `givens_block_apply` on the flattened stage schedule.

    Parameters
    ----------
    W : (..., m, e) float
        Working matrices.
    stages : tuple[tuple[(pivot, target, col), ...], ...]
        Static stage schedule; every inner tuple's row pairs must be
        disjoint (`sameh_kuck_schedule`).
    iters, hub, frac : as `givens_block_apply`.
    table_layout : 'split' | 'stacked', optional
        Stage-table transfer layout (autotuner dimension; None = 'split').

    Returns
    -------
    (..., m, e) float64 — the rotated working matrices.
    """
    interpret = _auto_interpret(interpret)
    tile_b = _resolve_tile_b(tile_b)
    table_layout = _resolve_layout(table_layout)
    W = jnp.asarray(W, jnp.float64)
    batch = W.shape[:-2]
    m, e = W.shape[-2:]
    piv, tgt, col = _stage_tables(stages, m)
    X, ex = _blockfp_encode(W.reshape((-1, m, e)), frac)
    out = qb.qr_blockfp_wavefront_call(X, piv, tgt, col, iters=iters,
                                       hub=hub, interpret=interpret,
                                       tile_b=tile_b,
                                       table_layout=table_layout)
    return _blockfp_decode(out, ex, frac).reshape(batch + (m, e))


# ---------------------------------------------------------------------------
# Tiled panel QR drivers (DESIGN.md §14): panel-at-a-time triangularization
# with exported control words replayed over trailing panels.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=SCHEDULE_CACHE_SIZE)
def panel_steps(mr: int, ncols: int):
    """Panel-local column-major step tables (memoized, bounded).

    The column-major schedule restricted to one panel: ``mr`` resident
    rows (global rows ``c0..m-1``, panel-relative), annihilating local
    columns ``0..ncols-1`` in `givens_schedule` order — so concatenating
    every panel's steps (offset by its ``c0``) reproduces the flat
    column-major schedule exactly, which is what makes the panel path
    bit-identical to the flat kernels.

    Returns three read-only (S,) int32 numpy arrays: pivot rows, target
    rows, columns (all panel-local).
    """
    trips = [(c, r, c) for c in range(min(mr - 1, ncols))
             for r in range(c + 1, mr)]
    piv = np.asarray([t[0] for t in trips], np.int32)
    tgt = np.asarray([t[1] for t in trips], np.int32)
    col = np.asarray([t[2] for t in trips], np.int32)
    for a in (piv, tgt, col):
        a.setflags(write=False)
    return piv, tgt, col


def _panel_sweep(P, n_cols, pw, factor_fn, apply_fn):
    """Shared panel-driver loop over a flattened (B, m, e) working batch.

    For each panel (static Python loop — one factor + one apply trace per
    panel): factor the resident (mr, nc) tile while exporting its control
    words, then replay them over the trailing region, chunked to G
    panel-width tiles on the apply kernel's grid.  Rows above ``c0`` are
    final after their panel (column-major order) and never re-enter a
    kernel.  The last trailing chunk is zero-padded to width ``pw`` —
    rotations are columnwise, so pad columns never feed back into real
    ones and are sliced off after the call.
    """
    m, e = P.shape[-2:]
    for c0 in range(0, min(n_cols, m - 1), pw):
        nc = min(pw, n_cols - c0)
        mr = m - c0
        piv, tgt, col = panel_steps(mr, nc)
        if piv.shape[0] == 0:
            continue
        out, flip, sig = factor_fn(P[:, c0:, c0:c0 + nc], piv, tgt, col)
        P = P.at[:, c0:, c0:c0 + nc].set(out)
        tw = e - (c0 + nc)
        if tw > 0:
            T = _pad_to(P[:, c0:, c0 + nc:], pw, 2)
            G = T.shape[-1] // pw
            T = T.reshape(-1, mr, G, pw).transpose(0, 2, 1, 3)
            T = apply_fn(T, piv, tgt, flip, sig)
            T = T.transpose(0, 2, 1, 3).reshape(-1, mr, G * pw)[:, :, :tw]
            P = P.at[:, c0:, c0 + nc:].set(T)
    return P


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_cols", "panel_n", "interpret",
                                    "tile_b"))
def qr_packed_panel(P, *, cfg, n_cols, panel_n=8, interpret=None,
                    tile_b=None):
    """Tiled panel QR over packed FP words (bit-exact path).

    The scaling counterpart of `qr_packed`: instead of unrolling the full
    schedule into one straight-line kernel body (which stops tracing
    beyond toy m), the triangularization proceeds panel by panel —
    `qrd_blocked.panel_factor_packed_call` scans the panel's steps with a
    resident (tile_b, mr, panel_n) tile and exports the (flip, sigma)
    control words, `qrd_blocked.panel_apply_packed_call` replays them
    over the trailing panels on a (batch, panel) grid.  Column-major
    order is preserved exactly, so the result is **bit-identical** to
    `qr_packed` on `givens_schedule(m, n)` (IEEE and HUB).

    Parameters
    ----------
    P : (..., m, e) int64
        Packed FP words of the augmented working matrices.
    cfg : GivensConfig
        Static unit configuration.  int64 words — interpret mode only,
        like `qr_packed`; the compiled tiled path is the block-FP driver
        (`givens_block_apply_panel`).
    n_cols : int
        Number of leading columns to annihilate (the matrix's n; the
        remaining ``e - n`` columns — identity columns for Q — only ever
        ride the trailing updates).
    panel_n : int
        Panel width (autotuner dimension, DESIGN.md §14).

    Returns
    -------
    (..., m, e) int64 — triangularized packed words.
    """
    interpret = _auto_interpret(interpret)
    tile_b = _resolve_tile_b(tile_b)
    batch = P.shape[:-2]
    m, e = P.shape[-2:]
    Pf = P.astype(jnp.int64).reshape((-1, m, e))

    def factor(tile, piv, tgt, col):
        return qb.panel_factor_packed_call(tile, piv, tgt, col, cfg=cfg,
                                           interpret=interpret,
                                           tile_b=tile_b)

    def apply_(T, piv, tgt, flip, sig):
        return qb.panel_apply_packed_call(T, piv, tgt, flip, sig, cfg=cfg,
                                          interpret=interpret, tile_b=tile_b)

    Pf = _panel_sweep(Pf, n_cols, panel_n, factor, apply_)
    return Pf.reshape(batch + (m, e))


@functools.partial(jax.jit,
                   static_argnames=("n_cols", "iters", "hub", "frac",
                                    "panel_n", "interpret", "tile_b"))
def givens_block_apply_panel(W, *, n_cols, iters=24, hub=True, frac=24,
                             panel_n=8, interpret=None, tile_b=None):
    """Tiled panel QR on the int32 block-FP datapath (the fast path).

    `givens_block_apply` at production shapes: quantize **once** (the
    per-(matrix, column) shared exponents are invariant under the whole
    rotation set, so the panel/trailing split needs no re-quantization),
    sweep the panels with `qrd_blocked.panel_factor_blockfp_call` /
    `panel_apply_blockfp_call`, decode once.  Bit-identical to
    `givens_block_apply` on `givens_schedule(m, n)` — same encode, same
    step order, same int32 recurrence.

    Capacity: frac + 2 CORDIC growth bits + log2(√m) column-norm growth
    must stay inside signed int32 — frac=24 supports m ≤ 128 (29.5 bits;
    the `blockfp_pallas` backend advertises ``max_shape=(128, 128)``).

    Parameters as `givens_block_apply` plus ``n_cols`` / ``panel_n`` (see
    `qr_packed_panel`).

    Returns
    -------
    (..., m, e) float64 — the triangularized working matrices.
    """
    interpret = _auto_interpret(interpret)
    tile_b = _resolve_tile_b(tile_b)
    W = jnp.asarray(W, jnp.float64)
    batch = W.shape[:-2]
    m, e = W.shape[-2:]
    X, ex = _blockfp_encode(W.reshape((-1, m, e)), frac)

    def factor(tile, piv, tgt, col):
        return qb.panel_factor_blockfp_call(tile, piv, tgt, col, iters=iters,
                                            hub=hub, interpret=interpret,
                                            tile_b=tile_b)

    def apply_(T, piv, tgt, flip, sig):
        return qb.panel_apply_blockfp_call(T, piv, tgt, flip, sig,
                                           iters=iters, hub=hub,
                                           interpret=interpret, tile_b=tile_b)

    X = _panel_sweep(X, n_cols, panel_n, factor, apply_)
    return _blockfp_decode(X, ex, frac).reshape(batch + (m, e))
