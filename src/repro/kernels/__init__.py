# Pallas TPU kernels for the paper's CORDIC Givens rotator:
#   cordic_givens.py  pl.pallas_call kernels (vectoring / rotation / fused)
#   qrd_blocked.py    kernel-resident blocked QR (packed bit-exact + int32
#                     block-fixed-point datapaths)
#   ops.py            jitted public wrappers (padding, interpret auto-select)
#   ref.py            pure-jnp oracles (tests assert exact integer equality)
from . import ops, qrd_blocked, ref

__all__ = ["ops", "qrd_blocked", "ref"]
