# Pallas TPU kernels for the paper's CORDIC Givens rotator:
#   cordic_givens.py  pl.pallas_call kernels (vectoring / rotation / fused)
#   ops.py            jitted public wrappers (padding, interpret auto-select)
#   ref.py            pure-jnp oracles (tests assert exact integer equality)
from . import ops, ref

__all__ = ["ops", "ref"]
