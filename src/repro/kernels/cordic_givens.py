"""Pallas TPU kernels for the fixed-point CORDIC Givens rotator.

TPU adaptation of the paper's pipeline (DESIGN.md §2): the FPGA's
one-element-per-cycle pipeline becomes lane-parallel integer arithmetic on
the VPU.  Two kernels:

  vectoring kernel : a (TB, 1) tile of leading element pairs; each lane runs
                     the full micro-rotation recurrence and packs its sigma
                     direction bits into one int32 word (+ a flip bit).
                     "Compute the tiny control word once."
  rotation kernel  : a (TB, TL) tile of row elements; the per-row sigma words
                     (one int32 per row, VMEM (TB,1) column) broadcast across
                     the 128-lane axis and the recurrence replays in parallel.
                     "Broadcast it across the wide vector."

Datapath: int32, w = N + 2 bits (N <= 28; N = 26 is the paper's recommended
single-precision config).  The CORDIC gain is compensated in-kernel with a
15x15-bit partial-product multiply (Q30 constant) so every intermediate fits
int32 — the same reasoning as the paper's "compensation in the embedded
multipliers".

Both kernels carry a static `hub` flag switching the add/sub arithmetic to
Half-Unit-Biased semantics (negate-by-inversion + the Fig. 6 carry-in rule).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.cordic import GAIN_TABLE

__all__ = ["vectoring_call", "rotation_call", "fused_call",
           "fused_rotate_block", "fused_rotate_pairs", "fused_rotate_ctrl",
           "fused_replay", "comp_q30",
           "packed_to_lanes", "lanes_to_packed", "TILE_B", "TILE_L"]

TILE_B = 8     # sublane tile (int32 native tile is (8, 128))
TILE_L = 128   # lane tile


def packed_to_lanes(p):
    """Packed int64 FP words -> stacked (hi, lo) int32 lane words (..., 2).

    The hi/lo split that makes the packed-word kernels compilable: Mosaic
    and Triton reject 64-bit integer lanes, so the compiled datapath
    (`repro.kernels.packed_lanes`) carries each word as two int32 lanes
    — ``[..., 0] = int32(p >> 32)``, ``[..., 1] = int32(p)``.  Exact
    (two's complement) and inverted by `lanes_to_packed`.
    """
    p = jnp.asarray(p, jnp.int64)
    return jnp.stack([(p >> 32).astype(jnp.int32), p.astype(jnp.int32)],
                     axis=-1)


def lanes_to_packed(L):
    """Stacked (hi, lo) int32 lane words (..., 2) -> packed int64 FP words."""
    hi = L[..., 0].astype(jnp.int64)
    lo = L[..., 1].astype(jnp.int64) & 0xFFFFFFFF
    return (hi << 32) | lo


def comp_q30(iters: int) -> int:
    """Gain compensation constant in Q30: round(2^30 / K(iters))."""
    return int(np.rint(2.0 ** 30 / GAIN_TABLE[iters]))


def _gain_mul_q30(v, comp: int):
    """(v * comp) >> 30 with all partial products inside int32.

    v: w-bit int32 (|v| < 2^29); comp: Q30 constant < 2^30.
    Split both into 15-bit halves; truncating partial shifts lose < 1 LSB.
    """
    c_hi = comp >> 15
    c_lo = comp & 0x7FFF
    v_hi = v >> 15          # arithmetic: keeps the sign
    v_lo = v & 0x7FFF
    return (v_hi * c_hi
            + ((v_hi * c_lo) >> 15)
            + ((v_lo * c_hi) >> 15)
            + ((v_lo * c_lo) >> 30))


def _microrotation(x, y, i: int, d_pos, hub: bool):
    """x' = x - d*(y>>i), y' = y + d*(x>>i); d_pos lanes: d = +1."""
    ys = y >> i
    xs = x >> i
    if hub:
        cy = jnp.int32(1) if i == 0 else (y >> (i - 1)) & 1
        cx = jnp.int32(1) if i == 0 else (x >> (i - 1)) & 1
        x_sub = x + ~ys + (1 - cy)
        x_add = x + ys + cy
        y_add = y + xs + cx
        y_sub = y + ~xs + (1 - cx)
    else:
        x_sub = x - ys
        x_add = x + ys
        y_add = y + xs
        y_sub = y - xs
    return (jnp.where(d_pos, x_sub, x_add),
            jnp.where(d_pos, y_add, y_sub))


def _negate(v, hub: bool):
    return ~v if hub else -v


# ---------------------------------------------------------------------------
# Vectoring kernel
# ---------------------------------------------------------------------------
def _vectoring_kernel(x_ref, y_ref, xo_ref, yo_ref, flip_ref, sig_ref,
                      *, iters: int, hub: bool, comp: int):
    x = x_ref[...]
    y = y_ref[...]
    flip = (x < 0)
    x = jnp.where(flip, _negate(x, hub), x)
    y = jnp.where(flip, _negate(y, hub), y)
    sig = jnp.zeros_like(x)
    for i in range(iters):          # static unroll == the FPGA pipeline depth
        d_pos = y < 0
        x, y = _microrotation(x, y, i, d_pos, hub)
        sig = sig | (d_pos.astype(jnp.int32) << i)
    xo_ref[...] = _gain_mul_q30(x, comp)
    yo_ref[...] = _gain_mul_q30(y, comp)
    flip_ref[...] = flip.astype(jnp.int32)
    sig_ref[...] = sig


def vectoring_call(x, y, *, iters: int, hub: bool, interpret: bool = True):
    """x, y: (B, 1) int32 block-FP significands -> (xr, yr, flip, sigma).

    B must be a multiple of TILE_B (ops.py pads).
    """
    B = x.shape[0]
    assert x.shape == (B, 1) and B % TILE_B == 0 and iters <= 30
    grid = (B // TILE_B,)
    spec = pl.BlockSpec((TILE_B, 1), lambda b: (b, 0))
    out_shape = [jax.ShapeDtypeStruct((B, 1), jnp.int32)] * 4
    kernel = functools.partial(_vectoring_kernel, iters=iters, hub=hub,
                               comp=comp_q30(iters))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec, spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(x, y)


# ---------------------------------------------------------------------------
# Rotation kernel
# ---------------------------------------------------------------------------
def _rotation_kernel(flip_ref, sig_ref, x_ref, y_ref, xo_ref, yo_ref,
                     *, iters: int, hub: bool, comp: int):
    x = x_ref[...]
    y = y_ref[...]
    flip = flip_ref[...] != 0           # (TB, 1) -> broadcasts over lanes
    sig = sig_ref[...]
    x = jnp.where(flip, _negate(x, hub), x)
    y = jnp.where(flip, _negate(y, hub), y)
    for i in range(iters):
        d_pos = ((sig >> i) & 1) == 1   # (TB, 1) control word, lane-broadcast
        x, y = _microrotation(x, y, i, d_pos, hub)
    xo_ref[...] = _gain_mul_q30(x, comp)
    yo_ref[...] = _gain_mul_q30(y, comp)


def rotation_call(x, y, flip, sigma, *, iters: int, hub: bool,
                  interpret: bool = True, tile_l: int = TILE_L):
    """x, y: (B, L) int32; flip, sigma: (B, 1) int32 -> rotated (B, L)."""
    B, L = x.shape
    assert B % TILE_B == 0 and L % tile_l == 0 and iters <= 30
    grid = (B // TILE_B, L // tile_l)
    tile = pl.BlockSpec((TILE_B, tile_l), lambda b, l: (b, l))
    ctrl = pl.BlockSpec((TILE_B, 1), lambda b, l: (b, 0))
    out_shape = [jax.ShapeDtypeStruct((B, L), jnp.int32)] * 2
    kernel = functools.partial(_rotation_kernel, iters=iters, hub=hub,
                               comp=comp_q30(iters))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[ctrl, ctrl, tile, tile],
        out_specs=[tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )(flip, sigma, x, y)


# ---------------------------------------------------------------------------
# Fused kernel (beyond-paper §Perf iteration): vectoring + rotation in one
# pass.  The separate-kernel pipeline writes the rows to HBM between the
# phases; here each (TB, L) row block stays in VMEM — sigma is derived from
# the leading column and replayed over the whole block before a single
# write-back.  HBM traffic per element drops 2x (one read + one write).
# ---------------------------------------------------------------------------
def fused_rotate_block(x, y, *, iters: int, hub: bool, comp: int):
    """Fused Givens step on two resident (TB, L) row blocks.

    Vectoring on the leading column derives the control words (flip +
    sigma), then the whole block — leading column included; its replay by
    its own sigma IS the vectoring result — rotates with the broadcast
    words and is gain-compensated.  Shared by the fused row kernel and the
    blocked QR kernel (`qrd_blocked`).
    """
    xl = x[:, :1]
    yl = y[:, :1]
    flip = xl < 0
    xl = jnp.where(flip, _negate(xl, hub), xl)
    yl = jnp.where(flip, _negate(yl, hub), yl)
    sig = jnp.zeros_like(xl)
    for i in range(iters):
        d_pos = yl < 0
        xl, yl = _microrotation(xl, yl, i, d_pos, hub)
        sig = sig | (d_pos.astype(jnp.int32) << i)
    x = jnp.where(flip, _negate(x, hub), x)
    y = jnp.where(flip, _negate(y, hub), y)
    for i in range(iters):
        d_pos = ((sig >> i) & 1) == 1
        x, y = _microrotation(x, y, i, d_pos, hub)
    return _gain_mul_q30(x, comp), _gain_mul_q30(y, comp)


def fused_rotate_pairs(x, y, lead, *, iters: int, hub: bool, comp: int):
    """Fused Givens step on a whole *pair axis* of resident row blocks.

    The wavefront variant of `fused_rotate_block` (DESIGN.md §8): instead
    of one (TB, L) row pair with its leading element at lane 0, the inputs
    carry a full Sameh–Kuck stage — ``x``/``y`` are (TB, P, e) pivot/target
    rows at *uniform* width e, and ``lead`` is the (P, e) 0/1 one-hot of
    each pair's leading column (the annihilated entry's column).  The
    leading pair is extracted by the one-hot contraction, vectoring derives
    one (flip, sigma) control word per (batch, pair) lane, and the replay
    broadcasts it across the whole e axis — every pair of the stage rotates
    in one shot.

    Column masking is the caller's job: lanes left of the leading column
    are rotated here too (uniform shape keeps the datapath wide) and must
    be restored from the inputs afterwards — they belong to earlier,
    already-annihilated columns, which the sequential path never touches.

    Replaying sigma on the leading column itself reproduces the vectoring
    result bit for bit (same micro-rotation sequence), so each pair's lanes
    at and right of `lead` match `fused_rotate_block` on the ragged slice
    exactly.
    """
    sel = lead[None].astype(x.dtype)                 # (1, P, e) 0/1
    # dtype-pinned sums: default integer accumulation widens to int64
    xl = jnp.sum(x * sel, axis=-1, dtype=x.dtype)    # (TB, P) leading pair
    yl = jnp.sum(y * sel, axis=-1, dtype=y.dtype)
    flip = xl < 0
    xl = jnp.where(flip, _negate(xl, hub), xl)
    yl = jnp.where(flip, _negate(yl, hub), yl)
    sig = jnp.zeros_like(xl)
    for i in range(iters):
        d_pos = yl < 0
        xl, yl = _microrotation(xl, yl, i, d_pos, hub)
        sig = sig | (d_pos.astype(jnp.int32) << i)
    fb = flip[..., None]                             # (TB, P, 1) -> e lanes
    x = jnp.where(fb, _negate(x, hub), x)
    y = jnp.where(fb, _negate(y, hub), y)
    for i in range(iters):
        d_pos = ((sig[..., None] >> i) & 1) == 1
        x, y = _microrotation(x, y, i, d_pos, hub)
    return _gain_mul_q30(x, comp), _gain_mul_q30(y, comp)


def fused_rotate_ctrl(x, y, lead, *, iters: int, hub: bool, comp: int):
    """`fused_rotate_pairs` for one pair, exporting the control words.

    The panel-factorization building block (DESIGN.md §14): identical
    vectoring recurrence and replay as `fused_rotate_block`, but the
    leading pair is selected by the ``lead`` one-hot (the panel kernels
    rotate at uniform panel width, like the wavefront path) and the
    derived ``(flip, sigma)`` words are *returned* so the caller can
    replay the whole rotation set over trailing panels later
    (`fused_replay`) — the paper's compute-once/replay-everywhere
    control-word contract, extended across kernel launches.

    x, y : (TB, pw) int32 pivot/target rows at uniform panel width.
    lead : (1, pw) 0/1 one-hot of the leading (annihilated) column.

    Returns ``(rx, ry, flip, sig)`` — rotated rows plus (TB,) int32
    control words.  Lanes at and right of `lead` match
    `fused_rotate_block` on the ragged slice exactly; left lanes must be
    restored by the caller (wavefront convention).
    """
    sel = lead.astype(x.dtype)                       # (1, pw) 0/1
    xl = jnp.sum(x * sel, axis=-1, dtype=x.dtype)    # (TB,) leading pair
    yl = jnp.sum(y * sel, axis=-1, dtype=y.dtype)
    flip = xl < 0
    xl = jnp.where(flip, _negate(xl, hub), xl)
    yl = jnp.where(flip, _negate(yl, hub), yl)
    sig = jnp.zeros_like(xl)
    for i in range(iters):
        d_pos = yl < 0
        xl, yl = _microrotation(xl, yl, i, d_pos, hub)
        sig = sig | (d_pos.astype(jnp.int32) << i)
    rx, ry = fused_replay(x, y, flip.astype(jnp.int32), sig,
                          iters=iters, hub=hub, comp=comp)
    return rx, ry, flip.astype(jnp.int32), sig


def fused_replay(x, y, flip, sig, *, iters: int, hub: bool, comp: int):
    """Replay exported `(flip, sigma)` control words over two row blocks.

    x, y : (TB, L) int32 rows; flip, sig : (TB,) int32 control words from
    `fused_rotate_ctrl` (flip as 0/1).  Replaying sigma on the pair that
    produced it reproduces the vectoring output bit for bit, and on any
    other column applies the exact same micro-rotation sequence — the
    trailing-panel update is therefore bit-identical to having rotated
    the full-width rows in one shot.
    """
    fb = (flip != 0)[..., None]                      # (TB, 1) -> L lanes
    x = jnp.where(fb, _negate(x, hub), x)
    y = jnp.where(fb, _negate(y, hub), y)
    for i in range(iters):
        d_pos = ((sig[..., None] >> i) & 1) == 1
        x, y = _microrotation(x, y, i, d_pos, hub)
    return _gain_mul_q30(x, comp), _gain_mul_q30(y, comp)


def _fused_kernel(x_ref, y_ref, xo_ref, yo_ref,
                  *, iters: int, hub: bool, comp: int):
    xo_ref[...], yo_ref[...] = fused_rotate_block(
        x_ref[...], y_ref[...], iters=iters, hub=hub, comp=comp)


def fused_call(x, y, *, iters: int, hub: bool, interpret: bool = True):
    """x, y: (B, L) int32 full rows (element 0 = leading pair) -> rotated."""
    B, L = x.shape
    assert B % TILE_B == 0 and iters <= 30
    grid = (B // TILE_B,)
    tile = pl.BlockSpec((TILE_B, L), lambda b: (b, 0))
    out_shape = [jax.ShapeDtypeStruct((B, L), jnp.int32)] * 2
    kernel = functools.partial(_fused_kernel, iters=iters, hub=hub,
                               comp=comp_q30(iters))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[tile, tile],
        out_specs=[tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )(x, y)
