"""Kernel-resident blocked QR: one `pallas_call` per triangularization.

The reference loop (`repro.core.qrd.qr_cordic`) launches one rotation per
schedule step from Python: every step reads the two packed rows from HBM,
runs the unit, and writes them back — 2·steps HBM passes over the working
set, plus per-step dispatch overhead.  The paper's FPGA never does this: the
control word is computed once per row pair and *replayed inside the
pipeline* (DESIGN.md §2, §5).  These kernels restore that property on the
TPU: the whole (batched) m×e working tile is staged into VMEM once, every
schedule step runs on the resident tile, and the result is written back
once.

Two datapaths, one schedule machinery:

`qr_packed_call` — bit-exact packed-word datapath
    The tile holds *packed FP words* (int64, see `repro.core.formats`).
    Each schedule step performs the unit's full per-step dataflow in
    registers — input-convert (block-FP align), CORDIC vectoring on the
    leading pair, sigma-replay rotation across the rows, gain compensation,
    output-convert — by calling the same `GivensUnit` arithmetic as the
    reference loop.  (Q, R) are therefore **bit-identical** to `qr_cordic`
    for any `GivensConfig` (IEEE and HUB).  int64 lanes: runs in interpret
    mode (CPU) today; it is the semantic reference for the fast datapath.

`qr_blockfp_call` — int32 block-fixed-point datapath (the TPU path)
    The tile holds int32 significands quantized once, outside the kernel,
    with one shared exponent per (matrix, column) — Givens rotations only
    ever combine same-column elements of two rows, so per-column block-FP
    scaling is invariant under the whole schedule.  Rows stay fixed-point
    across *all* rotation steps: no per-step FP round-trips at all, a
    single FP decode after the kernel returns.  Arithmetic is the fused
    int32 pipeline of `cordic_givens` (w ≤ 30 bits, Q30 gain compensation),
    so every intermediate fits the VPU's native int32 lanes.

Schedules are static tuples of `(pivot_row, target_row, col)` triples
(column-major `givens_schedule` or the Sameh–Kuck parallel pairing from
`repro.core.qrd`), unrolled at trace time — the kernel body is a straight
line of micro-rotation recurrences, exactly like the FPGA pipeline.

VMEM budget (DESIGN.md §5): one (TILE_B, m, e) tile per operand/result —
int64 packed: 2·8·m·e·8 bytes; int32 block-FP: 2·8·m·e·4 bytes.  A 64×128
augmented tall-skinny tile in block-FP is 8·64·192·4 ≈ 393 KiB ·2, well
inside the ~16 MiB VMEM of a TPU core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.givens import GivensConfig, GivensUnit
from .cordic_givens import TILE_B, comp_q30, fused_rotate_block

__all__ = ["qr_packed_call", "qr_blockfp_call", "TILE_B"]


# ---------------------------------------------------------------------------
# Bit-exact packed-word kernel
# ---------------------------------------------------------------------------
def _qr_packed_kernel(p_ref, o_ref, *, cfg: GivensConfig, steps):
    """Triangularize the resident (TB, m, e) tile of packed FP words.

    Replays `qr_cordic`'s per-step dataflow with the identical `GivensUnit`
    arithmetic, so the output words match the reference loop bit for bit.
    """
    unit = GivensUnit(cfg)
    P = p_ref[...]                       # (TB, m, e) int64 packed words
    for (k, j, col) in steps:
        rx, ry = unit.rotate_rows(P[:, k, col:], P[:, j, col:])
        ry = ry.at[:, 0].set(0)          # structural zero (systolic array)
        P = P.at[:, k, col:].set(rx)
        P = P.at[:, j, col:].set(ry)
    o_ref[...] = P


def qr_packed_call(P, *, cfg: GivensConfig, steps, interpret: bool = True,
                   tile_b: int = TILE_B):
    """Blocked QR over packed FP words, one grid cell per TILE_B matrices.

    Parameters
    ----------
    P : (B, m, e) int64
        Packed FP words of the augmented working matrices ([A | I] rows for
        a full QRD).  ``B`` must be a multiple of ``tile_b`` (`ops.py`
        pads).
    cfg : GivensConfig
        Static unit configuration (format, N, iters, HUB flags).
    steps : tuple[(int, int, int), ...]
        Static rotation schedule ``(pivot_row, target_row, col)``.
    interpret : bool
        int64 lanes + in-kernel converters: interpret mode only today.

    Returns
    -------
    (B, m, e) int64 — the triangularized packed working matrices.
    """
    B, m, e = P.shape
    assert B % tile_b == 0
    grid = (B // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e), lambda b: (b, 0, 0))
    kernel = functools.partial(_qr_packed_kernel, cfg=cfg, steps=tuple(steps))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, m, e), jnp.int64),
        interpret=interpret,
    )(P)


# ---------------------------------------------------------------------------
# int32 block-fixed-point kernel (significand-resident fast path)
# ---------------------------------------------------------------------------
def _qr_blockfp_kernel(x_ref, o_ref, *, iters: int, hub: bool, comp: int,
                       steps):
    X = x_ref[...]                       # (TB, m, e) int32 significands
    for (k, j, col) in steps:
        rx, ry = fused_rotate_block(X[:, k, col:], X[:, j, col:],
                                    iters=iters, hub=hub, comp=comp)
        ry = ry.at[:, 0].set(0)
        X = X.at[:, k, col:].set(rx)
        X = X.at[:, j, col:].set(ry)
    o_ref[...] = X


def qr_blockfp_call(X, *, iters: int, hub: bool, steps,
                    interpret: bool = True, tile_b: int = TILE_B):
    """Blocked QR over int32 block-FP significands (single decode at end).

    Parameters
    ----------
    X : (B, m, e) int32
        Significands with F fraction bits, one shared exponent per
        (matrix, column) — see `ops.givens_block_apply` for the
        quantization.  |X| ≤ 2^F on entry; the two CORDIC growth bits plus
        column-norm accumulation (≤ √m) must keep intermediates inside
        int32, so F = 24 supports m up to ~64.
    iters, hub : static CORDIC depth and HUB/conventional arithmetic.
    steps : static (pivot, target, col) schedule.

    Returns
    -------
    (B, m, e) int32 — triangularized significands (same per-column scale).
    """
    B, m, e = X.shape
    assert B % tile_b == 0 and iters <= 30
    grid = (B // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e), lambda b: (b, 0, 0))
    kernel = functools.partial(_qr_blockfp_kernel, iters=iters, hub=hub,
                               comp=comp_q30(iters), steps=tuple(steps))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, m, e), jnp.int32),
        interpret=interpret,
    )(X)
