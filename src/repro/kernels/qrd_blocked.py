"""Kernel-resident blocked QR: one `pallas_call` per triangularization.

The reference loop (`repro.core.qrd.qr_cordic`) launches one rotation per
schedule step from Python: every step reads the two packed rows from HBM,
runs the unit, and writes them back — 2·steps HBM passes over the working
set, plus per-step dispatch overhead.  The paper's FPGA never does this: the
control word is computed once per row pair and *replayed inside the
pipeline* (DESIGN.md §2, §5).  These kernels restore that property on the
TPU: the whole (batched) m×e working tile is staged into VMEM once, every
schedule step runs on the resident tile, and the result is written back
once.

Three datapaths, one schedule machinery:

`qr_packed_call` — bit-exact packed-word datapath (int64 lanes)
    The tile holds *packed FP words* (int64, see `repro.core.formats`).
    Each schedule step performs the unit's full per-step dataflow in
    registers — input-convert (block-FP align), CORDIC vectoring on the
    leading pair, sigma-replay rotation across the rows, gain compensation,
    output-convert — by calling the same `GivensUnit` arithmetic as the
    reference loop.  (Q, R) are therefore **bit-identical** to `qr_cordic`
    for any `GivensConfig` (IEEE and HUB).  int64 lanes: interpret mode
    only; it is the semantic reference for the two fast datapaths.

`qr_packed_lanes_call` — bit-exact packed-word datapath (dual int32 lanes)
    The same packed words carried as (hi, lo) int32 lane pairs on a
    trailing axis of size 2 (`cordic_givens.packed_to_lanes`), rotated by
    the emulated-64-bit `LaneUnit` (`repro.kernels.packed_lanes`) — no
    64-bit integer types anywhere in the kernel, so this datapath lowers
    through Mosaic/Triton (DESIGN.md §11).  Bit-identical to
    `qr_packed_call` by construction (asserted by tests).

`qr_blockfp_call` — int32 block-fixed-point datapath (the TPU fast path)
    The tile holds int32 significands quantized once, outside the kernel,
    with one shared exponent per (matrix, column) — Givens rotations only
    ever combine same-column elements of two rows, so per-column block-FP
    scaling is invariant under the whole schedule.  Rows stay fixed-point
    across *all* rotation steps: no per-step FP round-trips at all, a
    single FP decode after the kernel returns.  Arithmetic is the fused
    int32 pipeline of `cordic_givens` (w ≤ 30 bits, Q30 gain compensation),
    so every intermediate fits the VPU's native int32 lanes — this path
    runs ``interpret=False`` today wherever a Pallas compiler exists.

Two schedule machineries (one per sequential-depth regime):

step-serial (`qr_packed_call` / `qr_packed_lanes_call` / `qr_blockfp_call`)
    Schedules are static tuples of `(pivot_row, target_row, col)` triples
    (column-major `givens_schedule` or a flattened Sameh–Kuck pairing from
    `repro.core.qrd`), unrolled at trace time — the kernel body is a
    straight line of micro-rotation recurrences, exactly like the FPGA
    pipeline.  Depth: one dependent rotation per step.

wavefront (`qr_*_wavefront_call`, §8)
    The Sameh–Kuck schedule enters as (S, Pmax) stage index tables
    consumed by `lax.scan`: each iteration gathers ALL row pairs of one
    stage into two (TILE_B, Pmax, e) tensors, rotates the whole pair axis
    in one shot (per-pair column masks replace the ragged `[col:]`
    slices), and scatters the rows back.  Depth: one scan iteration per
    stage — min(m+n−2, 2m−3) instead of ~m·n/2 — and the trace holds one
    stage body instead of the unrolled schedule.  ``table_layout``
    selects how the three tables travel to the kernel: ``'split'`` (three
    (S, Pmax) operands) or ``'stacked'`` (one (3S, Pmax) operand, a
    single block transfer) — an autotuner search dimension
    (`repro.kernels.autotune`).

Batch handling: every ``*_call`` wrapper pads the leading batch axis up to
a multiple of ``tile_b`` with all-zero matrices (harmless through every
datapath — vectoring on packed/block-FP zeros is exact) and slices the
result back, so ragged batches are first-class here, not just in `ops.py`.

VMEM budget (DESIGN.md §5, §8): one (tile_b, m, e) tile per operand/result
— int64 packed: 2·tb·m·e·8 bytes; dual-lane packed: the same bytes as
int32 (tile_b, m, e, 2); int32 block-FP: 2·tb·m·e·4 bytes.  A 64×128
augmented tall-skinny tile in block-FP at tile_b=8 is 8·64·192·4 ≈ 393 KiB
·2, well inside the ~16 MiB VMEM of a TPU core; `autotune` searches
tile_b under an explicit budget.  The wavefront path adds two
(tile_b, Pmax ≤ m/2, e) pair tensors per stage (≈ the tile itself) plus
< 1 KiB of stage tables.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.givens import GivensConfig, GivensUnit
from .cordic_givens import (TILE_B, comp_q30, fused_replay,
                            fused_rotate_block, fused_rotate_ctrl,
                            fused_rotate_pairs)
from .packed_lanes import LaneUnit

__all__ = ["qr_packed_call", "qr_blockfp_call", "qr_packed_wavefront_call",
           "qr_blockfp_wavefront_call", "qr_packed_complex_call",
           "qr_packed_complex_wavefront_call", "qr_packed_lanes_call",
           "qr_packed_lanes_wavefront_call", "panel_factor_packed_call",
           "panel_apply_packed_call", "panel_factor_blockfp_call",
           "panel_apply_blockfp_call", "TILE_B", "TABLE_LAYOUTS",
           "HBM_PASSES_PER_QRD"]

TABLE_LAYOUTS = ("split", "stacked")

#: The kernel-resident HBM-traffic contract every `*_call` here honors:
#: the working tile is staged into VMEM once and written back once —
#: two passes over the (B, m, e) working set per decomposition,
#: independent of schedule length.  `repro.launch.perfmodel` builds the
#: roofline's memory term from this.
HBM_PASSES_PER_QRD = 2


def _pad_batch(X, tile_b: int):
    """Pad the leading batch axis to a multiple of tile_b with zeros.

    Packed words, lane words and block-FP significands all encode exact
    zero as the all-zero bit pattern, and the whole datapath is exact on
    all-zero matrices (the wavefront gather already relies on this), so
    zero-padding is harmless.  Returns (padded, original_B).
    """
    B = X.shape[0]
    pad = (-B) % tile_b
    if pad:
        X = jnp.pad(X, ((0, pad),) + ((0, 0),) * (X.ndim - 1))
    return X, B


def _table_operands(piv, tgt, col, table_layout: str):
    """Stage tables -> (operands, in_specs) for the chosen layout."""
    if table_layout not in TABLE_LAYOUTS:
        raise ValueError(f"table_layout must be one of {TABLE_LAYOUTS}, "
                         f"got {table_layout!r}")
    S, Pmax = piv.shape
    if table_layout == "stacked":
        tab = jnp.concatenate([jnp.asarray(piv), jnp.asarray(tgt),
                               jnp.asarray(col)], axis=0)
        return (tab,), [pl.BlockSpec((3 * S, Pmax), lambda b: (0, 0))]
    tspec = pl.BlockSpec((S, Pmax), lambda b: (0, 0))
    return ((jnp.asarray(piv), jnp.asarray(tgt), jnp.asarray(col)),
            [tspec, tspec, tspec])


def _read_tables(tab_refs, S: int, table_layout: str):
    """Kernel-side inverse of `_table_operands` (static S slicing)."""
    if table_layout == "stacked":
        (t_ref,) = tab_refs
        tab = t_ref[...]
        return tab[:S], tab[S:2 * S], tab[2 * S:]
    piv_ref, tgt_ref, col_ref = tab_refs
    return piv_ref[...], tgt_ref[...], col_ref[...]


# ---------------------------------------------------------------------------
# Bit-exact packed-word kernel (int64 lanes, interpret-mode reference)
# ---------------------------------------------------------------------------
def _qr_packed_kernel(p_ref, o_ref, *, cfg: GivensConfig, steps):
    """Triangularize the resident (TB, m, e) tile of packed FP words.

    Replays `qr_cordic`'s per-step dataflow with the identical `GivensUnit`
    arithmetic, so the output words match the reference loop bit for bit.
    """
    unit = GivensUnit(cfg)
    P = p_ref[...]                       # (TB, m, e) int64 packed words
    for (k, j, col) in steps:
        rx, ry = unit.rotate_rows(P[:, k, col:], P[:, j, col:])
        ry = ry.at[:, 0].set(0)          # structural zero (systolic array)
        P = P.at[:, k, col:].set(rx)
        P = P.at[:, j, col:].set(ry)
    o_ref[...] = P


def qr_packed_call(P, *, cfg: GivensConfig, steps, interpret: bool = True,
                   tile_b: int = TILE_B):
    """Blocked QR over packed FP words, one grid cell per tile_b matrices.

    Parameters
    ----------
    P : (B, m, e) int64
        Packed FP words of the augmented working matrices ([A | I] rows for
        a full QRD).  Ragged ``B`` is padded to a multiple of ``tile_b``
        with zero matrices and sliced back.
    cfg : GivensConfig
        Static unit configuration (format, N, iters, HUB flags).
    steps : tuple[(int, int, int), ...]
        Static rotation schedule ``(pivot_row, target_row, col)``.
    interpret : bool
        int64 lanes: interpret mode only — the compiled path is
        `qr_packed_lanes_call` on the hi/lo split of the same words.

    Returns
    -------
    (B, m, e) int64 — the triangularized packed working matrices.
    """
    P, B = _pad_batch(P, tile_b)
    Bp, m, e = P.shape
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e), lambda b: (b, 0, 0))
    kernel = functools.partial(_qr_packed_kernel, cfg=cfg, steps=tuple(steps))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e), jnp.int64),
        interpret=interpret,
    )(P)
    return out[:B]


# ---------------------------------------------------------------------------
# Bit-exact packed-word kernels on dual int32 lanes (the compilable path)
# ---------------------------------------------------------------------------
def _qr_packed_lanes_kernel(p_ref, o_ref, *, cfg: GivensConfig, steps):
    """`_qr_packed_kernel` on the (TB, m, e, 2) hi/lo lane tile.

    The `LaneUnit` emulates the unit's int64 arithmetic over (hi, lo)
    int32 pairs (`repro.kernels.packed_lanes`), so this body contains no
    64-bit types and lowers through Mosaic/Triton.  Bit-identical to the
    int64 kernel on `lanes_to_packed` of the result.
    """
    unit = LaneUnit(cfg)
    P = p_ref[...]                       # (TB, m, e, 2) int32 lane words
    for (k, j, col) in steps:
        rx, ry = unit.rotate_rows(P[:, k, col:, :], P[:, j, col:, :])
        ry = ry.at[:, 0, :].set(0)       # structural zero (both lanes)
        P = P.at[:, k, col:, :].set(rx)
        P = P.at[:, j, col:, :].set(ry)
    o_ref[...] = P


def qr_packed_lanes_call(P, *, cfg: GivensConfig, steps,
                         interpret: bool = False, tile_b: int = TILE_B):
    """Blocked QR over dual-int32 packed lane words (compilable bit-exact).

    Parameters as `qr_packed_call` with ``P : (B, m, e, 2) int32`` from
    `cordic_givens.packed_to_lanes`; returns the rotated lane words,
    satisfying ``lanes_to_packed(out) == qr_packed_call(packed)`` bit for
    bit.  ``interpret`` defaults to False — this datapath exists to
    compile; pass True on CPU (ops.py auto-selects).
    """
    P, B = _pad_batch(P, tile_b)
    Bp, m, e, two = P.shape
    assert two == 2
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e, 2), lambda b: (b, 0, 0, 0))
    kernel = functools.partial(_qr_packed_lanes_kernel, cfg=cfg,
                               steps=tuple(steps))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e, 2), jnp.int32),
        interpret=interpret,
    )(P)
    return out[:B]


def _wavefront_scan_lanes(P, tables, stage_fn):
    """`_wavefront_scan` for tiles with a trailing lane axis (TB, m, e, 2).

    The element-axis masks gain a trailing singleton so they broadcast
    across the (hi, lo) lanes; the structural zero forces both lanes (the
    packed zero word is the all-zero bit pattern).
    """
    TB, m, e, _ = P.shape

    def body(P, tab):
        piv, tgt, col = tab
        X = jnp.take(P, piv, axis=1, mode="fill", fill_value=0)
        Y = jnp.take(P, tgt, axis=1, mode="fill", fill_value=0)
        colid = jax.lax.broadcasted_iota(jnp.int32, (col.shape[0], e), 1)
        lead = colid == col[:, None]                      # (P, e)
        active = (colid >= col[:, None])[None, ..., None]
        rx, ry = stage_fn(X, Y, lead)
        rx = jnp.where(active, rx, X)                # untouched left lanes
        ry = jnp.where(active, ry, Y)
        ry = jnp.where(lead[None, ..., None], 0, ry)      # structural zero
        P = P.at[:, piv, :, :].set(rx, mode="drop")
        P = P.at[:, tgt, :, :].set(ry, mode="drop")
        return P, None

    P, _ = jax.lax.scan(body, P, tables)
    return P


def _qr_packed_lanes_wavefront_kernel(*refs, cfg: GivensConfig, S: int,
                                      table_layout: str):
    """Wavefront triangularization of the resident (TB, m, e, 2) lane tile.

    The lane-pair mirror of `_qr_packed_wavefront_kernel`: same stage
    machinery, `LaneUnit` arithmetic, one-hot lead contraction over the
    element axis per lane (exact — the contraction just selects words).
    """
    *tab_refs, p_ref, o_ref = refs
    unit = LaneUnit(cfg)

    def stage(X, Y, lead):
        sel = lead[None, ..., None].astype(X.dtype)       # (1, P, e, 1)
        xl = jnp.sum(X * sel, axis=-2, dtype=X.dtype)     # (TB, P, 2)
        yl = jnp.sum(Y * sel, axis=-2, dtype=Y.dtype)
        _, _, (flip, sig) = unit.vector(xl, yl)
        return unit.rotate(X, Y, (flip[..., None], sig[..., None, :]))

    tables = _read_tables(tab_refs, S, table_layout)
    o_ref[...] = _wavefront_scan_lanes(p_ref[...], tables, stage)


def qr_packed_lanes_wavefront_call(P, piv, tgt, col, *, cfg: GivensConfig,
                                   interpret: bool = False,
                                   tile_b: int = TILE_B,
                                   table_layout: str = "split"):
    """Wavefront blocked QR over dual-int32 packed lane words.

    Parameters as `qr_packed_wavefront_call` with the (B, m, e, 2) lane
    operand of `qr_packed_lanes_call`; bit-identical to it on the
    flattened stage schedule.
    """
    P, B = _pad_batch(P, tile_b)
    Bp, m, e, two = P.shape
    assert two == 2
    S, Pmax = piv.shape
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e, 2), lambda b: (b, 0, 0, 0))
    tab_ops, tab_specs = _table_operands(piv, tgt, col, table_layout)
    kernel = functools.partial(_qr_packed_lanes_wavefront_kernel, cfg=cfg,
                               S=S, table_layout=table_layout)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[*tab_specs, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e, 2), jnp.int32),
        interpret=interpret,
    )(*tab_ops, P)
    return out[:B]


# ---------------------------------------------------------------------------
# Complex packed-word kernels: three-rotation Givens on (re, im) lane pairs
# (DESIGN.md §10).  The resident tile gains a trailing axis of size 2; the
# schedule machinery (static step unroll / stage-table scan) is unchanged.
# int64 lanes (interpret mode) — the dual-lane split covers the real
# datapath only today (DESIGN.md §11).
# ---------------------------------------------------------------------------
def _qr_packed_complex_kernel(p_ref, o_ref, *, cfg: GivensConfig, steps):
    """Triangularize the resident (TB, m, e, 2) tile of packed re/im lanes.

    Replays `qr_cordic_complex`'s per-step three-rotation dataflow with
    the identical `GivensUnit` arithmetic, so the output words match the
    host reference loop bit for bit (IEEE and HUB).
    """
    unit = GivensUnit(cfg)
    P = p_ref[...]                       # (TB, m, e, 2) int64 packed words
    for (k, j, col) in steps:
        rx, ry = unit.rotate_rows_complex(P[:, k, col:, :], P[:, j, col:, :])
        P = P.at[:, k, col:, :].set(rx)
        P = P.at[:, j, col:, :].set(ry)
    o_ref[...] = P


def qr_packed_complex_call(P, *, cfg: GivensConfig, steps,
                           interpret: bool = True, tile_b: int = TILE_B):
    """Blocked complex QR over packed (re, im) lane pairs.

    Parameters
    ----------
    P : (B, m, e, 2) int64
        Packed FP words of the augmented complex working matrices; the
        trailing axis holds the (re, im) lanes.  Ragged ``B`` is padded
        to a multiple of ``tile_b`` and sliced back.
    cfg, steps, interpret : as `qr_packed_call`.

    Returns
    -------
    (B, m, e, 2) int64 — triangularized packed words, bit-identical to
    the `qr_cordic_complex` reference loop.
    """
    P, B = _pad_batch(P, tile_b)
    Bp, m, e, two = P.shape
    assert two == 2
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e, 2), lambda b: (b, 0, 0, 0))
    kernel = functools.partial(_qr_packed_complex_kernel, cfg=cfg,
                               steps=tuple(steps))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e, 2), jnp.int64),
        interpret=interpret,
    )(P)
    return out[:B]


def _wavefront_scan_complex(P, tables, stage_fn):
    """Complex counterpart of `_wavefront_scan` on a (TB, m, e, 2) tile.

    Identical gather/scatter machinery with the (re, im) lane axis riding
    along; the per-pair column masks are unchanged — they address the
    element axis and broadcast across the re/im lanes.  The structural
    zeros of the complex step are forced here: the annihilated target
    lead (both lanes) and the imaginary lane of the realized pivot lead.
    """
    TB, m, e, _ = P.shape

    def body(P, tab):
        piv, tgt, col = tab
        X = jnp.take(P, piv, axis=1, mode="fill", fill_value=0)
        Y = jnp.take(P, tgt, axis=1, mode="fill", fill_value=0)
        colid = jax.lax.broadcasted_iota(jnp.int32, (col.shape[0], e), 1)
        lead = (colid == col[:, None])[None, ..., None]   # (1, P, e, 1)
        active = (colid >= col[:, None])[None, ..., None]
        rx, ry = stage_fn(X, Y, lead[0, ..., 0])
        rx = jnp.where(active, rx, X)                # untouched left lanes
        ry = jnp.where(active, ry, Y)
        im = jnp.arange(2) == 1
        rx = jnp.where(lead & im, 0, rx)             # realized pivot is real
        ry = jnp.where(lead, 0, ry)                  # structural zero
        P = P.at[:, piv, :, :].set(rx, mode="drop")
        P = P.at[:, tgt, :, :].set(ry, mode="drop")
        return P, None

    P, _ = jax.lax.scan(body, P, tables)
    return P


def _qr_packed_complex_wavefront_kernel(*refs, cfg: GivensConfig, S: int,
                                        table_layout: str):
    """Wavefront complex triangularization of the resident (TB, m, e, 2) tile.

    One scan step per Sameh–Kuck stage: every pair of the stage runs the
    three-rotation decomposition along a (TB, P, e) pair axis — the phase
    control words come from vectoring on the gathered lead (re, im)
    pairs, replay across the whole row at uniform width (replaying a
    control word on the pair that produced it reproduces the vectoring
    output bit for bit), and the realized leads re-extracted from the
    phase-rotated rows drive the real Givens across both lanes.
    Bit-identical to `_qr_packed_complex_kernel` on the flattened stage
    schedule.
    """
    *tab_refs, p_ref, o_ref = refs
    unit = GivensUnit(cfg)

    def stage(X, Y, lead):
        sel = lead[None].astype(X.dtype)             # (1, P, e) 0/1
        xr, xi = X[..., 0], X[..., 1]                # (TB, P, e)
        yr, yi = Y[..., 0], Y[..., 1]
        _, stx, skx = unit.phase_vector(
            jnp.sum(xr * sel, axis=-1, dtype=X.dtype),
            jnp.sum(xi * sel, axis=-1, dtype=X.dtype))
        _, sty, sky = unit.phase_vector(
            jnp.sum(yr * sel, axis=-1, dtype=Y.dtype),
            jnp.sum(yi * sel, axis=-1, dtype=Y.dtype))
        pxr, pxi = unit.phase_rotate(
            xr, xi, (stx[0][..., None], stx[1][..., None]), skx[..., None])
        pyr, pyi = unit.phase_rotate(
            yr, yi, (sty[0][..., None], sty[1][..., None]), sky[..., None])
        magx = jnp.sum(pxr * sel, axis=-1, dtype=X.dtype)
        magy = jnp.sum(pyr * sel, axis=-1, dtype=Y.dtype)
        _, _, (flip, sig) = unit.vector(magx, magy)
        st_b = (flip[..., None], sig[..., None])
        rxr, ryr = unit.rotate(pxr, pyr, st_b)
        rxi, ryi = unit.rotate(pxi, pyi, st_b)
        return (jnp.stack([rxr, rxi], axis=-1),
                jnp.stack([ryr, ryi], axis=-1))

    tables = _read_tables(tab_refs, S, table_layout)
    o_ref[...] = _wavefront_scan_complex(p_ref[...], tables, stage)


def qr_packed_complex_wavefront_call(P, piv, tgt, col, *, cfg: GivensConfig,
                                     interpret: bool = True,
                                     tile_b: int = TILE_B,
                                     table_layout: str = "split"):
    """Wavefront blocked complex QR over packed (re, im) lane pairs.

    Parameters as `qr_packed_wavefront_call` with the (B, m, e, 2)
    operand of `qr_packed_complex_call`.

    Returns
    -------
    (B, m, e, 2) int64 — triangularized packed words, bit-identical to
    `qr_packed_complex_call` on the flattened stage schedule.
    """
    P, B = _pad_batch(P, tile_b)
    Bp, m, e, two = P.shape
    assert two == 2
    S, Pmax = piv.shape
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e, 2), lambda b: (b, 0, 0, 0))
    tab_ops, tab_specs = _table_operands(piv, tgt, col, table_layout)
    kernel = functools.partial(_qr_packed_complex_wavefront_kernel, cfg=cfg,
                               S=S, table_layout=table_layout)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[*tab_specs, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e, 2), jnp.int64),
        interpret=interpret,
    )(*tab_ops, P)
    return out[:B]


# ---------------------------------------------------------------------------
# int32 block-fixed-point kernel (significand-resident fast path)
# ---------------------------------------------------------------------------
def _qr_blockfp_kernel(x_ref, o_ref, *, iters: int, hub: bool, comp: int,
                       steps):
    X = x_ref[...]                       # (TB, m, e) int32 significands
    for (k, j, col) in steps:
        rx, ry = fused_rotate_block(X[:, k, col:], X[:, j, col:],
                                    iters=iters, hub=hub, comp=comp)
        ry = ry.at[:, 0].set(0)
        X = X.at[:, k, col:].set(rx)
        X = X.at[:, j, col:].set(ry)
    o_ref[...] = X


def _wavefront_scan(P, tables, stage_fn):
    """Run `stage_fn` over every Sameh–Kuck stage of the resident tile.

    P : (TB, m, e) resident working tile (packed int64 or block-FP int32).
    tables : three (S, Pmax) int32 arrays — pivot rows, target rows,
        leading columns, one row per stage, padded with ``piv = tgt = m``.
    stage_fn : (X, Y, lead) -> (rx, ry) — the pair-axis rotation on the
        gathered (TB, Pmax, e) pivot/target tensors, with `lead` the
        (Pmax, e) one-hot of each pair's leading column.

    One `lax.scan` iteration per stage: gather the stage's pivot and
    target rows into two (TB, Pmax, e) pair tensors, rotate the whole pair
    axis at uniform width e, restore the left-of-lead lanes from the
    inputs (they belong to earlier, already-annihilated columns, which the
    sequential path never touches), force the structural zero, and scatter
    the rotated rows back.  The padding convention makes both transfers
    total functions: padded pairs carry the out-of-range row index ``m``,
    so the mode='fill' gather hands them all-zero rows (harmless through
    the integer datapath) and the mode='drop' scatter discards their
    updates — deterministically, since within a stage the real row indices
    are disjoint by construction.  Sequential depth is the number of
    stages, not the number of rotations.
    """
    TB, m, e = P.shape

    def body(P, tab):
        piv, tgt, col = tab
        X = jnp.take(P, piv, axis=1, mode="fill", fill_value=0)
        Y = jnp.take(P, tgt, axis=1, mode="fill", fill_value=0)
        colid = jax.lax.broadcasted_iota(jnp.int32, (col.shape[0], e), 1)
        lead = colid == col[:, None]
        active = colid >= col[:, None]
        rx, ry = stage_fn(X, Y, lead)
        rx = jnp.where(active[None], rx, X)          # untouched left lanes
        ry = jnp.where(active[None], ry, Y)
        ry = jnp.where(lead[None], 0, ry)            # structural zero
        P = P.at[:, piv, :].set(rx, mode="drop")
        P = P.at[:, tgt, :].set(ry, mode="drop")
        return P, None

    P, _ = jax.lax.scan(body, P, tables)
    return P


def _qr_packed_wavefront_kernel(*refs, cfg: GivensConfig, S: int,
                                table_layout: str):
    """Wavefront triangularization of the resident packed (TB, m, e) tile.

    Same `GivensUnit` arithmetic as `_qr_packed_kernel`, but one scan step
    per Sameh–Kuck *stage*: every pair of the stage runs the full
    input-convert → vectoring → sigma-replay → gain → output-convert
    dataflow along a (TB, P, e) pair axis.  Within-stage rotations touch
    disjoint rows, so the result is bit-identical to replaying the
    flattened schedule pair by pair.
    """
    *tab_refs, p_ref, o_ref = refs
    unit = GivensUnit(cfg)

    def stage(X, Y, lead):
        sel = lead[None].astype(X.dtype)
        xl = jnp.sum(X * sel, axis=-1)               # (TB, P) leading pair
        yl = jnp.sum(Y * sel, axis=-1)
        _, _, (flip, sig) = unit.vector(xl, yl)
        # Replaying sigma on the leading column reproduces the vectoring
        # output bit for bit, so the whole row rotates at uniform width.
        return unit.rotate(X, Y, (flip[..., None], sig[..., None]))

    tables = _read_tables(tab_refs, S, table_layout)
    o_ref[...] = _wavefront_scan(p_ref[...], tables, stage)


def qr_packed_wavefront_call(P, piv, tgt, col, *, cfg: GivensConfig,
                             interpret: bool = True, tile_b: int = TILE_B,
                             table_layout: str = "split"):
    """Wavefront blocked QR over packed FP words (bit-exact path).

    Parameters
    ----------
    P : (B, m, e) int64
        Packed FP words of the augmented working matrices; ragged ``B``
        is padded to a multiple of ``tile_b`` and sliced back.
    piv, tgt, col : (S, Pmax) int32
        Stage index tables — one row per Sameh–Kuck stage, padded with
        ``piv = tgt = m`` / ``col = 0`` (see `ops._stage_tables`).
    cfg : GivensConfig
        Static unit configuration.
    table_layout : 'split' | 'stacked'
        How the stage tables travel to the kernel (autotuner dimension).

    Returns
    -------
    (B, m, e) int64 — triangularized packed words, bit-identical to
    `qr_packed_call` on the flattened stage schedule.
    """
    P, B = _pad_batch(P, tile_b)
    Bp, m, e = P.shape
    S, Pmax = piv.shape
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e), lambda b: (b, 0, 0))
    tab_ops, tab_specs = _table_operands(piv, tgt, col, table_layout)
    kernel = functools.partial(_qr_packed_wavefront_kernel, cfg=cfg,
                               S=S, table_layout=table_layout)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[*tab_specs, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e), jnp.int64),
        interpret=interpret,
    )(*tab_ops, P)
    return out[:B]


def _qr_blockfp_wavefront_kernel(*refs, iters: int, hub: bool, comp: int,
                                 S: int, table_layout: str):
    *tab_refs, x_ref, o_ref = refs
    stage = functools.partial(fused_rotate_pairs, iters=iters, hub=hub,
                              comp=comp)
    tables = _read_tables(tab_refs, S, table_layout)
    o_ref[...] = _wavefront_scan(x_ref[...], tables, stage)


def qr_blockfp_wavefront_call(X, piv, tgt, col, *, iters: int, hub: bool,
                              interpret: bool = True, tile_b: int = TILE_B,
                              table_layout: str = "split"):
    """Wavefront blocked QR over int32 block-FP significands.

    Parameters as `qr_blockfp_call`, with the static step schedule replaced
    by the (S, Pmax) stage index tables of `qr_packed_wavefront_call`.
    Bit-identical to `qr_blockfp_call` on the flattened stage schedule
    (within-stage pairs are disjoint; the pair-axis datapath replays the
    same int32 recurrence).
    """
    X, B = _pad_batch(X, tile_b)
    Bp, m, e = X.shape
    assert iters <= 30
    S, Pmax = piv.shape
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e), lambda b: (b, 0, 0))
    tab_ops, tab_specs = _table_operands(piv, tgt, col, table_layout)
    kernel = functools.partial(_qr_blockfp_wavefront_kernel, iters=iters,
                               hub=hub, comp=comp_q30(iters), S=S,
                               table_layout=table_layout)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[*tab_specs, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e), jnp.int32),
        interpret=interpret,
    )(*tab_ops, X)
    return out[:B]


def qr_blockfp_call(X, *, iters: int, hub: bool, steps,
                    interpret: bool = True, tile_b: int = TILE_B):
    """Blocked QR over int32 block-FP significands (single decode at end).

    Parameters
    ----------
    X : (B, m, e) int32
        Significands with F fraction bits, one shared exponent per
        (matrix, column) — see `ops.givens_block_apply` for the
        quantization.  |X| ≤ 2^F on entry; the two CORDIC growth bits plus
        column-norm accumulation (≤ √m) must keep intermediates inside
        int32, so F = 24 supports m up to ~64.  Ragged ``B`` is padded to
        a multiple of ``tile_b`` and sliced back.
    iters, hub : static CORDIC depth and HUB/conventional arithmetic.
    steps : static (pivot, target, col) schedule.

    Returns
    -------
    (B, m, e) int32 — triangularized significands (same per-column scale).
    """
    X, B = _pad_batch(X, tile_b)
    Bp, m, e = X.shape
    assert iters <= 30
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, m, e), lambda b: (b, 0, 0))
    kernel = functools.partial(_qr_blockfp_kernel, iters=iters, hub=hub,
                               comp=comp_q30(iters), steps=tuple(steps))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, m, e), jnp.int32),
        interpret=interpret,
    )(X)
    return out[:B]


# ---------------------------------------------------------------------------
# Tiled panel QR (DESIGN.md §14): factor a resident (TB, mr, pw) panel while
# *exporting* its rotation control words, then replay them over the trailing
# panels with a second kernel whose grid is batched over the trailing-panel
# axis — the paper's compute-once/replay-everywhere contract extended across
# kernel launches.  The step machinery is a `lax.scan` over (S,) local step
# tables (pivot row, target row, column — all panel-relative), NOT the
# unrolled straight-line body of the flat kernels: a 64-wide panel carries
# hundreds of steps and the scan keeps the trace at one body.
#
# Bit-exactness: with the column-major schedule the panel decomposition
# replays the *identical* rotation sequence as the flat kernel — each
# rotation is elementwise in the column axis once its (flip, sigma) word is
# fixed, so deferring the trailing-panel columns to the apply kernel cannot
# change a single bit (tests assert equality against `qr_packed_call`).
# The uniform-width rotate + left-lane restore is the wavefront convention
# (`_wavefront_scan`); replaying sigma on the lead reproduces vectoring
# bit for bit.
# ---------------------------------------------------------------------------
def _panel_factor_packed_kernel(piv_ref, tgt_ref, col_ref, p_ref,
                                o_ref, f_ref, s_ref, *, cfg: GivensConfig):
    """Factor the resident (TB, mr, pw) packed panel, exporting controls.

    One scan step per schedule entry: gather the pivot/target rows by the
    traced step index, vector on the lead column (one-hot contraction),
    rotate the pair at uniform panel width, restore the left-of-lead
    lanes, force the structural zero, scatter back — and emit the step's
    (flip, sigma) words into the (TB, S) control outputs.
    """
    unit = GivensUnit(cfg)
    P = p_ref[...]                       # (TB, mr, pw) int64 packed words
    pw = P.shape[-1]
    colid = jax.lax.broadcasted_iota(jnp.int32, (1, pw), 1)

    def body(P, tab):
        piv, tgt, col = tab
        x = P[:, piv]                    # (TB, pw)
        y = P[:, tgt]
        lead = colid == col              # (1, pw)
        active = colid >= col
        sel = lead.astype(x.dtype)
        xl = jnp.sum(x * sel, axis=-1)   # (TB,) leading pair
        yl = jnp.sum(y * sel, axis=-1)
        _, _, (flip, sig) = unit.vector(xl, yl)
        rx, ry = unit.rotate(x, y, (flip[..., None], sig[..., None]))
        rx = jnp.where(active, rx, x)    # untouched left lanes
        ry = jnp.where(active, ry, y)
        ry = jnp.where(lead, 0, ry)      # structural zero
        P = P.at[:, piv].set(rx)  # lint: allow[unguarded-scatter] piv != tgt per step by schedule
        P = P.at[:, tgt].set(ry)
        return P, (flip, sig)

    tables = (piv_ref[...], tgt_ref[...], col_ref[...])
    P, (flips, sigs) = jax.lax.scan(body, P, tables)
    o_ref[...] = P
    f_ref[...] = jnp.transpose(flips)    # (S, TB) -> (TB, S)
    s_ref[...] = jnp.transpose(sigs)


def panel_factor_packed_call(P, piv, tgt, col, *, cfg: GivensConfig,
                             interpret: bool = True, tile_b: int = TILE_B):
    """Panel factorization over packed FP words, exporting control words.

    Parameters
    ----------
    P : (B, mr, pw) int64
        Packed FP words of one panel — the ``mr`` resident rows are the
        not-yet-finalized rows of the full working matrix (global rows
        ``c0..m-1`` for the panel starting at column ``c0``), ``pw`` its
        columns.  Ragged ``B`` is padded with zero matrices, as
        everywhere here.
    piv, tgt, col : (S,) int32
        Panel-local step tables (`ops.panel_steps`) — the column-major
        schedule restricted to this panel, rows relative to the panel.
    cfg : GivensConfig
        Static unit configuration.  int64 lanes: interpret mode only,
        like `qr_packed_call`.

    Returns
    -------
    (out, flip, sig)
        ``out`` (B, mr, pw) int64 — the factored panel (upper-triangular
        head over zeros); ``flip``/``sig`` (B, S) int64 — the exported
        per-step control words, replayable over any trailing panel via
        `panel_apply_packed_call`.
    """
    P, B = _pad_batch(P, tile_b)
    Bp, mr, pw = P.shape
    S = piv.shape[0]
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, mr, pw), lambda b: (b, 0, 0))
    cspec = pl.BlockSpec((tile_b, S), lambda b: (b, 0))
    tspec = pl.BlockSpec((S,), lambda b: (0,))
    kernel = functools.partial(_panel_factor_packed_kernel, cfg=cfg)
    out, flip, sig = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[tspec, tspec, tspec, spec],
        out_specs=[spec, cspec, cspec],
        out_shape=[jax.ShapeDtypeStruct((Bp, mr, pw), jnp.int64),
                   jax.ShapeDtypeStruct((Bp, S), jnp.int64),
                   jax.ShapeDtypeStruct((Bp, S), jnp.int64)],
        interpret=interpret,
    )(jnp.asarray(piv), jnp.asarray(tgt), jnp.asarray(col), P)
    return out[:B], flip[:B], sig[:B]


def _panel_apply_packed_kernel(piv_ref, tgt_ref, f_ref, s_ref, t_ref, o_ref,
                               *, cfg: GivensConfig):
    """Replay a panel's exported control words on one trailing tile.

    The resident tile is one (TB, mr, pw) trailing-panel block; every
    element is active (the rotation set touches whole rows right of the
    factored panel), so no column masks are needed — just the scan over
    the (piv, tgt, flip, sigma) step stream.
    """
    unit = GivensUnit(cfg)
    T = t_ref[...][:, 0]                 # (TB, 1, mr, pw) -> (TB, mr, pw)
    flips = jnp.transpose(f_ref[...])    # (TB, S) -> (S, TB) scan stream
    sigs = jnp.transpose(s_ref[...])

    def body(T, tab):
        piv, tgt, flip, sig = tab
        rx, ry = unit.rotate(T[:, piv], T[:, tgt],
                             (flip[..., None], sig[..., None]))
        T = T.at[:, piv].set(rx)  # lint: allow[unguarded-scatter] piv != tgt per step by schedule
        T = T.at[:, tgt].set(ry)
        return T, None

    T, _ = jax.lax.scan(body, T, (piv_ref[...], tgt_ref[...], flips, sigs))
    o_ref[...] = T[:, None]


def panel_apply_packed_call(T, piv, tgt, flip, sig, *, cfg: GivensConfig,
                            interpret: bool = True, tile_b: int = TILE_B):
    """Replay exported panel controls over the trailing panels.

    The grid is (batch tiles, trailing panels): each cell replays the
    full (S,) rotation set on one (tile_b, mr, pw) trailing block — the
    trailing-panel axis rides the Pallas grid, not just ``tile_b``, so
    wide trailing regions parallelize across cells instead of growing
    the resident tile.

    Parameters
    ----------
    T : (B, G, mr, pw)
        The trailing region, chunked into G panel-width tiles (zero-pad
        the last chunk; rotations are columnwise, so pad columns never
        feed back into real ones).
    piv, tgt : (S,) int32 — panel-local step row tables.
    flip, sig : (B, S) int64 — control words from
        `panel_factor_packed_call`.

    Returns
    -------
    (B, G, mr, pw) int64 — the updated trailing region.
    """
    T, B = _pad_batch(T, tile_b)
    flip, _ = _pad_batch(flip, tile_b)
    sig, _ = _pad_batch(sig, tile_b)
    Bp, G, mr, pw = T.shape
    S = piv.shape[0]
    grid = (Bp // tile_b, G)
    spec = pl.BlockSpec((tile_b, 1, mr, pw), lambda b, g: (b, g, 0, 0))
    cspec = pl.BlockSpec((tile_b, S), lambda b, g: (b, 0))
    tspec = pl.BlockSpec((S,), lambda b, g: (0,))
    kernel = functools.partial(_panel_apply_packed_kernel, cfg=cfg)
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[tspec, tspec, cspec, cspec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, G, mr, pw), jnp.int64),
        interpret=interpret,
    )(jnp.asarray(piv), jnp.asarray(tgt), flip, sig, T)
    return out[:B]


def _panel_factor_blockfp_kernel(piv_ref, tgt_ref, col_ref, x_ref,
                                 o_ref, f_ref, s_ref, *, iters: int,
                                 hub: bool, comp: int):
    """Block-FP mirror of `_panel_factor_packed_kernel` (int32 datapath).

    `fused_rotate_ctrl` runs `fused_rotate_block`'s exact vectoring
    recurrence with the lead selected by one-hot and the (flip, sigma)
    words exported — int32 throughout (sigma ≤ 30 bits), so this panel
    kernel compiles wherever the flat block-FP kernel does.
    """
    X = x_ref[...]                       # (TB, mr, pw) int32 significands
    pw = X.shape[-1]
    colid = jax.lax.broadcasted_iota(jnp.int32, (1, pw), 1)

    def body(X, tab):
        piv, tgt, col = tab
        x = X[:, piv]
        y = X[:, tgt]
        lead = colid == col
        active = colid >= col
        rx, ry, flip, sig = fused_rotate_ctrl(x, y, lead, iters=iters,
                                              hub=hub, comp=comp)
        rx = jnp.where(active, rx, x)    # untouched left lanes
        ry = jnp.where(active, ry, y)
        ry = jnp.where(lead, 0, ry)      # structural zero
        X = X.at[:, piv].set(rx)  # lint: allow[unguarded-scatter] piv != tgt per step by schedule
        X = X.at[:, tgt].set(ry)
        return X, (flip, sig)

    tables = (piv_ref[...], tgt_ref[...], col_ref[...])
    X, (flips, sigs) = jax.lax.scan(body, X, tables)
    o_ref[...] = X
    f_ref[...] = jnp.transpose(flips)
    s_ref[...] = jnp.transpose(sigs)


def panel_factor_blockfp_call(X, piv, tgt, col, *, iters: int, hub: bool,
                              interpret: bool = True, tile_b: int = TILE_B):
    """Panel factorization over int32 block-FP significands.

    Parameters as `panel_factor_packed_call` with ``X : (B, mr, pw)
    int32`` significands (per-column shared exponents are invariant
    under the whole rotation set, so the panel/trailing split needs no
    re-quantization).  Returns ``(out, flip, sig)`` with (B, S) int32
    control words.
    """
    X, B = _pad_batch(X, tile_b)
    Bp, mr, pw = X.shape
    assert iters <= 30
    S = piv.shape[0]
    grid = (Bp // tile_b,)
    spec = pl.BlockSpec((tile_b, mr, pw), lambda b: (b, 0, 0))
    cspec = pl.BlockSpec((tile_b, S), lambda b: (b, 0))
    tspec = pl.BlockSpec((S,), lambda b: (0,))
    kernel = functools.partial(_panel_factor_blockfp_kernel, iters=iters,
                               hub=hub, comp=comp_q30(iters))
    out, flip, sig = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[tspec, tspec, tspec, spec],
        out_specs=[spec, cspec, cspec],
        out_shape=[jax.ShapeDtypeStruct((Bp, mr, pw), jnp.int32),
                   jax.ShapeDtypeStruct((Bp, S), jnp.int32),
                   jax.ShapeDtypeStruct((Bp, S), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(piv), jnp.asarray(tgt), jnp.asarray(col), X)
    return out[:B], flip[:B], sig[:B]


def _panel_apply_blockfp_kernel(piv_ref, tgt_ref, f_ref, s_ref, t_ref, o_ref,
                                *, iters: int, hub: bool, comp: int):
    """Block-FP mirror of `_panel_apply_packed_kernel` (`fused_replay`)."""
    T = t_ref[...][:, 0]                 # (TB, 1, mr, pw) -> (TB, mr, pw)
    flips = jnp.transpose(f_ref[...])
    sigs = jnp.transpose(s_ref[...])

    def body(T, tab):
        piv, tgt, flip, sig = tab
        rx, ry = fused_replay(T[:, piv], T[:, tgt], flip, sig,
                              iters=iters, hub=hub, comp=comp)
        T = T.at[:, piv].set(rx)  # lint: allow[unguarded-scatter] piv != tgt per step by schedule
        T = T.at[:, tgt].set(ry)
        return T, None

    T, _ = jax.lax.scan(body, T, (piv_ref[...], tgt_ref[...], flips, sigs))
    o_ref[...] = T[:, None]


def panel_apply_blockfp_call(T, piv, tgt, flip, sig, *, iters: int,
                             hub: bool, interpret: bool = True,
                             tile_b: int = TILE_B):
    """Replay exported panel controls over int32 block-FP trailing panels.

    Parameters as `panel_apply_packed_call` with int32 operands.
    """
    T, B = _pad_batch(T, tile_b)
    flip, _ = _pad_batch(flip, tile_b)
    sig, _ = _pad_batch(sig, tile_b)
    Bp, G, mr, pw = T.shape
    assert iters <= 30
    S = piv.shape[0]
    grid = (Bp // tile_b, G)
    spec = pl.BlockSpec((tile_b, 1, mr, pw), lambda b, g: (b, g, 0, 0))
    cspec = pl.BlockSpec((tile_b, S), lambda b, g: (b, 0))
    tspec = pl.BlockSpec((S,), lambda b, g: (0,))
    kernel = functools.partial(_panel_apply_blockfp_kernel, iters=iters,
                               hub=hub, comp=comp_q30(iters))
    out = pl.pallas_call(
        kernel, grid=grid,
        in_specs=[tspec, tspec, cspec, cspec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((Bp, G, mr, pw), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(piv), jnp.asarray(tgt), flip, sig, T)
    return out[:B]
