"""Pure-jnp oracles for the Pallas CORDIC kernels.

These mirror the kernel arithmetic *operation for operation* (int32 lanes,
15x15-bit gain multiply) so tests can assert exact integer equality against
the kernels, shape-by-shape.  A second set of tests cross-checks these
oracles against the independent int64 implementation in `repro.core.cordic`.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.cordic import GAIN_TABLE

__all__ = ["vectoring_ref", "rotation_ref", "gain_mul_q30_ref"]


def gain_mul_q30_ref(v, comp: int):
    c_hi = comp >> 15
    c_lo = comp & 0x7FFF
    v_hi = v >> 15
    v_lo = v & 0x7FFF
    return (v_hi * c_hi
            + ((v_hi * c_lo) >> 15)
            + ((v_lo * c_hi) >> 15)
            + ((v_lo * c_lo) >> 30))


def _negate(v, hub):
    return ~v if hub else -v


def _micro(x, y, i, d_pos, hub):
    ys = y >> i
    xs = x >> i
    if hub:
        one = jnp.int32(1)
        cy = one if i == 0 else (y >> (i - 1)) & 1
        cx = one if i == 0 else (x >> (i - 1)) & 1
        x_sub = x + ~ys + (1 - cy)
        x_add = x + ys + cy
        y_add = y + xs + cx
        y_sub = y + ~xs + (1 - cx)
    else:
        x_sub = x - ys
        x_add = x + ys
        y_add = y + xs
        y_sub = y - xs
    return (jnp.where(d_pos, x_sub, x_add),
            jnp.where(d_pos, y_add, y_sub))


def _comp(iters: int) -> int:
    return int(np.rint(2.0 ** 30 / GAIN_TABLE[iters]))


def vectoring_ref(x, y, *, iters: int, hub: bool):
    """x, y: int32 arrays (any shape) -> (xr, yr, flip, sigma)."""
    x = jnp.asarray(x, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    flip = x < 0
    x = jnp.where(flip, _negate(x, hub), x)
    y = jnp.where(flip, _negate(y, hub), y)
    sig = jnp.zeros_like(x)
    for i in range(iters):
        d_pos = y < 0
        x, y = _micro(x, y, i, d_pos, hub)
        sig = sig | (d_pos.astype(jnp.int32) << i)
    comp = _comp(iters)
    return (gain_mul_q30_ref(x, comp), gain_mul_q30_ref(y, comp),
            flip.astype(jnp.int32), sig)


def rotation_ref(x, y, flip, sigma, *, iters: int, hub: bool):
    """x, y: int32 (B, L); flip/sigma: int32 broadcastable -> rotated pair."""
    x = jnp.asarray(x, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    fl = jnp.asarray(flip, jnp.int32) != 0
    sig = jnp.asarray(sigma, jnp.int32)
    x = jnp.where(fl, _negate(x, hub), x)
    y = jnp.where(fl, _negate(y, hub), y)
    for i in range(iters):
        d_pos = ((sig >> i) & 1) == 1
        x, y = _micro(x, y, i, d_pos, hub)
    comp = _comp(iters)
    return gain_mul_q30_ref(x, comp), gain_mul_q30_ref(y, comp)
